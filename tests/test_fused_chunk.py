"""Fused chunk megakernel: interpret-mode parity vs the ref.py oracle
(bit-exact logits / x-grad / weights for a fixed SR seed), the cached-z CE
fast path, fused-vs-unfused head_train_step regression, and block-tuner
sanity.

The bit-exact comparisons target ``jax.jit(ref.fused_chunk_ref)``: the
megakernel is one compiled computation, and on the CPU backend XLA's fusion
of an *eagerly* dispatched op sequence can differ by one ULP from the same
sequence compiled together — the jitted oracle is the apples-to-apples
reference (and what production's "xla" fallback executes).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import elmo_head as H
from repro.core import losses as L
from repro.kernels import ops, ref, tuning

KEY = jax.random.PRNGKey(0)


def _mk(loss, B=32, Lc=256, D=64, w_dtype=jnp.float8_e4m3fn, num_labels=None,
        c0=0):
    num_labels = Lc if num_labels is None else num_labels
    kx, kw, kt, kg = jax.random.split(KEY, 4)
    x = (jax.random.normal(kx, (B, D)) * 0.5).astype(jnp.bfloat16)
    w = (jax.random.normal(kw, (Lc, D)) * 0.05).astype(w_dtype)
    xg = (jax.random.normal(kg, (B, D)) * 0.1).astype(jnp.bfloat16)
    if loss == "bce":
        tg = jax.random.randint(kt, (B, 5), 0, num_labels)
        lse = None
    else:
        tg = jax.random.randint(kt, (B,), -1, num_labels)
        z = ref.fp8_logits_ref(x, w)
        zm = jnp.where((c0 + jnp.arange(Lc))[None, :] < num_labels,
                       z.astype(jnp.float32), L.NEG_INF)
        m, s = L.lse_update(*L.lse_init(B), zm)
        lse = L.lse_finalize(m, s)
    args = (x, w, tg, xg, jnp.float32(0.05), jnp.float32(1e-4),
            jnp.float32(1.0 / B), jnp.int32(c0), jnp.uint32(7),
            jnp.uint32(13))
    return args, dict(loss=loss, num_labels=num_labels), lse


def _ref_jit(kw):
    return jax.jit(functools.partial(ref.fused_chunk_ref, return_z=True,
                                     **kw))


@pytest.mark.parametrize("w_dtype", [jnp.float8_e4m3fn, jnp.bfloat16])
@pytest.mark.parametrize("loss", ["bce", "softmax_ce"])
def test_fused_chunk_bitexact_vs_oracle(loss, w_dtype):
    """Single-tile (the tuner default here): z, x̄, W and the loss scalar
    are bit-identical to the oracle for a fixed SR seed."""
    args, kw, lse = _mk(loss, w_dtype=w_dtype, num_labels=300, Lc=320,
                        c0=0, D=70, B=24)
    k = ops.fused_chunk_step(*args, lse=lse, impl="interpret",
                             return_z=True, **kw)
    r = _ref_jit(kw)(*args, lse=lse)
    for name in ("z", "xg", "w"):
        np.testing.assert_array_equal(
            np.asarray(getattr(k, name), np.float32),
            np.asarray(getattr(r, name), np.float32), err_msg=name)
    assert float(k.loss) == float(r.loss)


def test_fused_chunk_tiled_weights_bitexact():
    """With a split label tile the per-tile W updates stay bit-exact (the
    dW reduction is over B, never split); only x̄ and the loss reassociate."""
    args, kw, _ = _mk("bce", Lc=512, num_labels=500)
    k = ops.fused_chunk_step(*args, impl="interpret", block_l=128, **kw)
    r = _ref_jit(kw)(*args)
    np.testing.assert_array_equal(np.asarray(k.w, np.float32),
                                  np.asarray(r.w, np.float32))
    np.testing.assert_allclose(np.asarray(k.xg, np.float32),
                               np.asarray(r.xg, np.float32),
                               rtol=2e-2, atol=2e-2)
    assert abs(float(k.loss) - float(r.loss)) < 1e-3 * abs(float(r.loss))


def test_fused_chunk_dropconnect_bitexact():
    args, kw, _ = _mk("bce")
    kw = dict(kw, drop_rate=0.5)
    k = ops.fused_chunk_step(*args, impl="interpret", **kw)
    r = jax.jit(functools.partial(ref.fused_chunk_ref, **kw))(*args)
    np.testing.assert_array_equal(np.asarray(k.w, np.float32),
                                  np.asarray(r.w, np.float32))
    np.testing.assert_array_equal(np.asarray(k.xg, np.float32),
                                  np.asarray(r.xg, np.float32))


def test_fused_chunk_kahan_bitexact():
    args, kw, _ = _mk("bce", w_dtype=jnp.bfloat16)
    comp = (jax.random.normal(jax.random.PRNGKey(5), args[1].shape)
            * 1e-4).astype(jnp.bfloat16)
    k = ops.fused_chunk_step(*args, comp=comp, impl="interpret", **kw)
    r = jax.jit(functools.partial(ref.fused_chunk_ref, **kw))(*args,
                                                              comp=comp)
    np.testing.assert_array_equal(np.asarray(k.w, np.float32),
                                  np.asarray(r.w, np.float32))
    np.testing.assert_array_equal(np.asarray(k.comp, np.float32),
                                  np.asarray(r.comp, np.float32))


def test_fused_chunk_cached_z_matches_recompute():
    """CE cached-z fast path: passing the pass-1 logits must change nothing
    (same DropConnect seed ⇒ identical z either way)."""
    args, kw, lse = _mk("softmax_ce", num_labels=300, Lc=320)
    x, w = args[0], args[1]
    z = ref.fp8_logits_ref(x, w)
    k_cached = ops.fused_chunk_step(*args, lse=lse, z=z, impl="interpret",
                                    **kw)
    k_fresh = ops.fused_chunk_step(*args, lse=lse, impl="interpret", **kw)
    for name in ("w", "xg"):
        np.testing.assert_array_equal(
            np.asarray(getattr(k_cached, name), np.float32),
            np.asarray(getattr(k_fresh, name), np.float32), err_msg=name)
    assert float(k_cached.loss) == float(k_fresh.loss)


# ---------------------------------------------------------------------------
# head-level regression: fused vs legacy unfused path
# ---------------------------------------------------------------------------


def _head_setup(loss, impl, cache_z="auto", kahan_chunks=0,
                weight_dtype="e4m3"):
    cfg = H.ELMOHeadConfig(num_labels=300, d_model=64, num_chunks=4,
                           weight_dtype=weight_dtype, loss=loss,
                           use_sr=True, impl=impl, cache_z=cache_z,
                           kahan_chunks=kahan_chunks)
    state = H.init_head(jax.random.PRNGKey(1), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(2), (32, 64)) * 0.5
         ).astype(jnp.bfloat16)
    if loss == "bce":
        tg = jax.random.randint(jax.random.PRNGKey(3), (32, 5), 0, 300)
    else:
        tg = jax.random.randint(jax.random.PRNGKey(3), (32,), -1, 300)
    return cfg, state, x, tg


@pytest.mark.parametrize("loss", ["bce", "softmax_ce"])
def test_head_fused_xla_matches_unfused(loss):
    """impl='xla' (fused oracle) vs impl='unfused_xla' (legacy 3-op path):
    the fused step is the exact composition, so states/metrics agree."""
    outs = {}
    for impl in ("xla", "unfused_xla"):
        cfg, state, x, tg = _head_setup(loss, impl)
        new, xg, m = H.head_train_step(cfg, state, x, tg, jnp.float32(0.1),
                                       jnp.float32(1e-4), jnp.uint32(9))
        outs[impl] = (np.asarray(new.w, np.float32),
                      np.asarray(xg, np.float32), float(m["loss"]))
    np.testing.assert_array_equal(outs["xla"][0], outs["unfused_xla"][0])
    np.testing.assert_array_equal(outs["xla"][1], outs["unfused_xla"][1])
    assert outs["xla"][2] == outs["unfused_xla"][2]


@pytest.mark.parametrize("loss", ["bce", "softmax_ce"])
def test_head_fused_kernel_matches_unfused(loss):
    """impl='interpret' (the megakernel) vs the legacy unfused path —
    identical update values up to one-ULP XLA fusion differences."""
    cfg, state, x, tg = _head_setup(loss, "interpret")
    new_k, xg_k, m_k = H.head_train_step(cfg, state, x, tg, jnp.float32(0.1),
                                         jnp.float32(1e-4), jnp.uint32(9))
    cfg, state, x, tg = _head_setup(loss, "unfused_xla")
    new_u, xg_u, m_u = H.head_train_step(cfg, state, x, tg, jnp.float32(0.1),
                                         jnp.float32(1e-4), jnp.uint32(9))
    # e4m3 weights: the coarse grid absorbs ULP noise except where an SR
    # draw lands on a boundary — allow a vanishing mismatch fraction
    wk = np.asarray(new_k.w, np.float32)
    wu = np.asarray(new_u.w, np.float32)
    assert (wk != wu).mean() < 5e-3, (wk != wu).mean()
    np.testing.assert_allclose(np.asarray(xg_k, np.float32),
                               np.asarray(xg_u, np.float32),
                               rtol=1e-2, atol=1e-2)
    np.testing.assert_allclose(float(m_k["loss"]), float(m_u["loss"]),
                               rtol=1e-5)


def test_head_cache_z_invariant():
    """cache_z on/off: identical CE training step (logits reuse is exact)."""
    outs = []
    for cache_z in ("on", "off"):
        cfg, state, x, tg = _head_setup("softmax_ce", "xla", cache_z=cache_z)
        new, xg, m = H.head_train_step(cfg, state, x, tg, jnp.float32(0.1),
                                       jnp.float32(0.0), jnp.uint32(4))
        outs.append((np.asarray(new.w, np.float32),
                     np.asarray(xg, np.float32), float(m["loss"])))
    np.testing.assert_array_equal(outs[0][0], outs[1][0])
    np.testing.assert_array_equal(outs[0][1], outs[1][1])
    assert outs[0][2] == outs[1][2]


def test_head_fused_kahan_chunks():
    cfg, state, x, tg = _head_setup("bce", "interpret", kahan_chunks=2,
                                    weight_dtype="bf16")
    new, xg, m = H.head_train_step(cfg, state, x, tg, jnp.float32(0.1),
                                   jnp.float32(0.0), jnp.uint32(0))
    assert new.comp.shape == state.comp.shape
    assert np.isfinite(float(m["loss"]))
    assert not np.allclose(np.asarray(new.w, np.float32),
                           np.asarray(state.w, np.float32))


# ---------------------------------------------------------------------------
# block-size tuner
# ---------------------------------------------------------------------------


def test_tuning_blocks_divide_and_fit():
    for B, Lc, D in ((256, 512, 256), (1024, 512, 768), (8, 16, 32),
                     (256, 4096, 256)):
        bb, bl, bd = tuning.logits_blocks(B, Lc, D)
        assert all(v >= 8 for v in (bb, bl, bd))
        # unsplit K whenever K fits a single tile candidate
        if D <= 1024:
            assert bd >= min(D, bd), (B, Lc, D, bd)
            assert tuning._pad_up(D, 8) <= bd or bd >= 1024
        blc = tuning.chunk_block_l(B, Lc, D)
        assert blc >= 128 or blc >= tuning._pad_up(Lc, 8)


def test_tuning_prefers_whole_chunk_when_it_fits():
    assert tuning.chunk_block_l(256, 512, 256) == 512
    # huge resident set: falls back to small tiles / non-viable
    assert not tuning.fused_chunk_viable(8192 * 4, 1024)
    assert tuning.fused_chunk_viable(256, 256)


def test_tuning_table_shape():
    rows = tuning.tuning_table()
    assert {"logits", "input_grad", "update", "fused_chunk_bl"} <= set(
        rows[0].keys())
