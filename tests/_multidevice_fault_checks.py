"""Elastic checkpoint restore checks, run in a subprocess with a forced
host-device count (4; tests/test_fault_tolerance.py drives this via the
``multidevice_runner`` fixture).  Exit code 0 = all checks passed.

The contract under test (DESIGN.md §10, ISSUE 6 acceptance):

* a checkpoint of a label-sharded head W (partitioned ``(None,"model",
  None)``) saved from a 1×4 mesh restores onto a 2×2, 4×1 or 1×4 mesh —
  the manifest stores full-logical leaves, ``restore_checkpoint`` lands
  them via ``dist.sharding.head_state_shardings`` — and continued training
  on the new mesh is **bit-identical** to an uninterrupted single-device
  run (deterministic BF16 + Kahan recipe, where sharded == single-device
  bit-for-bit is the ISSUE-2 guarantee);
* restored leaves are actually sharded (not replicated) on the new mesh;
* corruption fallback works on sharded state too: bit-flip the newest
  committed checkpoint and restore uses the older step.
"""
import os
import tempfile

_N_DEV = int(os.environ.get("REPRO_FORCE_DEVICES", "4"))
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + f" --xla_force_host_platform_device_count={_N_DEV}")

import jax                      # noqa: E402
import jax.numpy as jnp        # noqa: E402
import numpy as np             # noqa: E402

from repro import head as RH                         # noqa: E402
from repro.checkpoint import (restore_checkpoint,    # noqa: E402
                              save_checkpoint)
from repro.dist import meshctx, sharding             # noqa: E402
from repro.fault import inject                       # noqa: E402
from repro.kernels import prng_utils as PR           # noqa: E402
from repro.launch.mesh import make_host_mesh         # noqa: E402

assert len(jax.devices()) == _N_DEV, jax.devices()

B, D, NL = 16, 32, 1000        # chunk=256: divisible by every model size


def _cfg():
    # deterministic recipe: BF16 + full Kahan, no SR/DropConnect — the
    # regime where sharded and single-device steps are bit-identical, so
    # any restore-path bit flip is attributable to the checkpoint store
    return RH.ELMOHeadConfig(num_labels=NL, d_model=D, num_chunks=4,
                             weight_dtype="bf16", loss="bce", use_sr=False,
                             kahan_chunks=4, impl="unfused_xla")


def _batch_for(step):
    rng = np.random.default_rng(7000 + step)
    x = jnp.asarray(rng.standard_normal((B, D), np.float32) * 0.5,
                    jnp.bfloat16)
    tgt = jnp.asarray(rng.integers(0, NL, (B, 8)), jnp.int32)
    return x, tgt


def _run(cfg, state, lo, hi, ctx=None):
    head = RH.get_head(cfg, batch=B, target_slots=8, ctx=ctx)
    for s in range(lo, hi):
        x, tgt = _batch_for(s)
        hp = RH.HeadHparams(jnp.float32(0.05), jnp.float32(1e-4),
                            PR.mix32(jnp.uint32(s)))
        state, _, _ = head.train_step(state, x, tgt, hp)
    return state


def _full_logical(state):
    """Pull every leaf back to one host-local array (what a restore
    template looks like in a fresh process)."""
    return jax.tree.map(lambda a: None if a is None else jnp.asarray(
        np.asarray(a)), state,
        is_leaf=lambda x: x is None or hasattr(x, "shape"))


def check_restore_across_mesh_shapes():
    cfg = _cfg()
    state0 = RH.init_head(jax.random.PRNGKey(0), cfg)
    oracle = _run(cfg, state0, 0, 6)

    # train steps 0..3 label-sharded on 1×4, checkpoint at step 3
    ctx14 = make_host_mesh(1, 4)
    with meshctx.use(ctx14):
        shard14 = sharding.head_state_shardings(state0, ctx14.mesh)
        st = jax.tree.map(jax.device_put, state0, shard14)
        st = _run(cfg, st, 0, 3, ctx=ctx14)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, st._asdict())

        template = _full_logical(RH.init_head(jax.random.PRNGKey(9), cfg))
        for mesh_shape in ((2, 2), (4, 1), (1, 4)):
            ctx = make_host_mesh(*mesh_shape)
            shardings = sharding.head_state_shardings(
                template, ctx.mesh)._asdict()
            restored, step, _ = restore_checkpoint(
                d, template._asdict(), shardings=shardings)
            assert step == 3, step
            restored = RH.HeadState(**restored)
            # the leaf landed sharded on the new mesh, not replicated:
            # each device holds chunk/n_model label rows
            n_model = int(ctx.mesh.shape[ctx.model_axis])
            local = restored.w.addressable_shards[0].data.shape
            assert local[1] == cfg.chunk // n_model, (mesh_shape, local)
            with meshctx.use(ctx):
                resumed = _run(cfg, restored, 3, 6, ctx=ctx)
            assert RH.state_bits_equal(_full_logical(oracle),
                                       _full_logical(resumed)), mesh_shape
            print(f"restore 1x4 -> {mesh_shape[0]}x{mesh_shape[1]} "
                  "bit-identical ok")


def check_sharded_corruption_fallback():
    cfg = _cfg()
    state0 = RH.init_head(jax.random.PRNGKey(0), cfg)
    ctx = make_host_mesh(1, 4)
    with meshctx.use(ctx):
        st = jax.tree.map(jax.device_put, state0,
                          sharding.head_state_shardings(state0, ctx.mesh))
        s3 = _run(cfg, st, 0, 3, ctx=ctx)
        s5 = _run(cfg, s3, 3, 5, ctx=ctx)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, s3._asdict())
        p5 = save_checkpoint(d, 5, s5._asdict())
        inject.bit_flip_leaf(p5, leaf_index=0)

        template = _full_logical(RH.init_head(jax.random.PRNGKey(9), cfg))
        ctx2 = make_host_mesh(2, 2)
        restored, step, _ = restore_checkpoint(
            d, template._asdict(),
            shardings=sharding.head_state_shardings(
                template, ctx2.mesh)._asdict())
        assert step == 3, step
        assert RH.state_bits_equal(RH.HeadState(**restored),
                                   _full_logical(s3))
    print("sharded corruption fallback ok")


if __name__ == "__main__":
    check_restore_across_mesh_shapes()
    check_sharded_corruption_fallback()
    print("ALL FAULT CHECKS PASSED")
