"""Loss-skipping utilities (chunk targets, streaming LSE) + App. D post-hoc
refinement + hypothesis property tests on the loss invariants."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import elmo_head as H
from repro.core import losses as L


def test_chunk_multi_hot_partitions_labels():
    ids = jnp.array([[3, 7, -1], [0, 9, 9]], jnp.int32)
    full = np.asarray(L.chunk_multi_hot(ids, jnp.int32(0), 10))
    # duplicates collapse, padding ignored
    assert full[0].sum() == 2 and full[1].sum() == 2
    # chunked reconstruction == full
    parts = [np.asarray(L.chunk_multi_hot(ids, jnp.int32(c0), 5))
             for c0 in (0, 5)]
    np.testing.assert_array_equal(np.concatenate(parts, 1), full)


@given(st.integers(2, 40), st.integers(1, 6))
@settings(max_examples=20, deadline=None)
def test_chunk_one_hot_partition_property(nlab, nchunks):
    """Σ over chunks of chunk_one_hot == one_hot, for any chunking."""
    chunk = (nlab + nchunks - 1) // nchunks
    ids = jnp.array([1 % nlab, nlab - 1, -1], jnp.int32)
    full = np.zeros((3, chunk * nchunks), np.float32)
    for c in range(nchunks):
        full[:, c * chunk:(c + 1) * chunk] += np.asarray(
            L.chunk_one_hot(ids, jnp.int32(c * chunk), chunk))
    assert full[0].sum() == 1 and full[1].sum() == 1 and full[2].sum() == 0
    assert full[1, nlab - 1] == 1


@given(st.integers(1, 5))
@settings(max_examples=10, deadline=None)
def test_streaming_lse_matches_direct(nchunks):
    z = jax.random.normal(jax.random.PRNGKey(0), (4, 8 * nchunks)) * 3
    m, s = L.lse_init(4)
    for c in range(nchunks):
        m, s = L.lse_update(m, s, z[:, c * 8:(c + 1) * 8])
    got = np.asarray(L.lse_finalize(m, s))
    want = np.asarray(jax.scipy.special.logsumexp(z, axis=-1))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_posthoc_refinement_recovers_precision():
    """App. D.1: refine an FP8-trained head in BF16 on frozen features —
    P@1 must not regress and typically improves."""
    num_labels, d = 500, 32
    rng = np.random.default_rng(0)
    protos = rng.standard_normal((num_labels, d)).astype(np.float32)
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)

    def sample(n, seed):
        r = np.random.default_rng(seed)
        ys = r.integers(0, num_labels, (n, 3))
        x = protos[ys[:, 0]] + 0.1 * r.standard_normal((n, d)).astype(
            np.float32)
        return jnp.asarray(x, jnp.bfloat16), jnp.asarray(ys, jnp.int32)

    fp8 = H.ELMOHeadConfig(num_labels=num_labels, d_model=d, num_chunks=4,
                           weight_dtype="e4m3", loss="bce", impl="xla")
    state = H.init_head(jax.random.PRNGKey(1), fp8)
    step = jax.jit(lambda s, x, y, i: H.head_train_step(
        fp8, s, x, y, jnp.float32(2.0), jnp.float32(0.0), i))
    for i in range(150):
        x, y = sample(128, i)
        state, _, _ = step(state, x, y, jnp.uint32(i))
    xte, yte = sample(256, 9999)
    p1_fp8 = float(H.precision_at_k(fp8, state, xte, yte, k=1))

    bf16 = H.ELMOHeadConfig(num_labels=num_labels, d_model=d, num_chunks=4,
                            weight_dtype="bf16", loss="bce", impl="xla")
    refined = H.convert_head(state, fp8, bf16)
    batches = ((lambda t: (t[0], t[1]))(sample(128, 10_000 + i))
               for i in itertools.count())
    refined = H.posthoc_refine(bf16, refined, batches, steps=60, lr=1.0)
    p1_ref = float(H.precision_at_k(bf16, refined, xte, yte, k=1))
    assert p1_ref >= p1_fp8 - 0.02, (p1_fp8, p1_ref)
