"""Label-sharded head: single-process surface tests + the forced-4-device
parity suite (subprocess, ISSUE 2 acceptance criteria).

The bit-parity matrix itself lives in ``_multidevice_head_checks.py`` —
XLA's forced host-device count only takes effect at backend init, so
anything needing >1 device runs there via the ``multidevice_runner``
fixture.  Everything here runs on the plain tier-1 backend.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import elmo_head as H
from repro.core import losses as L
from repro.core import memory_model as MM
from repro.kernels import tuning


def _setup(loss, num_labels=500, num_chunks=4, d_model=32, batch=8,
           **kw):
    cfg = H.ELMOHeadConfig(num_labels=num_labels, d_model=d_model,
                           num_chunks=num_chunks, weight_dtype="bf16",
                           loss=loss, use_sr=False, impl="unfused_xla",
                           **kw)
    st = H.init_head(jax.random.PRNGKey(0), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(1), (batch, d_model)) * 0.5
         ).astype(jnp.bfloat16)
    shape = (batch, 8) if loss == "bce" else (batch,)
    tgt = jax.random.randint(jax.random.PRNGKey(2), shape, 0, num_labels)
    return cfg, st, x, tgt


# ---------------------------------------------------------------------------
# single-device surface (fallbacks, padding, budgets, memory model)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("loss", ["bce", "softmax_ce"])
def test_sharded_step_falls_back_without_mesh(loss):
    """No ambient mesh → byte-for-byte the single-device step."""
    cfg, st, x, tgt = _setup(loss)
    hp = (jnp.float32(0.05), jnp.float32(1e-4), jnp.uint32(3))
    st1, xg1, m1 = H.head_train_step(cfg, st, x, tgt, *hp)
    st2, xg2, m2 = H.head_train_step_sharded(cfg, st, x, tgt, *hp)
    np.testing.assert_array_equal(np.asarray(st1.w, np.float32),
                                  np.asarray(st2.w, np.float32))
    np.testing.assert_array_equal(np.asarray(xg1, np.float32),
                                  np.asarray(xg2, np.float32))
    assert float(m1["loss"]) == float(m2["loss"])


def test_sharded_serving_falls_back_without_mesh():
    cfg, st, x, _ = _setup("bce")
    np.testing.assert_array_equal(
        np.asarray(H.head_logits(cfg, st, x), np.float32),
        np.asarray(H.head_logits_sharded(cfg, st, x), np.float32))
    v1, i1 = H.head_topk(cfg, st, x, 5)
    v2, i2 = H.head_topk_sharded(cfg, st, x, 5)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


@pytest.mark.parametrize("num_labels,num_chunks", [(260, 2), (5, 2),
                                                   (300, 4)])
def test_topk_padding_never_surfaces(num_labels, num_chunks):
    """Padded label columns must never appear in top-k output, even when k
    exceeds the valid label count (every tie sits at the NEG_INF floor)."""
    cfg = H.ELMOHeadConfig(num_labels=num_labels, d_model=16,
                           num_chunks=num_chunks, weight_dtype="bf16",
                           use_sr=False, impl="unfused_xla")
    st = H.init_head(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16), jnp.bfloat16)
    k = min(num_labels + 40, cfg.padded_labels)
    vals, idx = H.head_topk(cfg, st, x, k)
    idx, vals = np.asarray(idx), np.asarray(vals)
    assert (idx < num_labels).all(), idx.max()
    # the overflow slots beyond the valid count are NEG_INF sentinels
    if k > num_labels:
        assert (vals[:, num_labels:] <= L.NEG_INF / 2).all()


def test_init_xg_err_shape():
    cfg, _, _, _ = _setup("bce", d_model=32, batch=8)
    err = H.init_xg_err(cfg, batch=8)       # no mesh → one shard row
    assert err.shape == (1, 8, 32) and err.dtype == jnp.bfloat16


def test_tuning_budgets_local_shard():
    """Sharded tile selection budgets against the local chunk: 4-way
    sharding must pick a tile at least as large as the global one, and
    identical to budgeting the local width directly."""
    B, L, D = 256, 8192, 256
    assert tuning.local_chunk(L, 4) == L // 4
    assert tuning.local_chunk(L, 1) == L
    bl_global = tuning.chunk_block_l(B, L, D, 1)
    bl_shard = tuning.chunk_block_l(B, L, D, 1, n_shards=4)
    assert bl_shard == tuning.chunk_block_l(B, L // 4, D, 1)
    assert bl_shard >= min(bl_global, L // 4)
    assert (L // 4) % bl_shard == 0


def test_memory_model_4x_head_drop():
    """ISSUE 2 acceptance: per-device head memory for xmc_bert_3m drops
    ~4× under 4-way label sharding (every head term lives on the label
    axis)."""
    s = MM.MemScenario(num_labels=2_812_281, d_model=768, batch=128,
                       num_chunks=8, kahan_chunks=2)
    h1 = MM.head_components(s, "e4m3", n_label_shards=1)
    h4 = MM.head_components(s, "e4m3", n_label_shards=4)
    ratio = h1["total"] / h4["total"]
    assert 3.9 < ratio < 4.1, ratio
    # every component shards (nothing in the head is replicated)
    for k in h1:
        if h1[k]:
            assert h1[k] / h4[k] == pytest.approx(4.0), k
    # full elmo_peak: encoder/activations stay whole, head terms shrink
    p1 = MM.elmo_peak(s, "e4m3", n_label_shards=1)["total"]
    p4 = MM.elmo_peak(s, "e4m3", n_label_shards=4)["total"]
    assert p4 < p1
    assert p1 - p4 == pytest.approx((h1["total"] - h4["total"]), rel=1e-6)


# ---------------------------------------------------------------------------
# forced-4-device suite (subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_multidevice_head_suite(multidevice_runner):
    out = multidevice_runner("_multidevice_head_checks.py", device_count=4)
    assert "ALL SHARDED HEAD CHECKS PASSED" in out
