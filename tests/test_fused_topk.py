"""Streaming top-k serving megakernel (DESIGN.md §9, ISSUE 5).

The contract under test:

* ``kernels/fused_topk.fused_topk`` (ONE Pallas launch, (B, k) running
  top-k in VMEM scratch) is **bit-identical** — values AND ids — to
  ``ref.fused_topk_ref`` (the chunk-scan oracle / non-TPU production
  path) and to ``serving._topk_scan`` (the historical streaming path),
  including the tie-break contract: equal logits resolve to the lowest
  label id, overflow slots surface (NEG_INF, id 0) sentinels, padded
  label columns never surface.  Edge cases: k > chunk width, k ≥
  num_labels, all columns masked (NEG_INF rows), duplicate logit values
  spanning label-block boundaries, any label tile ``block_l``.
* serving top-k on the grid path is exactly 1 launch (vs C on the
  interpret streaming scan), and the plan resolves ``topk_path``.
* eval-time DropConnect: serving defaults to dense weights
  (drop_rate 0); ``compat_eval_drop=True`` reproduces the historical
  fixed seed-0 mask bit-for-bit.
* ``precision_at_k`` denominator semantics (rows with < k positives) and
  the ``psp_at_k`` hook — pinned with hand-computed fixtures.
* ``benchmarks.run`` trajectory loading tolerates BENCH_*.json gaps.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import elmo_head as H
from repro.core import losses as L
from repro.head import plan as plan_mod
from repro.head import serving
from repro.kernels import introspect, ops, ref, tuning


def _mk(num_labels, d, B, num_chunks, wdtype="bf16", **kw):
    cfg = H.ELMOHeadConfig(num_labels=num_labels, d_model=d,
                           num_chunks=num_chunks, weight_dtype=wdtype,
                           use_sr=False, **kw)
    state = H.init_head(jax.random.PRNGKey(1), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(2), (B, d)) * 0.5
         ).astype(jnp.bfloat16)
    return cfg, state, x


def _scan_topk(cfg, state, x, k):
    """The historical streaming scan, pinned as the third parity leg."""
    return serving._topk_scan(cfg, state.w, x.astype(jnp.bfloat16), k,
                              cfg.chunk, lambda c: c * cfg.chunk, "xla")


# ---------------------------------------------------------------------------
# kernel ≡ oracle ≡ streaming scan (values AND ids)
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(B=st.integers(1, 10), D=st.integers(2, 40),
       num_chunks=st.integers(2, 4), l_frac=st.floats(0.0, 1.0),
       k_sel=st.integers(0, 3), dt_i=st.integers(0, 2),
       bl_i=st.integers(0, 2))
def test_kernel_oracle_scan_parity(B, D, num_chunks, l_frac, k_sel, dt_i,
                                   bl_i):
    wdtype = ("bf16", "e4m3", "e5m2")[dt_i]
    lo, hi = num_chunks, num_chunks * 300
    num_labels = int(lo + l_frac * (hi - lo))
    cfg, state, x = _mk(num_labels, D, B, num_chunks, wdtype,
                        impl="grid_interpret")
    lc = cfg.chunk
    # k spanning the satellite edge cases: tiny, > chunk width lc,
    # ≥ num_labels (overflow sentinels), and the full padded width
    k = (1, min(lc + 17, cfg.padded_labels),
         min(num_labels + 9, cfg.padded_labels), cfg.padded_labels)[k_sel]
    block_l = (None, 8, 64)[bl_i]

    seeds = serving._eval_seeds(cfg)
    base = serving._chunk_base(cfg)
    vk, ik = ops.fused_topk(x, state.w, seeds, base, k=k,
                            num_labels=cfg.num_labels, quantize_x=cfg.qx,
                            impl="interpret", block_l=block_l)
    vo, io = ref.fused_topk_ref(x, state.w, seeds, base, k=k,
                                num_labels=cfg.num_labels,
                                quantize_x=cfg.qx)
    vs, is_ = _scan_topk(cfg, state, x, k)
    np.testing.assert_array_equal(np.asarray(vk), np.asarray(vo))
    np.testing.assert_array_equal(np.asarray(ik), np.asarray(io))
    np.testing.assert_array_equal(np.asarray(vk), np.asarray(vs))
    np.testing.assert_array_equal(np.asarray(ik), np.asarray(is_))
    # padded ids never surface; overflow slots are (NEG_INF, 0) sentinels
    assert (np.asarray(ik) < max(num_labels, 1)).all()
    if k > num_labels:
        tail_v = np.asarray(vk)[:, num_labels:]
        tail_i = np.asarray(ik)[:, num_labels:]
        assert (tail_v <= L.NEG_INF / 2).all()
        assert (tail_i == 0).all()


def test_all_neg_inf_rows_surface_sentinels():
    """num_labels = 0 masks every column: the whole output must be the
    scan's (NEG_INF, id 0) sentinel carry, not garbage ids."""
    B, D, C, lc, k = 3, 16, 2, 24, 5
    x = (jax.random.normal(jax.random.PRNGKey(0), (B, D)) * 0.5
         ).astype(jnp.bfloat16)
    w = (jax.random.normal(jax.random.PRNGKey(1), (C, lc, D)) * 0.05
         ).astype(jnp.bfloat16)
    seeds = jnp.zeros((C,), jnp.uint32)
    base = jnp.arange(C, dtype=jnp.int32) * lc
    for impl in ("interpret", "xla"):
        v, i = ops.fused_topk(x, w, seeds, base, k=k, num_labels=0,
                              quantize_x=False, impl=impl)
        assert (np.asarray(v) <= L.NEG_INF / 2).all()
        assert (np.asarray(i) == 0).all()


def test_duplicate_logits_span_block_boundary():
    """Every logit identical (tiled W rows): ties must resolve to the
    lowest label ids in order, across chunk AND block boundaries, on
    every path."""
    B, D, C, lc, k = 4, 16, 2, 32, 11
    x = (jax.random.normal(jax.random.PRNGKey(0), (B, D)) * 0.5
         ).astype(jnp.bfloat16)
    row = (jax.random.normal(jax.random.PRNGKey(1), (1, 1, D)) * 0.05
           ).astype(jnp.bfloat16)
    w = jnp.tile(row, (C, lc, 1))
    seeds = jnp.zeros((C,), jnp.uint32)
    base = jnp.arange(C, dtype=jnp.int32) * lc
    for block_l in (8, 16, None):
        v, i = ops.fused_topk(x, w, seeds, base, k=k, num_labels=C * lc,
                              quantize_x=False, impl="interpret",
                              block_l=block_l)
        assert (np.asarray(i) == np.arange(k)).all(), (block_l, i)
        vo, io = ref.fused_topk_ref(x, w, seeds, base, k=k,
                                    num_labels=C * lc, quantize_x=False)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(vo))
        np.testing.assert_array_equal(np.asarray(i), np.asarray(io))


def test_serving_paths_bitwise_equal():
    """plan.topk_path ∈ {kernel, materialize, stream} are bit-identical
    through the public serving entry point."""
    cfg, state, x = _mk(300, 32, 4, 4, impl="grid_interpret")
    plan = plan_mod.resolve_plan(cfg, batch=x.shape[0])
    assert plan.topk_path == "kernel"
    outs = {}
    for path in ("kernel", "materialize", "stream"):
        p = dataclasses.replace(plan, topk_path=path)
        outs[path] = serving.topk_planned(p, cfg, state, x, 12)
    for path in ("materialize", "stream"):
        np.testing.assert_array_equal(np.asarray(outs["kernel"][0]),
                                      np.asarray(outs[path][0]))
        np.testing.assert_array_equal(np.asarray(outs["kernel"][1]),
                                      np.asarray(outs[path][1]))


# ---------------------------------------------------------------------------
# launch count + plan resolution
# ---------------------------------------------------------------------------


def test_topk_single_launch_vs_scan():
    cfg, state, x = _mk(300, 32, 4, 4, impl="grid_interpret")
    assert introspect.count_pallas_launches(
        lambda s, xx: H.head_topk(cfg, s, xx, 5)[0], state, x) == 1
    # the interpret streaming scan pays one launch per chunk
    plan = plan_mod.resolve_plan(cfg, batch=x.shape[0])
    p = dataclasses.replace(plan, topk_path="stream")
    assert introspect.count_pallas_launches(
        lambda s, xx: serving.topk_planned(p, cfg, s, xx, 5)[0],
        state, x) == cfg.num_chunks


def test_xmc_arch_topk_single_launch():
    """Acceptance: the paper's XMC arches serve top-k in ONE launch per
    query batch on the kernel path — pinned by abstract tracing (no
    3M-label weights materialize)."""
    from repro.configs import get_smoke
    from repro.head.config import head_config_for
    from repro.head.state import HeadState

    for arch in ("xmc-bert-3m", "xmc-distilbert-8.6m"):
        hcfg = dataclasses.replace(head_config_for(get_smoke(arch)),
                                   impl="grid_interpret")
        plan = plan_mod.resolve_plan(hcfg, batch=8)
        assert plan.topk_path == "kernel", (arch, plan.topk_path)
        st = HeadState(jax.ShapeDtypeStruct(
            (hcfg.num_chunks, hcfg.chunk, hcfg.d_model), hcfg.wdtype), None)
        x = jax.ShapeDtypeStruct((8, hcfg.d_model), jnp.bfloat16)
        assert introspect.count_pallas_launches(
            lambda s, xx: serving.topk_planned(plan, hcfg, s, xx, 5)[0],
            st, x) == 1, arch


def test_plan_topk_path_resolution():
    cfg, _, _ = _mk(300, 32, 4, 4, impl="grid_interpret")
    assert plan_mod.resolve_plan(cfg, batch=4).topk_path == "kernel"
    # xla inner: no kernel — the ops dispatch streams through the oracle
    x_cfg = dataclasses.replace(cfg, impl="grid_xla")
    assert plan_mod.resolve_plan(x_cfg, batch=4).topk_path == "stream"
    # back-compat property view
    p = plan_mod.resolve_plan(cfg, batch=4)
    assert p.topk_materialize == (p.topk_path == "materialize")


def test_plan_cli_expect_topk():
    assert plan_mod.main(["--arch", "xmc-bert-3m", "--smoke", "--batch",
                          "8", "--impl", "grid_interpret",
                          "--expect-topk", "kernel"]) == 0
    assert plan_mod.main(["--arch", "xmc-bert-3m", "--smoke", "--batch",
                          "8", "--impl", "grid_interpret",
                          "--expect-topk", "stream"]) == 1


def test_topk_kernel_downgrades_at_large_k():
    """The plan gates the kernel path at the nominal lane-tile k; a
    compiled query at a k the VMEM model rejects must re-gate and fall
    back per-call (results are path-invariant, so this is invisible)."""
    cfg, _, _ = _mk(300, 256, 4, 4, impl="grid_interpret")
    plan = plan_mod.resolve_plan(cfg, batch=256)
    compiled = dataclasses.replace(plan, rimpl="kernel")
    assert serving._topk_exec_path(compiled, cfg, 256, 10) == "kernel"
    big_k = 1 << 20        # (B, K) carry alone exceeds VMEM
    assert not tuning.fused_topk_viable(256, 256, 1, big_k)
    assert serving._topk_exec_path(compiled, cfg, 256, big_k) in (
        "materialize", "stream")
    # interpret inner has no VMEM: the plan's choice stands at any k
    assert serving._topk_exec_path(plan, cfg, 256, big_k) == "kernel"


def test_topk_viability_model():
    assert tuning.fused_topk_viable(256, 256, 1, 10)
    assert not tuning.fused_topk_viable(200_000, 1024, 1, 10)
    bl = tuning.topk_block_l(256, 512, 256, 1, 10)
    assert 512 % bl == 0 or bl >= 512
    assert tuning._topk_vmem(256, 256, bl, 1, 10) <= tuning.VMEM_BUDGET


# ---------------------------------------------------------------------------
# eval-time DropConnect (satellite): dense by default, compat escape hatch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["grid_interpret", "fused_xla"])
def test_serving_drop_defaults_to_dense(impl):
    """A head trained with drop_rate > 0 serves with DENSE weights: its
    serving outputs equal a drop-0 config's, on every path."""
    cfg, state, x = _mk(300, 32, 4, 4, impl=impl, drop_rate=0.3)
    dense = dataclasses.replace(cfg, drop_rate=0.0)
    np.testing.assert_array_equal(
        np.asarray(H.head_logits(cfg, state, x), np.float32),
        np.asarray(H.head_logits(dense, state, x), np.float32))
    v1, i1 = H.head_topk(cfg, state, x, 7)
    v2, i2 = H.head_topk(dense, state, x, 7)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


@pytest.mark.parametrize("impl", ["grid_interpret", "fused_xla"])
def test_serving_compat_eval_drop_reproduces_seed0_mask(impl):
    """compat_eval_drop=True reproduces the historical serving outputs:
    per-chunk DropConnect masks drawn from the constant seed 0."""
    cfg, state, x = _mk(300, 32, 4, 4, impl=impl, drop_rate=0.3)
    compat = dataclasses.replace(cfg, compat_eval_drop=True)
    z = np.asarray(H.head_logits(compat, state, x), np.float32)

    # the historical path, reconstructed from the oracle: seed-0 masked
    # logits per chunk.  ULP-level tolerance: whether XLA rounds the bf16
    # DropConnect rescale before or after fusing it into the dot depends
    # on the surrounding program (jit vs eager), so cross-program dropful
    # logits agree to bf16 ULPs, not bitwise — same masks, same math.
    zs = [ref.fp8_logits_ref(x, state.w[c], jnp.uint32(0),
                             drop_rate=cfg.drop_rate, quantize_x=cfg.qx)
          for c in range(cfg.num_chunks)]
    z_ref = np.asarray(jnp.concatenate(zs, axis=1)[:, :cfg.num_labels],
                       np.float32)
    np.testing.assert_allclose(z, z_ref, rtol=0.02, atol=4e-3)
    # and it differs from the dense default (the mask actually applies):
    # dropped rows change logits by far more than the rescale ULPs
    z_dense = np.asarray(H.head_logits(cfg, state, x), np.float32)
    assert np.abs(z - z_dense).max() > 0.05
    # top-k paths stay bit-identical to each other under compat mode
    # (kernel/materialize need a Pallas-capable inner: grid_interpret)
    plan = plan_mod.resolve_plan(compat, batch=x.shape[0])
    if plan.topk_path == "kernel":
        outs = [serving.topk_planned(dataclasses.replace(plan, topk_path=p),
                                     compat, state, x, 9)
                for p in ("kernel", "materialize", "stream")]
        for v, i in outs[1:]:
            np.testing.assert_array_equal(np.asarray(outs[0][0]),
                                          np.asarray(v))
            np.testing.assert_array_equal(np.asarray(outs[0][1]),
                                          np.asarray(i))


# ---------------------------------------------------------------------------
# precision_at_k denominators + psp hook (satellite) — hand-computed
# ---------------------------------------------------------------------------


def test_p_at_k_denominator_fixture():
    """B=2, k=3.  Row 0: 2 positives (labels 0, 1), both in the top-3.
    Row 1: 4 positives, 1 hit in the top-3.

      strict "k":      (2/3 + 1/3) / 2            = 0.5
      "positives":     (2/min(2,3) + 1/3) / 2     = (1 + 1/3)/2 = 2/3
    """
    pred = jnp.asarray([[0, 1, 7], [5, 9, 2]], jnp.int32)
    vals = jnp.ones_like(pred, jnp.float32)          # all real predictions
    labels = jnp.asarray([[0, 1, -1, -1], [2, 3, 4, 6]], jnp.int32)
    pk = float(serving._p_at_k(vals, pred, labels, 3, "k"))
    pp = float(serving._p_at_k(vals, pred, labels, 3, "positives"))
    assert pk == pytest.approx(0.5)
    assert pp == pytest.approx(2.0 / 3.0)
    # rows with ≥ k positives: the two conventions agree
    labels_full = jnp.asarray([[0, 1, 7, 9], [2, 3, 4, 6]], jnp.int32)
    assert float(serving._p_at_k(vals, pred, labels_full, 3, "k")) == \
        pytest.approx(float(serving._p_at_k(vals, pred, labels_full, 3,
                                            "positives")))
    # all-padding rows are excluded, not counted as zero
    labels_pad = jnp.asarray([[0, 1, -1, -1], [-1, -1, -1, -1]], jnp.int32)
    assert float(serving._p_at_k(vals, pred, labels_pad, 3, "positives")) \
        == pytest.approx(1.0)


def test_p_at_k_ignores_overflow_sentinels():
    """k ≥ num_labels: the (NEG_INF, id 0) overflow sentinels must not
    score hits against a genuine label 0 — P@k stays ≤ 1 and matches the
    hand count of REAL predictions only."""
    # top-3 of a 2-label space: one real hit (id 0) + one real miss
    # (id 1) + one sentinel slot that also carries id 0
    vals = jnp.asarray([[2.0, 1.0, L.NEG_INF]], jnp.float32)
    pred = jnp.asarray([[0, 1, 0]], jnp.int32)
    labels = jnp.asarray([[0, -1]], jnp.int32)
    assert float(serving._p_at_k(vals, pred, labels, 3, "k")) == \
        pytest.approx(1.0 / 3.0)
    assert float(serving._p_at_k(vals, pred, labels, 3, "positives")) == \
        pytest.approx(1.0)
    # end-to-end: a 5-label head queried at k=9 can never exceed 1.0
    cfg, state, x = _mk(5, 16, 4, 2, impl="grid_interpret")
    tg = jnp.zeros((4, 2), jnp.int32)       # every row: label 0 positive
    p = float(H.precision_at_k(cfg, state, x, tg, 9, denom="positives"))
    assert 0.0 <= p <= 1.0
    # and the psp hook masks sentinels the same way
    from repro.head import ELMOHead
    head = ELMOHead(cfg, batch=4)
    prop = jnp.full((5,), 0.5, jnp.float32)
    psp = float(head.psp_at_k(state, x, tg, prop, k=9))
    v9, p9 = head.topk(state, x, 9)
    expect = float(L.psp_at_k(serving._real_preds(v9, p9), tg, prop, 9))
    assert psp == pytest.approx(expect)


def test_head_p_at_k_and_psp_hook():
    from repro.head import ELMOHead
    cfg, state, x = _mk(40, 16, 6, 2, impl="xla")
    tg = jax.random.randint(jax.random.PRNGKey(5), (6, 3), 0, 40)
    head = ELMOHead(cfg, batch=6)
    p_pos = float(head.precision_at_k(state, x, tg, k=5))
    p_k = float(head.precision_at_k(state, x, tg, k=5, denom="k"))
    assert 0.0 <= p_k <= p_pos <= 1.0
    # legacy free function agrees with the facade on both conventions
    assert float(H.precision_at_k(cfg, state, x, tg, 5)) == \
        pytest.approx(p_pos)
    assert float(H.precision_at_k(cfg, state, x, tg, 5, denom="k")) == \
        pytest.approx(p_k)
    # psp hook: uniform propensities ≈ scaled hit count, and it runs
    # through the same top-k plan
    prop = jnp.full((40,), 0.5, jnp.float32)
    psp = float(head.psp_at_k(state, x, tg, prop, k=5))
    _, pred = head.topk(state, x, 5)
    expect = float(L.psp_at_k(pred, tg, prop, 5))
    assert psp == pytest.approx(expect)


# ---------------------------------------------------------------------------
# BENCH trajectory gap handling (satellite)
# ---------------------------------------------------------------------------


def test_bench_trajectory_tolerates_gaps(tmp_path):
    import json
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

    from benchmarks.run import bench_files, load_trajectory

    # sparse, renumbered history: 1 and 2 absent, plus junk files
    (tmp_path / "BENCH_3.json").write_text(json.dumps(
        [{"ts": 1.0, "sections": ["kernels"], "rows": []}]))
    (tmp_path / "BENCH_7.json").write_text(json.dumps(
        [{"ts": 2.0, "sections": ["serving"], "rows": [{"name": "x"}]}]))
    (tmp_path / "BENCH_5.json").write_text("{not json")       # corrupt
    (tmp_path / "BENCH_notanumber.json").write_text("[]")     # ignored
    files = bench_files(str(tmp_path))
    assert [f.split("BENCH_")[-1] for f in files] == \
        ["3.json", "5.json", "7.json"]
    hist = load_trajectory(str(tmp_path))
    assert [e["file"] for e in hist] == ["BENCH_3.json", "BENCH_7.json"]
    assert hist[1]["sections"] == ["serving"]
    # empty directory: no crash, empty history
    assert load_trajectory(str(tmp_path / "nowhere")) == []
