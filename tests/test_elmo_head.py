"""ELMO head vs full-width autodiff oracle; chunk invariance; eval paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import elmo_head as H
from repro.core import losses as L

KEY = jax.random.PRNGKey(0)


def _setup(loss="bce", num_labels=300, d=64, B=32, num_chunks=4,
           weight_dtype="f32", **kw):
    cfg = H.ELMOHeadConfig(num_labels=num_labels, d_model=d,
                           num_chunks=num_chunks, weight_dtype=weight_dtype,
                           loss=loss, use_sr=False, quantize_x=False,
                           impl="xla", **kw)
    state = H.init_head(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, d), jnp.float32) * 0.5
    if loss == "bce":
        tg = jax.random.randint(jax.random.PRNGKey(3), (B, 5), 0, num_labels)
        tg = jnp.where(jax.random.uniform(jax.random.PRNGKey(4), (B, 5)) < 0.2,
                       -1, tg)  # some padding
    else:
        tg = jax.random.randint(jax.random.PRNGKey(3), (B,), 0, num_labels)
        tg = tg.at[0].set(-1)  # one masked token
    return cfg, state, x.astype(jnp.bfloat16), tg


def _full_w(cfg, state):
    return state.w.reshape(-1, cfg.d_model)[:cfg.num_labels].astype(jnp.float32)


@pytest.mark.parametrize("loss", ["bce", "softmax_ce"])
def test_head_xgrad_matches_autodiff(loss):
    cfg, state, x, tg = _setup(loss)
    w_full = _full_w(cfg, state)

    def loss_fn(xf):
        z = xf @ w_full.T
        return (L.full_bce_loss(z, tg) if loss == "bce"
                else L.full_ce_loss(z, tg))

    oracle_xg = jax.grad(loss_fn)(x.astype(jnp.float32))
    _, xg, metrics = H.head_train_step(cfg, state, x, tg,
                                       jnp.float32(0.1), jnp.float32(0.0),
                                       jnp.uint32(0))
    np.testing.assert_allclose(np.asarray(xg, np.float32),
                               np.asarray(oracle_xg), rtol=0.05, atol=5e-3)
    # loss value also matches the oracle
    oracle_loss = float(loss_fn(x.astype(jnp.float32)))
    assert abs(float(metrics["loss"]) - oracle_loss) < 0.02 * abs(oracle_loss) + 1e-3


@pytest.mark.parametrize("loss", ["bce", "softmax_ce"])
def test_head_weight_update_matches_sgd(loss):
    cfg, state, x, tg = _setup(loss)
    w_full = _full_w(cfg, state)
    lr, wd = 0.1, 0.01

    def loss_fn(w):
        z = x.astype(jnp.float32) @ w.T
        return (L.full_bce_loss(z, tg) if loss == "bce"
                else L.full_ce_loss(z, tg))

    dw = jax.grad(loss_fn)(w_full)
    oracle_w = w_full * (1 - lr * wd) - lr * dw
    new_state, _, _ = H.head_train_step(cfg, state, x, tg, jnp.float32(lr),
                                        jnp.float32(wd), jnp.uint32(0))
    got = _full_w(cfg, new_state)
    np.testing.assert_allclose(np.asarray(got), np.asarray(oracle_w),
                               rtol=0.05, atol=5e-3)


@pytest.mark.parametrize("loss", ["bce", "softmax_ce"])
def test_chunk_count_invariance(loss):
    """1 chunk vs 6 chunks: identical results (no SR, f32 weights)."""
    outs = []
    # one weight draw at the real label count; padded rows are zero (they
    # are masked everywhere, and drawing at the padded shape would give
    # different leading rows per chunking under threefry)
    w_real = jax.random.normal(jax.random.PRNGKey(7), (312, 64),
                               jnp.float32) * 0.1
    for nc in (1, 6):
        cfg, state, x, tg = _setup(loss, num_labels=312, num_chunks=nc)
        w_flat = jnp.zeros((cfg.padded_labels, cfg.d_model),
                           jnp.float32).at[:312].set(w_real)
        w = w_flat.reshape(cfg.num_chunks, cfg.chunk, cfg.d_model)
        state = H.HeadState(w, None)
        new_state, xg, m = H.head_train_step(cfg, state, x, tg,
                                             jnp.float32(0.05),
                                             jnp.float32(0.0), jnp.uint32(0))
        outs.append((np.asarray(_full_w(cfg, new_state)),
                     np.asarray(xg, np.float32), float(m["loss"])))
    np.testing.assert_allclose(outs[0][0], outs[1][0], rtol=2e-2, atol=2e-3)
    np.testing.assert_allclose(outs[0][1], outs[1][1], rtol=5e-2, atol=2e-3)
    assert abs(outs[0][2] - outs[1][2]) < 0.01 * abs(outs[0][2]) + 1e-4


def test_padded_labels_never_updated_or_predicted():
    # chunks are padded to the 256-row MXU/sharding alignment
    cfg, state, x, tg = _setup("bce", num_labels=300, num_chunks=4)
    assert cfg.padded_labels == 1024 and cfg.chunk == 256
    cfg, state, x, tg = _setup("bce", num_labels=301, num_chunks=4)
    assert cfg.padded_labels == 1024
    # tiny label spaces below the alignment stay unpadded-per-chunk
    small = H.ELMOHeadConfig(num_labels=100, d_model=8, num_chunks=4)
    assert small.chunk == 25
    _, idx = H.head_topk(cfg, state, x, k=5)
    assert np.asarray(idx).max() < 301
    z = H.head_logits(cfg, state, x)
    assert z.shape == (x.shape[0], 301)


def test_head_topk_matches_full_logits():
    cfg, state, x, _ = _setup("bce", num_labels=513, num_chunks=8)
    z = H.head_logits(cfg, state, x).astype(jnp.float32)
    vals, idx = H.head_topk(cfg, state, x, k=7)
    ovals, oidx = jax.lax.top_k(z, 7)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(ovals),
                               rtol=1e-2, atol=1e-3)
    # indices may permute within ties; compare gathered scores instead
    gath = np.take_along_axis(np.asarray(z), np.asarray(idx), axis=1)
    np.testing.assert_allclose(gath, np.asarray(ovals), rtol=1e-2, atol=1e-3)


def test_fp8_head_trains_and_stays_finite():
    cfg = H.ELMOHeadConfig(num_labels=256, d_model=64, num_chunks=4,
                           weight_dtype="e4m3", loss="bce", use_sr=True,
                           impl="xla")
    state = H.init_head(jax.random.PRNGKey(1), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(2), (32, 64)) * 0.5
         ).astype(jnp.bfloat16)
    tg = jax.random.randint(jax.random.PRNGKey(3), (32, 3), 0, 256)
    losses = []
    for step in range(30):
        state, xg, m = H.head_train_step(cfg, state, x, tg, jnp.float32(0.5),
                                         jnp.float32(0.0), jnp.uint32(step))
        losses.append(float(m["loss"]))
    assert np.all(np.isfinite(losses))
    assert losses[-1] < losses[0] * 0.9, losses  # learns
    assert np.isfinite(np.asarray(state.w, np.float32)).all()


def test_kahan_hybrid_chunks():
    """App. D: leading (head-label) chunks carry a Kahan buffer."""
    cfg = H.ELMOHeadConfig(num_labels=256, d_model=64, num_chunks=4,
                           weight_dtype="bf16", loss="bce", kahan_chunks=2,
                           impl="xla")
    state = H.init_head(jax.random.PRNGKey(1), cfg)
    assert state.comp.shape == (2, cfg.chunk, 64)
    x = (jax.random.normal(jax.random.PRNGKey(2), (16, 64)) * 0.5
         ).astype(jnp.bfloat16)
    tg = jax.random.randint(jax.random.PRNGKey(3), (16, 3), 0, 256)
    new_state, xg, m = H.head_train_step(cfg, state, x, tg, jnp.float32(0.1),
                                         jnp.float32(0.0), jnp.uint32(0))
    assert new_state.comp.shape == state.comp.shape
    assert not np.allclose(np.asarray(new_state.w, np.float32),
                           np.asarray(state.w, np.float32))
    assert np.isfinite(float(m["loss"]))


def test_precision_at_k():
    cfg, state, x, tg = _setup("bce", num_labels=100, B=8)
    # craft weights so that label == argmax is known: W row i = e_i pattern
    p1 = H.precision_at_k(cfg, state, x, tg, k=5)
    assert 0.0 <= float(p1) <= 1.0
