"""Flash attention (custom VJP) vs dense oracle: forward + gradients,
causal / sliding-window / GQA / padding sweeps."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import blockwise_attention

KEY = jax.random.PRNGKey(0)


def dense_oracle(q, k, v, q_pos, k_pos, causal, window):
    """Reference O(S²) attention, f32."""
    B, Sq, H, dh = q.shape
    KH = k.shape[2]
    G = H // KH
    q5 = q.reshape(B, Sq, KH, G, dh).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", q5, k.astype(jnp.float32))
    s = s / np.sqrt(dh)
    mask = jnp.ones((B, Sq, k.shape[1]), bool)
    if causal:
        mask = mask & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window is not None:
        mask = mask & (q_pos[:, :, None] - k_pos[:, None, :] < window)
    s = jnp.where(mask[:, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, dh)


def _mk(B=2, Sq=48, Sk=48, H=4, KH=2, dh=16, key=KEY):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, dh), jnp.float32) * 0.5
    k = jax.random.normal(ks[1], (B, Sk, KH, dh), jnp.float32) * 0.5
    v = jax.random.normal(ks[2], (B, Sk, KH, dh), jnp.float32) * 0.5
    qp = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    kp = jnp.broadcast_to(jnp.arange(Sk, dtype=jnp.int32), (B, Sk))
    return (q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
            v.astype(jnp.bfloat16), qp, kp)


@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 8), (True, 24)])
@pytest.mark.parametrize("bq,bk", [(16, 16), (48, 48), (16, 32), (8, 8)])
def test_flash_forward_matches_dense(causal, window, bq, bk):
    q, k, v, qp, kp = _mk()
    got = blockwise_attention(q, k, v, qp, kp, causal=causal, window=window,
                              bq=bq, bk=bk)
    want = dense_oracle(q, k, v, qp, kp, causal, window)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("H,KH", [(4, 4), (4, 2), (6, 1)])
def test_flash_gqa_variants(H, KH):
    q, k, v, qp, kp = _mk(H=H, KH=KH)
    got = blockwise_attention(q, k, v, qp, kp, causal=True, window=None,
                              bq=16, bk=16)
    want = dense_oracle(q, k, v, qp, kp, True, None)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               rtol=3e-2, atol=3e-2)


def test_flash_ragged_padding():
    """Sq=37, Sk=53 with bq=16/bk=16 exercises the padding path."""
    q, k, v, qp, kp = _mk(Sq=37, Sk=53)
    got = blockwise_attention(q, k, v, qp, kp, causal=False, window=None,
                              bq=16, bk=16)
    want = dense_oracle(q, k, v, qp, kp, False, None)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 16),
                                           (False, None)])
def test_flash_gradients_match_dense(causal, window):
    q, k, v, qp, kp = _mk(Sq=32, Sk=32)
    q32, k32, v32 = (a.astype(jnp.float32) for a in (q, k, v))

    def loss_flash(q, k, v):
        o = blockwise_attention(q, k, v, qp, kp, causal=causal,
                                window=window, bq=16, bk=16)
        return (o.astype(jnp.float32) ** 2).sum()

    def loss_dense(q, k, v):
        o = dense_oracle(q, k, v, qp, kp, causal, window)
        return (o ** 2).sum()

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gd = jax.grad(loss_dense, argnums=(0, 1, 2))(q32, k32, v32)
    for a, b, name in zip(gf, gd, "qkv"):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b), rtol=6e-2, atol=6e-2,
            err_msg=f"grad w.r.t. {name} (causal={causal} window={window})")


def test_flash_bwd_memory_is_flat():
    """The custom VJP must NOT save per-block probability tiles: the jaxpr
    residuals should be O(S·d), not O(S²)."""
    B, S, H, dh = 1, 256, 2, 16
    q, k, v, qp, kp = _mk(B=B, Sq=S, Sk=S, H=H, KH=H, dh=dh)

    def loss(q, k, v):
        o = blockwise_attention(q, k, v, qp, kp, causal=True, window=None,
                                bq=32, bk=32)
        return (o.astype(jnp.float32) ** 2).sum()

    # residual sizes: inspect the vjp closure's saved arrays
    _, f_vjp = jax.vjp(loss, q, k, v)
    saved = jax.tree.leaves(f_vjp)
    total = sum(a.size * a.dtype.itemsize for a in saved
                if hasattr(a, "size"))
    dense_bytes = B * H * S * S * 4        # what autodiff-through-softmax keeps
    assert total < dense_bytes, (total, dense_bytes)
