"""Numerics-guard checks under a forced multi-device host (default 4;
tests/test_numerics_guard.py drives this via the ``multidevice_runner``
fixture).  Exit code 0 = all checks passed.

The contract under test (DESIGN.md §14):

* guard-on ≡ guard-off stays **bitwise** on the label-sharded train step
  — weights, Kahan comp, x̄ and loss — on every mesh factorization of the
  forced devices, for the deterministic (BF16 + Kahan) and the production
  (e4m3 + SR) update alike.
* the psum/pmax telemetry merge is exact: for deterministic updates the
  sharded counters equal the single-device counters bit-for-bit on 1×4,
  2×2 and 4×1 (counts are integers carried in f32 — psum cannot lose
  them; the comp-max slot merges by pmax).
* an injected saturation cliff on one label shard is visible in the
  merged telemetry (the counters cross the device boundary).
"""
import os

_N_DEV = int(os.environ.get("REPRO_FORCE_DEVICES", "4"))
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + f" --xla_force_host_platform_device_count={_N_DEV}")

import dataclasses              # noqa: E402

import jax                      # noqa: E402
import jax.numpy as jnp        # noqa: E402
import numpy as np             # noqa: E402

from repro.core import elmo_head as H                # noqa: E402
from repro.dist import meshctx                       # noqa: E402
from repro.head.state import state_bits_equal        # noqa: E402
from repro.launch.mesh import make_host_mesh         # noqa: E402
from repro.numerics import telemetry as NT           # noqa: E402

assert len(jax.devices()) == _N_DEV, jax.devices()

B, D, NL = 16, 32, 1000        # chunk=256, 4 chunks, 24 padded columns
_HYPERS = (jnp.float32(0.05), jnp.float32(1e-4), jnp.uint32(7))
_MESHES = ((1, 4), (2, 2), (4, 1))


def _mk(loss, wdtype, kahan, use_sr):
    # the fused scan path: the only inner with in-kernel telemetry AND an
    # xla resolution on a host-device mesh
    cfg = H.ELMOHeadConfig(num_labels=NL, d_model=D, num_chunks=4,
                           weight_dtype=wdtype, loss=loss, use_sr=use_sr,
                           kahan_chunks=kahan, impl="fused_xla")
    st = H.init_head(jax.random.PRNGKey(0), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(1), (B, D)) * 0.5
         ).astype(jnp.bfloat16)
    shape = (B, 8) if loss == "bce" else (B,)
    tgt = jax.random.randint(jax.random.PRNGKey(2), shape, 0, NL)
    return cfg, st, x, tgt


def _single(cfg, st, x, tgt):
    return jax.jit(lambda s, x, t: H.head_train_step(
        cfg, s, x, t, *_HYPERS))(st, x, tgt)


def _sharded(cfg, st, x, tgt, mesh_shape):
    ctx = make_host_mesh(*mesh_shape)
    with meshctx.use(ctx):
        return jax.jit(lambda s, x, t: H.head_train_step_sharded(
            cfg, s, x, t, *_HYPERS))(st, x, tgt)


def _bits_eq(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return a.shape == b.shape and a.tobytes() == b.tobytes()


def check_guard_invisible_sharded():
    """guard-on ≡ guard-off bitwise on every mesh, both update styles."""
    for loss in ("bce", "softmax_ce"):
        for wdtype, kahan, sr in (("bf16", 4, False), ("e4m3", 0, True)):
            cfg, st, x, tgt = _mk(loss, wdtype, kahan, sr)
            g_cfg = dataclasses.replace(cfg, guard=True)
            for mesh_shape in _MESHES:
                s_off, xg_off, m_off = _sharded(cfg, st, x, tgt, mesh_shape)
                s_on, xg_on, m_on = _sharded(g_cfg, st, x, tgt, mesh_shape)
                tag = (loss, wdtype, mesh_shape)
                assert state_bits_equal(s_off, s_on), tag
                assert _bits_eq(xg_off, xg_on), tag
                assert _bits_eq(m_off["loss"], m_on["loss"]), tag
                assert "telemetry" not in m_off, tag
                tele = np.asarray(m_on["telemetry"])
                assert tele.shape == (NT.N_SLOTS,) and \
                    np.isfinite(tele).all(), (tag, tele)
    print("guard invisibility (sharded): OK")


def check_telemetry_merge_exact():
    """Deterministic updates: the psum/pmax-merged sharded telemetry is
    bit-identical to single-device on every mesh factorization."""
    for loss in ("bce", "softmax_ce"):
        cfg, st, x, tgt = _mk(loss, "bf16", kahan=4, use_sr=False)
        g_cfg = dataclasses.replace(cfg, guard=True)
        s1, _, m1 = _single(g_cfg, st, x, tgt)
        t1 = np.asarray(m1["telemetry"])
        for mesh_shape in _MESHES:
            sS, _, mS = _sharded(g_cfg, st, x, tgt, mesh_shape)
            tS = np.asarray(mS["telemetry"])
            assert state_bits_equal(s1, sS), (loss, mesh_shape)
            assert _bits_eq(t1, tS), (loss, mesh_shape, t1, tS)
    print("telemetry psum/pmax merge: OK")


def check_saturation_crosses_shards():
    """Poison ONE label shard's Kahan comp past the e4m3 cliff: the merged
    counter must report it no matter which shard held the poison."""
    cfg, st, x, tgt = _mk("bce", "e4m3", kahan=4, use_sr=False)
    g_cfg = dataclasses.replace(cfg, guard=True)
    n_poison = 128
    for shard in (0, _N_DEV - 1):
        comp = np.asarray(st.comp.astype(jnp.float32)).copy()
        flat = comp.reshape(-1)
        per = flat.size // _N_DEV
        flat[shard * per: shard * per + n_poison] = 450.0   # → ±448, finite
        stP = st._replace(comp=jnp.asarray(comp).astype(st.comp.dtype))
        _, _, m = _sharded(g_cfg, stP, x, tgt, (1, _N_DEV))
        tele = np.asarray(m["telemetry"])
        assert tele[NT.SLOTS["sat"]] >= n_poison, (shard, tele)
        assert np.isfinite(tele).all(), (shard, tele)
    print("cross-shard saturation visibility: OK")


if __name__ == "__main__":
    check_guard_invisible_sharded()
    check_telemetry_merge_exact()
    check_saturation_crosses_shards()
    print("ALL NUMERICS GUARD CHECKS PASSED")
