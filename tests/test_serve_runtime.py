"""Deadline-aware serving runtime suite (ISSUE 8, DESIGN.md §12).

The contract under test:

* every submitted request reaches EXACTLY one terminal state
  (COMPLETED / REJECTED / TIMED_OUT) — proven by conservation soaks with
  injected slow/failing dispatches, not assumed;
* admission sheds at the door (queue_full / predicted_late /
  tenant_throttled, in that order) and clamps k to tenant policy;
* the batcher fills the largest power-of-two bucket each deadline
  allows (``bucket_for`` ≡ the ``launch.serve._buckets`` semantics —
  property-tested), takes earliest-deadline-first, and expires queued
  requests at their own deadline;
* transient dispatch failures are absorbed by ``fault.retry`` with
  full-jitter backoff charged to the runtime clock; exhaustion surfaces
  as TIMED_OUT(dispatch_failed), never a lost request;
* the degradation ladder engages under sustained overload, recovers
  with hysteresis, and is plan- AND recall-gated at build time;
* a seeded Poisson soak on the virtual clock is bit-deterministic:
  same trace + config → identical metrics report, every run.
"""
import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import serve as RS
from repro.fault import inject as FI
from repro.serve.request import Outcome, Request, TenantPolicy, TokenBucket

MODEL = RS.ServiceModel(base_s=2e-3, per_row_s=1e-4)


def _req(rid=0, t=0.0, deadline_s=0.05, k=5, tenant="default", d=4):
    return Request(rid=rid, tenant=tenant,
                   x=np.zeros(d, np.float32) + rid, k=k,
                   submit_t=t, deadline_s=deadline_s)


def _server(executor=None, levels=None, cfg=None, **kw):
    return RS.Server(executor or RS.SimExecutor(MODEL),
                     levels or RS.sim_ladder(),
                     cfg=cfg or RS.ServeConfig(max_batch=16, max_queue=64),
                     estimator=RS.ServiceEstimator(MODEL), **kw)


# ---------------------------------------------------------------------------
# clocks
# ---------------------------------------------------------------------------


def test_virtual_clock_monotone():
    c = RS.VirtualClock()
    assert c.now() == 0.0
    c.sleep(1.5)
    assert c.now() == 1.5
    c.advance_to(1.0)            # backwards advance is a no-op
    assert c.now() == 1.5
    c.advance_to(2.0)
    assert c.now() == 2.0
    c.sleep(-1.0)                # negative sleep cannot rewind either
    assert c.now() == 2.0


# ---------------------------------------------------------------------------
# bucket_for: property tests (the _buckets contract)
# ---------------------------------------------------------------------------


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 4096), st.integers(0, 8))
def test_bucket_for_properties(size, j):
    max_batch = 2 ** j
    b = RS.bucket_for(size, max_batch)
    assert b <= max_batch
    assert b >= min(size, max_batch)
    assert b & (b - 1) == 0                      # power of two
    # minimality: the next bucket down would not fit the group
    if b > 1 and size <= max_batch:
        assert b // 2 < size
    # monotone in size
    assert RS.bucket_for(size + 1, max_batch) >= b


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 500), min_size=1, max_size=20),
       st.integers(0, 7))
def test_bucket_for_matches_launch_buckets(sizes, j):
    """The runtime's sizing and the bench's ``_buckets`` are one
    definition (the bench delegates) — pin the equivalence anyway so a
    future fork of either reintroduces the drift visibly."""
    from repro.launch.serve import _buckets
    max_batch = 2 ** j
    assert _buckets(sizes, max_batch) == \
        [RS.bucket_for(s, max_batch) for s in sizes]


def test_bucket_for_non_power_of_two_cap():
    # a non-power-of-two max_batch is itself the top bucket
    assert RS.bucket_for(25, 24) == 24
    assert RS.bucket_for(24, 24) == 24
    assert RS.bucket_for(9, 24) == 16


# ---------------------------------------------------------------------------
# percentile
# ---------------------------------------------------------------------------


def test_percentile_nearest_rank():
    xs = [5.0, 1.0, 3.0, 2.0, 4.0]
    assert RS.percentile(xs, 50) == 3.0
    assert RS.percentile(xs, 100) == 5.0
    assert RS.percentile(xs, 0) == 1.0           # rank floor of 1
    assert RS.percentile([7.0], 99) == 7.0
    assert math.isnan(RS.percentile([], 50))
    # p99 is a value some request actually saw (no interpolation)
    many = list(range(1, 101))
    assert RS.percentile(many, 99) == 99


# ---------------------------------------------------------------------------
# token bucket + tenant policy
# ---------------------------------------------------------------------------


def test_token_bucket_burst_and_refill():
    tb = TokenBucket(TenantPolicy(rate_qps=10.0, burst=3.0), now=0.0)
    assert [tb.take(0.0) for _ in range(4)] == [True, True, True, False]
    assert not tb.take(0.05)     # 0.5 tokens refilled: still < 1
    assert tb.take(0.1)          # 1.0 token accrued
    tb2 = TokenBucket(TenantPolicy(rate_qps=10.0, burst=2.0), now=0.0)
    tb2.take(0.0)
    tb2.take(0.0)
    assert tb2.take(100.0)       # refill is capped at burst, then spends
    assert tb2.take(100.0)
    assert not tb2.take(100.0)


def test_default_policy_unlimited():
    tb = TokenBucket(TenantPolicy(), now=0.0)
    assert all(tb.take(0.0) for _ in range(1000))


# ---------------------------------------------------------------------------
# admission gates
# ---------------------------------------------------------------------------


def _admission(**kw):
    kw.setdefault("max_batch", 16)
    kw.setdefault("max_queue", 32)
    kw.setdefault("estimator", RS.ServiceEstimator(MODEL))
    return RS.AdmissionController(**kw)


def test_admission_queue_full_gate():
    adm = _admission(max_queue=4)
    dec = adm.admit(_req(), 0.0, queue_depth=4, busy_remaining_s=0.0,
                    level=RS.sim_ladder()[0])
    assert not dec.admitted and dec.reason == "queue_full"


def test_admission_predicted_late_gate():
    adm = _admission()
    lvl = RS.sim_ladder()[0]
    # generous deadline, shallow queue: admitted
    assert adm.admit(_req(deadline_s=0.5), 0.0, queue_depth=0,
                     busy_remaining_s=0.0, level=lvl).admitted
    # a deadline the predicted wait alone blows: shed as predicted_late
    dec = adm.admit(_req(rid=1, deadline_s=0.01), 0.0, queue_depth=31,
                    busy_remaining_s=0.05, level=lvl)
    assert not dec.admitted and dec.reason == "predicted_late"
    assert dec.predicted_wait_s > 0.01


def test_admission_tenant_throttle_checked_last():
    """A throttled tenant's queue_full/predicted_late rejections must not
    spend tokens — only otherwise-admittable requests do."""
    pol = {"hot": TenantPolicy(rate_qps=0.0, burst=2.0)}
    adm = _admission(policies=pol, max_queue=4)
    lvl = RS.sim_ladder()[0]
    # queue_full rejections: no token spend
    for _ in range(5):
        dec = adm.admit(_req(tenant="hot"), 0.0, queue_depth=4,
                        busy_remaining_s=0.0, level=lvl)
        assert dec.reason == "queue_full"
    # both burst tokens still available
    for _ in range(2):
        assert adm.admit(_req(tenant="hot", deadline_s=0.5), 0.0,
                         queue_depth=0, busy_remaining_s=0.0,
                         level=lvl).admitted
    dec = adm.admit(_req(tenant="hot", deadline_s=0.5), 0.0,
                    queue_depth=0, busy_remaining_s=0.0, level=lvl)
    assert not dec.admitted and dec.reason == "tenant_throttled"
    # other tenants are unaffected by the hot tenant's throttle
    assert adm.admit(_req(tenant="cold", deadline_s=0.5), 0.0,
                     queue_depth=0, busy_remaining_s=0.0,
                     level=lvl).admitted


def test_admission_clamps_k_to_tenant_policy():
    adm = _admission(policies={"small": TenantPolicy(max_k=3)})
    r = _req(tenant="small", k=100, deadline_s=0.5)
    assert adm.admit(r, 0.0, queue_depth=0, busy_remaining_s=0.0,
                     level=RS.sim_ladder()[0]).admitted
    assert r.k == 3
    r2 = _req(rid=1, k=100, deadline_s=0.5)     # default tenant: no cap
    adm.admit(r2, 0.0, queue_depth=0, busy_remaining_s=0.0,
              level=RS.sim_ladder()[0])
    assert r2.k == 100


# ---------------------------------------------------------------------------
# batcher
# ---------------------------------------------------------------------------


def test_batcher_take_is_edf_with_stable_ties():
    b = RS.DeadlineBatcher(max_queue=16)
    b.push(_req(rid=0, t=0.0, deadline_s=0.09))
    b.push(_req(rid=1, t=0.0, deadline_s=0.05))
    b.push(_req(rid=2, t=0.0, deadline_s=0.05))   # tie with rid 1
    b.push(_req(rid=3, t=0.0, deadline_s=0.01))
    assert [r.rid for r in b.take(3)] == [3, 1, 2]
    assert [r.rid for r in b.take(3)] == [0]


def test_batcher_sweep_expired():
    b = RS.DeadlineBatcher(max_queue=16)
    b.push(_req(rid=0, t=0.0, deadline_s=0.02))
    b.push(_req(rid=1, t=0.0, deadline_s=0.10))
    dead = b.sweep_expired(now=0.05)
    assert [r.rid for r in dead] == [0] and b.depth == 1
    assert b.sweep_expired(now=0.05) == []


def test_batcher_force_time_semantics():
    svc = lambda bucket: 0.01 * bucket           # noqa: E731
    b = RS.DeadlineBatcher(max_queue=64)
    assert b.force_time(svc, 16) is None         # empty queue: no force
    b.push(_req(rid=0, t=0.0, deadline_s=0.5))
    b.push(_req(rid=1, t=0.0, deadline_s=0.3))
    # bucket_for(2)=2 → force at earliest deadline − svc(2)
    assert b.force_time(svc, 16) == pytest.approx(0.3 - 0.02)
    for i in range(14):
        b.push(_req(rid=2 + i, t=0.0, deadline_s=0.5))
    assert b.force_time(svc, 16) == 0.0          # full max bucket: now


# ---------------------------------------------------------------------------
# degradation controller (hysteresis unit contract)
# ---------------------------------------------------------------------------


def test_degrade_controller_patience_and_recovery():
    c = RS.DegradeController(n_levels=3, hi=1.0, lo=0.4,
                             up_patience=3, down_patience=4)
    for i in range(2):
        assert c.observe(2.0, float(i)) == 0     # not yet: patience 3
    assert c.observe(2.0, 2.0) == 1              # engage
    assert c.observe(2.0, 3.0) == 1              # streak reset on step
    assert c.observe(2.0, 4.0) == 1
    assert c.observe(2.0, 5.0) == 2              # deeper
    assert c.observe(2.0, 6.0) == 2              # floor: no level 3
    for i in range(3):
        assert c.observe(0.1, 7.0 + i) == 2
    assert c.observe(0.1, 10.0) == 1             # recover after 4 cool
    assert [(f, t) for _, f, t, _ in c.transitions] == \
        [(0, 1), (1, 2), (2, 1)]


def test_degrade_controller_dead_band_resets_streaks():
    c = RS.DegradeController(n_levels=2, hi=1.0, lo=0.4, up_patience=2,
                             down_patience=2)
    assert c.observe(2.0, 0.0) == 0
    assert c.observe(0.7, 1.0) == 0              # dead band: hot streak dies
    assert c.observe(2.0, 2.0) == 0
    assert c.observe(2.0, 3.0) == 1
    assert c.observe(0.1, 4.0) == 1
    assert c.observe(0.7, 5.0) == 1              # dead band: cool streak dies
    assert c.observe(0.1, 6.0) == 1
    assert c.observe(0.1, 7.0) == 0


# ---------------------------------------------------------------------------
# runtime end-to-end on the virtual clock (SimExecutor)
# ---------------------------------------------------------------------------


def test_single_request_completes_with_demuxed_k():
    srv = _server()
    r = _req(k=3, deadline_s=0.5)
    srv.submit(r)
    srv.drain()
    assert r.outcome is Outcome.COMPLETED
    assert r.vals.shape == (3,) and r.ids.shape == (3,)
    assert r.level == "exact"
    assert r.latency_s > 0


def test_batch_waits_for_free_bucket_padding():
    """Two requests arriving close together ride ONE dispatch (waiting is
    free until the queue crosses the next power of two)."""
    srv = _server()
    srv.submit(_req(rid=0, t=0.0, deadline_s=0.5))
    srv.clock.advance_to(0.001)
    srv.submit(_req(rid=1, t=0.001, deadline_s=0.5))
    srv.drain()
    rep = srv.metrics.report()
    assert rep["dispatches"] == 1
    assert rep["completed"] == 2


def test_queue_deadline_timeout_stamped_at_own_deadline():
    """When real service runs persistently slower than the estimates
    admission trusted, queued requests expire before ever dispatching —
    and leave TIMED_OUT at their OWN deadline, not at whenever the
    runtime next looked at the queue."""
    ex = FI.SlowExecutor(RS.SimExecutor(MODEL), slow_calls=range(64),
                         factor=10.0)             # svc(1): 2.1ms → 21ms
    srv = _server(ex, cfg=RS.ServeConfig(max_batch=1, max_queue=64))
    reqs = [_req(rid=i, deadline_s=0.05) for i in range(10)]
    for r in reqs:                 # all admitted: estimates say ~23ms wait
        assert srv.submit(r).admitted
    srv.drain()
    assert srv.metrics.conserved()
    expired = [r for r in reqs if r.reason == "queue_deadline"]
    assert len(expired) >= 5       # the queue tail never got a dispatch
    for r in expired:
        assert r.outcome is Outcome.TIMED_OUT
        assert r.t_terminal == pytest.approx(r.deadline)
    assert any(r.outcome is Outcome.COMPLETED for r in reqs)


def test_transient_dispatch_failure_absorbed_by_retry():
    ex = FI.FailingExecutor(RS.SimExecutor(MODEL), fail_calls=[0])
    # max_batch=1: a single request fills the bucket → immediate dispatch
    srv = _server(ex, cfg=RS.ServeConfig(max_batch=1, max_queue=64))
    r = _req(deadline_s=0.5)
    srv.submit(r)
    srv.drain()
    assert r.outcome is Outcome.COMPLETED
    rep = srv.metrics.report()
    assert rep["dispatch_retries"] == 1
    assert ex.calls == 2


def test_retry_exhaustion_times_out_not_loses():
    cfg = RS.ServeConfig(max_batch=16, max_queue=64, dispatch_attempts=3)
    ex = FI.FailingExecutor(RS.SimExecutor(MODEL), fail_calls=[0, 1, 2])
    srv = _server(ex, cfg=cfg)
    reqs = [_req(rid=i, deadline_s=0.5) for i in range(3)]
    for r in reqs:
        srv.submit(r)
    t0 = srv.clock.now()
    srv.drain()
    for r in reqs:
        assert r.outcome is Outcome.TIMED_OUT
        assert r.reason == "dispatch_failed"
    assert srv.metrics.conserved()
    assert srv.clock.now() > t0          # jittered backoff charged the clock
    assert ex.calls == 3


def test_slow_dispatch_causes_late_completion():
    ex = FI.SlowExecutor(RS.SimExecutor(MODEL), slow_calls=[0], factor=100.0)
    srv = _server(ex)
    r = _req(deadline_s=0.05)
    srv.submit(r)
    srv.drain()
    assert r.outcome is Outcome.TIMED_OUT and r.reason == "late_completion"
    assert r.vals is None                # late results are not delivered


def test_estimator_learns_from_injected_slowness():
    """The EWMA belief must absorb observed (injected-slow) dispatches —
    that is what lets admission start shedding under a real slowdown."""
    est = RS.ServiceEstimator(MODEL, alpha=0.5)
    ex = FI.SlowExecutor(RS.SimExecutor(MODEL), slow_calls=range(100),
                         factor=10.0)
    srv = RS.Server(ex, RS.sim_ladder(),
                    cfg=RS.ServeConfig(max_batch=4, max_queue=64),
                    estimator=est)
    lvl = RS.sim_ladder()[0]
    before = est.estimate(4, lvl)
    for i in range(8):                  # all queue (10s deadlines), then
        srv.submit(_req(rid=i, t=0.0, deadline_s=10.0))
    srv.drain()                         # drain as two max-batch buckets
    assert srv.metrics.report()["dispatches"] == 2
    assert est.estimate(4, lvl) > 5.0 * before


# ---------------------------------------------------------------------------
# overload: shedding, degradation engage + recovery
# ---------------------------------------------------------------------------


def _burst_trace(d=4, deadline_s=0.05):
    base = FI.poisson_requests(rate_qps=400, horizon_s=1.0, seed=1,
                               d_model=d, deadline_s=deadline_s)
    burst = FI.poisson_requests(rate_qps=20000, horizon_s=0.4, seed=2,
                                d_model=d, deadline_s=deadline_s,
                                t0=1.0, rid0=len(base))
    cool = FI.poisson_requests(rate_qps=400, horizon_s=1.5, seed=3,
                               d_model=d, deadline_s=deadline_s,
                               t0=1.4, rid0=len(base) + len(burst))
    return base + burst + cool


def _soak(executor=None):
    cfg = RS.ServeConfig(max_batch=16, max_queue=256, slo_s=0.05)
    srv = RS.Server(executor or RS.SimExecutor(MODEL), RS.sim_ladder(),
                    cfg=cfg, estimator=RS.ServiceEstimator(MODEL))
    reqs = _burst_trace()
    return RS.run_trace(srv, reqs), reqs


def test_overload_sheds_and_ladder_engages_then_recovers():
    m, _ = _soak()
    rep = m.report()
    assert rep["conserved"]
    assert rep["rejected"] > 0 and rep["shed_rate"] > 0.05
    assert set(rep["reasons"]) >= {"queue_full", "predicted_late"}
    # ladder engaged during the burst AND fully recovered after it
    levels = [(frm, to) for _, frm, to, _ in rep["transitions"]]
    assert (0, 1) in levels, rep["transitions"]
    assert rep["transitions"][-1][2] == 0        # ends back at exact
    # degraded dispatches actually served requests
    assert len(rep["level_dispatches"]) >= 2
    assert sum(v for k, v in rep["level_dispatches"].items()
               if k != "exact") > 0
    # admitted requests still overwhelmingly met their deadlines
    assert rep["deadline_met_of_admitted"] > 0.99


def test_soak_conservation_every_request_exactly_one_terminal():
    m, reqs = _soak(FI.SlowExecutor(
        FI.FailingExecutor(RS.SimExecutor(MODEL), fail_calls=[5, 120, 121]),
        slow_calls=[10, 90], factor=8.0))
    assert m.conserved()
    assert m.submitted == len(reqs)
    for r in reqs:                       # exactly one terminal door each
        assert r.outcome is not None, r.rid
    by = {o: sum(1 for r in reqs if r.outcome is o) for o in Outcome}
    rep = m.report()
    assert by[Outcome.COMPLETED] == rep["completed"]
    assert by[Outcome.REJECTED] == rep["rejected"]
    assert by[Outcome.TIMED_OUT] == rep["timed_out"]
    assert rep["dispatch_retries"] >= 1  # injected faults actually fired


def test_soak_bit_deterministic_replay():
    """Same seeded trace + config → byte-identical report, including the
    full-jitter retry delays (seeded rng) and transition timestamps."""
    def run():
        ex = FI.SlowExecutor(
            FI.FailingExecutor(RS.SimExecutor(MODEL),
                               fail_calls=[5, 120, 121]),
            slow_calls=[10, 90], factor=8.0)
        m, _ = _soak(ex)
        return m.report()

    assert run() == run()


def test_loadgen_deterministic_and_open_loop():
    a = FI.poisson_requests(rate_qps=500, horizon_s=1.0, seed=7, d_model=8)
    b = FI.poisson_requests(rate_qps=500, horizon_s=1.0, seed=7, d_model=8)
    assert len(a) == len(b) > 300
    assert all(x.submit_t == y.submit_t and
               np.array_equal(x.x, y.x) and x.tenant == y.tenant
               for x, y in zip(a, b))
    c = FI.poisson_requests(rate_qps=500, horizon_s=1.0, seed=8, d_model=8)
    assert [r.submit_t for r in a] != [r.submit_t for r in c]
    # t0/rid0 composition: segment timestamps live in [t0, t0+horizon)
    seg = FI.poisson_requests(rate_qps=500, horizon_s=0.5, seed=9,
                              d_model=8, t0=10.0, rid0=len(a))
    assert all(10.0 <= r.submit_t < 10.5 for r in seg)
    assert seg[0].rid == len(a)


def test_tenant_fairness_under_overload():
    """A hot tenant over its rate is throttled; the in-policy tenant's
    completions survive the hot tenant's flood."""
    cfg = RS.ServeConfig(max_batch=16, max_queue=256, slo_s=0.05)
    policies = {"hot": TenantPolicy(rate_qps=50.0, burst=10.0)}
    srv = RS.Server(RS.SimExecutor(MODEL), RS.sim_ladder(), cfg=cfg,
                    policies=policies,
                    estimator=RS.ServiceEstimator(MODEL))
    hot = FI.poisson_requests(rate_qps=2000, horizon_s=1.0, seed=1,
                              d_model=4, tenants=("hot",))
    cold = FI.poisson_requests(rate_qps=100, horizon_s=1.0, seed=2,
                               d_model=4, tenants=("cold",), rid0=10**6)
    rep = RS.run_trace(srv, hot + cold).report()
    assert rep["conserved"]
    assert rep["reasons"].get("tenant_throttled", 0) > 1000
    done_hot = sum(1 for r in hot if r.outcome is Outcome.COMPLETED)
    done_cold = sum(1 for r in cold if r.outcome is Outcome.COMPLETED)
    assert done_hot <= 75                # ≈ rate × horizon + burst
    assert done_cold >= 0.95 * len(cold)


# ---------------------------------------------------------------------------
# real head: executor demux + ladder gating
# ---------------------------------------------------------------------------


def _small_head():
    import jax

    from repro.core import elmo_head as H
    from repro.head import ELMOHead

    cfg = H.ELMOHeadConfig(num_labels=512, d_model=16, num_chunks=2,
                           weight_dtype="bf16", use_sr=False, impl="xla")
    head = ELMOHead(cfg, batch=8)
    state = head.init(jax.random.PRNGKey(0))
    return head, state


def test_head_executor_demux_matches_direct_topk():
    """Per-request results demuxed from a padded bucket at k_hat=max(k)
    equal a direct head.topk row-for-row, each trimmed to its own k."""
    import jax

    head, state = _small_head()
    levels = [RS.DegradeLevel(
        "exact", 1.0, 1.0,
        lambda s, x, k: head.topk(s, x, k, shortlist=None))]
    ex = RS.HeadExecutor(state, timing="model", model=MODEL)
    srv = RS.Server(ex, levels,
                    cfg=RS.ServeConfig(max_batch=8, max_queue=32),
                    estimator=RS.ServiceEstimator(MODEL))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, tenant="default",
                    x=rng.standard_normal(16).astype(np.float32),
                    k=[3, 5, 2][i % 3], submit_t=1e-4 * i, deadline_s=0.5)
            for i in range(6)]
    RS.run_trace(srv, list(reqs))
    assert all(r.outcome is Outcome.COMPLETED for r in reqs)
    # ONE dispatch: all six rode a single padded bucket-8 program
    assert srv.metrics.report()["dispatches"] == 1
    xs = np.zeros((8, 16), np.float32)
    order = sorted(reqs, key=lambda r: (r.deadline, 0))  # EDF batch order
    for i, r in enumerate(order):
        xs[i] = r.x
    vals, ids = jax.jit(lambda s, x: head.topk(s, x, 5))(state, xs)
    for i, r in enumerate(order):
        np.testing.assert_array_equal(r.vals, np.asarray(vals)[i, :r.k])
        np.testing.assert_array_equal(r.ids, np.asarray(ids)[i, :r.k])


def test_build_ladder_plan_gate_collapses_without_shortlist_path():
    """A geometry whose shortlist="on" twin still refuses the shortlist
    path (L < 256: stage 1 would cost as much as exact) can never offer
    a degraded rung — no index is even built."""
    import jax

    from repro.core import elmo_head as H
    from repro.head import ELMOHead

    cfg = H.ELMOHeadConfig(num_labels=128, d_model=16, num_chunks=2,
                           weight_dtype="bf16", use_sr=False, impl="xla")
    head = ELMOHead(cfg, batch=8)
    state = head.init(jax.random.PRNGKey(0))
    levels = RS.build_ladder(head, state, k=5, max_batch=8)
    assert [lv.name for lv in levels] == ["exact"]


@pytest.mark.slow
def test_build_ladder_recall_gate_structured_vs_random():
    """On the golden structured head (PR 7 fixture recipe) the full-beam
    shortlist rung clears the 0.95 floor and joins the ladder; the
    half-beam rung (measured ≈0.91) and every rung of an i.i.d.-random
    head are correctly dropped."""
    import jax
    import jax.numpy as jnp

    from repro.core import elmo_head as H
    from repro.head import ELMOHead
    from repro.head import shortlist as SL

    cfg = H.ELMOHeadConfig(num_labels=4096, d_model=64, num_chunks=8,
                           weight_dtype="e4m3", use_sr=False)
    head = ELMOHead(cfg, batch=16)
    state = SL.synthetic_clustered_state(cfg, groups=128, noise=0.2, seed=7)
    probe = jax.random.normal(jax.random.PRNGKey(11),
                              (64, 64)).astype(jnp.bfloat16)
    # golden index geometry (tests/_shortlist_checks.GOLDEN): C=64 beam=28
    levels = RS.build_ladder(head, state, k=10, max_batch=16,
                             probe_x=probe, iters=8,
                             n_clusters=64, beam=28)
    assert [lv.name for lv in levels] == ["exact", "shortlist"]
    assert levels[1].recall >= 0.95
    assert levels[1].cost_scale < 0.5            # §11 work model
    # lowering the floor to 0.9 re-admits the half-beam rung, in
    # strictly descending cost order
    levels_lo = RS.build_ladder(head, state, k=10, max_batch=16,
                                probe_x=probe, iters=8,
                                n_clusters=64, beam=28, recall_floor=0.9)
    assert [lv.name for lv in levels_lo] == \
        ["exact", "shortlist", "shortlist/2"]
    assert levels_lo[1].cost_scale > levels_lo[2].cost_scale
    assert levels_lo[2].recall < 0.95
    # the same geometry on an i.i.d.-random head: no rung survives
    rnd = head.init(jax.random.PRNGKey(0))
    assert [lv.name for lv in RS.build_ladder(
        head, rnd, k=10, max_batch=16, probe_x=probe, iters=8,
        n_clusters=64, beam=28)] == ["exact"]


@pytest.mark.slow
def test_degraded_level_serves_real_shortlisted_results():
    """A runtime pinned at a degraded rung serves actual shortlisted
    top-k (ids drawn from the admitted clusters), not placeholders."""
    import jax
    import jax.numpy as jnp

    from repro.core import elmo_head as H
    from repro.head import ELMOHead
    from repro.head import shortlist as SL

    cfg = H.ELMOHeadConfig(num_labels=4096, d_model=64, num_chunks=8,
                           weight_dtype="e4m3", use_sr=False)
    head = ELMOHead(cfg, batch=4)
    state = SL.synthetic_clustered_state(cfg, groups=128, noise=0.2, seed=7)
    probe = jax.random.normal(jax.random.PRNGKey(11),
                              (64, 64)).astype(jnp.bfloat16)
    levels = RS.build_ladder(head, state, k=10, max_batch=4,
                             probe_x=probe, iters=8,
                             n_clusters=64, beam=28)
    assert len(levels) == 2
    ex = RS.HeadExecutor(state, timing="model", model=MODEL)
    rng = np.random.default_rng(3)
    x = rng.standard_normal((4, 64)).astype(np.float32)
    exact = ex.dispatch(x, 10, levels[0])
    degraded = ex.dispatch(x, 10, levels[1])
    # recall of the degraded answers vs exact on this batch ≥ the floor
    hits = sum(len(set(map(int, degraded.ids[i])) &
                   set(map(int, exact.ids[i]))) for i in range(4))
    assert hits / (4 * 10) >= 0.9
    assert (np.asarray(degraded.ids) < cfg.num_labels).all()


# ---------------------------------------------------------------------------
# forced-4-device soak through the sharded top-k path
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_multidevice_serve_runtime(multidevice_runner):
    out = multidevice_runner("_serve_runtime_checks.py", 4)
    assert "ALL SERVE RUNTIME CHECKS PASSED" in out
