"""Train/serve step composition: gradient accumulation equivalence,
paper-ordering seam, and end-to-end convergence parity (ELMO vs fp32)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import elmo_head as EH
from repro.launch import steps as St
from repro.models import transformer as T
from repro.optim import kahan_adamw, sgd_sr


def _batch(cfg, B=8, S=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    return {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
            "targets": jax.random.randint(ks[1], (B, S), 0, cfg.vocab)}


def test_grad_accum_matches_single_batch_loss():
    """accum=4 over the same global batch ≈ accum=1 (head updates stream,
    so weights differ slightly — losses and grads must stay close)."""
    cfg1 = get_smoke("smollm-360m")
    cfg4 = dataclasses.replace(cfg1, grad_accum=4)
    opt = kahan_adamw(weight_decay=0.0)
    state = St.init_train_state(jax.random.PRNGKey(1), cfg1, opt, impl="xla")
    batch = _batch(cfg1)
    s1, m1 = St.train_step(cfg1, opt, state, batch, jnp.float32(0.05),
                           jnp.float32(1e-3), impl="xla")
    s4, m4 = St.train_step(cfg4, opt, state, batch, jnp.float32(0.05),
                           jnp.float32(1e-3), impl="xla")
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 0.05, (m1, m4)
    # backbone params after update agree to bf16 tolerance
    for a, b in zip(jax.tree.leaves(s1.backbone), jax.tree.leaves(s4.backbone)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0.1, atol=2e-2)


def test_head_never_in_autodiff_graph():
    """Loss-skipping by construction: backbone grads must not depend on the
    head entering autodiff — vjp sees only the (B·S, D) seam."""
    cfg = get_smoke("smollm-360m")
    opt = sgd_sr()
    state = St.init_train_state(jax.random.PRNGKey(1), cfg, opt, impl="xla")
    batch = _batch(cfg)
    # jaxpr of the step must contain no sigmoid/softmax-grad on (·, vocab)…
    # cheap proxy: the step runs with a head whose logits would overflow an
    # O(B·S·V) autodiff buffer if it were differentiated through
    new_state, metrics = St.train_step(cfg, opt, state, batch,
                                       jnp.float32(0.1), jnp.float32(1e-3),
                                       impl="xla")
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state.step) == 1


def test_elmo_fp8_matches_fp32_training_quality():
    """Convergence parity (paper Tables 2/3 in miniature): training the
    same tiny model with an FP8+SR head reaches a loss within 5% of the
    f32-head run after 40 steps."""
    losses = {}
    for name, wd in (("fp32", "f32"), ("fp8", "e4m3")):
        cfg = get_smoke("smollm-360m", vocab=512)
        cfg = dataclasses.replace(cfg, head_weight_dtype=wd)
        opt = kahan_adamw(weight_decay=0.0)
        state = St.init_train_state(jax.random.PRNGKey(1), cfg, opt,
                                    impl="xla")
        step = jax.jit(lambda s, t, y: St.train_step(
            cfg, opt, s, {"tokens": t, "targets": y}, jnp.float32(0.3),
            jnp.float32(2e-3), impl="xla"))
        rng = np.random.default_rng(0)
        for i in range(40):
            toks = jnp.asarray(rng.integers(0, 512, (8, 17)), jnp.int32)
            state, m = step(state, toks[:, :-1], toks[:, 1:])
        losses[name] = float(m["loss"])
    assert abs(losses["fp8"] - losses["fp32"]) < 0.05 * losses["fp32"] + 0.1, \
        losses


def test_microbatch_seeds_distinct_and_match_scan():
    """ISSUE 4 satellite: grad-accum microbatches draw DISTINCT SR seeds.

    With identical data in both microbatches of an e4m3+SR head, the
    scanned ``train_step`` must equal the sequential two-call emulation
    using the per-index seed derivation — and the second microbatch's
    update must NOT replay the first one's stochastic-rounding draws
    (the historical ``mix32(seed + 1)`` bug made every microbatch's seed
    identical)."""
    cfg = get_smoke("xmc-bert-3m", head_labels=1024, head_chunks=4)
    cfg = dataclasses.replace(cfg, grad_accum=2)
    assert cfg.head_weight_dtype == "e4m3"       # SR is live
    opt = kahan_adamw(weight_decay=0.0)
    state = St.init_train_state(jax.random.PRNGKey(1), cfg, opt, impl="xla")
    mb, S = 4, 16
    t0 = jax.random.randint(jax.random.PRNGKey(5), (mb, S), 0, cfg.vocab)
    y0 = jax.random.randint(jax.random.PRNGKey(6), (mb, 8), 0,
                            cfg.head_size)
    batch = {"tokens": jnp.concatenate([t0, t0]),
             "targets": jnp.concatenate([y0, y0])}
    lr, wd = jnp.float32(0.1), jnp.float32(1e-4)
    new_state, _ = St.train_step(cfg, opt, state, batch, lr,
                                 jnp.float32(1e-3), wd, impl="xla")

    from repro.kernels import prng_utils as PR
    seed = PR.mix32(jnp.uint32(0))               # state.step == 0
    s0, s1 = St._micro_seed(seed, 0), St._micro_seed(seed, 1)
    assert int(s0) != int(s1)
    hcfg = St.make_head_cfg(cfg, "xla")
    h1, _, _ = St._one_microbatch(cfg, hcfg, state.backbone, state.head,
                                  t0, y0, None, lr, wd, s0)
    h2, _, _ = St._one_microbatch(cfg, hcfg, state.backbone, h1,
                                  t0, y0, None, lr, wd, s1)
    # the scan is exactly the sequential emulation with the derived seeds
    np.testing.assert_array_equal(np.asarray(h2.w, np.float32),
                                  np.asarray(new_state.head.w, np.float32))
    # replaying microbatch 0's seed (the old bug) gives DIFFERENT SR draws
    h2_replay, _, _ = St._one_microbatch(cfg, hcfg, state.backbone, h1,
                                         t0, y0, None, lr, wd, s0)
    assert not np.array_equal(np.asarray(h2_replay.w, np.float32),
                              np.asarray(h2.w, np.float32)), \
        "microbatch 2 replayed microbatch 1's SR stream"


def test_grad_accum_head_weight_divergence_sanity():
    """n_micro=1 vs n_micro=2 on the same global batch: the streaming head
    updates (and per-microbatch seeds) make the head weights diverge — but
    only slightly (losses stay close, per the accumulation contract)."""
    cfg1 = get_smoke("xmc-bert-3m", head_labels=1024, head_chunks=4)
    cfg2 = dataclasses.replace(cfg1, grad_accum=2)
    opt = kahan_adamw(weight_decay=0.0)
    state = St.init_train_state(jax.random.PRNGKey(1), cfg1, opt, impl="xla")
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    batch = {"tokens": jax.random.randint(ks[0], (8, 16), 0, cfg1.vocab),
             "targets": jax.random.randint(ks[1], (8, 8), 0,
                                           cfg1.head_size)}
    s1, m1 = St.train_step(cfg1, opt, state, batch, jnp.float32(0.1),
                           jnp.float32(1e-3), impl="xla")
    s2, m2 = St.train_step(cfg2, opt, state, batch, jnp.float32(0.1),
                           jnp.float32(1e-3), impl="xla")
    w1 = np.asarray(s1.head.w, np.float32)
    w2 = np.asarray(s2.head.w, np.float32)
    assert not np.array_equal(w1, w2)            # streaming ⇒ not bitwise
    # fp8+SR quantization noise dominates the elementwise delta; the norm
    # stays bounded — the accumulation contract.  (Losses are NOT close
    # here by design: microbatch 2's loss is measured after microbatch 1's
    # streamed update already moved the head at this lr.)
    rel = np.linalg.norm(w1 - w2) / max(np.linalg.norm(w1), 1e-30)
    assert rel < 0.5, rel
    l1, l2 = float(m1["loss"]), float(m2["loss"])
    assert np.isfinite(l1) and np.isfinite(l2)
    assert abs(l1 - l2) < 0.5 * abs(l1), (l1, l2)


def test_serve_prefill_decode_roundtrip_greedy_consistency():
    """decode(prefill(prompt)) == decode path applied token by token."""
    cfg = get_smoke("smollm-360m")
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    st = St.init_serve_state(jax.random.PRNGKey(2), cfg, B, max_len=S + 4,
                             impl="xla")
    t1, st1 = St.serve_prefill(cfg, st, toks)
    # pure step-by-step decode over the same prompt
    st2 = St.init_serve_state(jax.random.PRNGKey(2), cfg, B, max_len=S + 4,
                              impl="xla")
    hidden = None
    for i in range(S):
        tok_out, st2 = St.serve_decode(cfg, st2, toks[:, i:i + 1])
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(tok_out))
