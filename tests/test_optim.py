"""Optimizer tests: Kahan-AdamW vs f32 oracle, SGD-SR progress, MPT overflow
handling, Renee baseline stability, analytic memory model vs paper numbers."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import memory_model as MM
from repro.core import renee_baseline as RB
from repro.optim import adamw, kahan_adamw, mpt_adamw, sgd_sr


def _rosenbrock_grads(p):
    def f(p):
        return ((1 - p["a"]) ** 2).sum() + 100 * ((p["b"] - p["a"] ** 2) ** 2).sum()
    return jax.grad(f)(p)


def test_kahan_adamw_tracks_f32_adamw():
    """BF16+Kahan stays close to the f32 AdamW trajectory (paper §4.1)."""
    p32 = {"a": jnp.zeros((64,), jnp.float32), "b": jnp.zeros((64,), jnp.float32)}
    p16 = {"a": jnp.zeros((64,), jnp.bfloat16), "b": jnp.zeros((64,), jnp.bfloat16)}
    opt32, opt16 = adamw(weight_decay=0.0), kahan_adamw(weight_decay=0.0)
    s32, s16 = opt32.init(p32), opt16.init(p16)
    lr = jnp.float32(1e-3)
    for step in range(300):
        st = jnp.int32(step)
        g32 = _rosenbrock_grads({k: v.astype(jnp.float32) for k, v in p32.items()})
        g16 = _rosenbrock_grads({k: v.astype(jnp.float32) for k, v in p16.items()})
        p32, s32 = opt32.update(p32, s32, g32, st, lr)
        p16, s16 = opt16.update(p16, s16, g16, st, lr)
    for k in p32:
        a = np.asarray(p32[k])
        b = np.asarray(p16[k], np.float32)
        np.testing.assert_allclose(a, b, atol=2e-2, rtol=0.1)


def test_plain_bf16_adamw_stalls_but_kahan_does_not():
    """Tiny constant gradient: bf16 RN cancels updates; Kahan accumulates."""
    p = {"w": jnp.ones((16,), jnp.bfloat16)}
    g = {"w": jnp.full((16,), 1.0, jnp.float32)}
    opt = kahan_adamw(weight_decay=0.0)
    s = opt.init(p)
    lr = jnp.float32(3e-5)  # Adam step ≈ lr << bf16 ulp at 1.0 (0.0078)
    for step in range(200):
        p, s = opt.update(p, s, g, jnp.int32(step), lr)
    moved = 1.0 - float(p["w"][0].astype(jnp.float32))
    assert moved > 0.004, moved  # ≈ 200 × 3e-5 = 6e-3 net movement


def test_sgd_sr_makes_progress_below_ulp():
    p = {"w": jnp.full((256,), 1.0, jnp.bfloat16)}
    g = {"w": jnp.full((256,), 1.0, jnp.float32)}
    opt = sgd_sr()
    s = opt.init(p)
    lr = jnp.float32(1e-4)  # far below ulp(1.0)=0.0078
    for step in range(400):
        p, s = opt.update(p, s, g, jnp.int32(step), lr)
    mean = float(np.asarray(p["w"], np.float32).mean())
    assert abs((1.0 - mean) - 400 * 1e-4) < 0.01, mean


def test_mpt_adamw_skips_on_overflow_and_halves_scale():
    p = {"w": jnp.ones((8,), jnp.float16)}
    opt = mpt_adamw()
    s = opt.init(p)
    g_bad = {"w": jnp.full((8,), np.inf, jnp.float16)}
    p2, s2 = opt.update(p, s, g_bad, jnp.int32(0), jnp.float32(1e-3))
    np.testing.assert_array_equal(np.asarray(p2["w"], np.float32),
                                  np.asarray(p["w"], np.float32))
    assert float(s2["w"].loss_scale) == float(s["w"].loss_scale) / 2


def test_renee_baseline_trains_small():
    cfg = RB.ReneeConfig(num_labels=128, d_model=32, init_loss_scale=8.0)
    state = RB.init_renee(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 32), jnp.float32)
    tg = jax.random.randint(jax.random.PRNGKey(2), (16, 3), 0, 128)
    losses = []
    for i in range(25):
        state, xg, m = RB.renee_train_step(cfg, state, x, tg, jnp.float32(0.1))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


def test_renee_overflows_with_huge_scale_elmo_does_not():
    """The paper's instability claim: FP16 input-grad matmul overflows when
    the loss scale × label count pushes the accumulation past FP16 range."""
    cfg = RB.ReneeConfig(num_labels=4096, d_model=16,
                         init_loss_scale=2.0 ** 24)
    state = RB.init_renee(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16), jnp.float32) * 4
    tg = jax.random.randint(jax.random.PRNGKey(2), (8, 3), 0, 4096)
    _, _, m = RB.renee_train_step(cfg, state, x, tg, jnp.float32(0.05))
    assert bool(m["overflow"])  # step skipped → instability/slowdown


def test_memory_model_matches_paper_numbers():
    """§4.4: Renee ≈ 39.7 GiB, ELMO-BF16 ≈ 10.3, ELMO-FP8 ≈ 6.6 at 3M."""
    s = MM.MemScenario(num_labels=2_812_281)
    renee = MM.renee_peak(s)["total"] / MM.GIB
    bf16 = MM.elmo_peak(s, "bf16")["total"] / MM.GIB
    fp8 = MM.elmo_peak(s, "e4m3")["total"] / MM.GIB
    assert abs(renee - 39.7) < 2.5, renee
    assert abs(bf16 - 10.3) < 1.5, bf16
    assert abs(fp8 - 6.6) < 1.0, fp8
    # 4–6× reduction claim
    assert 3.5 < renee / bf16 < 5.5
    assert 5.0 < renee / fp8 < 7.5


def test_memory_model_sweep_monotone():
    rows = MM.sweep_labels([131_072, 670_091, 3_000_000, 8_623_847])
    for k in ("renee_gib", "elmo_bf16_gib", "elmo_fp8_gib"):
        v = [r[k] for r in rows]
        assert all(a < b for a, b in zip(v, v[1:]))
    # ratio grows with label count (paper: 6× at 3M → 11× at 8.6M)
    ratios = [r["renee_gib"] / r["elmo_fp8_gib"] for r in rows]
    assert ratios[-1] > ratios[0]
