"""Numerics guard (ISSUE 10): in-kernel FP8 telemetry, divergence
detection, and rollback-and-escalate recovery.

The contract under test (DESIGN.md §14):

* **Bitwise invisibility** — ``guard=True`` changes NOTHING but the extra
  ``metrics["telemetry"]`` vector: W, Kahan comp, x̄ and loss are
  bit-identical to ``guard=False`` on every train path (fused scan, grid
  megakernel, sparse megakernel, the full ``launch.train`` driver), for
  SR and Kahan updates, BCE and softmax-CE.
* **Telemetry parity** — the Pallas kernels' accumulated counters equal
  the jnp oracle's bit-for-bit (same slots, same counts, same comp max).
* **Detection** — the ``NumericsMonitor`` trips on non-finite loss /
  logits / telemetry, on the saturation fraction, and on EWMA loss
  spikes; spiking observations never drag their own baseline up.
* **Recovery** — ``run_guarded`` escalates the persisted ladder FIRST,
  then quarantines the suspect checkpoint (§10 CORRUPT demotion), rolls
  back and converges; the ladder replays deterministically, and a SIGKILL
  mid-recovery resumes to a bit-identical final state (manifest leaf
  crc32s compared across a killed and an unkilled run).
* Satellites: the sparse prune/regrow cadence fires under gradient
  accumulation (``n_micro > 1``); ``python -m repro.checkpoint verify``
  audits every leaf with a nonzero exit on damage; non-finite values
  propagate (never silently masked) through the losses and the top-k
  kernel keeps bit parity under ±Inf.
"""
import dataclasses
import json
import math
import os
import subprocess
import sys
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import head as RH
from repro.checkpoint import committed_paths, latest_committed
from repro.configs import get_smoke
from repro.core import elmo_head as H
from repro.core import losses as L
from repro.fault import inject as FI
from repro.head import serving
from repro.head.state import state_bits_equal
from repro.kernels import ops, ref
from repro.launch import steps as St
from repro.launch.train import run_guarded, train
from repro.numerics import recovery as NR
from repro.numerics import telemetry as NT
from repro.numerics.monitor import NumericsMonitor
from repro.optim import kahan_adamw

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_HYPERS = (jnp.float32(0.05), jnp.float32(1e-4), jnp.uint32(7))


def _bits_eq(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return a.shape == b.shape and a.tobytes() == b.tobytes()


def _mk_dense(loss, wdtype, kahan, use_sr, impl, B=6, D=24, NL=500, C=2):
    cfg = H.ELMOHeadConfig(num_labels=NL, d_model=D, num_chunks=C,
                           weight_dtype=wdtype, loss=loss, use_sr=use_sr,
                           kahan_chunks=kahan, impl=impl)
    state = H.init_head(jax.random.PRNGKey(0), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(1), (B, D)) * 0.5
         ).astype(jnp.bfloat16)
    shape = (B, 8) if loss == "bce" else (B,)
    tgt = jax.random.randint(jax.random.PRNGKey(2), shape, 0, NL)
    return cfg, state, x, tgt


def _run_steps(cfg, state, x, tgt, n=3):
    metrics = None
    for s in range(n):
        hy = (_HYPERS[0], _HYPERS[1], jnp.uint32(7 + s))
        state, xg, metrics = H.head_train_step(cfg, state, x, tgt, *hy)
    return state, xg, metrics


# ---------------------------------------------------------------------------
# bitwise invisibility + telemetry parity (dense)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("loss", ["bce", "softmax_ce"])
@pytest.mark.parametrize("mode", ["sr", "kahan"])
def test_guard_invisible_dense_fused(loss, mode):
    """guard=True is bit-invisible on the fused-scan path — and the
    telemetry it adds is finite with integer-valued count slots."""
    kahan, use_sr = (0, True) if mode == "sr" else (2, False)
    cfg, st0, x, tgt = _mk_dense(loss, "e4m3", kahan, use_sr, "fused_xla")
    g_cfg = dataclasses.replace(cfg, guard=True)
    s_off, xg_off, m_off = _run_steps(cfg, st0, x, tgt)
    s_on, xg_on, m_on = _run_steps(g_cfg, st0, x, tgt)
    assert state_bits_equal(s_off, s_on)
    assert _bits_eq(xg_off, xg_on)
    assert _bits_eq(m_off["loss"], m_on["loss"])
    assert "telemetry" not in m_off
    tele = np.asarray(m_on["telemetry"])
    assert tele.shape == (NT.N_SLOTS,)
    assert np.isfinite(tele).all()
    for name in ("sat", "z_nonfinite", "lse_nonfinite", "xg_nonfinite"):
        v = tele[NT.SLOTS[name]]
        assert v == int(v) and v >= 0, (name, v)
    if mode == "kahan":
        assert tele[NT.SLOTS["comp_max"]] > 0    # comp is live from step 1
    else:
        assert tele[NT.SLOTS["comp_max"]] == 0.0


@pytest.mark.parametrize("loss", ["bce", "softmax_ce"])
def test_guard_telemetry_parity_grid_vs_scan(loss):
    """The grid megakernel's in-VMEM accumulated telemetry equals the
    per-chunk scan oracle's bit-for-bit (and both stay bit-invisible)."""
    outs = {}
    for impl in ("grid_interpret", "fused_xla"):
        cfg, st0, x, tgt = _mk_dense(loss, "e4m3", 2, False, impl)
        g_cfg = dataclasses.replace(cfg, guard=True)
        s_on, xg_on, m_on = _run_steps(g_cfg, st0, x, tgt)
        s_off, xg_off, m_off = _run_steps(cfg, st0, x, tgt)
        assert state_bits_equal(s_off, s_on)
        assert _bits_eq(m_off["loss"], m_on["loss"])
        outs[impl] = (s_on, np.asarray(m_on["telemetry"]))
    sg, tg = outs["grid_interpret"]
    sf, tf = outs["fused_xla"]
    assert state_bits_equal(sg, sf)
    assert _bits_eq(tg, tf), (tg, tf)


def test_guard_counts_injected_saturation_dense():
    """A Kahan comp poisoned past the e4m3 cliff must show up in the sat
    slot with the exact poisoned-element count — the counter counts."""
    cfg, st0, x, tgt = _mk_dense("bce", "e4m3", 2, False, "fused_xla")
    g_cfg = dataclasses.replace(cfg, guard=True)
    comp = np.asarray(st0.comp.astype(jnp.float32)).copy()
    comp.reshape(-1)[:64] = 450.0      # rounds to ±448, stays finite
    st0 = st0._replace(comp=jnp.asarray(comp).astype(st0.comp.dtype))
    _, _, m = _run_steps(g_cfg, st0, x, tgt, n=1)
    tele = np.asarray(m["telemetry"])
    assert tele[NT.SLOTS["sat"]] >= 64
    assert np.isfinite(tele).all()


# ---------------------------------------------------------------------------
# bitwise invisibility + parity (sparse megakernel)
# ---------------------------------------------------------------------------


def _mk_sparse(mode, B=5, D=32, NL=400, C=2, F=8):
    kahan, use_sr = (0, True) if mode == "sr" else (C, False)
    cfg = H.ELMOHeadConfig(num_labels=NL, d_model=D, num_chunks=C,
                           weight_dtype="e4m3", loss="bce", use_sr=use_sr,
                           kahan_chunks=kahan, fan_in=F)
    from repro.head.sparse import init_sparse_head
    state = init_sparse_head(jax.random.PRNGKey(0), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(1), (B, D)) * 0.5
         ).astype(jnp.bfloat16)
    tgt = jax.random.randint(jax.random.PRNGKey(2), (B, 8), 0, NL)
    return cfg, state, x, tgt


def _run_sparse(cfg, state, x, tgt, inner):
    from repro.head.sparse.train import train_step_sparse
    plan = RH.resolve_plan(cfg, batch=x.shape[0], target_slots=tgt.shape[-1])
    assert plan.path == "sparse", plan.path
    plan = dataclasses.replace(plan, train_inner=inner)
    return train_step_sparse(plan, cfg, state, x, tgt, *_HYPERS)


@pytest.mark.parametrize("mode", ["sr", "kahan"])
def test_guard_invisible_sparse_and_kernel_parity(mode):
    """Sparse megakernel: guard-on ≡ guard-off bitwise, and the kernel's
    telemetry equals the scan oracle's bit-for-bit."""
    outs = {}
    for inner in ("interpret", "xla"):
        cfg, st0, x, tgt = _mk_sparse(mode)
        g_cfg = dataclasses.replace(cfg, guard=True)
        s_on, xg_on, m_on = _run_sparse(g_cfg, st0, x, tgt, inner)
        s_off, xg_off, m_off = _run_sparse(cfg, st0, x, tgt, inner)
        assert state_bits_equal(s_off, s_on)
        assert _bits_eq(xg_off, xg_on)
        assert _bits_eq(m_off["loss"], m_on["loss"])
        outs[inner] = (s_on, np.asarray(m_on["telemetry"]))
    si, ti = outs["interpret"]
    sx, tx = outs["xla"]
    assert state_bits_equal(si, sx)
    assert _bits_eq(ti, tx), (ti, tx)
    assert np.isfinite(ti).all()


# ---------------------------------------------------------------------------
# guard invisibility through the full training driver
# ---------------------------------------------------------------------------


def test_guard_invisible_launch_train(tmp_path):
    """The whole ``launch.train`` loop (backbone + head + data pipeline)
    produces a bit-identical loss trajectory and head state with the guard
    armed — on the XMC smoke config (BCE + Kahan + grad path)."""
    cfg = get_smoke("xmc-bert-3m", head_labels=600)
    kw = dict(steps=8, global_batch=4, seq=16, ckpt_dir="", impl="xla",
              log_every=100)
    st_off, l_off = train(cfg, **kw)
    st_on, l_on = train(cfg, guard=True, **kw)
    assert [float(a) for a in l_off] == [float(a) for a in l_on]
    assert state_bits_equal(st_off.head, st_on.head)


def test_guard_invisible_grad_accum_merge():
    """n_micro > 1: per-microbatch telemetry merges (counts add, comp max
    maxes) and the guard stays bit-invisible through the accumulation
    scan."""
    cfg = get_smoke("xmc-bert-3m", head_labels=600)
    cfg = dataclasses.replace(cfg, grad_accum=2)
    opt = kahan_adamw()
    state = St.init_train_state(jax.random.PRNGKey(0), cfg, opt, impl="xla")
    from repro.data import DataCursor, xmc_batches
    b = next(xmc_batches(cfg.vocab, cfg.head_labels, 4, 16,
                         cfg.max_labels_per_example,
                         DataCursor(seed=1234, step=0), 0, 1))
    batch = {"tokens": jnp.asarray(b["tokens"]),
             "targets": jnp.asarray(b["targets"])}
    g_cfg = dataclasses.replace(cfg, head_guard=True)
    s_off, m_off = St.train_step(cfg, opt, state, batch, jnp.float32(0.05),
                                 jnp.float32(2e-5), impl="xla")
    s_on, m_on = St.train_step(g_cfg, opt, state, batch, jnp.float32(0.05),
                               jnp.float32(2e-5), impl="xla")
    assert _bits_eq(m_off["loss"], m_on["loss"])
    assert state_bits_equal(s_off.head, s_on.head)
    tele = np.asarray(m_on["telemetry"])
    assert np.isfinite(tele).all()
    # two microbatches: count slots are sums over both (still integers)
    assert tele[NT.SLOTS["sat"]] == int(tele[NT.SLOTS["sat"]])


# ---------------------------------------------------------------------------
# satellite 1: sparse prune/regrow fires under gradient accumulation
# ---------------------------------------------------------------------------


def test_prune_regrow_fires_with_grad_accum():
    """Regression: the prune/regrow cadence is defined on optimizer steps,
    but the scan over microbatches used to pass no step at all — fan-in
    connectivity never moved under ``grad_accum > 1``.  Now the
    accumulation-boundary microbatch fires it: indices must move exactly
    when they do in an equivalent n_micro=1 run."""
    base = get_smoke("xmc-bert-3m-sparse", head_labels=400)
    base = dataclasses.replace(base, head_prune_every=2, head_fan_in=8)
    opt = kahan_adamw()
    from repro.data import DataCursor, xmc_batches

    def run(grad_accum, steps=3):
        cfg = dataclasses.replace(base, grad_accum=grad_accum)
        state = St.init_train_state(jax.random.PRNGKey(0), cfg, opt,
                                    impl="xla")
        it = xmc_batches(cfg.vocab, cfg.head_labels, 4, 16,
                         cfg.max_labels_per_example,
                         DataCursor(seed=1234, step=0), 0, 1)
        moved = []
        for _ in range(steps):
            b = next(it)
            idx0 = np.asarray(state.head.indices)
            state, _ = St.train_step(
                cfg, opt, state,
                {"tokens": jnp.asarray(b["tokens"]),
                 "targets": jnp.asarray(b["targets"])},
                jnp.float32(0.05), jnp.float32(2e-5), impl="xla")
            moved.append(not np.array_equal(idx0,
                                            np.asarray(state.head.indices)))
        return moved

    moved2 = run(grad_accum=2)
    # cadence: steps 0 and 1 never prune (controller's step>0 gate; the
    # prune for state.step==2 lands in the step-2 update), step 2 does
    assert moved2[2], "prune/regrow never fired under grad accumulation"
    assert not moved2[0] and not moved2[1]
    assert run(grad_accum=1) == moved2   # same cadence as unaccumulated


# ---------------------------------------------------------------------------
# monitor
# ---------------------------------------------------------------------------


def _tele(sat=0.0, z=0.0, lse=0.0, xg=0.0, cmax=0.0):
    t = [0.0] * NT.N_SLOTS
    t[NT.SLOTS["sat"]] = sat
    t[NT.SLOTS["z_nonfinite"]] = z
    t[NT.SLOTS["lse_nonfinite"]] = lse
    t[NT.SLOTS["xg_nonfinite"]] = xg
    t[NT.SLOTS["comp_max"]] = cmax
    return t


def test_monitor_hard_trips():
    m = NumericsMonitor(update_elems=1000)
    assert m.observe(0, float("nan"), _tele()).kind == "nonfinite_loss"
    assert m.observe(1, 1.0, _tele(cmax=float("inf"))).kind \
        == "nonfinite_telemetry"
    assert m.observe(2, 1.0, _tele(z=3)).kind == "nonfinite_z"
    assert m.observe(3, 1.0, _tele(lse=1)).kind == "nonfinite_lse"
    assert m.observe(4, 1.0, _tele(xg=2)).kind == "nonfinite_xg"
    trip = m.observe(5, 1.0, _tele(sat=51))     # 51/1000 > 0.05
    assert trip.kind == "saturation" and trip.value == pytest.approx(0.051)
    assert m.observe(6, 1.0, _tele(sat=50)) is None     # exactly at: no trip
    assert m.observe(7, 1.0, None) is None              # no telemetry → loss-only


def test_monitor_loss_spike_and_reset():
    m = NumericsMonitor(update_elems=10, warmup=4, z_thresh=8.0)
    for i in range(8):
        assert m.observe(i, 1.0 + 0.01 * (i % 2), _tele()) is None
    trip = m.observe(8, 100.0, _tele())
    assert trip is not None and trip.kind == "loss_spike"
    # the spike did NOT update the EWMA: a repeat still trips
    assert m.observe(9, 100.0, _tele()).kind == "loss_spike"
    m.reset()       # post-rollback: re-warms, big first loss is fine
    assert m.observe(10, 100.0, _tele()) is None


# ---------------------------------------------------------------------------
# escalation ladder
# ---------------------------------------------------------------------------


def _trip(step=3, kind="loss_spike"):
    return {"step": step, "kind": kind, "value": 1.0, "detail": ""}


def test_ladder_escalation_sequence():
    lad = NR.LadderState()
    assert lad.rung_name == "baseline"
    lad = lad.escalate(_trip(), base_dtype="e4m3")
    assert (lad.rung_name, lad.seed_salt, lad.lr_scale,
            lad.weight_dtype) == ("reseed", 1, 1.0, None)
    lad = lad.escalate(_trip(), base_dtype="e4m3")
    assert (lad.rung_name, lad.seed_salt, lad.lr_scale) \
        == ("lr_backoff", 2, 0.5)
    lad = lad.escalate(_trip(), base_dtype="e4m3")
    assert (lad.rung_name, lad.weight_dtype) \
        == ("escalate_precision", "bf16")
    assert lad.lr_scale == 0.5
    top = lad.escalate(_trip(), base_dtype="e4m3")  # at the top: keep halving
    assert top.rung_name == "escalate_precision"
    assert top.lr_scale == 0.25 and top.seed_salt == 4
    # bf16 base has no storage rung above it: LR halves instead
    lad2 = NR.LadderState(rung=2, seed_salt=2, lr_scale=0.5,
                          trips=[_trip(), _trip()])
    lad2 = lad2.escalate(_trip(), base_dtype="bf16")
    assert lad2.weight_dtype is None and lad2.lr_scale == 0.25


def test_ladder_persistence_and_quarantine(tmp_path):
    d = str(tmp_path)
    assert NR.load_ladder(d).rung == 0          # absent file → baseline
    lad = NR.LadderState().escalate(_trip(), base_dtype="e4m3")
    NR.save_ladder(d, lad)
    back = NR.load_ladder(d)
    assert back.as_dict() == lad.as_dict()
    # quarantine demotes committed steps ≥ horizon, idempotently
    from repro.checkpoint import save_checkpoint
    for s in (2, 4, 6):
        save_checkpoint(d, s, {"w": jnp.arange(3.0)})
    assert len(committed_paths(d)) == 3
    demoted = NR.quarantine(d, 4)
    assert [os.path.basename(p) for p in demoted] \
        == ["ckpt_00000004", "ckpt_00000006"]
    assert [os.path.basename(p) for p in committed_paths(d)] \
        == ["ckpt_00000002"]
    assert NR.quarantine(d, 4) == []            # idempotent
    for p in demoted:
        with open(os.path.join(p, "CORRUPT")) as f:
            assert "quarantine" in f.read()


# ---------------------------------------------------------------------------
# end-to-end: detect → quarantine → roll back → escalate → converge
# ---------------------------------------------------------------------------


def _guard_kw(ckpt_dir, **over):
    kw = dict(steps=8, global_batch=4, seq=16, ckpt_dir=ckpt_dir,
              ckpt_every=2, impl="xla", log_every=100,
              monitor_kw={"warmup": 4})
    kw.update(over)
    return kw


def test_run_guarded_nan_recovery(tmp_path):
    cfg = get_smoke("xmc-bert-3m", head_labels=600)
    d = str(tmp_path / "ck")
    state, losses, recoveries = run_guarded(
        cfg, inject=FI.at_step(3, FI.nan_poison_head), **_guard_kw(d))
    assert recoveries == 1
    lad = NR.load_ladder(d)
    assert lad.rung_name == "reseed" and lad.seed_salt == 1
    assert lad.trips[0]["kind"] in ("nonfinite_loss", "nonfinite_z")
    assert lad.trips[0]["step"] == 3
    assert all(math.isfinite(l) for l in losses)
    # the suspect checkpoint was demoted and the recovery re-trained past
    # it to completion (the demoted dir itself is re-saved clean / GC'd by
    # the keep=3 retention — quarantine mechanics are pinned separately in
    # test_ladder_persistence_and_quarantine)
    assert int(os.path.basename(latest_committed(d))[len("ckpt_"):]) == 8
    ok = subprocess.run(
        [sys.executable, "-m", "repro.checkpoint", "verify", "-q", d],
        env=FI.subprocess_env(os.path.join(REPO, "src")),
        capture_output=True, text=True, timeout=300)
    assert ok.returncode == 0, ok.stdout + ok.stderr


def test_run_guarded_saturation_recovery(tmp_path):
    """The silent failure mode: loss stays finite, only the in-kernel
    saturation counter sees the cliff."""
    cfg = get_smoke("xmc-bert-3m", head_labels=600)
    d = str(tmp_path / "ck")
    state, losses, recoveries = run_guarded(
        cfg, inject=FI.at_step(2, FI.saturate_head), **_guard_kw(d))
    assert recoveries == 1
    lad = NR.load_ladder(d)
    assert lad.trips[0]["kind"] == "saturation"
    assert all(math.isfinite(l) for l in losses)


def test_guarded_resume_applies_escalated_dtype(tmp_path):
    """A persisted escalate_precision ladder re-types the restored head:
    the e4m3 checkpoint upcasts into a bf16 head and training proceeds."""
    cfg = get_smoke("xmc-bert-3m", head_labels=600)
    d = str(tmp_path / "ck")
    train(cfg, guard=True, **_guard_kw(d, steps=4))
    lad = NR.LadderState()
    for _ in range(3):
        lad = lad.escalate(_trip(), base_dtype="e4m3")
    assert lad.weight_dtype == "bf16"
    NR.save_ladder(d, lad)
    state, losses = train(cfg, guard=True, **_guard_kw(d, steps=6))
    assert state.head.w.dtype == jnp.bfloat16
    assert len(losses) == 2 and all(math.isfinite(l) for l in losses)


# ---------------------------------------------------------------------------
# SIGKILL mid-recovery: bit-identical replay
# ---------------------------------------------------------------------------


def _leaf_crcs(ckpt_path):
    with open(os.path.join(ckpt_path, "manifest.json")) as f:
        man = json.load(f)
    return {e["name"]: e["checksum"] for e in man["leaves"]}


@pytest.mark.slow
def test_sigkill_mid_recovery_resumes_bit_identically(tmp_path):
    """Kill the guarded run AFTER the trip, mid-recovery; relaunching must
    replay the persisted ladder (same salt, no re-injection) and land on a
    final checkpoint bit-identical to an unkilled reference run."""
    argv_common = ["--arch", "xmc-bert-3m", "--smoke", "--steps", "8",
                   "--global-batch", "4", "--seq", "16", "--head-labels",
                   "600", "--ckpt-every", "2", "--guard", "--guard-warmup",
                   "4", "--inject-nan-step", "3"]
    env = FI.subprocess_env(os.path.join(REPO, "src"))
    env.setdefault("JAX_PLATFORMS", "cpu")

    def run_to_end(d):
        out = subprocess.run(
            FI.train_argv(*argv_common, "--ckpt-dir", d), env=env,
            capture_output=True, text=True, timeout=540)
        assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
        return out.stdout

    ref_dir = str(tmp_path / "ref")
    out = run_to_end(ref_dir)
    assert "NUMERICS TRIP" in out

    kill_dir = str(tmp_path / "kill")
    # step 6 only exists in the SECOND incarnation (the first trips at 3),
    # so the SIGKILL lands mid-recovery with the ladder already persisted
    res = FI.run_and_kill(
        FI.train_argv(*argv_common, "--ckpt-dir", kill_dir),
        hb_file=os.path.join(kill_dir, "hb", "host_0000.hb"),
        kill_step=6, env=env, timeout_s=540)
    assert res.killed and "NUMERICS TRIP" in res.stdout
    assert NR.load_ladder(kill_dir).seed_salt == 1   # persisted pre-kill
    run_to_end(kill_dir)                             # resume mid-recovery

    for d in (ref_dir, kill_dir):
        assert NR.load_ladder(d).as_dict() == \
            NR.load_ladder(ref_dir).as_dict()
    a, b = latest_committed(ref_dir), latest_committed(kill_dir)
    assert os.path.basename(a) == os.path.basename(b) == "ckpt_00000008"
    assert _leaf_crcs(a) == _leaf_crcs(b)    # bit-identical final state


# ---------------------------------------------------------------------------
# satellite 2: checkpoint verify CLI
# ---------------------------------------------------------------------------


def _verify_cli(*args):
    env = FI.subprocess_env(os.path.join(REPO, "src"))
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run(
        [sys.executable, "-m", "repro.checkpoint", "verify", *args],
        env=env, capture_output=True, text=True, timeout=300)


@pytest.mark.slow
def test_checkpoint_verify_cli(tmp_path):
    from repro.checkpoint import save_checkpoint
    d = str(tmp_path)
    tree = {"w": jnp.arange(8.0), "c": jnp.zeros((4,), jnp.bfloat16)}
    save_checkpoint(d, 2, tree)
    p4 = save_checkpoint(d, 4, tree)
    out = _verify_cli(d)
    assert out.returncode == 0 and "2/2 intact" in out.stdout
    FI.bit_flip_leaf(p4, leaf_index=0)
    out = _verify_cli(d)
    assert out.returncode == 1
    assert "ckpt_00000004: CORRUPT" in out.stdout
    assert "checksum mismatch" in out.stdout
    assert "ckpt_00000002: ok" in out.stdout
    out = _verify_cli(p4)                  # single-checkpoint form
    assert out.returncode == 1 and "CORRUPT" in out.stdout
    out = _verify_cli(str(tmp_path / "nope"))
    assert out.returncode == 2


# ---------------------------------------------------------------------------
# satellite 3: non-finite propagation (losses + top-k)
# ---------------------------------------------------------------------------


def test_ce_all_padded_rows_finite_and_zero_grad():
    """Every target padded (-1): CE must yield a finite zero loss and an
    exactly-zero logit gradient — not NaN from a 0/0 softmax row."""
    z = jax.random.normal(jax.random.PRNGKey(0), (4, 16)).astype(jnp.bfloat16)
    ids = jnp.full((4,), -1, jnp.int32)
    assert float(L.full_ce_loss(z, ids)) == 0.0
    m, s = L.lse_init(4)
    m, s = L.lse_update(m, s, z)
    lse = L.lse_finalize(m, s)
    g, loss_c = L.chunk_loss_skip_grad(
        "softmax_ce", z, ids, jnp.int32(0), 16, 16, lse, jnp.float32(1.0))
    assert np.isfinite(np.asarray(lse)).all()
    assert (np.asarray(g, np.float32) == 0.0).all()
    assert math.isfinite(float(loss_c))


def test_nonfinite_logits_propagate_not_masked():
    """NaN logits must surface in the loss-skip gradient (the monitor's
    job is to catch them — the math must not silently launder them)."""
    z = jnp.ones((2, 8), jnp.float32).at[0, 3].set(jnp.nan)
    ids = jnp.array([[3, -1], [1, -1]], jnp.int32)
    g, loss_c = L.chunk_loss_skip_grad(
        "bce", z, ids, jnp.int32(0), 8, 8, None, jnp.float32(1.0))
    assert np.isnan(np.asarray(g, np.float32)[0, 3])
    assert not np.isfinite(float(loss_c))
    assert np.isfinite(np.asarray(g, np.float32)[1]).all()  # row-local


def test_fused_topk_inf_bit_parity():
    """±Inf features: the streaming top-k kernel keeps exact value AND id
    parity with the scan oracle (Inf ordering is well-defined; ties among
    equal +Inf logits still break to the lowest label id)."""
    cfg = H.ELMOHeadConfig(num_labels=100, d_model=16, num_chunks=2,
                           weight_dtype="bf16", use_sr=False,
                           impl="grid_interpret")
    state = H.init_head(jax.random.PRNGKey(1), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(2), (4, 16)) * 0.5
         ).astype(jnp.bfloat16)
    x = x.at[1, 3].set(jnp.inf).at[3, 2].set(-jnp.inf)
    seeds = serving._eval_seeds(cfg)
    base = serving._chunk_base(cfg)
    for k in (1, 5, 64):
        vk, ik = ops.fused_topk(x, state.w, seeds, base, k=k,
                                num_labels=cfg.num_labels,
                                quantize_x=cfg.qx, impl="interpret")
        vo, io = ref.fused_topk_ref(x, state.w, seeds, base, k=k,
                                    num_labels=cfg.num_labels,
                                    quantize_x=cfg.qx)
        assert _bits_eq(vk, vo) and _bits_eq(ik, io)
        assert np.asarray(vk)[1, 0] == np.inf    # poison actually surfaced


def test_fused_topk_nan_row_is_isolated():
    """A NaN feature row poisons ONLY its own top-k row: every clean row
    keeps bit parity across kernel and oracle.  (NaN ordering within the
    poisoned row is impl-defined — detection is the guard's job, not the
    kernel's.)"""
    cfg = H.ELMOHeadConfig(num_labels=100, d_model=16, num_chunks=2,
                           weight_dtype="bf16", use_sr=False,
                           impl="grid_interpret")
    state = H.init_head(jax.random.PRNGKey(1), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(2), (4, 16)) * 0.5
         ).astype(jnp.bfloat16)
    x = x.at[2, 5].set(jnp.nan)
    seeds = serving._eval_seeds(cfg)
    base = serving._chunk_base(cfg)
    vk, ik = ops.fused_topk(x, state.w, seeds, base, k=5,
                            num_labels=cfg.num_labels, quantize_x=cfg.qx,
                            impl="interpret")
    vo, io = ref.fused_topk_ref(x, state.w, seeds, base, k=5,
                                num_labels=cfg.num_labels,
                                quantize_x=cfg.qx)
    clean = [0, 1, 3]
    assert _bits_eq(np.asarray(vk)[clean], np.asarray(vo)[clean])
    assert _bits_eq(np.asarray(ik)[clean], np.asarray(io)[clean])
    assert not np.isfinite(np.asarray(vk)[2]).all()   # poison surfaces


# ---------------------------------------------------------------------------
# forced multi-device suite
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_multidevice_numerics_suite(multidevice_runner):
    out = multidevice_runner("_multidevice_numerics_checks.py",
                             device_count=4)
    assert "ALL NUMERICS GUARD CHECKS PASSED" in out
