"""Shared fixtures + checks for 2-stage shortlisted serving (ISSUE 7).

Two consumers:

* ``tests/test_shortlist.py`` — the differential harness proper.
* ``python tests/_shortlist_checks.py --write`` — regenerates the
  committed golden artifacts under ``tests/goldens/shortlist_4096{,/}``
  (the saved index directory plus a JSON pinning recall@{1,5,10} and the
  cluster-size histogram).  The golden head is NOT stored: it is fully
  reproducible from ``shortlist.synthetic_clustered_state`` (pure seeded
  numpy), so only the derived index + measured numbers are committed.

The golden geometry (L=4096, D=64, e4m3, 128 latent groups, noise 0.2;
index C=64/beam=28) was swept offline: an unstructured i.i.d. head tops
out near recall@10 ≈ 0.8 at this beam fraction, while the structured
head — the regime real trained XMC heads live in — clears 0.95 with
margin (measured 0.984 at generation time).
"""
from __future__ import annotations

import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import elmo_head as H
from repro.head import serving
from repro.head import shortlist as SL
from repro.kernels import ops, ref

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "goldens", "shortlist_4096")
GOLDEN_JSON = GOLDEN_DIR + ".json"

# one source of truth for the golden recipe — tests re-derive the head
# and queries from these, and compare against the committed index
GOLDEN = dict(num_labels=4096, d_model=64, num_chunks=8,
              weight_dtype="e4m3", groups=128, noise=0.2, head_seed=7,
              query_seed=11, batch=64, n_clusters=64, beam=28,
              iters=8, index_seed=0)
RECALL_FLOOR = 0.95  # acceptance: recall@10 on the golden fixture


def golden_cfg(**over) -> H.ELMOHeadConfig:
    kw = dict(num_labels=GOLDEN["num_labels"], d_model=GOLDEN["d_model"],
              num_chunks=GOLDEN["num_chunks"],
              weight_dtype=GOLDEN["weight_dtype"], use_sr=False,
              shortlist="on")
    kw.update(over)
    return H.ELMOHeadConfig(**kw)


def golden_state(cfg: H.ELMOHeadConfig):
    return SL.synthetic_clustered_state(cfg, groups=GOLDEN["groups"],
                                        noise=GOLDEN["noise"],
                                        seed=GOLDEN["head_seed"])


def golden_queries(cfg: H.ELMOHeadConfig, batch: int | None = None):
    b = GOLDEN["batch"] if batch is None else batch
    return jax.random.normal(jax.random.PRNGKey(GOLDEN["query_seed"]),
                             (b, cfg.d_model)).astype(jnp.bfloat16)


def build_golden_index(cfg: H.ELMOHeadConfig, state) -> SL.ShortlistIndex:
    return SL.build_shortlist_index(cfg, state,
                                    n_clusters=GOLDEN["n_clusters"],
                                    beam=GOLDEN["beam"],
                                    iters=GOLDEN["iters"],
                                    seed=GOLDEN["index_seed"])


# ---------------------------------------------------------------------------
# shared differential checks
# ---------------------------------------------------------------------------


def restricted_pair(cfg, state, x, k, assign, beam, *, impl,
                    block_l=None):
    """(kernel-or-impl result, restricted-oracle result) for one case."""
    seeds = serving._eval_seeds(cfg)
    base = serving._chunk_base(cfg)
    got = ops.fused_topk(x, state.w, seeds, base, k=k,
                         num_labels=cfg.num_labels, quantize_x=cfg.qx,
                         impl=impl, block_l=block_l,
                         assign=assign, beam=beam)
    want = ref.fused_topk_ref(x, state.w, seeds, base, k=k,
                              num_labels=cfg.num_labels,
                              quantize_x=cfg.qx,
                              assign=assign, beam=beam)
    return got, want


def assert_bit_equal(got, want, msg=""):
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]),
                                  err_msg=f"values {msg}")
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]),
                                  err_msg=f"ids {msg}")


def check_sentinels(vals, ids, num_labels, admitted_per_row=None):
    """Padded columns never surface; overflow slots are exactly the
    (NEG_INF, id 0) sentinel pairs from the carry init."""
    from repro.core.losses import NEG_INF
    v, i = np.asarray(vals), np.asarray(ids)
    assert (i < max(num_labels, 1)).all(), "padded/ghost label id surfaced"
    assert (i >= 0).all()
    sent = v <= NEG_INF / 2
    assert (i[sent] == 0).all(), "sentinel slot carries a non-zero id"
    if admitted_per_row is not None:
        k = v.shape[1]
        for r, adm in enumerate(admitted_per_row):
            n_real = (~sent[r]).sum()
            assert n_real <= min(adm, k), (r, n_real, adm)


# ---------------------------------------------------------------------------
# golden regeneration
# ---------------------------------------------------------------------------


def _write_golden() -> None:
    cfg = golden_cfg()
    state = golden_state(cfg)
    index = build_golden_index(cfg, state)
    x = golden_queries(cfg)
    recall = SL.shortlist_recall_at_k(cfg, state, index, x,
                                      ks=(1, 5, 10), impl="xla")
    sizes = SL.cluster_sizes(index)
    assert recall[10] >= RECALL_FLOOR, recall
    SL.save_shortlist_index(GOLDEN_DIR, index,
                            extra={"recipe": GOLDEN})
    blob = {"recipe": GOLDEN,
            "w_checksum": index.w_checksum,
            "recall": {str(k): float(v) for k, v in recall.items()},
            "cluster_sizes": [int(s) for s in sizes]}
    with open(GOLDEN_JSON, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {GOLDEN_DIR} + {GOLDEN_JSON}  recall={recall}")


if __name__ == "__main__":
    if "--write" in sys.argv:
        _write_golden()
    else:
        print(__doc__)
