"""Fault-tolerance suite (ISSUE 6): crash-safe checksummed checkpointing,
bit-identical kill/resume, elastic supervision, and the injection harness.

The contract under test (DESIGN.md §10):

* a checkpoint with a torn or bit-flipped leaf (or torn manifest) is
  detected via per-leaf crc32, demoted to uncommitted, and restore falls
  back to the previous committed step;
* a background ``save_async`` failure surfaces as ``CheckpointError`` on
  the next ``save_async``/``wait`` (never silently lost);
* a training run SIGKILLed at an arbitrary step resumes from the last
  committed checkpoint and reproduces the **bit-identical** W / Kahan /
  loss trajectory of an uninterrupted run — including SR / DropConnect
  seed replay across the resume boundary and the exact data-batch order;
* a stale peer heartbeat raises ``HostFailure`` out of the train loop and
  ``run_elastic`` re-plans the fleet and continues from the checkpoint.
"""
import json
import os
import warnings
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import head as RH
from repro.checkpoint import (CheckpointError, CheckpointManager,
                              latest_committed, restore_checkpoint,
                              save_checkpoint, verify_checkpoint)
from repro.checkpoint import ckpt as ckpt_mod
from repro.configs import get_smoke
from repro.data import DataCursor, lm_batches, xmc_batches
from repro.fault import (ElasticController, Heartbeat, HostFailure, retry)
from repro.fault import inject
from repro.kernels import prng_utils as PR
from repro.launch import train as train_mod

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_GOLDEN = os.path.join(REPO, "tests", "goldens",
                       "train_smollm_360m_smoke.json")


def _tree():
    return {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "fp8": (jnp.ones((64,), jnp.float32) * 0.37).astype(
            jnp.float8_e4m3fn),
        "bf16": (jnp.ones((4, 4)) * 1.5).astype(jnp.bfloat16),
        "nested": {"step": jnp.int32(7)},
    }


# ---------------------------------------------------------------------------
# corruption safety: checksums, demotion, fallback
# ---------------------------------------------------------------------------


def test_bit_flip_detected_and_falls_back(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 1, tree, extra={"mark": 1})
    p2 = save_checkpoint(str(tmp_path), 2, tree, extra={"mark": 2})
    ok, _ = verify_checkpoint(p2)
    assert ok
    inject.bit_flip_leaf(p2, leaf_index=1)
    ok, reason = verify_checkpoint(p2)
    assert not ok and "checksum mismatch" in reason
    restored, step, extra = restore_checkpoint(str(tmp_path), tree)
    assert step == 1 and extra["mark"] == 1
    # the corrupt checkpoint is demoted: no longer committed, reason kept
    assert latest_committed(str(tmp_path)).endswith("ckpt_00000001")
    assert not os.path.exists(os.path.join(p2, "COMMITTED"))
    assert os.path.exists(os.path.join(p2, "CORRUPT"))


def test_torn_leaf_falls_back(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 3, tree, extra={"mark": 3})
    p = save_checkpoint(str(tmp_path), 4, tree)
    inject.truncate_leaf(p, leaf_index=0, keep_fraction=0.4)
    _, step, extra = restore_checkpoint(str(tmp_path), tree)
    assert step == 3 and extra["mark"] == 3


def test_torn_manifest_falls_back(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 1, tree)
    p = save_checkpoint(str(tmp_path), 2, tree)
    inject.truncate_manifest(p)
    _, step, _ = restore_checkpoint(str(tmp_path), tree)
    assert step == 1


def test_no_intact_checkpoint_raises(tmp_path):
    tree = _tree()
    p = save_checkpoint(str(tmp_path), 1, tree)
    inject.bit_flip_leaf(p, leaf_index=0)
    with pytest.raises(CheckpointError, match="no intact committed"):
        restore_checkpoint(str(tmp_path), tree)


def test_roundtrip_bit_exact_low_precision(tmp_path):
    """FP8 / BF16 leaves survive the round trip as raw bits (the Kahan
    compensation buffer must come back exactly, App. D)."""
    tree = _tree()
    save_checkpoint(str(tmp_path), 5, tree)
    restored, step, _ = restore_checkpoint(str(tmp_path), tree)
    assert step == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


def test_tmp_partials_garbage_collected(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 1, tree)
    partial = tmp_path / "ckpt_00000002.tmp"
    partial.mkdir()
    (partial / "leaf_00000.npy").write_bytes(b"torn")
    assert latest_committed(str(tmp_path)).endswith("ckpt_00000001")
    assert not partial.exists()


# ---------------------------------------------------------------------------
# async manager: background-failure surfacing
# ---------------------------------------------------------------------------


def test_save_async_error_propagates(tmp_path, monkeypatch):
    """A failed background disk write must raise from the next
    ``save_async``/``wait`` — and must not destroy the previous committed
    checkpoint (regression: the daemon thread used to swallow it)."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = _tree()
    mgr.save_async(1, tree, extra={"s": 1})
    mgr.wait()

    real_save = ckpt_mod.np.save
    boom = {"armed": True}

    def flaky_save(path, arr):
        if boom["armed"]:
            raise OSError("disk full (injected)")
        return real_save(path, arr)

    monkeypatch.setattr(ckpt_mod.np, "save", flaky_save)
    mgr.save_async(2, tree, extra={"s": 2})
    with pytest.raises(CheckpointError, match="background checkpoint"):
        mgr.wait()
    # error is cleared once surfaced; the store still serves step 1
    assert latest_committed(str(tmp_path)).endswith("ckpt_00000001")
    boom["armed"] = False
    mgr.save_async(3, tree, extra={"s": 3})
    mgr.wait()
    _, step, extra = restore_checkpoint(str(tmp_path), tree)
    assert step == 3 and extra["s"] == 3


def test_save_async_error_surfaces_on_next_save(tmp_path, monkeypatch):
    mgr = CheckpointManager(str(tmp_path))
    monkeypatch.setattr(ckpt_mod.np, "save",
                        lambda *a, **k: (_ for _ in ()).throw(
                            OSError("injected")))
    mgr.save_async(1, _tree())
    mgr._thread.join()       # let the failure land without calling wait()
    with pytest.raises(CheckpointError):
        mgr.save_async(2, _tree())


# ---------------------------------------------------------------------------
# fault runtime satellites: fd leaks, retry validation, controller edges
# ---------------------------------------------------------------------------


def test_heartbeat_and_restore_close_files(tmp_path):
    """Regression: ``json.load(open(...))`` leaked fds in
    ``alive_hosts``/``restore_checkpoint``; unclosed files now surface as
    ResourceWarning-as-error."""
    hb = Heartbeat(str(tmp_path / "hb"), 0, timeout_s=10)
    hb.beat(step=1)
    save_checkpoint(str(tmp_path / "ck"), 1, _tree())
    with warnings.catch_warnings():
        warnings.simplefilter("error", ResourceWarning)
        assert hb.alive_hosts(1, now=None) == [0]
        restore_checkpoint(str(tmp_path / "ck"), _tree())


def test_retry_validates_attempts():
    with pytest.raises(ValueError, match="attempts >= 1"):
        retry(lambda: "ok", attempts=0)
    with pytest.raises(ValueError):
        retry(lambda: "ok", attempts=-2)
    assert retry(lambda: "ok", attempts=1) == "ok"
    with pytest.raises(ValueError, match="jitter"):
        retry(lambda: "ok", jitter="equal")


class _AlwaysFails:
    def __init__(self, exc=RuntimeError):
        self.calls = 0
        self.exc = exc

    def __call__(self):
        self.calls += 1
        raise self.exc("transient")


def test_retry_default_delays_bit_compatible():
    """The default call — no jitter, no cap — must sleep the historical
    pure-exponential sequence base·2^i exactly."""
    slept = []
    fn = _AlwaysFails()
    with pytest.raises(RuntimeError):
        retry(fn, attempts=4, base_delay_s=0.5, sleep=slept.append)
    assert fn.calls == 4
    assert slept == [0.5, 1.0, 2.0]      # no sleep after the last attempt


def test_retry_max_delay_caps_exponential():
    slept = []
    with pytest.raises(RuntimeError):
        retry(_AlwaysFails(), attempts=6, base_delay_s=1.0,
              max_delay_s=3.0, sleep=slept.append)
    assert slept == [1.0, 2.0, 3.0, 3.0, 3.0]


def test_retry_full_jitter_seeded_sequence():
    """jitter="full" draws each delay uniform from [0, capped delay] —
    deterministic for a seeded rng, and exactly reproducible from the
    same seed (the serving runtime's bit-identical-soak requirement)."""
    import random

    def run():
        slept = []
        with pytest.raises(RuntimeError):
            retry(_AlwaysFails(), attempts=5, base_delay_s=1.0,
                  max_delay_s=4.0, jitter="full", rng=random.Random(7),
                  sleep=slept.append)
        return slept

    slept = run()
    assert slept == run()                # seeded → reproducible
    caps = [1.0, 2.0, 4.0, 4.0]          # capped exponential envelope
    assert len(slept) == 4
    assert all(0.0 <= d <= c for d, c in zip(slept, caps))
    assert len(set(slept)) > 1           # actually jittered, not constant
    # and the draws are exactly the rng's: replay the same stream
    ref = random.Random(7)
    assert slept == [ref.uniform(0.0, c) for c in caps]


def test_retry_succeeds_mid_backoff_policy():
    """A success after transient failures returns the value; jitter and
    cap only shape the sleeps in between."""
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    import random
    slept = []
    assert retry(flaky, attempts=5, base_delay_s=0.25, jitter="full",
                 max_delay_s=0.4, rng=random.Random(0),
                 sleep=slept.append) == "ok"
    assert len(slept) == 2 and all(0.0 <= d <= 0.4 for d in slept)


def test_straggler_median_even_fleet_regression():
    """True median on even fleet sizes: with EWMAs [1.0, 1.0, 1.4, 2.0]
    the old upper-middle 'median' (1.4) hid the 2.0 straggler behind a
    2.1 cut line; the true median 1.2 flags it."""
    from repro.fault import StragglerMonitor

    mon = StragglerMonitor(threshold=1.5, alpha=1.0)
    for host, t in enumerate([1.0, 1.0, 1.4, 2.0]):
        mon.record(host, t)
    assert mon.stragglers() == [3]
    # odd count unchanged: median is the middle element
    mon_odd = StragglerMonitor(threshold=1.5, alpha=1.0)
    for host, t in enumerate([1.0, 1.0, 2.0]):
        mon_odd.record(host, t)
    assert mon_odd.stragglers() == [2]
    # a healthy even fleet stays unflagged
    mon_ok = StragglerMonitor(threshold=1.5, alpha=1.0)
    for host, t in enumerate([1.0, 1.1, 1.0, 1.2]):
        mon_ok.record(host, t)
    assert mon_ok.stragglers() == []


def test_heartbeat_fsyncs_before_replace(tmp_path, monkeypatch):
    """``Heartbeat.beat`` follows the §10 commit protocol: the record is
    fsynced BEFORE the rename publishes it, so a crash can never leave
    an empty-but-renamed heartbeat (which would read as a dead host)."""
    events = []
    real_fsync, real_replace = os.fsync, os.replace
    monkeypatch.setattr(os, "fsync",
                        lambda fd: (events.append("fsync"),
                                    real_fsync(fd))[1])
    monkeypatch.setattr(os, "replace",
                        lambda a, b: (events.append("replace"),
                                      real_replace(a, b))[1])
    hb = Heartbeat(str(tmp_path / "hb"), 0, timeout_s=10)
    hb.beat(step=3, now=123.0)
    assert events == ["fsync", "replace"]
    assert hb.records(1)[0] == {"step": 3, "t": 123.0}


def test_torn_heartbeat_reads_as_absent(tmp_path):
    """An empty heartbeat file (the artifact a pre-fsync binary could
    publish) must read as 'never beaten' — absent from records and never
    alive — not as a host dead since t=0."""
    hb_dir = str(tmp_path / "hb")
    hb = Heartbeat(hb_dir, 0, timeout_s=10)
    hb.beat(step=5)
    inject.torn_heartbeat(hb_dir, host=1)
    recs = hb.records(2)
    assert 0 in recs and 1 not in recs
    assert hb.alive_hosts(2) == [0]


def test_elastic_controller_edge_cases():
    # model group incomplete: fewer survivors than one model group needs
    ctl = ElasticController(n_hosts=8, hosts_per_data_shard=4, min_hosts=1)
    plan = ctl.plan_after_failure(alive=[0, 1, 5])
    assert plan["action"] == "abort" and "model group" in plan["reason"]
    # exactly min_hosts alive → still restarts
    ctl = ElasticController(n_hosts=8, hosts_per_data_shard=1, min_hosts=3)
    plan = ctl.plan_after_failure(alive=[0, 4, 7])
    assert plan["action"] == "restart"
    assert plan["new_data_parallelism"] == 3
    # below min_hosts → abort
    assert ctl.plan_after_failure(alive=[0, 4])["action"] == "abort"
    # non-divisible survivor count truncates to whole model groups
    ctl = ElasticController(n_hosts=8, hosts_per_data_shard=2, min_hosts=1)
    plan = ctl.plan_after_failure(alive=[0, 1, 2, 3, 6])
    assert plan["action"] == "restart"
    assert plan["hosts"] == [0, 1, 2, 3]
    assert plan["new_data_parallelism"] == 2


# ---------------------------------------------------------------------------
# data-pipeline cursor round-trip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("maker", [
    lambda c: lm_batches(100, 8, 16, c),
    lambda c: xmc_batches(100, 10_000, 8, 16, 10, c),
], ids=["lm", "xmc"])
def test_cursor_roundtrip_replays_unconsumed_batch(maker):
    """Saving ``next_cursor`` after consuming batch k and resuming must
    yield batch k+1 — NOT replay batch k (the historical off-by-one: the
    checkpoint stored the consumed batch's own cursor)."""
    it = maker(DataCursor(7, 0))
    ref = [next(it) for _ in range(6)]
    saved = ref[3]["next_cursor"]            # checkpoint after batch 3
    resumed = maker(DataCursor.from_state(saved))
    for want in ref[4:]:
        got = next(resumed)
        np.testing.assert_array_equal(want["tokens"], got["tokens"])
        np.testing.assert_array_equal(want["targets"], got["targets"])
        assert want["cursor"] == got["cursor"]


def test_flaky_batches_retry_preserves_sequence():
    """Transient pipeline errors + retry: the recovered stream is exactly
    the unfailed stream (no skipped or duplicated batch)."""
    ref_it = lm_batches(50, 4, 8, DataCursor(3, 0))
    ref = [next(ref_it) for _ in range(4)]
    flaky = inject.FlakyBatches(lm_batches(50, 4, 8, DataCursor(3, 0)),
                                fail_fetches=[1, 2, 4])
    got = [retry(lambda: next(flaky), attempts=4, base_delay_s=0,
                 sleep=lambda s: None) for _ in range(4)]
    for w, g in zip(ref, got):
        np.testing.assert_array_equal(w["tokens"], g["tokens"])


# ---------------------------------------------------------------------------
# plan checkpoint metadata
# ---------------------------------------------------------------------------


def test_plan_checkpoint_meta():
    cfg = RH.ELMOHeadConfig(num_labels=1000, d_model=32, num_chunks=4,
                            weight_dtype="e4m3", kahan_chunks=4, impl="xla")
    plan = RH.resolve_plan(cfg, batch=16)
    meta = plan.checkpoint_meta()
    assert meta["model_size"] == 1 and meta["lc"] == plan.lc
    assert "w_spec" in meta and "backend" in meta
    assert "checkpoint" in plan.explain()
    sharded = RH.resolve_plan(cfg, batch=16, model_size=4,
                              model_axis="model")
    assert sharded.checkpoint_meta()["model_size"] == 4
    assert "model" in sharded.checkpoint_meta()["w_spec"]


# ---------------------------------------------------------------------------
# bit-identical head resume: SR + Kahan + DropConnect across the boundary
# ---------------------------------------------------------------------------


def _head_setup():
    cfg = RH.ELMOHeadConfig(num_labels=600, d_model=32, num_chunks=2,
                            weight_dtype="e4m3", kahan_chunks=2,
                            use_sr=True, drop_rate=0.25, loss="bce",
                            impl="xla")
    B, P = 8, 6
    state = RH.init_head(jax.random.PRNGKey(0), cfg)
    head = RH.get_head(cfg, batch=B, target_slots=P, ctx=None)

    def batch_for(s):
        rng = np.random.default_rng(1000 + s)
        x = jnp.asarray(rng.standard_normal((B, 32), np.float32) * 0.5,
                        jnp.bfloat16)
        tgt = jnp.asarray(rng.integers(0, 600, (B, P)), jnp.int32)
        return x, tgt

    def run(state, lo, hi):
        losses = []
        for s in range(lo, hi):
            x, tgt = batch_for(s)
            hp = RH.HeadHparams(jnp.float32(0.05), jnp.float32(1e-4),
                                PR.mix32(jnp.uint32(s)))
            state, _, m = head.train_step(state, x, tgt, hp)
            losses.append(float(m["loss"]))
        return state, losses

    return cfg, state, run


def test_head_resume_bit_identical_sr_kahan(tmp_path):
    """FP8 W + BF16 Kahan + SR + DropConnect: kill after step 3, restore,
    continue — W, comp and losses bit-identical to the uninterrupted run
    (the step-keyed seeds replay the same SR/DropConnect draws)."""
    cfg, state0, run = _head_setup()
    full_state, full_losses = run(state0, 0, 7)

    part_state, part_losses = run(state0, 0, 3)
    save_checkpoint(str(tmp_path), 3, part_state._asdict())
    del part_state                                  # "crash"

    template = RH.init_head(jax.random.PRNGKey(9), cfg)   # fresh process
    restored_d, step, _ = restore_checkpoint(str(tmp_path),
                                             template._asdict())
    assert step == 3
    resumed, resumed_losses = run(RH.HeadState(**restored_d), 3, 7)

    assert RH.state_bits_equal(full_state, resumed)
    assert part_losses + resumed_losses == full_losses


def test_head_resume_detects_corruption_then_uses_older_step(tmp_path):
    """Corrupt the newest head checkpoint: restore falls back one step and
    the continued trajectory still matches the uninterrupted run from that
    older step."""
    cfg, state0, run = _head_setup()
    full_state, _ = run(state0, 0, 7)

    s2, _ = run(state0, 0, 2)
    save_checkpoint(str(tmp_path), 2, s2._asdict())
    s4, _ = run(s2, 2, 4)
    p4 = save_checkpoint(str(tmp_path), 4, s4._asdict())
    inject.bit_flip_leaf(p4, leaf_index=0)

    template = RH.init_head(jax.random.PRNGKey(9), cfg)
    restored_d, step, _ = restore_checkpoint(str(tmp_path),
                                             template._asdict())
    assert step == 2                      # fell back past the corrupt 4
    resumed, _ = run(RH.HeadState(**restored_d), 2, 7)
    assert RH.state_bits_equal(full_state, resumed)


# ---------------------------------------------------------------------------
# launch.train integration: cursor round-trip, flaky data, supervision
# ---------------------------------------------------------------------------


def _smoke_cfg():
    return get_smoke("smollm-360m", vocab=256)


_KW = dict(global_batch=4, seq=8, impl="xla", log_every=100)


def _manifest_checksums(ckpt_path):
    with open(os.path.join(ckpt_path, "manifest.json")) as f:
        manifest = json.load(f)
    return {e["name"]: e["checksum"] for e in manifest["leaves"]}


@pytest.mark.slow
def test_train_resume_bit_identical(tmp_path):
    """In-process kill/resume through ``launch.train``: the resumed run's
    losses equal the uninterrupted run's exactly, and the final committed
    checkpoints are bit-identical leaf-for-leaf (manifest checksums)."""
    cfg = _smoke_cfg()
    full_dir, part_dir = str(tmp_path / "full"), str(tmp_path / "part")
    _, full = train_mod.train(cfg, steps=6, ckpt_dir=full_dir,
                              ckpt_every=3, **_KW)
    _, part = train_mod.train(cfg, steps=3, ckpt_dir=part_dir,
                              ckpt_every=3, **_KW)
    _, rest = train_mod.train(cfg, steps=6, ckpt_dir=part_dir,
                              ckpt_every=3, **_KW)
    assert part == full[:3]
    assert rest == full[3:]            # exact float equality: same backend
    a = _manifest_checksums(os.path.join(full_dir, "ckpt_00000006"))
    b = _manifest_checksums(os.path.join(part_dir, "ckpt_00000006"))
    assert a == b


@pytest.mark.slow
def test_train_transient_data_errors_absorbed(tmp_path, monkeypatch):
    """Injected transient pipeline errors do not change the trajectory."""
    cfg = _smoke_cfg()
    _, clean = train_mod.train(cfg, steps=3, ckpt_dir="", **_KW)
    real = train_mod.make_batches
    monkeypatch.setattr(
        train_mod, "make_batches",
        lambda *a, **k: inject.FlakyBatches(real(*a, **k),
                                            fail_fetches=[1]))
    _, flaky = train_mod.train(cfg, steps=3, ckpt_dir="", **_KW)
    assert flaky == clean


@pytest.mark.slow
def test_run_elastic_detects_dead_host_and_continues(tmp_path):
    """Supervision path end to end: peers heartbeat in lockstep; host 2
    goes stale at step 4 → ``HostFailure`` → ``ElasticController`` plans a
    2-host fleet → restart restores the committed checkpoint (step 4) and
    finishes the run."""
    cfg = _smoke_cfg()
    ckpt_dir = str(tmp_path / "ck")
    hb_dir = os.path.join(ckpt_dir, "hb")
    failed = {"done": False}

    def on_step(i):
        inject.write_heartbeat(hb_dir, 1, i)
        if not failed["done"]:
            inject.write_heartbeat(hb_dir, 3, i)
            if i < 4:
                inject.write_heartbeat(hb_dir, 2, i)
            else:
                inject.make_stale(hb_dir, 2)
                failed["done"] = True

    controller = ElasticController(n_hosts=4, hosts_per_data_shard=2,
                                   min_hosts=2)
    _, losses, restarts = train_mod.run_elastic(
        cfg, steps=8, global_batch=8, seq=8, ckpt_dir=ckpt_dir,
        n_hosts=4, controller=controller, ckpt_every=2, impl="xla",
        log_every=100, on_step=on_step)
    assert restarts == 1
    assert failed["done"]
    assert len(losses) == 8            # 4 kept from attempt 0 + steps 4..7
    assert all(np.isfinite(losses))


@pytest.mark.slow
def test_run_elastic_aborts_below_min_hosts(tmp_path):
    cfg = _smoke_cfg()
    ckpt_dir = str(tmp_path / "ck")
    hb_dir = os.path.join(ckpt_dir, "hb")

    def on_step(i):
        # every peer immediately stale: the controller cannot rebuild
        for h in (1, 2, 3):
            inject.make_stale(hb_dir, h)

    controller = ElasticController(n_hosts=4, hosts_per_data_shard=1,
                                   min_hosts=3)
    with pytest.raises(HostFailure):
        train_mod.run_elastic(cfg, steps=4, global_batch=8, seq=8,
                              ckpt_dir=ckpt_dir, n_hosts=4,
                              controller=controller, ckpt_every=2,
                              impl="xla", log_every=100, on_step=on_step)


# ---------------------------------------------------------------------------
# the real thing: SIGKILL a training subprocess, resume, compare to the
# 20-step goldens
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sigkill_resume_matches_goldens(tmp_path):
    """A run SIGKILLed at an arbitrary step and restarted reaches step 20
    with the loss trajectory bit-identical to an uninterrupted run and
    within the committed goldens' tolerance — and the final checkpoint is
    leaf-for-leaf bit-identical (manifest crc32s)."""
    with open(_GOLDEN) as f:
        golden = json.load(f)
    r = golden["recipe"]
    env = inject.subprocess_env(os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)

    def argv(ckpt_dir, losses_out):
        return inject.train_argv(
            "--arch", "smollm-360m", "--smoke",
            "--steps", str(r["steps"]),
            "--global-batch", str(r["global_batch"]),
            "--seq", str(r["seq"]),
            "--head-lr", str(r["head_lr"]),
            "--backbone-lr", str(r["backbone_lr"]),
            "--ckpt-dir", ckpt_dir, "--ckpt-every", "2",
            "--losses-out", losses_out)

    full_dir = str(tmp_path / "full")
    kill_dir = str(tmp_path / "kill")
    full_json = str(tmp_path / "full.json")
    resume_json = str(tmp_path / "resume.json")

    # (a) uninterrupted oracle
    res = inject.run_and_kill(argv(full_dir, full_json),
                              hb_file=os.path.join(
                                  full_dir, "hb", "host_0000.hb"),
                              kill_step=10**9, env=env)
    assert not res.killed and res.returncode == 0, \
        res.stdout[-2000:] + res.stderr[-2000:]

    # (b) killed at an "arbitrary" (pinned pseudo-random) step
    kill_step = 5 + zlib.crc32(b"elmo-fault-injection") % 8   # ∈ [5, 12]
    res = inject.run_and_kill(argv(kill_dir, str(tmp_path / "unused.json")),
                              hb_file=os.path.join(
                                  kill_dir, "hb", "host_0000.hb"),
                              kill_step=kill_step, env=env)
    assert res.killed and res.step_seen >= kill_step
    last = latest_committed(kill_dir)
    assert last is not None, "no committed checkpoint survived the kill"

    # (c) restart: resumes from the last committed step, reaches 20
    res = inject.run_and_kill(argv(kill_dir, resume_json),
                              hb_file=os.path.join(
                                  kill_dir, "hb", "host_0000.hb"),
                              kill_step=10**9, env=env)
    assert not res.killed and res.returncode == 0, \
        res.stdout[-2000:] + res.stderr[-2000:]
    assert "restored step" in res.stdout

    with open(full_json) as f:
        full = json.load(f)
    with open(resume_json) as f:
        resumed = json.load(f)
    assert full["start"] == 0
    start = resumed["start"]
    assert 0 < start <= kill_step + 1
    # bit-identical loss trajectory across the resume boundary
    np.testing.assert_array_equal(np.asarray(resumed["losses"]),
                                  np.asarray(full["losses"][start:]))
    # the combined trajectory is the goldens' (same tolerance as
    # test_train_golden)
    combined = full["losses"][:start] + resumed["losses"]
    np.testing.assert_allclose(np.asarray(combined),
                               np.asarray(golden["loss"]),
                               rtol=2e-2, atol=1e-3)
    # final state bit-identical: compare every leaf's crc32
    a = _manifest_checksums(os.path.join(full_dir, "ckpt_00000020"))
    b = _manifest_checksums(os.path.join(kill_dir, "ckpt_00000020"))
    assert a == b


# ---------------------------------------------------------------------------
# sharded restore parity across mesh-shape changes (forced 4 devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_multidevice_fault_suite(multidevice_runner):
    out = multidevice_runner("_multidevice_fault_checks.py", device_count=4)
    assert "ALL FAULT CHECKS PASSED" in out
