"""Fixed-fan-in sparse head subsystem (DESIGN.md §13, ISSUE 9).

The contract under test:

* ``sparsify``/``densify`` are exact inverses on the kept slots (byte
  compare — ``-0.0`` and FP8 encodings survive), indices stay sorted
  strictly increasing, and at ``fan_in == d_model`` sparsify yields the
  identity index plane;
* the sparse megakernel (interpret lowering) is **bit-identical** to the
  pure-JAX oracle scan (``ref.sparse_head_step_ref``) in values, Kahan
  comp, x̄ and the CE streaming LSE, across a hypothesis sweep of shapes,
  losses, SR/Kahan and DropConnect — the same SR/DropConnect draws by
  construction (``hash_bits_at`` at the gathered coordinates);
* at ``fan_in == d_model`` with identity indices the sparse step is
  bit-identical to the dense grid path — the subsystem's parity anchor;
* prune/regrow is a deterministic pure function of (state, x, targets):
  same inputs → bit-identical topology, the strictly-increasing index
  invariant is preserved, regrown slots start at zero, and the cadence
  wrapper is an exact identity off-schedule;
* sparse serving (logits / top-k) equals the dense serving paths on the
  densified state bit-for-bit, values AND ids;
* ``memory_model.head_components(fan_in=...)`` accounts the §13 layout —
  ≥10× weight+optimizer shrink at the configured fan-in for the paper's
  Amazon-3M arch — while leaving the dense numbers bit-for-bit unchanged
  (satellite);
* ``ELMOHead.attach_shortlist(rebuild_if_stale=True)`` warns and rebuilds
  a stale index, passes a fresh one through silently, and refuses
  ``rebuild_if_stale`` without a state (satellite);
* a 20-step sparse training run with prune/regrow events SIGKILLed
  mid-run resumes bit-identically (§10 harness) — the controller has no
  RNG stream, so raw-bit checkpointing of values/indices/comp is the
  whole resume contract.
"""
import dataclasses
import json
import os
import warnings
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import head as H
from repro.core import memory_model as MM
from repro.fault import inject
from repro.head import sparse as SP
from repro.head.sparse.train import train_step_sparse
from repro.kernels import ref

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_HP = H.HeadHparams(jnp.float32(0.05), jnp.float32(1e-4), jnp.uint32(7))


def _mk(loss="bce", L=300, D=64, C=4, F=12, wdtype="e4m3", kahan=0,
        sr=True, drop=0.0, B=16, seed=0, **kw):
    cfg = H.ELMOHeadConfig(num_labels=L, d_model=D, num_chunks=C,
                           weight_dtype=wdtype, loss=loss, fan_in=F,
                           kahan_chunks=kahan, use_sr=sr, drop_rate=drop,
                           **kw)
    state = SP.init_sparse_head(jax.random.PRNGKey(seed), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(seed + 1), (B, D)) * 0.5
         ).astype(jnp.bfloat16)
    if loss == "bce":
        tg = jax.random.randint(jax.random.PRNGKey(seed + 2), (B, 5), 0, L)
    else:
        tg = jax.random.randint(jax.random.PRNGKey(seed + 2), (B,), -1, L)
    return cfg, state, x, tg


def _bits(a):
    return None if a is None else np.asarray(a).view(np.uint8)


def _run_sparse(cfg, state, x, tg, inner):
    plan = H.resolve_plan(cfg, batch=x.shape[0],
                          target_slots=tg.shape[-1] if tg.ndim == 2 else 1)
    assert plan.path == "sparse", plan.path
    plan = dataclasses.replace(plan, train_inner=inner)
    st2, xg, m = train_step_sparse(plan, cfg, state, x, tg, _HP.lr, _HP.wd,
                                   _HP.seed)
    return st2, xg, float(m["loss"])


# ---------------------------------------------------------------------------
# state: sparsify / densify
# ---------------------------------------------------------------------------


def test_sparsify_densify_identity_at_full_fan_in():
    cfg_d = H.ELMOHeadConfig(num_labels=300, d_model=64, num_chunks=4,
                             weight_dtype="e4m3")
    dense = H.init_head(jax.random.PRNGKey(0), cfg_d)
    cfg_s = dataclasses.replace(cfg_d, fan_in=64)
    sp = SP.sparsify(cfg_s, dense)
    # identity index plane and exact (byte-level) weight round-trip
    assert (np.asarray(sp.indices) == np.arange(64)).all()
    back = SP.densify(cfg_s, sp)
    np.testing.assert_array_equal(_bits(back.w), _bits(dense.w))
    assert SP.indices_strictly_increasing(sp)


def test_sparsify_keeps_top_magnitude_and_roundtrips():
    cfg_d = H.ELMOHeadConfig(num_labels=300, d_model=64, num_chunks=4,
                             weight_dtype="e4m3")
    dense = H.init_head(jax.random.PRNGKey(0), cfg_d)
    cfg_s = dataclasses.replace(cfg_d, fan_in=12)
    sp = SP.sparsify(cfg_s, dense)
    assert sp.values.shape == (4, cfg_s.chunk, 12)
    assert SP.indices_strictly_increasing(sp)
    # densify→sparsify is idempotent: the kept slots survive exactly
    sp2 = SP.sparsify(cfg_s, SP.densify(cfg_s, sp))
    np.testing.assert_array_equal(_bits(sp.values), _bits(sp2.values))
    np.testing.assert_array_equal(np.asarray(sp.indices),
                                  np.asarray(sp2.indices))
    # the kept magnitude per row dominates the dropped magnitude
    w = np.abs(np.asarray(SP.densify(cfg_s, sp).w, np.float32))
    full = np.abs(np.asarray(dense.w, np.float32))
    assert (np.sort(w, -1)[..., -12:] >= np.sort(full, -1)[..., -13:-12]
            - 1e-6).all()


# ---------------------------------------------------------------------------
# kernel ≡ oracle (hypothesis sweep) and the dense parity anchor
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(B=st.integers(1, 12), D=st.integers(8, 48), F=st.integers(1, 8),
       C=st.integers(1, 3), bce=st.integers(0, 1), kahan=st.integers(0, 1),
       sr=st.integers(0, 1), drop=st.floats(0.0, 0.3))
def test_sparse_kernel_bitwise_matches_oracle(B, D, F, C, bce, kahan, sr,
                                              drop):
    F = min(F, D)
    loss = "bce" if bce else "softmax_ce"
    cfg, state, x, tg = _mk(loss=loss, L=C * 97 + 11, D=D, C=C, F=F,
                            kahan=C if kahan else 0, sr=bool(sr),
                            drop=round(drop, 2), B=B, seed=B + D + F)
    sk, xgk, lk = _run_sparse(cfg, state, x, tg, "interpret")
    so, xgo, lo = _run_sparse(cfg, state, x, tg, "xla")
    np.testing.assert_array_equal(_bits(sk.values), _bits(so.values))
    np.testing.assert_array_equal(_bits(sk.comp), _bits(so.comp))
    np.testing.assert_array_equal(_bits(xgk), _bits(xgo))
    assert lk == lo, (lk, lo)


@pytest.mark.parametrize("loss", ["bce", "softmax_ce"])
@pytest.mark.parametrize("wdtype,kahan,sr", [
    ("e4m3", 0, True), ("bf16", 4, False)])
def test_full_fan_in_bitwise_matches_dense_grid(loss, wdtype, kahan, sr):
    """fan_in = d_model with identity indices ≡ the dense grid path: every
    SR/DropConnect draw addresses the same (row, col), so weights, Kahan
    comp, x̄ and the loss are bitwise the dense step's."""
    cfg_d = H.ELMOHeadConfig(num_labels=300, d_model=64, num_chunks=4,
                             weight_dtype=wdtype, loss=loss,
                             kahan_chunks=kahan, use_sr=sr,
                             impl="grid_interpret")
    dense = H.init_head(jax.random.PRNGKey(1), cfg_d)
    x = (jax.random.normal(jax.random.PRNGKey(2), (16, 64)) * 0.5
         ).astype(jnp.bfloat16)
    tg = (jax.random.randint(jax.random.PRNGKey(3), (16, 5), 0, 300)
          if loss == "bce" else
          jax.random.randint(jax.random.PRNGKey(3), (16,), -1, 300))
    cfg_s = dataclasses.replace(cfg_d, fan_in=64)
    sp = SP.sparsify(cfg_s, dense)

    st_d, xg_d, m_d = H.head_train_step(cfg_d, dense, x, tg, _HP.lr, _HP.wd,
                                        _HP.seed)
    for inner in ("interpret", "xla"):
        st_s, xg_s, loss_s = _run_sparse(cfg_s, sp, x, tg, inner)
        back = SP.densify(cfg_s, st_s)
        np.testing.assert_array_equal(_bits(back.w), _bits(st_d.w))
        np.testing.assert_array_equal(_bits(back.comp), _bits(st_d.comp))
        np.testing.assert_array_equal(_bits(xg_s), _bits(xg_d))
        assert loss_s == float(m_d["loss"]), inner


# ---------------------------------------------------------------------------
# prune/regrow controller
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("loss,kahan", [("bce", 0), ("softmax_ce", 4)])
def test_prune_regrow_deterministic_and_invariant(loss, kahan):
    cfg, state, x, tg = _mk(loss=loss, kahan=kahan, prune_every=4)
    a = jax.jit(lambda s: SP.prune_regrow(cfg, s, x, tg))(state)
    b = jax.jit(lambda s: SP.prune_regrow(cfg, s, x, tg))(state)
    # pure function of (state, x, targets): bit-identical replay
    np.testing.assert_array_equal(_bits(a.values), _bits(b.values))
    np.testing.assert_array_equal(np.asarray(a.indices),
                                  np.asarray(b.indices))
    np.testing.assert_array_equal(_bits(a.comp), _bits(b.comp))
    # invariant: sorted strictly increasing → unique, exact fan-in
    assert SP.indices_strictly_increasing(a)
    # the topology moved, a row swaps at most n_swap columns, and every
    # newly-grown column (absent from the old row's index set — position
    # shifts from re-sorting don't count) starts at value/comp zero
    old_i, new_i = np.asarray(state.indices), np.asarray(a.indices)
    fresh = ~(new_i[..., :, None] == old_i[..., None, :]).any(-1)
    assert fresh.any()
    assert fresh.sum(-1).max() <= SP.n_swap_of(cfg)
    vals = np.asarray(a.values, np.float32)
    assert (vals[fresh] == 0.0).all()
    if kahan:
        comp = np.asarray(a.comp, np.float32)
        assert (comp[fresh] == 0.0).all()


def test_maybe_prune_regrow_cadence():
    cfg, state, x, tg = _mk(prune_every=4)
    for step, fires in ((0, False), (3, False), (4, True), (8, True)):
        out = jax.jit(lambda s, t: SP.maybe_prune_regrow(cfg, s, x, tg, t)
                      )(state, jnp.int32(step))
        changed = (np.asarray(out.indices) != np.asarray(state.indices)
                   ).any()
        assert changed == fires, (step, fires)
        if fires:   # the cond's taken branch is the controller, bit-exact
            want = SP.prune_regrow(cfg, state, x, tg)
            np.testing.assert_array_equal(np.asarray(out.indices),
                                          np.asarray(want.indices))
            np.testing.assert_array_equal(_bits(out.values),
                                          _bits(want.values))


def test_n_swap_floor():
    cfg = _mk(F=4)[0]
    assert SP.n_swap_of(cfg) == 1                       # max(1, round(0.4))
    assert SP.n_swap_of(dataclasses.replace(cfg, regrow_frac=0.5)) == 2


# ---------------------------------------------------------------------------
# serving: sparse paths ≡ dense paths on the densified state
# ---------------------------------------------------------------------------


def test_sparse_serving_bitwise_matches_dense():
    cfg, state, x, _ = _mk(F=12, sr=False)
    cfg_d = dataclasses.replace(cfg, fan_in=0)
    dense = SP.densify(cfg, state)
    plan_s = H.resolve_plan(cfg, batch=x.shape[0])
    z_s = SP.logits_sparse_planned(plan_s, cfg, state, x)
    z_d = H.head_logits(cfg_d, dense, x)
    np.testing.assert_array_equal(_bits(z_s), _bits(z_d))
    for k in (5, 64, 400):      # k beyond a chunk and beyond num_labels
        k = min(k, cfg.padded_labels)
        v_s, i_s = SP.topk_sparse_planned(plan_s, cfg, state, x, k)
        v_d, i_d = H.head_topk(cfg_d, dense, x, k)
        np.testing.assert_array_equal(_bits(v_s), _bits(v_d))
        np.testing.assert_array_equal(np.asarray(i_s), np.asarray(i_d))
        assert (np.asarray(i_s)[np.asarray(v_s) > -1e15]
                < cfg.num_labels).all()


def test_facade_sparse_dispatch_and_plan():
    cfg, state, x, tg = _mk(F=12, prune_every=4)
    head = H.ELMOHead(cfg, batch=16, target_slots=5)
    assert head.plan.path == "sparse"
    assert head.plan.fan_in == 12
    assert "sparse" in head.plan.explain()
    st0 = head.init(jax.random.PRNGKey(0))
    assert isinstance(st0, SP.SparseHeadState)
    st1, xg, m = head.train_step(st0, x, tg, _HP)
    assert isinstance(st1, SP.SparseHeadState)
    assert np.isfinite(float(m["loss"]))
    # facade serving round-trips through the sparse paths
    v, i = head.topk(st1, x, 5)
    assert v.shape == (16, 5) and i.shape == (16, 5)
    # cadence hook: identity off-schedule, topology update on-schedule
    same = head.maybe_prune_regrow(st1, x, tg, jnp.int32(3))
    np.testing.assert_array_equal(np.asarray(same.indices),
                                  np.asarray(st1.indices))
    swapped = head.maybe_prune_regrow(st1, x, tg, jnp.int32(4))
    assert (np.asarray(swapped.indices) != np.asarray(st1.indices)).any()
    # dense heads: the hook is a structural no-op
    cfg_d = dataclasses.replace(cfg, fan_in=0, prune_every=0)
    head_d = H.ELMOHead(cfg_d, batch=16, target_slots=5)
    dstate = head_d.init(jax.random.PRNGKey(0))
    assert head_d.maybe_prune_regrow(dstate, x, tg, jnp.int32(4)) is dstate


# ---------------------------------------------------------------------------
# memory model (satellite): §13 accounting, dense numbers untouched
# ---------------------------------------------------------------------------


def test_memory_model_sparse_accounting():
    s = MM.MemScenario(num_labels=2_812_281, d_model=768, batch=128,
                       num_chunks=8, kahan_chunks=2)
    dense = MM.head_components(s, "e4m3")
    # dense accounting is bit-for-bit what it always was (pinned)
    L = 2_812_281
    assert dense["W_e4m3"] == L * 768
    assert dense["W_kahan_comp_bf16"] == L * 768 * 2 * (2 / 8)
    assert dense["W_grad"] == 0.0
    dense_w = sum(v for k, v in dense.items() if k.startswith("W_"))
    assert dense_w == L * 1152

    sp = MM.head_components(dataclasses.replace(s, kahan_chunks=0),
                            "e4m3", fan_in=16)
    assert sp["W_e4m3"] == L * 16
    assert sp["W_indices_i32"] == L * 16 * 4
    assert sp["W_kahan_comp_bf16"] == 0.0
    sparse_w = sum(v for k, v in sp.items() if k.startswith("W_"))
    # ≥10× head weight+optimizer shrink at the configured fan-in (14.4×)
    assert dense_w / sparse_w >= 10.0
    # transients unchanged by the sparse flag (dense compute shapes)
    assert sp["chunk_logits_bf16"] == dense["chunk_logits_bf16"]
    # label sharding divides every sparse plane
    sp4 = MM.head_components(dataclasses.replace(s, kahan_chunks=0),
                             "e4m3", n_label_shards=4, fan_in=16)
    assert sp4["W_e4m3"] == sp["W_e4m3"] / 4
    assert sp4["W_indices_i32"] == sp["W_indices_i32"] / 4


# ---------------------------------------------------------------------------
# attach_shortlist(rebuild_if_stale=...) (satellite)
# ---------------------------------------------------------------------------


def test_attach_shortlist_rebuild_if_stale():
    from repro.head import shortlist as SL

    cfg = H.ELMOHeadConfig(num_labels=300, d_model=64, num_chunks=4,
                           weight_dtype="bf16", use_sr=False,
                           shortlist="on")
    state = H.init_head(jax.random.PRNGKey(0), cfg)
    head = H.ELMOHead(cfg, batch=8)
    index = head.build_shortlist(state, iters=2, n_clusters=8, beam=4)

    # fresh index: attached silently, same object
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        got = head.attach_shortlist(index, rebuild_if_stale=True,
                                    state=state)
    assert got is index and head.shortlist is index

    # stale index (weights moved): warns and rebuilds, same geometry
    moved = state._replace(w=(state.w.astype(jnp.float32) * 1.5
                              ).astype(state.w.dtype))
    assert SL.is_stale(index, moved)
    with pytest.warns(UserWarning, match="stale"):
        rebuilt = head.attach_shortlist(index, rebuild_if_stale=True,
                                        state=moved, iters=2)
    assert rebuilt is not index
    assert not SL.is_stale(rebuilt, moved)
    assert (rebuilt.n_clusters, rebuilt.beam) == (index.n_clusters,
                                                  index.beam)
    assert head.shortlist is rebuilt

    # rebuild_if_stale without the state to check against: refused
    with pytest.raises(ValueError, match="needs the state"):
        head.attach_shortlist(index, rebuild_if_stale=True)


# ---------------------------------------------------------------------------
# label-sharded bit parity (forced 4-device subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_sparse_parity(multidevice_runner):
    out = multidevice_runner("_sparse_head_checks.py", 4)
    assert "ALL SPARSE SHARDED CHECKS PASSED" in out


# ---------------------------------------------------------------------------
# §10 resume: 20 sparse steps with prune/regrow events across a SIGKILL
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sparse_sigkill_resume_bit_identical(tmp_path):
    """20 steps of the sparse smoke arch with prune/regrow every 4 steps,
    SIGKILLed at a pinned pseudo-random step ∈ [5, 12] (so topology swaps
    land on BOTH sides of the kill), restarted, and compared to an
    uninterrupted run: the loss trajectory across the resume boundary is
    bit-identical and the final committed checkpoints match leaf-for-leaf
    (manifest crc32s) — values, i32 indices and Kahan comp all round-trip
    as raw bits (§10)."""
    env = inject.subprocess_env(os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)

    def argv(ckpt_dir, losses_out):
        return inject.train_argv(
            "--arch", "xmc-bert-3m-sparse", "--smoke", "--steps", "20",
            "--global-batch", "8", "--head-labels", "2003",
            "--head-prune-every", "4",
            "--ckpt-dir", ckpt_dir, "--ckpt-every", "2",
            "--losses-out", losses_out)

    full_dir, kill_dir = str(tmp_path / "full"), str(tmp_path / "kill")
    full_json = str(tmp_path / "full.json")
    resume_json = str(tmp_path / "resume.json")

    res = inject.run_and_kill(argv(full_dir, full_json),
                              hb_file=os.path.join(full_dir, "hb",
                                                   "host_0000.hb"),
                              kill_step=10**9, env=env)
    assert not res.killed and res.returncode == 0, \
        res.stdout[-2000:] + res.stderr[-2000:]

    kill_step = 5 + zlib.crc32(b"elmo-sparse-head") % 8       # ∈ [5, 12]
    res = inject.run_and_kill(argv(kill_dir, str(tmp_path / "unused.json")),
                              hb_file=os.path.join(kill_dir, "hb",
                                                   "host_0000.hb"),
                              kill_step=kill_step, env=env)
    assert res.killed and res.step_seen >= kill_step

    res = inject.run_and_kill(argv(kill_dir, resume_json),
                              hb_file=os.path.join(kill_dir, "hb",
                                                   "host_0000.hb"),
                              kill_step=10**9, env=env)
    assert not res.killed and res.returncode == 0, \
        res.stdout[-2000:] + res.stderr[-2000:]
    assert "restored step" in res.stdout

    with open(full_json) as f:
        full = json.load(f)
    with open(resume_json) as f:
        resumed = json.load(f)
    start = resumed["start"]
    assert 0 < start <= kill_step + 1
    # bit-identical trajectory across the resume boundary — prune/regrow
    # events after the boundary replayed from the restored raw bits
    np.testing.assert_array_equal(np.asarray(resumed["losses"]),
                                  np.asarray(full["losses"][start:]))

    def checksums(d):
        with open(os.path.join(d, "ckpt_00000020", "manifest.json")) as f:
            return {e["name"]: e["checksum"]
                    for e in json.load(f)["leaves"]}

    assert checksums(full_dir) == checksums(kill_dir)
