"""Minimal deterministic stand-in for ``hypothesis``.

This container does not ship hypothesis and installing packages is not
allowed, so conftest registers this stub when the real library is missing.
Property tests degrade to seeded random sweeps: ``@given`` reruns the test
``max_examples`` times with draws from a fixed-seed RandomState — no
shrinking, no database, but the same invariants get exercised on every run.

Only the surface the test suite uses is implemented:
``given``, ``settings``, ``strategies.{integers,floats,lists}``.
"""
from __future__ import annotations

import functools
import sys
import types

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.randint(min_value, max_value + 1)))


def floats(min_value=0.0, max_value=1.0, allow_nan=False,
           allow_infinity=False, **_):
    span = float(max_value) - float(min_value)
    return _Strategy(
        lambda rng: float(min_value) + span * float(rng.random_sample()))


def lists(elements, min_size=0, max_size=10):
    def draw(rng):
        n = int(rng.randint(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(n)]
    return _Strategy(draw)


def settings(max_examples=20, deadline=None, **_):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strats, **kwstrats):
    def deco(fn):
        # NOTE: wrapper must take no parameters and must NOT carry
        # __wrapped__ — pytest introspects the signature and would treat
        # the original test parameters as fixtures otherwise.
        def wrapper():
            n = getattr(fn, "_max_examples", 20)
            rng = np.random.RandomState(0)
            for _ in range(n):
                vals = [s.draw(rng) for s in strats]
                kvals = {k: s.draw(rng) for k, s in kwstrats.items()}
                fn(*vals, **kvals)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


def _register():
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = integers
    strategies.floats = floats
    strategies.lists = lists
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


_register()
