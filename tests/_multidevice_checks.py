"""Multi-device checks, run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (tests/test_distributed.py).
Exit code 0 = all checks passed."""
import os

_N_DEV = int(os.environ.get("REPRO_FORCE_DEVICES", "8"))
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + f" --xla_force_host_platform_device_count={_N_DEV}")

import jax                      # noqa: E402
import jax.numpy as jnp        # noqa: E402
import numpy as np             # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_smoke                  # noqa: E402
from repro.dist import meshctx                       # noqa: E402
from repro.dist.pipeline_parallel import pipeline_apply  # noqa: E402
from repro.launch import steps as St                 # noqa: E402
from repro.launch.mesh import make_host_mesh         # noqa: E402
from repro.models import moe as Moe                  # noqa: E402
from repro.models import transformer as T            # noqa: E402
from repro.optim import kahan_adamw                  # noqa: E402

assert len(jax.devices()) == _N_DEV, jax.devices()


def check_moe_ep_matches_local():
    """shard_map EP (4 experts / 2 model ranks) == single-device MoE."""
    cfg = get_smoke("arctic-480b", n_experts=4, d_model=64, d_ff=64,
                    capacity_factor=8.0)  # generous capacity: no drops
    p = Moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64), jnp.bfloat16)

    local = Moe.moe_apply(p, cfg, x)                 # no ctx → local path
    ctx = make_host_mesh(4, 2)
    with meshctx.use(ctx):
        dist = jax.jit(lambda p, x: Moe.moe_apply(p, cfg, x))(p, x)
    np.testing.assert_allclose(np.asarray(local, np.float32),
                               np.asarray(dist, np.float32),
                               rtol=5e-2, atol=5e-2)
    print("moe_ep ok")


def check_moe_tp_matches_local():
    """TP-inside-expert mode (E=3 not divisible by model=2)."""
    cfg = get_smoke("mixtral-8x7b", n_experts=3, d_model=64, d_ff=64,
                    capacity_factor=8.0)
    p = Moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 64), jnp.bfloat16)
    local = Moe.moe_apply(p, cfg, x)
    ctx = make_host_mesh(4, 2)
    with meshctx.use(ctx):
        dist = jax.jit(lambda p, x: Moe.moe_apply(p, cfg, x))(p, x)
    np.testing.assert_allclose(np.asarray(local, np.float32),
                               np.asarray(dist, np.float32),
                               rtol=5e-2, atol=5e-2)
    print("moe_tp ok")


def check_train_step_sharded_matches_single():
    """pjit-sharded train step == single-device step (same seeds)."""
    cfg = get_smoke("smollm-360m")
    opt = kahan_adamw(weight_decay=0.0)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0,
                                     cfg.vocab),
        "targets": jax.random.randint(jax.random.PRNGKey(3), (8, 16), 0,
                                      cfg.vocab),
    }
    state0 = St.init_train_state(jax.random.PRNGKey(1), cfg, opt, impl="xla")
    _, m_single = St.train_step(cfg, opt, state0, batch,
                                jnp.float32(0.05), jnp.float32(1e-3),
                                impl="xla")

    ctx = make_host_mesh(4, 2)
    with meshctx.use(ctx):
        sb = {k: jax.device_put(v, NamedSharding(ctx.mesh, P("data", None)))
              for k, v in batch.items()}
        _, m_shard = jax.jit(
            lambda s, b: St.train_step(cfg, opt, s, b, jnp.float32(0.05),
                                       jnp.float32(1e-3), impl="xla"))(
            state0, sb)
    a, b = float(m_single["loss"]), float(m_shard["loss"])
    assert abs(a - b) < 0.02 * abs(a) + 1e-3, (a, b)
    print("sharded train ok", a, b)


def check_pipeline_parallel():
    from repro.dist.compat import make_mesh
    mesh = make_mesh((8,), ("stage",))
    n_stages, D = 8, 16
    ws = jax.random.normal(jax.random.PRNGKey(0), (n_stages, D, D),
                           jnp.float32) * 0.3

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    x = jax.random.normal(jax.random.PRNGKey(1), (32, D), jnp.float32)
    got = pipeline_apply(mesh, "stage", n_micro=16, stage_fn=stage_fn,
                         stage_params=ws, x=x)
    want = x
    for s in range(n_stages):
        want = stage_fn(ws[s], want)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    print("pipeline ok")


def check_seq_parallel_constraint_applies():
    cfg = get_smoke("smollm-360m")
    ctx = make_host_mesh(2, 4)
    with meshctx.use(ctx):
        bb = T.backbone_init(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                  cfg.vocab)
        h = jax.jit(lambda bb, t: T.backbone_apply(bb, cfg, t))(bb, toks)
    h_local = T.backbone_apply(bb, cfg, toks)
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(h_local, np.float32),
                               rtol=5e-2, atol=5e-2)
    print("seq-parallel ok")


def check_moe_a2a_matches_local():
    """a2a-EP (E over data, F over model) == single-device MoE oracle."""
    import dataclasses
    cfg = get_smoke("arctic-480b", n_experts=8, d_model=64, d_ff=64,
                    capacity_factor=8.0)
    cfg = dataclasses.replace(cfg, moe_mode="a2a")
    p = Moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64), jnp.bfloat16)
    base = dataclasses.replace(cfg, moe_mode="auto")
    local = Moe.moe_apply(p, base, x)                 # no ctx → local path
    ctx = make_host_mesh(4, 2)
    with meshctx.use(ctx):
        dist = jax.jit(lambda p, x: Moe.moe_apply(p, cfg, x))(p, x)
    np.testing.assert_allclose(np.asarray(local, np.float32),
                               np.asarray(dist, np.float32),
                               rtol=5e-2, atol=5e-2)
    # gradients flow through dispatch (a2a/scatter/psum transposes)
    with meshctx.use(ctx):
        g = jax.grad(lambda xx: jnp.sum(
            Moe.moe_apply(p, cfg, xx).astype(jnp.float32) ** 2))(x)
    assert np.isfinite(np.asarray(g, np.float32)).all()
    print("moe_a2a ok")


if __name__ == "__main__":
    check_moe_ep_matches_local()
    check_moe_a2a_matches_local()
    check_moe_tp_matches_local()
    check_train_step_sharded_matches_single()
    check_pipeline_parallel()
    check_seq_parallel_constraint_applies()
    print("ALL MULTIDEVICE CHECKS PASSED")
