"""Property + statistical tests for core/precision.py (SR, Kahan, format sim)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import precision as P

jax.config.update("jax_enable_x64", False)


def _neighbors(x32, dtype):
    """Grid neighbours of x in target dtype, as f32 numpy arrays."""
    y = np.asarray(jnp.asarray(x32, jnp.float32).astype(dtype).astype(jnp.float32))
    # brute-force next up / next down by scanning the (tiny) fp8/bf16 grid
    return y


@pytest.mark.parametrize("dtype", [P.BF16, P.E4M3, P.E5M2])
def test_sr_returns_grid_values(dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4096,), jnp.float32) * 3.0
    out = P.stochastic_round(x, dtype, jax.random.PRNGKey(1))
    # output must be exactly representable: casting to f32 and back is identity
    rt = out.astype(jnp.float32).astype(dtype)
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(rt, np.float32))


@pytest.mark.parametrize("dtype", [P.BF16, P.E4M3])
def test_sr_neighbor_property(dtype):
    """SR lands on one of the two bracketing grid points."""
    key = jax.random.PRNGKey(42)
    x = jax.random.normal(key, (2048,), jnp.float32)
    out = np.asarray(P.stochastic_round(x, dtype, jax.random.PRNGKey(7))
                     .astype(jnp.float32))
    x_np = np.asarray(x)
    err = np.abs(out - x_np)
    # SR lands within one full grid step of x: step ≈ 2^(floor(log2|x|) - m)
    m = {jnp.dtype(P.BF16): 7, jnp.dtype(P.E4M3): 3}[jnp.dtype(dtype)]
    step = np.maximum(np.abs(x_np), 2.0 ** -6) * 2.0 ** (1 - m)
    assert np.all(err <= step + 1e-9)


@pytest.mark.parametrize("maker,dtype", [
    (lambda x, b: P.sr_bits_bf16(x, b), P.BF16),
    (lambda x, b: P.sr_bits_e4m3(x, b), P.E4M3),
])
def test_sr_unbiased(maker, dtype):
    """E[SR(x)] == x to statistical tolerance (the paper's core property)."""
    n_rep = 512
    x = jnp.array([0.1, -0.3, 1.7, 0.017, -2.31, 0.0007, 3.3, -0.09],
                  jnp.float32)
    xs = jnp.tile(x[None, :], (n_rep, 1))
    bits = jax.random.bits(jax.random.PRNGKey(3), xs.shape, jnp.uint32)
    out = maker(xs, bits).astype(jnp.float32)
    mean = np.asarray(out.mean(axis=0))
    # tolerance: grid step / sqrt(n_rep) * few sigma
    rn = np.asarray(jnp.asarray(x).astype(dtype).astype(jnp.float32))
    step = np.maximum(np.abs(np.asarray(x) - rn) * 2, np.abs(np.asarray(x)) * 2.0 ** -9)
    tol = 6.0 * (step + 1e-9) / np.sqrt(n_rep)
    np.testing.assert_allclose(mean, np.asarray(x), atol=float(tol.max()))


def test_sr_bits_e4m3_subnormal_grid():
    """Subnormal SR stays on the 2^-9 grid and is unbiased there."""
    x = jnp.array([2.0 ** -8 * 1.3, -(2.0 ** -7) * 0.7, 2.0 ** -10], jnp.float32)
    xs = jnp.tile(x[None, :], (2048, 1))
    bits = jax.random.bits(jax.random.PRNGKey(5), xs.shape, jnp.uint32)
    out = np.asarray(P.sr_bits_e4m3(xs, bits).astype(jnp.float32))
    grid = out * 512.0
    np.testing.assert_allclose(grid, np.round(grid), atol=1e-6)
    np.testing.assert_allclose(out.mean(0), np.asarray(x), atol=2.0 ** -9)


def test_sr_saturates_no_nan():
    x = jnp.array([1e9, -1e9, 500.0, -460.0], jnp.float32)
    out = P.stochastic_round(x, P.E4M3, jax.random.PRNGKey(0))
    out_np = np.asarray(out.astype(jnp.float32))
    assert np.all(np.isfinite(out_np))
    np.testing.assert_array_equal(out_np, [448.0, -448.0, 448.0, -448.0])
    bits = jax.random.bits(jax.random.PRNGKey(1), x.shape, jnp.uint32)
    out2 = np.asarray(P.sr_bits_e4m3(x, bits).astype(jnp.float32))
    assert np.all(np.isfinite(out2))
    assert np.all(np.abs(out2) <= 448.0)


def test_bit_trick_matches_oracle_distribution():
    """Bit-trick SR and oracle SR agree in mean over many draws."""
    x = jnp.array([0.123, -0.456, 7.89, 0.00123], jnp.float32)
    xs = jnp.tile(x[None, :], (16384, 1))
    keys = jax.random.split(jax.random.PRNGKey(9), 2)
    bits = jax.random.bits(keys[0], xs.shape, jnp.uint32)
    fast = np.asarray(P.sr_bits_e4m3(xs, bits).astype(jnp.float32)).mean(0)
    oracle = np.asarray(
        P.stochastic_round(xs, P.E4M3, keys[1]).astype(jnp.float32)).mean(0)
    tol = np.abs(np.asarray(x)) * 0.02 + 1e-5
    assert np.all(np.abs(fast - oracle) <= tol), (fast, oracle)


# ---------------------------------------------------------------------------
# Kahan summation
# ---------------------------------------------------------------------------


def test_kahan_tracks_f32_sum():
    """1e4 tiny updates: plain BF16 RN stalls, Kahan tracks the f32 oracle."""
    n_steps = 10_000
    upd = 1e-4  # far below bf16 ulp at 1.0 (≈ 0.0078)

    def body(carry, _):
        p, c, p_plain = carry
        p, c = P.kahan_update(p, c, jnp.float32(upd))
        p_plain = (p_plain.astype(jnp.float32) + upd).astype(jnp.bfloat16)
        return (p, c, p_plain), None

    init = (jnp.bfloat16(1.0), jnp.bfloat16(0.0), jnp.bfloat16(1.0))
    (p, c, p_plain), _ = jax.lax.scan(body, init, None, length=n_steps)
    oracle = 1.0 + n_steps * upd  # 2.0
    # bf16-stored compensation leaks ≲ a few ulps over 1e4 adversarial
    # constant updates (ulp(2.0) = 0.015625); plain RN never moves at all.
    assert abs(float(p) - oracle) <= 3 * 0.015625, float(p)
    assert abs(float(p_plain) - 1.0) < 1e-6  # plain RN never moves
    assert abs(float(p) - oracle) < 0.1 * abs(float(p_plain) - oracle)


@given(st.lists(st.floats(-1e-3, 1e-3, allow_nan=False), min_size=1, max_size=200))
@settings(max_examples=25, deadline=None)
def test_kahan_error_bound_property(updates):
    """|kahan_sum - f32_sum| ≤ one bf16 ulp of the result, for any updates."""
    p, c = jnp.bfloat16(1.0), jnp.bfloat16(0.0)
    for u in updates:
        p, c = P.kahan_update(p, c, jnp.float32(u))
    oracle = 1.0 + float(np.sum(np.asarray(updates, np.float32)))
    ulp = max(abs(oracle), 1.0) * 2.0 ** -8
    assert abs(float(p) - float(c) * 0 - oracle) <= 2 * ulp


# ---------------------------------------------------------------------------
# simulate_format
# ---------------------------------------------------------------------------


def test_simulate_format_idempotent():
    x = jax.random.normal(jax.random.PRNGKey(0), (1024,), jnp.float32)
    y = P.simulate_format(x, 4, 3)
    y2 = P.simulate_format(y, 4, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), rtol=0, atol=0)


def test_simulate_format_matches_e4m3_cast():
    """Simulated (4,3) grid ≈ real e4m3 RN cast away from tie boundaries."""
    x = jax.random.normal(jax.random.PRNGKey(1), (4096,), jnp.float32)
    sim = np.asarray(P.simulate_format(x, 4, 3))
    real = np.asarray(jnp.asarray(x).astype(P.E4M3).astype(jnp.float32))
    # identical except possibly at round-half ties (different tie rules)
    frac_diff = np.mean(sim != real)
    assert frac_diff < 0.02, frac_diff


@given(st.integers(2, 8), st.integers(1, 10))
@settings(max_examples=20, deadline=None)
def test_simulate_format_monotone(e_bits, m_bits):
    x = jnp.linspace(-4.0, 4.0, 513, dtype=jnp.float32)
    y = np.asarray(P.simulate_format(x, e_bits, m_bits))
    assert np.all(np.diff(y) >= -1e-9)


def test_sr_cast_dispatch():
    x = jax.random.normal(jax.random.PRNGKey(2), (256,), jnp.float32)
    for dt in (P.BF16, P.E4M3, P.E5M2):
        out = P.sr_cast(x, dt, jax.random.PRNGKey(3))
        assert out.dtype == jnp.dtype(dt)
        assert np.all(np.isfinite(np.asarray(out.astype(jnp.float32))))
