import os
import sys

import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:  # container without hypothesis: deterministic stub
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_stub  # noqa: F401  (registers sys.modules entries)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running integration tests (subprocess suites)")
