import os
import subprocess
import sys

import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:  # container without hypothesis: deterministic stub
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_stub  # noqa: F401  (registers sys.modules entries)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running integration tests (subprocess suites)")


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Drop compiled executables between test modules.

    A full suite run accumulates hundreds of jitted programs in one
    process; on constrained hosts the XLA CPU JIT eventually segfaults
    inside ``backend_compile`` (observed deterministically in
    ``test_property_parity`` at ~75% of the run).  Compiled programs are
    never shared across modules here, so clearing is behavior-neutral —
    it only trades some recompilation time for bounded JIT memory."""
    yield
    import jax

    jax.clear_caches()


@pytest.fixture
def multidevice_runner():
    """Run a ``tests/_*.py`` check script in a subprocess with a forced
    host-device count (``--xla_force_host_platform_device_count``).

    The script reads ``REPRO_FORCE_DEVICES`` and sets XLA_FLAGS itself
    *before* importing jax — the flag only takes effect at backend init, so
    it cannot be applied in-process once the parent's jax is live."""

    def run(script_name: str, device_count: int, timeout: int = 540) -> str:
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        env.pop("XLA_FLAGS", None)
        env["REPRO_FORCE_DEVICES"] = str(device_count)
        proc = subprocess.run(
            [sys.executable, os.path.join(REPO, "tests", script_name)],
            env=env, capture_output=True, text=True, timeout=timeout)
        assert proc.returncode == 0, \
            proc.stdout[-3000:] + proc.stderr[-3000:]
        return proc.stdout

    return run
