"""Distributed substrate tests: checkpoint roundtrip + elastic restore,
gradient compression, fault-tolerance primitives, data-pipeline determinism,
and the multi-device suite (MoE EP/TP, sharded train step, pipeline
parallelism, sequence parallelism) in a forced-8-device subprocess."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.checkpoint.ckpt import CheckpointManager, latest_committed
from repro.data import DataCursor, lm_batches, xmc_batches
from repro.dist import compression as C
from repro.fault import ElasticController, Heartbeat, StragglerMonitor, retry


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _tree():
    return {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "fp8": (jnp.ones((8,), jnp.float32) * 0.37).astype(jnp.float8_e4m3fn),
        "bf16": (jnp.ones((4, 4)) * 1.5).astype(jnp.bfloat16),
        "nested": {"step": jnp.int32(7)},
    }


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 5, tree, extra={"cursor": {"seed": 1,
                                                              "step": 5}})
    restored, step, extra = restore_checkpoint(str(tmp_path), tree)
    assert step == 5 and extra["cursor"]["step"] == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_uncommitted_ignored(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, tree)
    # fake a crashed (uncommitted) later checkpoint
    crash = tmp_path / "ckpt_00000003"
    crash.mkdir()
    (crash / "manifest.json").write_text("{}")
    assert latest_committed(str(tmp_path)).endswith("ckpt_00000002")


def test_checkpoint_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save_async(s, tree, extra={"s": s})
        mgr.wait()
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("ckpt_"))
    assert kept == ["ckpt_00000003", "ckpt_00000004"]
    _, step, extra = restore_checkpoint(str(tmp_path), tree)
    assert step == 4 and extra["s"] == 4


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_compression_roundtrip_error_bounded():
    g = jax.random.normal(jax.random.PRNGKey(0), (5000,), jnp.float32)
    c = C.compress(g)
    r = C.decompress(c, g.shape)
    rel = np.abs(np.asarray(r - g)) / (np.abs(np.asarray(g)) + 1e-6)
    assert np.median(rel) < 0.15          # e5m2 has 2 mantissa bits
    assert c.payload.dtype == jnp.float8_e5m2


def test_error_feedback_removes_bias():
    """Repeated compression of the same gradient: error feedback makes the
    time-average exact, plain compression keeps a persistent bias."""
    g = jax.random.normal(jax.random.PRNGKey(1), (2048,), jnp.float32) * 0.1
    err = jnp.zeros_like(g, jnp.bfloat16)
    acc_fb, acc_plain = np.zeros(2048), np.zeros(2048)
    n = 50
    for _ in range(n):
        c, err = C.compress_with_feedback(g, err)
        acc_fb += np.asarray(C.decompress(c, g.shape))
        acc_plain += np.asarray(C.decompress(C.compress(g), g.shape))
    err_fb = np.abs(acc_fb / n - np.asarray(g)).mean()
    err_plain = np.abs(acc_plain / n - np.asarray(g)).mean()
    assert err_fb < 0.5 * err_plain, (err_fb, err_plain)


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def test_heartbeat_detects_dead_host(tmp_path):
    hbs = [Heartbeat(str(tmp_path), h, timeout_s=10) for h in range(4)]
    t0 = 1000.0
    for h, hb in enumerate(hbs):
        if h != 2:     # host 2 is dead
            hb.beat(step=1, now=t0)
    alive = hbs[0].alive_hosts(4, now=t0 + 5)
    assert alive == [0, 1, 3]


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=1.5)
    for step in range(20):
        for h in range(4):
            mon.record(h, 1.0 if h != 3 else 2.5)
    assert mon.stragglers() == [3]


def test_elastic_controller_plans():
    ctl = ElasticController(n_hosts=8, hosts_per_data_shard=2, min_hosts=2)
    plan = ctl.plan_after_failure(alive=[0, 1, 2, 3, 4, 6, 7])
    assert plan["action"] == "restart"
    assert plan["new_data_parallelism"] == 3
    assert ctl.plan_after_failure(alive=[5])["action"] == "abort"


def test_retry_backoff():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "ok"

    assert retry(flaky, attempts=4, sleep=lambda s: None) == "ok"
    assert len(calls) == 3


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_resume():
    it1 = lm_batches(100, 8, 16, DataCursor(7, 0))
    ref = [next(it1) for _ in range(5)]
    it2 = lm_batches(100, 8, 16, DataCursor(7, 3))   # resume at step 3
    b3 = next(it2)
    np.testing.assert_array_equal(ref[3]["tokens"], b3["tokens"])


def test_data_host_sharding_partitions_batch():
    full = next(lm_batches(100, 8, 16, DataCursor(0, 0)))
    parts = [next(lm_batches(100, 8, 16, DataCursor(0, 0), host_id=h,
                             n_hosts=4)) for h in range(4)]
    np.testing.assert_array_equal(
        np.concatenate([p["tokens"] for p in parts]), full["tokens"])


def test_xmc_labels_power_law():
    b = next(xmc_batches(100, 10_000, 512, 8, 10, DataCursor(0, 0)))
    labels = b["targets"][b["targets"] >= 0]
    # head labels (rank < 100) should be far more frequent than uniform
    frac_head = (labels < 100).mean()
    assert frac_head > 0.3, frac_head     # uniform would be 0.01


# ---------------------------------------------------------------------------
# multi-device suite (subprocess with 8 forced host devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_multidevice_suite(multidevice_runner):
    out = multidevice_runner("_multidevice_checks.py", device_count=8)
    assert "ALL MULTIDEVICE CHECKS PASSED" in out
