"""Grid-resident whole-head megakernel (DESIGN.md §7, ISSUE 3).

The contract under test:

* ``impl="grid*"`` (one Pallas launch per step) is **bit-identical** to
  ``impl="fused*"`` (the PR-1 per-chunk scan) in weights, Kahan
  compensation, x̄ and — for deterministic/no-DropConnect configs — the
  loss scalar, across losses, weight dtypes, SR and Kahan.  (With
  DropConnect the loss reduction may refuse to fuse identically across the
  two programs; weights/x̄ stay bitwise, the loss is allowed 1 ULP.)
* the grid path emits exactly ONE ``pallas_call`` launch per train step
  for BCE and ≤ 2 for softmax-CE (it achieves 1: the two CE passes share
  a launch), vs O(num_chunks) on the legacy paths — counted statically by
  ``kernels/introspect.py``.
* serving (``head_logits`` / ``head_topk``) on the grid path is bit-equal
  to the streaming chunk scans, including top-k tie-breaks and padded-id
  sentinels.
* ``fused_chunk_step`` masks by the logical batch when the step level
  hands it pre-padded operands (the once-per-step pad hoist), and resolves
  ``interpret=None`` from the backend.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import elmo_head as H
from repro.core import memory_model as MM
from repro.kernels import introspect, ops, ref, tuning
from repro.kernels import fused_chunk as FC

KEY = jax.random.PRNGKey(0)


def _setup(loss, num_labels=300, d=64, B=32, num_chunks=4,
           weight_dtype="e4m3", **kw):
    cfg = H.ELMOHeadConfig(num_labels=num_labels, d_model=d,
                           num_chunks=num_chunks,
                           weight_dtype=weight_dtype, loss=loss, **kw)
    state = H.init_head(jax.random.PRNGKey(1), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(2), (B, d)) * 0.5
         ).astype(jnp.bfloat16)
    if loss == "bce":
        tg = jax.random.randint(jax.random.PRNGKey(3), (B, 5), 0,
                                num_labels)
    else:
        tg = jax.random.randint(jax.random.PRNGKey(3), (B,), -1, num_labels)
    return cfg, state, x, tg


def _run(cfg, state, x, tg, impl):
    cfg = dataclasses.replace(cfg, impl=impl)
    st2, xg, m = H.head_train_step(cfg, state, x, tg, jnp.float32(0.1),
                                   jnp.float32(1e-4), jnp.uint32(9))
    return (np.asarray(st2.w, np.float32),
            None if st2.comp is None else np.asarray(st2.comp, np.float32),
            np.asarray(xg, np.float32), float(m["loss"]))


# ---------------------------------------------------------------------------
# bitwise parity: grid == fused == unfused
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("loss", ["bce", "softmax_ce"])
@pytest.mark.parametrize("wdtype,kahan,sr", [
    ("e4m3", 0, True), ("e5m2", 0, True), ("bf16", 4, False),
    ("bf16", 0, False), ("f32", 0, True)])
def test_grid_bitwise_matches_fused_and_unfused(loss, wdtype, kahan, sr):
    cfg, state, x, tg = _setup(loss, weight_dtype=wdtype,
                               kahan_chunks=kahan, use_sr=sr)
    g = _run(cfg, state, x, tg, "grid_interpret")
    f = _run(cfg, state, x, tg, "fused_interpret")
    u = _run(cfg, state, x, tg, "unfused_xla")
    for name, a, b in (("w", g[0], f[0]), ("comp", g[1], f[1]),
                       ("xg", g[2], f[2])):
        if a is None:
            continue
        np.testing.assert_array_equal(a, b, err_msg=f"grid≠fused {name}")
    assert g[3] == pytest.approx(f[3], rel=1e-6), "grid≠fused loss"
    # the fused scan is itself the exact legacy composition — chain the
    # equality so grid ≡ unfused transitively holds on the same draw
    f_oracle = _run(cfg, state, x, tg, "fused_xla")
    np.testing.assert_array_equal(f_oracle[0], u[0])
    np.testing.assert_array_equal(f_oracle[2], u[2])
    assert f_oracle[3] == u[3]


def test_grid_dropconnect_weights_bitwise():
    """DropConnect: masks hash identically (weights/x̄ bitwise); the loss
    scalar is allowed 1 ULP of reduction-fusion noise."""
    for loss in ("bce", "softmax_ce"):
        cfg, state, x, tg = _setup(loss, drop_rate=0.3)
        g = _run(cfg, state, x, tg, "grid_interpret")
        f = _run(cfg, state, x, tg, "fused_interpret")
        np.testing.assert_array_equal(g[0], f[0])
        np.testing.assert_array_equal(g[2], f[2])
        assert g[3] == pytest.approx(f[3], rel=1e-6)


def test_grid_cache_z_invariant_and_boundary():
    """CE cached-z on/off/auto around the budget boundary: identical steps
    on the grid path (the cache is exact logits reuse, grid-resident)."""
    cfg, state, x, tg = _setup("softmax_ce", weight_dtype="bf16",
                               use_sr=False)
    zbytes = x.shape[0] * cfg.padded_labels * 2
    orig = H._CACHE_Z_BYTES
    outs = {}
    try:
        for side, budget in (("lo", zbytes - 1), ("hi", zbytes + 1)):
            H._CACHE_Z_BYTES = budget
            for mode in ("on", "off", "auto"):
                c = dataclasses.replace(cfg, cache_z=mode)
                outs[(side, mode)] = _run(c, state, x, tg, "grid_interpret")
    finally:
        H._CACHE_Z_BYTES = orig
    base = outs[("lo", "on")]
    for k, o in outs.items():
        np.testing.assert_array_equal(base[0], o[0], err_msg=str(k))
        np.testing.assert_array_equal(base[2], o[2], err_msg=str(k))
        assert base[3] == o[3], k


def test_grid_mixed_kahan_falls_back_to_fused():
    """The mixed Kahan hybrid (0 < ck < C) keeps the per-chunk scan — and
    the dispatch produces identical results either way."""
    cfg, state, x, tg = _setup("bce", weight_dtype="bf16", kahan_chunks=2,
                               use_sr=False)
    g = _run(cfg, state, x, tg, "grid_interpret")
    f = _run(cfg, state, x, tg, "fused_interpret")
    np.testing.assert_array_equal(g[0], f[0])
    np.testing.assert_array_equal(g[1], f[1])


# ---------------------------------------------------------------------------
# launch counts (ISSUE 3 acceptance: 1 BCE, ≤2 CE vs L/chunk legacy)
# ---------------------------------------------------------------------------


def _launches(impl, loss, cache_z="auto"):
    cfg, state, x, tg = _setup(loss, cache_z=cache_z)
    cfg = dataclasses.replace(cfg, impl=impl)
    return introspect.count_pallas_launches(
        lambda s, xx, t: H.head_train_step(cfg, s, xx, t, jnp.float32(0.1),
                                           jnp.float32(1e-4),
                                           jnp.uint32(9)),
        state, x, tg)


def test_grid_single_launch_bce():
    assert _launches("grid_interpret", "bce") == 1
    assert _launches("fused_interpret", "bce") == 4          # 1 per chunk


@pytest.mark.parametrize("cache_z", ["on", "off"])
def test_grid_launches_softmax_ce(cache_z):
    n = _launches("grid_interpret", "softmax_ce", cache_z)
    assert n <= 2, n          # acceptance bound; the 2-pass grid achieves 1
    assert n == 1
    # legacy: LSE pre-pass + update pass, one launch per chunk each
    assert _launches("fused_interpret", "softmax_ce", cache_z) == 8


def test_grid_serving_single_launch():
    cfg, state, x, _ = _setup("bce")
    cfg = dataclasses.replace(cfg, impl="grid_interpret")
    assert introspect.count_pallas_launches(
        lambda s, xx: H.head_logits(cfg, s, xx), state, x) == 1
    assert introspect.count_pallas_launches(
        lambda s, xx: H.head_topk(cfg, s, xx, 5)[0], state, x) == 1


def test_introspect_counts_scan_multiplicity():
    """A pallas_call inside a scan counts trip-count times."""
    def f(x):
        def body(c, _):
            return c + ops.sr_cast_2d(c, jnp.uint32(3),
                                      out_dtype=jnp.bfloat16,
                                      impl="interpret"
                                      ).astype(jnp.float32), None
        out, _ = jax.lax.scan(body, x, None, length=5)
        return out

    x = jnp.ones((8, 8), jnp.float32)
    assert introspect.count_pallas_launches(f, x) == 5


# ---------------------------------------------------------------------------
# serving parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("num_labels,num_chunks", [(300, 4), (513, 8),
                                                   (5, 2), (260, 2)])
def test_grid_serving_bitwise(num_labels, num_chunks):
    cfg, state, x, _ = _setup("bce", num_labels=num_labels, d=32, B=4,
                              num_chunks=num_chunks, weight_dtype="bf16",
                              use_sr=False)
    grid = dataclasses.replace(cfg, impl="grid_interpret")
    scan = dataclasses.replace(cfg, impl="fused_xla")
    np.testing.assert_array_equal(
        np.asarray(H.head_logits(grid, state, x), np.float32),
        np.asarray(H.head_logits(scan, state, x), np.float32))
    # k beyond the valid label count: overflow slots must reproduce the
    # streaming scan's (NEG_INF, id 0) sentinels, not padded label ids
    k = min(num_labels + 40, cfg.padded_labels)
    vg, ig = H.head_topk(grid, state, x, k)
    vf, if_ = H.head_topk(scan, state, x, k)
    np.testing.assert_array_equal(np.asarray(vg), np.asarray(vf))
    np.testing.assert_array_equal(np.asarray(ig), np.asarray(if_))
    assert (np.asarray(ig) < num_labels).all()


def test_grid_topk_budget_fallback():
    """Past the z budget the grid path streams — same results."""
    cfg, state, x, _ = _setup("bce", num_labels=300, d=32, B=4,
                              weight_dtype="bf16", use_sr=False)
    grid = dataclasses.replace(cfg, impl="grid_interpret")
    orig = H._TOPK_Z_BYTES
    try:
        H._TOPK_Z_BYTES = 0
        v1, i1 = H.head_topk(grid, state, x, 7)
    finally:
        H._TOPK_Z_BYTES = orig
    v2, i2 = H.head_topk(grid, state, x, 7)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


# ---------------------------------------------------------------------------
# pad hoist (satellite): logical-dim masking + backend interpret default
# ---------------------------------------------------------------------------


def test_fused_chunk_prepadded_matches_unpadded():
    """Manually pre-padded operands + n_b/n_l give the unpadded results in
    the valid region and zero gradient in the padding."""
    B, Lc, D, P = 12, 40, 24, 4
    kx, kw, kt, kg = jax.random.split(KEY, 4)
    x = (jax.random.normal(kx, (B, D)) * 0.5).astype(jnp.bfloat16)
    w = (jax.random.normal(kw, (Lc, D)) * 0.05).astype(jnp.bfloat16)
    xg = jnp.zeros((B, D), jnp.bfloat16)
    tg = jax.random.randint(kt, (B, P), 0, Lc)
    hp = (jnp.float32(0.05), jnp.float32(1e-4), jnp.float32(1.0 / B),
          jnp.int32(0), jnp.uint32(7), jnp.uint32(13))
    kw_ = dict(loss="bce", num_labels=Lc, use_sr=False)
    ref_out = ops.fused_chunk_step(x, w, tg, xg, *hp, impl="interpret",
                                   **kw_)
    Bp = B + 6
    xp = tuning.pad2(x, Bp, D)
    xgp = tuning.pad2(xg, Bp, D)
    tp = tuning.pad2(tg, Bp, 1, value=-1)
    pad_out = ops.fused_chunk_step(xp, w, tp, xgp, *hp, impl="interpret",
                                   n_b=B, **kw_)
    np.testing.assert_array_equal(np.asarray(pad_out.w, np.float32),
                                  np.asarray(ref_out.w, np.float32))
    np.testing.assert_array_equal(
        np.asarray(pad_out.xg[:B], np.float32),
        np.asarray(ref_out.xg, np.float32))
    assert (np.asarray(pad_out.xg[B:], np.float32) == 0).all()
    assert float(pad_out.loss) == float(ref_out.loss)


def test_fused_path_pads_once_per_step():
    """The scan bodies of the compiled fused path must contain no pad of
    the step-invariant operands (x/x̄/targets/LSE — anything with a leading
    batch dim): their alignment happens once at the step level.  Only the
    *scanned* W chunk (leading dim = chunk rows) may still pad per
    iteration, since each iteration pads different data."""
    B = 30
    cfg, state, x, tg = _setup("bce", B=B, d=60)   # unaligned B and D
    cfg = dataclasses.replace(cfg, impl="fused_kernel")

    jaxpr = jax.make_jaxpr(
        lambda s, xx, t: H.head_train_step(cfg, s, xx, t, jnp.float32(0.1),
                                           jnp.float32(1e-4),
                                           jnp.uint32(9)))(state, x, tg)

    def in_scan_pad_shapes(jx, in_scan=False, acc=None):
        acc = [] if acc is None else acc
        for eqn in jx.eqns:
            scan = eqn.primitive.name == "scan"
            if in_scan and eqn.primitive.name == "pad":
                acc.append(eqn.invars[0].aval.shape)
            for sub in introspect._sub_jaxprs(eqn.params):
                in_scan_pad_shapes(sub, in_scan or scan, acc)
        return acc

    shapes = in_scan_pad_shapes(jaxpr.jaxpr)
    batchy = [s for s in shapes
              if s and s[0] in (B, tuning._pad_up(B, 16))]
    assert not batchy, shapes
    assert all(s[0] == cfg.chunk for s in shapes), shapes


def test_interpret_default_resolves_from_backend():
    """interpret=None (the new wrapper default) must resolve from the
    backend — True everywhere but TPU — not from a hardcoded keyword."""
    assert tuning.interpret_default(None) == \
        (jax.default_backend() != "tpu")
    assert tuning.interpret_default(True) is True
    assert tuning.interpret_default(False) is False
    # and the wrappers accept the default on this backend
    x = jnp.ones((4, 8), jnp.bfloat16)
    w = jnp.ones((16, 8), jnp.bfloat16) * 0.1
    z = FC.fused_chunk_step(
        x, w, jnp.zeros((4, 2), jnp.int32), jnp.zeros((4, 8), jnp.bfloat16),
        jnp.float32(0.1), jnp.float32(0.0), jnp.float32(0.25),
        jnp.int32(0), jnp.uint32(0), jnp.uint32(1), loss="bce",
        num_labels=16, use_sr=False)
    assert z.w.shape == (16, 8)


# ---------------------------------------------------------------------------
# tuner + memory model
# ---------------------------------------------------------------------------


def test_head_grid_tuner_prefers_whole_chunk():
    assert tuning.head_grid_block_l(256, 512, 256) == 512
    bl = tuning.head_grid_block_l(256, 4096, 256)
    assert 4096 % bl == 0 or bl >= 4096
    # the grid kernel's persistent set mirrors the chunk kernel's gate
    assert tuning.fused_head_viable(256, 256)
    assert not tuning.fused_head_viable(8192 * 4, 1024)
    # asking for the grid-resident z cache costs VMEM: the viability gate
    # must notice a cache that cannot fit
    assert not tuning.fused_head_viable(1024, 256, cache_z=True,
                                        lc=4096, n_chunks=8)


def test_memory_model_grid_transients():
    """The grid cost model shrinks the logit/grad transients from the
    chunk width to the label-block width."""
    s = MM.MemScenario(num_labels=2_812_281, d_model=768, batch=128,
                       num_chunks=8)
    full = MM.head_components(s, "e4m3")
    grid = MM.head_components(s, "e4m3", grid_block_l=512)
    assert grid["chunk_logits_bf16"] < full["chunk_logits_bf16"]
    assert grid["total"] < full["total"]
    assert grid["grid_resident_bf16"] > 0
    # weight terms are untouched by the execution schedule
    assert grid["W_e4m3"] == full["W_e4m3"]
