"""Roofline model validation.

1. Documents the scan-body-once behaviour of XLA cost_analysis (the reason
   the roofline is analytic).
2. Validates the analytic FLOPs model against compiled cost_analysis on a
   config whose loops are all trip-1 (XLA inlines those, so counters are
   exact).
3. Sanity properties of the full table.
"""
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import roofline as R                       # noqa: E402
from repro.configs.registry import SHAPES, ShapeCell       # noqa: E402
from repro.launch import steps as St                       # noqa: E402
from repro.models import transformer as T                  # noqa: E402
from repro.dist import compat
from repro.models.config import BlockSpec, ModelConfig     # noqa: E402


def test_cost_analysis_counts_scan_body_once():
    """The documented premise: while bodies are visited once."""
    def f_scan(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=8)
        return out

    x = jnp.ones((64, 128))
    w = jnp.ones((128, 128))
    c = compat.cost_analysis(jax.jit(f_scan).lower(x, w).compile())
    one_iter = 2 * 64 * 128 * 128
    assert c["flops"] < 2 * one_iter, c["flops"]   # ≪ 8 iterations


def _tiny_cfg():
    """All loops trip-1: 1 period, 1 head chunk, S ≤ one attention block."""
    return ModelConfig(
        name="tiny-dense", n_layers=1, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, pattern=(BlockSpec(kind="attn", ffn="swiglu"),),
        head_chunks=1, head_weight_dtype="bf16")


def test_analytic_fwd_flops_matches_compiled():
    cfg = _tiny_cfg()
    B, S = 4, 64
    bb = T.backbone_init(jax.random.PRNGKey(0), cfg)
    toks = jnp.zeros((B, S), jnp.int32)

    comp = jax.jit(
        lambda bb, t: T.backbone_apply(bb, cfg, t, remat=False)
    ).lower(bb, toks).compile()
    measured = compat.cost_analysis(comp)["flops"]

    f = R.fwd_flops(cfg, B * S, S)
    analytic = sum(f.values())
    # within 40%: cost_analysis includes norms/softmax; we count matmuls
    assert 0.6 * measured < analytic < 1.6 * measured, (analytic, measured)


def test_roofline_table_sane():
    rows = R.full_table()
    assert len(rows) >= 33           # live LM cells + xmc cells
    for r in rows:
        assert r["compute_s"] > 0 and r["memory_s"] > 0
        assert r["dominant"] in ("compute", "memory", "collective")
        # 6·N·D (the spec's MODEL_FLOPS) counts embedding params as matmul
        # work, so embedding-heavy small models (xlstm: 77M of 125M params
        # are embed+head) can exceed 1 — bounded, and documented in
        # EXPERIMENTS.md §Roofline
        assert r["useful_ratio"] < 1.6, r
        if r["shape"] == "train_4k":
            assert r["useful_ratio"] > 0.2, r


def test_model_flops_moe_uses_active_params():
    pc_moe = R.param_counts(__import__("repro.configs", fromlist=["x"]
                                       ).get_config("mixtral-8x7b"))
    # 8×7b: total ≈ 47B, active ≈ top2/8 of experts + shared ≈ 13B
    assert 40e9 < pc_moe["total"] < 60e9, pc_moe["total"]
    assert 10e9 < pc_moe["active"] < 16e9, pc_moe["active"]


def test_param_counts_match_eval_shape():
    """Analytic param counts vs actual initialized trees (dense + hybrid)."""
    from repro.configs import get_config
    for arch, tol in (("smollm-360m", 0.05), ("gemma-7b", 0.05),
                      ("hymba-1.5b", 0.15), ("xlstm-125m", 0.15)):
        cfg = get_config(arch)
        abs_bb = jax.eval_shape(
            lambda k: T.backbone_init(k, cfg), jax.random.PRNGKey(0))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(abs_bb))
        pc = R.param_counts(cfg)
        analytic_backbone = pc["total"] - pc["head"]
        assert abs(actual - analytic_backbone) / actual < tol, \
            (arch, actual, analytic_backbone)


def test_sliding_window_cuts_attention_flops():
    swa = R._attn_core_flops(32768 * 32, 32768, 32, 128, 4096, True)
    full = R._attn_core_flops(32768 * 32, 32768, 32, 128, None, True)
    assert swa < 0.25 * full
