"""Golden-loss regression: 20 steps of ``launch.train`` on the smoke config
with pinned seeds, against a committed trajectory.

Every source of randomness is pinned (model/head init PRNGKey(0), data
cursor seed 1234, hash-based SR bits keyed off the step counter), so on a
fixed backend the trajectory is bit-reproducible — the committed goldens
were generated on the CPU backend with ``impl="xla"``.  The per-step
tolerance absorbs backend/BLAS reduction-order differences while still
catching silent numeric drift from future kernel changes (any algorithmic
change to the head step moves the loss at the 1e-1 scale within a few
steps; observed cross-run noise is 0)."""
import json
import os

import numpy as np
import pytest

from repro.configs import get_smoke
from repro.launch.train import train

_GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "goldens", "train_smollm_360m_smoke.json")


def _run_against_goldens(impl):
    with open(_GOLDEN) as f:
        golden = json.load(f)
    r = golden["recipe"]
    cfg = get_smoke("smollm-360m")
    _, losses = train(cfg, steps=r["steps"], global_batch=r["global_batch"],
                      seq=r["seq"], ckpt_dir="", impl=impl,
                      head_lr=r["head_lr"], backbone_lr=r["backbone_lr"],
                      log_every=100)
    assert len(losses) == len(golden["loss"])
    np.testing.assert_allclose(np.asarray(losses),
                               np.asarray(golden["loss"]),
                               rtol=2e-2, atol=1e-3)
    # the trajectory mean is a tighter invariant than any single step
    assert np.mean(losses) == pytest.approx(np.mean(golden["loss"]),
                                            rel=5e-3)


def test_train_loss_matches_goldens():
    with open(_GOLDEN) as f:
        golden = json.load(f)
    _run_against_goldens(golden["recipe"]["impl"])


def test_train_loss_matches_goldens_grid_path():
    """The whole-head grid megakernel (ISSUE 3) must reproduce the same
    20-step trajectory the committed goldens pin — the per-step tolerance
    absorbs the interpret-vs-xla backend reduction-order ULPs (observed
    deviation ~5e-7)."""
    _run_against_goldens("grid_interpret")
