"""Per-kernel tests: Pallas (interpret=True) vs pure-jnp oracle, shape/dtype
sweeps, and statistical properties of the in-kernel SR path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precision as P
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)

SHAPES_MM = [  # (B, L, D)
    (8, 16, 32),
    (128, 256, 256),      # exactly one block
    (64, 300, 130),       # ragged → padding path
    (256, 512, 384),      # multi-block all dims
    (1, 7, 9),            # degenerate tiny
]


def _rand(key, shape, dtype=jnp.bfloat16, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# sr_cast
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("out_dtype", [P.BF16, P.E4M3])
@pytest.mark.parametrize("shape", [(8, 8), (256, 256), (100, 300), (1, 513)])
def test_sr_cast_kernel_matches_ref(shape, out_dtype):
    x = jax.random.normal(KEY, shape, jnp.float32)
    seed = jnp.uint32(1234)
    k = ops.sr_cast_2d(x, seed, out_dtype=out_dtype, impl="interpret")
    r = ref.sr_cast_2d_ref(x, seed, out_dtype=out_dtype)
    np.testing.assert_array_equal(np.asarray(k, np.float32),
                                  np.asarray(r, np.float32))


def test_sr_cast_kernel_tiling_invariance():
    """Same bits regardless of block size (hash is global-index based)."""
    x = jax.random.normal(KEY, (512, 512), jnp.float32)
    seed = jnp.uint32(7)
    a = ops.sr_cast_2d(x, seed, out_dtype=P.E4M3, impl="interpret",
                       block=(128, 128))
    b = ops.sr_cast_2d(x, seed, out_dtype=P.E4M3, impl="interpret",
                       block=(256, 512))
    np.testing.assert_array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))


def test_sr_cast_unbiased_through_kernel():
    x = jnp.full((64, 128), 0.0123, jnp.float32)
    outs = []
    for s in range(64):
        outs.append(np.asarray(
            ops.sr_cast_2d(x, jnp.uint32(s), out_dtype=P.E4M3,
                           impl="interpret"), np.float32))
    mean = np.stack(outs).mean()
    assert abs(mean - 0.0123) < 0.0123 * 0.05, mean


# ---------------------------------------------------------------------------
# fp8 matmuls
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,L,D", SHAPES_MM)
@pytest.mark.parametrize("w_dtype", [P.E4M3, P.BF16])
def test_fp8_logits_matches_ref(B, L, D, w_dtype):
    kx, kw = jax.random.split(KEY)
    x = _rand(kx, (B, D))
    w = _rand(kw, (L, D), w_dtype, scale=0.05)
    k = ops.fp8_logits(x, w, impl="interpret")
    r = ref.fp8_logits_ref(x, w)
    np.testing.assert_allclose(np.asarray(k, np.float32),
                               np.asarray(r, np.float32), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("B,L,D", SHAPES_MM)
def test_fp8_input_grad_matches_ref(B, L, D):
    kg, kw = jax.random.split(KEY, 2)
    g = _rand(kg, (B, L), scale=0.1)
    w = _rand(kw, (L, D), P.E4M3, scale=0.05)
    k = ops.fp8_input_grad(g, w, impl="interpret")
    r = ref.fp8_input_grad_ref(g, w)
    np.testing.assert_allclose(np.asarray(k, np.float32),
                               np.asarray(r, np.float32), rtol=2e-2, atol=2e-2)


def test_fp8_logits_vs_f32_oracle():
    """Against a plain f32 matmul: fp8 quantization error stays bounded."""
    kx, kw = jax.random.split(KEY)
    x = _rand(kx, (64, 256))
    w = _rand(kw, (128, 256), P.E4M3, scale=0.05)
    z = np.asarray(ops.fp8_logits(x, w, impl="interpret"), np.float32)
    z32 = np.asarray(x.astype(jnp.float32)) @ np.asarray(
        w.astype(jnp.float32)).T
    # e4m3 has ~2^-3 relative mantissa error on x; matmul averages it down
    rel = np.abs(z - z32) / (np.abs(z32) + 1e-2)
    assert np.median(rel) < 0.05, np.median(rel)


def test_dropconnect_in_kernel():
    kx, kw = jax.random.split(KEY)
    x = _rand(kx, (32, 128))
    w = _rand(kw, (64, 128), P.E4M3, scale=0.05)
    seed = jnp.uint32(99)
    k = ops.fp8_logits(x, w, seed, drop_rate=0.5, impl="interpret")
    r = ref.fp8_logits_ref(x, w, seed, drop_rate=0.5)
    np.testing.assert_allclose(np.asarray(k, np.float32),
                               np.asarray(r, np.float32), rtol=2e-2, atol=2e-2)
    # masks differ with a different seed
    k2 = ops.fp8_logits(x, w, jnp.uint32(100), drop_rate=0.5, impl="interpret")
    assert not np.allclose(np.asarray(k, np.float32),
                           np.asarray(k2, np.float32))
    # E[dropconnect logits] ≈ plain logits (inverted scaling)
    acc = np.zeros((32, 64), np.float32)
    for s in range(48):
        acc += np.asarray(ops.fp8_logits(x, w, jnp.uint32(s), drop_rate=0.5,
                                         impl="interpret"), np.float32)
    plain = np.asarray(ops.fp8_logits(x, w, impl="interpret"), np.float32)
    err = np.abs(acc / 48 - plain)
    # σ of the 48-draw mean is ≈ 0.081 here; median |err| ≈ 0.054 (≈ 0.67 σ)
    assert np.median(err) < 0.25 * (np.median(np.abs(plain)) + 0.1)


# ---------------------------------------------------------------------------
# fused head update
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,L,D", SHAPES_MM)
@pytest.mark.parametrize("w_dtype", [P.E4M3, P.BF16])
def test_fused_update_matches_ref(B, L, D, w_dtype):
    kg, kx, kw = jax.random.split(KEY, 3)
    g = _rand(kg, (B, L), scale=0.1)
    x = _rand(kx, (B, D))
    w = _rand(kw, (L, D), w_dtype, scale=0.05)
    lr, wd, seed = jnp.float32(0.05), jnp.float32(1e-4), jnp.uint32(11)
    k = ops.fused_head_update(g, x, w, lr, wd, seed, impl="interpret")
    r = ref.fused_head_update_ref(g, x, w, lr, wd, seed)
    assert k.dtype == w.dtype
    # bitwise-identical only when no padding splits the reduction; allow a
    # one-ulp SR disagreement from bf16 accumulation-order differences
    kf, rf = np.asarray(k, np.float32), np.asarray(r, np.float32)
    mism = np.mean(kf != rf)
    assert mism < 0.02, mism
    np.testing.assert_allclose(kf, rf, rtol=0.3, atol=0.05)


def test_fused_update_no_sr_deterministic():
    kg, kx, kw = jax.random.split(KEY, 3)
    g = _rand(kg, (128, 256), scale=0.1)
    x = _rand(kx, (128, 256))
    w = _rand(kw, (256, 256), P.BF16, scale=0.05)
    lr, wd = jnp.float32(0.01), jnp.float32(0.0)
    k = ops.fused_head_update(g, x, w, lr, wd, jnp.uint32(0), use_sr=False,
                              impl="interpret")
    # plain f32 oracle
    dw = np.asarray(g, np.float32).T @ np.asarray(x, np.float32)
    w_new = np.asarray(w, np.float32) - 0.01 * dw
    np.testing.assert_allclose(np.asarray(k, np.float32), w_new,
                               rtol=2e-2, atol=2e-2)


def test_fused_update_moves_weights_despite_tiny_update():
    """The paper's point: SR lets tiny updates make progress in fp8."""
    L, D, B = 256, 256, 128
    w = jnp.full((L, D), 0.5, P.E4M3)  # grid step at 0.5 is 2^-4 = 0.0625
    g = jnp.full((B, L), 1e-3, jnp.bfloat16)
    x = jnp.full((B, D), 1e-2, jnp.bfloat16)
    lr = jnp.float32(0.1)  # update = -lr * B * 1e-5 = -1.28e-4 << ulp
    stepped = []
    for s in range(16):
        w_new = ops.fused_head_update(g, x, w, lr, jnp.float32(0),
                                      jnp.uint32(s), impl="interpret")
        stepped.append(np.asarray(w_new, np.float32))
    mean_w = np.stack(stepped).mean()
    # RN would leave all weights at exactly 0.5; SR moves the mean down
    assert mean_w < 0.5 - 1e-5, mean_w
    rn = ops.fused_head_update(g, x, w, lr, jnp.float32(0), jnp.uint32(0),
                               use_sr=False, impl="interpret")
    assert np.all(np.asarray(rn, np.float32) == 0.5)


def test_fused_update_kahan_matches_ref():
    kg, kx, kw = jax.random.split(KEY, 3)
    g = _rand(kg, (128, 128), scale=0.1)
    x = _rand(kx, (128, 128))
    w = _rand(kw, (128, 128), P.BF16, scale=0.05)
    c = jnp.zeros((128, 128), P.BF16)
    lr, wd, seed = jnp.float32(0.05), jnp.float32(0.0), jnp.uint32(3)
    kw_, kc_ = ops.fused_head_update_kahan(g, x, w, c, lr, wd, seed,
                                           impl="interpret")
    rw_, rc_ = ref.fused_head_update_kahan_ref(g, x, w, c, lr, wd, seed)
    np.testing.assert_allclose(np.asarray(kw_, np.float32),
                               np.asarray(rw_, np.float32), rtol=2e-2,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(kc_, np.float32),
                               np.asarray(rc_, np.float32), rtol=0.5,
                               atol=1e-3)


def test_fused_update_kahan_accumulates_tiny_updates():
    """Kahan hybrid (App. D): tiny deterministic updates accumulate in bf16."""
    L = D = 128
    w = jnp.full((L, D), 1.0, P.BF16)
    c = jnp.zeros((L, D), P.BF16)
    g = jnp.full((8, L), 1e-2, jnp.bfloat16)
    x = jnp.full((8, D), -1e-2, jnp.bfloat16)  # dW = -8e-4, upd = +8e-5/step
    lr = jnp.float32(0.1)
    for s in range(100):
        w, c = ops.fused_head_update_kahan(g, x, w, c, lr, jnp.float32(0),
                                           jnp.uint32(s), impl="interpret")
    target = 1.0 + 100 * 8e-4 * 0.1
    assert abs(float(w[0, 0].astype(jnp.float32)) - target) < 3e-3
