"""Per-architecture smoke tests (task spec f): REDUCED same-family config,
one forward/train step on CPU, asserting output shapes + no NaNs; plus a
prefill→decode consistency pass for decoder archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke
from repro.launch import steps as St
from repro.models import transformer as T
from repro.optim import kahan_adamw

ALL = list(ARCHS)
DECODERS = [a for a in ALL if not a.startswith("xmc-")]


def _batch(cfg, B=2, S=16, key=jax.random.PRNGKey(0)):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab)}
    if cfg.head_labels:
        batch["targets"] = jax.random.randint(ks[1], (B, 5), 0,
                                              cfg.head_labels)
    else:
        batch["targets"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab)
    if cfg.frontend == "audio_frames":
        batch["frontend_embeds"] = jax.random.normal(
            ks[2], (B, S, 512), jnp.bfloat16)
    elif cfg.frontend == "vision":
        batch["frontend_embeds"] = jax.random.normal(
            ks[2], (B, cfg.n_frontend_tokens, 1280), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ALL)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    cfg.validate()
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    opt = kahan_adamw(weight_decay=0.0)
    state = St.init_train_state(jax.random.PRNGKey(1), cfg, opt, impl="xla")

    hidden = T.backbone_apply(state.backbone, cfg, batch["tokens"],
                              batch.get("frontend_embeds"))
    assert hidden.shape == (B, S, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, np.float32)).all()

    new_state, metrics = St.train_step(
        cfg, opt, state, batch, head_lr=jnp.float32(0.05),
        backbone_lr=jnp.float32(1e-3), impl="xla")
    assert np.isfinite(float(metrics["loss"])), arch
    assert int(new_state.step) == 1
    # some parameters actually moved (embed may be untouched for stub
    # frontends whose inputs bypass the token embedding)
    moved = any(
        not np.array_equal(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(state.backbone),
                        jax.tree.leaves(new_state.backbone)))
    assert moved, arch


@pytest.mark.parametrize("arch", DECODERS)
def test_smoke_prefill_then_decode(arch):
    cfg = get_smoke(arch)
    B, S = 2, 12
    batch = _batch(cfg, B, S)
    sstate = St.init_serve_state(jax.random.PRNGKey(2), cfg, B,
                                 max_len=S + 8, impl="xla")
    tok, sstate = St.serve_prefill(cfg, sstate, batch["tokens"],
                                   batch.get("frontend_embeds"), impl="xla")
    assert tok.shape == (B,)
    assert np.asarray(tok).max() < cfg.vocab
    fe = None
    if cfg.frontend == "audio_frames":
        fe = jnp.zeros((B, 1, 512), jnp.bfloat16)
    elif cfg.frontend == "vision":
        fe = batch["frontend_embeds"]
    for _ in range(3):
        tok, sstate = St.serve_decode(cfg, sstate, tok[:, None], fe,
                                      impl="xla")
        assert tok.shape == (B,)
        assert np.asarray(tok).max() < cfg.vocab


@pytest.mark.parametrize("arch", ["smollm-360m", "xlstm-125m"])
def test_decode_matches_training_forward(arch):
    """Greedy decode logits == training forward logits at the same prefix
    (recurrent-state and KV-cache paths agree with the parallel path)."""
    cfg = get_smoke(arch)
    B, S = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    sstate = St.init_serve_state(jax.random.PRNGKey(2), cfg, B, max_len=S + 4,
                                 impl="xla")
    # training-style forward on the full prefix
    hidden = T.backbone_apply(sstate.backbone, cfg, tokens)
    # stateful prefill on the same prefix
    tok_p, sstate2 = St.serve_prefill(cfg, sstate, tokens)
    hcfg = St.make_head_cfg(cfg, impl="xla")
    from repro.core import elmo_head as EH
    _, topk_train = EH.head_topk(hcfg, sstate.head, hidden[:, -1, :], k=1)
    assert int(tok_p[0]) == int(topk_train[0, 0])
