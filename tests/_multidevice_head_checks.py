"""Label-sharded head checks, run in a subprocess with a forced host-device
count (default 4; tests/test_sharded_head.py drives this via the
``multidevice_runner`` fixture).  Exit code 0 = all checks passed.

The contract under test (DESIGN.md §6, ISSUE 2 acceptance):

* ``head_train_step_sharded`` on 1×4 and 2×2 meshes is **bit-identical** to
  single-device ``head_train_step`` in weights, Kahan compensation and loss
  for deterministic updates (BF16 + Kahan, no SR) with ``ce_comm="gather"``.
* x̄ matches to BF16 accumulation-order tolerance (the per-shard partials
  are psum-reduced in f32; single-device rounds to BF16 between chunks).
* SR / FP8 runs match distributionally (per-shard SR streams are
  independent by design — the paper's own App. C guarantee).
* ``head_logits_sharded`` / ``head_topk_sharded`` are bit-identical
  (values *and* ids) to the local paths.
* ``launch.steps.train_step`` picks the sharded head under an ambient mesh
  and reproduces the single-device loss.
"""
import os

_N_DEV = int(os.environ.get("REPRO_FORCE_DEVICES", "4"))
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + f" --xla_force_host_platform_device_count={_N_DEV}")

import jax                      # noqa: E402
import jax.numpy as jnp        # noqa: E402
import numpy as np             # noqa: E402

from repro.configs import get_smoke                  # noqa: E402
from repro.core import elmo_head as H                # noqa: E402
from repro.dist import meshctx                       # noqa: E402
from repro.launch import steps as St                 # noqa: E402
from repro.launch.mesh import make_host_mesh         # noqa: E402
from repro.optim import kahan_adamw                  # noqa: E402

assert len(jax.devices()) == _N_DEV, jax.devices()

B, D, NL = 16, 32, 1000        # chunk=256, 4 chunks, 24 padded columns


def _mk(loss, wdtype, kahan, use_sr, impl="unfused_xla"):
    cfg = H.ELMOHeadConfig(num_labels=NL, d_model=D, num_chunks=4,
                           weight_dtype=wdtype, loss=loss, use_sr=use_sr,
                           kahan_chunks=kahan, impl=impl)
    st = H.init_head(jax.random.PRNGKey(0), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(1), (B, D)) * 0.5
         ).astype(jnp.bfloat16)
    shape = (B, 8) if loss == "bce" else (B,)
    tgt = jax.random.randint(jax.random.PRNGKey(2), shape, 0, NL)
    return cfg, st, x, tgt


_HYPERS = (jnp.float32(0.05), jnp.float32(1e-4), jnp.uint32(7))


def _single(cfg, st, x, tgt):
    return jax.jit(lambda s, x, t: H.head_train_step(
        cfg, s, x, t, *_HYPERS))(st, x, tgt)


def _sharded(cfg, st, x, tgt, mesh_shape, **kw):
    ctx = make_host_mesh(*mesh_shape)
    with meshctx.use(ctx):
        return jax.jit(lambda s, x, t: H.head_train_step_sharded(
            cfg, s, x, t, *_HYPERS, **kw))(st, x, tgt)


def _f32(a):
    return np.asarray(a, np.float32)


def check_bit_parity_deterministic():
    """BF16 + Kahan, no SR: weights, comp and loss bit-identical on every
    mesh factorization of the 4 forced devices."""
    for loss in ("bce", "softmax_ce"):
        cfg, st, x, tgt = _mk(loss, "bf16", kahan=4, use_sr=False)
        st1, xg1, m1 = _single(cfg, st, x, tgt)
        for mesh_shape in ((1, 4), (2, 2), (4, 1)):
            stS, xgS, mS = _sharded(cfg, st, x, tgt, mesh_shape)
            assert (_f32(st1.w) == _f32(stS.w)).all(), (loss, mesh_shape)
            assert (_f32(st1.comp) == _f32(stS.comp)).all(), \
                (loss, mesh_shape)
            assert float(m1["loss"]) == float(mS["loss"]), \
                (loss, mesh_shape, float(m1["loss"]), float(mS["loss"]))
            np.testing.assert_allclose(_f32(xg1), _f32(xgS),
                                       rtol=5e-2, atol=2e-3)
    print("bit parity (bf16/kahan) ok")


def check_stats_lse_close():
    """O(B)-comm pmax/psum LSE: same result to f32 reassociation error."""
    cfg, st, x, tgt = _mk("softmax_ce", "bf16", kahan=4, use_sr=False)
    st1, xg1, m1 = _single(cfg, st, x, tgt)
    stS, xgS, mS = _sharded(cfg, st, x, tgt, (1, 4), ce_comm="stats")
    np.testing.assert_allclose(_f32(st1.w), _f32(stS.w), rtol=1e-5,
                               atol=1e-5)
    assert abs(float(m1["loss"]) - float(mS["loss"])) \
        < 1e-4 * abs(float(m1["loss"]))
    print("stats LSE ok")


def check_sr_fp8_distributional():
    """E4M3 + SR: per-shard SR streams are independent, so trajectories
    differ — but the loss and the update *statistics* must agree."""
    for wdtype in ("e4m3", "e5m2"):
        cfg, st, x, tgt = _mk("bce", wdtype, kahan=0, use_sr=True)
        st1, _, m1 = _single(cfg, st, x, tgt)
        stS, _, mS = _sharded(cfg, st, x, tgt, (1, 4))
        # loss is computed from pre-update weights: identical logits path
        assert abs(float(m1["loss"]) - float(mS["loss"])) \
            < 1e-3 * abs(float(m1["loss"])), wdtype
        d1 = _f32(st1.w) - _f32(st.w)
        dS = _f32(stS.w) - _f32(st.w)
        assert abs(d1.mean() - dS.mean()) < 5e-5, wdtype
        assert abs(d1.std() - dS.std()) < 0.3 * max(d1.std(), 1e-8), wdtype
    print("SR/FP8 distributional ok")


def check_serving_bit_parity():
    cfg, st, x, _ = _mk("bce", "bf16", kahan=0, use_sr=False)
    z1 = H.head_logits(cfg, st, x)
    v1, i1 = H.head_topk(cfg, st, x, 10)
    for mesh_shape in ((1, 4), (2, 2)):
        ctx = make_host_mesh(*mesh_shape)
        with meshctx.use(ctx):
            zS = jax.jit(lambda s, x: H.head_logits_sharded(cfg, s, x)
                         )(st, x)
            vS, iS = jax.jit(lambda s, x: H.head_topk_sharded(cfg, s, x, 10)
                             )(st, x)
        assert (_f32(z1) == _f32(zS)).all(), mesh_shape
        assert (_f32(v1) == _f32(vS)).all(), mesh_shape
        assert (np.asarray(i1) == np.asarray(iS)).all(), mesh_shape
        assert (np.asarray(iS) < NL).all(), mesh_shape   # no padded ids
    print("sharded serving ok")


def check_topk_padding_sharded():
    """k larger than the valid label count: padded columns must never
    surface from any shard (they are masked on the local window)."""
    cfg = H.ELMOHeadConfig(num_labels=260, d_model=D, num_chunks=2,
                           weight_dtype="bf16", use_sr=False,
                           impl="unfused_xla")   # chunk=256, 252 padded
    st = H.init_head(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, D), jnp.bfloat16)
    ctx = make_host_mesh(1, 4)
    with meshctx.use(ctx):
        _, idx = jax.jit(lambda s, x: H.head_topk_sharded(cfg, s, x, 300)
                         )(st, x)
    assert (np.asarray(idx) < 260).all()
    print("sharded topk padding ok")


def check_compressed_xg():
    """E5M2-compressed x̄ reduction (+ error feedback): weights stay
    bit-identical (the W update never sees the reduced x̄), x̄ is close,
    and the feedback carry round-trips."""
    cfg, st, x, tgt = _mk("bce", "bf16", kahan=4, use_sr=False)
    st1, xg1, _ = _single(cfg, st, x, tgt)
    ctx = make_host_mesh(1, 4)
    with meshctx.use(ctx):
        err0 = H.init_xg_err(cfg, B)
        stS, xgS, _, err1 = jax.jit(
            lambda s, x, t, e: H.head_train_step_sharded(
                cfg, s, x, t, *_HYPERS, compress_xg=True, xg_err=e)
        )(st, x, tgt, err0)
    assert (_f32(st1.w) == _f32(stS.w)).all()
    # E5M2 has 2 mantissa bits: ≤12.5% per-element wire error → small L2
    rel = (np.linalg.norm(_f32(xg1) - _f32(xgS))
           / max(np.linalg.norm(_f32(xg1)), 1e-30))
    assert rel < 0.1, rel
    assert err1.shape == err0.shape and err1.dtype == err0.dtype
    assert np.abs(_f32(err1)).max() > 0   # residual actually carried
    print("compressed x̄ ok")


def check_grid_bit_parity():
    """ISSUE 3: the sharded whole-head grid-megakernel path reproduces the
    single-device grid step bit-for-bit in weights and Kahan compensation
    on every mesh factorization, for both losses and both ce_comm modes
    (gather-mode loss exact; stats-mode loss at f32 reassociation
    tolerance; x̄ at BF16 accumulation-order tolerance)."""
    for loss in ("bce", "softmax_ce"):
        cfg, st, x, tgt = _mk(loss, "bf16", kahan=4, use_sr=False,
                              impl="grid_interpret")
        st1, xg1, m1 = _single(cfg, st, x, tgt)
        for ce_comm in ("gather", "stats"):
            for mesh_shape in ((1, 4), (2, 2), (4, 1)):
                stS, xgS, mS = _sharded(cfg, st, x, tgt, mesh_shape,
                                        ce_comm=ce_comm)
                assert (_f32(st1.w) == _f32(stS.w)).all(), \
                    (loss, ce_comm, mesh_shape)
                assert (_f32(st1.comp) == _f32(stS.comp)).all(), \
                    (loss, ce_comm, mesh_shape)
                if ce_comm == "gather":
                    assert abs(float(m1["loss"]) - float(mS["loss"])) \
                        <= 2e-6 * abs(float(m1["loss"])), \
                        (loss, mesh_shape, float(m1["loss"]),
                         float(mS["loss"]))
                else:
                    np.testing.assert_allclose(float(m1["loss"]),
                                               float(mS["loss"]), rtol=1e-4)
                np.testing.assert_allclose(_f32(xg1), _f32(xgS),
                                           rtol=5e-2, atol=2e-3)
    print("grid bit parity ok")


def check_grid_sharded_serving():
    """Grid serving paths (single-launch logits / materialized top-k) are
    bit-identical to the single-device outputs under label sharding."""
    cfg, st, x, _ = _mk("bce", "bf16", kahan=0, use_sr=False,
                        impl="grid_interpret")
    z1 = H.head_logits(cfg, st, x)
    v1, i1 = H.head_topk(cfg, st, x, 10)
    for mesh_shape in ((1, 4), (2, 2)):
        ctx = make_host_mesh(*mesh_shape)
        with meshctx.use(ctx):
            zS = jax.jit(lambda s, x: H.head_logits_sharded(cfg, s, x)
                         )(st, x)
            vS, iS = jax.jit(lambda s, x: H.head_topk_sharded(cfg, s, x, 10)
                             )(st, x)
        assert (_f32(z1) == _f32(zS)).all(), mesh_shape
        assert (_f32(v1) == _f32(vS)).all(), mesh_shape
        assert (np.asarray(i1) == np.asarray(iS)).all(), mesh_shape
        assert (np.asarray(iS) < NL).all(), mesh_shape
    print("grid sharded serving ok")


def check_grid_sr_fp8_distributional():
    """Grid path, FP8 + SR: per-shard streams are independent (same
    contract as the chunk scan) — statistics must agree with the
    single-device grid step."""
    cfg, st, x, tgt = _mk("bce", "e4m3", kahan=0, use_sr=True,
                          impl="grid_interpret")
    st1, _, m1 = _single(cfg, st, x, tgt)
    stS, _, mS = _sharded(cfg, st, x, tgt, (1, 4))
    assert abs(float(m1["loss"]) - float(mS["loss"])) \
        < 1e-3 * abs(float(m1["loss"]))
    d1 = _f32(st1.w) - _f32(st.w)
    dS = _f32(stS.w) - _f32(st.w)
    assert abs(d1.mean() - dS.mean()) < 5e-5
    assert abs(d1.std() - dS.std()) < 0.3 * max(d1.std(), 1e-8)
    print("grid SR/FP8 distributional ok")


def check_topk_kernel_sharded_parity():
    """ISSUE 5: the streaming top-k megakernel under label sharding
    (local single-launch top-k → all-gather n·k → (−value, id) re-rank)
    is bit-identical — values AND ids — to the single-device kernel AND
    to the historical streaming scan, on 1×4, 2×2 and 4×1 meshes,
    including k beyond the local shard width and beyond num_labels."""
    import dataclasses

    from repro.head import plan as plan_mod
    from repro.head import serving

    cfg, st, x, _ = _mk("bce", "bf16", kahan=0, use_sr=False,
                        impl="grid_interpret")
    plan1 = plan_mod.resolve_plan(cfg, batch=B)
    assert plan1.topk_path == "kernel", plan1.topk_path
    for k in (10, 300, 1010):        # k > lc (=64 on 4 shards), k ≥ NL
        k = min(k, cfg.padded_labels)
        v1, i1 = H.head_topk(cfg, st, x, k)
        vs, is_ = serving.topk_planned(
            dataclasses.replace(plan1, topk_path="stream"), cfg, st, x, k)
        assert (_f32(v1) == _f32(vs)).all(), k
        assert (np.asarray(i1) == np.asarray(is_)).all(), k
        for mesh_shape in ((1, 4), (2, 2), (4, 1)):
            ctx = make_host_mesh(*mesh_shape)
            with meshctx.use(ctx):
                vS, iS = jax.jit(
                    lambda s, x: H.head_topk_sharded(cfg, s, x, k))(st, x)
            assert (_f32(v1) == _f32(vS)).all(), (k, mesh_shape)
            assert (np.asarray(i1) == np.asarray(iS)).all(), (k, mesh_shape)
            assert (np.asarray(iS)[:, :min(k, NL)] < NL).all(), \
                (k, mesh_shape)
    print("sharded streaming-top-k kernel parity ok")


def check_facade_matches_legacy():
    """ISSUE 4: the ``ELMOHead`` facade (plan resolved once at
    construction, ambient or explicit mesh) is bit-identical to every
    legacy ``core.elmo_head`` sharded entry point — train step W/comp/
    loss/x̄, logits, and top-k ids+values — on 1×4 and 2×2 meshes, for
    both the scan and grid paths."""
    from repro.head import ELMOHead, HeadHparams

    for impl in ("unfused_xla", "grid_interpret"):
        cfg, st, x, tgt = _mk("softmax_ce", "bf16", kahan=4, use_sr=False,
                              impl=impl)
        for mesh_shape in ((1, 4), (2, 2)):
            ctx = make_host_mesh(*mesh_shape)
            stL, xgL, mL = _sharded(cfg, st, x, tgt, mesh_shape)
            with meshctx.use(ctx):
                zL = jax.jit(lambda s, x: H.head_logits_sharded(cfg, s, x)
                             )(st, x)
                vL, iL = jax.jit(
                    lambda s, x: H.head_topk_sharded(cfg, s, x, 10))(st, x)
                # ambient-mesh construction: the facade must pick the
                # sharded plan on its own
                head = ELMOHead(cfg, batch=B, target_slots=1)
                assert head.plan.sharded, (impl, mesh_shape)
                stF, xgF, mF = jax.jit(
                    lambda s, x, t: head.train_step(
                        s, x, t, HeadHparams(*_HYPERS)))(st, x, tgt)
                zF = jax.jit(lambda s, x: head.logits(s, x))(st, x)
                vF, iF = jax.jit(lambda s, x: head.topk(s, x, 10))(st, x)
            assert (_f32(stL.w) == _f32(stF.w)).all(), (impl, mesh_shape)
            assert (_f32(stL.comp) == _f32(stF.comp)).all(), \
                (impl, mesh_shape)
            assert float(mL["loss"]) == float(mF["loss"]), (impl, mesh_shape)
            assert (_f32(xgL) == _f32(xgF)).all(), (impl, mesh_shape)
            assert (_f32(zL) == _f32(zF)).all(), (impl, mesh_shape)
            assert (_f32(vL) == _f32(vF)).all(), (impl, mesh_shape)
            assert (np.asarray(iL) == np.asarray(iF)).all(), \
                (impl, mesh_shape)
    print("facade ≡ legacy (sharded) ok")


def check_shortlist_sharded_parity():
    """ISSUE 7: 2-stage shortlisted serving under label sharding.  The
    beam is computed per rank from the REPLICATED centroids (no
    collective — every rank derives the same beam), each rank restricts
    its local label window via its assign slice, and the existing
    all-gather + (−value, id) re-rank merges.  Must be bit-identical —
    values AND ids — to single-device shortlisted serving AND to the
    restricted oracle on 1×4, 2×2 and 4×1 meshes, including k beyond the
    local shard width and a handcrafted index where whole ranks admit NO
    cluster for any query (their k local sentinels must sort behind every
    real candidate, exactly like the padded-column case)."""
    from repro.head import ELMOHead
    from repro.head import plan as plan_mod
    from repro.head import serving
    from repro.head import shortlist as SL

    cfg = H.ELMOHeadConfig(num_labels=NL, d_model=D, num_chunks=4,
                           weight_dtype="bf16", use_sr=False,
                           impl="grid_interpret", shortlist="on")
    st = H.init_head(jax.random.PRNGKey(0), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(1), (B, D)) * 0.5
         ).astype(jnp.bfloat16)
    plan1 = plan_mod.resolve_plan(cfg, batch=B)
    assert plan1.topk_path == "shortlist", plan1.topk_path
    index = SL.build_shortlist_index(cfg, st,
                                     n_clusters=plan1.shortlist_c,
                                     beam=plan1.shortlist_beam, iters=2)

    # a degenerate index: cluster c = chunk c (rank-contiguous on 1×4),
    # all-zero centroids so stage-1 ties resolve to cluster 0 for every
    # query at beam 1 → on 1×4 three ranks serve an empty shortlist
    asg = np.repeat(np.arange(4, dtype=np.int32)[:, None], cfg.chunk,
                    axis=1).reshape(-1)
    asg[NL:] = -1
    empty_rank_index = SL.ShortlistIndex(
        centroids=jnp.zeros((4, D), jnp.bfloat16),
        assign=jnp.asarray(asg.reshape(4, cfg.chunk)),
        n_clusters=4, beam=1, w_checksum=index.w_checksum)

    for sl in (index, empty_rank_index):
        for k in (10, 300, min(1010, cfg.padded_labels)):
            v1, i1 = serving.topk_planned(plan1, cfg, st, x, k, sl)
            beam_w = min(plan1.shortlist_beam or sl.beam, sl.beam)
            beam = SL.shortlist_clusters(index=sl, x=x, beam=beam_w,
                                         impl="xla")
            from repro.kernels import ref
            vo, io = ref.fused_topk_ref(
                x, st.w, serving._eval_seeds(cfg),
                serving._chunk_base(cfg), k=k, num_labels=NL,
                quantize_x=cfg.qx, assign=sl.assign, beam=beam)
            assert (_f32(v1) == _f32(vo)).all(), (sl.beam, k)
            assert (np.asarray(i1) == np.asarray(io)).all(), (sl.beam, k)
            for mesh_shape in ((1, 4), (2, 2), (4, 1)):
                ctx = make_host_mesh(*mesh_shape)
                with meshctx.use(ctx):
                    head = ELMOHead(cfg, batch=B)
                    # label axis 1 (4×1) legitimately plans unsharded
                    assert head.plan.sharded == (mesh_shape[1] > 1), \
                        mesh_shape
                    assert head.plan.topk_path == "shortlist", mesh_shape
                    head.attach_shortlist(sl)
                    vS, iS = jax.jit(
                        lambda s, xx: head.topk(s, xx, k))(st, x)
                assert (_f32(v1) == _f32(vS)).all(), (sl.beam, k,
                                                      mesh_shape)
                assert (np.asarray(i1) == np.asarray(iS)).all(), \
                    (sl.beam, k, mesh_shape)
                assert (np.asarray(iS) < NL).all(), (sl.beam, k,
                                                     mesh_shape)
    # the empty-rank index really is degenerate: only cluster-0 labels
    # (chunk 0) ever surface as non-sentinel results
    v1, i1 = serving.topk_planned(plan1, cfg, st, x, 300,
                                  empty_rank_index)
    real = _f32(v1) > -1e15
    assert real.sum(axis=1).max() <= cfg.chunk
    assert (np.asarray(i1)[real] < cfg.chunk).all()
    print("sharded shortlisted serving parity ok")


def check_train_step_picks_sharded_head():
    """launch.steps.train_step under an ambient 2×2 mesh: the head runs
    label-sharded and the loss matches the single-device step closely
    (identical weights; x̄→backbone differs only by BF16 summation order)."""
    cfg = get_smoke("xmc-bert-3m")
    opt = kahan_adamw(weight_decay=0.0)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0,
                                     cfg.vocab),
        "targets": jax.random.randint(jax.random.PRNGKey(3), (8, 10), 0,
                                      cfg.head_size),
    }
    state0 = St.init_train_state(jax.random.PRNGKey(1), cfg, opt, impl="xla")
    _, m1 = St.train_step(cfg, opt, state0, batch, jnp.float32(0.05),
                          jnp.float32(1e-3), impl="xla")
    ctx = make_host_mesh(2, 2)
    with meshctx.use(ctx):
        _, mS = jax.jit(lambda s, b: St.train_step(
            cfg, opt, s, b, jnp.float32(0.05), jnp.float32(1e-3),
            impl="xla"))(state0, batch)
    a, b = float(m1["loss"]), float(mS["loss"])
    assert abs(a - b) < 1e-3 * abs(a) + 1e-5, (a, b)
    print("train_step sharded head ok", a, b)


if __name__ == "__main__":
    check_bit_parity_deterministic()
    check_stats_lse_close()
    check_sr_fp8_distributional()
    check_serving_bit_parity()
    check_topk_padding_sharded()
    check_compressed_xg()
    check_grid_bit_parity()
    check_grid_sharded_serving()
    check_topk_kernel_sharded_parity()
    check_shortlist_sharded_parity()
    check_grid_sr_fp8_distributional()
    check_facade_matches_legacy()
    check_train_step_picks_sharded_head()
    print("ALL SHARDED HEAD CHECKS PASSED")
