"""Bench trajectory loading + the hillclimb driver (ISSUE 9 satellites).

The regression under test: ``benchmarks/run.py --show-trajectory`` used
to anchor at the *cwd*, so the committed ``BENCH_*.json`` history
rendered as ``[]`` from any directory but the repo root.  The loaders
now anchor at the repo root derived from ``__file__`` — asserted here by
loading from a foreign cwd.  ``benchmarks/hillclimb.py`` used to mutate
``XLA_FLAGS``/``sys.path`` and import jax at *import* time; it must now
be importable with zero side effects.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

REPO = str(Path(__file__).resolve().parent.parent)
sys.path.insert(0, REPO)

from benchmarks import run as bench_run        # noqa: E402

EXPECTED = {"BENCH_3.json", "BENCH_4.json", "BENCH_5.json",
            "BENCH_7.json", "BENCH_8.json", "BENCH_9.json",
            "BENCH_10.json"}


def test_bench_files_found_from_any_cwd(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)               # the historical failure mode
    files = bench_run.bench_files()
    names = {os.path.basename(p) for p in files}
    assert EXPECTED <= names, names
    # ordered by n, gap-tolerant (no BENCH_1/2/6)
    nums = [int(os.path.basename(p)[6:-5]) for p in files]
    assert nums == sorted(nums)


def test_trajectory_renders_committed_history(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)
    hist = bench_run.load_trajectory()
    assert hist, "committed BENCH_*.json rendered as an empty trajectory"
    for e in hist:
        assert {"ts", "sections", "rows", "file"} <= set(e)
    # the ISSUE 9 artifact is part of the history, gates included
    sparse = [e for e in hist if e["file"] == "BENCH_9.json"
              and "sparse" in e["sections"]]
    assert sparse, [e["file"] for e in hist]
    mem_rows = [r for e in sparse for r in e["rows"]
                if str(r.get("name", "")).startswith("sparse/mem_")]
    assert mem_rows
    assert all(r["shrink_x"] >= 10.0 for r in mem_rows)


def test_trajectory_skips_malformed_files(tmp_path):
    (tmp_path / "BENCH_1.json").write_text("{not json")
    (tmp_path / "BENCH_2.json").write_text(json.dumps({"not": "a list"}))
    (tmp_path / "BENCH_3.json").write_text(json.dumps(
        [{"ts": 1.0, "sections": ["x"], "rows": []}, "stray-non-dict"]))
    hist = bench_run.load_trajectory(str(tmp_path))
    assert [e["file"] for e in hist] == ["BENCH_3.json"]


def test_resolve_json_path_auto_appends_to_latest(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)
    assert os.path.basename(bench_run._resolve_json_path("auto")) \
        == sorted(EXPECTED, key=lambda n: int(n[6:-5]))[-1]
    assert bench_run._resolve_json_path("other.json") == "other.json"


def test_hillclimb_importable_without_side_effects():
    """Importing benchmarks.hillclimb must not touch XLA_FLAGS, sys.path
    or the jax backend — checked in a pristine subprocess so this test is
    immune to whatever the suite already imported."""
    code = (
        "import os, sys\n"
        "flags = os.environ.get('XLA_FLAGS')\n"
        "path = list(sys.path)\n"
        f"sys.path.insert(0, {REPO!r})\n"
        "import benchmarks.hillclimb as hc\n"
        "assert os.environ.get('XLA_FLAGS') == flags, 'XLA_FLAGS mutated'\n"
        "assert 'jax' not in sys.modules, 'jax imported at module import'\n"
        "assert 'repro.launch.dryrun' not in sys.modules\n"
        "assert hc.parse_override('a=2') == ('a', 2)\n"
        "assert hc.parse_override('r=0.5') == ('r', 0.5)\n"
        "assert hc.parse_override('s=fsdp_pure') == ('s', 'fsdp_pure')\n"
        "ap = hc.build_parser()\n"
        "ns = ap.parse_args(['--cell', 'gemma-7b/train_4k',\n"
        "                    '--set', 'n_layers=2'])\n"
        "assert ns.cell == 'gemma-7b/train_4k' and ns.set == ['n_layers=2']\n"
        "print('ok')\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "ok" in proc.stdout
