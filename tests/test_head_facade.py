"""`repro.head` facade (ISSUE 4): legacy free functions ≡ ``ELMOHead``
bit-for-bit, plan resolution happens exactly once per construction, and
the ``core.elmo_head`` deprecation shim forwards the mutable budget knobs.

The sharded half of the parity matrix runs in the forced-4-device
subprocess suite (``_multidevice_head_checks.check_facade_matches_legacy``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import elmo_head as H          # the deprecation shim
from repro.head import (ELMOHead, ELMOHeadConfig, HeadHparams, get_head,
                        head_config_for, resolve_plan)
from repro.head import plan as plan_mod


def _setup(loss="bce", num_labels=300, d=32, B=16, num_chunks=4,
           weight_dtype="e4m3", impl="grid_interpret", **kw):
    cfg = ELMOHeadConfig(num_labels=num_labels, d_model=d,
                         num_chunks=num_chunks, weight_dtype=weight_dtype,
                         loss=loss, impl=impl, **kw)
    st = H.init_head(jax.random.PRNGKey(1), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(2), (B, d)) * 0.5
         ).astype(jnp.bfloat16)
    if loss == "bce":
        tg = jax.random.randint(jax.random.PRNGKey(3), (B, 5), 0, num_labels)
    else:
        tg = jax.random.randint(jax.random.PRNGKey(3), (B,), -1, num_labels)
    return cfg, st, x, tg


HP = HeadHparams(lr=jnp.float32(0.1), wd=jnp.float32(1e-4),
                 seed=jnp.uint32(9))


def _f32(a):
    return np.asarray(a, np.float32)


# ---------------------------------------------------------------------------
# facade ≡ legacy free functions, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("loss", ["bce", "softmax_ce"])
@pytest.mark.parametrize("impl,wdtype,kahan", [
    ("grid_interpret", "e4m3", 0), ("fused_xla", "bf16", 4),
    ("unfused_xla", "bf16", 0), ("auto", "e4m3", 0)])
def test_facade_train_step_matches_legacy(loss, impl, wdtype, kahan):
    cfg, st, x, tg = _setup(loss, weight_dtype=wdtype, impl=impl,
                            kahan_chunks=kahan)
    st1, xg1, m1 = H.head_train_step(cfg, st, x, tg, HP.lr, HP.wd, HP.seed)
    head = ELMOHead(cfg, batch=x.shape[0],
                    target_slots=tg.shape[-1] if tg.ndim == 2 else 1)
    st2, xg2, m2 = head.train_step(st, x, tg, HP)
    np.testing.assert_array_equal(_f32(st1.w), _f32(st2.w))
    if st1.comp is not None:
        np.testing.assert_array_equal(_f32(st1.comp), _f32(st2.comp))
    np.testing.assert_array_equal(_f32(xg1), _f32(xg2))
    assert float(m1["loss"]) == float(m2["loss"])


@pytest.mark.parametrize("impl", ["grid_interpret", "fused_xla"])
def test_facade_serving_matches_legacy(impl):
    cfg, st, x, tg = _setup("bce", weight_dtype="bf16", use_sr=False,
                            impl=impl)
    head = ELMOHead(cfg, batch=x.shape[0])
    np.testing.assert_array_equal(_f32(H.head_logits(cfg, st, x)),
                                  _f32(head.logits(st, x)))
    v1, i1 = H.head_topk(cfg, st, x, 7)
    v2, i2 = head.topk(st, x, 7)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    p1 = H.precision_at_k(cfg, st, x, tg, k=3)
    p2 = head.precision_at_k(st, x, tg, k=3)
    assert float(p1) == float(p2)


def test_facade_sharded_entry_points_fall_back_without_mesh():
    """No mesh → the facade's plan is single-device, byte-identical to the
    legacy sharded wrappers (which fall back the same way)."""
    cfg, st, x, tg = _setup("softmax_ce", weight_dtype="bf16", use_sr=False,
                            impl="unfused_xla")
    st1, xg1, m1 = H.head_train_step_sharded(cfg, st, x, tg, HP.lr, HP.wd,
                                             HP.seed)
    head = ELMOHead(cfg, batch=x.shape[0])
    assert not head.plan.sharded
    st2, xg2, m2 = head.train_step(st, x, tg, HP)
    np.testing.assert_array_equal(_f32(st1.w), _f32(st2.w))
    np.testing.assert_array_equal(_f32(xg1), _f32(xg2))
    assert float(m1["loss"]) == float(m2["loss"])


def test_facade_convert_and_refine_match_legacy():
    cfg, st, x, tg = _setup("bce", weight_dtype="e4m3", impl="fused_xla")
    to_cfg = dataclasses.replace(cfg, weight_dtype="bf16", kahan_chunks=4)
    ref = H.convert_head(st, cfg, to_cfg)
    got = ELMOHead(to_cfg, batch=x.shape[0]).convert_from(st, cfg)
    np.testing.assert_array_equal(_f32(ref.w), _f32(got.w))
    np.testing.assert_array_equal(_f32(ref.comp), _f32(got.comp))


# ---------------------------------------------------------------------------
# plan resolution happens once per construction (ISSUE 4 acceptance)
# ---------------------------------------------------------------------------


def test_plan_resolved_once_per_construction():
    """Construction resolves the plan; traced/jitted step, logits and topk
    calls at the declared shapes perform ZERO further resolver entries —
    no `_impl_split`/`_grid_ok` re-resolution inside step functions."""
    cfg, st, x, tg = _setup("bce", impl="grid_interpret")
    head = ELMOHead(cfg, batch=x.shape[0], target_slots=tg.shape[-1])
    n0 = plan_mod._RESOLVE_CALLS

    step = jax.jit(lambda s, xx, t: head.train_step(s, xx, t, HP))
    st2, _, _ = step(st, x, tg)
    step(st2, x, tg)                       # cached trace
    jax.jit(lambda s, xx, t: head.train_step(s, xx, t, HP))(st, x, tg)
    head.topk(st, x, 5)                    # topk plans with target_slots=1…
    n_topk = plan_mod._RESOLVE_CALLS - n0  # …which is a different shape key
    head.topk(st, x, 5)
    head.logits(st, x)
    assert plan_mod._RESOLVE_CALLS - n0 == n_topk <= 1

    # same-shape train steps never re-resolved
    head2 = ELMOHead(cfg, batch=x.shape[0], target_slots=tg.shape[-1])
    n1 = plan_mod._RESOLVE_CALLS
    jax.jit(lambda s, xx, t: head2.train_step(s, xx, t, HP))(st, x, tg)
    assert plan_mod._RESOLVE_CALLS == n1


def test_get_head_is_memoized():
    cfg, st, x, tg = _setup("bce")
    h1 = get_head(cfg, batch=x.shape[0], target_slots=5)
    h2 = get_head(cfg, batch=x.shape[0], target_slots=5)
    assert h1 is h2
    h3 = get_head(cfg, batch=x.shape[0] * 2, target_slots=5)
    assert h3 is not h1


# ---------------------------------------------------------------------------
# plan content, explain, budgets, CLI
# ---------------------------------------------------------------------------


def test_plan_fields_and_explain():
    cfg, _, x, tg = _setup("softmax_ce", impl="grid_interpret",
                           weight_dtype="bf16", use_sr=False)
    plan = resolve_plan(cfg, batch=x.shape[0], target_slots=1)
    assert plan.path == "grid" and plan.fallback_reason == ""
    assert plan.block_l == cfg.chunk          # interpret keeps exact shapes
    assert plan.cache_z                        # small head fits the budget
    assert plan.temp_bytes > 0 and plan.vmem_bytes > 0
    txt = plan.explain()
    for needle in ("executed", "path=grid", "cache_z=on", "serving",
                   "estimates"):
        assert needle in txt, txt

    # mixed Kahan: grid request falls back to the fused scan, with a reason
    mixed = dataclasses.replace(cfg, kahan_chunks=2)
    p2 = resolve_plan(mixed, batch=x.shape[0])
    assert p2.path == "fused" and "Kahan" in p2.fallback_reason
    assert "fallback" in p2.explain()

    # "auto" on a non-TPU backend resolves inner to xla → fused oracle
    if jax.default_backend() != "tpu":
        p3 = resolve_plan(dataclasses.replace(cfg, impl="auto"),
                          batch=x.shape[0])
        assert p3.path == "fused" and p3.rimpl == "xla"


def test_shim_forwards_budget_knobs():
    """Monkeypatching the legacy module's budget constants must steer the
    one true policy in repro.head.plan (reads AND writes forward)."""
    orig = H._CACHE_Z_BYTES
    assert orig == plan_mod._CACHE_Z_BYTES
    try:
        H._CACHE_Z_BYTES = 123
        assert plan_mod._CACHE_Z_BYTES == 123
        assert H._CACHE_Z_BYTES == 123
    finally:
        H._CACHE_Z_BYTES = orig
    assert plan_mod._CACHE_Z_BYTES == orig

    # and the plan cache keys on the budget: a changed budget re-resolves
    cfg, _, x, _ = _setup("softmax_ce", impl="grid_interpret",
                          weight_dtype="bf16", use_sr=False)
    zbytes = x.shape[0] * cfg.padded_labels * 2
    try:
        H._CACHE_Z_BYTES = zbytes - 1
        assert not resolve_plan(cfg, batch=x.shape[0]).cache_z
        H._CACHE_Z_BYTES = zbytes + 1
        assert resolve_plan(cfg, batch=x.shape[0]).cache_z
    finally:
        H._CACHE_Z_BYTES = orig


def test_plan_cli_smoke_and_expectation(capsys):
    assert plan_mod.main(["--arch", "xmc-bert-3m", "--smoke", "--explain",
                          "--expect-path", "grid,fused"]) == 0
    out = capsys.readouterr().out
    assert "HeadPlan" in out and "executed" in out
    # an impossible expectation reports the fallback and fails
    assert plan_mod.main(["--arch", "xmc-bert-3m", "--smoke",
                          "--expect-path", "nonexistent"]) == 1
    assert "PLAN REGRESSION" in capsys.readouterr().out


def test_head_config_for_matches_make_head_cfg():
    from repro.configs import get_smoke
    from repro.launch.steps import make_head_cfg
    mcfg = get_smoke("xmc-bert-3m")
    assert make_head_cfg(mcfg, "xla") == head_config_for(mcfg, "xla")
