"""Pallas flash-attention TPU kernel vs dense oracle (interpret mode):
shape/dtype/GQA sweeps, causal + sliding-window block skipping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention_tpu import flash_attention_fwd_tpu

KEY = jax.random.PRNGKey(0)


def dense_oracle(q, k, v, causal, window):
    B, H, Sq, dh = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    G = H // KH
    kk = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vv = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk)
    s = s / np.sqrt(dh)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (kp <= qp)
    if window is not None:
        mask = mask & (qp - kp < window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv)


def _mk(B=1, H=4, KH=2, S=128, dh=32):
    ks = jax.random.split(KEY, 3)
    q = (jax.random.normal(ks[0], (B, H, S, dh)) * 0.5).astype(jnp.bfloat16)
    k = (jax.random.normal(ks[1], (B, KH, S, dh)) * 0.5).astype(jnp.bfloat16)
    v = (jax.random.normal(ks[2], (B, KH, S, dh)) * 0.5).astype(jnp.bfloat16)
    return q, k, v


@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 48)])
@pytest.mark.parametrize("bq,bk", [(32, 32), (64, 32), (128, 128)])
def test_fa_kernel_matches_dense(causal, window, bq, bk):
    q, k, v = _mk()
    got = flash_attention_fwd_tpu(q, k, v, causal=causal, window=window,
                                  bq=bq, bk=bk, interpret=True)
    want = dense_oracle(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=4e-2, atol=4e-2)


@pytest.mark.parametrize("H,KH,dh", [(4, 4, 32), (8, 2, 64), (6, 1, 32)])
def test_fa_kernel_gqa_and_heads(H, KH, dh):
    q, k, v = _mk(H=H, KH=KH, dh=dh, S=64)
    got = flash_attention_fwd_tpu(q, k, v, causal=True, bq=32, bk=32,
                                  interpret=True)
    want = dense_oracle(q, k, v, True, None)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=4e-2, atol=4e-2)


def test_fa_kernel_matches_xla_flash_path():
    """Kernel vs the XLA custom-VJP flash path (the production fallback)."""
    from repro.models.flash_attention import flash_attention as xla_flash
    B, H, KH, S, dh = 2, 4, 2, 96, 32
    q, k, v = _mk(B=B, H=H, KH=KH, S=S, dh=dh)
    got = flash_attention_fwd_tpu(q, k, v, causal=True, bq=32, bk=32,
                                  interpret=True)
    G = H // KH
    q5 = q.transpose(0, 2, 1, 3).reshape(B, S, KH, G, dh)
    k4 = k.transpose(0, 2, 1, 3)
    v4 = v.transpose(0, 2, 1, 3)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    valid = jnp.ones((B, S), bool)
    ref = xla_flash(q5, k4, v4, pos, pos, valid, True, None, 32, 32)
    ref = ref.reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)
