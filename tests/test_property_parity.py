"""Property-based parity sweeps (hypothesis, or the deterministic stub).

PR 1's (and now ISSUE 3's) contract is that four implementations of the
chunked head step are the *same algorithm*:

  * grid       — the whole-head grid megakernel, ONE ``pallas_call`` for
                 every chunk (``kernels/fused_head.py``, interpret mode)
  * fused      — one ``ops.fused_chunk_step`` launch per chunk
                 (``ref.fused_chunk_ref`` on the XLA path)
  * unfused    — the legacy 3-kernel composition
  * composed   — the hand-rolled jnp pipeline (logits → loss-skip grad →
                 x̄ → SR/Kahan update) the refs are built from

These sweeps drive random (B, D, L, chunking, dtype, loss, SR/Kahan) draws
through all of them — L deliberately not divisible by the chunk so the
padded-column masking is always live — and require bit-equality, plus the
cached-z fast-path boundary behavior around the VMEM budget constant.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import elmo_head as H
from repro.core import losses as L
from repro.kernels import ref

_DTYPES = ("bf16", "e4m3", "e5m2")
_LOSSES = ("bce", "softmax_ce")


def _draw_case(B, D, num_chunks, l_frac, dtype_i, loss_i, kahan_i, sr):
    """Materialize one random head-step case from integer draws."""
    dtype, loss = _DTYPES[dtype_i], _LOSSES[loss_i]
    cfg0 = H.ELMOHeadConfig(num_labels=64, d_model=D, num_chunks=num_chunks,
                            weight_dtype=dtype, loss=loss)
    # L strictly inside (chunk·(C−1), chunk·C): never divisible by the
    # chunk, so the final chunk always carries masked padded columns
    chunk_guess = max(2, cfg0.chunk)
    lo, hi = chunk_guess * (num_chunks - 1) + 1, chunk_guess * num_chunks - 1
    num_labels = max(2, lo + int(l_frac * (hi - lo)))
    kahan = (0, num_chunks, max(1, num_chunks // 2))[kahan_i]
    cfg = H.ELMOHeadConfig(num_labels=num_labels, d_model=D,
                           num_chunks=num_chunks, weight_dtype=dtype,
                           loss=loss, use_sr=sr, kahan_chunks=kahan,
                           impl="xla")
    k = jax.random.PRNGKey(B * 7919 + D * 31 + num_labels)
    kw, kx, kt = jax.random.split(k, 3)
    state = H.init_head(kw, cfg)
    x = (jax.random.normal(kx, (B, D)) * 0.5).astype(jnp.bfloat16)
    if loss == "bce":
        tgt = jax.random.randint(kt, (B, 4), 0, num_labels)
    else:
        tgt = jax.random.randint(kt, (B,), -1, num_labels)
    return cfg, state, x, tgt


def _run(cfg, state, x, tgt, impl):
    cfg = dataclasses.replace(cfg, impl=impl)
    st2, xg, m = H.head_train_step(cfg, state, x, tgt, jnp.float32(0.07),
                                   jnp.float32(1e-4), jnp.uint32(11))
    return (np.asarray(st2.w, np.float32),
            None if st2.comp is None else np.asarray(st2.comp, np.float32),
            np.asarray(xg, np.float32), float(m["loss"]))


@settings(max_examples=12, deadline=None)
@given(B=st.integers(1, 12), D=st.integers(2, 48),
       num_chunks=st.integers(2, 5), l_frac=st.floats(0.0, 1.0),
       dtype_i=st.integers(0, 2), loss_i=st.integers(0, 1),
       kahan_i=st.integers(0, 2), sr=st.integers(0, 1))
def test_property_fused_matches_unfused(B, D, num_chunks, l_frac, dtype_i,
                                        loss_i, kahan_i, sr):
    """head_train_step: fused (megakernel oracle) == unfused (legacy
    3-kernel path) bit-for-bit across the whole config space — including
    SR draws (same per-chunk seed hash on both paths)."""
    cfg, state, x, tgt = _draw_case(B, D, num_chunks, l_frac, dtype_i,
                                    loss_i, kahan_i, bool(sr))
    w_f, c_f, xg_f, l_f = _run(cfg, state, x, tgt, "fused_xla")
    w_u, c_u, xg_u, l_u = _run(cfg, state, x, tgt, "unfused_xla")
    np.testing.assert_array_equal(w_f, w_u)
    if c_f is not None:
        np.testing.assert_array_equal(c_f, c_u)
    np.testing.assert_array_equal(xg_f, xg_u)
    assert l_f == l_u


@settings(max_examples=12, deadline=None)
@given(B=st.integers(1, 12), D=st.integers(2, 48),
       num_chunks=st.integers(2, 5), l_frac=st.floats(0.0, 1.0),
       dtype_i=st.integers(0, 2), loss_i=st.integers(0, 1),
       kahan_i=st.integers(0, 2), sr=st.integers(0, 1))
def test_property_grid_matches_fused(B, D, num_chunks, l_frac, dtype_i,
                                     loss_i, kahan_i, sr):
    """head_train_step: grid (whole-head megakernel, one launch, interpret
    mode) == fused (per-chunk scan) bit-for-bit across the whole config
    space — including SR draws (the grid kernel replays the per-chunk seed
    hash and SR-bit addressing) and the mixed-Kahan fallback.  Both run
    the interpret backend: the chunk kernel's own bitwise contract against
    the jnp oracle is per-launch (tests/test_fused_chunk.py) — across a
    whole scanned step, eager-XLA vs compiled-kernel fusion differs by
    ULPs, which is a pre-existing property of the fused path, not of the
    grid rewrite."""
    cfg, state, x, tgt = _draw_case(B, D, num_chunks, l_frac, dtype_i,
                                    loss_i, kahan_i, bool(sr))
    w_g, c_g, xg_g, l_g = _run(cfg, state, x, tgt, "grid_interpret")
    w_f, c_f, xg_f, l_f = _run(cfg, state, x, tgt, "fused_interpret")
    np.testing.assert_array_equal(w_g, w_f)
    if c_g is not None:
        np.testing.assert_array_equal(c_g, c_f)
    np.testing.assert_array_equal(xg_g, xg_f)
    # the loss *scalar* is a cross-kernel reduction: XLA may fuse it
    # differently in the two programs — allow 1 ULP (arrays stay bitwise)
    assert l_g == pytest.approx(l_f, rel=2e-6)


@settings(max_examples=12, deadline=None)
@given(B=st.integers(1, 16), D=st.integers(2, 40), Lc=st.integers(2, 96),
       pad=st.integers(0, 20), dtype_i=st.integers(0, 2),
       loss_i=st.integers(0, 1), sr=st.integers(0, 1),
       kahan=st.integers(0, 1))
def test_property_chunk_ref_is_exact_composition(B, D, Lc, pad, dtype_i,
                                                 loss_i, sr, kahan):
    """ref.fused_chunk_ref == the hand-composed jnp pipeline, bitwise, for
    one random chunk with a random number of padded (masked) columns."""
    dtype = {"bf16": jnp.bfloat16, "e4m3": jnp.float8_e4m3fn,
             "e5m2": jnp.float8_e5m2}[_DTYPES[dtype_i]]
    loss = _LOSSES[loss_i]
    num_labels = max(1, Lc - pad)
    qx = dtype == jnp.float8_e4m3fn
    k = jax.random.PRNGKey(B * 131 + D * 17 + Lc)
    kx, kw, kt, kg = jax.random.split(k, 4)
    x = (jax.random.normal(kx, (B, D)) * 0.5).astype(jnp.bfloat16)
    w = (jax.random.normal(kw, (Lc, D)) * 0.05).astype(dtype)
    xg0 = (jax.random.normal(kg, (B, D)) * 0.1).astype(jnp.bfloat16)
    comp = jnp.zeros((Lc, D), jnp.bfloat16) if kahan else None
    if loss == "bce":
        tgt, lse = jax.random.randint(kt, (B, 4), 0, num_labels), None
    else:
        tgt = jax.random.randint(kt, (B,), -1, num_labels)
        z0 = ref.fp8_logits_ref(x, w, jnp.uint32(7), quantize_x=qx)
        zm = jnp.where(jnp.arange(Lc)[None, :] < num_labels,
                       z0.astype(jnp.float32), L.NEG_INF)
        lse = L.lse_finalize(*L.lse_update(*L.lse_init(B), zm))
    hp = (jnp.float32(0.07), jnp.float32(1e-4), jnp.float32(1.0 / B),
          jnp.int32(0), jnp.uint32(7), jnp.uint32(13))
    out = ref.fused_chunk_ref(x, w, tgt, xg0, *hp, lse=lse, comp=comp,
                              loss=loss, num_labels=num_labels,
                              use_sr=bool(sr), quantize_x=qx)
    # hand-composed pipeline
    z = ref.fp8_logits_ref(x, w, jnp.uint32(7), quantize_x=qx)
    g, loss_c = L.chunk_loss_skip_grad(loss, z, tgt, jnp.int32(0), Lc,
                                       num_labels, lse, jnp.float32(1.0 / B))
    xg = xg0 + ref.fp8_input_grad_ref(g, w)
    if kahan:
        w_new, _ = ref.fused_head_update_kahan_ref(
            g, x, w, comp, jnp.float32(0.07), jnp.float32(1e-4),
            jnp.uint32(13))
    else:
        w_new = ref.fused_head_update_ref(g, x, w, jnp.float32(0.07),
                                          jnp.float32(1e-4), jnp.uint32(13),
                                          use_sr=bool(sr))
    np.testing.assert_array_equal(np.asarray(out.w, np.float32),
                                  np.asarray(w_new, np.float32))
    np.testing.assert_array_equal(np.asarray(out.xg, np.float32),
                                  np.asarray(xg, np.float32))
    assert float(out.loss) == float(jnp.float32(loss_c))


@settings(max_examples=8, deadline=None)
@given(B=st.integers(1, 10), D=st.integers(2, 32),
       num_chunks=st.integers(2, 4), l_frac=st.floats(0.0, 1.0),
       side=st.integers(0, 2))
def test_property_cached_z_boundary(B, D, num_chunks, l_frac, side,
                                    monkeypatch=None):
    """softmax-CE cached-z fast path: 'on', 'off' and 'auto' produce
    bit-identical steps on either side of the cache-budget boundary (the
    cache is a *reuse* of exact pass-1 logits, never an approximation).

    ``side`` pins the auto decision: budget below / exactly at / above the
    z-cache footprint B·padded·2 — for the per-chunk scan AND the grid
    megakernel (whose cache is grid-resident VMEM scratch)."""
    cfg, state, x, tgt = _draw_case(B, D, num_chunks, l_frac, 0, 1, 1,
                                    False)
    zbytes = B * cfg.padded_labels * 2
    budget = (zbytes - 1, zbytes, zbytes + 1)[side]
    orig = H._CACHE_Z_BYTES
    H._CACHE_Z_BYTES = budget
    try:
        outs = {}
        for impl in ("fused_xla", "grid_interpret"):
            for mode in ("on", "off", "auto"):
                c = dataclasses.replace(cfg, cache_z=mode)
                outs[(impl, mode)] = _run(c, state, x, tgt, impl)
    finally:
        H._CACHE_Z_BYTES = orig
    # cache on/off/auto is invariant within each path (the cache is exact
    # logits reuse); paths are compared to each other elsewhere
    for impl in ("fused_xla", "grid_interpret"):
        base = outs[(impl, "on")]
        for mode in ("off", "auto"):
            got = outs[(impl, mode)]
            np.testing.assert_array_equal(base[0], got[0],
                                          err_msg=f"{impl}/{mode}")
            np.testing.assert_array_equal(base[2], got[2],
                                          err_msg=f"{impl}/{mode}")
            assert base[3] == got[3], (impl, mode)
