"""Serving-runtime checks under a forced host-device count (default 4;
tests/test_serve_runtime.py drives this via the ``multidevice_runner``
fixture, CI also runs it single-device).  Exit code 0 = all passed.

The contract under test (DESIGN.md §12, ISSUE 8 acceptance):

* the runtime's dispatch path really is the sharded top-k when a mesh
  is ambient: the ELMOHead facade plans ``sharded`` and every ladder
  level's served (vals, ids) are bit-identical to the single-device
  head (PR 6's parity contract, now exercised through the
  ``HeadExecutor`` program cache);
* the plan- and recall-gated degradation ladder builds identically
  under the mesh (same rungs, same measured recalls);
* a fault-injected overload soak on the virtual clock — seeded Poisson
  burst + transient dispatch failures — conserves every request
  (exactly one terminal state), meets admitted deadlines, engages the
  ladder and recovers, and replays BIT-IDENTICALLY run to run.
"""
import os

_N_DEV = int(os.environ.get("REPRO_FORCE_DEVICES", "4"))
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + f" --xla_force_host_platform_device_count={_N_DEV}")

import jax                     # noqa: E402
import jax.numpy as jnp        # noqa: E402
import numpy as np             # noqa: E402

from repro import serve as RS                        # noqa: E402
from repro.core import elmo_head as H                # noqa: E402
from repro.dist import meshctx                       # noqa: E402
from repro.fault import inject as FI                 # noqa: E402
from repro.head import ELMOHead                      # noqa: E402
from repro.head import shortlist as SL               # noqa: E402
from repro.launch.mesh import make_host_mesh         # noqa: E402

assert len(jax.devices()) == _N_DEV, jax.devices()

B, K = 16, 10
# the golden structured-head recipe (tests/_shortlist_checks.GOLDEN):
# the one geometry where the shortlist rung provably clears the 0.95
# recall floor, so the ladder has a real degraded level to exercise
CFG = H.ELMOHeadConfig(num_labels=4096, d_model=64, num_chunks=8,
                       weight_dtype="e4m3", use_sr=False)
STATE = SL.synthetic_clustered_state(CFG, groups=128, noise=0.2, seed=7)
PROBE = jax.random.normal(jax.random.PRNGKey(11),
                          (64, CFG.d_model)).astype(jnp.bfloat16)


def _ladder(head):
    return RS.build_ladder(head, STATE, k=K, max_batch=B, probe_x=PROBE,
                           iters=8, n_clusters=64, beam=28)


def _mesh():
    # model (label) axis = device count: every rank serves a label shard
    return make_host_mesh(1, _N_DEV)


def check_sharded_ladder_parity():
    """Each ladder level under the mesh serves bit-identical (vals, ids)
    to the single-device head — the runtime cannot tell the difference,
    which is exactly the point."""
    head1 = ELMOHead(CFG, batch=B)
    levels1 = _ladder(head1)
    assert [lv.name for lv in levels1] == ["exact", "shortlist"], levels1
    ex1 = RS.HeadExecutor(STATE, timing="model")
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(3),
                                     (B, CFG.d_model)), np.float32)
    res1 = [ex1.dispatch(x, K, lv) for lv in levels1]
    with meshctx.use(_mesh()):
        headS = ELMOHead(CFG, batch=B)
        assert headS.plan.sharded == (_N_DEV > 1), headS.plan
        levelsS = _ladder(headS)
        assert [lv.name for lv in levelsS] == ["exact", "shortlist"]
        for lv1, lvS in zip(levels1, levelsS):
            assert lv1.recall == lvS.recall, (lv1, lvS)
        exS = RS.HeadExecutor(STATE, timing="model")
        resS = [exS.dispatch(x, K, lv) for lv in levelsS]
    for lv, r1, rS in zip(levels1, res1, resS):
        assert (np.asarray(r1.vals, np.float32)
                == np.asarray(rS.vals, np.float32)).all(), lv.name
        assert (r1.ids == rS.ids).all(), lv.name
        assert r1.service_s == rS.service_s, lv.name   # model timing
    print(f"sharded ladder parity ok ({_N_DEV} devices)")


def _trace():
    base = FI.poisson_requests(rate_qps=300, horizon_s=0.5, seed=1,
                               d_model=CFG.d_model, k=K)
    # 20k qps tops even the degraded-rung capacity (≈10k qps at the
    # shortlist cost scale), so admission must shed as well as degrade
    burst = FI.poisson_requests(rate_qps=20000, horizon_s=0.3, seed=2,
                                d_model=CFG.d_model, k=K,
                                t0=0.5, rid0=len(base))
    cool = FI.poisson_requests(rate_qps=300, horizon_s=0.5, seed=3,
                               d_model=CFG.d_model, k=K, t0=0.8,
                               rid0=len(base) + len(burst))
    return base + burst + cool


def check_sharded_overload_soak_deterministic():
    """The full fault-injected overload soak, served by the real sharded
    head on the virtual clock: conservation, deadline SLO, ladder
    engage + recover, and bit-identical replay."""
    with meshctx.use(_mesh()):
        head = ELMOHead(CFG, batch=B)
        levels = _ladder(head)
        assert len(levels) == 2

        def run():
            ex = FI.FailingExecutor(RS.HeadExecutor(STATE, timing="model"),
                                    fail_calls=[3, 40])
            srv = RS.Server(ex, levels,
                            cfg=RS.ServeConfig(max_batch=B, max_queue=256,
                                               slo_s=0.05),
                            estimator=RS.ServiceEstimator(RS.ServiceModel()))
            reqs = _trace()
            rep = RS.run_trace(srv, reqs).report()
            for r in reqs:             # exactly one terminal door each
                assert r.outcome is not None, r.rid
            done = [r for r in reqs
                    if r.outcome is RS.Outcome.COMPLETED][:4]
            assert done and all(r.vals.shape == (K,) and
                                (np.asarray(r.ids) < CFG.num_labels).all()
                                for r in done)
            return rep

        rep = run()
        assert rep["conserved"], rep
        assert rep["shed_rate"] > 0.05, rep["shed_rate"]
        assert rep["deadline_met_of_admitted"] > 0.99, rep
        assert rep["dispatch_retries"] >= 1, rep
        frm_to = [(f, t) for _, f, t, _ in rep["transitions"]]
        assert (0, 1) in frm_to, rep["transitions"]
        assert rep["transitions"][-1][2] == 0, rep["transitions"]
        assert rep["level_dispatches"].get("shortlist", 0) > 0, rep
        rep2 = run()
        assert rep == rep2, "sharded soak replay is not bit-identical"
    print(f"sharded overload soak ok ({_N_DEV} devices): "
          f"shed={rep['shed_rate']:.3f} "
          f"p99={rep['p99_ms']:.1f}ms transitions={len(rep['transitions'])}")


if __name__ == "__main__":
    check_sharded_ladder_parity()
    check_sharded_overload_soak_deterministic()
    print("ALL SERVE RUNTIME CHECKS PASSED")
