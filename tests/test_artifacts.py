"""Deliverable artifacts stay valid: the dry-run report covers every live
(arch × shape × mesh) cell with 0 errors, and PSP@k behaves per the paper."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.registry import SHAPES, cell_applicable
from repro.core import losses as L

REPORT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "dryrun_report.json")


@pytest.mark.skipif(not os.path.exists(REPORT),
                    reason="run launch/dryrun.py --all first")
def test_dryrun_report_complete_and_green():
    rep = json.load(open(REPORT))
    by_key = {(r["arch"], r["shape"], r["mesh"]): r for r in rep}
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                rec = by_key.get((arch, shape, mesh))
                assert rec is not None, (arch, shape, mesh)
                assert "error" not in rec, rec
                if cell_applicable(cfg, SHAPES[shape]):
                    assert "skipped" in rec
                else:
                    assert "memory" in rec and "collectives" in rec
                    assert rec["memory"]["peak_per_device_gib"] > 0


def test_psp_at_k_weights_tail_hits_higher():
    freq = jnp.array([1000.0, 1000.0, 2.0, 2.0])   # 2 head, 2 tail labels
    prop = L.propensity_scores(freq)
    assert float(prop[0]) > float(prop[2])          # head labels more likely
    labels = jnp.array([[0, 2, -1]], jnp.int32)
    head_hit = L.psp_at_k(jnp.array([[0]], jnp.int32), labels, prop, k=1)
    tail_hit = L.psp_at_k(jnp.array([[2]], jnp.int32), labels, prop, k=1)
    assert float(tail_hit) > float(head_hit) > 0
