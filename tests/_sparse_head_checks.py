"""Label-sharded sparse-head checks, run in a subprocess with a forced
host-device count (default 4; tests/test_sparse_head.py drives this via
the ``multidevice_runner`` fixture).  Exit code 0 = all checks passed.

The contract under test (DESIGN.md §13, ISSUE 9 acceptance):

* the sharded sparse train step (values/indices/comp row-partitioned
  over the model axis) is **bit-identical** to the single-device sparse
  step in values, Kahan comp and loss for deterministic configs (no SR,
  no DropConnect) with ``ce_comm="gather"``, on every mesh factorization
  of the 4 forced devices (1×4, 2×2, 4×1 — the last legitimately plans
  unsharded);
* x̄ matches to f32 psum-reassociation tolerance (per-shard partials);
* sharded sparse serving (logits / top-k values AND ids) is bit-identical
  to the single-device sparse paths, padded ids never surface;
* prune/regrow commutes with sharding: the controller on the densified
  global state equals gathering the sharded controller's output;
* the ``ELMOHead`` facade under an ambient mesh auto-plans the sharded
  sparse path.
"""
import os

_N_DEV = int(os.environ.get("REPRO_FORCE_DEVICES", "4"))
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + f" --xla_force_host_platform_device_count={_N_DEV}")

import dataclasses             # noqa: E402

import jax                     # noqa: E402
import jax.numpy as jnp        # noqa: E402
import numpy as np             # noqa: E402

from repro import head as H                    # noqa: E402
from repro.dist import meshctx                 # noqa: E402
from repro.head import sparse as SP            # noqa: E402
from repro.launch.mesh import make_host_mesh   # noqa: E402

assert len(jax.devices()) == _N_DEV, jax.devices()

B, D, NL, F = 16, 32, 1000, 8      # chunk=256, 4 chunks, 24 padded columns
_HP = H.HeadHparams(jnp.float32(0.05), jnp.float32(1e-4), jnp.uint32(7))


def _mk(loss, kahan):
    cfg = H.ELMOHeadConfig(num_labels=NL, d_model=D, num_chunks=4,
                           weight_dtype="e4m3", loss=loss, fan_in=F,
                           kahan_chunks=kahan, use_sr=False)
    st = SP.init_sparse_head(jax.random.PRNGKey(0), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(1), (B, D)) * 0.5
         ).astype(jnp.bfloat16)
    shape = (B, 8) if loss == "bce" else (B,)
    tgt = jax.random.randint(jax.random.PRNGKey(2), shape, 0, NL)
    return cfg, st, x, tgt


def _bits(a):
    return None if a is None else np.asarray(a).view(np.uint8)


def _f32(a):
    return np.asarray(a, np.float32)


def _heads(cfg, mesh_shape, tgt):
    ctx = make_host_mesh(*mesh_shape)
    slots = tgt.shape[-1] if tgt.ndim == 2 else 1
    with meshctx.use(ctx):
        head = H.ELMOHead(cfg, batch=B, target_slots=slots)
    return ctx, head


def check_train_bit_parity():
    """Deterministic sparse configs: values/comp/loss bit-identical on
    every mesh factorization; x̄ to psum-reassociation tolerance."""
    for loss, kahan in (("bce", 0), ("bce", 4), ("softmax_ce", 4)):
        cfg, st, x, tgt = _mk(loss, kahan)
        head1 = H.ELMOHead(cfg, batch=B,
                           target_slots=tgt.shape[-1] if tgt.ndim == 2
                           else 1, ctx=None)
        assert head1.plan.path == "sparse"
        st1, xg1, m1 = jax.jit(lambda s, x, t: head1.train_step(
            s, x, t, _HP))(st, x, tgt)
        for mesh_shape in ((1, 4), (2, 2), (4, 1)):
            ctx, head = _heads(cfg, mesh_shape, tgt)
            with meshctx.use(ctx):
                assert head.plan.path == "sparse", mesh_shape
                assert head.plan.sharded == (mesh_shape[1] > 1), mesh_shape
                stS, xgS, mS = jax.jit(lambda s, x, t: head.train_step(
                    s, x, t, _HP))(st, x, tgt)
            np.testing.assert_array_equal(_bits(st1.values),
                                          _bits(stS.values))
            assert (np.asarray(st1.indices)
                    == np.asarray(stS.indices)).all(), (loss, mesh_shape)
            if kahan:
                np.testing.assert_array_equal(_bits(st1.comp),
                                              _bits(stS.comp))
            assert float(m1["loss"]) == float(mS["loss"]), \
                (loss, kahan, mesh_shape, float(m1["loss"]),
                 float(mS["loss"]))
            np.testing.assert_allclose(_f32(xg1), _f32(xgS), rtol=5e-2,
                                       atol=2e-3)
    print("sparse sharded train bit parity ok")


def check_serving_bit_parity():
    cfg, st, x, _ = _mk("bce", 0)
    head1 = H.ELMOHead(cfg, batch=B, ctx=None)
    z1 = jax.jit(lambda s, x: head1.logits(s, x))(st, x)
    for k in (10, 300, 1010):
        k = min(k, cfg.padded_labels)
        v1, i1 = jax.jit(lambda s, x, k=k: head1.topk(s, x, k))(st, x)
        for mesh_shape in ((1, 4), (2, 2)):
            ctx, head = _heads(cfg, mesh_shape, jnp.zeros((B,), jnp.int32))
            with meshctx.use(ctx):
                zS = jax.jit(lambda s, x: head.logits(s, x))(st, x)
                vS, iS = jax.jit(lambda s, x, k=k: head.topk(s, x, k)
                                 )(st, x)
            np.testing.assert_array_equal(_bits(z1), _bits(zS))
            assert (_f32(v1) == _f32(vS)).all(), (k, mesh_shape)
            assert (np.asarray(i1) == np.asarray(iS)).all(), (k, mesh_shape)
            real = _f32(vS) > -1e15
            assert (np.asarray(iS)[real] < NL).all(), (k, mesh_shape)
    print("sparse sharded serving bit parity ok")


def check_prune_regrow_shard_invariant():
    """The controller is a pure per-row function, so the swap a row takes
    is independent of which shard holds it: the single-device controller
    output IS the sharded ground truth (the facade runs it on the
    gathered state between steps)."""
    cfg, st, x, tgt = _mk("bce", 4)
    cfg = dataclasses.replace(cfg, prune_every=2)
    want = jax.jit(lambda s: SP.prune_regrow(cfg, s, x, tgt))(st)
    for mesh_shape in ((1, 4), (2, 2)):
        ctx, head = _heads(cfg, mesh_shape, tgt)
        with meshctx.use(ctx):
            got = head.maybe_prune_regrow(st, x, tgt, jnp.int32(2))
        assert (np.asarray(got.indices) == np.asarray(want.indices)).all()
        np.testing.assert_array_equal(_bits(got.values), _bits(want.values))
        np.testing.assert_array_equal(_bits(got.comp), _bits(want.comp))
        assert SP.indices_strictly_increasing(got)
    print("sparse prune/regrow shard-invariant ok")


if __name__ == "__main__":
    check_train_bit_parity()
    check_serving_bit_parity()
    check_prune_regrow_shard_invariant()
    print("ALL SPARSE SHARDED CHECKS PASSED")
