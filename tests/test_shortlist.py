"""Two-stage shortlisted serving: differential harness (DESIGN.md §11,
ISSUE 7).

The contract under test:

* **Restricted exactness** — the shortlisted top-k (Pallas block-skip
  kernel AND the xla streaming path) is **bit-identical** — values AND
  ids — to ``ref.fused_topk_ref`` with the same (assign, beam)
  restriction, which in turn equals the EXACT full ranking filtered to
  admitted labels and truncated to k (an independent derivation that
  never touches the restriction code).  Swept over B, D, L ∤ block,
  cluster counts, beam widths, k past the admitted count, bf16/e4m3
  weights, and label tiles.
* **Full beam ≡ exact** — admitting every cluster reproduces the exact
  serving result bit-for-bit and recall@k == 1.0.
* **Tie-breaks** — duplicate logits straddling cluster boundaries still
  resolve to the lowest admitted label id.
* **Sentinels** — padded rows/columns and unadmitted labels never
  surface; overflow slots are exactly (NEG_INF, id 0); an all-empty beam
  yields nothing but sentinels.
* **Plan gating** — ``shortlist="on"`` rewires kernel/stream plans,
  ``"auto"`` only above ``_SHORTLIST_MIN_LABELS``, ``"off"`` never;
  ``explain()`` and the plan CLI surface (C, beam); serving with a
  shortlist plan but NO attached index downgrades to exact.
* **Persistence** — ckpt-style crc32 leaves round-trip bit-exactly;
  torn/corrupt/missing artifacts raise ``ShortlistError``; ``is_stale``
  flags indices built from different weight bits.
* **Golden fixture** — the committed 4096-label index reproduces pinned
  recall@{1,5,10} (≥ 0.95 floor) and exact cluster sizes.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import _shortlist_checks as C
from repro.core import elmo_head as H
from repro.core import losses as L
from repro.head import ELMOHead, convert
from repro.head import plan as plan_mod
from repro.head import serving
from repro.head import shortlist as SL
from repro.kernels import ops, ref, tuning


def _mk(num_labels, d, B, num_chunks, wdtype="bf16", **kw):
    cfg = H.ELMOHeadConfig(num_labels=num_labels, d_model=d,
                           num_chunks=num_chunks, weight_dtype=wdtype,
                           use_sr=False, **kw)
    state = H.init_head(jax.random.PRNGKey(1), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(2), (B, d)) * 0.5
         ).astype(jnp.bfloat16)
    return cfg, state, x


def _random_restriction(cfg, B, n_clusters, n_beam, seed):
    """(assign, beam) drawn uniformly — padded label rows get -1."""
    rng = np.random.default_rng(seed)
    asg = np.full((cfg.padded_labels,), -1, np.int32)
    asg[:cfg.num_labels] = rng.integers(0, n_clusters, cfg.num_labels)
    beam_w = min(n_beam, n_clusters)
    beam = np.stack([rng.choice(n_clusters, size=beam_w, replace=False)
                     for _ in range(B)]).astype(np.int32)
    return asg.reshape(cfg.num_chunks, cfg.chunk), beam


# ---------------------------------------------------------------------------
# restricted kernel ≡ restricted oracle (values AND ids), swept
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 8), D=st.integers(2, 40),
       num_chunks=st.integers(2, 4), l_frac=st.floats(0.0, 1.0),
       n_clusters=st.integers(1, 9), n_beam=st.integers(1, 4),
       k_sel=st.integers(0, 2), dt_i=st.integers(0, 1),
       bl_i=st.integers(0, 2), seed=st.integers(0, 2**31 - 1))
def test_restricted_kernel_oracle_parity(B, D, num_chunks, l_frac,
                                         n_clusters, n_beam, k_sel, dt_i,
                                         bl_i, seed):
    wdtype = ("bf16", "e4m3")[dt_i]
    lo, hi = num_chunks, num_chunks * 300
    num_labels = int(lo + l_frac * (hi - lo))
    cfg, state, x = _mk(num_labels, D, B, num_chunks, wdtype,
                        impl="grid_interpret")
    # k spanning: tiny, > chunk width (well past any admitted count),
    # and the full padded width (overflow sentinels guaranteed)
    k = (1, min(cfg.chunk + 17, cfg.padded_labels),
         cfg.padded_labels)[k_sel]
    block_l = (None, 8, 64)[bl_i]
    assign, beam = _random_restriction(cfg, B, n_clusters, n_beam, seed)

    got, want = C.restricted_pair(cfg, state, x, k, assign, beam,
                                  impl="interpret", block_l=block_l)
    C.assert_bit_equal(got, want, f"k={k} bl={block_l}")
    admitted = [np.isin(assign.reshape(-1)[:num_labels], beam[r]).sum()
                for r in range(B)]
    C.check_sentinels(*got, num_labels, admitted)


def test_restriction_equals_filtered_exact_ranking():
    """Independent oracle: the restricted top-k must equal the EXACT full
    ranking (k = padded width) filtered to admitted labels, truncated to
    k.  Stable (value desc, id asc) order is preserved under filtering,
    so this derivation never touches assign/beam masking code."""
    cfg, state, x = _mk(700, 24, 5, 3, "e4m3", impl="grid_interpret")
    assign, beam = _random_restriction(cfg, x.shape[0], 6, 2, seed=123)
    k = 37
    seeds = serving._eval_seeds(cfg)
    base = serving._chunk_base(cfg)
    vf, if_ = ref.fused_topk_ref(x, state.w, seeds, base,
                                 k=cfg.padded_labels,
                                 num_labels=cfg.num_labels,
                                 quantize_x=cfg.qx)
    flat_assign = np.asarray(assign).reshape(-1)
    for impl in ("interpret", "xla"):
        (vr, ir), _ = C.restricted_pair(cfg, state, x, k, assign, beam,
                                        impl=impl)
        vr, ir = np.asarray(vr), np.asarray(ir)
        for r in range(x.shape[0]):
            keep = np.isin(flat_assign[np.asarray(if_)[r]], beam[r])
            keep &= np.asarray(vf)[r] > L.NEG_INF / 2  # drop sentinels
            want_i = np.asarray(if_)[r][keep][:k]
            want_v = np.asarray(vf)[r][keep][:k]
            n = len(want_i)
            np.testing.assert_array_equal(ir[r, :n], want_i, err_msg=impl)
            np.testing.assert_array_equal(vr[r, :n], want_v, err_msg=impl)
            assert (vr[r, n:] <= L.NEG_INF / 2).all()
            assert (ir[r, n:] == 0).all()


def test_full_beam_equals_exact_and_recall_one():
    cfg, state, x = _mk(600, 32, 6, 3, "e4m3", impl="grid_interpret")
    index = SL.build_shortlist_index(cfg, state, n_clusters=8, beam=3,
                                     iters=2)
    full = SL.full_beam(index, x.shape[0])
    k = 29
    seeds = serving._eval_seeds(cfg)
    base = serving._chunk_base(cfg)
    for impl in ("interpret", "xla"):
        ve, ie = ops.fused_topk(x, state.w, seeds, base, k=k,
                                num_labels=cfg.num_labels,
                                quantize_x=cfg.qx, impl=impl)
        vr, ir = ops.fused_topk(x, state.w, seeds, base, k=k,
                                num_labels=cfg.num_labels,
                                quantize_x=cfg.qx, impl=impl,
                                assign=index.assign, beam=full)
        C.assert_bit_equal((vr, ir), (ve, ie), f"full-beam {impl}")
    wide = index._replace(beam=index.n_clusters)
    recall = SL.shortlist_recall_at_k(cfg, state, wide, x, ks=(1, 5, 10))
    assert recall == {1: 1.0, 5: 1.0, 10: 1.0}, recall


def test_duplicate_ties_straddle_cluster_boundary():
    """Every label row identical → every logit ties.  With clusters
    assigned alternately 0/1 per label, a beam admitting both must
    resolve ties to ids 0,1,2,..., and a beam admitting only cluster 1
    to ids 1,3,5,... — on kernel and oracle, bit-identically."""
    B, D, num_chunks, lc, k = 3, 16, 2, 32, 9
    x = (jax.random.normal(jax.random.PRNGKey(0), (B, D)) * 0.5
         ).astype(jnp.bfloat16)
    row = (jax.random.normal(jax.random.PRNGKey(1), (1, 1, D)) * 0.05
           ).astype(jnp.bfloat16)
    w = jnp.tile(row, (num_chunks, lc, 1))
    L_tot = num_chunks * lc
    seeds = jnp.zeros((num_chunks,), jnp.uint32)
    base = jnp.arange(num_chunks, dtype=jnp.int32) * lc
    assign = (np.arange(L_tot, dtype=np.int32) % 2
              ).reshape(num_chunks, lc)
    for beam_row, want in ((np.array([0, 1]), np.arange(k)),
                           (np.array([1, -1]), 1 + 2 * np.arange(k))):
        beam = np.tile(beam_row[None].astype(np.int32), (B, 1))
        outs = {}
        for impl in ("interpret", "xla"):
            outs[impl] = ops.fused_topk(
                x, w, seeds, base, k=k, num_labels=L_tot,
                quantize_x=False, impl=impl, block_l=8,
                assign=jnp.asarray(assign), beam=jnp.asarray(beam))
            assert (np.asarray(outs[impl][1]) == want).all(), \
                (impl, beam_row, outs[impl][1])
        C.assert_bit_equal(outs["interpret"], outs["xla"],
                           f"ties beam={beam_row}")


def test_empty_beam_surfaces_only_sentinels():
    cfg, state, x = _mk(200, 16, 4, 2, impl="grid_interpret")
    assign, _ = _random_restriction(cfg, x.shape[0], 4, 1, seed=5)
    beam = np.full((x.shape[0], 3), -1, np.int32)
    k = 7
    for impl in ("interpret", "xla"):
        (v, i), _ = C.restricted_pair(cfg, state, x, k, assign, beam,
                                      impl=impl)
        assert (np.asarray(v) <= L.NEG_INF / 2).all(), impl
        assert (np.asarray(i) == 0).all(), impl


def test_stage1_sentinels_masked_to_minus_one():
    """beam wider than the cluster count: stage-1 overflow slots must
    come back as -1 (inert), never as a phantom cluster 0."""
    cent = (jax.random.normal(jax.random.PRNGKey(0), (3, 16)) * 0.1
            ).astype(jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16)
                          ).astype(jnp.bfloat16)
    ids = SL.stage1_clusters(cent, x, n_clusters=3, beam=5, impl="xla")
    ids = np.asarray(ids)
    assert ids.shape == (4, 5)
    assert (np.sort(ids[:, :3], axis=1) == np.arange(3)).all()
    assert (ids[:, 3:] == -1).all()


# ---------------------------------------------------------------------------
# plan gating + CLI
# ---------------------------------------------------------------------------


def test_plan_shortlist_gating():
    mk = lambda sl: H.ELMOHeadConfig(num_labels=1000, d_model=32,
                                     num_chunks=4, weight_dtype="bf16",
                                     use_sr=False, impl="grid_interpret",
                                     shortlist=sl)
    p_on = plan_mod.resolve_plan(mk("on"), batch=8)
    assert p_on.topk_path == "shortlist"
    assert (p_on.shortlist_c, p_on.shortlist_beam) == \
        tuning.shortlist_params(1000, 32)
    assert f"(C={p_on.shortlist_c} beam={p_on.shortlist_beam})" \
        in p_on.explain()
    # "auto" below the label floor and "off" both stay exact
    assert plan_mod.resolve_plan(mk("auto"), batch=8).topk_path == "kernel"
    p_off = plan_mod.resolve_plan(mk("off"), batch=8)
    assert p_off.topk_path == "kernel"
    assert (p_off.shortlist_c, p_off.shortlist_beam) == (0, 0)
    assert "(C=" not in p_off.explain()


def test_plan_auto_engages_at_xmc_scale():
    from repro.configs import get_smoke
    from repro.head.config import head_config_for

    for arch in ("xmc-bert-3m", "xmc-distilbert-8.6m"):
        hcfg = dataclasses.replace(head_config_for(get_smoke(arch)),
                                   impl="grid_interpret",
                                   shortlist="auto")
        assert hcfg.num_labels >= plan_mod._SHORTLIST_MIN_LABELS
        plan = plan_mod.resolve_plan(hcfg, batch=8)
        assert plan.topk_path == "shortlist", (arch, plan.topk_path)
        assert plan.shortlist_c >= 2 and \
            plan.shortlist_beam <= plan.shortlist_c


def test_plan_cli_expect_topk_shortlist(capsys):
    argv = ["--arch", "xmc-bert-3m", "--impl", "grid_interpret",
            "--batch", "8"]
    assert plan_mod.main(argv + ["--shortlist", "auto",
                                 "--expect-topk", "shortlist"]) == 0
    assert plan_mod.main(argv + ["--shortlist", "off",
                                 "--expect-topk", "kernel"]) == 0
    # mismatch is a hard failure (CI plan-stability contract)
    assert plan_mod.main(argv + ["--shortlist", "off",
                                 "--expect-topk", "shortlist"]) == 1
    capsys.readouterr()


def test_shortlist_params_geometry():
    assert tuning.shortlist_params(100, 64) == (0, 0)    # too small
    assert tuning.shortlist_params(1000, 32) == (128, 16)
    assert tuning.shortlist_params(4096, 64) == (256, 16)
    c, bm = tuning.shortlist_params(3_000_000, 768)
    assert c & (c - 1) == 0 and bm == 16
    # C ≈ √(beam·L), within one power of two
    assert 0.5 <= c / (16 * 3_000_000) ** 0.5 <= 2.0
    # the config only admits the three documented modes
    with pytest.raises(AssertionError):
        H.ELMOHeadConfig(num_labels=100, d_model=8, num_chunks=1,
                         shortlist="yes")


# ---------------------------------------------------------------------------
# facade: build/attach/detach + downgrade-to-exact
# ---------------------------------------------------------------------------


def test_facade_build_attach_detach_downgrade():
    cfg, state, x = _mk(1000, 32, 8, 4, "e4m3", impl="grid_interpret",
                        shortlist="on")
    cfg_off = dataclasses.replace(cfg, shortlist="off")
    k = 12
    exact = ELMOHead(cfg_off, batch=x.shape[0]).topk(state, x, k)

    head = ELMOHead(cfg, batch=x.shape[0])
    assert head.plan.topk_path == "shortlist"
    assert head.shortlist is None
    # no index attached → downgrade to the exact path, bit-identically
    C.assert_bit_equal(head.topk(state, x, k), exact, "downgrade")

    index = head.build_shortlist(state, iters=2)
    assert head.shortlist is index
    assert index.n_clusters == head.plan.shortlist_c
    assert index.beam == head.plan.shortlist_beam
    assert not SL.is_stale(index, state)
    got = head.topk(state, x, k)
    beam = SL.shortlist_clusters(index, x, impl="xla")
    want = ref.fused_topk_ref(x, state.w, serving._eval_seeds(cfg),
                              serving._chunk_base(cfg), k=k,
                              num_labels=cfg.num_labels,
                              quantize_x=cfg.qx,
                              assign=index.assign, beam=beam)
    C.assert_bit_equal(got, want, "facade vs restricted oracle")

    head.attach_shortlist(None)
    assert head.shortlist is None
    C.assert_bit_equal(head.topk(state, x, k), exact, "detach")


def test_convert_build_shortlist_entry(tmp_path):
    cfg, state, _ = _mk(600, 16, 2, 3, "e4m3", impl="unfused_xla")
    out = os.path.join(str(tmp_path), "sl")
    index = convert.build_shortlist(cfg, state, out_dir=out,
                                    n_clusters=8, beam=3, iters=2)
    loaded = SL.load_shortlist_index(out)
    assert loaded.n_clusters == 8 and loaded.beam == 3
    np.testing.assert_array_equal(np.asarray(loaded.assign),
                                  np.asarray(index.assign))
    np.testing.assert_array_equal(
        np.asarray(loaded.centroids).view(np.uint16),
        np.asarray(index.centroids).view(np.uint16))
    assert loaded.w_checksum == index.w_checksum


# ---------------------------------------------------------------------------
# persistence: round-trip, torn writes, staleness
# ---------------------------------------------------------------------------


def _small_index():
    cfg, state, _ = _mk(300, 16, 2, 2, impl="unfused_xla")
    return cfg, state, SL.build_shortlist_index(cfg, state, n_clusters=4,
                                                beam=2, iters=2)


def test_persistence_roundtrip_bit_exact(tmp_path):
    _, state, index = _small_index()
    p = os.path.join(str(tmp_path), "idx")
    SL.save_shortlist_index(p, index, extra={"note": "t"})
    got = SL.load_shortlist_index(p)
    np.testing.assert_array_equal(
        np.asarray(got.centroids).view(np.uint16),
        np.asarray(index.centroids).view(np.uint16))
    assert got.centroids.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(got.assign),
                                  np.asarray(index.assign))
    assert got.assign.dtype == jnp.int32
    assert (got.n_clusters, got.beam) == (index.n_clusters, index.beam)
    assert got.w_checksum == index.w_checksum
    assert not SL.is_stale(got, state)


@pytest.mark.parametrize("damage", ["no_committed", "leaf_bits",
                                    "manifest_bits", "missing_leaf"])
def test_persistence_corruption_raises(tmp_path, damage):
    _, _, index = _small_index()
    p = os.path.join(str(tmp_path), "idx")
    SL.save_shortlist_index(p, index)
    if damage == "no_committed":
        os.remove(os.path.join(p, "COMMITTED"))
    elif damage == "leaf_bits":
        f = os.path.join(p, "assign.npy")
        raw = bytearray(open(f, "rb").read())
        raw[-1] ^= 0xFF
        open(f, "wb").write(bytes(raw))
    elif damage == "manifest_bits":
        f = os.path.join(p, "manifest.json")
        txt = open(f).read().replace('"elmo-shortlist-v1"',
                                     '"elmo-shortlist-v9"')
        open(f, "w").write(txt)
    elif damage == "missing_leaf":
        os.remove(os.path.join(p, "centroids.npy"))
    with pytest.raises(SL.ShortlistError):
        SL.load_shortlist_index(p)


def test_is_stale_tracks_weight_bits():
    cfg, state, index = _small_index()
    assert not SL.is_stale(index, state)
    moved = H.init_head(jax.random.PRNGKey(9), cfg)
    assert SL.is_stale(index, moved)


# ---------------------------------------------------------------------------
# golden fixture: committed index, pinned recall + cluster sizes
# ---------------------------------------------------------------------------


def test_golden_fixture_recall_and_sizes():
    import json

    with open(C.GOLDEN_JSON) as f:
        pinned = json.load(f)
    cfg = C.golden_cfg()
    state = C.golden_state(cfg)
    index = SL.load_shortlist_index(C.GOLDEN_DIR)   # crc-verified
    # the recipe reproduces the exact head bits the index was built from
    assert index.w_checksum == pinned["w_checksum"]
    assert not SL.is_stale(index, state)
    np.testing.assert_array_equal(SL.cluster_sizes(index),
                                  np.asarray(pinned["cluster_sizes"]))
    x = C.golden_queries(cfg)
    recall = SL.shortlist_recall_at_k(cfg, state, index, x,
                                      ks=(1, 5, 10), impl="xla")
    assert recall[10] >= C.RECALL_FLOOR, recall
    for k, want in ((1, pinned["recall"]["1"]), (5, pinned["recall"]["5"]),
                    (10, pinned["recall"]["10"])):
        assert abs(recall[k] - want) <= 0.02, (k, recall[k], want)
    # a from-scratch rebuild (same seed) lands near the committed numbers
    rebuilt = C.build_golden_index(cfg, state)
    r2 = SL.shortlist_recall_at_k(cfg, state, rebuilt, x, ks=(10,),
                                  impl="xla")
    assert abs(r2[10] - pinned["recall"]["10"]) <= 0.05, r2
    assert SL.cluster_sizes(rebuilt).max() <= \
        -(-cfg.num_labels // index.n_clusters)


def test_golden_fixture_serves_restricted_exact():
    """End-to-end: the committed index attached to the facade serves the
    restricted oracle bit-for-bit on the golden queries."""
    cfg = C.golden_cfg(impl="grid_interpret")
    state = C.golden_state(cfg)
    index = SL.load_shortlist_index(C.GOLDEN_DIR)
    x = C.golden_queries(cfg, batch=8)
    head = ELMOHead(cfg, batch=8)
    assert head.plan.topk_path == "shortlist"
    head.attach_shortlist(index)
    got = head.topk(state, x, 10)
    beam_w = min(head.plan.shortlist_beam or index.beam, index.beam)
    beam = SL.shortlist_clusters(index, x, beam=beam_w, impl="xla")
    want = ref.fused_topk_ref(x, state.w, serving._eval_seeds(cfg),
                              serving._chunk_base(cfg), k=10,
                              num_labels=cfg.num_labels,
                              quantize_x=cfg.qx,
                              assign=index.assign, beam=beam)
    C.assert_bit_equal(got, want, "golden facade")
    C.check_sentinels(*got, cfg.num_labels)
