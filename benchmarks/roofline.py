"""Roofline model: compute / memory / collective terms per (arch × shape).

Why analytic: XLA's ``cost_analysis()`` visits ``while`` (scan) bodies ONCE
(verified in tests/test_roofline.py), and this framework deliberately scans
over layers / label chunks / attention blocks, so compiled counters
undercount by the trip counts.  ``memory_analysis()`` (buffer assignment) is
loop-aware and is taken from the dry-run; FLOPs / HBM bytes / collective
bytes come from the closed-form model below, validated against
``cost_analysis`` on configs whose loops are trip-1 (inlined by XLA).

The model counts what the implementation ACTUALLY executes, including its
known inefficiencies (they are the hillclimb targets in EXPERIMENTS.md §Perf):

* causal full attention visits all block pairs → ~2× ideal FLOPs,
* remat recomputes each period's forward once (+1× fwd),
* softmax-CE heads run the logits matmul twice (LSE pass + grad pass),
* MoE routers run replicated over the model axis (EP mode).

Hardware constants (TPU v5e, task spec): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.configs import get_config
from repro.configs.registry import SHAPES, ShapeCell, cell_applicable
from repro.core.elmo_head import ELMOHeadConfig
from repro.models.config import BlockSpec, ModelConfig

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link
CHIPS = 256                  # single-pod 16×16 (roofline table mesh)
N_DATA, N_MODEL = 16, 16

# training multipliers: fwd + remat-recompute + bwd(2×)
MM_TRAIN = 4.0               # plain matmuls
ATTN_TRAIN = 4.5             # flash bwd ≈ 2.5× fwd (recompute + 4 matmuls)

# attention block sizes (models/attention.py defaults)
BQ, BK = 512, 1024


def _head_cfg(cfg: ModelConfig) -> ELMOHeadConfig:
    return ELMOHeadConfig(num_labels=cfg.head_size, d_model=cfg.d_model,
                          num_chunks=cfg.head_chunks,
                          weight_dtype=cfg.head_weight_dtype,
                          loss=cfg.head_loss)


# ---------------------------------------------------------------------------
# parameter counts
# ---------------------------------------------------------------------------


def param_counts(cfg: ModelConfig) -> dict:
    D, F, dh = cfg.d_model, cfg.d_ff, cfg.hdim
    H, KH = cfg.n_heads, cfg.n_kv_heads
    per_period = 0
    expert = 0
    for bs in cfg.pattern:
        if bs.kind in ("attn", "hymba"):
            per_period += D * H * dh * 2 + D * KH * dh * 2
        if bs.kind in ("mamba", "hymba"):
            DI, N, R = cfg.d_inner, cfg.ssm_state, max(1, D // 16)
            per_period += (D * 2 * DI + 4 * DI + DI * (R + 2 * N)
                           + R * DI + DI * N + DI + DI * D)
        if bs.kind == "mlstm":
            per_period += 5 * D * D + 2 * D * cfg.mlstm_heads
        if bs.kind == "slstm":
            per_period += D * 4 * D + 4 * D * (D // cfg.mlstm_heads) + D * D
        if bs.cross_attn:
            per_period += D * H * dh * 2 + D * KH * dh * 2
        if bs.ffn != "none":
            mult = 3 if bs.ffn in ("swiglu", "geglu") else 2
            if bs.moe:
                expert += cfg.n_experts * mult * D * F
                per_period += D * cfg.n_experts          # router
                if cfg.moe_dense_residual:
                    per_period += mult * D * F
            else:
                per_period += mult * D * F
    n_backbone = cfg.n_periods * (per_period + expert)
    n_expert = cfg.n_periods * expert
    hc = _head_cfg(cfg)
    n_head = hc.padded_labels * D
    n_embed = cfg.vocab * D
    total = n_backbone + n_head + n_embed
    active = (total - n_expert
              + n_expert * cfg.top_k / max(cfg.n_experts, 1))
    return {"total": total, "active": active, "expert": n_expert,
            "head": n_head, "embed": n_embed}


# ---------------------------------------------------------------------------
# FLOPs actually executed per step (global)
# ---------------------------------------------------------------------------


def _attn_core_flops(T: int, ctx: int, H: int, dh: int,
                     window: Optional[int], causal_full_blocks: bool) -> float:
    """scores + PV for T query tokens against ``ctx`` keys, as implemented."""
    if window is not None:
        n_win = min(math.ceil(ctx / BK), math.ceil(window / BK) + 2)
        visited = min(ctx, n_win * BK)
    else:
        visited = ctx                      # all blocks (causal masks inside)
    return 2.0 * T * visited * H * dh * 2


def fwd_flops(cfg: ModelConfig, T: int, S: int, decode: bool = False) -> dict:
    """Forward FLOPs by component (global, one pass)."""
    D, F, dh = cfg.d_model, cfg.d_ff, cfg.hdim
    H, KH = cfg.n_heads, cfg.n_kv_heads
    proj = attn_core = ffn = moe = ssm = rec = cross = 0.0
    for bs in cfg.pattern:
        if bs.kind in ("attn", "hymba"):
            proj += 2.0 * T * D * (2 * H * dh + 2 * KH * dh)
            ctx = S if not decode else min(S, cfg.sliding_window or S)
            attn_core += _attn_core_flops(T, ctx, H, dh,
                                          cfg.sliding_window, True)
        if bs.kind in ("mamba", "hymba"):
            DI, N, R = cfg.d_inner, cfg.ssm_state, max(1, D // 16)
            ssm += T * (2 * D * 2 * DI + 2 * DI * 4 + 2 * DI * (R + 2 * N)
                        + 2 * R * DI + 10 * DI * N + 2 * DI * D)
        if bs.kind == "mlstm":
            Hm = cfg.mlstm_heads
            dhm = D // Hm
            W = 64
            rec += T * (5 * 2 * D * D          # q,k,v,z,o projections
                        + 2 * W * D * 2        # intra scores + PV
                        + 2 * D * dhm * 2 * 2)  # inter read + state update
        if bs.kind == "slstm":
            Hm = cfg.mlstm_heads
            dhm = D // Hm
            rec += T * (2 * D * 4 * D + 2 * 4 * D * dhm + 2 * D * D)
        if bs.cross_attn:
            N_img = cfg.n_frontend_tokens
            B = max(1, T // max(S, 1))
            proj += 2.0 * T * D * 2 * H * dh + 2.0 * B * N_img * D * 2 * KH * dh
            cross += 2.0 * T * N_img * H * dh * 2
        if bs.ffn != "none":
            mult = 6 if bs.ffn in ("swiglu", "geglu") else 4
            if bs.moe:
                moe += 2.0 * T * D * cfg.n_experts * (
                    N_MODEL if cfg.n_experts % N_MODEL == 0 else 1)  # router ×EP
                slots = T * cfg.top_k * cfg.capacity_factor
                moe += mult * slots * D * F
                if cfg.moe_dense_residual:
                    ffn += mult * T * D * F
            else:
                ffn += mult * T * D * F
    out = {k: v * cfg.n_periods for k, v in
           dict(proj=proj, attn_core=attn_core, ffn=ffn, moe=moe, ssm=ssm,
                rec=rec, cross=cross).items()}
    return out


def head_flops(cfg: ModelConfig, T_head: int, kind: str) -> float:
    hc = _head_cfg(cfg)
    L = hc.padded_labels
    D = cfg.d_model
    if kind == "train":
        passes = 4 if hc.loss == "softmax_ce" else 3
        return passes * 2.0 * T_head * D * L
    return 2.0 * T_head * D * L    # serve: logits once


def step_flops(cfg: ModelConfig, shape: ShapeCell) -> dict:
    decode = shape.kind == "decode"
    T = shape.batch if decode else shape.batch * shape.seq
    # XMC encoders pool to one vector per example before the head
    T_head = shape.batch if cfg.pool == "first" else T
    f = fwd_flops(cfg, T, shape.seq, decode)
    attn = f.pop("attn_core")
    fwd_total = sum(f.values()) + attn
    kind = "train" if shape.kind == "train" else "serve"
    hf = head_flops(cfg, T_head, kind)
    if shape.kind == "train":
        total = sum(f.values()) * MM_TRAIN + attn * ATTN_TRAIN + hf
    else:
        total = fwd_total + hf
    return {"fwd": fwd_total, "total": total, "head": hf,
            "attn_core_fwd": attn}


# ---------------------------------------------------------------------------
# HBM bytes per step (global)
# ---------------------------------------------------------------------------


def step_hbm_bytes(cfg: ModelConfig, shape: ShapeCell) -> float:
    pc = param_counts(cfg)
    D = cfg.d_model
    decode = shape.kind == "decode"
    T = shape.batch if decode else shape.batch * shape.seq
    hc = _head_cfg(cfg)
    wb = {"bf16": 2, "e4m3": 1, "f32": 4}[cfg.head_weight_dtype]
    backbone_bytes = (pc["total"] - pc["head"]) * 2          # bf16
    head_bytes = pc["head"] * wb

    if shape.kind == "train":
        # weights: fwd + remat + bwd reads; update read+write; opt r/w
        w_traffic = backbone_bytes * 3 + backbone_bytes * 2 \
            + (pc["total"] - pc["head"] - pc["expert"]) * 6 * 2
        head_passes = 4 if hc.loss == "softmax_ce" else 3
        head_traffic = head_bytes * head_passes + head_bytes * 2
        # activations: boundary saves w+r, per-chunk logits w+r, x̄ f32
        acts = cfg.n_periods * T * D * 2 * 2
        t_head = shape.batch if cfg.pool == "first" else T
        logits = head_passes * t_head * hc.chunk * 2 * 2
        xg = T * D * 4 * 2
        return w_traffic + head_traffic + acts + logits + xg
    # serving: weights once + cache traffic + chunked logits
    cache = 0.0
    if shape.kind == "decode":
        ctx = min(shape.seq, cfg.sliding_window or shape.seq)
        kv_layers = sum(1 for b in cfg.pattern if b.kind in ("attn", "hymba"))
        cache = shape.batch * ctx * cfg.n_kv_heads * cfg.hdim * 2 * 2 \
            * kv_layers * cfg.n_periods
    logits = T * hc.padded_labels * 2
    return backbone_bytes + head_bytes + cache + logits


# ---------------------------------------------------------------------------
# collective bytes per device per step
# ---------------------------------------------------------------------------


def step_collective_bytes(cfg: ModelConfig, shape: ShapeCell,
                          multi_pod: bool = False) -> dict:
    pc = param_counts(cfg)
    D = cfg.d_model
    decode = shape.kind == "decode"
    T = shape.batch if decode else shape.batch * shape.seq
    hc = _head_cfg(cfg)
    nm = N_MODEL
    ring = 2 * (nm - 1) / nm
    out = {}
    backbone_bytes = (pc["total"] - pc["head"]) * 2
    n_micro = max(1, cfg.grad_accum) if shape.kind == "train" else 1

    if cfg.sharding_strategy == "fsdp_pure":
        # batch over (data×model); params FSDP over 256; no TP/SP
        T_local = T / (N_DATA * nm * (2 if multi_pod else 1))
        if shape.kind == "train":
            # each device RECEIVES ~full params per pass; passes = 3
            # (fwd + remat + bwd) × microbatches; grads reduce-scatter once
            out["fsdp_allgather"] = 3 * n_micro * backbone_bytes
            out["grad_reduce_scatter"] = backbone_bytes
            # head W chunks gathered over model per pass (weights, small)
            wb = {"bf16": 2, "e4m3": 1, "f32": 4}[cfg.head_weight_dtype]
            passes = 4 if hc.loss == "softmax_ce" else 3
            out["head_w_gather"] = passes * n_micro * pc["head"] * wb
            if hc.loss == "softmax_ce":
                out["head_lse_psum"] = 2 * hc.num_chunks * ring * T_local * 8
            if multi_pod:
                out["crosspod_grad_allreduce"] = \
                    2 * 0.5 * backbone_bytes / (nm * N_DATA)
        else:
            out["serve_w_gather"] = backbone_bytes
        out["total"] = sum(out.values())
        return out

    T_local = T / max(N_DATA * (2 if multi_pod else 1), 1)
    T_micro = T_local / n_micro
    if shape.kind == "train":
        # FSDP param all-gathers (fwd + remat + bwd per microbatch) + grad RS
        shard = backbone_bytes / (nm * N_DATA)
        out["fsdp_allgather"] = 3 * n_micro * shard * (N_DATA - 1)
        out["grad_reduce_scatter"] = shard * (N_DATA - 1)
        # SP boundary all-gather/reduce-scatter per period (fwd+remat+2bwd)
        sp = (T_micro * D * 2 / nm) * (nm - 1) * 2 * cfg.n_periods * 4 \
            * n_micro
        out["seq_parallel"] = sp
        # head x̄ all-reduce over model per chunk (bf16 accumulator)
        out["head_xgrad_allreduce"] = \
            n_micro * hc.num_chunks * ring * T_micro * D * 2
        if hc.loss == "softmax_ce":
            out["head_lse_psum"] = \
                n_micro * 2 * hc.num_chunks * ring * T_micro * 8
        # MoE combine psum per layer (bf16; fwd+bwd+remat ≈ 4 passes)
        if any(b.moe for b in cfg.pattern):
            out["moe_psum"] = ring * T_micro * D * 2 * cfg.n_periods * 4 \
                * n_micro
        if multi_pod:
            out["crosspod_grad_allreduce"] = \
                2 * 0.5 * backbone_bytes / (nm * N_DATA)  # e5m2 compressed
    else:
        # TP all-reduces through the stack (attn out + ffn out per layer)
        out["tp_allreduce"] = ring * T_local * D * 2 * 2 * cfg.n_periods
        out["head_logits"] = ring * T_local * 8  # top-k combine, tiny
    out["total"] = sum(out.values())
    return out


# ---------------------------------------------------------------------------
# roofline assembly
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops: float
    useful_ratio: float
    note: str = ""

    def row(self) -> dict:
        return dataclasses.asdict(self)


def analyze_cell(arch: str, shape_name: str, chips: int = CHIPS,
                 multi_pod: bool = False) -> Optional[Roofline]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if cell_applicable(cfg, shape):
        return None
    fl = step_flops(cfg, shape)
    hbm = step_hbm_bytes(cfg, shape)
    coll = step_collective_bytes(cfg, shape, multi_pod)

    compute_s = fl["total"] / (chips * PEAK_FLOPS)
    memory_s = hbm / (chips * HBM_BW)
    collective_s = coll["total"] / ICI_BW       # already per device
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]

    pc = param_counts(cfg)
    decode = shape.kind == "decode"
    T = shape.batch if decode else shape.batch * shape.seq
    # 6·N_active·D, with the head counted at its own token count (XMC heads
    # see one pooled vector per example, not per token)
    T_head = shape.batch if cfg.pool == "first" else T
    n_body = pc["active"] - pc["head"]
    mult = 6.0 if shape.kind == "train" else 2.0
    model_flops = mult * (n_body * T + pc["head"] * T_head)
    notes = {
        "compute": "raise MFU: cut causal block waste / fuse head passes",
        "memory": "cut HBM traffic: larger chunks, fp8 weights, fewer passes",
        "collective": "cut collectives: defer head x̄ reduce, a2a MoE dispatch",
    }
    return Roofline(arch=arch, shape=shape_name,
                    compute_s=compute_s, memory_s=memory_s,
                    collective_s=collective_s, dominant=dominant,
                    model_flops=model_flops, hlo_flops=fl["total"],
                    useful_ratio=model_flops / max(fl["total"], 1.0),
                    note=notes[dominant])


def full_table(multi_pod: bool = False):
    rows = []
    from repro.configs.registry import ARCHS
    for arch in ARCHS:
        for shape in SHAPES:
            r = analyze_cell(arch, shape, multi_pod=multi_pod)
            if r is not None:
                rows.append(r.row())
    return rows


if __name__ == "__main__":
    import json
    print(json.dumps(full_table(), indent=1))
