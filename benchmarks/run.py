"""Benchmark driver — one section per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only mem # one section

Prints ``name,us_per_call,derived...`` CSV rows per section, and appends
every section's rows (with a timestamp) to a ``BENCH_*.json`` trajectory
file so successive runs build a perf history (fused-vs-unfused temp bytes
and µs/call land there via the ``kernels`` section).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys
import time

_JSON_ROWS: list = []

# the committed BENCH_*.json files live next to this package at the repo
# root — anchor there, not at the cwd, so --show-trajectory (and the
# trajectory loaders in tests) see the history from any directory
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def bench_files(root: str | None = None) -> list:
    """Every ``BENCH_<n>.json`` present, ordered by ``n`` — tolerating
    gaps (BENCH_1/2 were never committed), renumbering, and stray
    non-numeric names (ignored).  Nothing here assumes a dense sequence.
    ``root`` defaults to the repo root (where the files are committed),
    NOT the cwd — running from elsewhere used to render an empty
    trajectory."""
    if root is None:
        root = REPO_ROOT
    out = []
    for p in glob.glob(os.path.join(root, "BENCH_*.json")):
        m = re.fullmatch(r"BENCH_(\d+)\.json", os.path.basename(p))
        if m:
            out.append((int(m.group(1)), p))
    return [p for _, p in sorted(out)]


def load_trajectory(root: str | None = None) -> list:
    """The merged perf history across every ``BENCH_*.json``: a flat list
    of run entries ({ts, sections, rows, file}), oldest file first.
    Unreadable or malformed files are skipped, never fatal — the loader's
    contract is that a gap or a bad file can't sink the whole history."""
    hist = []
    for p in bench_files(root):
        try:
            with open(p) as f:
                entries = json.load(f)
        except (json.JSONDecodeError, OSError):
            continue
        if not isinstance(entries, list):
            continue
        for e in entries:
            if isinstance(e, dict):
                hist.append({**e, "file": os.path.basename(p)})
    return hist


def _resolve_json_path(arg: str) -> str:
    """``--json auto`` appends to the highest-numbered existing
    ``BENCH_<n>.json`` (or starts BENCH_1.json); anything else is a
    literal path."""
    if arg != "auto":
        return arg
    files = bench_files()
    return files[-1] if files else os.path.join(REPO_ROOT, "BENCH_1.json")


def _emit(rows):
    for r in rows:
        _JSON_ROWS.append(dict(r))
        r = dict(r)
        name = r.pop("name")
        us = r.pop("us_per_call", r.pop("us_per_step", ""))
        derived = ",".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{us},{derived}", flush=True)


def _jsonable(v):
    """Plain JSON value: numpy/jax scalars → python, non-finite → None."""
    if hasattr(v, "item"):
        v = v.item()
    if isinstance(v, float) and (v != v or v in (float("inf"),
                                                 float("-inf"))):
        return None
    return v


def _append_trajectory(path: str, sections: list) -> None:
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                history = json.load(f)
        except (json.JSONDecodeError, OSError):
            history = []
    rows = [{k: _jsonable(v) for k, v in r.items()} for r in _JSON_ROWS]
    history.append({"ts": round(time.time(), 1), "sections": sections,
                    "rows": rows})
    # serialize fully before touching the file: a dump error must not
    # truncate the accumulated history
    text = json.dumps(history, indent=1, allow_nan=False)
    with open(path, "w") as f:
        f.write(text)


SECTIONS = {}


def section(name):
    def deco(fn):
        SECTIONS[name] = fn
        return fn
    return deco


@section("parity")      # paper Table 2/3
def _parity():
    from benchmarks.paper_tables import bench_convergence_parity
    _emit(bench_convergence_parity())


@section("grid")        # paper Fig. 2a
def _grid():
    from benchmarks.paper_tables import bench_precision_grid
    _emit(bench_precision_grid())


@section("ranges")      # paper Fig. 2b / 5
def _ranges():
    from benchmarks.paper_tables import bench_range_histograms
    _emit(bench_range_histograms())


@section("chunks")      # paper Table 10
def _chunks():
    from benchmarks.paper_tables import bench_chunk_sweep
    _emit(bench_chunk_sweep())


@section("mem")         # paper Fig. 4 + §4.4
def _mem():
    from benchmarks.paper_tables import bench_memory_vs_labels
    _emit(bench_memory_vs_labels())


@section("stability")   # paper §5 Renee instability
def _stability():
    from benchmarks.paper_tables import bench_stability
    _emit(bench_stability())


@section("kernels")
def _kernels():
    from benchmarks.kernel_bench import (bench_fp8_logits, bench_fused_chunk,
                                         bench_fused_update,
                                         bench_grid_head,
                                         bench_sharded_head)
    _emit(bench_grid_head())        # whole-head 1-launch grid vs chunk scan
    _emit(bench_fused_chunk())      # single-launch megakernel vs 3-launch
    _emit(bench_sharded_head())     # per-device temp bytes, label-sharded
    _emit(bench_fused_update())
    _emit(bench_fp8_logits())


@section("serving")     # ISSUE 5: streaming top-k megakernel (DESIGN.md §9)
def _serving():         # ISSUE 7: + 2-stage shortlisted serving (§11)
    from benchmarks.kernel_bench import (bench_serving_topk,
                                         bench_shortlist_topk)
    _emit(bench_serving_topk())     # 1 launch, O(B·k) temps vs materialize
    _emit(bench_shortlist_topk())   # recall-gated (≥0.95) 2-stage serving


@section("serve_runtime")   # ISSUE 8: deadline-aware runtime (DESIGN.md §12)
def _serve_runtime():
    from benchmarks.kernel_bench import bench_serve_runtime
    _emit(bench_serve_runtime())    # fault-injected overload soak, hard-gated


@section("sparse")      # ISSUE 9: fixed-fan-in sparse head (DESIGN.md §13)
def _sparse():
    from benchmarks.kernel_bench import bench_sparse_head
    _emit(bench_sparse_head())      # kernel≡oracle parity + ≥10× mem gate


@section("numerics")    # ISSUE 10: numerics guard (DESIGN.md §14)
def _numerics():
    from benchmarks.kernel_bench import bench_numerics_guard
    _emit(bench_numerics_guard())   # BENCH_10: overhead + detect/recover


@section("plan")        # HeadPlan resolution (DESIGN.md §8): predicted rows
def _plan():
    from repro.configs import get_config
    from repro.head import default_target_slots, head_config_for, resolve_plan
    rows = []
    for arch, batch, n in (("xmc-bert-3m", 128, 1), ("xmc-bert-3m", 128, 4),
                           ("xmc-bert-3m-sparse", 128, 1),
                           ("smollm-360m", 8 * 32, 1)):
        cfg = get_config(arch)
        hcfg = head_config_for(cfg)
        plan = resolve_plan(
            hcfg, batch=batch, target_slots=default_target_slots(cfg),
            model_size=n, model_axis="model" if n > 1 else None)
        rows.append({
            "name": f"plan/{arch}/n{n}",
            "us_per_call": 0,              # resolution is trace-time only
            "path": plan.path, "inner": plan.train_inner,
            "fan_in": plan.fan_in,
            "block_l": plan.block_l, "cache_z": plan.cache_z,
            "temp_bytes": plan.temp_bytes, "vmem_bytes": plan.vmem_bytes,
            "fallback": plan.fallback_reason or "none",
        })
    _emit(rows)


@section("roofline")    # §Roofline table (analytic; dry-run mem separate)
def _roofline():
    from benchmarks.roofline import full_table
    rows = []
    for r in full_table():
        rows.append({
            "name": f"roofline/{r['arch']}/{r['shape']}",
            "compute_ms": round(r["compute_s"] * 1e3, 2),
            "memory_ms": round(r["memory_s"] * 1e3, 2),
            "collective_ms": round(r["collective_s"] * 1e3, 2),
            "dominant": r["dominant"],
            "useful_ratio": round(r["useful_ratio"], 3),
        })
    _emit(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=list(SECTIONS), default=None)
    ap.add_argument("--json", default="BENCH_trajectory.json",
                    help="append rows to this BENCH_*.json history file "
                         "('auto' = highest-numbered existing BENCH_<n>"
                         ".json, '' disables)")
    ap.add_argument("--show-trajectory", action="store_true",
                    help="print a one-line summary per recorded run "
                         "across every BENCH_*.json (gap-tolerant) "
                         "and exit")
    args = ap.parse_args()
    if args.show_trajectory:
        for e in load_trajectory():
            print(f"{e['file']}: ts={e.get('ts')} "
                  f"sections={','.join(e.get('sections', []))} "
                  f"rows={len(e.get('rows', []))}")
        return
    todo = [args.only] if args.only else list(SECTIONS)
    t0 = time.time()
    failed = []
    for name in todo:
        print(f"# === {name} ===", flush=True)
        try:
            SECTIONS[name]()
        except Exception as e:  # noqa: BLE001 — finish the other sections
            failed.append(name)
            print(f"{name},ERROR,{type(e).__name__}: {e}", file=sys.stderr)
    if args.json:
        _append_trajectory(_resolve_json_path(args.json), todo)
    print(f"# done in {time.time() - t0:.1f}s")
    if failed:
        # a failed section (incl. its in-bench acceptance asserts, e.g.
        # the serving top-k parity/temp-byte gate) must fail the CI step
        print(f"# FAILED sections: {', '.join(failed)}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
