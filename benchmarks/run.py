"""Benchmark driver — one section per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only mem # one section

Prints ``name,us_per_call,derived...`` CSV rows per section, and appends
every section's rows (with a timestamp) to a ``BENCH_*.json`` trajectory
file so successive runs build a perf history (fused-vs-unfused temp bytes
and µs/call land there via the ``kernels`` section).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_JSON_ROWS: list = []


def _emit(rows):
    for r in rows:
        _JSON_ROWS.append(dict(r))
        r = dict(r)
        name = r.pop("name")
        us = r.pop("us_per_call", r.pop("us_per_step", ""))
        derived = ",".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{us},{derived}", flush=True)


def _jsonable(v):
    """Plain JSON value: numpy/jax scalars → python, non-finite → None."""
    if hasattr(v, "item"):
        v = v.item()
    if isinstance(v, float) and (v != v or v in (float("inf"),
                                                 float("-inf"))):
        return None
    return v


def _append_trajectory(path: str, sections: list) -> None:
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                history = json.load(f)
        except (json.JSONDecodeError, OSError):
            history = []
    rows = [{k: _jsonable(v) for k, v in r.items()} for r in _JSON_ROWS]
    history.append({"ts": round(time.time(), 1), "sections": sections,
                    "rows": rows})
    # serialize fully before touching the file: a dump error must not
    # truncate the accumulated history
    text = json.dumps(history, indent=1, allow_nan=False)
    with open(path, "w") as f:
        f.write(text)


SECTIONS = {}


def section(name):
    def deco(fn):
        SECTIONS[name] = fn
        return fn
    return deco


@section("parity")      # paper Table 2/3
def _parity():
    from benchmarks.paper_tables import bench_convergence_parity
    _emit(bench_convergence_parity())


@section("grid")        # paper Fig. 2a
def _grid():
    from benchmarks.paper_tables import bench_precision_grid
    _emit(bench_precision_grid())


@section("ranges")      # paper Fig. 2b / 5
def _ranges():
    from benchmarks.paper_tables import bench_range_histograms
    _emit(bench_range_histograms())


@section("chunks")      # paper Table 10
def _chunks():
    from benchmarks.paper_tables import bench_chunk_sweep
    _emit(bench_chunk_sweep())


@section("mem")         # paper Fig. 4 + §4.4
def _mem():
    from benchmarks.paper_tables import bench_memory_vs_labels
    _emit(bench_memory_vs_labels())


@section("stability")   # paper §5 Renee instability
def _stability():
    from benchmarks.paper_tables import bench_stability
    _emit(bench_stability())


@section("kernels")
def _kernels():
    from benchmarks.kernel_bench import (bench_fp8_logits, bench_fused_chunk,
                                         bench_fused_update,
                                         bench_grid_head,
                                         bench_sharded_head)
    _emit(bench_grid_head())        # whole-head 1-launch grid vs chunk scan
    _emit(bench_fused_chunk())      # single-launch megakernel vs 3-launch
    _emit(bench_sharded_head())     # per-device temp bytes, label-sharded
    _emit(bench_fused_update())
    _emit(bench_fp8_logits())


@section("plan")        # HeadPlan resolution (DESIGN.md §8): predicted rows
def _plan():
    from repro.configs import get_config
    from repro.head import default_target_slots, head_config_for, resolve_plan
    rows = []
    for arch, batch, n in (("xmc-bert-3m", 128, 1), ("xmc-bert-3m", 128, 4),
                           ("smollm-360m", 8 * 32, 1)):
        cfg = get_config(arch)
        hcfg = head_config_for(cfg)
        plan = resolve_plan(
            hcfg, batch=batch, target_slots=default_target_slots(cfg),
            model_size=n, model_axis="model" if n > 1 else None)
        rows.append({
            "name": f"plan/{arch}/n{n}",
            "us_per_call": 0,              # resolution is trace-time only
            "path": plan.path, "inner": plan.train_inner,
            "block_l": plan.block_l, "cache_z": plan.cache_z,
            "temp_bytes": plan.temp_bytes, "vmem_bytes": plan.vmem_bytes,
            "fallback": plan.fallback_reason or "none",
        })
    _emit(rows)


@section("roofline")    # §Roofline table (analytic; dry-run mem separate)
def _roofline():
    from benchmarks.roofline import full_table
    rows = []
    for r in full_table():
        rows.append({
            "name": f"roofline/{r['arch']}/{r['shape']}",
            "compute_ms": round(r["compute_s"] * 1e3, 2),
            "memory_ms": round(r["memory_s"] * 1e3, 2),
            "collective_ms": round(r["collective_s"] * 1e3, 2),
            "dominant": r["dominant"],
            "useful_ratio": round(r["useful_ratio"], 3),
        })
    _emit(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=list(SECTIONS), default=None)
    ap.add_argument("--json", default="BENCH_trajectory.json",
                    help="append rows to this BENCH_*.json history file "
                         "('' disables)")
    args = ap.parse_args()
    todo = [args.only] if args.only else list(SECTIONS)
    t0 = time.time()
    for name in todo:
        print(f"# === {name} ===", flush=True)
        try:
            SECTIONS[name]()
        except Exception as e:  # noqa: BLE001 — keep the harness running
            print(f"{name},ERROR,{type(e).__name__}: {e}", file=sys.stderr)
    if args.json:
        _append_trajectory(args.json, todo)
    print(f"# done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
