"""Kernel microbenchmarks: fused vs unfused head update, fp8 vs bf16 matmul,
and the single-launch fused chunk megakernel vs the legacy 3-launch path.

On this CPU container the Pallas kernels run in interpret mode, so absolute
times are meaningless for TPU; what IS meaningful here (and reported) is
the *memory* side: the fused paths materialize no (B, L) logits, no (B, L)
gradient and no weight copy — verified by jitting both and comparing XLA's
``memory_analysis()`` temp bytes.  Wall-times are reported for the XLA
(production-fallback) paths.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _time(f, *args, n=10):
    jax.block_until_ready(f(*args))          # warm up exactly once
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(f(*args))      # block per iteration: no
    return (time.time() - t0) / n * 1e6      # async-dispatch pile-up


def _temp_bytes(jitted, *args) -> int:
    mem = jitted.lower(*args).compile().memory_analysis()
    return int(mem.temp_size_in_bytes)


def bench_fused_update(L=4096, D=256, B=256):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    g = jax.random.normal(ks[0], (B, L), jnp.bfloat16) * 0.1
    x = jax.random.normal(ks[1], (B, D), jnp.bfloat16)
    w = (jax.random.normal(ks[2], (L, D)) * 0.05).astype(jnp.float8_e4m3fn)
    seed = jnp.uint32(0)

    fused = jax.jit(lambda g, x, w: ref.fused_head_update_ref(
        g, x, w, 0.05, 0.0, seed))

    def unfused_fn(g, x, w):
        # materializes dW (L, D) f32 then SR — what the fusion removes
        dw = jax.lax.dot_general(g, x, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        w_new = w.astype(jnp.float32) - 0.05 * dw
        from repro.core import precision as P
        from repro.kernels import prng_utils as PR
        bits = PR.hash_bits_nd(seed, w_new.shape)
        return P.sr_bits_e4m3(w_new, bits)

    unfused = jax.jit(unfused_fn)

    t_f = _time(fused, g, x, w)
    t_u = _time(unfused, g, x, w)
    return [{"name": "kernel/fused_update", "us_per_call": round(t_f),
             "temp_mib": round(_temp_bytes(fused, g, x, w) / 2**20, 2)},
            {"name": "kernel/unfused_update", "us_per_call": round(t_u),
             "temp_mib": round(_temp_bytes(unfused, g, x, w) / 2**20, 2)}]


def bench_fp8_logits(L=4096, D=256, B=256):
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    x = jax.random.normal(ks[0], (B, D), jnp.bfloat16)
    w8 = (jax.random.normal(ks[1], (L, D)) * 0.05).astype(jnp.float8_e4m3fn)
    w16 = w8.astype(jnp.bfloat16)
    f8 = jax.jit(lambda x, w: ref.fp8_logits_ref(x, w))
    f16 = jax.jit(lambda x, w: ref.fp8_logits_ref(x, w, quantize_x=False))
    t8, t16 = _time(f8, x, w8), _time(f16, x, w16)
    return [{"name": "kernel/fp8_logits", "us_per_call": round(t8),
             "w_bytes": w8.nbytes},
            {"name": "kernel/bf16_logits", "us_per_call": round(t16),
             "w_bytes": w16.nbytes}]


def bench_sharded_head(L=4096, D=256, B=256, shards=(1, 2, 4)):
    """Per-device footprint of the label-sharded fused chunk step.

    Under vocab parallelism every device runs the *same* program on its
    L/n label rows (core/elmo_head.head_train_step_sharded), so the
    per-device transient memory is exactly the single-device fused chunk
    step compiled at the local width — measured here via XLA's
    ``memory_analysis()`` temp bytes, without needing a forced multi-device
    backend inside the bench process.  The tuner's local-shard tile
    (``chunk_block_l(..., n_shards=n)``) is reported alongside.
    """
    from repro.head import ELMOHeadConfig, resolve_plan
    from repro.kernels import ops, tuning

    rows = []
    for n in shards:
        Lc = L // n
        # the HeadPlan this geometry resolves to (one chunk of L rows,
        # label-sharded n ways) — predicted bytes ride along with the
        # measured ones so drift shows in the trajectory
        plan = resolve_plan(
            ELMOHeadConfig(num_labels=L, d_model=D, num_chunks=1,
                           weight_dtype="e4m3", loss="bce",
                           impl="fused_interpret"),
            batch=B, target_slots=8, model_size=n,
            model_axis="model" if n > 1 else None)
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        x = (jax.random.normal(ks[0], (B, D)) * 0.5).astype(jnp.bfloat16)
        w = (jax.random.normal(ks[1], (Lc, D)) * 0.05
             ).astype(jnp.float8_e4m3fn)
        xg = jnp.zeros((B, D), jnp.bfloat16)
        tg = jax.random.randint(ks[2], (B, 8), 0, L)
        args = (x, w, tg, xg, jnp.float32(0.05), jnp.float32(0.0),
                jnp.float32(1.0 / B), jnp.int32(0), jnp.uint32(3),
                jnp.uint32(5))
        kw = dict(loss="bce", num_labels=L)
        fused_k = jax.jit(lambda *a: ops.fused_chunk_step(
            *a, impl="interpret", **kw))
        fused_x = jax.jit(lambda *a: ops.fused_chunk_step(
            *a, impl="xla", **kw))
        b = _temp_bytes(fused_k, *args)
        rows.append({
            "name": f"kernel/sharded_chunk_n{n}",
            "us_per_call": round(_time(fused_x, *args)),
            "per_device_temp_bytes": b,
            "temp_mib": round(b / 2**20, 2),
            "local_rows": Lc,
            "block_l": tuning.chunk_block_l(B, L, D, 1, n_shards=n),
            "plan_path": plan.path,
            "plan_block_l": plan.block_l,
            "plan_temp_bytes": plan.temp_bytes,
        })
    return rows


def bench_grid_head(L=4096, D=256, B=256, num_chunks=8, shard_widths=(1, 4)):
    """Whole-head grid megakernel (one launch/step, DESIGN.md §7) vs the
    PR-1 per-chunk scan, at the head level.

    Reported per path: wall-clock per step of the jitted *interpret
    lowering* (both rows run the same backend, so the number is honest
    only relative — absolute CPU-interpret µs say nothing about TPU), the
    *statically counted* runtime launch count (``kernels/introspect.py``),
    and XLA's ``memory_analysis()`` temp bytes of the same lowerings —
    the acceptance metric: the grid step's transients must not exceed the
    per-chunk scan's.

    ``shard_widths`` emulates label sharding exactly like
    ``bench_sharded_head``: every device of an n-way vocab-parallel head
    runs the same program at ``L/n`` label rows, so the per-device numbers
    are the single-device numbers at the local width.
    """
    import dataclasses

    from repro import head as H
    from repro.head import resolve_plan
    from repro.kernels import introspect, tuning

    rows = []
    for n in shard_widths:
        cfg = H.ELMOHeadConfig(num_labels=L // n, d_model=D,
                               num_chunks=num_chunks, weight_dtype="e4m3",
                               loss="bce", impl="grid_interpret")
        state = H.init_head(jax.random.PRNGKey(0), cfg)
        x = (jax.random.normal(jax.random.PRNGKey(1), (B, D)) * 0.5
             ).astype(jnp.bfloat16)
        tg = jax.random.randint(jax.random.PRNGKey(2), (B, 8), 0,
                                cfg.num_labels)
        hp = (jnp.float32(0.05), jnp.float32(0.0), jnp.uint32(7))

        def step(c):
            return jax.jit(lambda s, xx, t: H.head_train_step(
                c, s, xx, t, *hp))

        variants = {"grid": cfg,
                    "fused_scan": dataclasses.replace(
                        cfg, impl="fused_interpret")}
        temp = {}
        for name, c in variants.items():
            f = step(c)
            temp[name] = _temp_bytes(f, state, x, tg)
            launches = introspect.count_pallas_launches(
                lambda s, xx, t: H.head_train_step(c, s, xx, t, *hp),
                state, x, tg)
            t_us = _time(f, state, x, tg, n=3)
            # the plan this variant resolves to — its predicted transient
            # bytes land next to the measured temp bytes (drift tracking)
            plan = resolve_plan(c, batch=B, target_slots=8)
            rows.append({
                "name": f"kernel/head_{name}_n{n}",
                "us_per_call": round(t_us),
                "launches_per_step": launches,
                "temp_mib": round(temp[name] / 2**20, 2),
                "temp_size_in_bytes": temp[name],
                "local_labels": cfg.num_labels,
                # the block the measured (interpret, exact-shape) runs use
                "block_l": cfg.chunk,
                # the tile the compiled launch would pick, sized with the
                # benchmarked step's real target-slot count
                "tuned_block_l": tuning.head_grid_block_l(
                    B, cfg.chunk, D, 1, n_chunks=num_chunks, p_slots=8),
                "plan_path": plan.path,
                "plan_block_l": plan.block_l,
                "plan_temp_bytes": plan.temp_bytes,
                "plan_vmem_bytes": plan.vmem_bytes,
            })
        assert temp["grid"] <= temp["fused_scan"], temp   # acceptance
    return rows


def bench_serving_topk(L=4096, D=256, B=256, k=10, num_chunks=8):
    """Top-k serving: the single-launch streaming megakernel vs the
    materialized fast path vs the per-chunk streaming scan (ISSUE 5).

    All three produce bit-identical (values, ids) — asserted here before
    timing.  Reported per path: µs/call of the jitted lowering actually
    runnable on this backend, the statically counted Pallas launch count
    (1 for the kernel, 1 for materialize, C for the interpret scan), and
    XLA ``memory_analysis()`` temp bytes — the acceptance metric: the
    streaming kernel's transients are O(B·k) and must undercut the
    materialized path's O(B·L) by ≥ 4× at the default shape.
    """
    import dataclasses

    from repro import head as H
    from repro.head import resolve_plan, serving
    from repro.kernels import introspect

    cfg = H.ELMOHeadConfig(num_labels=L, d_model=D, num_chunks=num_chunks,
                           weight_dtype="e4m3", loss="bce",
                           impl="grid_interpret")
    state = H.init_head(jax.random.PRNGKey(0), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(1), (B, D)) * 0.5
         ).astype(jnp.bfloat16)

    # pin the two fallback paths by overriding the resolved plan's
    # topk_path (bit-parity across paths is part of the contract, so the
    # override cannot change results — asserted below)
    plan_k = resolve_plan(cfg, batch=B)
    plan_mat = dataclasses.replace(plan_k, topk_path="materialize")
    plan_str = dataclasses.replace(plan_k, topk_path="stream")
    jobs = {
        "kernel": (plan_k, jax.jit(
            lambda s, xx: serving.topk_planned(plan_k, cfg, s, xx, k))),
        "materialize": (plan_mat, jax.jit(
            lambda s, xx: serving.topk_planned(plan_mat, cfg, s, xx, k))),
        "stream": (plan_str, jax.jit(
            lambda s, xx: serving.topk_planned(plan_str, cfg, s, xx, k))),
    }
    # Interpret-mode Pallas carries each call's whole W operand through
    # its grid while-loop (entry + carry copies: 2 × W bytes — see
    # jax pallas_call._pallas_call_impl_interpret's "(i, loop_idx,
    # *consts, *ins, *outs, *scratch)" carry).  On TPU the W stream is a
    # double-buffered DMA, never an XLA temp, so the bench reports both
    # the raw temp bytes and the data-path bytes with that per-variant
    # carry subtracted — the number the acceptance ratio is about.
    w_bytes = int(state.w.size) * jnp.dtype(state.w.dtype).itemsize
    interp = jax.default_backend() != "tpu"   # TPU compiles: no carry
    carry = {"kernel": 2 * w_bytes, "materialize": 2 * w_bytes,
             "stream": 2 * (w_bytes // num_chunks)}   # scan carries 1 chunk
    if not interp:
        carry = {name: 0 for name in carry}
    outs, rows, temps = {}, [], {}
    for name, (plan, f) in jobs.items():
        outs[name] = jax.block_until_ready(f(state, x))
        raw = _temp_bytes(f, state, x)
        # subtract the carry only while it is a strict lower bound of the
        # measurement — never clamp to 0, which would make the ≥4×
        # acceptance assert below vacuous if the estimate overshoots
        temps[name] = raw - carry[name] if raw > carry[name] else raw
        launches = introspect.count_pallas_launches(
            lambda s, xx: serving.topk_planned(plan, cfg, s, xx, k),
            state, x)
        rows.append({
            "name": f"serving/topk_{name}",
            "us_per_call": round(_time(f, state, x, n=3)),
            "launches": launches,
            "temp_size_in_bytes": raw,
            "interp_w_carry_bytes": carry[name],
            "temp_bytes_data_path": temps[name],
            "temp_mib": round(temps[name] / 2**20, 3),
            "B": B, "L": L, "D": D, "k": k,
        })
    import numpy as np
    for name in ("materialize", "stream"):
        np.testing.assert_array_equal(np.asarray(outs["kernel"][0]),
                                      np.asarray(outs[name][0]))
        np.testing.assert_array_equal(np.asarray(outs["kernel"][1]),
                                      np.asarray(outs[name][1]))
    # acceptance: ≥ 4× data-path temp-byte reduction vs materialized
    assert temps["kernel"] * 4 <= temps["materialize"], temps
    return rows


def bench_shortlist_topk(L=4096, D=64, B=256, k=10, num_chunks=8,
                         groups=128, noise=0.2, n_clusters=64, beam=28):
    """2-stage shortlisted serving vs exact top-k (ISSUE 7, DESIGN §11).

    Runs the golden structured-head geometry (``shortlist.
    synthetic_clustered_state``: labels drawn around latent group
    centers — the regime trained XMC heads live in; an i.i.d. head has
    no cluster structure and shortlisting it is meaningless).  Reported:
    µs/call and QPS for exact vs 2-stage serving, the admitted-label
    fraction (the work ratio a compiled backend realizes), and
    recall@{1,5,10} of shortlisted vs exact results.

    Two hard gates (a failure exits the bench driver non-zero):

    * the shortlisted (values, ids) are bit-identical to the restricted
      oracle ``ref.fused_topk_ref`` on the same (assign, beam) — the
      beam is the ONLY approximation;
    * recall@10 ≥ ``RECALL_FLOOR`` — the regression tripwire for the
      partition build and the stage-1 router.
    """
    import numpy as np

    from repro import head as H
    from repro.head import resolve_plan, serving
    from repro.head import shortlist as SL

    RECALL_FLOOR = 0.95
    cfg = H.ELMOHeadConfig(num_labels=L, d_model=D, num_chunks=num_chunks,
                           weight_dtype="e4m3", use_sr=False,
                           impl="grid_interpret", shortlist="on")
    state = SL.synthetic_clustered_state(cfg, groups=groups, noise=noise,
                                         seed=7)
    x = jax.random.normal(jax.random.PRNGKey(11), (B, D)
                          ).astype(jnp.bfloat16)
    index = SL.build_shortlist_index(cfg, state, n_clusters=n_clusters,
                                     beam=beam, iters=8, seed=0)
    plan = resolve_plan(cfg, batch=B)
    assert plan.topk_path == "shortlist", plan.topk_path
    # pin the bench geometry (the auto plan sizes for generic heads;
    # recall is a property of THIS index)
    import dataclasses
    plan = dataclasses.replace(plan, shortlist_c=index.n_clusters,
                               shortlist_beam=index.beam)
    plan_exact = dataclasses.replace(plan, topk_path="kernel",
                                     shortlist_c=0, shortlist_beam=0)

    f_sl = jax.jit(lambda s, xx: serving.topk_planned(plan, cfg, s, xx, k,
                                                      index))
    f_ex = jax.jit(
        lambda s, xx: serving.topk_planned(plan_exact, cfg, s, xx, k))
    out_sl = jax.block_until_ready(f_sl(state, x))
    jax.block_until_ready(f_ex(state, x))

    # gate 1: bit-parity against the restricted oracle
    beam_ids = SL.shortlist_clusters(index, x, impl="xla")
    want = ref.fused_topk_ref(
        x, state.w, jnp.zeros((num_chunks,), jnp.uint32),
        jnp.arange(num_chunks, dtype=jnp.int32) * cfg.chunk, k=k,
        num_labels=L, quantize_x=cfg.qx, assign=index.assign,
        beam=beam_ids)
    np.testing.assert_array_equal(np.asarray(out_sl[0]),
                                  np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(out_sl[1]),
                                  np.asarray(want[1]))

    # gate 2: recall floor
    recall = SL.shortlist_recall_at_k(cfg, state, index, x, ks=(1, 5, 10))
    assert recall[10] >= RECALL_FLOOR, \
        f"shortlist recall@10 {recall[10]} below floor {RECALL_FLOOR}"

    us_ex = _time(f_ex, state, x, n=3)
    us_sl = _time(f_sl, state, x, n=3)
    cap = -(-L // index.n_clusters)
    admitted_frac = index.beam * cap / L
    common = {"B": B, "L": L, "D": D, "k": k}
    return [
        {"name": "serving/shortlist_exact",
         "us_per_call": round(us_ex), "qps": round(B / us_ex * 1e6),
         **common},
        {"name": "serving/shortlist_2stage",
         "us_per_call": round(us_sl), "qps": round(B / us_sl * 1e6),
         "qps_vs_exact": round(us_ex / us_sl, 3),
         "n_clusters": index.n_clusters, "beam": index.beam,
         "admitted_label_frac": round(admitted_frac, 4),
         "recall_at_1": recall[1], "recall_at_5": recall[5],
         "recall_at_10": recall[10], "recall_floor": RECALL_FLOOR,
         **common},
    ]


def bench_serve_runtime(L=4096, D=64, B=16, k=10, num_chunks=8,
                        groups=128, noise=0.2, slo_s=0.05):
    """The deadline-aware serving runtime under a seeded overload soak
    (ISSUE 8, DESIGN §12): real head, virtual clock, fault injection.

    The golden structured head serves through a 2-rung ladder (exact +
    the recall-gated shortlist); a Poisson steady → 20k-qps burst →
    recovery trace with injected transient dispatch failures drives
    continuous batching, admission shedding, and the degradation
    controller.  The trace replays on a virtual clock with model
    timing, so every number below is deterministic — the report IS the
    artifact.

    Four hard gates (a failure exits the bench driver non-zero):

    * conservation — submitted == completed + rejected + timed_out, and
      every request reached exactly one terminal state;
    * SLO — p99 of completed requests within the deadline, and ≥99% of
      admitted requests met theirs;
    * the ladder ENGAGED during the burst and RECOVERED to exact after;
    * bit-identical replay — the whole soak run twice gives the same
      report.
    """
    import numpy as np

    from repro import head as H
    from repro import serve as RS
    from repro.fault import inject as FI
    from repro.head import ELMOHead
    from repro.head import shortlist as SL

    cfg = H.ELMOHeadConfig(num_labels=L, d_model=D, num_chunks=num_chunks,
                           weight_dtype="e4m3", use_sr=False)
    state = SL.synthetic_clustered_state(cfg, groups=groups, noise=noise,
                                         seed=7)
    head = ELMOHead(cfg, batch=B)
    probe = jax.random.normal(jax.random.PRNGKey(11), (64, D)
                              ).astype(jnp.bfloat16)
    levels = RS.build_ladder(head, state, k=k, max_batch=B, probe_x=probe,
                             iters=8, n_clusters=64, beam=28)
    assert [lv.name for lv in levels] == ["exact", "shortlist"], levels

    def trace():
        base = FI.poisson_requests(rate_qps=300, horizon_s=0.5, seed=1,
                                   d_model=D, k=k, deadline_s=slo_s)
        burst = FI.poisson_requests(rate_qps=20000, horizon_s=0.3, seed=2,
                                    d_model=D, k=k, deadline_s=slo_s,
                                    t0=0.5, rid0=len(base))
        cool = FI.poisson_requests(rate_qps=300, horizon_s=0.5, seed=3,
                                   d_model=D, k=k, deadline_s=slo_s,
                                   t0=0.8, rid0=len(base) + len(burst))
        return base + burst + cool

    def run():
        ex = FI.FailingExecutor(RS.HeadExecutor(state, timing="model"),
                                fail_calls=[3, 40])
        srv = RS.Server(ex, levels,
                        cfg=RS.ServeConfig(max_batch=B, max_queue=256,
                                           slo_s=slo_s),
                        estimator=RS.ServiceEstimator(RS.ServiceModel()))
        reqs = trace()
        rep = RS.run_trace(srv, reqs).report()
        assert all(r.outcome is not None for r in reqs)
        return rep

    t0 = time.time()
    rep = run()
    wall_s = time.time() - t0

    # gate 1: conservation
    assert rep["conserved"], rep
    # gate 2: the SLO held for admitted traffic
    assert rep["p99_ms"] <= slo_s * 1e3, rep["p99_ms"]
    assert rep["deadline_met_of_admitted"] >= 0.99, rep
    # gate 3: the ladder engaged under the burst and fully recovered
    frm_to = [(f, t) for _, f, t, _ in rep["transitions"]]
    assert (0, 1) in frm_to, rep["transitions"]
    assert rep["transitions"][-1][2] == 0, rep["transitions"]
    assert rep["level_dispatches"].get("shortlist", 0) > 0, rep
    assert rep["shed_rate"] > 0.0, "overload burst never shed"
    assert rep["dispatch_retries"] >= 1, "injected faults never fired"
    # gate 4: deterministic replay
    assert run() == rep, "soak replay is not bit-identical"

    shortlist_lv = levels[1]
    return [
        {"name": "serve_runtime/soak",
         "us_per_call": round(1e3 * rep["p50_ms"]),   # p50 latency in µs
         "submitted": rep["submitted"], "completed": rep["completed"],
         "rejected": rep["rejected"], "timed_out": rep["timed_out"],
         "shed_rate": round(rep["shed_rate"], 4),
         "timeout_rate": round(rep["timeout_rate"], 6),
         "p50_ms": round(rep["p50_ms"], 3), "p95_ms": round(rep["p95_ms"], 3),
         "p99_ms": round(rep["p99_ms"], 3), "slo_ms": slo_s * 1e3,
         "deadline_met_of_admitted": round(
             rep["deadline_met_of_admitted"], 5),
         "qps": round(rep["qps"]), "fill": round(rep["fill"], 4),
         "max_depth": rep["max_depth"], "B": B, "L": L, "D": D, "k": k,
         "bench_wall_s": round(wall_s, 1)},
        {"name": "serve_runtime/degradation",
         "us_per_call": 0,
         "transitions": len(rep["transitions"]),
         "engaged_at_signal": round(rep["transitions"][0][3], 3),
         "recovered": rep["transitions"][-1][2] == 0,
         "exact_dispatches": rep["level_dispatches"].get("exact", 0),
         "shortlist_dispatches": rep["level_dispatches"].get(
             "shortlist", 0),
         "dispatch_retries": rep["dispatch_retries"],
         "rung_recall": round(shortlist_lv.recall, 4),
         "rung_cost_scale": round(shortlist_lv.cost_scale, 4)},
    ]


def bench_sparse_head(L=4096, D=256, B=256, F=32, num_chunks=8):
    """Fixed-fan-in sparse head step (DESIGN.md §13) + the §13 memory gate.

    Measured: the whole-step sparse megakernel (interpret lowering) vs
    the XLA oracle scan at a synthetic shape — bit-parity of the updated
    value slots and x̄ asserted first, then XLA ``memory_analysis()``
    temp bytes per path and µs/call for the XLA (production non-TPU)
    path.  The per-step weight+optimizer stream bytes ride along: HBM
    weight traffic scales with ``fan_in``, not ``d_model``.

    Modeled, fail-hard: head weight+optimizer bytes (FP8 values + i32
    index plane + Kahan comp) of each registered sparse XMC variant vs
    its dense base arch, from ``core.memory_model.head_components`` —
    the acceptance gate is **≥10×** at the variant's configured fan-in.
    """
    import dataclasses

    import numpy as np

    from repro import head as H
    from repro.configs import get_config
    from repro.core import memory_model as MM
    from repro.head import resolve_plan
    from repro.head.sparse.state import init_sparse_head
    from repro.head.sparse.train import train_step_sparse

    cfg = H.ELMOHeadConfig(num_labels=L, d_model=D, num_chunks=num_chunks,
                           weight_dtype="e4m3", loss="bce", fan_in=F,
                           impl="grid_interpret")
    state = init_sparse_head(jax.random.PRNGKey(0), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(1), (B, D)) * 0.5
         ).astype(jnp.bfloat16)
    tg = jax.random.randint(jax.random.PRNGKey(2), (B, 8), 0, L)
    hp = (jnp.float32(0.05), jnp.float32(0.0), jnp.uint32(7))
    plan = resolve_plan(cfg, batch=B, target_slots=8)
    assert plan.path == "sparse", plan.path

    variants = {
        "kernel": dataclasses.replace(plan, train_inner="interpret"),
        "xla": dataclasses.replace(plan, train_inner="xla"),
    }
    # dense-weight HBM stream of the equivalent dense step vs the sparse
    # value+index(+comp) stream — the §13 bandwidth claim, exact bytes
    w_stream = {"dense": L * D,
                "sparse": L * F * (1 + 4 + (2 if state.comp is not None
                                            else 0))}
    outs, rows = {}, []
    for name, p in variants.items():
        f = jax.jit(lambda s, xx, t, p=p: train_step_sparse(
            p, cfg, s, xx, t, *hp))
        outs[name] = jax.block_until_ready(f(state, x, tg))
        b = _temp_bytes(f, state, x, tg)
        rows.append({
            "name": f"sparse/head_{name}",
            "us_per_call": round(_time(f, state, x, tg, n=3)),
            "temp_mib": round(b / 2**20, 2),
            "temp_size_in_bytes": b,
            "fan_in": F, "block_l": plan.block_l,
            "w_stream_bytes": w_stream["sparse"],
            "dense_w_stream_bytes": w_stream["dense"],
            "B": B, "L": L, "D": D,
        })
    # bit-parity gate: megakernel ≡ oracle scan (values are FP8 — compare
    # the raw byte patterns so -0.0 / NaN encodings can't slip through)
    for got, want in ((outs["kernel"][0].values, outs["xla"][0].values),
                      (outs["kernel"][1], outs["xla"][1])):
        np.testing.assert_array_equal(np.asarray(got).view(np.uint8),
                                      np.asarray(want).view(np.uint8))

    # ---- modeled §13 memory gate at the paper's own archs (fail-hard) ----
    for arch in ("xmc-bert-3m-sparse", "xmc-distilbert-8.6m-sparse"):
        scfg = get_config(arch)
        dcfg = get_config(arch[:-len("-sparse")])
        sd = MM.MemScenario(num_labels=dcfg.head_labels,
                            d_model=dcfg.d_model,
                            num_chunks=dcfg.head_chunks,
                            kahan_chunks=dcfg.head_kahan_chunks)
        ss = dataclasses.replace(sd, num_chunks=scfg.head_chunks,
                                 kahan_chunks=scfg.head_kahan_chunks)
        dense = MM.head_components(sd, dcfg.head_weight_dtype)
        sparse = MM.head_components(ss, scfg.head_weight_dtype,
                                    fan_in=scfg.head_fan_in)
        dense_w = sum(v for k, v in dense.items() if k.startswith("W_"))
        sparse_w = sum(v for k, v in sparse.items() if k.startswith("W_"))
        ratio = dense_w / sparse_w
        # acceptance: ≥10× head weight+optimizer shrink at configured fan-in
        assert ratio >= 10.0, (arch, ratio)
        rows.append({
            "name": f"sparse/mem_{arch}",
            "us_per_call": 0,                  # modeled, not timed
            "fan_in": scfg.head_fan_in,
            "labels": dcfg.head_labels,
            "dense_w_bytes": round(dense_w),
            "sparse_w_bytes": round(sparse_w),
            "shrink_x": round(ratio, 2),
            "gate": "ratio>=10",
        })
    return rows


def bench_fused_chunk(L=4096, D=256, B=256):
    """Single-launch fused chunk step vs the legacy 3-launch composition.

    Both run the Pallas interpret path so XLA cannot fuse across the kernel
    boundaries — the unfused variant's (B, L) logits and BF16 gradient show
    up as temp buffers, the megakernel's do not (they never leave VMEM).
    µs/call is additionally reported for the jitted XLA-oracle variants,
    which is what non-TPU backends execute in production.
    """
    from repro.core import losses as Lo
    from repro.kernels import ops, tuning

    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = (jax.random.normal(ks[0], (B, D)) * 0.5).astype(jnp.bfloat16)
    w = (jax.random.normal(ks[1], (L, D)) * 0.05).astype(jnp.float8_e4m3fn)
    xg = jnp.zeros((B, D), jnp.bfloat16)
    tg = jax.random.randint(ks[2], (B, 8), 0, L)
    lr, wd, scale = jnp.float32(0.05), jnp.float32(0.0), jnp.float32(1.0 / B)
    c0, sd, su = jnp.int32(0), jnp.uint32(3), jnp.uint32(5)
    args = (x, w, tg, xg, lr, wd, scale, c0, sd, su)
    kw = dict(loss="bce", num_labels=L)

    fused_k = jax.jit(lambda *a: ops.fused_chunk_step(
        *a, impl="interpret", **kw))

    def unfused_fn(x, w, tg, xg, lr, wd, scale, c0, sd, su):
        # the seed path: 3 separate launches, z and g round-trip HBM.
        # Includes the chunk loss so both variants do identical work.
        z = ops.fp8_logits(x, w, sd, impl="interpret")
        y = Lo.chunk_multi_hot(tg, c0, L)
        g = (Lo.bce_logit_grad(z, y, scale)).astype(jnp.bfloat16)
        loss = Lo.bce_chunk_loss(z, y)
        xg = xg + ops.fp8_input_grad(g, w, impl="interpret")
        w_new = ops.fused_head_update(g, x, w, lr, wd, su, impl="interpret")
        return w_new, xg, loss

    unfused_k = jax.jit(unfused_fn)
    fused_x = jax.jit(lambda *a: ops.fused_chunk_step(*a, impl="xla", **kw))

    def unfused_x_fn(x, w, tg, xg, lr, wd, scale, c0, sd, su):
        z = ref.fp8_logits_ref(x, w, sd)
        y = Lo.chunk_multi_hot(tg, c0, L)
        g = (Lo.bce_logit_grad(z, y, scale)).astype(jnp.bfloat16)
        loss = Lo.bce_chunk_loss(z, y)
        xg = xg + ref.fp8_input_grad_ref(g, w)
        return ref.fused_head_update_ref(g, x, w, lr, wd, su), xg, loss

    unfused_x = jax.jit(unfused_x_fn)

    b_f, b_u = _temp_bytes(fused_k, *args), _temp_bytes(unfused_k, *args)
    return [{"name": "kernel/fused_chunk", "us_per_call": round(_time(
                 fused_x, *args)),
             "temp_mib": round(b_f / 2**20, 2), "temp_size_in_bytes": b_f,
             "block_l": tuning.chunk_block_l(B, L, D, 1)},
            {"name": "kernel/unfused_chunk", "us_per_call": round(_time(
                 unfused_x, *args)),
             "temp_mib": round(b_u / 2**20, 2), "temp_size_in_bytes": b_u}]


def bench_numerics_guard(L=4096, D=256, B=256, num_chunks=8):
    """BENCH_10: the numerics guard's cost at the paper shape (DESIGN.md
    §14) — hard-gated.

    Structural gates (exact, backend-independent — these carry the perf
    contract):
      * guard-on compiles to the SAME number of gemms as guard-off (the
        telemetry replays no dot; a CSE regression here once cost 12%),
      * temp-byte delta ≤ 1.5 MiB (one f32 chunk buffer for the pre-cast
        observation + reduction scratch; no (B, L) / extra (L, D)
        materialization),
      * guard-on is bitwise invisible in W/comp/x̄/loss at this shape.

    Wall-clock gate: median of paired (adjacent on/off) step-time ratios,
    drift-cancelled.  <3% on a compiled-kernel backend (TPU — counters
    accumulate in the megakernel's VMEM scratch); on the XLA-oracle
    fallback the telemetry reductions are separate un-fusable passes
    worth ~4-6% single-core (observed medians swing ±4% with machine
    noise on shared CI boxes), so the CPU gate is a noise-safe <15%.

    Detection/recovery rows ride along from a fault-injected guarded run:
    NaN-poison at step 3 must trip AT step 3 (0-step latency, gated),
    quarantine, re-train, and end at a finite loss below the pre-fault
    envelope (gated).
    """
    import dataclasses
    import statistics
    import tempfile

    import numpy as np

    from repro import head as H
    from repro.configs import get_smoke
    from repro.fault import inject as FI
    from repro.launch.train import run_guarded
    from repro.numerics import recovery as NR
    from repro.numerics import telemetry as NT

    hp = (jnp.float32(0.05), jnp.float32(1e-4), jnp.uint32(7))
    x = (jax.random.normal(jax.random.PRNGKey(1), (B, D)) * 0.5
         ).astype(jnp.bfloat16)
    tg = jax.random.randint(jax.random.PRNGKey(2), (B, 8), 0, L)
    on_tpu = jax.default_backend() == "tpu"
    gate = 0.03 if on_tpu else 0.15

    def once(f, st, n=4):
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(f(st, x, tg))
        return (time.time() - t0) / n

    rows = []
    for mode, use_sr, kahan in (("sr", True, 0), ("kahan", False, num_chunks)):
        cfg = H.ELMOHeadConfig(num_labels=L, d_model=D,
                               num_chunks=num_chunks, weight_dtype="e4m3",
                               loss="bce", use_sr=use_sr,
                               kahan_chunks=kahan, impl="fused_xla")
        st = H.init_head(jax.random.PRNGKey(0), cfg)
        gcfg = dataclasses.replace(cfg, guard=True)
        f_off = jax.jit(lambda s, xx, t, c=cfg: H.head_train_step(
            c, s, xx, t, *hp))
        f_on = jax.jit(lambda s, xx, t, c=gcfg: H.head_train_step(
            c, s, xx, t, *hp))
        o_off = jax.block_until_ready(f_off(st, x, tg))
        o_on = jax.block_until_ready(f_on(st, x, tg))

        # gate: bitwise invisibility at the bench shape
        for a, b in ((o_off[0].w, o_on[0].w), (o_off[1], o_on[1]),
                     (o_off[2]["loss"], o_on[2]["loss"])):
            assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
        assert np.isfinite(np.asarray(o_on[2]["telemetry"])).all()

        # gate: zero extra gemms, bounded temp delta
        hlo_off = f_off.lower(st, x, tg).compile()
        hlo_on = f_on.lower(st, x, tg).compile()
        d_off = hlo_off.as_text().count(" dot(")
        d_on = hlo_on.as_text().count(" dot(")
        assert d_on == d_off, (mode, d_off, d_on)
        t_off = hlo_off.memory_analysis().temp_size_in_bytes
        t_on = hlo_on.memory_analysis().temp_size_in_bytes
        assert t_on - t_off <= 1.5 * 2**20, (mode, t_off, t_on)

        ratios = []
        for _ in range(12):
            a = once(f_off, st)
            b = once(f_on, st)
            ratios.append(b / a)
        over = statistics.median(ratios) - 1.0
        assert over < gate, (mode, over, gate)
        rows.append({
            "name": f"numerics/guard_overhead_{mode}",
            "us_per_call": round(once(f_on, st) * 1e6),
            "overhead_pct": round(over * 100, 2),
            "gate_pct": gate * 100, "backend": jax.default_backend(),
            "extra_dots": d_on - d_off,
            "extra_temp_mib": round((t_on - t_off) / 2**20, 2),
            "B": B, "L": L, "D": D,
        })

    # ---- detection latency + recovery outcome (fault-injected, gated) ----
    cfg = get_smoke("xmc-bert-3m", head_labels=600)
    with tempfile.TemporaryDirectory() as d:
        inject_at = 3
        state, losses, recoveries = run_guarded(
            cfg, steps=8, global_batch=4, seq=16, ckpt_dir=d, ckpt_every=2,
            impl="xla", log_every=100, monitor_kw={"warmup": 4},
            inject=FI.at_step(inject_at, FI.nan_poison_head))
        lad = NR.load_ladder(d)
    trip_step = lad.trips[0]["step"]
    latency = trip_step - inject_at
    assert latency == 0, (trip_step, inject_at)       # same-step detection
    assert recoveries == 1 and lad.rung_name == "reseed"
    # pre-fault envelope: best loss the poisoned incarnation reached
    # before the trip — recovery must end strictly below it
    pre_fault = min(losses[:inject_at])
    assert all(l == l for l in losses)                # no NaN survived
    assert losses[-1] < pre_fault
    rows.append({
        "name": "numerics/detect_recover",
        "us_per_call": 0,
        "detect_latency_steps": latency,
        "trip_kind": lad.trips[0]["kind"],
        "recoveries": recoveries, "rung": lad.rung_name,
        "pre_fault_loss": round(float(pre_fault), 4),
        "final_loss": round(float(losses[-1]), 4),
        "gate": "latency==0 & final<pre_fault",
    })
    return rows
