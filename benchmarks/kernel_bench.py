"""Kernel microbenchmarks: fused vs unfused head update, fp8 vs bf16 matmul.

On this CPU container the Pallas kernels run in interpret mode, so absolute
times are meaningless for TPU; what IS meaningful here (and reported) is
the *memory* side: the fused path materializes no (L, D) gradient and no
weight copy — verified by jitting both and comparing peak temp bytes.
Wall-times are reported for the XLA (production-fallback) paths.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _time(f, *args, n=10):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6


def bench_fused_update(L=4096, D=256, B=256):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    g = jax.random.normal(ks[0], (B, L), jnp.bfloat16) * 0.1
    x = jax.random.normal(ks[1], (B, D), jnp.bfloat16)
    w = (jax.random.normal(ks[2], (L, D)) * 0.05).astype(jnp.float8_e4m3fn)
    lr, wd, seed = jnp.float32(0.05), jnp.float32(0.0), jnp.uint32(0)

    fused = jax.jit(lambda g, x, w: ref.fused_head_update_ref(
        g, x, w, 0.05, 0.0, seed))

    def unfused_fn(g, x, w):
        # materializes dW (L, D) f32 then SR — what the fusion removes
        dw = jax.lax.dot_general(g, x, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        w_new = w.astype(jnp.float32) - 0.05 * dw
        from repro.core import precision as P
        from repro.kernels import prng_utils as PR
        bits = PR.hash_bits_nd(seed, w_new.shape)
        return P.sr_bits_e4m3(w_new, bits)

    unfused = jax.jit(unfused_fn)

    t_f = _time(fused, g, x, w)
    t_u = _time(unfused, g, x, w)
    m_f = jax.jit(lambda g, x, w: ref.fused_head_update_ref(
        g, x, w, 0.05, 0.0, seed)).lower(g, x, w).compile().memory_analysis()
    m_u = unfused.lower(g, x, w).compile().memory_analysis()
    return [{"name": "kernel/fused_update", "us_per_call": round(t_f),
             "temp_mib": round(m_f.temp_size_in_bytes / 2**20, 1)},
            {"name": "kernel/unfused_update", "us_per_call": round(t_u),
             "temp_mib": round(m_u.temp_size_in_bytes / 2**20, 1)}]


def bench_fp8_logits(L=4096, D=256, B=256):
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    x = jax.random.normal(ks[0], (B, D), jnp.bfloat16)
    w8 = (jax.random.normal(ks[1], (L, D)) * 0.05).astype(jnp.float8_e4m3fn)
    w16 = w8.astype(jnp.bfloat16)
    f8 = jax.jit(lambda x, w: ref.fp8_logits_ref(x, w))
    f16 = jax.jit(lambda x, w: ref.fp8_logits_ref(x, w, quantize_x=False))
    t8, t16 = _time(f8, x, w8), _time(f16, x, w16)
    return [{"name": "kernel/fp8_logits", "us_per_call": round(t8),
             "w_bytes": w8.nbytes},
            {"name": "kernel/bf16_logits", "us_per_call": round(t16),
             "w_bytes": w16.nbytes}]
