"""§Perf hillclimb driver: compile cells with config overrides, record the
three roofline terms + dry-run memory before/after each change.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell gemma-7b/train_4k \
        --set sharding_strategy=fsdp_pure --out hc.json
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse   # noqa: E402
import json       # noqa: E402
import sys        # noqa: E402
from pathlib import Path  # noqa: E402

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.launch import dryrun as DR       # noqa: E402


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    return k, v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True)      # arch/shape
    ap.add_argument("--set", action="append", default=[])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="hillclimb.json")
    args = ap.parse_args()
    arch, shape = args.cell.split("/")
    overrides = dict(parse_override(kv) for kv in args.set)

    rec = DR.run_cell(arch, shape, args.multi_pod, overrides or None)
    # attach analytic roofline terms under the same overrides
    import dataclasses
    from benchmarks import roofline as R
    from repro.configs import get_config
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    from repro.configs.registry import SHAPES
    sh = SHAPES[shape]
    fl = R.step_flops(cfg, sh)
    coll = R.step_collective_bytes(cfg, sh, args.multi_pod)
    rec["roofline"] = {
        "compute_s": fl["total"] / (R.CHIPS * R.PEAK_FLOPS),
        "memory_s": R.step_hbm_bytes(cfg, sh) / (R.CHIPS * R.HBM_BW),
        "collective_s": coll["total"] / R.ICI_BW,
        "collective_breakdown": coll,
    }
    hist = json.load(open(args.out)) if os.path.exists(args.out) else []
    hist.append(rec)
    json.dump(hist, open(args.out, "w"), indent=1)
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("trace",)}, indent=1, default=str))


if __name__ == "__main__":
    main()
