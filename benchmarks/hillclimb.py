"""§Perf hillclimb driver: compile cells with config overrides, record the
three roofline terms + dry-run memory before/after each change.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell gemma-7b/train_4k \
        --set sharding_strategy=fsdp_pure --out hc.json

Importing this module has NO side effects (no env mutation, no jax
backend init, no sys.path edits) — everything environmental happens
inside ``main()``, so tests and other benchmarks can import the helpers
without forking a 512-device host platform.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

# the dry-run meshes need this many host devices; must be appended to
# XLA_FLAGS before jax initializes its backend (main() does this first)
HOST_DEVICES = 512


def _setup_environment() -> None:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={HOST_DEVICES}")
    repo_root = str(Path(__file__).resolve().parent.parent)
    if repo_root not in sys.path:
        sys.path.insert(0, repo_root)


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    return k, v


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True)      # arch/shape
    ap.add_argument("--set", action="append", default=[])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="hillclimb.json")
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)
    _setup_environment()
    from repro.launch import dryrun as DR

    arch, shape = args.cell.split("/")
    overrides = dict(parse_override(kv) for kv in args.set)

    rec = DR.run_cell(arch, shape, args.multi_pod, overrides or None)
    # attach analytic roofline terms under the same overrides
    import dataclasses

    from benchmarks import roofline as R
    from repro.configs import get_config
    from repro.configs.registry import SHAPES
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    sh = SHAPES[shape]
    fl = R.step_flops(cfg, sh)
    coll = R.step_collective_bytes(cfg, sh, args.multi_pod)
    rec["roofline"] = {
        "compute_s": fl["total"] / (R.CHIPS * R.PEAK_FLOPS),
        "memory_s": R.step_hbm_bytes(cfg, sh) / (R.CHIPS * R.HBM_BW),
        "collective_s": coll["total"] / R.ICI_BW,
        "collective_breakdown": coll,
    }
    hist = json.load(open(args.out)) if os.path.exists(args.out) else []
    hist.append(rec)
    json.dump(hist, open(args.out, "w"), indent=1)
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("trace",)}, indent=1, default=str))
    return rec


if __name__ == "__main__":
    main()
