"""Benchmarks mirroring the paper's tables/figures at CPU-runnable scale.

Each function returns a list of result dicts; benchmarks/run.py prints them
as CSV.  Scale is reduced (CPU container) but the *comparisons* are the
paper's: precision parity across fp32/BF16/FP8, Renee's instability, memory
vs labels, chunk-count trade-off, and the (E, M) precision grid.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import elmo_head as H
from repro.core import memory_model as MM
from repro.core import precision as P
from repro.core import renee_baseline as RB
from repro.data import DataCursor, xmc_batches


# ---------------------------------------------------------------------------
# shared tiny-XMC training harness
# ---------------------------------------------------------------------------


def _make_data(num_labels=2000, d=64, n_train=512, n_test=256, seed=0):
    """Linearly-separable-ish synthetic XMC: each label has a prototype."""
    rng = np.random.default_rng(seed)
    protos = rng.standard_normal((num_labels, d)).astype(np.float32)
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)

    def sample(n):
        ys = rng.integers(0, num_labels, (n, 3))
        x = protos[ys[:, 0]] + 0.3 * protos[ys[:, 1]] \
            + 0.1 * rng.standard_normal((n, d)).astype(np.float32)
        return jnp.asarray(x), jnp.asarray(ys, jnp.int32)

    return sample(n_train), sample(n_test)


def _train_head(cfg: H.ELMOHeadConfig, data, steps=300, lr=2.0, bs=128,
                seed=1):
    (xtr, ytr), (xte, yte) = data
    state = H.init_head(jax.random.PRNGKey(seed), cfg)
    n = xtr.shape[0]
    step_fn = jax.jit(lambda s, x, y, sd: H.head_train_step(
        cfg, s, x, y, jnp.float32(lr), jnp.float32(0.0), sd))
    t0 = time.time()
    for i in range(steps):
        lo = (i * bs) % (n - bs)
        state, _, m = step_fn(state, xtr[lo:lo + bs], ytr[lo:lo + bs],
                              jnp.uint32(i))
    train_s = time.time() - t0
    p1 = float(H.precision_at_k(cfg, state, xte, yte, k=1, denom="k"))
    p5 = float(H.precision_at_k(cfg, state, xte, yte, k=5, denom="k"))
    return {"p@1": round(p1, 4), "p@5": round(p5, 4),
            "train_s": round(train_s, 2), "loss": float(m["loss"])}


# ---------------------------------------------------------------------------
# Table 2/3 analogue: precision parity fp32 / ELMO-BF16 / ELMO-FP8 / Renee
# ---------------------------------------------------------------------------


def bench_convergence_parity(num_labels=500, d=32, steps=300):
    data = _make_data(num_labels, d)
    rows = []
    for name, wd, sr in [("fp32", "f32", False), ("elmo_bf16", "bf16", True),
                         ("elmo_fp8", "e4m3", True),
                         ("bf16_no_sr", "bf16", False)]:
        cfg = H.ELMOHeadConfig(num_labels=num_labels, d_model=d,
                               num_chunks=4, weight_dtype=wd, loss="bce",
                               use_sr=sr, impl="xla")
        r = _train_head(cfg, data, steps=steps)
        rows.append(dict(name=f"parity/{name}", **r))
    # Renee baseline (full logits, FP16 MPT)
    rcfg = RB.ReneeConfig(num_labels=num_labels, d_model=d,
                          init_loss_scale=64.0)
    rstate = RB.init_renee(jax.random.PRNGKey(1), rcfg)
    (xtr, ytr), (xte, yte) = data
    step_fn = jax.jit(lambda s, x, y: RB.renee_train_step(
        rcfg, s, x, y, jnp.float32(0.2)))   # momentum 0.9 → eff. lr ≈ 2.0
    t0 = time.time()
    for i in range(steps):
        lo = (i * 128) % (xtr.shape[0] - 128)
        rstate, _, m = step_fn(rstate, xtr[lo:lo + 128], ytr[lo:lo + 128])
    z = xte @ rstate.w_master.T
    top1 = jnp.argsort(z, axis=1)[:, -1:]
    p1 = float(((top1[:, :, None] == yte[:, None, :]).any(-1)).mean())
    rows.append({"name": "parity/renee_fp16", "p@1": round(p1, 4),
                 "p@5": float("nan"), "train_s": round(time.time() - t0, 2),
                 "loss": float(m["loss"])})
    return rows


# ---------------------------------------------------------------------------
# Fig 2a: precision grid over (exponent, mantissa) bits, ± SR
# ---------------------------------------------------------------------------


def bench_precision_grid(num_labels=500, d=32, steps=120):
    data = _make_data(num_labels, d)
    rows = []
    for e_bits, m_bits in [(4, 3), (4, 2), (3, 3), (5, 2), (2, 3)]:
        for sr in (False, True):
            # simulate the format by quantizing after every update
            cfg = H.ELMOHeadConfig(num_labels=num_labels, d_model=d,
                                   num_chunks=2, weight_dtype="f32",
                                   loss="bce", use_sr=False, impl="xla")
            state = H.init_head(jax.random.PRNGKey(1), cfg)
            (xtr, ytr), (xte, yte) = data

            @jax.jit
            def step_q(state, x, y, i):
                state, _, _ = H.head_train_step(
                    cfg, state, x, y, jnp.float32(2.0), jnp.float32(0.0),
                    i.astype(jnp.uint32))
                if sr:
                    w = P.simulate_format(state.w.astype(jnp.float32),
                                          e_bits, m_bits, True,
                                          jax.random.fold_in(
                                              jax.random.PRNGKey(0), i))
                else:
                    w = P.simulate_format(state.w.astype(jnp.float32),
                                          e_bits, m_bits)
                return H.HeadState(w.astype(state.w.dtype), state.comp)

            for i in range(steps):
                lo = (i * 128) % (xtr.shape[0] - 128)
                state = step_q(state, xtr[lo:lo + 128], ytr[lo:lo + 128],
                               jnp.int32(i))
            p1 = float(H.precision_at_k(cfg, state, xte, yte, k=1, denom="k"))
            rows.append({"name": f"grid/E{e_bits}M{m_bits}"
                                 f"{'+sr' if sr else ''}",
                         "p@1": round(p1, 4)})
    return rows


# ---------------------------------------------------------------------------
# Fig 2b/5: value-range histograms (what fraction fits e4m3/e5m2 range)
# ---------------------------------------------------------------------------


def bench_range_histograms(num_labels=500, d=32, steps=50):
    data = _make_data(num_labels, d)
    cfg = H.ELMOHeadConfig(num_labels=num_labels, d_model=d, num_chunks=4,
                           weight_dtype="bf16", loss="bce", impl="xla")
    state = H.init_head(jax.random.PRNGKey(1), cfg)
    (xtr, ytr), _ = data
    for i in range(steps):
        lo = (i * 128) % (xtr.shape[0] - 128)
        state, xg, _ = H.head_train_step(cfg, state, xtr[lo:lo + 128],
                                         ytr[lo:lo + 128], jnp.float32(2.0),
                                         jnp.float32(0.0), jnp.uint32(i))
    w = np.abs(np.asarray(state.w, np.float32).ravel())
    w = w[w > 0]
    # grads: recompute one loss-skip grad batch
    z = H.head_logits(cfg, state, xtr[:64])
    from repro.core import losses as L
    y = L.chunk_multi_hot(ytr[:64], jnp.int32(0), cfg.num_labels)
    g = np.abs(np.asarray(L.bce_logit_grad(z, y, jnp.float32(1 / 64))))
    g = g[g > 0]

    def in_range(vals, lo_e, hi):
        return float(((vals >= 2.0 ** lo_e) & (vals <= hi)).mean())

    def flushed(vals, lo_e):      # would round to zero (paper Fig. 2b)
        return float((vals < 2.0 ** lo_e).mean())

    # paper (131K labels): ~90% of grads flush in E4M3, ~20% in E5M2; the
    # flush fraction is scale-dependent (grows with label count), so at
    # this 500-label scale the absolute numbers are smaller — the ORDERING
    # e4m3 ≫ e5m2 is the reproduced claim
    return [{
        "name": "ranges/weights_in_e4m3", "frac": round(in_range(w, -9, 448), 4)},
        {"name": "ranges/grads_flushed_e4m3", "frac": round(flushed(g, -9), 4)},
        {"name": "ranges/grads_flushed_e5m2", "frac": round(flushed(g, -16), 4)},
        {"name": "ranges/grad_p01_log2",
         "val": round(float(np.log2(np.percentile(g, 1))), 1)},
    ]


# ---------------------------------------------------------------------------
# Table 10: chunk count vs peak memory (analytic) + measured latency
# ---------------------------------------------------------------------------


def bench_chunk_sweep(num_labels=4096, d=32, steps=30):
    data = _make_data(num_labels, d)
    rows = []
    for k in (1, 2, 4, 8, 16):
        cfg = H.ELMOHeadConfig(num_labels=num_labels, d_model=d,
                               num_chunks=k, weight_dtype="bf16",
                               loss="bce", impl="xla")
        r = _train_head(cfg, data, steps=steps)
        analytic = MM.elmo_peak(
            MM.MemScenario(num_labels=2_812_281, num_chunks=k),
            "bf16")["total"] / MM.GIB
        rows.append({"name": f"chunks/k{k}",
                     "us_per_step": round(r["train_s"] / steps * 1e6),
                     "amazon3m_peak_gib": round(analytic, 2)})
    return rows


# ---------------------------------------------------------------------------
# Fig 4: peak memory vs label count (analytic model, paper-validated)
# ---------------------------------------------------------------------------


def bench_memory_vs_labels():
    rows = []
    for r in MM.sweep_labels([131_072, 670_091, 3_000_000, 8_623_847,
                              18_000_000]):
        rows.append({"name": f"mem/{r['labels']}",
                     "renee_gib": round(r["renee_gib"], 2),
                     "elmo_bf16_gib": round(r["elmo_bf16_gib"], 2),
                     "elmo_fp8_gib": round(r["elmo_fp8_gib"], 2),
                     "ratio_fp8": round(r["renee_gib"] / r["elmo_fp8_gib"],
                                        1)})
    return rows


# ---------------------------------------------------------------------------
# §5-style stability: Renee overflow rate vs loss scale (why BF16)
# ---------------------------------------------------------------------------


def bench_stability():
    rows = []
    for scale_pow in (8, 16, 24):
        cfg = RB.ReneeConfig(num_labels=4096, d_model=16,
                             init_loss_scale=2.0 ** scale_pow)
        state = RB.init_renee(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16)) * 4
        tg = jax.random.randint(jax.random.PRNGKey(2), (8, 3), 0, 4096)
        overflows = 0
        for i in range(10):
            state, _, m = RB.renee_train_step(cfg, state, x, tg,
                                              jnp.float32(0.05))
            overflows += int(m["overflow"])
        rows.append({"name": f"stability/renee_scale2^{scale_pow}",
                     "overflow_steps": overflows, "of": 10})
    return rows
