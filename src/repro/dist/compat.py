"""JAX API drift shims.

The codebase targets the modern ``jax.shard_map`` / ``jax.make_mesh``
surface; this module maps those calls onto whatever the installed JAX
provides so the same code runs on 0.4.x (``jax.experimental.shard_map``,
``check_rep``) and on ≥0.6 (``jax.shard_map``, ``check_vma``,
``axis_types``).
"""
from __future__ import annotations

import jax

_NEW_SHARD_MAP = hasattr(jax, "shard_map")
if not _NEW_SHARD_MAP:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the ``check_vma`` knob mapped per JAX version."""
    if _NEW_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check_vma)


@jax.custom_vjp
def optimization_barrier(operands):
    """``jax.lax.optimization_barrier`` with a gradient-passthrough VJP.

    Older JAX has no differentiation rule for the barrier primitive; the
    barrier is an identity, so its cotangent is the identity too (the
    backward pass simply loses the scheduling hint)."""
    return jax.lax.optimization_barrier(operands)


def _barrier_fwd(operands):
    return optimization_barrier(operands), None


def _barrier_bwd(_, g):
    return (g,)


optimization_barrier.defvjp(_barrier_fwd, _barrier_bwd)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every JAX version
    (0.4.x returned a one-element list of per-device dicts)."""
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return dict(c or {})


def make_mesh(axis_shapes, axis_names) -> jax.sharding.Mesh:
    """``jax.make_mesh`` requesting Auto axis types where supported."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)
