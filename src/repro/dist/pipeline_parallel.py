"""GPipe-style pipeline parallelism over one mesh axis.

``pipeline_apply`` shards the per-stage parameters over ``axis`` (stage s
lives on rank s) and streams ``n_micro`` microbatches through the stage
chain with ``ppermute`` handoffs: at tick t, stage s runs microbatch
t − s (when in range), so the pipeline reaches steady state after a
``n_stages − 1``-tick fill and drains symmetrically.  Bubble fraction is
(S−1)/(S−1+M) — callers pick ``n_micro ≫ n_stages``.

This is the *inference/forward* building block (multi-pod dry-run and the
multidevice checks); training composes it under ``jax.vjp`` like any other
JAX function — ``ppermute`` transposes to the reverse permutation.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import compat


def pipeline_apply(mesh: jax.sharding.Mesh, axis: str, *, n_micro: int,
                   stage_fn: Callable[[Any, jax.Array], jax.Array],
                   stage_params: Any, x: jax.Array) -> jax.Array:
    """Apply ``stage_fn`` for every stage in sequence, pipelined.

    stage_params: pytree whose leaves are stacked over stages on axis 0
                  (shape (n_stages, ...)).
    x:            (B, D) with B divisible by n_micro.
    Returns stage_{S-1}(... stage_0(x)) as a replicated (B, D) array.
    """
    n_stages = int(mesh.shape[axis])
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    mb = B // n_micro
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def body(ws, xf):
        w = jax.tree.map(lambda a: a[0], ws)   # this rank's stage slice
        sid = jax.lax.axis_index(axis)
        micro = xf.reshape(n_micro, mb, *xf.shape[1:])

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t during the fill window
            inj = micro[jnp.minimum(t, n_micro - 1)]
            cur = jnp.where(sid == 0,
                            jnp.where(t < n_micro, inj, jnp.zeros_like(inj)),
                            buf)
            y = stage_fn(w, cur)
            # the last stage emits microbatch t − (n_stages − 1)
            oidx = t - (n_stages - 1)
            safe = jnp.clip(oidx, 0, n_micro - 1)
            take = (sid == n_stages - 1) & (oidx >= 0)
            outs = outs.at[safe].set(jnp.where(take, y, outs[safe]))
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, outs

        buf0 = jnp.zeros((mb,) + xf.shape[1:], xf.dtype)
        outs0 = jnp.zeros((n_micro, mb) + xf.shape[1:], xf.dtype)
        _, outs = jax.lax.fori_loop(0, n_micro + n_stages - 1, tick,
                                    (buf0, outs0))
        # only the last stage holds results; psum replicates them
        outs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs.reshape(B, *xf.shape[1:])

    # stage params: sharded over `axis` on dim 0, replicated on the rest
    param_specs = jax.tree.map(
        lambda a: P(axis, *([None] * (a.ndim - 1))), stage_params)
    run = compat.shard_map(body, mesh=mesh,
                           in_specs=(param_specs, P(*([None] * x.ndim))),
                           out_specs=P(*([None] * x.ndim)),
                           check_vma=False)
    return run(stage_params, x)
