"""Gradient compression for cross-host all-reduce (FP8 E5M2 wire format).

Gradients live in BF16 on-chip (paper §4.1); across the (slow) DCN/pod links
they travel as E5M2 — 1 byte/elem, wide exponent range, 2 mantissa bits.  A
per-tensor power-of-two scale keeps the payload inside E5M2's normal range so
the 12.5% worst-case mantissa error is the only loss.

``compress_with_feedback`` adds classic error feedback (1-bit-Adam lineage):
the residual of each round is carried (BF16) and folded into the next round,
making the *time-averaged* transmitted gradient exact even though each round
is quantized.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import precision as P

_E5M2_MAX = float(P.max_finite(P.E5M2))


class Compressed(NamedTuple):
    payload: jax.Array   # flat E5M2
    scale: jax.Array     # f32 scalar; decompress = payload * scale


def compress(g: jax.Array) -> Compressed:
    g32 = g.astype(jnp.float32).reshape(-1)
    amax = jnp.max(jnp.abs(g32))
    # power-of-two scale: exactly representable, so scaling is lossless
    scale = jnp.exp2(jnp.ceil(jnp.log2(
        jnp.maximum(amax, 1e-30) / _E5M2_MAX)))
    scale = jnp.maximum(scale, jnp.float32(2.0 ** -40))
    payload = (g32 / scale).astype(P.E5M2)
    return Compressed(payload, scale)


def decompress(c: Compressed, shape) -> jax.Array:
    return (c.payload.astype(jnp.float32) * c.scale).reshape(shape)


def compress_with_feedback(g: jax.Array, err: jax.Array
                           ) -> Tuple[Compressed, jax.Array]:
    """One error-feedback round: compress(g + carried error), return the new
    residual in the carry's dtype (BF16 keeps the buffer at 2 bytes/param)."""
    acc = g.astype(jnp.float32) + err.astype(jnp.float32)
    c = compress(acc)
    err_new = acc - decompress(c, g.shape)
    return c, err_new.astype(err.dtype)
