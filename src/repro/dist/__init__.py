"""Distribution substrate: mesh context, sharding specs, gradient
compression, and pipeline parallelism.

Submodules
----------
* ``meshctx``           — ambient MeshContext (thread-local, context-managed)
* ``sharding``          — PartitionSpec factories for state/batch/cache trees
* ``compression``       — FP8-E5M2 gradient compression (+ error feedback)
* ``pipeline_parallel`` — GPipe-style microbatch pipeline over a mesh axis
* ``compat``            — version shims (shard_map / make_mesh API drift)
"""
from repro.dist import compat, compression, meshctx, pipeline_parallel, sharding  # noqa: F401
from repro.dist.meshctx import MeshContext  # noqa: F401
