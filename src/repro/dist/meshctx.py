"""Ambient mesh context.

A ``MeshContext`` names the mesh axes once; model code asks ``meshctx.get()``
whether a distributed context is active instead of threading mesh arguments
through every layer.  ``None`` (the default) means single-device semantics —
the layers' local code paths.

    ctx = make_context(...)            # launch/mesh.py
    with meshctx.use(ctx):
        out = jax.jit(step)(state, batch)
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class MeshContext:
    """Mesh + axis roles.  ``data_axes`` shard the batch (FSDP axes during
    training); ``model_axis`` is the TP/EP/vocab-parallel axis; ``pod_axis``
    (multi-pod) is pure data parallelism on top."""
    mesh: jax.sharding.Mesh
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    pod_axis: Optional[str] = None

    @property
    def model_size(self) -> int:
        if self.model_axis is None or self.model_axis not in self.mesh.shape:
            return 1
        return int(self.mesh.shape[self.model_axis])

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        """Axes the token batch is sharded over (pod is always a batch axis)."""
        axes = tuple(self.data_axes)
        if self.pod_axis is not None:
            axes = (self.pod_axis,) + axes
        return axes

    @property
    def n_batch(self) -> int:
        n = 1
        for a in self.batch_axes:
            n *= int(self.mesh.shape[a])
        return n


_state = threading.local()


def get() -> Optional[MeshContext]:
    """The active context, or None (single-device code paths)."""
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use(ctx: MeshContext):
    prev = get()
    _state.ctx = ctx
    try:
        yield ctx
    finally:
        _state.ctx = prev
