"""PartitionSpec factories for the dry-run / pjit entry points.

Specs are *intentions*: every consumer routes them through ``sanitize_spec``
which drops any axis that does not divide the concrete dimension (batch=1
decode cells, tiny smoke shapes, ragged vocab), so the factories can state
the ideal layout without case analysis.

Layout policy (launch/dryrun.py, DESIGN.md §4):
* backbone weights — TP over ``model`` on the last (output-feature) dim,
  FSDP over ``data`` on the largest remaining dim; ``fsdp_pure`` strategies
  shard the largest dim over (data, model) jointly and skip TP.
* optimizer state — mirrors the parameter spec leaf-for-leaf (moments and
  Kahan compensation are elementwise companions of the parameter).
* ELMO head — vocab-parallel: label rows over ``model`` (the chunk dimension
  is padded to 256 precisely so this always divides).
* batches — sharded over the batch axes on dim 0, replicated elsewhere.
* decode caches — stacked (period, batch, ...): batch axes on dim 1.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.head.state import HeadState


def _is_speclike(x) -> bool:
    return x is None or isinstance(x, P)


def sanitize_spec(shape, spec, mesh) -> P:
    """Trim ``spec`` to ``shape``'s rank and drop axes that don't divide."""
    parts = list(spec) if spec is not None else []
    parts = parts[:len(shape)]
    parts += [None] * (len(shape) - len(parts))
    out = []
    for dim, part in zip(shape, parts):
        if part is None:
            out.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        n = 1
        for a in axes:
            n *= int(mesh.shape[a])
        out.append(part if (n > 0 and dim % n == 0) else None)
    return P(*out)


def _leaf_spec(shape, n_model: int, n_data: int, fsdp_pure: bool) -> P:
    if len(shape) < 2:
        return P()
    parts = [None] * len(shape)
    if fsdp_pure:
        # params FSDP over (data, model) on the largest dim; no TP
        big = max(range(len(shape)), key=lambda i: shape[i])
        if shape[big] % max(1, n_data * n_model) == 0:
            parts[big] = ("data", "model")
        return P(*parts)
    # TP on the last dim when divisible
    if n_model > 1 and shape[-1] % n_model == 0:
        parts[-1] = "model"
    # FSDP over data on the largest remaining dim
    cands = [i for i in range(len(shape)) if parts[i] is None]
    cands.sort(key=lambda i: shape[i], reverse=True)
    for i in cands:
        if n_data > 1 and shape[i] % n_data == 0:
            parts[i] = "data"
            break
    return P(*parts)


def backbone_specs(cfg, backbone, n_model: int, n_data: int):
    """Spec tree matching a (possibly abstract) backbone parameter tree."""
    fsdp_pure = getattr(cfg, "sharding_strategy", "tp_sp") == "fsdp_pure"

    def spec(leaf):
        if leaf is None:
            return None
        return _leaf_spec(leaf.shape, n_model, n_data, fsdp_pure)

    return jax.tree.map(spec, backbone,
                        is_leaf=lambda x: x is None or hasattr(x, "shape"))


def opt_state_specs(bspec, opt_state):
    """Optimizer state inherits its parameter's spec (elementwise state).

    ``opt_state`` may be any tree refinement of the parameter tree (e.g.
    each param leaf replaced by a KahanAdamWState, or a dict of per-group
    states from the partitioned optimizer); every array under a parameter
    position gets that parameter's spec.  State leaves with no parameter
    counterpart (empty placeholders) are replicated.
    """
    flat_spec, treedef = jax.tree.flatten(bspec, is_leaf=_is_speclike)

    def _broadcast(s, sub):
        def one(leaf):
            if leaf is None:
                return None
            shape = getattr(leaf, "shape", ())
            if s is None or len(shape) != len(s):
                # rank mismatch (scalar counters, empty groups): replicate
                return P()
            return s
        return jax.tree.map(one, sub,
                            is_leaf=lambda x: x is None or hasattr(x, "shape"))

    try:
        subtrees = treedef.flatten_up_to(opt_state)
    except ValueError:
        # opt_state is not a refinement of the param tree (partitioned
        # optimizer wraps groups in a dict) — fall back to per-leaf specs
        return jax.tree.map(
            lambda leaf: P() if leaf is None or not hasattr(leaf, "shape")
            else _leaf_spec(leaf.shape, 1, 1, False),
            opt_state, is_leaf=lambda x: x is None or hasattr(x, "shape"))
    out = [_broadcast(s, sub) for s, sub in zip(flat_spec, subtrees)]
    return treedef.unflatten(out)


def head_specs(cfg, n_model: int):
    """Vocab-parallel ELMO head: (chunks, rows, d_model) rows over model."""
    w_spec = P(None, "model", None) if n_model > 1 else P()
    comp_spec = w_spec if getattr(cfg, "head_kahan_chunks", 0) else None
    return HeadState(w=w_spec, comp=comp_spec)


def sparse_head_specs(cfg, n_model: int):
    """Vocab-parallel fixed-fan-in sparse head (DESIGN.md §13): values,
    indices, and Kahan comp are all (chunks, rows, fan_in) with the label
    rows on dim 1 — the same row partition as the dense head, so the
    sharded sparse step and serving reuse the dense collectives."""
    from repro.head.sparse.state import SparseHeadState

    w_spec = P(None, "model", None) if n_model > 1 else P()
    comp_spec = w_spec if getattr(cfg, "head_kahan_chunks", 0) else None
    return SparseHeadState(values=w_spec, indices=w_spec, comp=comp_spec)


def head_state_shardings(state: HeadState, mesh, model_axis: str = "model"):
    """``NamedSharding`` tree matching ``state`` for elastic checkpoint
    restore: label rows over ``model_axis``, sanitized per leaf.  Pass to
    ``checkpoint.restore_checkpoint(..., shardings=...)`` to land restored
    full-logical leaves directly on a (possibly reshaped) mesh."""
    def ns(leaf):
        if leaf is None:
            return None
        spec = sanitize_spec(leaf.shape, P(None, model_axis, None), mesh)
        return jax.sharding.NamedSharding(mesh, spec)

    return jax.tree.map(ns, state,
                        is_leaf=lambda x: x is None or hasattr(x, "shape"))


def batch_specs(cfg, batch_axes) -> dict:
    """Specs for every possible step-function input key (dim 0 = batch)."""
    b = tuple(batch_axes)
    return {k: P(b) for k in ("tokens", "targets", "token",
                              "frontend_embeds")}


def cache_specs(cfg, caches, batch_axes, n_model: int):
    """Decode caches are stacked (period, batch, ...): shard dim 1."""
    b = tuple(batch_axes)

    def spec(leaf):
        if leaf is None or not hasattr(leaf, "shape"):
            return P()
        if len(leaf.shape) >= 2:
            return P(None, b)
        return P()

    return jax.tree.map(spec, caches,
                        is_leaf=lambda x: x is None or hasattr(x, "shape"))
