"""DEPRECATED shim — the ELMO head moved to the ``repro.head`` package.

The monolithic free-function module was split into a layered package
fronted by one mesh-aware facade (DESIGN.md §8):

    repro/head/config.py         ELMOHeadConfig, HeadHparams
    repro/head/state.py          HeadState, init_head, init_xg_err
    repro/head/plan.py           HeadPlan (all residency/dispatch decisions,
                                 resolved once), the plan-stability CLI
    repro/head/train.py          single-device train step
    repro/head/train_sharded.py  label-sharded train step (DESIGN.md §6)
    repro/head/serving.py        logits / top-k / P@k (+ sharded)
    repro/head/convert.py        re-typing + post-hoc refinement
    repro/head/__init__.py       the ``ELMOHead`` facade

This module re-exports the historical names unchanged — including the
mutable ``_CACHE_Z_BYTES`` / ``_TOPK_Z_BYTES`` budget knobs, whose reads
AND writes are forwarded to ``repro.head.plan`` (tests monkeypatch them
here) — so every legacy entry point is the same code as the facade and
bit-parity between the two surfaces holds by construction.  New code
should import from ``repro.head``.
"""
from __future__ import annotations

import sys
import types

from repro.head import plan as _planmod
from repro.head.config import (_WEIGHT_DTYPES, ELMOHeadConfig,  # noqa: F401
                               HeadHparams)
from repro.head.convert import convert_head, posthoc_refine     # noqa: F401
from repro.head.plan import (_grid_ok, _grid_serving_ok,        # noqa: F401
                             _impl_split, _target_slots, _want_cache_z,
                             HeadPlan, resolve_plan)
from repro.head.serving import (_chunk_base, _eval_seeds,  # noqa: F401
                                _p_at_k, _serve_drop, _topk_materialized,
                                _topk_scan, head_logits,
                                head_logits_sharded, head_topk,
                                head_topk_sharded, precision_at_k,
                                psp_at_k_planned)
from repro.head.state import (HeadState, _resolve_ctx, init_head,  # noqa: F401
                              init_xg_err)
from repro.head.train import (_chunk_grad, _chunk_logits,       # noqa: F401
                              _chunk_seed, _finalize_step, _grid_seeds,
                              _masked_z, _scan_chunks, _valid_cols,
                              head_train_step)
from repro.head.train_sharded import head_train_step_sharded    # noqa: F401


class _DeprecatedShim(types.ModuleType):
    """Forward the mutable budget knobs to their new home so legacy
    monkeypatching (``elmo_head._CACHE_Z_BYTES = …``) keeps steering the
    one true policy in ``repro.head.plan``."""

    _FORWARDED = ("_CACHE_Z_BYTES", "_TOPK_Z_BYTES")

    def __getattr__(self, name):        # only reached for missing attrs
        if name in self._FORWARDED:
            return getattr(_planmod, name)
        raise AttributeError(
            f"module {self.__name__!r} has no attribute {name!r}")

    def __setattr__(self, name, value):
        if name in self._FORWARDED:
            setattr(_planmod, name, value)
        else:
            super().__setattr__(name, value)


sys.modules[__name__].__class__ = _DeprecatedShim
