"""ELMO head: the paper's chunked, low-precision large output layer.

This module is the paper's primary contribution as a composable JAX unit.
One ``head_train_step`` performs, for each label chunk (paper §4.2–4.3):

    1. forward    z_c = q8(X) @ W_cᵀ            (FP8-storage matmul)
    2. loss-skip  ḡ_c = σ(z_c) − Y_c   |  softmax(z_c) − onehot      (App. B)
    3. input grad X̄  += ḡ_c @ W_c
    4. fused upd  W_c ← SR((1 − lr·wd) W_c − lr ḡ_cᵀ X)   (grad never in HBM)

so transient memory is 1/k of the full logits (paper §4.2, Table 10) and
the weight/optimizer memory is W itself — SGD without momentum (§4.2),
stochastic rounding instead of master weights (§4.1/4.3).

On the default ``impl="grid"`` path the *entire* label loop runs inside
ONE Pallas launch (``kernels/fused_head.py``, DESIGN.md §7): the grid
iterates over every label block of every chunk, W streams through
double-buffered DMA, and x, x̄, the streaming-LSE statistics and the loss
stay resident in VMEM scratch across all grid steps.  BCE is one launch
per train step; softmax-CE runs its LSE pre-pass and update as the two
passes of a single 2-D grid, with the pass-1 logits optionally kept
grid-resident for pass 2 (``cache_z``).  ``impl="fused"`` keeps the PR-1
per-chunk ``lax.scan`` of ``kernels/fused_chunk.py`` — the grid path's
bit-parity oracle — and ``impl="unfused"`` the original multi-kernel
composition.  Head-label chunks can use Kahan compensation instead of SR
(paper App. D; the mixed hybrid runs on the per-chunk scan).

The head never enters autodiff: the caller runs the backbone under
``jax.vjp`` and seeds it with the returned ``x_grad`` — which reproduces the
paper's reordered computation flow (encoder fwd → head fwd/bwd/update →
encoder bwd) and its peak-memory profile by construction.

When a mesh is active (``dist.meshctx``), ``head_train_step_sharded`` runs
the same step label-sharded over the model axis (every device owns
``chunk/n`` rows of each chunk, per ``dist.sharding.head_specs``), with a
cross-device two-pass LSE for softmax-CE and a ``psum`` of the per-shard
input gradients — DESIGN.md §6.  On the grid path each shard runs the
whole-head megakernel on its local rows: one launch for BCE, two for
softmax-CE (the normalizer collective sits between the LSE and update
launches).  ``head_topk_sharded``/``head_logits_sharded`` are the matching
serving paths (local top-k → gather → global re-rank).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as PS

from repro.core import losses as L
from repro.core import precision as P
from repro.kernels import ops
from repro.kernels import prng_utils as PR
from repro.kernels import tuning as _tuning

_WEIGHT_DTYPES = {"bf16": P.BF16, "e4m3": P.E4M3, "e5m2": P.E5M2,
                  "f32": P.F32}


@dataclasses.dataclass(frozen=True)
class ELMOHeadConfig:
    num_labels: int
    d_model: int
    num_chunks: int = 8
    weight_dtype: str = "bf16"         # "bf16" | "e4m3" | "e5m2" | "f32"
    loss: str = "bce"                  # "bce" (XMC) | "softmax_ce" (LM)
    use_sr: bool = True                # stochastic rounding in the update
    kahan_chunks: int = 0              # leading chunks w/ Kahan comp (App. D)
    drop_rate: float = 0.0             # in-kernel DropConnect (App. H)
    quantize_x: Optional[bool] = None  # default: True iff weight is e4m3
    compute_loss: bool = True          # loss value is optional (loss-skip)
    # impl selects "<path>[_<inner>]" where path is one of
    #   grid    — whole-head grid megakernel, ONE launch per step
    #             (kernels/fused_head.py, DESIGN.md §7) — the default
    #   fused   — PR-1 per-chunk scan of the single-launch chunk kernel
    #             (kernels/fused_chunk.py) — the grid path's bit-parity
    #             oracle
    #   unfused — legacy 3-kernel composition, kept for A/B
    # and inner is auto|kernel|interpret|xla.  Bare inner names ("auto",
    # "xla", "interpret", …) select the grid path with that inner impl;
    # a grid path whose inner resolves to "xla" runs the fused scan (the
    # two are the same algorithm — the grid kernel has no jnp oracle of
    # its own).
    impl: str = "auto"
    # softmax-CE only: reuse the LSE pre-pass logits in pass 2 ("on"/"off",
    # or "auto" = on when the z cache fits _CACHE_Z_BYTES)
    cache_z: str = "auto"

    @property
    def wdtype(self):
        return _WEIGHT_DTYPES[self.weight_dtype]

    @property
    def qx(self) -> bool:
        return self.weight_dtype == "e4m3" if self.quantize_x is None \
            else self.quantize_x

    # label rows per chunk are padded to a multiple of _CHUNK_ALIGN so the
    # chunk dimension stays divisible by the mesh's model axis (vocab-
    # parallel sharding) and by MXU tile sizes
    _CHUNK_ALIGN = 256

    @property
    def chunk(self) -> int:
        c = self.num_chunks
        per = (self.num_labels + c - 1) // c
        if self.num_labels >= self._CHUNK_ALIGN:
            per = ((per + self._CHUNK_ALIGN - 1) // self._CHUNK_ALIGN
                   ) * self._CHUNK_ALIGN
        return per

    @property
    def padded_labels(self) -> int:
        return self.chunk * self.num_chunks

    def __post_init__(self):
        assert 0 <= self.kahan_chunks <= self.num_chunks
        assert self.loss in ("bce", "softmax_ce")
        assert self.cache_z in ("auto", "on", "off")


# z-cache budget for the CE cached-logits fast path (B·padded_labels bf16);
# past this, recomputing pass-2 logits beats holding them (paper §4.2: the
# whole point of chunking is not materializing (B, L))
_CACHE_Z_BYTES = 32 * 2 ** 20


def _want_cache_z(cfg: "ELMOHeadConfig", z_bytes: int) -> bool:
    """The ONE CE z-cache policy shared by the grid, fused-scan and
    sharded paths: explicit on/off wins; "auto" caches iff this path's
    z footprint (``z_bytes``, local to the device) fits the budget."""
    return cfg.cache_z == "on" or (cfg.cache_z == "auto"
                                   and z_bytes <= _CACHE_Z_BYTES)


def _impl_split(impl: str) -> Tuple[str, str]:
    """cfg.impl → (path, inner kernel impl).

    path ∈ {"grid", "fused", "unfused"} (see ``ELMOHeadConfig.impl``).
    Bare inner names keep their historical meaning of "the default fast
    path with this inner impl" — which is now the grid path."""
    for path in ("grid", "fused", "unfused"):
        if impl == path or impl.startswith(path + "_") \
                or impl.startswith(path + ":"):
            rest = impl[len(path):].lstrip("_:")
            return path, (rest or "auto")
    return "grid", impl


def _grid_ok(cfg: ELMOHeadConfig, batch: int, rimpl: str,
             p_slots: int = 1) -> bool:
    """Whether the whole-head grid megakernel can run this step.

    The grid kernel has no jnp oracle (inner "xla" routes to the fused
    scan, which *is* the oracle), the mixed Kahan hybrid keeps the
    per-chunk scan (a homogeneous update rule lets one grid cover every
    block), and the compiled path must fit the §7 VMEM residency model —
    gated with the same ``p_slots`` (resident target columns) the launch
    will size the kernel with, so gate and tile chooser agree."""
    if rimpl not in ("kernel", "interpret"):
        return False
    if cfg.kahan_chunks not in (0, cfg.num_chunks):
        return False
    if rimpl == "kernel" and not _tuning.fused_head_viable(
            batch, cfg.d_model, jnp.dtype(cfg.wdtype).itemsize,
            kahan=cfg.kahan_chunks > 0, p_slots=p_slots):
        return False
    return True


def _target_slots(targets: jax.Array) -> int:
    return targets.shape[-1] if targets.ndim == 2 else 1


def _grid_seeds(cfg: ELMOHeadConfig, seed: jax.Array):
    """Per-chunk DropConnect/SR seed vectors — elementwise identical to the
    scalar ``_chunk_seed`` draws of the per-chunk scan."""
    cids = jnp.arange(cfg.num_chunks, dtype=jnp.int32)
    return _chunk_seed(seed, cids, 0), _chunk_seed(seed, cids, 1), cids


class HeadState(NamedTuple):
    """w: (C, Lc, D) in storage dtype; comp: (Ck, Lc, D) BF16 (App. D)."""
    w: jax.Array
    comp: Optional[jax.Array]


def init_head(key: jax.Array, cfg: ELMOHeadConfig, scale: float | None = None
              ) -> HeadState:
    scale = scale if scale is not None else 1.0 / np.sqrt(cfg.d_model)
    w = (jax.random.normal(key, (cfg.num_chunks, cfg.chunk, cfg.d_model),
                           jnp.float32) * scale).astype(cfg.wdtype)
    comp = (jnp.zeros((cfg.kahan_chunks, cfg.chunk, cfg.d_model), P.BF16)
            if cfg.kahan_chunks else None)
    return HeadState(w, comp)


def _valid_cols(cfg: ELMOHeadConfig, cidx: jax.Array) -> jax.Array:
    """(chunk,) bool — masks padded label columns in the final chunk."""
    c0 = cidx * cfg.chunk
    return (c0 + jnp.arange(cfg.chunk)) < cfg.num_labels


def _chunk_logits(cfg: ELMOHeadConfig, wc: jax.Array, x: jax.Array,
                  seed: jax.Array, impl: str | None = None) -> jax.Array:
    impl = _impl_split(cfg.impl)[1] if impl is None else impl
    return ops.fp8_logits(x, wc, seed, drop_rate=cfg.drop_rate,
                          quantize_x=cfg.qx, impl=impl)


def _chunk_seed(seed: jax.Array, cidx: jax.Array, salt: int) -> jax.Array:
    return PR.mix32(seed.astype(jnp.uint32)
                    + cidx.astype(jnp.uint32) * np.uint32(2654435761)
                    + np.uint32(salt))


# ---------------------------------------------------------------------------
# training step
# ---------------------------------------------------------------------------


def _chunk_grad(cfg: ELMOHeadConfig, z: jax.Array, targets: jax.Array,
                cidx: jax.Array, lse: Optional[jax.Array],
                scale: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Loss-skip logit gradient + optional loss contribution for one chunk."""
    return L.chunk_loss_skip_grad(cfg.loss, z, targets, cidx * cfg.chunk,
                                  cfg.chunk, cfg.num_labels, lse, scale,
                                  cfg.compute_loss)


def _masked_z(cfg: ELMOHeadConfig, z: jax.Array, cidx: jax.Array) -> jax.Array:
    valid = _valid_cols(cfg, cidx)[None, :]
    return jnp.where(valid, z.astype(jnp.float32), L.NEG_INF)


def _scan_chunks(cfg: ELMOHeadConfig, w, comp, chunk_ids, zs, carry,
                 chunk_step):
    """The Kahan/SR chunk-scan split shared by every train-step path
    (fused, unfused, sharded).  ``chunk_step(xg, loss, wc, comp_c, cidx,
    z_c)`` is the per-chunk work; the documented fused-vs-unfused-vs-
    sharded parity depends on this scaffolding living in exactly one
    place.  Returns (carry, w_kahan, w_sr, comp_new)."""

    def kahan_body(carry, inp):
        xg, loss = carry
        wc, comp_c, cidx, z_c = (inp if zs is not None else inp + (None,))
        xg, loss, wc_new, comp_new = chunk_step(xg, loss, wc, comp_c, cidx,
                                                z_c)
        return (xg, loss), (wc_new, comp_new)

    def sr_body(carry, inp):
        xg, loss = carry
        wc, cidx, z_c = inp if zs is not None else inp + (None,)
        xg, loss, wc_new, _ = chunk_step(xg, loss, wc, None, cidx, z_c)
        return (xg, loss), wc_new

    ck = cfg.kahan_chunks
    if ck:
        xs = (w[:ck], comp, chunk_ids[:ck])
        if zs is not None:
            xs += (zs[:ck],)
        carry, (w_k, comp_new) = jax.lax.scan(kahan_body, carry, xs)
    else:
        w_k, comp_new = w[:0], comp

    if ck < cfg.num_chunks:
        xs = (w[ck:], chunk_ids[ck:])
        if zs is not None:
            xs += (zs[ck:],)
        carry, w_s = jax.lax.scan(sr_body, carry, xs)
    else:
        w_s = w[:0]
    return carry, w_k, w_s, comp_new


def head_train_step(cfg: ELMOHeadConfig, state: HeadState, x: jax.Array,
                    targets: jax.Array, lr: jax.Array, wd: jax.Array,
                    seed: jax.Array
                    ) -> Tuple[HeadState, jax.Array, dict]:
    """One fused forward/backward/update pass over all label chunks.

    x: (B, D) bf16 backbone outputs (tokens flattened).
    targets: (B, P) int32 multi-label ids (bce) or (B,) int32 ids (ce).
    Returns (new_state, x_grad (B, D) bf16, metrics).

    Default path: the whole-head grid megakernel — ONE Pallas launch for
    every label chunk (two grid passes sharing that launch for softmax-CE),
    with x/x̄/LSE stats resident in VMEM across the grid (DESIGN.md §7).
    ``cfg.impl="fused*"`` keeps the PR-1 per-chunk scan (the grid path's
    bit-parity oracle), ``"unfused*"`` the legacy multi-kernel composition;
    all three are numerically identical by construction.
    """
    path, impl = _impl_split(cfg.impl)
    rimpl = ops.resolve_impl(impl)
    if path == "grid" and _grid_ok(cfg, x.shape[0], rimpl,
                                   _target_slots(targets)):
        return _head_train_step_grid(cfg, state, x, targets, lr, wd, seed,
                                     impl)
    fused = path != "unfused"
    if (fused and rimpl == "kernel"
            and not _tuning.fused_chunk_viable(
                x.shape[0], cfg.d_model,
                jnp.dtype(cfg.wdtype).itemsize,
                kahan=cfg.kahan_chunks > 0)):
        fused = False   # megakernel working set exceeds VMEM at this B·S
    if fused:
        return _head_train_step_fused(cfg, state, x, targets, lr, wd, seed,
                                      impl)
    return _head_train_step_unfused(cfg, state, x, targets, lr, wd, seed,
                                    impl)


def _head_train_step_grid(cfg: ELMOHeadConfig, state: HeadState,
                          x: jax.Array, targets: jax.Array, lr: jax.Array,
                          wd: jax.Array, seed: jax.Array, impl: str
                          ) -> Tuple[HeadState, jax.Array, dict]:
    """One whole-head grid-megakernel launch (DESIGN.md §7): the label loop
    runs inside the Pallas grid, so BCE is exactly one launch per step and
    softmax-CE one two-pass launch (the z-cache spills through a
    grid-mapped HBM buffer instead of a second launch)."""
    B = x.shape[0]
    x = x.astype(jnp.bfloat16)
    seed = seed.astype(jnp.uint32)
    seeds_d, seeds_u, cids = _grid_seeds(cfg, seed)
    base = cids * cfg.chunk
    kahan = cfg.kahan_chunks > 0
    comp = state.comp if kahan else None
    common = dict(num_labels=cfg.num_labels, use_sr=cfg.use_sr,
                  quantize_x=cfg.qx, drop_rate=cfg.drop_rate,
                  compute_loss=cfg.compute_loss, impl=impl)

    if cfg.loss == "bce":
        scale, lse = jnp.float32(1.0 / B), None
        out = ops.fused_head_step(x, state.w, targets, lr, wd, scale,
                                  seeds_d, seeds_u, base, comp=comp,
                                  mode="bce", **common)
    else:
        n_tok = jnp.maximum((targets >= 0).sum(), 1).astype(jnp.float32)
        scale = 1.0 / n_tok
        # same cache budget rule as the per-chunk scan — but the grid
        # cache is VMEM-resident (fused_head.py), so the compiled path
        # additionally requires it to fit the §7 residency model
        cache = _want_cache_z(cfg, B * cfg.padded_labels * 2)
        if cache and ops.resolve_impl(impl) == "kernel" \
                and not _tuning.fused_head_viable(
                    B, cfg.d_model, jnp.dtype(cfg.wdtype).itemsize,
                    kahan=kahan, cache_z=True, lc=cfg.chunk,
                    n_chunks=cfg.num_chunks):
            cache = False       # recompute pass-2 logits in-kernel instead
        out = ops.fused_head_step(x, state.w, targets, lr, wd, scale,
                                  seeds_d, seeds_u, base, comp=comp,
                                  mode="ce_full", cache_z=cache, **common)
        lse = out.lse

    w_k = out.w if kahan else state.w[:0]
    w_s = state.w[:0] if kahan else out.w
    return _finalize_step(cfg, (out.xg, out.loss), w_k, w_s, out.comp,
                          targets, lse, scale, B)


def _head_train_step_fused(cfg: ELMOHeadConfig, state: HeadState,
                           x: jax.Array, targets: jax.Array, lr: jax.Array,
                           wd: jax.Array, seed: jax.Array, impl: str
                           ) -> Tuple[HeadState, jax.Array, dict]:
    B = x.shape[0]
    x = x.astype(jnp.bfloat16)
    seed = seed.astype(jnp.uint32)
    chunk_ids = jnp.arange(cfg.num_chunks, dtype=jnp.int32)

    if cfg.loss == "bce":
        n_tok = None
        scale = jnp.float32(1.0 / B)
    else:
        n_tok = jnp.maximum((targets >= 0).sum(), 1).astype(jnp.float32)
        scale = 1.0 / n_tok

    # hoisted tile-alignment padding: the compiled-kernel path pads
    # x/x̄/targets ONCE per step here (the chunk kernel's own pad2 calls
    # become no-ops), instead of re-padding the loop-invariant operands at
    # every chunk of the scan.  ``n_b`` tells the kernel the logical batch
    # so its masking ignores the padded rows.  interpret/xla inners keep
    # exact shapes (their bitwise-parity contract forbids padding).
    n_b = None
    if ops.resolve_impl(impl) == "kernel":
        n_b = B
        Bp = _tuning._pad_up(B, 16)
        Dp = _tuning._pad_up(cfg.d_model, _tuning.LANE)
        x = _tuning.pad2(x, Bp, Dp)
        targets = _tuning.pad2(
            targets if targets.ndim == 2 else targets.reshape(B, 1),
            Bp, 1, value=-1)
        if cfg.loss == "softmax_ce":
            targets = targets.reshape(-1)

    if cfg.loss == "bce":
        lse, zs = None, None
    else:
        cache = _want_cache_z(cfg, B * cfg.padded_labels * 2)

        # ----- pass 1: streaming LSE (optionally caching each chunk's z
        # so pass 2 skips the forward matmul entirely)
        def lse_body(carry, inp):
            wc, cidx = inp
            m, s = carry
            z = _chunk_logits(cfg, wc, x, _chunk_seed(seed, cidx, 0), impl)
            carry = L.lse_update(m, s, _masked_z(cfg, z, cidx))
            return carry, (z if cache else None)

        (m, s), zs = jax.lax.scan(lse_body, L.lse_init(x.shape[0]),
                                  (state.w, chunk_ids))
        lse = L.lse_finalize(m, s)

    def chunk_step(xg, loss_acc, wc, comp_c, cidx, z_c):
        out = ops.fused_chunk_step(
            x, wc, targets, xg, lr, wd, scale, cidx * cfg.chunk,
            _chunk_seed(seed, cidx, 0), _chunk_seed(seed, cidx, 1),
            lse=lse, z=z_c, comp=comp_c, loss=cfg.loss,
            num_labels=cfg.num_labels, use_sr=cfg.use_sr,
            quantize_x=cfg.qx, drop_rate=cfg.drop_rate,
            compute_loss=cfg.compute_loss, impl=impl,
            **({"n_b": n_b} if n_b is not None else {}))
        return out.xg, loss_acc + out.loss, out.w, out.comp

    carry = (jnp.zeros(x.shape, jnp.bfloat16), jnp.float32(0.0))
    carry, w_k, w_s, comp_new = _scan_chunks(cfg, state.w, state.comp,
                                             chunk_ids, zs, carry,
                                             chunk_step)
    carry = (carry[0][:B, :cfg.d_model], carry[1])
    return _finalize_step(cfg, carry, w_k, w_s, comp_new, targets, lse,
                          scale, B)


def _finalize_step(cfg: ELMOHeadConfig, carry, w_k, w_s, comp_new, targets,
                   lse, scale, B: int) -> Tuple[HeadState, jax.Array, dict]:
    """Shared epilogue of both train-step paths: reassemble the chunk
    weights and fold the accumulated loss (the fused/unfused A/B guarantee
    depends on this formula living in exactly one place)."""
    (xg, loss_raw) = carry
    w_new = jnp.concatenate([w_k, w_s], axis=0) if cfg.kahan_chunks else w_s

    if cfg.loss == "bce":
        loss = loss_raw / B
    else:
        # Σ(lse − z_target) over valid tokens; loss_raw = Σ z_target
        tok_mask = (targets >= 0)
        loss = ((lse * tok_mask).sum() - loss_raw) * scale \
            if cfg.compute_loss else loss_raw

    metrics = {"loss": loss,
               "xgrad_norm": jnp.linalg.norm(xg.astype(jnp.float32))}
    return HeadState(w_new, comp_new), xg, metrics


def _head_train_step_unfused(cfg: ELMOHeadConfig, state: HeadState,
                             x: jax.Array, targets: jax.Array,
                             lr: jax.Array, wd: jax.Array, seed: jax.Array,
                             impl: str
                             ) -> Tuple[HeadState, jax.Array, dict]:
    """Legacy multi-kernel path (three launches + HBM logits/grad round
    trips per chunk) — kept selectable for fused-vs-unfused A/B."""
    B = x.shape[0]
    x = x.astype(jnp.bfloat16)
    seed = seed.astype(jnp.uint32)

    if cfg.loss == "bce":
        scale = jnp.float32(1.0 / B)
        lse = None
    else:
        n_tok = jnp.maximum((targets >= 0).sum(), 1).astype(jnp.float32)
        scale = 1.0 / n_tok

        # ----- pass 1: streaming LSE over chunks (paper §4.2 chunking + CE)
        def lse_body(carry, inp):
            wc, cidx = inp
            m, s = carry
            z = _masked_z(cfg, _chunk_logits(cfg, wc, x,
                                             _chunk_seed(seed, cidx, 0),
                                             impl), cidx)
            return L.lse_update(m, s, z), None

        (m, s), _ = jax.lax.scan(
            lse_body, L.lse_init(B),
            (state.w, jnp.arange(cfg.num_chunks, dtype=jnp.int32)))
        lse = L.lse_finalize(m, s)

    # ----- pass 2: per-chunk grad + fused update + x̄ accumulation
    def chunk_step(xg, loss_acc, wc, comp_c, cidx, _z):
        sd = _chunk_seed(seed, cidx, 0)
        z = _chunk_logits(cfg, wc, x, sd, impl)
        g, loss_c = _chunk_grad(cfg, z, targets, cidx, lse, scale)
        # x̄ accumulates in BF16 (paper §4.1: gradients stay BF16) — halves
        # the accumulator and its cross-model all-reduce
        xg = xg + ops.fp8_input_grad(g, wc, impl=impl)
        upd_seed = _chunk_seed(seed, cidx, 1)
        if comp_c is None:
            wc_new = ops.fused_head_update(g, x, wc, lr, wd, upd_seed,
                                           use_sr=cfg.use_sr, impl=impl)
            return xg, loss_acc + loss_c, wc_new, None
        wc_new, comp_new = ops.fused_head_update_kahan(
            g, x, wc, comp_c, lr, wd, upd_seed, impl=impl)
        return xg, loss_acc + loss_c, wc_new, comp_new

    carry = (jnp.zeros((B, cfg.d_model), jnp.bfloat16), jnp.float32(0.0))
    carry, w_k, w_s, comp_new = _scan_chunks(
        cfg, state.w, state.comp,
        jnp.arange(cfg.num_chunks, dtype=jnp.int32), None, carry,
        chunk_step)
    return _finalize_step(cfg, carry, w_k, w_s, comp_new, targets, lse,
                          scale, B)


# ---------------------------------------------------------------------------
# label-sharded training (DESIGN.md §6)
# ---------------------------------------------------------------------------


def _resolve_ctx(ctx):
    """Active MeshContext (explicit arg wins) and its model-axis size."""
    from repro.dist import meshctx as _meshctx  # lazy: dist imports core
    ctx = _meshctx.get() if ctx is None else ctx
    return ctx, (1 if ctx is None else ctx.model_size)


def init_xg_err(cfg: ELMOHeadConfig, batch: int, ctx=None) -> jax.Array:
    """Per-shard E5M2 error-feedback carry for the compressed x̄ reduction:
    (model_size, B, D) BF16, row r owned by model rank r."""
    _, n = _resolve_ctx(ctx)
    return jnp.zeros((n, batch, cfg.d_model), P.BF16)


def head_train_step_sharded(cfg: ELMOHeadConfig, state: HeadState,
                            x: jax.Array, targets: jax.Array, lr: jax.Array,
                            wd: jax.Array, seed: jax.Array, ctx=None, *,
                            ce_comm: str = "gather",
                            compress_xg: bool = False,
                            xg_err: Optional[jax.Array] = None):
    """``head_train_step`` with the label dimension sharded over the mesh's
    model axis (vocab parallelism, per ``dist.sharding.head_specs``).

    Every model rank holds ``chunk/n`` rows of each chunk (W and the Kahan
    buffer partitioned identically) and runs the whole-head grid megakernel
    (DESIGN.md §7 — one launch for BCE, two for softmax-CE whose normalizer
    collective sits between them) or, off the grid path, the per-chunk
    fused kernel scan on its local shard; the batch is gathered over the
    data axes so the in-kernel weight update sees full-B gradients — W
    updates stay deterministic and need no cross-data all-reduce.
    Per-shard x̄ partials are ``psum``-reduced over the model axis
    (optionally E5M2-compressed, see ``compress_xg``).

    Softmax-CE couples shards through the row normalizer; ``ce_comm`` picks
    the cross-device LSE strategy (DESIGN.md §6):

    * ``"gather"`` (default) — the pass-1 logits of each chunk are
      all-gathered (BF16, column-tiled) and the streaming LSE + the loss
      run on the full-width rows: weights, Kahan state and the loss are
      **bit-identical** to single-device ``head_train_step`` for
      deterministic updates (BF16 Kahan / no-SR).  Comm: B·L·2 bytes/step.
    * ``"stats"`` — each shard folds a local (max, Σexp) over its label
      windows, then one ``pmax`` + one rescaled ``psum`` form the global
      log-normalizer: comm is O(B) but sums reassociate (parity to ~1e-6).

    BCE is embarrassingly parallel; ``ce_comm`` only selects whether the
    loss *value* is computed from gathered logits (bit-parity) or from
    ``psum``-ed per-shard partials.

    ``compress_xg`` sends each shard's x̄ over the wire as E5M2 (1 byte/elem,
    ``dist.compression``); with ``xg_err`` (see ``init_xg_err``) the residual
    is carried across steps as classic error feedback, and the updated carry
    is returned as a fourth output.

    Falls back to the single-device step when no mesh is active or the
    chunk does not divide the model axis.  SR and DropConnect draws are
    hashed per *local* tile, so low-precision SR runs match single-device
    only distributionally (the paper's own guarantee, App. C).
    """
    from repro.dist.compat import shard_map as _shard_map

    assert ce_comm in ("gather", "stats"), ce_comm
    assert xg_err is None or compress_xg, "xg_err implies compress_xg"
    ctx, n = _resolve_ctx(ctx)
    if n == 1 or cfg.chunk % n != 0:
        out = head_train_step(cfg, state, x, targets, lr, wd, seed)
        return out if xg_err is None else out + (xg_err,)

    mesh, axis = ctx.mesh, ctx.model_axis
    batch_axes = tuple(a for a in ctx.batch_axes
                      if a in mesh.shape and mesh.shape[a] > 1)
    n_batch = 1
    for a in batch_axes:
        n_batch *= int(mesh.shape[a])
    if x.shape[0] % n_batch != 0:
        batch_axes, n_batch = (), 1      # ragged batch: replicate instead
    b0 = batch_axes if batch_axes else None

    path, inner = _impl_split(cfg.impl)
    rimpl = ops.resolve_impl(inner)
    lc = cfg.chunk // n
    B_g = x.shape[0]                 # global batch (the body re-gathers it)
    # grid path: ONE whole-head launch per collective-free pass (BCE = 1
    # launch; CE = LSE launch + collective + update launch, ≤ 2).  The
    # gather-mode losses/LSE read the local logits back, so those paths
    # additionally need the local z to fit the cache budget.
    grid = path == "grid" and _grid_ok(cfg, B_g, rimpl,
                                       _target_slots(targets))
    z_fits = B_g * (cfg.padded_labels // n) * 2 <= _CACHE_Z_BYTES
    if ce_comm == "gather" and (cfg.loss == "softmax_ce"
                                or cfg.compute_loss):
        grid = grid and z_fits
    if not grid and rimpl == "kernel" and not _tuning.fused_chunk_viable(
            B_g, cfg.d_model, jnp.dtype(cfg.wdtype).itemsize,
            kahan=cfg.kahan_chunks > 0):
        inner = "xla"    # sharded path is megakernel-shaped; oracle fallback

    kahan = cfg.kahan_chunks > 0
    chunk_ids = jnp.arange(cfg.num_chunks, dtype=jnp.int32)
    has_err = xg_err is not None
    impl = inner

    def body(*args):
        it = iter(args)
        w = next(it)
        comp = next(it) if kahan else None
        xl, tgt = next(it), next(it)
        lr_, wd_, seed_ = next(it), next(it), next(it)
        err = next(it) if has_err else None          # (1, B, D) local slice

        Bl = xl.shape[0]
        for a in reversed(batch_axes):   # innermost batch axis first
            xl = jax.lax.all_gather(xl, a, axis=0, tiled=True)
            tgt = jax.lax.all_gather(tgt, a, axis=0, tiled=True)
        x16 = xl.astype(jnp.bfloat16)
        B = x16.shape[0]
        r = jax.lax.axis_index(axis)
        # independent SR/DropConnect stream per shard: kernel bits are
        # hashed by the *local* tile index, so shards must not share seeds
        seed_sh = PR.mix32(seed_.astype(jnp.uint32)
                           + (r.astype(jnp.uint32) + 1)
                           * np.uint32(0x85EBCA6B))

        def c0_of(cidx):
            return cidx * cfg.chunk + r.astype(jnp.int32) * lc

        kernel_loss = cfg.compute_loss and ce_comm == "stats"

        if grid:
            # ---- whole-head grid-megakernel branch (DESIGN.md §7) ----
            seeds_d = _chunk_seed(seed_sh, chunk_ids, 0)
            seeds_u = _chunk_seed(seed_sh, chunk_ids, 1)
            base = chunk_ids * cfg.chunk + r.astype(jnp.int32) * lc
            gkw = dict(num_labels=cfg.num_labels, use_sr=cfg.use_sr,
                       quantize_x=cfg.qx, drop_rate=cfg.drop_rate,
                       impl=impl)
            lse = None
            if cfg.loss == "bce":
                scale = jnp.float32(1.0 / B)
                # gather-mode loss needs the (pre-update) local logits:
                # the single launch emits them alongside the update
                want_z = cfg.compute_loss and ce_comm == "gather"
                out = ops.fused_head_step(
                    x16, w, tgt, lr_, wd_, scale, seeds_d, seeds_u, base,
                    comp=comp, mode="bce", cache_z=want_z,
                    compute_loss=kernel_loss, **gkw)
                loss_raw = out.loss
                if want_z:
                    z3 = jnp.moveaxis(
                        out.z.reshape(B, cfg.num_chunks, lc), 1, 0)

                    def loss_body(acc, inp):
                        zl, cidx = inp
                        zf = jax.lax.all_gather(zl, axis, axis=1,
                                                tiled=True)
                        y = L.chunk_multi_hot(tgt, cidx * cfg.chunk,
                                              cfg.chunk)
                        return acc + L.bce_chunk_loss(
                            zf, y, mask=_valid_cols(cfg, cidx)[None, :]), \
                            None

                    loss_raw, _ = jax.lax.scan(
                        loss_body, jnp.float32(0.0), (z3, chunk_ids))
            else:
                n_tok = jnp.maximum((tgt >= 0).sum(), 1
                                    ).astype(jnp.float32)
                scale = 1.0 / n_tok
                loss_pre = jnp.float32(0.0)
                if ce_comm == "gather":
                    # launch 1: all local logits; LSE + exact loss on the
                    # per-chunk gathered rows, op-for-op the single-device
                    # sequence (the bit-parity contract)
                    zflat = ops.fused_head_logits(
                        x16, w, seeds_d, quantize_x=cfg.qx,
                        drop_rate=cfg.drop_rate, impl=impl)
                    z3 = jnp.moveaxis(
                        zflat.reshape(B, cfg.num_chunks, lc), 1, 0)

                    def lse_body(carry, inp):
                        zl, cidx = inp
                        m, s, lraw = carry
                        zf = jax.lax.all_gather(zl, axis, axis=1,
                                                tiled=True)
                        m, s = L.lse_update(m, s, _masked_z(cfg, zf, cidx))
                        if cfg.compute_loss:
                            lraw = lraw + L.ce_target_logit_chunk(
                                zf, tgt, cidx * cfg.chunk, cfg.chunk).sum()
                        return (m, s, lraw), None

                    (m, s, loss_pre), _ = jax.lax.scan(
                        lse_body, L.lse_init(B) + (jnp.float32(0.0),),
                        (z3, chunk_ids))
                    lse = L.lse_finalize(m, s)
                else:
                    # launch 1: in-kernel local streaming (max, Σexp),
                    # then the O(B) pmax/psum normalizer collective
                    cache = _want_cache_z(
                        cfg, B * (cfg.padded_labels // n) * 2)
                    st = ops.fused_head_lse(
                        x16, w, seeds_d, base, num_labels=cfg.num_labels,
                        quantize_x=cfg.qx, drop_rate=cfg.drop_rate,
                        cache_z=cache, impl=impl)
                    m_g = jax.lax.pmax(st.m, axis)
                    s_g = jax.lax.psum(st.s * jnp.exp(st.m - m_g), axis)
                    lse = L.lse_finalize(m_g, s_g)
                    zflat = st.z
                # launch 2: the whole-head update against the global LSE
                out = ops.fused_head_step(
                    x16, w, tgt, lr_, wd_, scale, seeds_d, seeds_u, base,
                    lse=lse, z=zflat, comp=comp, mode="ce_update",
                    cache_z=zflat is not None, compute_loss=kernel_loss,
                    **gkw)
                loss_raw = loss_pre + out.loss
            xg_loc = out.xg
            w_k = out.w if kahan else w[:0]
            w_s = w[:0] if kahan else out.w
            comp_new = out.comp
        else:
            # ---- legacy per-chunk scan branch (fused_chunk_step per chunk) ----
            loss_pre = jnp.float32(0.0)
            if cfg.loss == "bce":
                scale = jnp.float32(1.0 / B)
                lse, zs = None, None
            else:
                n_tok = jnp.maximum((tgt >= 0).sum(), 1).astype(jnp.float32)
                scale = 1.0 / n_tok
                cache = _want_cache_z(cfg,
                                      B * (cfg.padded_labels // n) * 2)

                if ce_comm == "gather":
                    # pass 1: full-width streaming LSE on gathered chunk logits
                    # (identical op sequence to the single-device pass — the
                    # source of the bit-parity guarantee); the CE target-logit
                    # sum rides along so the loss is exact too
                    def lse_body(carry, inp):
                        wc, cidx = inp
                        m, s, lraw = carry
                        zl = _chunk_logits(cfg, wc, x16,
                                           _chunk_seed(seed_sh, cidx, 0), impl)
                        zf = jax.lax.all_gather(zl, axis, axis=1, tiled=True)
                        m, s = L.lse_update(m, s, _masked_z(cfg, zf, cidx))
                        if cfg.compute_loss:
                            lraw = lraw + L.ce_target_logit_chunk(
                                zf, tgt, cidx * cfg.chunk, cfg.chunk).sum()
                        return (m, s, lraw), (zl if cache else None)

                    (m, s, loss_pre), zs = jax.lax.scan(
                        lse_body, L.lse_init(B) + (jnp.float32(0.0),),
                        (w, chunk_ids))
                else:
                    # pass 1 (stats): local (max, Σexp) over this shard's label
                    # windows, then pmax + one rescaled psum — O(B) comm
                    def lse_body(carry, inp):
                        wc, cidx = inp
                        m, s = carry
                        zl = _chunk_logits(cfg, wc, x16,
                                           _chunk_seed(seed_sh, cidx, 0), impl)
                        validl = (c0_of(cidx) + jnp.arange(lc)) < cfg.num_labels
                        zm = jnp.where(validl[None, :], zl.astype(jnp.float32),
                                       L.NEG_INF)
                        return L.lse_update(m, s, zm), (zl if cache else None)

                    (m, s), zs = jax.lax.scan(lse_body, L.lse_init(B),
                                              (w, chunk_ids))
                    m_g = jax.lax.pmax(m, axis)
                    s_g = jax.lax.psum(s * jnp.exp(m - m_g), axis)
                    m, s = m_g, s_g
                lse = L.lse_finalize(m, s)

            def chunk_step(xg, loss_acc, wc, comp_c, cidx, z_c):
                if cfg.loss == "bce" and ce_comm == "gather":
                    z_c = _chunk_logits(cfg, wc, x16,
                                        _chunk_seed(seed_sh, cidx, 0), impl)
                    if cfg.compute_loss:
                        zf = jax.lax.all_gather(z_c, axis, axis=1, tiled=True)
                        y = L.chunk_multi_hot(tgt, cidx * cfg.chunk, cfg.chunk)
                        loss_acc = loss_acc + L.bce_chunk_loss(
                            zf, y, mask=_valid_cols(cfg, cidx)[None, :])
                out = ops.fused_chunk_step(
                    x16, wc, tgt, xg, lr_, wd_, scale, c0_of(cidx),
                    _chunk_seed(seed_sh, cidx, 0), _chunk_seed(seed_sh, cidx, 1),
                    lse=lse, z=z_c, comp=comp_c, loss=cfg.loss,
                    num_labels=cfg.num_labels, use_sr=cfg.use_sr,
                    quantize_x=cfg.qx, drop_rate=cfg.drop_rate,
                    compute_loss=kernel_loss, impl=impl)
                return out.xg, loss_acc + out.loss, out.w, out.comp

            carry = (jnp.zeros((B, cfg.d_model), jnp.bfloat16), loss_pre)
            carry, w_k, w_s, comp_new = _scan_chunks(cfg, w, comp, chunk_ids,
                                                     zs, carry, chunk_step)
            xg_loc, loss_raw = carry

        if ce_comm == "stats" and cfg.compute_loss:
            loss_raw = jax.lax.psum(loss_raw, axis)

        # ---- cross-shard x̄ reduction (optionally E5M2 on the wire) ----
        err_new = err
        if compress_xg:
            from repro.dist import compression as C
            if err is not None:
                cpr, e = C.compress_with_feedback(xg_loc, err[0])
                err_new = e[None]
            else:
                cpr = C.compress(xg_loc)
            payloads = jax.lax.all_gather(cpr.payload, axis)   # (n, B·D) e5m2
            scales = jax.lax.all_gather(cpr.scale, axis)       # (n,)
            xg32 = (payloads.astype(jnp.float32) * scales[:, None]).sum(0)
            xg_comb = xg32.reshape(B, cfg.d_model).astype(jnp.bfloat16)
        else:
            xg_comb = jax.lax.psum(xg_loc.astype(jnp.float32), axis
                                   ).astype(jnp.bfloat16)

        st_new, xg_full, metrics = _finalize_step(
            cfg, (xg_comb, loss_raw), w_k, w_s, comp_new, tgt, lse, scale, B)

        if batch_axes:   # hand back only this rank's batch rows
            bidx = jnp.int32(0)
            for a in batch_axes:
                bidx = bidx * mesh.shape[a] + jax.lax.axis_index(a)
            xg_out = jax.lax.dynamic_slice_in_dim(xg_full, bidx * Bl, Bl, 0)
        else:
            xg_out = xg_full

        outs = [st_new.w]
        if kahan:
            outs.append(st_new.comp)
        outs += [xg_out, metrics["loss"], metrics["xgrad_norm"]]
        if has_err:
            outs.append(err_new)
        return tuple(outs)

    wspec = PS(None, axis, None)
    tgt_spec = PS(b0, None) if targets.ndim == 2 else PS(b0)
    operands = [state.w] + ([state.comp] if kahan else []) + [
        x, targets, jnp.asarray(lr, jnp.float32),
        jnp.asarray(wd, jnp.float32), jnp.asarray(seed).astype(jnp.uint32)]
    in_specs = [wspec] + ([wspec] if kahan else []) + [
        PS(b0, None), tgt_spec, PS(), PS(), PS()]
    out_specs = [wspec] + ([wspec] if kahan else []) + [
        PS(b0, None), PS(), PS()]
    if has_err:
        operands.append(xg_err)
        in_specs.append(PS(axis, None, None))
        out_specs.append(PS(axis, None, None))

    outs = _shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                      out_specs=tuple(out_specs), check_vma=False)(*operands)
    it = iter(outs)
    w_new = next(it)
    comp_new = next(it) if kahan else None
    xg, loss, xnorm = next(it), next(it), next(it)
    metrics = {"loss": loss, "xgrad_norm": xnorm}
    ret = (HeadState(w_new, comp_new), xg, metrics)
    return ret + ((next(it),) if has_err else ())


# ---------------------------------------------------------------------------
# inference
# ---------------------------------------------------------------------------


def _grid_serving_ok(cfg: ELMOHeadConfig, batch: int) -> Tuple[bool, str]:
    """(use the single-launch logits grid kernel?, inner impl) for the
    serving paths — gated on the logits-only VMEM model (the serving grid
    allocates none of the train step's resident accumulators)."""
    path, inner = _impl_split(cfg.impl)
    rimpl = ops.resolve_impl(inner)
    ok = (path == "grid" and rimpl in ("kernel", "interpret")
          and (rimpl != "kernel" or _tuning.head_logits_viable(
              batch, cfg.d_model, jnp.dtype(cfg.wdtype).itemsize)))
    return ok, inner


def _eval_seeds(cfg: ELMOHeadConfig) -> jax.Array:
    """The chunk-scan serving paths draw every chunk's DropConnect mask
    from the constant seed 0; the grid kernel reproduces that exactly."""
    return jnp.zeros((cfg.num_chunks,), jnp.uint32)


def head_logits(cfg: ELMOHeadConfig, state: HeadState, x: jax.Array
                ) -> jax.Array:
    """Full (B, L) logits — O(B·L) memory; eval/serve at modest B only.

    On the grid path this is ONE Pallas launch over every label block
    (``kernels/fused_head.fused_head_logits``) instead of one per chunk;
    the per-column op sequence is unchanged, so values are bit-equal."""
    x = x.astype(jnp.bfloat16)
    grid, inner = _grid_serving_ok(cfg, x.shape[0])
    if grid:
        z = ops.fused_head_logits(x, state.w, _eval_seeds(cfg),
                                  quantize_x=cfg.qx,
                                  drop_rate=cfg.drop_rate, impl=inner)
        return z[:, :cfg.num_labels]

    def body(_, inp):
        wc, cidx = inp
        z = _chunk_logits(cfg, wc, x, jnp.uint32(0))  # no dropout at eval
        return None, z

    _, zs = jax.lax.scan(
        body, None, (state.w, jnp.arange(cfg.num_chunks, dtype=jnp.int32)))
    z = jnp.moveaxis(zs, 0, 1).reshape(x.shape[0], cfg.padded_labels)
    return z[:, :cfg.num_labels]


def _topk_scan(cfg: ELMOHeadConfig, w: jax.Array, x: jax.Array, k: int,
               width: int, c0_of) -> Tuple[jax.Array, jax.Array]:
    """Streaming top-k over chunk slices of ``width`` label columns whose
    global offset is ``c0_of(cidx)`` — never materializes full logits.

    The single scan shared by the local and sharded serving paths: ties at
    equal logits resolve to the earliest candidate (lowest label id), and
    padded columns (≥ num_labels) are masked to NEG_INF so they can never
    surface; the sharded merge's tie-break contract depends on this body
    living in exactly one place."""
    B = x.shape[0]

    def body(carry, inp):
        vals, idx = carry
        wc, cidx = inp
        c0 = c0_of(cidx)
        z = _chunk_logits(cfg, wc, x, jnp.uint32(0))  # no dropout at eval
        valid = (c0 + jnp.arange(width)) < cfg.num_labels
        z = jnp.where(valid[None, :], z.astype(jnp.float32), L.NEG_INF)
        cand = jnp.concatenate([vals, z], axis=1)
        cand_idx = jnp.concatenate(
            [idx, jnp.broadcast_to(c0 + jnp.arange(width), (B, width))],
            axis=1)
        v, local = jax.lax.top_k(cand, k)
        return (v, jnp.take_along_axis(cand_idx, local, axis=1)), None

    init = (jnp.full((B, k), L.NEG_INF, jnp.float32),
            jnp.zeros((B, k), jnp.int32))
    (vals, idx), _ = jax.lax.scan(
        body, init, (w, jnp.arange(cfg.num_chunks, dtype=jnp.int32)))
    return vals, idx


def _topk_materialized(z: jax.Array, col_ids: jax.Array, num_labels: int,
                       k: int) -> Tuple[jax.Array, jax.Array]:
    """Top-k over single-launch logits, reproducing ``_topk_scan``'s
    tie-break contract exactly: ``col_ids`` must be in the scan's visit
    order (ascending label id), padded ids (≥ num_labels) are masked to
    NEG_INF, and k NEG_INF sentinel candidates with id 0 — the scan's
    initial carry — precede the label columns, so overflow slots surface
    (NEG_INF, 0) and ties at equal logits resolve to the earliest (lowest
    label id) candidate; ``lax.top_k`` is stable, which seals the match."""
    B, W = z.shape
    zm = jnp.where((col_ids < num_labels)[None, :], z.astype(jnp.float32),
                   L.NEG_INF)
    cand = jnp.concatenate(
        [jnp.full((B, k), L.NEG_INF, jnp.float32), zm], axis=1)
    cand_ids = jnp.concatenate(
        [jnp.zeros((B, k), jnp.int32), jnp.broadcast_to(col_ids, (B, W))],
        axis=1)
    vals, local = jax.lax.top_k(cand, k)
    return vals, jnp.take_along_axis(cand_ids, local, axis=1)


# serving z-materialization budget for the single-launch top-k fast path —
# its own knob (initialized to the training z-cache default; retuning one
# at runtime deliberately does not move the other): past it, streaming wins
_TOPK_Z_BYTES = 32 * 2 ** 20


def head_topk(cfg: ELMOHeadConfig, state: HeadState, x: jax.Array, k: int
              ) -> Tuple[jax.Array, jax.Array]:
    """Streaming top-k over chunks — never materializes full logits.

    On the grid path, heads whose full logits fit ``_TOPK_Z_BYTES`` use
    ONE logits launch + one global ``top_k`` (bit-identical values *and*
    ids — see ``_topk_materialized``); larger heads keep the per-chunk
    streaming scan."""
    x = x.astype(jnp.bfloat16)
    grid, inner = _grid_serving_ok(cfg, x.shape[0])
    if grid and x.shape[0] * cfg.padded_labels * 2 <= _TOPK_Z_BYTES:
        z = ops.fused_head_logits(x, state.w, _eval_seeds(cfg),
                                  quantize_x=cfg.qx,
                                  drop_rate=cfg.drop_rate, impl=inner)
        return _topk_materialized(z, jnp.arange(cfg.padded_labels),
                                  cfg.num_labels, k)
    return _topk_scan(cfg, state.w, x, k, cfg.chunk,
                      lambda cidx: cidx * cfg.chunk)


def head_logits_sharded(cfg: ELMOHeadConfig, state: HeadState, x: jax.Array,
                        ctx=None) -> jax.Array:
    """``head_logits`` with W label-sharded over the mesh's model axis.

    Each rank computes its (B, C·chunk/n) logit columns; one BF16
    ``all_gather`` per chunk restores the global column order — the op
    sequence per column matches ``head_logits``, so values are bit-equal.
    Falls back to the local path when no mesh is active."""
    from repro.dist.compat import shard_map as _shard_map

    ctx, n = _resolve_ctx(ctx)
    if n == 1 or cfg.chunk % n != 0:
        return head_logits(cfg, state, x)
    axis = ctx.model_axis
    x = x.astype(jnp.bfloat16)
    grid, inner = _grid_serving_ok(cfg, x.shape[0])
    lc = cfg.chunk // n

    def body(w, x):
        B = x.shape[0]
        if grid:
            # one launch for every local label block, then one chunk-tiled
            # gather — same per-column values as the per-chunk scan
            zl = ops.fused_head_logits(x, w, _eval_seeds(cfg),
                                       quantize_x=cfg.qx,
                                       drop_rate=cfg.drop_rate, impl=inner)
            z3 = jnp.moveaxis(zl.reshape(B, cfg.num_chunks, lc), 1, 0)
            zs = jax.lax.all_gather(z3, axis, axis=2, tiled=True)
        else:
            def scan_body(_, inp):
                wc, cidx = inp
                zc = _chunk_logits(cfg, wc, x, jnp.uint32(0))
                return None, jax.lax.all_gather(zc, axis, axis=1, tiled=True)

            _, zs = jax.lax.scan(
                scan_body, None,
                (w, jnp.arange(cfg.num_chunks, dtype=jnp.int32)))
        return jnp.moveaxis(zs, 0, 1).reshape(B, cfg.padded_labels)

    z = _shard_map(body, mesh=ctx.mesh,
                   in_specs=(PS(None, axis, None), PS()),
                   out_specs=PS(), check_vma=False)(state.w, x)
    return z[:, :cfg.num_labels]


def head_topk_sharded(cfg: ELMOHeadConfig, state: HeadState, x: jax.Array,
                      k: int, ctx=None) -> Tuple[jax.Array, jax.Array]:
    """``head_topk`` with W label-sharded: local streaming top-k per rank,
    gather of the n·k candidates, global re-rank (DESIGN.md §6).

    Comm is O(B·k·n) instead of O(B·L); padded label columns are masked on
    the *local* column window so they can never surface, and ids are global.
    Falls back to the local path when no mesh is active."""
    from repro.dist.compat import shard_map as _shard_map

    ctx, n = _resolve_ctx(ctx)
    if n == 1 or cfg.chunk % n != 0:
        return head_topk(cfg, state, x, k)
    axis = ctx.model_axis
    lc = cfg.chunk // n
    x = x.astype(jnp.bfloat16)
    grid, inner = _grid_serving_ok(cfg, x.shape[0])
    grid = grid and x.shape[0] * (cfg.padded_labels // n) * 2 \
        <= _TOPK_Z_BYTES

    def body(w, x):
        r = jax.lax.axis_index(axis).astype(jnp.int32)
        if grid:
            # local candidates from one logits launch; the local column
            # visit order (chunk-major, then row) is ascending global id
            # for a fixed rank, so _topk_materialized's tie-break matches
            # the streaming scan's
            zl = ops.fused_head_logits(x, w, _eval_seeds(cfg),
                                       quantize_x=cfg.qx,
                                       drop_rate=cfg.drop_rate, impl=inner)
            cids = jnp.arange(cfg.num_chunks, dtype=jnp.int32)
            col_ids = ((cids * cfg.chunk + r * lc)[:, None]
                       + jnp.arange(lc, dtype=jnp.int32)[None, :]
                       ).reshape(-1)
            vals, idx = _topk_materialized(zl, col_ids, cfg.num_labels, k)
        else:
            vals, idx = _topk_scan(cfg, w, x, k, lc,
                                   lambda cidx: cidx * cfg.chunk + r * lc)
        # (n, B, k) candidates → (B, n·k) → global re-rank.  Sorting on
        # (−value, id) reproduces head_topk's streaming tie-break (equal
        # logits resolve to the lowest label id) so the merged ids match
        # the single-device output exactly, not just the values.
        vall = jax.lax.all_gather(vals, axis)
        idxl = jax.lax.all_gather(idx, axis)
        B = x.shape[0]
        vall = jnp.moveaxis(vall, 0, 1).reshape(B, n * k)
        idxl = jnp.moveaxis(idxl, 0, 1).reshape(B, n * k)
        nv, ids = jax.lax.sort((-vall, idxl), dimension=1, num_keys=2)
        return -nv[:, :k], ids[:, :k]

    return _shard_map(body, mesh=ctx.mesh,
                      in_specs=(PS(None, axis, None), PS()),
                      out_specs=(PS(), PS()), check_vma=False)(state.w, x)


def precision_at_k(cfg: ELMOHeadConfig, state: HeadState, x: jax.Array,
                   label_ids: jax.Array, k: int) -> jax.Array:
    """P@k for multi-label targets (paper's headline metric)."""
    _, pred = head_topk(cfg, state, x, k)
    hits = (pred[:, :, None] == label_ids[:, None, :]) \
        & (label_ids >= 0)[:, None, :]
    return hits.any(-1).sum(-1).astype(jnp.float32).mean() / k


# ---------------------------------------------------------------------------
# post-hoc classifier refinement (paper App. D.1)
# ---------------------------------------------------------------------------


def convert_head(state: HeadState, from_cfg: ELMOHeadConfig,
                 to_cfg: ELMOHeadConfig) -> HeadState:
    """Re-type the head weights (e.g. FP8 checkpoint → BF16 for refinement).

    Shapes must match (same labels/chunks); the Kahan buffer is created or
    dropped per the target config."""
    assert from_cfg.padded_labels == to_cfg.padded_labels
    assert from_cfg.num_chunks == to_cfg.num_chunks
    w = state.w.astype(jnp.float32).astype(to_cfg.wdtype)
    comp = (jnp.zeros((to_cfg.kahan_chunks, to_cfg.chunk, to_cfg.d_model),
                      P.BF16) if to_cfg.kahan_chunks else None)
    return HeadState(w, comp)


def posthoc_refine(to_cfg: ELMOHeadConfig, state: HeadState,
                   batches, steps: int, lr: float, seed: int = 0
                   ) -> HeadState:
    """App. D.1: fine-tune the head in higher precision on FROZEN encoder
    features.  ``batches`` yields (x, targets) with x already encoded —
    only head memory is resident, so this stays within the low-precision
    run's budget (label chunks stream exactly as in training)."""
    for i, (x, targets) in zip(range(steps), batches):
        state, _, _ = head_train_step(to_cfg, state, x, targets,
                                      jnp.float32(lr), jnp.float32(0.0),
                                      jnp.uint32(seed + i))
    return state
