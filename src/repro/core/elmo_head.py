"""ELMO head: the paper's chunked, low-precision large output layer.

This module is the paper's primary contribution as a composable JAX unit.
One ``head_train_step`` performs, for each label chunk (paper §4.2–4.3):

    1. forward    z_c = q8(X) @ W_cᵀ            (FP8-storage matmul)
    2. loss-skip  ḡ_c = σ(z_c) − Y_c   |  softmax(z_c) − onehot      (App. B)
    3. input grad X̄  += ḡ_c @ W_c
    4. fused upd  W_c ← SR((1 − lr·wd) W_c − lr ḡ_cᵀ X)   (grad never in HBM)

as a ``lax.scan`` over chunks, so transient memory is 1/k of the full logits
(paper §4.2, Table 10) and the weight/optimizer memory is W itself — SGD
without momentum (§4.2), stochastic rounding instead of master weights
(§4.1/4.3).  Steps 1–4 execute as ONE Pallas launch per chunk
(``kernels/fused_chunk.py``, DESIGN.md §3): logits and the logit gradient
live only in VMEM, and W updates in place via ``input_output_aliases``.
The softmax-CE variant (for LM heads, DESIGN.md §3) adds a streaming-LSE
pre-pass whose logits can be cached and reused by pass 2 (``cache_z``).
Head-label chunks can use Kahan compensation instead of SR (paper App. D).

The head never enters autodiff: the caller runs the backbone under
``jax.vjp`` and seeds it with the returned ``x_grad`` — which reproduces the
paper's reordered computation flow (encoder fwd → head fwd/bwd/update →
encoder bwd) and its peak-memory profile by construction.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses as L
from repro.core import precision as P
from repro.kernels import ops
from repro.kernels import prng_utils as PR
from repro.kernels import tuning as _tuning

_WEIGHT_DTYPES = {"bf16": P.BF16, "e4m3": P.E4M3, "f32": P.F32}


@dataclasses.dataclass(frozen=True)
class ELMOHeadConfig:
    num_labels: int
    d_model: int
    num_chunks: int = 8
    weight_dtype: str = "bf16"         # "bf16" | "e4m3" | "f32" (baseline)
    loss: str = "bce"                  # "bce" (XMC) | "softmax_ce" (LM)
    use_sr: bool = True                # stochastic rounding in the update
    kahan_chunks: int = 0              # leading chunks w/ Kahan comp (App. D)
    drop_rate: float = 0.0             # in-kernel DropConnect (App. H)
    quantize_x: Optional[bool] = None  # default: True iff weight is e4m3
    compute_loss: bool = True          # loss value is optional (loss-skip)
    # impl: auto|kernel|interpret|xla run the single-launch fused chunk
    # megakernel (kernels/fused_chunk.py); "unfused[_<inner>]" keeps the
    # legacy multi-kernel path for A/B (e.g. "unfused", "unfused_xla")
    impl: str = "auto"
    # softmax-CE only: reuse the LSE pre-pass logits in pass 2 ("on"/"off",
    # or "auto" = on when the z cache fits _CACHE_Z_BYTES)
    cache_z: str = "auto"

    @property
    def wdtype(self):
        return _WEIGHT_DTYPES[self.weight_dtype]

    @property
    def qx(self) -> bool:
        return self.weight_dtype == "e4m3" if self.quantize_x is None \
            else self.quantize_x

    # label rows per chunk are padded to a multiple of _CHUNK_ALIGN so the
    # chunk dimension stays divisible by the mesh's model axis (vocab-
    # parallel sharding) and by MXU tile sizes
    _CHUNK_ALIGN = 256

    @property
    def chunk(self) -> int:
        c = self.num_chunks
        per = (self.num_labels + c - 1) // c
        if self.num_labels >= self._CHUNK_ALIGN:
            per = ((per + self._CHUNK_ALIGN - 1) // self._CHUNK_ALIGN
                   ) * self._CHUNK_ALIGN
        return per

    @property
    def padded_labels(self) -> int:
        return self.chunk * self.num_chunks

    def __post_init__(self):
        assert 0 <= self.kahan_chunks <= self.num_chunks
        assert self.loss in ("bce", "softmax_ce")
        assert self.cache_z in ("auto", "on", "off")


# z-cache budget for the CE cached-logits fast path (B·padded_labels bf16);
# past this, recomputing pass-2 logits beats holding them (paper §4.2: the
# whole point of chunking is not materializing (B, L))
_CACHE_Z_BYTES = 32 * 2 ** 20


def _impl_split(impl: str) -> Tuple[bool, str]:
    """cfg.impl → (use fused megakernel?, inner kernel impl)."""
    if impl.startswith("unfused"):
        rest = impl[len("unfused"):].lstrip("_:")
        return False, (rest or "auto")
    return True, impl


class HeadState(NamedTuple):
    """w: (C, Lc, D) in storage dtype; comp: (Ck, Lc, D) BF16 (App. D)."""
    w: jax.Array
    comp: Optional[jax.Array]


def init_head(key: jax.Array, cfg: ELMOHeadConfig, scale: float | None = None
              ) -> HeadState:
    scale = scale if scale is not None else 1.0 / np.sqrt(cfg.d_model)
    w = (jax.random.normal(key, (cfg.num_chunks, cfg.chunk, cfg.d_model),
                           jnp.float32) * scale).astype(cfg.wdtype)
    comp = (jnp.zeros((cfg.kahan_chunks, cfg.chunk, cfg.d_model), P.BF16)
            if cfg.kahan_chunks else None)
    return HeadState(w, comp)


def _valid_cols(cfg: ELMOHeadConfig, cidx: jax.Array) -> jax.Array:
    """(chunk,) bool — masks padded label columns in the final chunk."""
    c0 = cidx * cfg.chunk
    return (c0 + jnp.arange(cfg.chunk)) < cfg.num_labels


def _chunk_logits(cfg: ELMOHeadConfig, wc: jax.Array, x: jax.Array,
                  seed: jax.Array, impl: str | None = None) -> jax.Array:
    impl = _impl_split(cfg.impl)[1] if impl is None else impl
    return ops.fp8_logits(x, wc, seed, drop_rate=cfg.drop_rate,
                          quantize_x=cfg.qx, impl=impl)


def _chunk_seed(seed: jax.Array, cidx: jax.Array, salt: int) -> jax.Array:
    return PR.mix32(seed.astype(jnp.uint32)
                    + cidx.astype(jnp.uint32) * np.uint32(2654435761)
                    + np.uint32(salt))


# ---------------------------------------------------------------------------
# training step
# ---------------------------------------------------------------------------


def _chunk_grad(cfg: ELMOHeadConfig, z: jax.Array, targets: jax.Array,
                cidx: jax.Array, lse: Optional[jax.Array],
                scale: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Loss-skip logit gradient + optional loss contribution for one chunk."""
    return L.chunk_loss_skip_grad(cfg.loss, z, targets, cidx * cfg.chunk,
                                  cfg.chunk, cfg.num_labels, lse, scale,
                                  cfg.compute_loss)


def _masked_z(cfg: ELMOHeadConfig, z: jax.Array, cidx: jax.Array) -> jax.Array:
    valid = _valid_cols(cfg, cidx)[None, :]
    return jnp.where(valid, z.astype(jnp.float32), L.NEG_INF)


def head_train_step(cfg: ELMOHeadConfig, state: HeadState, x: jax.Array,
                    targets: jax.Array, lr: jax.Array, wd: jax.Array,
                    seed: jax.Array
                    ) -> Tuple[HeadState, jax.Array, dict]:
    """One fused forward/backward/update pass over all label chunks.

    x: (B, D) bf16 backbone outputs (tokens flattened).
    targets: (B, P) int32 multi-label ids (bce) or (B,) int32 ids (ce).
    Returns (new_state, x_grad (B, D) bf16, metrics).

    Default path: one ``fused_chunk_step`` launch per chunk (logits, loss-
    skip gradient, x̄ accumulation and the in-place weight update never
    leave VMEM — DESIGN.md §3).  ``cfg.impl="unfused*"`` selects the legacy
    multi-kernel composition for A/B comparison; both paths are numerically
    identical by construction.
    """
    fused, impl = _impl_split(cfg.impl)
    if (fused and ops.resolve_impl(impl) == "kernel"
            and not _tuning.fused_chunk_viable(
                x.shape[0], cfg.d_model,
                jnp.dtype(cfg.wdtype).itemsize,
                kahan=cfg.kahan_chunks > 0)):
        fused = False   # megakernel working set exceeds VMEM at this B·S
    if fused:
        return _head_train_step_fused(cfg, state, x, targets, lr, wd, seed,
                                      impl)
    return _head_train_step_unfused(cfg, state, x, targets, lr, wd, seed,
                                    impl)


def _head_train_step_fused(cfg: ELMOHeadConfig, state: HeadState,
                           x: jax.Array, targets: jax.Array, lr: jax.Array,
                           wd: jax.Array, seed: jax.Array, impl: str
                           ) -> Tuple[HeadState, jax.Array, dict]:
    B = x.shape[0]
    x = x.astype(jnp.bfloat16)
    seed = seed.astype(jnp.uint32)
    chunk_ids = jnp.arange(cfg.num_chunks, dtype=jnp.int32)

    if cfg.loss == "bce":
        scale = jnp.float32(1.0 / B)
        lse, zs = None, None
    else:
        n_tok = jnp.maximum((targets >= 0).sum(), 1).astype(jnp.float32)
        scale = 1.0 / n_tok
        cache = cfg.cache_z == "on" or (
            cfg.cache_z == "auto"
            and B * cfg.padded_labels * 2 <= _CACHE_Z_BYTES)

        # ----- pass 1: streaming LSE (optionally caching each chunk's z
        # so pass 2 skips the forward matmul entirely)
        def lse_body(carry, inp):
            wc, cidx = inp
            m, s = carry
            z = _chunk_logits(cfg, wc, x, _chunk_seed(seed, cidx, 0), impl)
            carry = L.lse_update(m, s, _masked_z(cfg, z, cidx))
            return carry, (z if cache else None)

        (m, s), zs = jax.lax.scan(lse_body, L.lse_init(B),
                                  (state.w, chunk_ids))
        lse = L.lse_finalize(m, s)

    def chunk_step(xg, loss_acc, wc, comp_c, cidx, z_c):
        out = ops.fused_chunk_step(
            x, wc, targets, xg, lr, wd, scale, cidx * cfg.chunk,
            _chunk_seed(seed, cidx, 0), _chunk_seed(seed, cidx, 1),
            lse=lse, z=z_c, comp=comp_c, loss=cfg.loss,
            num_labels=cfg.num_labels, use_sr=cfg.use_sr,
            quantize_x=cfg.qx, drop_rate=cfg.drop_rate,
            compute_loss=cfg.compute_loss, impl=impl)
        return out.xg, loss_acc + out.loss, out.w, out.comp

    def kahan_body(carry, inp):
        xg, loss = carry
        wc, comp_c, cidx, z_c = (inp if zs is not None
                                 else inp + (None,))
        xg, loss, wc_new, comp_new = chunk_step(xg, loss, wc, comp_c, cidx,
                                                z_c)
        return (xg, loss), (wc_new, comp_new)

    def sr_body(carry, inp):
        xg, loss = carry
        wc, cidx, z_c = inp if zs is not None else inp + (None,)
        xg, loss, wc_new, _ = chunk_step(xg, loss, wc, None, cidx, z_c)
        return (xg, loss), wc_new

    carry = (jnp.zeros((B, cfg.d_model), jnp.bfloat16), jnp.float32(0.0))
    ck = cfg.kahan_chunks
    if ck:
        xs = (state.w[:ck], state.comp, chunk_ids[:ck])
        if zs is not None:
            xs += (zs[:ck],)
        carry, (w_k, comp_new) = jax.lax.scan(kahan_body, carry, xs)
    else:
        w_k, comp_new = state.w[:0], state.comp

    if ck < cfg.num_chunks:
        xs = (state.w[ck:], chunk_ids[ck:])
        if zs is not None:
            xs += (zs[ck:],)
        carry, w_s = jax.lax.scan(sr_body, carry, xs)
    else:
        w_s = state.w[:0]

    return _finalize_step(cfg, carry, w_k, w_s, comp_new, targets, lse,
                          scale, B)


def _finalize_step(cfg: ELMOHeadConfig, carry, w_k, w_s, comp_new, targets,
                   lse, scale, B: int) -> Tuple[HeadState, jax.Array, dict]:
    """Shared epilogue of both train-step paths: reassemble the chunk
    weights and fold the accumulated loss (the fused/unfused A/B guarantee
    depends on this formula living in exactly one place)."""
    (xg, loss_raw) = carry
    w_new = jnp.concatenate([w_k, w_s], axis=0) if cfg.kahan_chunks else w_s

    if cfg.loss == "bce":
        loss = loss_raw / B
    else:
        # Σ(lse − z_target) over valid tokens; loss_raw = Σ z_target
        tok_mask = (targets >= 0)
        loss = ((lse * tok_mask).sum() - loss_raw) * scale \
            if cfg.compute_loss else loss_raw

    metrics = {"loss": loss,
               "xgrad_norm": jnp.linalg.norm(xg.astype(jnp.float32))}
    return HeadState(w_new, comp_new), xg, metrics


def _head_train_step_unfused(cfg: ELMOHeadConfig, state: HeadState,
                             x: jax.Array, targets: jax.Array,
                             lr: jax.Array, wd: jax.Array, seed: jax.Array,
                             impl: str
                             ) -> Tuple[HeadState, jax.Array, dict]:
    """Legacy multi-kernel path (three launches + HBM logits/grad round
    trips per chunk) — kept selectable for fused-vs-unfused A/B."""
    B = x.shape[0]
    x = x.astype(jnp.bfloat16)
    seed = seed.astype(jnp.uint32)

    if cfg.loss == "bce":
        scale = jnp.float32(1.0 / B)
        lse = None
    else:
        n_tok = jnp.maximum((targets >= 0).sum(), 1).astype(jnp.float32)
        scale = 1.0 / n_tok

        # ----- pass 1: streaming LSE over chunks (paper §4.2 chunking + CE)
        def lse_body(carry, inp):
            wc, cidx = inp
            m, s = carry
            z = _masked_z(cfg, _chunk_logits(cfg, wc, x,
                                             _chunk_seed(seed, cidx, 0),
                                             impl), cidx)
            return L.lse_update(m, s, z), None

        (m, s), _ = jax.lax.scan(
            lse_body, L.lse_init(B),
            (state.w, jnp.arange(cfg.num_chunks, dtype=jnp.int32)))
        lse = L.lse_finalize(m, s)

    # ----- pass 2: per-chunk grad + fused update + x̄ accumulation
    def chunk_step(xg, loss_acc, wc, comp_c, cidx):
        sd = _chunk_seed(seed, cidx, 0)
        z = _chunk_logits(cfg, wc, x, sd, impl)
        g, loss_c = _chunk_grad(cfg, z, targets, cidx, lse, scale)
        # x̄ accumulates in BF16 (paper §4.1: gradients stay BF16) — halves
        # the accumulator and its cross-model all-reduce
        xg = xg + ops.fp8_input_grad(g, wc, impl=impl)
        upd_seed = _chunk_seed(seed, cidx, 1)
        if comp_c is None:
            wc_new = ops.fused_head_update(g, x, wc, lr, wd, upd_seed,
                                           use_sr=cfg.use_sr, impl=impl)
            return xg, loss_acc + loss_c, wc_new, None
        wc_new, comp_new = ops.fused_head_update_kahan(
            g, x, wc, comp_c, lr, wd, upd_seed, impl=impl)
        return xg, loss_acc + loss_c, wc_new, comp_new

    xg0 = jnp.zeros((B, cfg.d_model), jnp.bfloat16)
    loss0 = jnp.float32(0.0)
    ck = cfg.kahan_chunks

    def kahan_body(carry, inp):
        xg, loss = carry
        wc, comp_c, cidx = inp
        xg, loss, wc_new, comp_new = chunk_step(xg, loss, wc, comp_c, cidx)
        return (xg, loss), (wc_new, comp_new)

    def sr_body(carry, inp):
        xg, loss = carry
        wc, cidx = inp
        xg, loss, wc_new, _ = chunk_step(xg, loss, wc, None, cidx)
        return (xg, loss), wc_new

    carry = (xg0, loss0)
    if ck:
        carry, (w_k, comp_new) = jax.lax.scan(
            kahan_body, carry,
            (state.w[:ck], state.comp, jnp.arange(ck, dtype=jnp.int32)))
    else:
        w_k, comp_new = state.w[:0], state.comp

    if ck < cfg.num_chunks:
        carry, w_s = jax.lax.scan(
            sr_body, carry,
            (state.w[ck:], jnp.arange(ck, cfg.num_chunks, dtype=jnp.int32)))
    else:
        w_s = state.w[:0]

    return _finalize_step(cfg, carry, w_k, w_s, comp_new, targets, lse,
                          scale, B)


# ---------------------------------------------------------------------------
# inference
# ---------------------------------------------------------------------------


def head_logits(cfg: ELMOHeadConfig, state: HeadState, x: jax.Array
                ) -> jax.Array:
    """Full (B, L) logits — O(B·L) memory; eval/serve at modest B only."""
    x = x.astype(jnp.bfloat16)

    def body(_, inp):
        wc, cidx = inp
        z = _chunk_logits(cfg, wc, x, jnp.uint32(0))  # no dropout at eval
        return None, z

    _, zs = jax.lax.scan(
        body, None, (state.w, jnp.arange(cfg.num_chunks, dtype=jnp.int32)))
    z = jnp.moveaxis(zs, 0, 1).reshape(x.shape[0], cfg.padded_labels)
    return z[:, :cfg.num_labels]


def head_topk(cfg: ELMOHeadConfig, state: HeadState, x: jax.Array, k: int
              ) -> Tuple[jax.Array, jax.Array]:
    """Streaming top-k over chunks — never materializes full logits."""
    x = x.astype(jnp.bfloat16)
    B = x.shape[0]

    def body(carry, inp):
        vals, idx = carry
        wc, cidx = inp
        z = _masked_z(cfg, _chunk_logits(cfg, wc, x, jnp.uint32(0)), cidx)
        cand = jnp.concatenate([vals, z], axis=1)
        cand_idx = jnp.concatenate(
            [idx, jnp.broadcast_to(cidx * cfg.chunk + jnp.arange(cfg.chunk),
                                   (B, cfg.chunk))], axis=1)
        v, local = jax.lax.top_k(cand, k)
        return (v, jnp.take_along_axis(cand_idx, local, axis=1)), None

    init = (jnp.full((B, k), L.NEG_INF, jnp.float32),
            jnp.zeros((B, k), jnp.int32))
    (vals, idx), _ = jax.lax.scan(
        body, init, (state.w, jnp.arange(cfg.num_chunks, dtype=jnp.int32)))
    return vals, idx


def precision_at_k(cfg: ELMOHeadConfig, state: HeadState, x: jax.Array,
                   label_ids: jax.Array, k: int) -> jax.Array:
    """P@k for multi-label targets (paper's headline metric)."""
    _, pred = head_topk(cfg, state, x, k)
    hits = (pred[:, :, None] == label_ids[:, None, :]) \
        & (label_ids >= 0)[:, None, :]
    return hits.any(-1).sum(-1).astype(jnp.float32).mean() / k


# ---------------------------------------------------------------------------
# post-hoc classifier refinement (paper App. D.1)
# ---------------------------------------------------------------------------


def convert_head(state: HeadState, from_cfg: ELMOHeadConfig,
                 to_cfg: ELMOHeadConfig) -> HeadState:
    """Re-type the head weights (e.g. FP8 checkpoint → BF16 for refinement).

    Shapes must match (same labels/chunks); the Kahan buffer is created or
    dropped per the target config."""
    assert from_cfg.padded_labels == to_cfg.padded_labels
    assert from_cfg.num_chunks == to_cfg.num_chunks
    w = state.w.astype(jnp.float32).astype(to_cfg.wdtype)
    comp = (jnp.zeros((to_cfg.kahan_chunks, to_cfg.chunk, to_cfg.d_model),
                      P.BF16) if to_cfg.kahan_chunks else None)
    return HeadState(w, comp)


def posthoc_refine(to_cfg: ELMOHeadConfig, state: HeadState,
                   batches, steps: int, lr: float, seed: int = 0
                   ) -> HeadState:
    """App. D.1: fine-tune the head in higher precision on FROZEN encoder
    features.  ``batches`` yields (x, targets) with x already encoded —
    only head memory is resident, so this stays within the low-precision
    run's budget (label chunks stream exactly as in training)."""
    for i, (x, targets) in zip(range(steps), batches):
        state, _, _ = head_train_step(to_cfg, state, x, targets,
                                      jnp.float32(lr), jnp.float32(0.0),
                                      jnp.uint32(seed + i))
    return state
