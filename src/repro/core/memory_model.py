"""Analytic peak-memory model — reproduces paper Fig. 3, Fig. 4, Table 10.

XLA allocates statically, so at full scale we *verify* with
``compiled.memory_analysis()`` (launch/dryrun.py); this model provides the
paper-style component breakdown and the label-size sweeps without
instantiating 3M×768 tensors.  Constants follow the paper §4.4 walkthrough
(BERT-base, B=128, seq 128): encoder+opt ≈ 1.2 GiB, BF16 activations
≈ 4.6 GiB, FP8 activations ≈ 3.0 GiB (+0.5 GiB torchao-style buffers).
"""
from __future__ import annotations

import dataclasses

GIB = 1024 ** 3


@dataclasses.dataclass(frozen=True)
class MemScenario:
    num_labels: int
    d_model: int = 768
    batch: int = 128
    num_chunks: int = 8
    kahan_chunks: int = 0             # leading chunks w/ BF16 comp (App. D)
    encoder_gib: float = 1.2          # params + AdamW states (BERT-base)
    act_bf16_gib: float = 4.6         # paper §4.4
    act_fp8_gib: float = 3.0 + 0.5    # fp8 acts + scaling buffers


def _w_bytes(s: MemScenario, bytes_per: float) -> float:
    return s.num_labels * s.d_model * bytes_per


WEIGHT_BYTES = {"bf16": 2, "e4m3": 1, "e5m2": 1, "f32": 4}


def head_components(s: MemScenario, weight_dtype: str = "bf16",
                    n_label_shards: int = 1,
                    grid_block_l: int | None = None,
                    fan_in: int | None = None) -> dict:
    """Per-device ELMO *head* memory (the paper's Fig. 3 head terms only).

    ``n_label_shards`` is the mesh's model-axis size when the head is
    vocab-parallel (``dist.sharding.head_specs``): W, the Kahan buffer and
    the per-chunk logit/grad transients all live on the label axis, so every
    component divides by the shard count — the encoder/activation terms are
    data-parallel and excluded here.

    ``grid_block_l`` models the grid-resident whole-head megakernel
    (DESIGN.md §7, ``kernels/fused_head.py``): logits and their gradient
    only ever exist as one (batch, block_l) VMEM tile of the grid, so the
    transient terms shrink from the chunk width to the label-block width —
    and stop depending on the shard count (the tile is chosen per device).
    The residency the kernel adds instead (x, x̄, LSE stats — a few B·D
    buffers) is accounted as ``grid_resident_bf16``.

    ``fan_in`` models the fixed-fan-in sparse head (DESIGN.md §13): every
    label row keeps exactly ``fan_in`` FP8 value slots plus an i32 column
    index per slot, so the weight terms scale with L·fan_in instead of
    L·d_model — the index plane is the sparse format's only overhead.
    ``None``/0 leaves the dense accounting bit-for-bit unchanged."""
    wb = WEIGHT_BYTES[weight_dtype]
    frac = 1.0 / max(1, n_label_shards)
    chunk_rows = s.num_labels / s.num_chunks
    if fan_in:
        slots = s.num_labels * fan_in
        comp = {
            f"W_{weight_dtype}": slots * wb * frac,
            "W_indices_i32": slots * 4 * frac,
            "W_kahan_comp_bf16":
                slots * 2 * (s.kahan_chunks / s.num_chunks) * frac,
            "W_grad": 0.0,                  # fused into the update kernel
        }
    else:
        comp = {
            f"W_{weight_dtype}": _w_bytes(s, wb) * frac,
            "W_kahan_comp_bf16":
                _w_bytes(s, 2) * (s.kahan_chunks / s.num_chunks) * frac,
            "W_grad": 0.0,                  # fused into the update kernel
        }
    if grid_block_l is None:
        comp["chunk_logits_bf16"] = s.batch * chunk_rows * 2 * frac
        comp["chunk_logit_grad_bf16"] = s.batch * chunk_rows * 2 * frac
    else:
        tile = min(grid_block_l, chunk_rows * frac)
        comp["chunk_logits_bf16"] = s.batch * tile * 2
        comp["chunk_logit_grad_bf16"] = s.batch * tile * 2
        # x (bf16) + x̄ f32 accumulator + x̄ bf16 carry + LSE stats,
        # resident in VMEM for the whole launch
        comp["grid_resident_bf16"] = s.batch * s.d_model * (2 + 4 + 2)
    comp["total"] = sum(comp.values())
    return comp


def renee_peak(s: MemScenario) -> dict:
    """Paper Fig. 3 (left): masters + momentum + fp16 copy + fp16 grads +
    f32 upcast grads + full logit-grad buffer, stacked at one instant."""
    comp = {
        "W_master_f32": _w_bytes(s, 4),
        "W_momentum_f32": _w_bytes(s, 4),
        "W_copy_fp16": _w_bytes(s, 2),
        "W_grad_fp16": _w_bytes(s, 2),
        "W_grad_f32_upcast": _w_bytes(s, 4),
        "logit_grad_buffer": s.batch * s.num_labels * 2,
        "encoder": s.encoder_gib * GIB,
        "activations": s.act_bf16_gib * GIB,
    }
    comp["total"] = sum(comp.values())
    return comp


def elmo_peak(s: MemScenario, weight_dtype: str = "bf16",
              n_label_shards: int = 1) -> dict:
    """Paper Fig. 3 (right): W in 16/8-bit, no momentum, no grads (fused),
    logits/grads divided by the chunk count.  With ``n_label_shards`` > 1
    the head terms are per-device under label sharding (DESIGN.md §6);
    encoder/activations are data-parallel and stay whole."""
    act = s.act_fp8_gib if weight_dtype in ("e4m3", "e5m2") \
        else s.act_bf16_gib
    comp = head_components(s, weight_dtype, n_label_shards)
    del comp["total"]
    if not s.kahan_chunks:
        del comp["W_kahan_comp_bf16"]
    comp["encoder"] = s.encoder_gib * GIB
    comp["activations"] = act * GIB
    comp["total"] = sum(comp.values())
    return comp


def sweep_labels(labels: list[int], **kw) -> list[dict]:
    """Fig. 4: peak GiB vs label count for Renee / ELMO-BF16 / ELMO-FP8."""
    rows = []
    for lab in labels:
        s = MemScenario(num_labels=lab, **kw)
        rows.append({
            "labels": lab,
            "renee_gib": renee_peak(s)["total"] / GIB,
            "elmo_bf16_gib": elmo_peak(s, "bf16")["total"] / GIB,
            "elmo_fp8_gib": elmo_peak(s, "e4m3")["total"] / GIB,
        })
    return rows


def chunk_sweep(num_chunks: list[int], num_labels: int = 2_812_281,
                **kw) -> list[dict]:
    """Table 10: peak memory vs chunk count (BF16, Amazon-3M geometry)."""
    rows = []
    for k in num_chunks:
        s = MemScenario(num_labels=num_labels, num_chunks=k, **kw)
        rows.append({"chunks": k,
                     "elmo_bf16_gib": elmo_peak(s, "bf16")["total"] / GIB})
    return rows
