"""Renee-style baseline: full-logit, FP16-mixed-precision end-to-end head.

Implements the training scheme the paper compares against (Jain et al. 2023,
as characterized in ELMO §3/Fig. 1):

* f32 master classifier weights + SGD **with** momentum (f32) — 8 GiB each at
  3M labels;
* an ephemeral FP16 compute copy of W created every step;
* full (B, L) logits materialized; loss-skip BCE gradient in FP16 with a
  dynamic loss scale;
* input-gradient matmul ḡ @ W executed in FP16 — the overflow-prone
  accumulation over L that makes Renee unstable (paper §4.1);
* FP16 weight gradients upcast to f32 for the update (the memory spike in
  Fig. 1).

Used by the stability tests and the memory benchmarks; not a production path.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import losses as L


@dataclasses.dataclass(frozen=True)
class ReneeConfig:
    num_labels: int
    d_model: int
    momentum: float = 0.9
    init_loss_scale: float = 2.0 ** 12
    growth_interval: int = 2000


class ReneeState(NamedTuple):
    w_master: jax.Array     # (L, D) f32
    mom: jax.Array          # (L, D) f32
    loss_scale: jax.Array
    good_steps: jax.Array


def init_renee(key: jax.Array, cfg: ReneeConfig) -> ReneeState:
    w = jax.random.normal(key, (cfg.num_labels, cfg.d_model),
                          jnp.float32) / jnp.sqrt(cfg.d_model)
    return ReneeState(w, jnp.zeros_like(w), jnp.float32(cfg.init_loss_scale),
                      jnp.int32(0))


def renee_train_step(cfg: ReneeConfig, state: ReneeState, x: jax.Array,
                     targets: jax.Array, lr: jax.Array
                     ) -> Tuple[ReneeState, jax.Array, dict]:
    """Full-logit FP16 MPT step. Returns (state, x_grad, metrics)."""
    B = x.shape[0]
    w16 = state.w_master.astype(jnp.float16)          # ephemeral FP16 copy
    x16 = x.astype(jnp.float16)
    z = jnp.dot(x16, w16.T)                           # full (B, L) FP16 logits
    y = L.chunk_multi_hot(targets, jnp.int32(0), cfg.num_labels)
    # loss-skip grad, scaled into FP16 range (§3: loss scaling)
    g16 = ((jax.nn.sigmoid(z.astype(jnp.float32)) - y)
           * (state.loss_scale / B)).astype(jnp.float16)
    # FP16 × FP16 matmuls — the overflow-prone path
    xg16 = jnp.dot(g16, w16)                          # (B, D) FP16
    dw16 = jnp.dot(g16.T, x16)                        # (L, D) FP16
    dw32 = dw16.astype(jnp.float32) / state.loss_scale  # the f32 upcast spike

    finite = jnp.isfinite(dw16).all() & jnp.isfinite(xg16).all()
    mom = jnp.where(finite, cfg.momentum * state.mom + dw32, state.mom)
    w_new = jnp.where(finite, state.w_master - lr * mom, state.w_master)
    good = jnp.where(finite, state.good_steps + 1, 0)
    scale = jnp.where(finite,
                      jnp.where(good >= cfg.growth_interval,
                                state.loss_scale * 2, state.loss_scale),
                      state.loss_scale * 0.5)
    good = jnp.where(good >= cfg.growth_interval, 0, good)

    xg = jnp.where(finite, xg16.astype(jnp.float32) / state.loss_scale, 0.0)
    loss = L.full_bce_loss(z.astype(jnp.float32), targets)
    metrics = {"loss": loss, "overflow": ~finite,
               "loss_scale": state.loss_scale}
    return ReneeState(w_new, mom, scale, good), xg.astype(x.dtype), metrics
