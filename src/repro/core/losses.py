"""Loss-skipping gradients for large output spaces (paper App. B).

Renee/ELMO never materialize the loss graph for the output layer: the
logit gradient has a closed form, so autodiff (and its activation buffers)
is skipped entirely:

    BCE        :  ḡ = σ(z) − Y                      (paper App. B)
    softmax CE :  ḡ = softmax(z) − onehot(Y)

For softmax CE the row normalizer (LSE) couples all label chunks, so the
chunked head uses an *online* (max, sumexp) accumulator across chunks —
the standard streaming-softmax recurrence — followed by a second pass that
emits per-chunk gradients.  Loss *values* are optional byproducts.

Target encodings (dense multi-hot is never materialized at full width):
  * multi-label (XMC): ``ids (B, P) int32`` padded with -1 — P ≪ L.
  * single-label (LM): ``ids (B,) int32`` with -1 = ignore.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# chunk-local target materialization
# ---------------------------------------------------------------------------


def chunk_multi_hot(ids: jax.Array, c0: jax.Array, chunk: int) -> jax.Array:
    """(B, P) padded label ids → (B, chunk) multi-hot for labels [c0, c0+chunk).

    Padding entries (-1) and out-of-chunk ids scatter into a dropped slot.
    """
    B = ids.shape[0]
    local = ids - c0
    valid = (ids >= 0) & (local >= 0) & (local < chunk)
    slot = jnp.where(valid, local, chunk)  # `chunk` = trash slot
    y = jnp.zeros((B, chunk + 1), jnp.float32)
    y = y.at[jnp.arange(B)[:, None], slot].add(1.0)
    return jnp.minimum(y[:, :chunk], 1.0)  # duplicate ids collapse to 1


def chunk_one_hot(ids: jax.Array, c0: jax.Array, chunk: int) -> jax.Array:
    """(B,) target ids → (B, chunk) one-hot restricted to this chunk."""
    local = ids - c0
    valid = (ids >= 0) & (local >= 0) & (local < chunk)
    iota = jnp.arange(chunk)[None, :]
    return ((iota == local[:, None]) & valid[:, None]).astype(jnp.float32)


# ---------------------------------------------------------------------------
# BCE (multi-label XMC)
# ---------------------------------------------------------------------------


def bce_logit_grad(z: jax.Array, y: jax.Array, scale: jax.Array) -> jax.Array:
    """ḡ = scale · (σ(z) − y).  scale folds the 1/B loss normalization."""
    return (jax.nn.sigmoid(z.astype(jnp.float32)) - y) * scale


def bce_chunk_loss(z: jax.Array, y: jax.Array,
                   mask: jax.Array | None = None) -> jax.Array:
    """Numerically stable Σ BCE-with-logits over this chunk (f32 scalar)."""
    z32 = z.astype(jnp.float32)
    # softplus(z) - z*y  ==  max(z,0) - z*y + log1p(exp(-|z|))
    per = jnp.maximum(z32, 0.0) - z32 * y + jnp.log1p(jnp.exp(-jnp.abs(z32)))
    if mask is not None:
        per = per * mask
    return per.sum()


# ---------------------------------------------------------------------------
# softmax CE (LM heads) — streaming LSE across chunks
# ---------------------------------------------------------------------------


def lse_init(batch: int) -> Tuple[jax.Array, jax.Array]:
    return (jnp.full((batch,), NEG_INF, jnp.float32),
            jnp.zeros((batch,), jnp.float32))


def lse_update(m: jax.Array, s: jax.Array, z: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """Online logsumexp: fold one logits chunk into the (max, sumexp) carry."""
    z32 = z.astype(jnp.float32)
    m_new = jnp.maximum(m, z32.max(axis=-1))
    s_new = s * jnp.exp(m - m_new) + jnp.exp(z32 - m_new[:, None]).sum(-1)
    return m_new, s_new


def lse_finalize(m: jax.Array, s: jax.Array) -> jax.Array:
    return m + jnp.log(s)


def ce_logit_grad(z: jax.Array, lse: jax.Array, onehot: jax.Array,
                  scale: jax.Array) -> jax.Array:
    """ḡ = scale · (softmax(z) − onehot), softmax via the precomputed LSE."""
    p = jnp.exp(z.astype(jnp.float32) - lse[:, None])
    return (p - onehot) * scale


def ce_target_logit_chunk(z: jax.Array, ids: jax.Array, c0: jax.Array,
                          chunk: int) -> jax.Array:
    """Per-row target logit contribution from this chunk (0 if not here)."""
    local = ids - c0
    valid = (ids >= 0) & (local >= 0) & (local < chunk)
    safe = jnp.where(valid, local, 0)
    picked = jnp.take_along_axis(z.astype(jnp.float32), safe[:, None],
                                 axis=1)[:, 0]
    return jnp.where(valid, picked, 0.0)


def chunk_loss_skip_grad(loss: str, z: jax.Array, targets: jax.Array,
                         c0: jax.Array, chunk: int, num_labels: int,
                         lse: jax.Array | None, scale: jax.Array,
                         compute_loss: bool = True
                         ) -> Tuple[jax.Array, jax.Array]:
    """Loss-skip logit gradient (BF16) + optional loss contribution for the
    label window [c0, c0+chunk) of a ``num_labels``-wide output space.

    The single jnp implementation shared by the unfused head path and the
    fused-chunk oracle (``kernels/ref.py``) — their bit-exact A/B guarantee
    depends on this formula living here and nowhere else."""
    valid = ((c0 + jnp.arange(chunk)) < num_labels)[None, :]
    if loss == "bce":
        y = chunk_multi_hot(targets, c0, chunk)
        g = bce_logit_grad(z, y, scale) * valid
        loss_c = (bce_chunk_loss(z, y, mask=valid)
                  if compute_loss else jnp.float32(0.0))
    else:
        assert lse is not None, "softmax_ce needs the streaming LSE"
        onehot = chunk_one_hot(targets, c0, chunk)
        tok_mask = (targets >= 0).astype(jnp.float32)[:, None]
        g = ce_logit_grad(z, lse, onehot, scale) * valid * tok_mask
        # CE loss needs the target logit; the caller folds Σ lse − this in
        loss_c = (ce_target_logit_chunk(z, targets, c0, chunk).sum()
                  if compute_loss else jnp.float32(0.0))
    return g.astype(jnp.bfloat16), loss_c


# ---------------------------------------------------------------------------
# full-width oracles (tests / tiny eval only)
# ---------------------------------------------------------------------------


def full_bce_loss(z: jax.Array, ids: jax.Array) -> jax.Array:
    y = chunk_multi_hot(ids, jnp.int32(0), z.shape[1])
    return bce_chunk_loss(z, y) / z.shape[0]


def full_ce_loss(z: jax.Array, ids: jax.Array) -> jax.Array:
    mask = ids >= 0
    safe = jnp.where(mask, ids, 0)
    lse = jax.scipy.special.logsumexp(z.astype(jnp.float32), axis=-1)
    zt = jnp.take_along_axis(z.astype(jnp.float32), safe[:, None], 1)[:, 0]
    per = (lse - zt) * mask
    return per.sum() / jnp.maximum(mask.sum(), 1)


def propensity_scores(label_freq: jax.Array, a: float = 0.55,
                      b: float = 1.5) -> jax.Array:
    """Jain et al. (2016) propensities from label frequencies (paper App. A):
    p_l = 1 / (1 + C·e^{−a·log(N_l + b)}), standard XMC constants."""
    c = (jnp.log(label_freq.sum()) - 1.0) * (b + 1.0) ** a
    return 1.0 / (1.0 + c * jnp.exp(-a * jnp.log(label_freq + b)))


def psp_at_k(pred_ids: jax.Array, label_ids: jax.Array,
             propensity: jax.Array, k: int) -> jax.Array:
    """Propensity-scored P@k (paper eq. 3, Tables 7/8): tail-label-weighted
    precision.  pred_ids (B, k); label_ids (B, P) padded with -1."""
    hits = (pred_ids[:, :k, None] == label_ids[:, None, :]) \
        & (label_ids >= 0)[:, None, :]
    hit_any = hits.any(-1)
    inv_p = 1.0 / jnp.take(propensity, jnp.clip(pred_ids[:, :k], 0, None))
    return (hit_any * inv_p).sum(-1).mean() / k
