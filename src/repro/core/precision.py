"""Low-precision numerics: stochastic rounding, Kahan summation, format sim.

This is the numerical foundation of ELMO (paper §3, §4.1, §4.3):

* ``stochastic_round``      — exact two-neighbour SR into any ml_dtypes float
                              (the *oracle*; used by tests and small tensors).
* ``sr_bits_bf16/e4m3``     — the fast bit-trick SR used inside optimizers and
                              Pallas kernels (add uniform low bits, truncate).
* ``kahan_update``          — compensated summation step for BF16 parameters.
* ``simulate_format``       — generic (E, M) float quantizer (RN or SR) used to
                              reproduce the paper's Fig. 2(a) precision grid.

All functions are pure jnp and jit-compatible.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# dtype registry
# ---------------------------------------------------------------------------

F32 = jnp.float32
BF16 = jnp.bfloat16
E4M3 = jnp.float8_e4m3fn
E5M2 = jnp.float8_e5m2

# (unsigned int view dtype, total bits, mantissa bits, max finite)
_FLOAT_INFO = {
    jnp.dtype(F32): (jnp.uint32, 32, 23, float(np.finfo(np.float32).max)),
    jnp.dtype(BF16): (jnp.uint16, 16, 7, float(jnp.finfo(BF16).max)),
    jnp.dtype(E4M3): (jnp.uint8, 8, 3, 448.0),
    jnp.dtype(E5M2): (jnp.uint8, 8, 2, 57344.0),
}


def max_finite(dtype) -> float:
    return _FLOAT_INFO[jnp.dtype(dtype)][3]


def _uint_view(dtype):
    return _FLOAT_INFO[jnp.dtype(dtype)][0]


# ---------------------------------------------------------------------------
# exact two-neighbour stochastic rounding (oracle)
# ---------------------------------------------------------------------------


def _to_ordered(bits: jax.Array, nbits: int) -> jax.Array:
    """Map IEEE sign-magnitude bit patterns to a monotone unsigned ordering."""
    sign = np.uint32(1) << np.uint32(nbits - 1)
    bits32 = bits.astype(jnp.uint32)
    neg = (bits32 & sign) != 0
    return jnp.where(neg, (sign << 1) - 1 - bits32, bits32 | sign)


def _from_ordered(ordered: jax.Array, nbits: int, out_dtype) -> jax.Array:
    sign = np.uint32(1) << np.uint32(nbits - 1)
    neg = (ordered & sign) == 0
    bits = jnp.where(neg, (sign << 1) - 1 - ordered, ordered & (sign - 1))
    return bits.astype(_uint_view(out_dtype))


def _nextafter_dir(y: jax.Array, direction: jax.Array) -> jax.Array:
    """nextafter(y, ±inf) within y.dtype. ``direction`` ∈ {-1, 0, +1} (f32)."""
    dtype = y.dtype
    uint = _uint_view(dtype)
    nbits = jnp.iinfo(uint).bits
    bits = jax.lax.bitcast_convert_type(y, uint)
    ordered = _to_ordered(bits, nbits)
    step = direction.astype(jnp.int32)
    moved = (ordered.astype(jnp.int32) + step).astype(jnp.uint32)
    out_bits = _from_ordered(moved, nbits, dtype)
    return jax.lax.bitcast_convert_type(out_bits, dtype)


def stochastic_round(x: jax.Array, dtype, key: jax.Array) -> jax.Array:
    """Exact stochastic rounding of f32/bf16 ``x`` into ``dtype``.

    SR(x) = up   with p = (x - down)/(up - down)
          = down with 1 - p          (paper eq. 1)

    Implemented as: round-to-nearest, then move to the neighbour in the
    residual direction with probability |err| / gridstep.  Saturates at the
    target's max finite value (e4m3fn convention — no inf).
    """
    dtype = jnp.dtype(dtype)
    x32 = x.astype(F32)
    lim = max_finite(dtype)
    x32 = jnp.clip(x32, -lim, lim)
    y = x32.astype(dtype)  # round-to-nearest-even
    y32 = y.astype(F32)
    err = x32 - y32
    direction = jnp.sign(err)
    z = _nextafter_dir(y, direction)
    z32 = jnp.clip(z.astype(F32), -lim, lim)
    denom = z32 - y32
    p = jnp.where(denom != 0, err / jnp.where(denom == 0, 1.0, denom), 0.0)
    u = jax.random.uniform(key, x32.shape, dtype=F32)
    take_z = u < p
    return jnp.where(take_z, z32, y32).astype(dtype)


# ---------------------------------------------------------------------------
# fast bit-trick stochastic rounding (optimizer / kernel fast path)
# ---------------------------------------------------------------------------


def sr_bits_bf16(x32: jax.Array, rand_bits: jax.Array) -> jax.Array:
    """SR f32→bf16 by adding 16 uniform random low bits and truncating.

    ``rand_bits`` is uint32 (only the low 16 bits are used).  Exact SR for all
    finite values (carry into the exponent implements the grid step across
    binades); saturating at bf16 max to avoid rounding into inf.
    """
    bits = jax.lax.bitcast_convert_type(x32.astype(F32), jnp.uint32)
    r = rand_bits.astype(jnp.uint32) & np.uint32(0xFFFF)
    jittered = bits + r
    trunc = jittered & np.uint32(0xFFFF0000)
    y = jax.lax.bitcast_convert_type(trunc, F32)
    lim = max_finite(BF16)
    y = jnp.where(jnp.isfinite(y), y, jnp.sign(x32) * lim)
    # non-finite inputs propagate as-is (RN cast)
    y = jnp.where(jnp.isfinite(x32), y, x32)
    return y.astype(BF16)


def sr_bits_e4m3(x32: jax.Array, rand_bits: jax.Array) -> jax.Array:
    """SR f32→float8_e4m3fn via the 20-low-mantissa-bit trick.

    Normal range (|x| ≥ 2⁻⁶): the e4m3 grid equals the f32 grid truncated to
    3 mantissa bits, so adding U[0, 2²⁰) below bit 20 and truncating is exact
    SR.  Subnormal range (|x| < 2⁻⁶): the grid is uniform with step 2⁻⁹; we SR
    on that fixed grid explicitly.  Saturates at ±448 (e4m3fn has no inf).
    """
    x32 = x32.astype(F32)
    lim = 448.0
    xc = jnp.clip(x32, -lim, lim)

    # --- normal-range bit trick ---
    mask = np.uint32((1 << 20) - 1)
    bits = jax.lax.bitcast_convert_type(xc, jnp.uint32)
    r = rand_bits.astype(jnp.uint32) & mask
    trunc = (bits + r) & ~mask
    y_norm = jax.lax.bitcast_convert_type(trunc, F32)
    y_norm = jnp.clip(y_norm, -lim, lim)

    # --- subnormal fixed grid (step 2⁻⁹) ---
    scaled = xc * 512.0  # 2⁹
    lo = jnp.floor(scaled)
    frac = scaled - lo
    # reuse high random bits as the uniform draw
    u = (rand_bits.astype(jnp.uint32) >> 8).astype(F32) * (1.0 / float(1 << 24))
    y_sub = (lo + (u < frac).astype(F32)) * (1.0 / 512.0)

    y = jnp.where(jnp.abs(xc) < 2.0 ** -6, y_sub, y_norm)
    y = jnp.where(jnp.isfinite(x32), y, x32)
    return y.astype(E4M3)


def sr_bits_e5m2(x32: jax.Array, rand_bits: jax.Array) -> jax.Array:
    """SR f32→float8_e5m2 via the 21-low-mantissa-bit trick.

    Normal range (|x| ≥ 2⁻¹⁴): the e5m2 grid equals the f32 grid truncated to
    2 mantissa bits, so adding U[0, 2²¹) below bit 21 and truncating is exact
    SR.  Subnormal range (|x| < 2⁻¹⁴): uniform grid with step 2⁻¹⁶; we SR on
    that fixed grid explicitly.  Saturates at ±57344 (avoid rounding to inf —
    e5m2 *has* an inf encoding, which training must never produce).
    """
    x32 = x32.astype(F32)
    lim = max_finite(E5M2)
    xc = jnp.clip(x32, -lim, lim)

    # --- normal-range bit trick ---
    mask = np.uint32((1 << 21) - 1)
    bits = jax.lax.bitcast_convert_type(xc, jnp.uint32)
    r = rand_bits.astype(jnp.uint32) & mask
    trunc = (bits + r) & ~mask
    y_norm = jax.lax.bitcast_convert_type(trunc, F32)
    y_norm = jnp.clip(y_norm, -lim, lim)

    # --- subnormal fixed grid (step 2⁻¹⁶) ---
    scaled = xc * 65536.0  # 2¹⁶
    lo = jnp.floor(scaled)
    frac = scaled - lo
    u = (rand_bits.astype(jnp.uint32) >> 8).astype(F32) * (1.0 / float(1 << 24))
    y_sub = (lo + (u < frac).astype(F32)) * (1.0 / 65536.0)

    y = jnp.where(jnp.abs(xc) < 2.0 ** -14, y_sub, y_norm)
    y = jnp.where(jnp.isfinite(x32), y, x32)
    return y.astype(E5M2)


def sr_bits(x32: jax.Array, rand_bits: jax.Array, dtype) -> jax.Array:
    """Dispatching bit-trick SR cast (the single dtype switch shared by the
    kernels, their oracles, and the optimizers)."""
    dtype = jnp.dtype(dtype)
    if dtype == jnp.dtype(F32):
        # every f32 value is exactly representable: SR degenerates to the
        # identity (the seed code silently e4m3-cast f32 weights here)
        return x32.astype(F32)
    if dtype == jnp.dtype(BF16):
        return sr_bits_bf16(x32, rand_bits)
    if dtype == jnp.dtype(E4M3):
        return sr_bits_e4m3(x32, rand_bits)
    if dtype == jnp.dtype(E5M2):
        return sr_bits_e5m2(x32, rand_bits)
    raise ValueError(f"no bit-trick SR for dtype {dtype}")


def sr_cast(x: jax.Array, dtype, key: jax.Array) -> jax.Array:
    """Dispatching fast SR cast (bit trick where available, oracle otherwise)."""
    dtype = jnp.dtype(dtype)
    if dtype in (jnp.dtype(BF16), jnp.dtype(E4M3), jnp.dtype(E5M2)):
        bits = jax.random.bits(key, x.shape, jnp.uint32)
        return sr_bits(x.astype(F32), bits, dtype)
    return stochastic_round(x, dtype, key)


# ---------------------------------------------------------------------------
# Kahan summation (paper §3; used for the encoder optimizer, §4.1)
# ---------------------------------------------------------------------------


def kahan_update(param: jax.Array, comp: jax.Array, update: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """One compensated addition: param ← param + update, error carried in comp.

        y ← v − c;  s ← s + y;  c ← (s_new − s_old) − y      (paper §3)

    ``param``/``comp`` are stored low-precision (BF16); arithmetic is f32.
    Returns (new_param, new_comp), each in its OWN input's storage dtype —
    an FP8 parameter keeps its BF16 compensation buffer (App. D pairs
    8-bit weights with 16-bit comp; the in-kernel Kahan paths alias the
    BF16 comp buffer in place, so the oracle must not narrow it).
    """
    p32 = param.astype(F32)
    c32 = comp.astype(F32)
    y = update.astype(F32) - c32
    t32 = p32 + y
    p_new = t32.astype(param.dtype)
    # what actually landed in the parameter, minus what we meant to add
    c_new = (p_new.astype(F32) - p32) - y
    return p_new, c_new.astype(comp.dtype)


# ---------------------------------------------------------------------------
# generic (E, M) format simulation — paper Fig. 2(a)
# ---------------------------------------------------------------------------


def format_max(e_bits: int, m_bits: int) -> float:
    bias = 2 ** (e_bits - 1) - 1
    max_exp = 2 ** e_bits - 2 - bias  # reserve top exponent (IEEE inf/nan)
    return float((2.0 - 2.0 ** (-m_bits)) * 2.0 ** max_exp)


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def simulate_format(x: jax.Array, e_bits: int, m_bits: int,
                    use_sr: bool = False, key: jax.Array | None = None
                    ) -> jax.Array:
    """Quantize f32 ``x`` onto a simulated (e_bits, m_bits) float grid.

    IEEE-like: bias 2^(E-1)−1, subnormals with fixed step 2^(1−bias−M),
    saturating at the max finite value.  RN (ties away, adequate for the
    grid study) or SR when ``use_sr``.
    """
    bias = 2 ** (e_bits - 1) - 1
    min_exp = 1 - bias
    x32 = x.astype(F32)
    lim = format_max(e_bits, m_bits)
    xc = jnp.clip(x32, -lim, lim)

    mant, expo = jnp.frexp(xc)  # x = mant * 2^expo, mant in [0.5, 1)
    # rescale so grid exponent = floor(log2|x|) = expo - 1
    grid_exp = jnp.maximum(expo - 1, min_exp)
    step = jnp.exp2((grid_exp - m_bits).astype(F32))
    q = xc / step
    if use_sr:
        assert key is not None, "SR needs a PRNG key"
        lo = jnp.floor(q)
        u = jax.random.uniform(key, x32.shape, dtype=F32)
        qr = lo + (u < (q - lo)).astype(F32)
    else:
        qr = jnp.round(q)
    y = qr * step
    y = jnp.clip(y, -lim, lim)
    return jnp.where(jnp.isfinite(x32), y, x32)


# ---------------------------------------------------------------------------
# tree helpers
# ---------------------------------------------------------------------------


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a,
        tree)


def tree_bytes(tree) -> int:
    return sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(tree)
               if hasattr(a, "dtype"))
