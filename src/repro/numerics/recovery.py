"""Deterministic rollback-and-escalate recovery (DESIGN.md §14).

On a ``NumericsMonitor`` trip the supervisor in ``launch/train.py``:

1. **persists** the escalated ``LadderState`` to ``guard.json`` (tmp +
   fsync + atomic replace) — BEFORE touching the checkpoint store, so a
   SIGKILL at any later point resumes mid-recovery bit-identically;
2. **quarantines** the suspect checkpoints — every committed step at or
   after the rollback horizon is demoted ``COMMITTED`` → ``CORRUPT``
   (reusing §10's demotion, so ``latest_committed``/restore skip them);
3. **rolls back** to the last-good committed step and resumes under the
   new rung.

The escalation ladder is a pure function of the trip count — replaying
the same trips always produces the same rung:

    rung 0  baseline             salt 0, lr ×1, configured dtype
    rung 1  reseed               new SR seed stream (seed_salt = trips)
    rung 2  lr_backoff           + learning rate × ``LR_BACKOFF``
    rung 3  escalate_precision   + head weights e4m3/e5m2 → bf16
                                 (further trips keep halving the LR)

``seed_salt`` bumps on *every* trip (each recovery attempt replays a
fresh SR stream — the cheapest lever against an unlucky rounding
sequence); salt 0 reproduces the unguarded seed derivation exactly, so a
run that never trips is bit-identical to a guard-off run.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import List, Optional

RUNGS = ("baseline", "reseed", "lr_backoff", "escalate_precision")
LR_BACKOFF = 0.5
_GUARD_FILE = "guard.json"
# low-precision storage dtypes escalate to bf16; bf16/f32 heads have no
# higher storage rung (the ladder then only reseeds + backs off LR)
_ESCALATED_DTYPE = {"e4m3": "bf16", "e5m2": "bf16"}


@dataclasses.dataclass
class LadderState:
    """Where on the escalation ladder the run currently sits."""
    rung: int = 0
    seed_salt: int = 0
    lr_scale: float = 1.0
    weight_dtype: Optional[str] = None     # override iff escalated
    trips: List[dict] = dataclasses.field(default_factory=list)

    @property
    def rung_name(self) -> str:
        return RUNGS[self.rung]

    def escalate(self, trip: dict, *, base_dtype: str) -> "LadderState":
        """One rung up (pure — returns a new state).  ``trip`` is the
        TripReason dict being recorded; ``base_dtype`` is the configured
        head ``weight_dtype`` the precision rung escalates from."""
        trips = self.trips + [dict(trip)]
        rung = min(self.rung + 1, len(RUNGS) - 1)
        lr_scale = self.lr_scale
        if RUNGS[rung] == "lr_backoff" and RUNGS[self.rung] != "lr_backoff":
            lr_scale *= LR_BACKOFF
        elif self.rung == len(RUNGS) - 1:      # already at the top: keep
            lr_scale *= LR_BACKOFF             # halving the LR
        weight_dtype = self.weight_dtype
        if RUNGS[rung] == "escalate_precision" and weight_dtype is None:
            weight_dtype = _ESCALATED_DTYPE.get(base_dtype)
            if weight_dtype is None:           # bf16/f32 head: no storage
                weight_dtype = None            # rung above it — LR instead
                lr_scale = self.lr_scale * LR_BACKOFF
        return LadderState(rung=rung, seed_salt=len(trips),
                           lr_scale=lr_scale, weight_dtype=weight_dtype,
                           trips=trips)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def describe(self) -> str:
        dt = f", dtype→{self.weight_dtype}" if self.weight_dtype else ""
        return (f"rung {self.rung} ({self.rung_name}): salt="
                f"{self.seed_salt}, lr×{self.lr_scale:g}{dt}, "
                f"{len(self.trips)} trip(s)")


class NumericsTrip(RuntimeError):
    """Raised out of the inner train loop on a monitor trip — caught by
    the guard supervisor (the numeric sibling of ``fault.HostFailure``)."""

    def __init__(self, reason, losses=None):
        super().__init__(f"numerics trip at step {reason.step}: "
                         f"{reason.kind} ({reason.detail or reason.value})")
        self.reason = reason
        self.losses = list(losses or [])


# ---------------------------------------------------------------------------
# crash-safe ladder persistence
# ---------------------------------------------------------------------------


def _guard_path(ckpt_dir: str) -> str:
    return os.path.join(ckpt_dir, _GUARD_FILE)


def save_ladder(ckpt_dir: str, state: LadderState) -> str:
    """Atomically persist the ladder (tmp + fsync + replace) — the same
    torn-write discipline as the checkpoint commit marker."""
    os.makedirs(ckpt_dir, exist_ok=True)
    path = _guard_path(ckpt_dir)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(state.as_dict(), f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path


def load_ladder(ckpt_dir: str) -> LadderState:
    """The persisted ladder, or the baseline if none was ever saved.  An
    unreadable/torn file is treated as baseline (the .tmp protocol makes
    that only possible for pre-guard runs)."""
    try:
        with open(_guard_path(ckpt_dir)) as f:
            d = json.load(f)
    except (OSError, json.JSONDecodeError):
        return LadderState()
    return LadderState(rung=int(d.get("rung", 0)),
                       seed_salt=int(d.get("seed_salt", 0)),
                       lr_scale=float(d.get("lr_scale", 1.0)),
                       weight_dtype=d.get("weight_dtype"),
                       trips=list(d.get("trips", [])))


def quarantine(ckpt_dir: str, min_step: int) -> List[str]:
    """Demote every committed checkpoint at step ≥ ``min_step`` to CORRUPT
    (idempotent — a SIGKILL mid-quarantine just re-demotes the rest on
    resume).  Returns the demoted paths."""
    from repro.checkpoint import committed_paths      # local: keep the
    from repro.checkpoint.ckpt import _demote         # import graph light
    demoted = []
    for path in committed_paths(ckpt_dir):
        try:
            step = int(os.path.basename(path).split("_")[-1])
        except ValueError:
            continue
        if step >= min_step:
            _demote(path, f"numerics quarantine (trip horizon {min_step})")
            demoted.append(path)
    return demoted
