"""Numerics guard subsystem (DESIGN.md §14): in-step FP8 telemetry,
divergence detection, and rollback-and-escalate recovery.

* ``telemetry`` — the shared 8-slot per-step counter vector (kernels,
  oracles, and wrappers all emit/merge the same layout).
* ``monitor``   — host-side ``NumericsMonitor``: EWMA loss z-score,
  non-finite hard trips, saturation-fraction threshold.
* ``recovery``  — the deterministic escalation ladder (reseed → LR
  backoff → precision escalation) with crash-safe ``guard.json`` state.
"""
from repro.numerics import telemetry  # noqa: F401
from repro.numerics.monitor import NumericsMonitor, TripReason  # noqa: F401
from repro.numerics.recovery import (  # noqa: F401
    LadderState, NumericsTrip, RUNGS, load_ladder, save_ladder,
)
