"""Host-side divergence detection over the per-step telemetry stream.

``NumericsMonitor`` consumes one ``(step, loss, telemetry)`` observation
per optimizer step (the telemetry vector is ``telemetry.N_SLOTS`` f32 —
see that module for the slot layout) and decides whether the run has gone
numerically bad:

* **non-finite hard trips** — a NaN/Inf loss, any non-finite count in
  slots 1–3 (logits / LSE / x̄), or a non-finite telemetry value itself
  (a NaN Kahan-comp max) trip immediately;
* **saturation-fraction threshold** — slot 0 divided by the head's
  update-element count exceeding ``sat_frac``
  (``ELMOHeadConfig.guard_sat_frac``) trips: an e4m3 head whose updates
  pile onto the ±448 cliff is silently losing its gradient signal;
* **EWMA loss-spike z-score** — an exponentially-weighted mean/variance
  of the loss; after ``warmup`` observations a loss more than
  ``z_thresh`` EWMA standard deviations above the mean trips.  Spiking
  observations do NOT update the statistics (a divergence must not drag
  its own baseline up), and ``reset()`` re-warms the estimator after a
  rollback (the resumed stream starts from last-good, not the spike).

Everything here is plain Python floats — deterministic, replayable, and
independent of the device mesh.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.numerics import telemetry as T


@dataclasses.dataclass(frozen=True)
class TripReason:
    """Why the monitor tripped — recorded in the ladder state / manifest."""
    step: int
    kind: str          # "nonfinite_loss" | "nonfinite_telemetry" |
    #                    "nonfinite_z" | "nonfinite_lse" | "nonfinite_xg" |
    #                    "saturation" | "loss_spike"
    value: float       # the offending measurement
    detail: str = ""

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class NumericsMonitor:
    """EWMA loss-spike + non-finite + saturation-fraction trip logic.

    ``update_elems`` is the denominator for the saturation fraction —
    the number of W-update elements per step (``padded_labels · d_model``
    dense, ``padded_labels · fan_in`` sparse)."""

    def __init__(self, *, update_elems: int, sat_frac: float = 0.05,
                 z_thresh: float = 8.0, ewma_beta: float = 0.9,
                 warmup: int = 8):
        assert update_elems > 0
        assert 0.0 < sat_frac <= 1.0
        assert z_thresh > 0.0 and 0.0 < ewma_beta < 1.0
        self.update_elems = update_elems
        self.sat_frac = sat_frac
        self.z_thresh = z_thresh
        self.ewma_beta = ewma_beta
        self.warmup = warmup
        self.reset()

    def reset(self) -> None:
        """Forget the loss statistics (call after a rollback)."""
        self._mean: Optional[float] = None
        self._var = 0.0
        self._n = 0

    # ------------------------------------------------------------------
    def observe(self, step: int, loss: float,
                tele: Optional[Sequence[float]] = None
                ) -> Optional[TripReason]:
        """Feed one step; returns a ``TripReason`` iff the run tripped."""
        loss = float(loss)
        trip = self._check_hard(step, loss, tele)
        if trip is None:
            trip = self._check_spike(step, loss)
        if trip is None:
            self._update_ewma(loss)
        return trip

    # ------------------------------------------------------------------
    def _check_hard(self, step: int, loss: float, tele) -> Optional[TripReason]:
        if not math.isfinite(loss):
            return TripReason(step, "nonfinite_loss", loss)
        if tele is None:
            return None
        vals = [float(v) for v in tele]
        for v in vals:
            if not math.isfinite(v):
                return TripReason(step, "nonfinite_telemetry", v,
                                  "non-finite telemetry slot (Kahan comp?)")
        for kind, slot in (("nonfinite_z", T.SLOTS["z_nonfinite"]),
                           ("nonfinite_lse", T.SLOTS["lse_nonfinite"]),
                           ("nonfinite_xg", T.SLOTS["xg_nonfinite"])):
            if vals[slot] > 0:
                return TripReason(step, kind, vals[slot],
                                  f"{int(vals[slot])} non-finite elements")
        frac = vals[T.SLOTS["sat"]] / self.update_elems
        if frac > self.sat_frac:
            return TripReason(step, "saturation", frac,
                              f"update saturation {frac:.4f} > "
                              f"{self.sat_frac}")
        return None

    def _check_spike(self, step: int, loss: float) -> Optional[TripReason]:
        if self._n < self.warmup or self._mean is None:
            return None
        std = math.sqrt(max(self._var, 1e-12))
        z = (loss - self._mean) / std
        if z > self.z_thresh:
            return TripReason(step, "loss_spike", z,
                              f"loss {loss:.6g} is {z:.1f}σ above EWMA "
                              f"{self._mean:.6g}")
        return None

    def _update_ewma(self, loss: float) -> None:
        if self._mean is None:
            self._mean = loss
        else:
            b = self.ewma_beta
            delta = loss - self._mean
            self._mean = b * self._mean + (1.0 - b) * loss
            self._var = b * (self._var + (1.0 - b) * delta * delta)
        self._n += 1
