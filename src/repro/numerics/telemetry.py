"""The 8-slot numerics-telemetry vector (DESIGN.md §14).

Every guard-enabled train-step path — the grid megakernels, the per-chunk
scan, the sparse kernel, their ``ref.py`` oracles, and the sharded
wrappers — emits one ``(8,)`` f32 vector per step:

    slot 0  sat            # W-update elements whose pre-cast f32 value
                           lies at or beyond the storage dtype's max
                           finite (the e4m3 cliff is ±448) — SR saturates
                           there, Kahan's cast clips there
    slot 1  z_nonfinite    # non-finite logits among valid (row, col)s
    slot 2  lse_nonfinite  # non-finite entries of the finalized LSE
    slot 3  xg_nonfinite   # non-finite entries of the (B, D) x̄
    slot 4  comp_max       max |Kahan comp'| after the update (0 if no
                           Kahan chunks)
    slots 5–7              reserved (always 0)

Slots 0/1/4 are measured *inside* the step (the pre-cast update value and
the logits never materialize outside the kernels); slots 2/3 are filled
by the step wrappers from the final LSE/x̄ outputs (``finalize``), which
is exact on every path because those arrays ARE step outputs.

Exactness contract: slots 0–3 are integer-valued f32 *counts* — sums of
1.0 indicators are reassociation-safe below 2²⁴, so a kernel that sums
per label block and an oracle that sums per chunk agree bitwise; slot 4
is a max-reduction, order-independent (NaN propagates through
``jnp.maximum`` regardless of order).  Padding contributes exactly 0 to
every slot: padded W rows/cols update 0 → 0 (|0| < lim, and a NaN from a
poisoned x fails the ``>=`` compare), padded logits are masked out of
slot 1, and padded comp stays 0.  That is why guard-on telemetry is
identical across the grid kernel, the chunk scan, and the XLA oracle —
and why the counters can ride along without perturbing W/comp/x̄/loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import precision as P

N_SLOTS = 8
SLOTS = {"sat": 0, "z_nonfinite": 1, "lse_nonfinite": 2,
         "xg_nonfinite": 3, "comp_max": 4}


def zero() -> jax.Array:
    return jnp.zeros((N_SLOTS,), jnp.float32)


def combine(a: jax.Array, b: jax.Array) -> jax.Array:
    """Merge two telemetry vectors (across chunks / microbatches / shards):
    counts add, the comp max maxes."""
    slot = jnp.arange(N_SLOTS)
    return jnp.where(slot == SLOTS["comp_max"], jnp.maximum(a, b), a + b)


def chunk(pre_cast: jax.Array, comp_new, z: jax.Array, mask: jax.Array,
          wdtype) -> jax.Array:
    """In-step telemetry of one chunk/block — the oracle-side mirror of the
    kernels' in-VMEM accumulation (same indicator products, same reduction
    values).  ``pre_cast`` is the f32 update value before the storage-dtype
    cast; ``mask`` selects the valid logit positions."""
    lim = jnp.float32(P.max_finite(wdtype))
    sat = jnp.sum((jnp.abs(pre_cast) >= lim).astype(jnp.float32))
    znf = jnp.sum((~jnp.isfinite(z.astype(jnp.float32))).astype(jnp.float32)
                  * mask.astype(jnp.float32))
    cmax = (jnp.max(jnp.abs(comp_new.astype(jnp.float32)))
            if comp_new is not None else jnp.float32(0.0))
    slot = jnp.arange(N_SLOTS)
    out = (jnp.where(slot == SLOTS["sat"], sat, 0.0)
           + jnp.where(slot == SLOTS["z_nonfinite"], znf, 0.0)
           + jnp.where(slot == SLOTS["comp_max"], cmax, 0.0))
    return out.astype(jnp.float32)


def finalize(tele: jax.Array, xg: jax.Array, lse) -> jax.Array:
    """Fill the wrapper-computed slots (LSE/x̄ non-finite counts) from the
    step's final outputs.  Uniform across grid/scan/sparse/xla/sharded
    paths — the inputs are the *outputs* every path agrees on bitwise."""
    lse_nf = (jnp.float32(0.0) if lse is None else
              jnp.sum((~jnp.isfinite(lse.astype(jnp.float32))
                       ).astype(jnp.float32)))
    xg_nf = jnp.sum((~jnp.isfinite(xg.astype(jnp.float32))
                     ).astype(jnp.float32))
    slot = jnp.arange(N_SLOTS)
    return tele + jnp.where(slot == SLOTS["lse_nonfinite"], lse_nf, 0.0) \
        + jnp.where(slot == SLOTS["xg_nonfinite"], xg_nf, 0.0)
