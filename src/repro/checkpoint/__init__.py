"""Mesh-independent sharded checkpointing: crash-safe commits, per-leaf
checksums, async writes with surfaced errors, elastic restore."""
from repro.checkpoint.ckpt import (CheckpointError, CheckpointManager,
                                   committed_paths, latest_committed,
                                   restore_checkpoint, save_checkpoint,
                                   verify_checkpoint)

__all__ = ["CheckpointError", "CheckpointManager", "committed_paths",
           "latest_committed", "restore_checkpoint", "save_checkpoint",
           "verify_checkpoint"]
