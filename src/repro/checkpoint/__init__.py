"""Mesh-independent sharded checkpointing with async writes and elastic
restore."""
from repro.checkpoint.ckpt import (CheckpointManager, restore_checkpoint,
                                   save_checkpoint)

__all__ = ["CheckpointManager", "save_checkpoint", "restore_checkpoint"]
