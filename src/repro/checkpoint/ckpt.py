"""Sharded, mesh-independent, crash-safe checkpointing (no orbax).

Layout (one directory per step):

    ckpt_000123/
      manifest.json         # treedef, leaf paths, shapes, dtypes, per-leaf
                            # crc32 checksums, step, data cursor, head-plan
                            # metadata, mesh that wrote it (informative)
      leaf_00000.npy        # one .npy per leaf (f8/bf16 stored as raw bits)
      ...
      COMMITTED             # written LAST — crash-safe commit marker; holds
                            # the manifest's own crc32 (torn-manifest guard)

Commit protocol (DESIGN.md §10):

1. leaves + manifest are written into ``ckpt_N.tmp/``;
2. ``COMMITTED`` (containing the manifest crc32) is flushed + fsynced;
3. ``ckpt_N.tmp`` is atomically renamed to ``ckpt_N``.

A crash at any point leaves either a ``.tmp`` partial (garbage-collected by
``latest_committed``) or a fully committed step.  Every leaf's crc32 is
recorded in the manifest and re-verified on restore: a torn or bit-flipped
leaf **demotes** the checkpoint (``COMMITTED`` → ``CORRUPT`` with the
reason) and restore falls back to the previous committed step.

Key properties for the elastic-restart story:

* **Mesh-independent restore**: leaves are saved as full logical arrays and
  restored with ``jax.device_put(..., NamedSharding(new_mesh, spec))`` — the
  job can come back on a different pod count / mesh shape.
* **Async double-buffered saves**: ``CheckpointManager.save_async``
  snapshots to host memory synchronously (cheap) and writes to disk on a
  background thread; a failed background write is surfaced as a
  ``CheckpointError`` on the next ``save_async``/``wait`` instead of
  vanishing in the daemon thread.
* **Bit-exact low precision**: FP8 / BF16 leaves are stored as raw bits
  (bitcast to uint8/uint16), so FP8 W and the BF16 Kahan compensation
  survive a round trip bit-for-bit — the resume-determinism contract.
* **Data-cursor**: the manifest stores the *next* (seed, step) cursor so
  the deterministic data pipeline resumes exactly (repro.data).

On a real multi-host cluster each host writes only the shards it owns
(``process_allgather`` is avoided); in this single-process harness the full
array is local already.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import zlib
from typing import Any, Callable, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

_F8_TYPES = {"float8_e4m3fn": jnp.float8_e4m3fn, "float8_e5m2": jnp.float8_e5m2,
             "bfloat16": jnp.bfloat16}

FORMAT_VERSION = 2


class CheckpointError(RuntimeError):
    """Raised for failed writes (surfaced from the background thread) and
    for restores with no intact committed checkpoint to fall back to."""


class _LeafCorrupt(Exception):
    """Internal: one leaf failed its integrity check (torn / bit-flipped)."""


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", getattr(
        k, "name", k)))) for k in p) for p, _ in flat]
    return names, [l for _, l in flat], treedef


def _to_numpy(a: jax.Array) -> np.ndarray:
    if a.dtype in (jnp.float8_e4m3fn, jnp.float8_e5m2, jnp.bfloat16):
        # store raw bits; dtype recorded in the manifest
        return np.asarray(jax.lax.bitcast_convert_type(
            a, jnp.uint8 if a.dtype.itemsize == 1 else jnp.uint16))
    return np.asarray(a)


def _from_numpy(x: np.ndarray, dtype_str: str) -> np.ndarray:
    if dtype_str in _F8_TYPES:
        target = _F8_TYPES[dtype_str]
        arr = jnp.asarray(x)
        return np.asarray(jax.lax.bitcast_convert_type(arr, target))
    return x


def _checksum(arr: np.ndarray) -> str:
    return f"crc32:{zlib.crc32(np.ascontiguousarray(arr).tobytes()):08x}"


@dataclasses.dataclass
class _LeafRecord:
    """One leaf snapshotted to host memory, ready for the writer thread."""
    name: str
    data: np.ndarray          # storage representation (bits for f8/bf16)
    dtype: str                # logical dtype string
    shape: List[int]          # logical shape


def _snapshot(tree: Any) -> List[_LeafRecord]:
    names, leaves, _ = _leaf_paths(tree)
    return [_LeafRecord(n, _to_numpy(l), str(l.dtype), list(l.shape))
            for n, l in zip(names, leaves)]


def _fsync_write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
        f.flush()
        os.fsync(f.fileno())


def _write_snapshot(directory: str, step: int, records: List[_LeafRecord],
                    extra: Optional[dict], keep: Optional[int] = None) -> str:
    """The one commit path shared by sync and async saves."""
    path = os.path.join(directory, f"ckpt_{step:08d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"format": FORMAT_VERSION, "step": step, "extra": extra or {},
                "leaves": []}
    for i, rec in enumerate(records):
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), rec.data)
        manifest["leaves"].append({
            "name": rec.name, "file": fname, "shape": rec.shape,
            "dtype": rec.dtype, "checksum": _checksum(rec.data)})
    mtext = json.dumps(manifest)
    _fsync_write(os.path.join(tmp, "manifest.json"), mtext)
    _fsync_write(os.path.join(tmp, "COMMITTED"), json.dumps(
        {"manifest_crc32": f"{zlib.crc32(mtext.encode()):08x}"}))
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    if keep is not None:
        _gc_old(directory, keep)
    return path


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Synchronous commit-marked save. Returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    return _write_snapshot(directory, step, _snapshot(tree), extra)


def _demote(path: str, reason: str) -> None:
    """Strip the commit marker from a corrupt checkpoint so every future
    ``latest_committed`` skips it; record why for the postmortem."""
    marker = os.path.join(path, "COMMITTED")
    try:
        os.replace(marker, os.path.join(path, "CORRUPT"))
    except OSError:
        pass
    try:
        with open(os.path.join(path, "CORRUPT"), "a") as f:
            f.write("\n" + reason)
    except OSError:
        pass


def committed_paths(directory: str) -> List[str]:
    """All committed checkpoint dirs, ascending by step; GCs ``.tmp``
    partials (crashed mid-write) as a side effect."""
    if not os.path.isdir(directory):
        return []
    out = []
    for d in sorted(os.listdir(directory)):
        full = os.path.join(directory, d)
        if d.startswith("ckpt_") and not d.endswith(".tmp") \
                and os.path.exists(os.path.join(full, "COMMITTED")):
            out.append(full)
        elif d.startswith("ckpt_") and d.endswith(".tmp"):
            shutil.rmtree(full, ignore_errors=True)   # GC partial saves
    return out


def latest_committed(directory: str) -> Optional[str]:
    paths = committed_paths(directory)
    return paths[-1] if paths else None


def _read_manifest(path: str) -> dict:
    """Parse + integrity-check a committed checkpoint's manifest.

    Raises ``_LeafCorrupt`` on a torn manifest (crc mismatch against the
    COMMITTED marker, or unparseable json)."""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            mtext = f.read()
        manifest = json.loads(mtext)
    except (OSError, json.JSONDecodeError) as e:
        raise _LeafCorrupt(f"manifest unreadable: {e!r}")
    try:
        with open(os.path.join(path, "COMMITTED")) as f:
            marker = f.read()
        rec = json.loads(marker)
        want = rec.get("manifest_crc32")
    except (OSError, json.JSONDecodeError):
        want = None    # legacy "ok" marker: no manifest crc recorded
    if want is not None and f"{zlib.crc32(mtext.encode()):08x}" != want:
        raise _LeafCorrupt("manifest crc mismatch (torn manifest write)")
    return manifest


def _load_leaf(path: str, entry: dict, verify: bool) -> np.ndarray:
    """np.load one leaf and verify its recorded checksum.

    Raises ``_LeafCorrupt`` on torn files (np.load fails) or bit flips
    (crc mismatch)."""
    try:
        raw = np.load(os.path.join(path, entry["file"]))
    except (OSError, ValueError, EOFError) as e:
        raise _LeafCorrupt(f"{entry['name']}: unreadable ({e!r})")
    want = entry.get("checksum")
    if verify and want is not None and _checksum(raw) != want:
        raise _LeafCorrupt(f"{entry['name']}: checksum mismatch "
                           f"({_checksum(raw)} != {want})")
    return raw


def verify_checkpoint(path: str) -> Tuple[bool, str]:
    """Full integrity check of one checkpoint dir: commit marker, manifest
    crc, every leaf's existence + crc32.  Returns (ok, reason)."""
    if not os.path.exists(os.path.join(path, "COMMITTED")):
        return False, "no COMMITTED marker"
    try:
        manifest = _read_manifest(path)
        for entry in manifest["leaves"]:
            _load_leaf(path, entry, verify=True)
    except _LeafCorrupt as e:
        return False, str(e)
    except KeyError as e:
        return False, f"malformed manifest: {e!r}"
    return True, ""


def _resolve_shardings(shardings, treedef, names, t_leaves):
    """``shardings`` may be None, a matching pytree of Shardings, or a
    callable ``(leaf_name, template_leaf) -> Optional[Sharding]``."""
    if shardings is None:
        return [None] * len(t_leaves)
    if callable(shardings):
        return [shardings(n, l) for n, l in zip(names, t_leaves)]
    return treedef.flatten_up_to(shardings)


def restore_checkpoint(directory: str, template: Any,
                       shardings: Union[None, Any, Callable] = None,
                       verify: bool = True) -> tuple[Any, int, dict]:
    """Restore into ``template``'s structure; reshard onto ``shardings``
    (a matching tree of jax.sharding.Sharding, or a callable
    ``(name, leaf) -> Sharding``) if given — the elastic-restart path (the
    mesh may differ from the writer's).

    Integrity: each leaf's crc32 is re-verified against the manifest; a
    corrupt or torn checkpoint is demoted to uncommitted (``COMMITTED`` →
    ``CORRUPT``) and restore falls back to the previous committed step.
    Raises ``CheckpointError`` when no intact committed checkpoint remains.
    """
    names, t_leaves, treedef = _leaf_paths(template)
    shard_leaves = _resolve_shardings(shardings, treedef, names, t_leaves)
    while True:
        path = latest_committed(directory)
        if path is None:
            raise CheckpointError(
                f"no intact committed checkpoint under {directory}")
        try:
            manifest = _read_manifest(path)
            by_name = {e["name"]: e for e in manifest["leaves"]}
            out = []
            for name, tl, sh in zip(names, t_leaves, shard_leaves):
                entry = by_name.get(name)
                if entry is None:
                    raise ValueError(
                        f"{path}: leaf {name!r} missing from manifest — "
                        "template structure changed since the save")
                raw = _load_leaf(path, entry, verify)
                arr = _from_numpy(raw, entry["dtype"])
                if list(tl.shape) != entry["shape"]:
                    raise ValueError(f"{name}: shape changed "
                                     f"{entry['shape']} → {list(tl.shape)}")
                if sh is not None and not isinstance(
                        sh, jax.sharding.PartitionSpec):
                    out.append(jax.device_put(arr, sh))
                else:
                    out.append(jnp.asarray(arr).astype(tl.dtype))
        except _LeafCorrupt as e:
            _demote(path, str(e))
            print(f"checkpoint {os.path.basename(path)} corrupt ({e}); "
                  "falling back to previous committed step", flush=True)
            continue
        return (jax.tree_util.tree_unflatten(treedef, out),
                manifest["step"], manifest.get("extra", {}))


def _gc_old(directory: str, keep: int) -> None:
    cks = sorted(d for d in os.listdir(directory)
                 if d.startswith("ckpt_") and not d.endswith(".tmp"))
    for d in cks[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


class CheckpointManager:
    """Async double-buffered manager with retention and error surfacing.

    The background writer never swallows exceptions: a failed disk write is
    stored and re-raised as ``CheckpointError`` from the next ``wait()`` or
    ``save_async()`` — the train loop finds out *before* it deletes the
    state the failed checkpoint was supposed to protect."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    def wait(self):
        """Join the in-flight write; raise if it (or a previous one)
        failed."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise CheckpointError(
                f"background checkpoint write failed: {err!r}") from err

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None):
        """Blocks only for device→host transfer; disk I/O on a thread.
        Raises ``CheckpointError`` if the previous background write
        failed."""
        self.wait()
        records = _snapshot(tree)   # device→host now; bit-exact f8/bf16

        def _write():
            try:
                _write_snapshot(self.directory, step, records, extra,
                                keep=self.keep)
            except BaseException as e:   # surfaced on next wait/save_async
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
