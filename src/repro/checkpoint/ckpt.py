"""Sharded, mesh-independent checkpointing (no orbax dependency).

Layout (one directory per step):

    ckpt_000123/
      manifest.json         # treedef, leaf paths, shapes, dtypes, step,
                            # data cursor, mesh that wrote it (informative)
      leaf_00000.npy        # one .npy per leaf (f8 stored as raw uint8)
      ...
      COMMITTED             # written LAST — crash-safe commit marker

Key properties for the 1000+-node story:

* **Mesh-independent restore**: leaves are saved as full logical arrays and
  restored with ``jax.device_put(..., NamedSharding(new_mesh, spec))`` — the
  job can come back on a different pod count / mesh shape (elastic restart).
* **Async double-buffered saves**: ``CheckpointManager.save_async`` snapshots
  to host memory synchronously (cheap) and writes to disk on a background
  thread, so the train loop only blocks for the device→host copy.
* **Crash safety**: a checkpoint without COMMITTED is ignored and garbage-
  collected; the previous committed step is used instead.
* **Data-cursor**: the manifest stores (epoch, step, shard cursor) so the
  deterministic data pipeline resumes exactly (repro.data).

On a real multi-host cluster each host writes only the shards it owns
(``process_allgather`` is avoided); in this single-process harness the full
array is local already.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_F8_TYPES = {"float8_e4m3fn": jnp.float8_e4m3fn, "float8_e5m2": jnp.float8_e5m2,
             "bfloat16": jnp.bfloat16}


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", getattr(
        k, "name", k)))) for k in p) for p, _ in flat]
    return names, [l for _, l in flat], treedef


def _to_numpy(a: jax.Array) -> np.ndarray:
    if a.dtype in (jnp.float8_e4m3fn, jnp.float8_e5m2, jnp.bfloat16):
        # store raw bits; dtype recorded in the manifest
        return np.asarray(jax.lax.bitcast_convert_type(
            a, jnp.uint8 if a.dtype.itemsize == 1 else jnp.uint16))
    return np.asarray(a)


def _from_numpy(x: np.ndarray, dtype_str: str) -> np.ndarray:
    if dtype_str in _F8_TYPES:
        target = _F8_TYPES[dtype_str]
        arr = jnp.asarray(x)
        return np.asarray(jax.lax.bitcast_convert_type(arr, target))
    return x


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Synchronous commit-marked save. Returns the checkpoint path."""
    path = os.path.join(directory, f"ckpt_{step:08d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    names, leaves, _ = _leaf_paths(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = _to_numpy(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "name": name, "file": fname, "shape": list(leaf.shape),
            "dtype": str(leaf.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


def latest_committed(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    best = None
    for d in sorted(os.listdir(directory)):
        full = os.path.join(directory, d)
        if d.startswith("ckpt_") and not d.endswith(".tmp") \
                and os.path.exists(os.path.join(full, "COMMITTED")):
            best = full
        elif d.endswith(".tmp"):
            shutil.rmtree(full, ignore_errors=True)   # GC partial saves
    return best


def restore_checkpoint(directory: str, template: Any,
                       shardings: Any = None) -> tuple[Any, int, dict]:
    """Restore into ``template``'s structure; reshard onto ``shardings``
    (a matching tree of jax.sharding.Sharding) if given — this is the
    elastic-restart path (mesh may differ from the writer's)."""
    path = latest_committed(directory)
    assert path is not None, f"no committed checkpoint under {directory}"
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    names, t_leaves, treedef = _leaf_paths(template)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(t_leaves))
    out = []
    for name, tl, sh in zip(names, t_leaves, shard_leaves):
        entry = by_name[name]
        raw = np.load(os.path.join(path, entry["file"]))
        arr = _from_numpy(raw, entry["dtype"])
        assert list(tl.shape) == entry["shape"], \
            f"{name}: shape changed {entry['shape']} → {tl.shape}"
        if sh is not None and not isinstance(sh, jax.sharding.PartitionSpec):
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr).astype(tl.dtype))
    return (jax.tree_util.tree_unflatten(treedef, out),
            manifest["step"], manifest.get("extra", {}))


class CheckpointManager:
    """Async double-buffered manager with retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None):
        """Blocks only for device→host transfer; disk I/O on a thread."""
        self.wait()
        host_tree = jax.tree.map(_to_numpy, tree)   # snapshot now
        names, leaves, treedef = _leaf_paths(tree)
        dtypes = [str(l.dtype) for l in leaves]

        def _write():
            # rebuild a tree of (numpy, dtype) for save
            h_names, h_leaves, h_treedef = _leaf_paths(host_tree)
            path = os.path.join(self.directory, f"ckpt_{step:08d}")
            tmp = path + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "extra": extra or {}, "leaves": []}
            for i, (name, arr, dt) in enumerate(
                    zip(h_names, h_leaves, dtypes)):
                fname = f"leaf_{i:05d}.npy"
                np.save(os.path.join(tmp, fname), arr)
                manifest["leaves"].append({
                    "name": name, "file": fname,
                    "shape": list(np.asarray(arr).shape)
                    if dt not in ("bfloat16",) else list(arr.shape),
                    "dtype": dt})
            json.dump(manifest, open(os.path.join(tmp, "manifest.json"), "w"))
            open(os.path.join(tmp, "COMMITTED"), "w").write("ok")
            if os.path.exists(path):
                shutil.rmtree(path)
            os.rename(tmp, path)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def _gc(self):
        cks = sorted(d for d in os.listdir(self.directory)
                     if d.startswith("ckpt_") and not d.endswith(".tmp"))
        for d in cks[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, d),
                          ignore_errors=True)
