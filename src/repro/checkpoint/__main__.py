"""Offline checkpoint integrity audit (DESIGN.md §10 / §14).

    python -m repro.checkpoint verify <ckpt_dir | ckpt_dir/ckpt_NNNNNNNN>

Re-runs the full restore-time integrity checks — commit marker, manifest
crc against the COMMITTED record, every leaf's existence + crc32 — WITHOUT
demoting anything (read-only: the online restore path owns demotion).
Unlike ``verify_checkpoint`` (which reports the first failure), the audit
checks **every** leaf and prints one line per defect, so a postmortem sees
the full blast radius of a torn write or a flaky disk.

Exit status: 0 iff every audited checkpoint is intact; 1 otherwise (so CI
and the recovery runbooks can gate on it); 2 for usage errors.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.checkpoint.ckpt import (_LeafCorrupt, _load_leaf, _read_manifest,
                                   committed_paths)


def _audit_one(path: str) -> list:
    """Every defect in one checkpoint dir, as ``(leaf_or_scope, reason)``.
    Empty list == intact."""
    defects = []
    if os.path.exists(os.path.join(path, "CORRUPT")):
        try:
            with open(os.path.join(path, "CORRUPT")) as f:
                why = f.read().strip().splitlines()
        except OSError:
            why = []
        defects.append(("<marker>", "quarantined: "
                        + (why[-1] if why else "CORRUPT marker present")))
    elif not os.path.exists(os.path.join(path, "COMMITTED")):
        defects.append(("<marker>", "no COMMITTED marker (torn or partial "
                        "write)"))
    try:
        manifest = _read_manifest(path)
    except _LeafCorrupt as e:
        defects.append(("<manifest>", str(e)))
        return defects
    for entry in manifest.get("leaves", []):
        try:
            _load_leaf(path, entry, verify=True)
        except _LeafCorrupt as e:
            name = entry.get("name", entry.get("file", "?"))
            reason = str(e)
            if reason.startswith(name + ": "):
                reason = reason[len(name) + 2:]
            defects.append((name, reason))
    return defects


def _targets(path: str) -> list:
    """A single checkpoint dir, or every ckpt_* under a store dir."""
    if os.path.exists(os.path.join(path, "manifest.json")):
        return [path]
    store = [os.path.join(path, d) for d in sorted(os.listdir(path))
             if d.startswith("ckpt_") and not d.endswith(".tmp")]
    if not store:
        raise FileNotFoundError(
            f"{path}: neither a checkpoint dir (no manifest.json) nor a "
            "store containing ckpt_* dirs")
    return store


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.checkpoint")
    sub = ap.add_subparsers(dest="cmd", required=True)
    v = sub.add_parser("verify", help="audit checkpoint integrity")
    v.add_argument("path", help="a checkpoint store dir, or one ckpt_N dir")
    v.add_argument("-q", "--quiet", action="store_true",
                   help="only print defects")
    args = ap.parse_args(argv)

    try:
        targets = _targets(args.path)
    except (FileNotFoundError, NotADirectoryError, OSError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    bad = 0
    for path in targets:
        defects = _audit_one(path)
        name = os.path.basename(path.rstrip("/"))
        if defects:
            bad += 1
            print(f"{name}: CORRUPT ({len(defects)} defect(s))")
            for leaf, reason in defects:
                print(f"  {leaf}: {reason}")
        elif not args.quiet:
            print(f"{name}: ok")
    if not args.quiet:
        n_committed = len(committed_paths(args.path)) \
            if len(targets) != 1 else None
        tail = (f"; {n_committed} committed in store"
                if n_committed is not None else "")
        print(f"{len(targets) - bad}/{len(targets)} intact{tail}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
