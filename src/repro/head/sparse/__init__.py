"""Fixed-fan-in sparse head subsystem (DESIGN.md §13).

Layout: each label row keeps exactly ``fan_in`` weight slots — FP8
values + i32 column indices, a dense ``(L, fan_in)`` pair that streams
through the same grid machinery as the dense head.  ``state`` holds the
SparseHeadState + dense↔sparse conversion (the densify oracle),
``train`` the plan-driven single-device/sharded steps, ``controller``
the deterministic periodic prune/regrow.  The Pallas kernel lives in
``repro.kernels.sparse_head``; its bit-parity oracle in
``repro.kernels.ref`` (``sparse_head_step_ref``).
"""
from repro.head.sparse.controller import (maybe_prune_regrow, n_swap_of,
                                          prune_regrow)
from repro.head.sparse.serving import (logits_sparse_planned,
                                       logits_sparse_sharded_planned,
                                       precision_at_k_sparse_planned,
                                       topk_sparse_planned,
                                       topk_sparse_sharded_planned)
from repro.head.sparse.state import (SparseHeadState, densify,
                                     indices_strictly_increasing,
                                     init_sparse_head, sparsify)
from repro.head.sparse.train import (train_step_sparse,
                                     train_step_sparse_sharded)

__all__ = [
    "SparseHeadState", "densify", "indices_strictly_increasing",
    "init_sparse_head", "sparsify", "maybe_prune_regrow", "n_swap_of",
    "prune_regrow", "train_step_sparse", "train_step_sparse_sharded",
    "logits_sparse_planned", "logits_sparse_sharded_planned",
    "topk_sparse_planned", "topk_sparse_sharded_planned",
    "precision_at_k_sparse_planned",
]
