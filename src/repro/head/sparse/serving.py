"""Sparse-head serving: logits and streaming top-k (DESIGN.md §13).

The dense head's serving contract (``head/serving.py``) carries over
unchanged — same DropConnect policy (dense by default, the historical
seed-0 mask behind ``cfg.compat_eval_drop``), same §9 top-k tie-break
(``kernels.ref.topk_merge``), same sharded n·k gather + (−value, id)
re-rank.  What changes is the weight access: the head is never
densified whole.  Value/index rows stream through in ``(block, D)``
tiles — each tile is densified (select-scatter, ``ref.sparse_densify``),
scored, and folded into the running (B, k) carry, so serving transients
are O(B·k + block·D) for any label count.  Because the per-column op
sequence equals the dense scan's (the densified tile IS the dense rows),
sparse serving is bit-identical to dense serving on the densified state
— the differential test anchor.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from repro.head import plan as _plan
from repro.head.config import ELMOHeadConfig
from repro.head.serving import _p_at_k, _serve_drop
from repro.head.sparse.state import SparseHeadState
from repro.kernels import prng_utils as PR
from repro.kernels import ref as REF


def _row_block(lc: int) -> int:
    """Serving row-tile: the largest power-of-two ≤ 2048 dividing the
    chunk width (the result is bit-invariant to this choice — it only
    bounds the densified transient)."""
    for bl in (2048, 1024, 512, 256, 128):
        if lc % bl == 0 and bl <= lc:
            return bl
    return lc


def _block_logits(cfg: ELMOHeadConfig, vblk: jax.Array, iblk: jax.Array,
                  x16: jax.Array, off: jax.Array) -> jax.Array:
    """(B, bl) serving logits of one sparse row block at row offset
    ``off`` inside its chunk — op-for-op ``ref.fp8_logits_ref`` on the
    densified tile, with the DropConnect mask (only live under
    ``cfg.compat_eval_drop``) drawn at the block's absolute in-chunk
    rows so any tiling reproduces the per-chunk seed-0 mask exactly."""
    w16 = REF.sparse_densify(vblk, iblk, cfg.d_model)
    drop = _serve_drop(cfg)
    if drop > 0.0:
        bits = PR.hash_bits_2d(jnp.zeros((), jnp.uint32),
                               off.astype(jnp.uint32),
                               jnp.zeros((), jnp.uint32), w16.shape)
        keep = PR.uniform_from_bits(bits) >= drop
        w16 = jnp.where(keep, w16, 0).astype(jnp.bfloat16) \
            / jnp.bfloat16(1.0 - drop)
    xq = x16.astype(jnp.float8_e4m3fn) if cfg.qx else x16
    z = jax.lax.dot_general(xq.astype(jnp.bfloat16), w16,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return z.astype(jnp.bfloat16)


def _chunk_logits_blocked(cfg: ELMOHeadConfig, vc: jax.Array, ic: jax.Array,
                          x16: jax.Array) -> jax.Array:
    """(B, lc) logits of one sparse chunk via the block-streamed scan."""
    lc, F = vc.shape
    bl = _row_block(lc)
    nb = lc // bl

    def body(_, inp):
        vblk, iblk, bi = inp
        return None, _block_logits(cfg, vblk, iblk, x16, bi * bl)

    _, zs = jax.lax.scan(
        body, None, (vc.reshape(nb, bl, F), ic.reshape(nb, bl, F),
                     jnp.arange(nb, dtype=jnp.int32)))
    return jnp.moveaxis(zs, 0, 1).reshape(x16.shape[0], lc)


# ---------------------------------------------------------------------------
# logits
# ---------------------------------------------------------------------------


def logits_sparse_planned(plan: "_plan.HeadPlan", cfg: ELMOHeadConfig,
                          state: SparseHeadState, x: jax.Array) -> jax.Array:
    """Full (B, L) sparse logits — O(B·L) output like the dense path, but
    the densified transient is one row block, never a whole chunk."""
    x16 = x.astype(jnp.bfloat16)

    def body(_, inp):
        vc, ic = inp
        return None, _chunk_logits_blocked(cfg, vc, ic, x16)

    _, zs = jax.lax.scan(body, None, (state.values, state.indices))
    z = jnp.moveaxis(zs, 0, 1).reshape(x.shape[0], cfg.padded_labels)
    return z[:, :cfg.num_labels]


def logits_sparse_sharded_planned(plan: "_plan.HeadPlan",
                                  cfg: ELMOHeadConfig, ctx,
                                  state: SparseHeadState, x: jax.Array
                                  ) -> jax.Array:
    """``logits_sparse_planned`` with the label rows sharded: each rank
    scores its (B, lc) window per chunk, one tiled all_gather restores
    the global column order (bit-equal per column, as dense §6)."""
    from repro.dist.compat import shard_map as _shard_map

    if not plan.sharded:
        return logits_sparse_planned(plan, cfg, state, x)
    axis = ctx.model_axis
    x = x.astype(jnp.bfloat16)

    def body(vals, idx, x16):
        def scan_body(_, inp):
            vc, ic = inp
            zc = _chunk_logits_blocked(cfg, vc, ic, x16)
            return None, jax.lax.all_gather(zc, axis, axis=1, tiled=True)

        _, zs = jax.lax.scan(scan_body, None, (vals, idx))
        return jnp.moveaxis(zs, 0, 1).reshape(x16.shape[0],
                                              cfg.padded_labels)

    z = _shard_map(body, mesh=ctx.mesh,
                   in_specs=(plan.w_spec, plan.w_spec, PS()),
                   out_specs=PS(), check_vma=False)(
                       state.values, state.indices, x)
    return z[:, :cfg.num_labels]


# ---------------------------------------------------------------------------
# top-k
# ---------------------------------------------------------------------------


def _topk_scan_sparse(cfg: ELMOHeadConfig, values: jax.Array,
                      indices: jax.Array, x16: jax.Array, k: int,
                      c0_of) -> Tuple[jax.Array, jax.Array]:
    """Streaming sparse top-k: every (block, D) densified tile folds into
    the (B, k) carry through ``ref.topk_merge`` — the §9 contract, so the
    result is bit-identical to the dense streaming scan on the densified
    state at ANY row-block size (the merge's total order on (value, id)
    does not depend on how the label axis is partitioned)."""
    B = x16.shape[0]
    C, lc, F = values.shape
    bl = _row_block(lc)
    nb = lc // bl

    def body(carry, inp):
        vc, ic, cidx = inp
        c0 = c0_of(cidx)

        def bbody(bcarry, binp):
            vblk, iblk, bi = binp
            z = _block_logits(cfg, vblk, iblk, x16, bi * bl)
            cols = c0 + bi * bl + jnp.arange(bl, dtype=jnp.int32)
            return REF.topk_merge(*bcarry, z, cols, k, cfg.num_labels), None

        carry, _ = jax.lax.scan(
            bbody, carry, (vc.reshape(nb, bl, F), ic.reshape(nb, bl, F),
                           jnp.arange(nb, dtype=jnp.int32)))
        return carry, None

    (vals, idx), _ = jax.lax.scan(
        body, REF.topk_carry_init(B, k),
        (values, indices, jnp.arange(C, dtype=jnp.int32)))
    return vals, idx


def topk_sparse_planned(plan: "_plan.HeadPlan", cfg: ELMOHeadConfig,
                        state: SparseHeadState, x: jax.Array, k: int
                        ) -> Tuple[jax.Array, jax.Array]:
    """Top-k serving for the sparse head (``plan.topk_path == "stream"``
    always — the sparse layout IS the streaming format)."""
    x16 = x.astype(jnp.bfloat16)
    return _topk_scan_sparse(cfg, state.values, state.indices, x16, k,
                             lambda cidx: cidx * cfg.chunk)


def topk_sparse_sharded_planned(plan: "_plan.HeadPlan", cfg: ELMOHeadConfig,
                                ctx, state: SparseHeadState, x: jax.Array,
                                k: int) -> Tuple[jax.Array, jax.Array]:
    """Sharded sparse top-k: local streaming scan per rank over its label
    window, gather of the n·k candidates, (−value, id) re-rank — ids and
    values bit-identical to single-device (same §6 merge argument as the
    dense path; a rank's candidates are already in ascending global id)."""
    from repro.dist.compat import shard_map as _shard_map

    if not plan.sharded:
        return topk_sparse_planned(plan, cfg, state, x, k)
    axis = ctx.model_axis
    lc = plan.lc
    n = plan.model_size
    x = x.astype(jnp.bfloat16)

    def body(vals_s, idx_s, x16):
        r = jax.lax.axis_index(axis).astype(jnp.int32)
        vals, idx = _topk_scan_sparse(
            cfg, vals_s, idx_s, x16, k,
            lambda cidx: cidx * cfg.chunk + r * lc)
        vall = jax.lax.all_gather(vals, axis)
        idxl = jax.lax.all_gather(idx, axis)
        B = x16.shape[0]
        vall = jnp.moveaxis(vall, 0, 1).reshape(B, n * k)
        idxl = jnp.moveaxis(idxl, 0, 1).reshape(B, n * k)
        nv, ids = jax.lax.sort((-vall, idxl), dimension=1, num_keys=2)
        return -nv[:, :k], ids[:, :k]

    return _shard_map(body, mesh=ctx.mesh,
                      in_specs=(plan.w_spec, plan.w_spec, PS()),
                      out_specs=(PS(), PS()), check_vma=False)(
                          state.values, state.indices, x)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def precision_at_k_sparse_planned(plan: "_plan.HeadPlan",
                                  cfg: ELMOHeadConfig, ctx,
                                  state: SparseHeadState, x: jax.Array,
                                  label_ids: jax.Array, k: int,
                                  denom: str = "positives") -> jax.Array:
    """P@k over the sparse top-k — same hit/denominator semantics as the
    dense path (``serving._p_at_k``), same sentinel masking."""
    vals, pred = topk_sparse_sharded_planned(plan, cfg, ctx, state, x, k)
    return _p_at_k(vals, pred, label_ids, k, denom)
