"""Sparse-head training steps (single-device + label-sharded), plan-driven.

``train_step_sparse`` mirrors ``head.train._train_step_grid``: one
``ops.sparse_head_step`` launch per step (two-pass in-launch grid for
softmax-CE), dispatched by the plan's ``train_inner`` — the Pallas sparse
megakernel on kernel/interpret, the bit-identical ``ref`` scan on xla.
The per-chunk seeds, loss fold, and metrics come from the same helpers
as the dense paths, so sparse-at-``fan_in = D`` and dense-grid steps are
bit-identical end to end.

``train_step_sparse_sharded`` mirrors ``head.train_sharded``: the label
dimension of values/indices/comp shards over the mesh's model axis
(row-partitioned chunks, ``plan.w_spec``), the batch gathers over the
data axes, per-shard x̄ partials psum-reduce, and softmax-CE picks the
normalizer strategy via ``ce_comm`` ("gather" = full-width LSE on
all-gathered logits, bit-identical to single-device for deterministic
updates; "stats" = O(B) pmax/psum).  The sharded sparse step runs the
pure-JAX ref composition inside ``shard_map`` (the sparse forward is
cheap; a per-shard kernel launch is a measured-autotuning follow-up).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as PS

from repro.core import losses as L
from repro.head.config import ELMOHeadConfig
from repro.head.sparse.state import SparseHeadState
from repro.head.train import _chunk_seed, _fold_loss, _grid_seeds, _masked_z
from repro.kernels import ops
from repro.kernels import prng_utils as PR
from repro.kernels import ref as REF
from repro.numerics import telemetry as NT


def train_step_sparse(plan, cfg: ELMOHeadConfig, state: SparseHeadState,
                      x: jax.Array, targets: jax.Array, lr: jax.Array,
                      wd: jax.Array, seed: jax.Array
                      ) -> Tuple[SparseHeadState, jax.Array, dict]:
    """One whole sparse-head launch: forward, loss-skip grad, x̄, in-place
    SR/Kahan value update.  Indices are read-only here — prune/regrow
    mutates them between steps (``controller.maybe_prune_regrow``)."""
    B = x.shape[0]
    x = x.astype(jnp.bfloat16)
    seed = seed.astype(jnp.uint32)
    seeds_d, seeds_u, cids = _grid_seeds(cfg, seed)
    base = cids * cfg.chunk
    common = dict(num_labels=cfg.num_labels, use_sr=cfg.use_sr,
                  quantize_x=cfg.qx, drop_rate=cfg.drop_rate,
                  compute_loss=cfg.compute_loss, impl=plan.train_inner,
                  guard=cfg.guard)

    if cfg.loss == "bce":
        scale, lse = jnp.float32(1.0 / B), None
        out = ops.sparse_head_step(x, state.values, state.indices, targets,
                                   lr, wd, scale, seeds_d, seeds_u, base,
                                   comp=state.comp, mode="bce", **common)
    else:
        n_tok = jnp.maximum((targets >= 0).sum(), 1).astype(jnp.float32)
        scale = 1.0 / n_tok
        out = ops.sparse_head_step(x, state.values, state.indices, targets,
                                   lr, wd, scale, seeds_d, seeds_u, base,
                                   comp=state.comp, mode="ce_full", **common)
        lse = out.lse

    loss = _fold_loss(cfg, out.loss, targets, lse, scale, B)
    metrics = {"loss": loss,
               "xgrad_norm": jnp.linalg.norm(out.xg.astype(jnp.float32))}
    if cfg.guard:
        metrics["telemetry"] = NT.finalize(out.tele, out.xg, lse)
    return (SparseHeadState(out.values, state.indices, out.comp),
            out.xg, metrics)


def train_step_sparse_sharded(plan, cfg: ELMOHeadConfig, ctx,
                              state: SparseHeadState, x: jax.Array,
                              targets: jax.Array, lr: jax.Array,
                              wd: jax.Array, seed: jax.Array, *,
                              ce_comm: str = "gather"
                              ) -> Tuple[SparseHeadState, jax.Array, dict]:
    """Label-sharded sparse step (the sparse mirror of
    ``train_sharded.train_step_sharded_planned``)."""
    from repro.dist.compat import shard_map as _shard_map

    assert ce_comm in ("gather", "stats"), ce_comm
    if not plan.sharded:
        return train_step_sparse(plan, cfg, state, x, targets, lr, wd, seed)

    mesh, axis = ctx.mesh, ctx.model_axis
    batch_axes = tuple(a for a in ctx.batch_axes
                       if a in mesh.shape and mesh.shape[a] > 1)
    n_batch = 1
    for a in batch_axes:
        n_batch *= int(mesh.shape[a])
    if x.shape[0] % n_batch != 0:
        batch_axes, n_batch = (), 1
    b0 = batch_axes if batch_axes else None

    lc = plan.lc
    kahan = state.comp is not None
    chunk_ids = jnp.arange(cfg.num_chunks, dtype=jnp.int32)

    def body(*args):
        it = iter(args)
        vals, idx = next(it), next(it)
        comp = next(it) if kahan else None
        xl, tgt = next(it), next(it)
        lr_, wd_, seed_ = next(it), next(it), next(it)

        Bl = xl.shape[0]
        for a in reversed(batch_axes):
            xl = jax.lax.all_gather(xl, a, axis=0, tiled=True)
            tgt = jax.lax.all_gather(tgt, a, axis=0, tiled=True)
        x16 = xl.astype(jnp.bfloat16)
        B = x16.shape[0]
        r = jax.lax.axis_index(axis)
        seed_sh = PR.mix32(seed_.astype(jnp.uint32)
                           + (r.astype(jnp.uint32) + 1)
                           * np.uint32(0x85EBCA6B))
        seeds_d = _chunk_seed(seed_sh, chunk_ids, 0)
        seeds_u = _chunk_seed(seed_sh, chunk_ids, 1)
        base = chunk_ids * cfg.chunk + r.astype(jnp.int32) * lc

        lse = None
        loss_pre = jnp.float32(0.0)
        if cfg.loss == "bce":
            scale = jnp.float32(1.0 / B)
            mode, kernel_loss = "bce", False
            if cfg.compute_loss:
                # exact loss on the full-width gathered logits (the local
                # sparse forward re-runs inside the step — XLA CSEs it)
                def loss_body(acc, inp):
                    vals_c, idx_c, sd, b0c, cidx = inp
                    w16 = REF.sparse_densify(vals_c, idx_c, cfg.d_model)
                    zl = REF.fp8_logits_ref(x16, w16, sd,
                                            drop_rate=cfg.drop_rate,
                                            quantize_x=cfg.qx)
                    zf = jax.lax.all_gather(zl, axis, axis=1, tiled=True)
                    y = L.chunk_multi_hot(tgt, cidx * cfg.chunk, cfg.chunk)
                    valid = ((cidx * cfg.chunk + jnp.arange(cfg.chunk))
                             < cfg.num_labels)[None, :]
                    return acc + L.bce_chunk_loss(zf, y, mask=valid), None

                loss_pre, _ = jax.lax.scan(
                    loss_body, jnp.float32(0.0),
                    (vals, idx, seeds_d, base, chunk_ids))
        else:
            n_tok = jnp.maximum((tgt >= 0).sum(), 1).astype(jnp.float32)
            scale = 1.0 / n_tok
            mode, kernel_loss = "ce_update", False
            if ce_comm == "gather":
                # full-width streaming LSE on gathered chunk logits — the
                # same op sequence as single-device (bit-parity contract)
                def lse_body(carry, inp):
                    vals_c, idx_c, sd, cidx = inp
                    m, s, lraw = carry
                    w16 = REF.sparse_densify(vals_c, idx_c, cfg.d_model)
                    zl = REF.fp8_logits_ref(x16, w16, sd,
                                            drop_rate=cfg.drop_rate,
                                            quantize_x=cfg.qx)
                    zf = jax.lax.all_gather(zl, axis, axis=1, tiled=True)
                    m, s = L.lse_update(m, s, _masked_z(cfg, zf, cidx))
                    if cfg.compute_loss:
                        lraw = lraw + L.ce_target_logit_chunk(
                            zf, tgt, cidx * cfg.chunk, cfg.chunk).sum()
                    return (m, s, lraw), None

                (m, s, loss_pre), _ = jax.lax.scan(
                    lse_body, L.lse_init(B) + (jnp.float32(0.0),),
                    (vals, idx, seeds_d, chunk_ids))
            else:
                def lse_body(carry, inp):
                    vals_c, idx_c, sd, b0c = inp
                    m, s = carry
                    return REF.sparse_lse_chunk_ref(
                        x16, vals_c, idx_c, m, s, b0c, sd,
                        num_labels=cfg.num_labels, quantize_x=cfg.qx,
                        drop_rate=cfg.drop_rate), None

                (m, s), _ = jax.lax.scan(lse_body, L.lse_init(B),
                                         (vals, idx, seeds_d, base))
                m_g = jax.lax.pmax(m, axis)
                s_g = jax.lax.psum(s * jnp.exp(m - m_g), axis)
                m, s = m_g, s_g
                kernel_loss = cfg.compute_loss
            lse = L.lse_finalize(m, s)

        out = ops.sparse_head_step(
            x16, vals, idx, tgt, lr_, wd_, scale, seeds_d, seeds_u, base,
            lse=lse, comp=comp, mode=mode, num_labels=cfg.num_labels,
            use_sr=cfg.use_sr, quantize_x=cfg.qx, drop_rate=cfg.drop_rate,
            compute_loss=kernel_loss, impl="xla", guard=cfg.guard)
        loss_raw = loss_pre + out.loss
        if ce_comm == "stats" and cfg.loss != "bce" and cfg.compute_loss:
            loss_raw = jax.lax.psum(loss_raw, axis)

        xg_comb = jax.lax.psum(out.xg.astype(jnp.float32), axis
                               ).astype(jnp.bfloat16)
        loss = _fold_loss(cfg, loss_raw, tgt, lse, scale, B)
        xnorm = jnp.linalg.norm(xg_comb.astype(jnp.float32))

        if batch_axes:
            bidx = jnp.int32(0)
            for a in batch_axes:
                bidx = bidx * mesh.shape[a] + jax.lax.axis_index(a)
            xg_out = jax.lax.dynamic_slice_in_dim(xg_comb, bidx * Bl, Bl, 0)
        else:
            xg_out = xg_comb

        outs = [out.values]
        if kahan:
            outs.append(out.comp)
        outs += [xg_out, loss, xnorm]
        if cfg.guard:
            # counts (slots 0–3) sum across label shards, the comp max
            # maxes; the LSE/x̄ slots then come from the replicated final
            # outputs — identical on every shard, so the vector replicates
            slot = jnp.arange(out.tele.shape[0])
            t = jnp.where(slot == NT.SLOTS["comp_max"],
                          jax.lax.pmax(out.tele, axis),
                          jax.lax.psum(out.tele, axis))
            outs.append(NT.finalize(t, xg_comb, lse))
        return tuple(outs)

    wspec = plan.w_spec
    tgt_spec = PS(b0, None) if targets.ndim == 2 else PS(b0)
    operands = [state.values, state.indices] \
        + ([state.comp] if kahan else []) \
        + [x, targets, jnp.asarray(lr, jnp.float32),
           jnp.asarray(wd, jnp.float32),
           jnp.asarray(seed).astype(jnp.uint32)]
    in_specs = [wspec, wspec] + ([wspec] if kahan else []) + [
        PS(b0, None), tgt_spec, PS(), PS(), PS()]
    out_specs = [wspec] + ([wspec] if kahan else []) + [
        PS(b0, None), PS(), PS()]
    if cfg.guard:
        out_specs.append(PS())

    outs = _shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                      out_specs=tuple(out_specs), check_vma=False)(*operands)
    it = iter(outs)
    v_new = next(it)
    comp_new = next(it) if kahan else None
    xg, loss, xnorm = next(it), next(it), next(it)
    metrics = {"loss": loss, "xgrad_norm": xnorm}
    if cfg.guard:
        metrics["telemetry"] = next(it)
    return (SparseHeadState(v_new, state.indices, comp_new), xg, metrics)
