"""Fixed-fan-in sparse head state: values + indices + Kahan comp.

Each label row keeps exactly ``fan_in`` weight slots (DESIGN.md §13):
``values`` holds the slot weights in the storage dtype and ``indices``
their dense column ids — **sorted strictly increasing per row**, the
invariant every kernel and oracle relies on (unique columns make the
where-select densify and the masked-sum gather exact inverses).  The
state is a dumb NamedTuple like the dense ``HeadState`` so it passes
through jit/shard_map/checkpointing untouched; values and indices
checkpoint as raw bits (the §10 resume contract — an i32 index array
round-trips exactly, and prune/regrow replays deterministically from
the restored bits).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import precision as P
from repro.head.config import ELMOHeadConfig
from repro.head.state import HeadState, init_head


class SparseHeadState(NamedTuple):
    """values: (C, Lc, F) storage dtype · indices: (C, Lc, F) int32 sorted
    strictly increasing per row · comp: (C, Lc, F) BF16 (homogeneous Kahan
    — all chunks or none, unlike the dense mixed hybrid)."""
    values: jax.Array
    indices: jax.Array
    comp: Optional[jax.Array]


def _scatter_rows(values: jax.Array, idx: jax.Array, d: int) -> jax.Array:
    """Dtype-preserving row scatter: slot f of each row lands at dense
    column idx[..., f].  Iterated *select* — never add, which would turn
    a stored ``-0.0`` into ``+0.0`` and break bitwise parity."""
    out = jnp.zeros(values.shape[:-1] + (d,), values.dtype)
    iota = jax.lax.broadcasted_iota(jnp.int32, out.shape, out.ndim - 1)
    for f in range(values.shape[-1]):
        out = jnp.where(iota == idx[..., f:f + 1], values[..., f:f + 1], out)
    return out


def sparsify(cfg: ELMOHeadConfig, dense: HeadState) -> SparseHeadState:
    """Dense → sparse: keep the ``fan_in`` largest-|w| columns per row
    (ties break to the lowest column — ``lax.top_k`` is stable), then
    order the kept slots by ascending column id.  At ``fan_in == d_model``
    this selects every column and the indices are exactly the identity —
    the dense-parity anchor."""
    F = cfg.fan_in
    assert F > 0, "sparsify needs a sparse config (fan_in > 0)"
    w = dense.w
    score = jnp.abs(w.astype(jnp.float32))
    _, slots = jax.lax.top_k(score, F)               # (C, lc, F) descending
    idx = jnp.sort(slots.astype(jnp.int32), axis=-1)
    values = jnp.take_along_axis(w, idx, axis=-1)
    comp = None
    if cfg.kahan_chunks:
        assert cfg.kahan_chunks == cfg.num_chunks
        if dense.comp is not None and dense.comp.shape[0] == cfg.num_chunks:
            comp = jnp.take_along_axis(dense.comp, idx, axis=-1)
        else:
            comp = jnp.zeros(values.shape, P.BF16)
    return SparseHeadState(values, idx, comp)


def densify(cfg: ELMOHeadConfig, state: SparseHeadState) -> HeadState:
    """Sparse → dense oracle: scatter the value (and comp) slots back into
    (C, lc, D) zeros.  ``densify(sparsify(s))`` reproduces exactly the
    kept columns; at ``fan_in == d_model`` it is the identity bit-for-bit."""
    w = _scatter_rows(state.values, state.indices, cfg.d_model)
    comp = (_scatter_rows(state.comp, state.indices, cfg.d_model)
            if state.comp is not None else None)
    return HeadState(w, comp)


def init_sparse_head(key: jax.Array, cfg: ELMOHeadConfig,
                     scale: float | None = None) -> SparseHeadState:
    """Seeded sparse init: draw the dense init and keep the top-|w| slots
    per row — deterministic in (key, cfg), and identical to the dense init
    at ``fan_in == d_model``.  (Materializes the dense draw once; a
    direct chunk-streamed sparse init is a future-scale follow-up.)"""
    return sparsify(cfg, init_head(key, cfg, scale))


def indices_strictly_increasing(state: SparseHeadState) -> bool:
    """Check the sorted-unique index invariant (test/debug helper)."""
    import numpy as np
    idx = np.asarray(state.indices)
    return bool((np.diff(idx, axis=-1) > 0).all())
