"""Prune/regrow controller for the fixed-fan-in sparse head (DESIGN.md §13).

Every ``cfg.prune_every`` steps each label row swaps its ``n_swap =
round(fan_in · regrow_frac)`` lowest-|value| slots for the ``n_swap``
highest-|gradient| dense columns it does not already hold (HASTE-style
magnitude-prune + gradient-signal regrow).  Regrown slots start at value
zero (comp zero), so the step after a swap grows them from the live
gradient.

Determinism is the whole design: the controller is a **pure function of
(state, x, targets)** — the gradient probe runs the *expected* forward
(DropConnect off, no SR; ranking by |E[dW]| needs no stochastic draw),
and every selection is a stable ``lax.top_k`` / ``argsort`` (ties break
to the lowest slot / lowest column).  Replay across checkpoint resume
(§10) therefore follows from nothing but raw-bit checkpointing of
values/indices/comp: restore, feed the same batch, and the same swap
happens — there is no controller RNG stream to restore.

The fan-in count is exact by construction: kept and regrown slots are
disjoint (regrow candidates mask out kept columns), their union is
re-sorted ascending, so the sorted-strictly-increasing index invariant
is maintained.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import losses as L
from repro.head.config import ELMOHeadConfig
from repro.head.sparse.state import SparseHeadState
from repro.kernels import ref as REF


def n_swap_of(cfg: ELMOHeadConfig) -> int:
    return max(1, int(round(cfg.fan_in * cfg.regrow_frac)))


def prune_regrow(cfg: ELMOHeadConfig, state: SparseHeadState, x: jax.Array,
                 targets: jax.Array) -> SparseHeadState:
    """One deterministic prune/regrow pass against batch (x, targets)."""
    assert cfg.fan_in > 0
    x16 = x.astype(jnp.bfloat16)
    B = x16.shape[0]
    kahan = state.comp is not None
    n_sw = n_swap_of(cfg)
    n_keep = cfg.fan_in - n_sw
    cids = jnp.arange(cfg.num_chunks, dtype=jnp.int32)
    base = cids * cfg.chunk

    if cfg.loss == "bce":
        scale, lse = jnp.float32(1.0 / B), None
    else:
        n_tok = jnp.maximum((targets >= 0).sum(), 1).astype(jnp.float32)
        scale = 1.0 / n_tok

        def lse_body(carry, inp):
            vals_c, idx_c, b0 = inp
            m, s = carry
            return REF.sparse_lse_chunk_ref(
                x16, vals_c, idx_c, m, s, b0, None,
                num_labels=cfg.num_labels, quantize_x=cfg.qx,
                drop_rate=0.0), None

        (m, s), _ = jax.lax.scan(lse_body, L.lse_init(B),
                                 (state.values, state.indices, base))
        lse = L.lse_finalize(m, s)

    def body(_, inp):
        if kahan:
            vals_c, idx_c, comp_c, b0 = inp
        else:
            vals_c, idx_c, b0 = inp
            comp_c = None
        # |E[dW]| gradient probe on the densified chunk (dropless forward)
        w16 = REF.sparse_densify(vals_c, idx_c, cfg.d_model)
        z = REF.fp8_logits_ref(x16, w16, None, drop_rate=0.0,
                               quantize_x=cfg.qx)
        g, _ = L.chunk_loss_skip_grad(cfg.loss, z, targets, b0,
                                      vals_c.shape[0], cfg.num_labels, lse,
                                      scale, False)
        dw_abs = jnp.abs(jax.lax.dot_general(
            g.astype(jnp.bfloat16), x16, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32))          # (lc, D)

        # prune in the slot domain: keep the n_keep largest |value| slots
        # (stable top_k → equal magnitudes keep the lower slot)
        _, keep_slots = jax.lax.top_k(
            jnp.abs(vals_c.astype(jnp.float32)), n_keep)
        kept_idx = jnp.take_along_axis(idx_c, keep_slots, axis=-1)
        kept_val = jnp.take_along_axis(vals_c, keep_slots, axis=-1)

        # regrow in the dense domain: largest |dW| among columns not kept
        kept_mask = REF.sparse_densify(
            jnp.ones(kept_idx.shape, jnp.bfloat16), kept_idx,
            cfg.d_model) > 0
        cand = jnp.where(kept_mask, L.NEG_INF, dw_abs)
        _, regrow_idx = jax.lax.top_k(cand, n_sw)

        new_idx = jnp.concatenate(
            [kept_idx, regrow_idx.astype(jnp.int32)], axis=-1)
        order = jnp.argsort(new_idx, axis=-1, stable=True)
        new_idx = jnp.take_along_axis(new_idx, order, axis=-1)
        new_val = jnp.take_along_axis(
            jnp.concatenate(
                [kept_val, jnp.zeros(regrow_idx.shape, vals_c.dtype)],
                axis=-1), order, axis=-1)
        ys = (new_val, new_idx)
        if kahan:
            kept_comp = jnp.take_along_axis(comp_c, keep_slots, axis=-1)
            new_comp = jnp.take_along_axis(
                jnp.concatenate(
                    [kept_comp, jnp.zeros(regrow_idx.shape, comp_c.dtype)],
                    axis=-1), order, axis=-1)
            ys += (new_comp,)
        return None, ys

    xs = ((state.values, state.indices, state.comp, base) if kahan
          else (state.values, state.indices, base))
    _, ys = jax.lax.scan(body, None, xs)
    return SparseHeadState(ys[0], ys[1], ys[2] if kahan else None)


def maybe_prune_regrow(cfg: ELMOHeadConfig, state: SparseHeadState,
                       x: jax.Array, targets: jax.Array,
                       step: jax.Array) -> SparseHeadState:
    """Apply prune/regrow when ``step`` hits the cadence (step > 0 and
    step % prune_every == 0); identity otherwise.  jit-safe: ``step`` may
    be traced."""
    if not cfg.prune_every:
        return state
    step = jnp.asarray(step, jnp.int32)
    do = (step > 0) & (step % cfg.prune_every == 0)
    return jax.lax.cond(do,
                        lambda s: prune_regrow(cfg, s, x, targets),
                        lambda s: s, state)
