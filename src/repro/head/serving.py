"""ELMO head inference: full logits, top-k, P@k/PSP@k — single-device and
label-sharded, plan-driven (DESIGN.md §6/§7/§8/§9).

Top-k serving has four plan-resolved paths (``HeadPlan.topk_path``).
Three are exact and bit-identical in values AND ids: the streaming
megakernel (ONE Pallas launch, (B, k) carry in VMEM scratch, O(B·k)
transients for any label count — ``kernels/fused_topk.py``), the
materialized fast path (one logits launch + one stable ``top_k``, under
``plan._TOPK_Z_BYTES``), and the per-chunk streaming scan (also the
xla-oracle / non-TPU production path).  The fourth, ``"shortlist"``
(DESIGN.md §11), is 2-stage PLT-style serving: a centroid beam routes
each query to a few clusters and the restricted kernel/scan serves
exactly those — bit-identical to the exact top-k restricted to the
admitted labels, with recall@k quantifying what the beam excludes.  The
``HeadPlan`` resolves the path once per (config, batch, mesh); the
planned functions here execute without re-deriving anything.
Bit-parity contracts (tie-breaks, padded-id sentinels, sharded merge
order) are unchanged from the free-function era and pinned by
tests/test_fused_head.py, tests/test_fused_topk.py and the multi-device
suite.  Serving applies NO DropConnect by default (the historical fixed
seed-0 eval mask is behind ``cfg.compat_eval_drop``).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from repro.core import losses as L
from repro.head import plan as _plan
from repro.head.config import ELMOHeadConfig
from repro.head.state import HeadState, _resolve_ctx
from repro.kernels import ops


def _eval_seeds(cfg: ELMOHeadConfig) -> jax.Array:
    """The chunk-scan serving paths draw every chunk's DropConnect mask
    from the constant seed 0; the grid kernels reproduce that exactly.
    Only consulted when ``cfg.compat_eval_drop`` re-enables eval-time
    DropConnect — the default serving path is dense (drop_rate 0)."""
    return jnp.zeros((cfg.num_chunks,), jnp.uint32)


def _serve_drop(cfg: ELMOHeadConfig) -> float:
    """Serving DropConnect rate: 0 (dense weights — standard DropConnect
    eval) unless ``cfg.compat_eval_drop`` asks for the historical fixed
    seed-0 mask (pre-ISSUE-5 bit-parity goldens)."""
    return cfg.drop_rate if cfg.compat_eval_drop else 0.0


def _serve_chunk_logits(cfg: ELMOHeadConfig, wc: jax.Array, x: jax.Array,
                        impl: str) -> jax.Array:
    """One chunk of serving logits — the train-path op sequence with the
    *serving* DropConnect policy (``_serve_drop``) instead of the train
    rate."""
    return ops.fp8_logits(x, wc, jnp.uint32(0), drop_rate=_serve_drop(cfg),
                          quantize_x=cfg.qx, impl=impl)


# ---------------------------------------------------------------------------
# logits
# ---------------------------------------------------------------------------


def logits_planned(plan: "_plan.HeadPlan", cfg: ELMOHeadConfig,
                   state: HeadState, x: jax.Array) -> jax.Array:
    """Full (B, L) logits — O(B·L) memory; eval/serve at modest B only.

    On the grid path this is ONE Pallas launch over every label block
    (``kernels/fused_head.fused_head_logits``) instead of one per chunk;
    the per-column op sequence is unchanged, so values are bit-equal."""
    x = x.astype(jnp.bfloat16)
    if plan.serve_grid:
        z = ops.fused_head_logits(x, state.w, _eval_seeds(cfg),
                                  quantize_x=cfg.qx,
                                  drop_rate=_serve_drop(cfg),
                                  impl=plan.inner)
        return z[:, :cfg.num_labels]

    def body(_, inp):
        wc, cidx = inp
        z = _serve_chunk_logits(cfg, wc, x, plan.inner)
        return None, z

    _, zs = jax.lax.scan(
        body, None, (state.w, jnp.arange(cfg.num_chunks, dtype=jnp.int32)))
    z = jnp.moveaxis(zs, 0, 1).reshape(x.shape[0], cfg.padded_labels)
    return z[:, :cfg.num_labels]


def head_logits(cfg: ELMOHeadConfig, state: HeadState, x: jax.Array
                ) -> jax.Array:
    """Deprecated free-function form of ``ELMOHead.logits``."""
    plan = _plan.resolve_plan(cfg, batch=x.shape[0])
    return logits_planned(plan, cfg, state, x)


# ---------------------------------------------------------------------------
# top-k
# ---------------------------------------------------------------------------


def _topk_scan(cfg: ELMOHeadConfig, w: jax.Array, x: jax.Array, k: int,
               width: int, c0_of, impl: str
               ) -> Tuple[jax.Array, jax.Array]:
    """Streaming top-k over chunk slices of ``width`` label columns whose
    global offset is ``c0_of(cidx)`` — never materializes full logits.

    The single scan shared by the local and sharded serving paths; the
    carry init and the merge/tie-break body live in ``kernels.ref``
    (``topk_carry_init`` / ``topk_merge``) — ONE home for the contract
    that the oracle, this scan, and the Pallas megakernel all share."""
    from repro.kernels import ref as _ref

    def body(carry, inp):
        wc, cidx = inp
        c0 = c0_of(cidx)
        z = _serve_chunk_logits(cfg, wc, x, impl)
        cols = c0 + jnp.arange(width)
        return _ref.topk_merge(*carry, z, cols, k, cfg.num_labels), None

    (vals, idx), _ = jax.lax.scan(
        body, _ref.topk_carry_init(x.shape[0], k),
        (w, jnp.arange(cfg.num_chunks, dtype=jnp.int32)))
    return vals, idx


def _topk_materialized(z: jax.Array, col_ids: jax.Array, num_labels: int,
                       k: int) -> Tuple[jax.Array, jax.Array]:
    """Top-k over single-launch logits, reproducing ``_topk_scan``'s
    tie-break contract exactly: ``col_ids`` must be in the scan's visit
    order (ascending label id), padded ids (≥ num_labels) are masked to
    NEG_INF, and k NEG_INF sentinel candidates with id 0 — the scan's
    initial carry — precede the label columns, so overflow slots surface
    (NEG_INF, 0) and ties at equal logits resolve to the earliest (lowest
    label id) candidate; ``lax.top_k`` is stable, which seals the match."""
    B, W = z.shape
    zm = jnp.where((col_ids < num_labels)[None, :], z.astype(jnp.float32),
                   L.NEG_INF)
    cand = jnp.concatenate(
        [jnp.full((B, k), L.NEG_INF, jnp.float32), zm], axis=1)
    cand_ids = jnp.concatenate(
        [jnp.zeros((B, k), jnp.int32), jnp.broadcast_to(col_ids, (B, W))],
        axis=1)
    vals, local = jax.lax.top_k(cand, k)
    return vals, jnp.take_along_axis(cand_ids, local, axis=1)


def _chunk_base(cfg: ELMOHeadConfig) -> jax.Array:
    """(C,) int32 global label id of each chunk's local row 0 — the
    ``base`` operand of the streaming top-k megakernel."""
    return jnp.arange(cfg.num_chunks, dtype=jnp.int32) * cfg.chunk


def _topk_exec_path(plan: "_plan.HeadPlan", cfg: ELMOHeadConfig,
                    B: int, k: int, shortlist=None) -> str:
    """``plan.topk_path``, re-gated at the query's ACTUAL k.

    The plan resolves serving before any query k exists, so its kernel
    viability check uses the nominal lane-tile k (≤ 128 shares the
    padded carry footprint).  A compiled launch at a much larger k grows
    the resident (B, K) carry past what the model validated — re-check
    here and fall back (all paths are bit-identical, so the downgrade is
    invisible in results).  Interpret/xla inners have no VMEM and keep
    the plan's choice.

    A "shortlist" plan additionally needs an attached ``ShortlistIndex``
    (``shortlist``); without one it downgrades to the exact path the
    shortlist replaced — a correctness-invisible fallback (the exact
    result is a superset of any restricted one)."""
    from repro.kernels import tuning as _tuning

    path = plan.topk_path
    if path == "shortlist" and shortlist is None:
        path = ("kernel" if (plan.requested_path == "grid"
                             and plan.rimpl in ("kernel", "interpret"))
                else "stream")
    if (path == "kernel" and plan.rimpl == "kernel"
            and not _tuning.fused_topk_viable(
                B, cfg.d_model, jnp.dtype(cfg.wdtype).itemsize, k)):
        lp = cfg.padded_labels // max(1, plan.model_size)
        if plan.serve_grid and B * lp * 2 <= _plan._TOPK_Z_BYTES:
            return "materialize"
        return "stream"
    return path


def _shortlist_impls(plan: "_plan.HeadPlan", cfg: ELMOHeadConfig,
                     B: int, k: int, beam: int) -> Tuple[str, str]:
    """(stage-1 impl, stage-2 impl) for shortlisted serving.

    Stage 2 runs the restricted streaming kernel when the exact kernel
    path would have been chosen AND the beam-resident VMEM model still
    fits at the query's actual k; otherwise the restricted chunk-scan
    oracle ("xla") — bit-identical by the differential-test contract.
    Stage 1 scores the tiny (C, D) centroid block and follows the same
    inner (its carry is beam-wide, so the nominal model always fits)."""
    from repro.kernels import tuning as _tuning

    kernelish = (plan.requested_path == "grid"
                 and plan.rimpl in ("kernel", "interpret"))
    if kernelish and (plan.rimpl != "kernel" or _tuning.fused_topk_viable(
            B, cfg.d_model, jnp.dtype(cfg.wdtype).itemsize, k,
            n_beam=beam)):
        return plan.inner, plan.inner
    return ("xla", "xla") if not kernelish else (plan.inner, "xla")


def topk_planned(plan: "_plan.HeadPlan", cfg: ELMOHeadConfig,
                 state: HeadState, x: jax.Array, k: int,
                 shortlist=None) -> Tuple[jax.Array, jax.Array]:
    """Top-k serving on the path the plan resolved (DESIGN.md §9) — the
    exact paths produce bit-identical values AND ids:

    * ``"kernel"``      — ONE Pallas launch, the (B, k) running top-k
      lives in VMEM scratch across every label block; O(B·k) transients
      for any label count (``kernels/fused_topk.py``).
    * ``"materialize"`` — one logits launch + one stable ``top_k`` over
      the full width (≤ ``plan._TOPK_Z_BYTES``; see ``_topk_materialized``).
    * ``"stream"``      — the per-chunk ``lax.scan`` (also the xla oracle
      and the non-TPU production path).
    * ``"shortlist"``   — 2-stage (DESIGN.md §11): centroid beam, then
      the restricted kernel/scan over admitted clusters only —
      bit-identical to the exact top-k RESTRICTED to the labels the beam
      admits (``ref.fused_topk_ref`` with the same assign/beam).
      Requires an attached ``ShortlistIndex``; downgrades to exact when
      ``shortlist`` is None."""
    x = x.astype(jnp.bfloat16)
    tpath = _topk_exec_path(plan, cfg, x.shape[0], k, shortlist)
    if tpath == "shortlist":
        from repro.head import shortlist as _sl
        beam_w = min(plan.shortlist_beam or shortlist.beam, shortlist.beam)
        impl1, impl2 = _shortlist_impls(plan, cfg, x.shape[0], k, beam_w)
        beam_ids = _sl.stage1_clusters(
            shortlist.centroids, x, n_clusters=shortlist.n_clusters,
            beam=beam_w, impl=impl1)
        return ops.fused_topk(x, state.w, _eval_seeds(cfg),
                              _chunk_base(cfg), k=k,
                              num_labels=cfg.num_labels, quantize_x=cfg.qx,
                              drop_rate=_serve_drop(cfg), impl=impl2,
                              assign=shortlist.assign, beam=beam_ids)
    if tpath == "kernel":
        return ops.fused_topk(x, state.w, _eval_seeds(cfg),
                              _chunk_base(cfg), k=k,
                              num_labels=cfg.num_labels, quantize_x=cfg.qx,
                              drop_rate=_serve_drop(cfg), impl=plan.inner)
    if tpath == "materialize":
        z = ops.fused_head_logits(x, state.w, _eval_seeds(cfg),
                                  quantize_x=cfg.qx,
                                  drop_rate=_serve_drop(cfg),
                                  impl=plan.inner)
        return _topk_materialized(z, jnp.arange(cfg.padded_labels),
                                  cfg.num_labels, k)
    return _topk_scan(cfg, state.w, x, k, cfg.chunk,
                      lambda cidx: cidx * cfg.chunk, plan.inner)


def head_topk(cfg: ELMOHeadConfig, state: HeadState, x: jax.Array, k: int,
              shortlist=None) -> Tuple[jax.Array, jax.Array]:
    """Deprecated free-function form of ``ELMOHead.topk``."""
    plan = _plan.resolve_plan(cfg, batch=x.shape[0])
    return topk_planned(plan, cfg, state, x, k, shortlist)


# ---------------------------------------------------------------------------
# sharded serving (DESIGN.md §6)
# ---------------------------------------------------------------------------


def logits_sharded_planned(plan: "_plan.HeadPlan", cfg: ELMOHeadConfig,
                           ctx, state: HeadState, x: jax.Array) -> jax.Array:
    """``logits_planned`` with W label-sharded over the mesh's model axis.

    Each rank computes its (B, C·chunk/n) logit columns; one BF16
    ``all_gather`` per chunk restores the global column order — the op
    sequence per column matches the local path, so values are bit-equal."""
    from repro.dist.compat import shard_map as _shard_map

    if not plan.sharded:
        return logits_planned(plan, cfg, state, x)
    axis = ctx.model_axis
    x = x.astype(jnp.bfloat16)
    lc = plan.lc
    grid, inner = plan.serve_grid, plan.inner

    def body(w, x):
        B = x.shape[0]
        if grid:
            # one launch for every local label block, then one chunk-tiled
            # gather — same per-column values as the per-chunk scan
            zl = ops.fused_head_logits(x, w, _eval_seeds(cfg),
                                       quantize_x=cfg.qx,
                                       drop_rate=_serve_drop(cfg),
                                       impl=inner)
            z3 = jnp.moveaxis(zl.reshape(B, cfg.num_chunks, lc), 1, 0)
            zs = jax.lax.all_gather(z3, axis, axis=2, tiled=True)
        else:
            def scan_body(_, inp):
                wc, cidx = inp
                zc = _serve_chunk_logits(cfg, wc, x, inner)
                return None, jax.lax.all_gather(zc, axis, axis=1, tiled=True)

            _, zs = jax.lax.scan(
                scan_body, None,
                (w, jnp.arange(cfg.num_chunks, dtype=jnp.int32)))
        return jnp.moveaxis(zs, 0, 1).reshape(B, cfg.padded_labels)

    z = _shard_map(body, mesh=ctx.mesh,
                   in_specs=(plan.w_spec, PS()),
                   out_specs=PS(), check_vma=False)(state.w, x)
    return z[:, :cfg.num_labels]


def head_logits_sharded(cfg: ELMOHeadConfig, state: HeadState, x: jax.Array,
                        ctx=None) -> jax.Array:
    """Deprecated free-function form of ``ELMOHead.logits`` (sharded)."""
    ctx, n = _resolve_ctx(ctx)
    plan = _plan.resolve_plan(
        cfg, batch=x.shape[0], model_size=n,
        model_axis=None if ctx is None else ctx.model_axis)
    return logits_sharded_planned(plan, cfg, ctx, state, x)


def topk_sharded_planned(plan: "_plan.HeadPlan", cfg: ELMOHeadConfig,
                         ctx, state: HeadState, x: jax.Array, k: int,
                         shortlist=None) -> Tuple[jax.Array, jax.Array]:
    """``topk_planned`` with W label-sharded: local streaming top-k per
    rank, gather of the n·k candidates, global re-rank (DESIGN.md §6).

    Comm is O(B·k·n) instead of O(B·L); padded label columns are masked on
    the *local* column window so they can never surface, and ids are
    global.

    Shortlisted serving (DESIGN.md §11) needs no extra communication:
    the centroids and x are replicated, so every rank computes the SAME
    per-query beam locally, slices its own (C, lc) window of the cluster
    assignment, and restricts its local top-k to the admitted labels it
    owns.  A rank owning none of a query's admitted labels contributes k
    (NEG_INF, 0) sentinels, which the (−value, id) re-rank sorts behind
    every real candidate — so the merged result is bit-identical to
    single-device shortlisted serving."""
    from repro.dist.compat import shard_map as _shard_map

    if not plan.sharded:
        return topk_planned(plan, cfg, state, x, k, shortlist)
    axis = ctx.model_axis
    lc = plan.lc
    n = plan.model_size
    x = x.astype(jnp.bfloat16)
    tpath = _topk_exec_path(plan, cfg, x.shape[0], k, shortlist)
    inner = plan.inner
    sl_ops, sl_specs = (), ()
    if tpath == "shortlist":
        beam_w = min(plan.shortlist_beam or shortlist.beam, shortlist.beam)
        impl1, impl2 = _shortlist_impls(plan, cfg, x.shape[0], k, beam_w)
        n_clusters = shortlist.n_clusters
        sl_ops = (jnp.asarray(shortlist.centroids),
                  jnp.asarray(shortlist.assign))
        sl_specs = (PS(), PS())              # replicated index leaves

    def body(w, x, *sl):
        r = jax.lax.axis_index(axis).astype(jnp.int32)
        if tpath == "shortlist":
            from repro.head import shortlist as _sl
            cent, asg = sl
            # stage 1 locally per rank: replicated (centroids, x) make
            # every rank's beam identical without a collective
            beam_ids = _sl.stage1_clusters(cent, x, n_clusters=n_clusters,
                                           beam=beam_w, impl=impl1)
            # this rank's (C, lc) window of the cluster assignment: rank
            # r owns rows [r·lc, (r+1)·lc) of every chunk
            asg_local = jax.lax.dynamic_slice_in_dim(asg, r * lc, lc,
                                                     axis=1)
            base = _chunk_base(cfg) + r * lc
            vals, idx = ops.fused_topk(x, w, _eval_seeds(cfg), base, k=k,
                                       num_labels=cfg.num_labels,
                                       quantize_x=cfg.qx,
                                       drop_rate=_serve_drop(cfg),
                                       impl=impl2, assign=asg_local,
                                       beam=beam_ids)
        elif tpath == "kernel":
            # one streaming top-k launch over the LOCAL label blocks: the
            # kernel's visit order (chunk-major, then row) is ascending
            # global id for a fixed rank, so its tie-break contract
            # matches the local streaming scan's candidate for candidate
            base = _chunk_base(cfg) + r * lc
            vals, idx = ops.fused_topk(x, w, _eval_seeds(cfg), base, k=k,
                                       num_labels=cfg.num_labels,
                                       quantize_x=cfg.qx,
                                       drop_rate=_serve_drop(cfg),
                                       impl=inner)
        elif tpath == "materialize":
            # local candidates from one logits launch (same visit-order
            # argument as above for _topk_materialized's tie-break)
            zl = ops.fused_head_logits(x, w, _eval_seeds(cfg),
                                       quantize_x=cfg.qx,
                                       drop_rate=_serve_drop(cfg),
                                       impl=inner)
            cids = jnp.arange(cfg.num_chunks, dtype=jnp.int32)
            col_ids = ((cids * cfg.chunk + r * lc)[:, None]
                       + jnp.arange(lc, dtype=jnp.int32)[None, :]
                       ).reshape(-1)
            vals, idx = _topk_materialized(zl, col_ids, cfg.num_labels, k)
        else:
            vals, idx = _topk_scan(cfg, w, x, k, lc,
                                   lambda cidx: cidx * cfg.chunk + r * lc,
                                   inner)
        # (n, B, k) candidates → (B, n·k) → global re-rank.  Sorting on
        # (−value, id) reproduces the streaming tie-break (equal logits
        # resolve to the lowest label id) so the merged ids match the
        # single-device output exactly, not just the values.
        vall = jax.lax.all_gather(vals, axis)
        idxl = jax.lax.all_gather(idx, axis)
        B = x.shape[0]
        vall = jnp.moveaxis(vall, 0, 1).reshape(B, n * k)
        idxl = jnp.moveaxis(idxl, 0, 1).reshape(B, n * k)
        nv, ids = jax.lax.sort((-vall, idxl), dimension=1, num_keys=2)
        return -nv[:, :k], ids[:, :k]

    return _shard_map(body, mesh=ctx.mesh,
                      in_specs=(plan.w_spec, PS()) + sl_specs,
                      out_specs=(PS(), PS()),
                      check_vma=False)(state.w, x, *sl_ops)


def head_topk_sharded(cfg: ELMOHeadConfig, state: HeadState, x: jax.Array,
                      k: int, ctx=None, shortlist=None
                      ) -> Tuple[jax.Array, jax.Array]:
    """Deprecated free-function form of ``ELMOHead.topk`` (sharded)."""
    ctx, n = _resolve_ctx(ctx)
    plan = _plan.resolve_plan(
        cfg, batch=x.shape[0], model_size=n,
        model_axis=None if ctx is None else ctx.model_axis)
    return topk_sharded_planned(plan, cfg, ctx, state, x, k, shortlist)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def _real_preds(vals: jax.Array, pred: jax.Array) -> jax.Array:
    """(B, k) predicted ids with overflow sentinel slots masked to -1.

    When k exceeds the valid candidate count, top-k overflow slots
    surface the scan's (NEG_INF, id 0) sentinels — id 0 there is a
    placeholder, not a prediction, and must not score a hit against a
    genuine label 0 (it would double-count and could push P@k past 1).
    Real logits are bf16-finite, so value ≤ NEG_INF/2 identifies a
    sentinel exactly; -1 can never match a valid label id."""
    return jnp.where(vals > L.NEG_INF / 2, pred, -1)


def _p_at_k(vals: jax.Array, pred: jax.Array, label_ids: jax.Array, k: int,
            denom: str) -> jax.Array:
    """P@k from (B, k) top-k values/ids and (B, P) padded label ids.

    ``denom`` selects the denominator convention (both are published XMC
    practice; the difference only shows on rows with fewer than k
    positives):

    * ``"positives"`` — divide each row's hit count by min(k, #positives)
      (and skip all-padding rows): a row with 2 positives and both in the
      top-5 scores 1.0, not 2/5.  The default: rows can all reach 1.0.
    * ``"k"`` — the strict P@k of the XMC leaderboards: always divide by
      k, so rows with < k positives can never reach 1.0.

    For the tail-weighted variant use ``psp_at_k`` (paper eq. 3), which
    takes Jain et al. propensities from ``losses.propensity_scores``."""
    assert denom in ("positives", "k"), denom
    pred = _real_preds(vals, pred)
    hits = (pred[:, :, None] == label_ids[:, None, :]) \
        & (label_ids >= 0)[:, None, :]
    hit = hits.any(-1).sum(-1).astype(jnp.float32)        # (B,)
    if denom == "k":
        return (hit / k).mean()
    npos = (label_ids >= 0).sum(-1).astype(jnp.float32)   # (B,)
    per = hit / jnp.maximum(jnp.minimum(npos, float(k)), 1.0)
    rows = (npos > 0).astype(jnp.float32)
    return (per * rows).sum() / jnp.maximum(rows.sum(), 1.0)


def precision_at_k_planned(plan: "_plan.HeadPlan", cfg: ELMOHeadConfig,
                           ctx, state: HeadState, x: jax.Array,
                           label_ids: jax.Array, k: int,
                           denom: str = "positives",
                           shortlist=None) -> jax.Array:
    """P@k for multi-label targets (paper's headline metric)."""
    vals, pred = topk_sharded_planned(plan, cfg, ctx, state, x, k,
                                      shortlist)
    return _p_at_k(vals, pred, label_ids, k, denom)


def precision_at_k(cfg: ELMOHeadConfig, state: HeadState, x: jax.Array,
                   label_ids: jax.Array, k: int,
                   denom: str = "positives") -> jax.Array:
    """Deprecated free-function form of ``ELMOHead.precision_at_k``
    (local top-k, as historically)."""
    plan = _plan.resolve_plan(cfg, batch=x.shape[0])
    vals, pred = topk_planned(plan, cfg, state, x, k)
    return _p_at_k(vals, pred, label_ids, k, denom)


def psp_at_k_planned(plan: "_plan.HeadPlan", cfg: ELMOHeadConfig, ctx,
                     state: HeadState, x: jax.Array, label_ids: jax.Array,
                     propensity: jax.Array, k: int,
                     shortlist=None) -> jax.Array:
    """Propensity-scored P@k (paper eq. 3) over the served top-k: the
    psp-ready hook — ``propensity`` comes from
    ``losses.propensity_scores(label_freq)``."""
    vals, pred = topk_sharded_planned(plan, cfg, ctx, state, x, k,
                                      shortlist)
    return L.psp_at_k(_real_preds(vals, pred), label_ids, propensity, k)
