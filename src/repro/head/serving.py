"""ELMO head inference: full logits, streaming/materialized top-k, P@k —
single-device and label-sharded, plan-driven (DESIGN.md §6/§7/§8).

The serving grid kernel (one launch for every label block) and the
materialized-top-k fast path are *decisions*, not call-site branches: the
``HeadPlan`` resolves them once per (config, batch, mesh) and the planned
functions here execute without re-deriving anything.  Bit-parity contracts
(tie-breaks, padded-id sentinels, sharded merge order) are unchanged from
the free-function era and pinned by tests/test_fused_head.py and the
multi-device suite.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from repro.core import losses as L
from repro.head import plan as _plan
from repro.head.config import ELMOHeadConfig
from repro.head.state import HeadState, _resolve_ctx
from repro.head.train import _chunk_logits
from repro.kernels import ops


def _eval_seeds(cfg: ELMOHeadConfig) -> jax.Array:
    """The chunk-scan serving paths draw every chunk's DropConnect mask
    from the constant seed 0; the grid kernel reproduces that exactly."""
    return jnp.zeros((cfg.num_chunks,), jnp.uint32)


# ---------------------------------------------------------------------------
# logits
# ---------------------------------------------------------------------------


def logits_planned(plan: "_plan.HeadPlan", cfg: ELMOHeadConfig,
                   state: HeadState, x: jax.Array) -> jax.Array:
    """Full (B, L) logits — O(B·L) memory; eval/serve at modest B only.

    On the grid path this is ONE Pallas launch over every label block
    (``kernels/fused_head.fused_head_logits``) instead of one per chunk;
    the per-column op sequence is unchanged, so values are bit-equal."""
    x = x.astype(jnp.bfloat16)
    if plan.serve_grid:
        z = ops.fused_head_logits(x, state.w, _eval_seeds(cfg),
                                  quantize_x=cfg.qx,
                                  drop_rate=cfg.drop_rate, impl=plan.inner)
        return z[:, :cfg.num_labels]

    def body(_, inp):
        wc, cidx = inp
        z = _chunk_logits(cfg, wc, x, jnp.uint32(0),
                          plan.inner)  # no dropout at eval
        return None, z

    _, zs = jax.lax.scan(
        body, None, (state.w, jnp.arange(cfg.num_chunks, dtype=jnp.int32)))
    z = jnp.moveaxis(zs, 0, 1).reshape(x.shape[0], cfg.padded_labels)
    return z[:, :cfg.num_labels]


def head_logits(cfg: ELMOHeadConfig, state: HeadState, x: jax.Array
                ) -> jax.Array:
    """Deprecated free-function form of ``ELMOHead.logits``."""
    plan = _plan.resolve_plan(cfg, batch=x.shape[0])
    return logits_planned(plan, cfg, state, x)


# ---------------------------------------------------------------------------
# top-k
# ---------------------------------------------------------------------------


def _topk_scan(cfg: ELMOHeadConfig, w: jax.Array, x: jax.Array, k: int,
               width: int, c0_of, impl: str
               ) -> Tuple[jax.Array, jax.Array]:
    """Streaming top-k over chunk slices of ``width`` label columns whose
    global offset is ``c0_of(cidx)`` — never materializes full logits.

    The single scan shared by the local and sharded serving paths: ties at
    equal logits resolve to the earliest candidate (lowest label id), and
    padded columns (≥ num_labels) are masked to NEG_INF so they can never
    surface; the sharded merge's tie-break contract depends on this body
    living in exactly one place."""
    B = x.shape[0]

    def body(carry, inp):
        vals, idx = carry
        wc, cidx = inp
        c0 = c0_of(cidx)
        z = _chunk_logits(cfg, wc, x, jnp.uint32(0), impl)  # no drop at eval
        valid = (c0 + jnp.arange(width)) < cfg.num_labels
        z = jnp.where(valid[None, :], z.astype(jnp.float32), L.NEG_INF)
        cand = jnp.concatenate([vals, z], axis=1)
        cand_idx = jnp.concatenate(
            [idx, jnp.broadcast_to(c0 + jnp.arange(width), (B, width))],
            axis=1)
        v, local = jax.lax.top_k(cand, k)
        return (v, jnp.take_along_axis(cand_idx, local, axis=1)), None

    init = (jnp.full((B, k), L.NEG_INF, jnp.float32),
            jnp.zeros((B, k), jnp.int32))
    (vals, idx), _ = jax.lax.scan(
        body, init, (w, jnp.arange(cfg.num_chunks, dtype=jnp.int32)))
    return vals, idx


def _topk_materialized(z: jax.Array, col_ids: jax.Array, num_labels: int,
                       k: int) -> Tuple[jax.Array, jax.Array]:
    """Top-k over single-launch logits, reproducing ``_topk_scan``'s
    tie-break contract exactly: ``col_ids`` must be in the scan's visit
    order (ascending label id), padded ids (≥ num_labels) are masked to
    NEG_INF, and k NEG_INF sentinel candidates with id 0 — the scan's
    initial carry — precede the label columns, so overflow slots surface
    (NEG_INF, 0) and ties at equal logits resolve to the earliest (lowest
    label id) candidate; ``lax.top_k`` is stable, which seals the match."""
    B, W = z.shape
    zm = jnp.where((col_ids < num_labels)[None, :], z.astype(jnp.float32),
                   L.NEG_INF)
    cand = jnp.concatenate(
        [jnp.full((B, k), L.NEG_INF, jnp.float32), zm], axis=1)
    cand_ids = jnp.concatenate(
        [jnp.zeros((B, k), jnp.int32), jnp.broadcast_to(col_ids, (B, W))],
        axis=1)
    vals, local = jax.lax.top_k(cand, k)
    return vals, jnp.take_along_axis(cand_ids, local, axis=1)


def topk_planned(plan: "_plan.HeadPlan", cfg: ELMOHeadConfig,
                 state: HeadState, x: jax.Array, k: int
                 ) -> Tuple[jax.Array, jax.Array]:
    """Streaming top-k over chunks — never materializes full logits —
    unless the plan chose the single-launch materialized fast path
    (bit-identical values *and* ids; see ``_topk_materialized``)."""
    x = x.astype(jnp.bfloat16)
    if plan.topk_materialize:
        z = ops.fused_head_logits(x, state.w, _eval_seeds(cfg),
                                  quantize_x=cfg.qx,
                                  drop_rate=cfg.drop_rate, impl=plan.inner)
        return _topk_materialized(z, jnp.arange(cfg.padded_labels),
                                  cfg.num_labels, k)
    return _topk_scan(cfg, state.w, x, k, cfg.chunk,
                      lambda cidx: cidx * cfg.chunk, plan.inner)


def head_topk(cfg: ELMOHeadConfig, state: HeadState, x: jax.Array, k: int
              ) -> Tuple[jax.Array, jax.Array]:
    """Deprecated free-function form of ``ELMOHead.topk``."""
    plan = _plan.resolve_plan(cfg, batch=x.shape[0])
    return topk_planned(plan, cfg, state, x, k)


# ---------------------------------------------------------------------------
# sharded serving (DESIGN.md §6)
# ---------------------------------------------------------------------------


def logits_sharded_planned(plan: "_plan.HeadPlan", cfg: ELMOHeadConfig,
                           ctx, state: HeadState, x: jax.Array) -> jax.Array:
    """``logits_planned`` with W label-sharded over the mesh's model axis.

    Each rank computes its (B, C·chunk/n) logit columns; one BF16
    ``all_gather`` per chunk restores the global column order — the op
    sequence per column matches the local path, so values are bit-equal."""
    from repro.dist.compat import shard_map as _shard_map

    if not plan.sharded:
        return logits_planned(plan, cfg, state, x)
    axis = ctx.model_axis
    x = x.astype(jnp.bfloat16)
    lc = plan.lc
    grid, inner = plan.serve_grid, plan.inner

    def body(w, x):
        B = x.shape[0]
        if grid:
            # one launch for every local label block, then one chunk-tiled
            # gather — same per-column values as the per-chunk scan
            zl = ops.fused_head_logits(x, w, _eval_seeds(cfg),
                                       quantize_x=cfg.qx,
                                       drop_rate=cfg.drop_rate, impl=inner)
            z3 = jnp.moveaxis(zl.reshape(B, cfg.num_chunks, lc), 1, 0)
            zs = jax.lax.all_gather(z3, axis, axis=2, tiled=True)
        else:
            def scan_body(_, inp):
                wc, cidx = inp
                zc = _chunk_logits(cfg, wc, x, jnp.uint32(0), inner)
                return None, jax.lax.all_gather(zc, axis, axis=1, tiled=True)

            _, zs = jax.lax.scan(
                scan_body, None,
                (w, jnp.arange(cfg.num_chunks, dtype=jnp.int32)))
        return jnp.moveaxis(zs, 0, 1).reshape(B, cfg.padded_labels)

    z = _shard_map(body, mesh=ctx.mesh,
                   in_specs=(plan.w_spec, PS()),
                   out_specs=PS(), check_vma=False)(state.w, x)
    return z[:, :cfg.num_labels]


def head_logits_sharded(cfg: ELMOHeadConfig, state: HeadState, x: jax.Array,
                        ctx=None) -> jax.Array:
    """Deprecated free-function form of ``ELMOHead.logits`` (sharded)."""
    ctx, n = _resolve_ctx(ctx)
    plan = _plan.resolve_plan(
        cfg, batch=x.shape[0], model_size=n,
        model_axis=None if ctx is None else ctx.model_axis)
    return logits_sharded_planned(plan, cfg, ctx, state, x)


def topk_sharded_planned(plan: "_plan.HeadPlan", cfg: ELMOHeadConfig,
                         ctx, state: HeadState, x: jax.Array, k: int
                         ) -> Tuple[jax.Array, jax.Array]:
    """``topk_planned`` with W label-sharded: local streaming top-k per
    rank, gather of the n·k candidates, global re-rank (DESIGN.md §6).

    Comm is O(B·k·n) instead of O(B·L); padded label columns are masked on
    the *local* column window so they can never surface, and ids are
    global."""
    from repro.dist.compat import shard_map as _shard_map

    if not plan.sharded:
        return topk_planned(plan, cfg, state, x, k)
    axis = ctx.model_axis
    lc = plan.lc
    n = plan.model_size
    x = x.astype(jnp.bfloat16)
    grid, inner = plan.topk_materialize, plan.inner

    def body(w, x):
        r = jax.lax.axis_index(axis).astype(jnp.int32)
        if grid:
            # local candidates from one logits launch; the local column
            # visit order (chunk-major, then row) is ascending global id
            # for a fixed rank, so _topk_materialized's tie-break matches
            # the streaming scan's
            zl = ops.fused_head_logits(x, w, _eval_seeds(cfg),
                                       quantize_x=cfg.qx,
                                       drop_rate=cfg.drop_rate, impl=inner)
            cids = jnp.arange(cfg.num_chunks, dtype=jnp.int32)
            col_ids = ((cids * cfg.chunk + r * lc)[:, None]
                       + jnp.arange(lc, dtype=jnp.int32)[None, :]
                       ).reshape(-1)
            vals, idx = _topk_materialized(zl, col_ids, cfg.num_labels, k)
        else:
            vals, idx = _topk_scan(cfg, w, x, k, lc,
                                   lambda cidx: cidx * cfg.chunk + r * lc,
                                   inner)
        # (n, B, k) candidates → (B, n·k) → global re-rank.  Sorting on
        # (−value, id) reproduces the streaming tie-break (equal logits
        # resolve to the lowest label id) so the merged ids match the
        # single-device output exactly, not just the values.
        vall = jax.lax.all_gather(vals, axis)
        idxl = jax.lax.all_gather(idx, axis)
        B = x.shape[0]
        vall = jnp.moveaxis(vall, 0, 1).reshape(B, n * k)
        idxl = jnp.moveaxis(idxl, 0, 1).reshape(B, n * k)
        nv, ids = jax.lax.sort((-vall, idxl), dimension=1, num_keys=2)
        return -nv[:, :k], ids[:, :k]

    return _shard_map(body, mesh=ctx.mesh,
                      in_specs=(plan.w_spec, PS()),
                      out_specs=(PS(), PS()), check_vma=False)(state.w, x)


def head_topk_sharded(cfg: ELMOHeadConfig, state: HeadState, x: jax.Array,
                      k: int, ctx=None) -> Tuple[jax.Array, jax.Array]:
    """Deprecated free-function form of ``ELMOHead.topk`` (sharded)."""
    ctx, n = _resolve_ctx(ctx)
    plan = _plan.resolve_plan(
        cfg, batch=x.shape[0], model_size=n,
        model_axis=None if ctx is None else ctx.model_axis)
    return topk_sharded_planned(plan, cfg, ctx, state, x, k)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def precision_at_k_planned(plan: "_plan.HeadPlan", cfg: ELMOHeadConfig,
                           ctx, state: HeadState, x: jax.Array,
                           label_ids: jax.Array, k: int) -> jax.Array:
    """P@k for multi-label targets (paper's headline metric)."""
    _, pred = topk_sharded_planned(plan, cfg, ctx, state, x, k)
    hits = (pred[:, :, None] == label_ids[:, None, :]) \
        & (label_ids >= 0)[:, None, :]
    return hits.any(-1).sum(-1).astype(jnp.float32).mean() / k


def precision_at_k(cfg: ELMOHeadConfig, state: HeadState, x: jax.Array,
                   label_ids: jax.Array, k: int) -> jax.Array:
    """Deprecated free-function form of ``ELMOHead.precision_at_k``
    (local top-k, as historically)."""
    plan = _plan.resolve_plan(cfg, batch=x.shape[0])
    _, pred = topk_planned(plan, cfg, state, x, k)
    hits = (pred[:, :, None] == label_ids[:, None, :]) \
        & (label_ids >= 0)[:, None, :]
    return hits.any(-1).sum(-1).astype(jnp.float32).mean() / k
