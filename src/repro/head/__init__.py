"""``repro.head`` — the ELMO large-output-space head as one mesh-aware
object (DESIGN.md §8).

The paper's contribution is a *system* of residency/precision decisions:
chunked low-precision training whose viability depends on Kahan-vs-SR,
z-cache budgets, grid block sizes and label sharding.  This package makes
those decisions the product:

    from repro.head import ELMOHead, ELMOHeadConfig, HeadHparams

    cfg = ELMOHeadConfig(num_labels=3_000_000, d_model=768,
                         weight_dtype="e4m3")
    head = ELMOHead(cfg, batch=128, target_slots=40)   # plan resolved HERE
    print(head.plan.explain())                          # and inspectable

    state = head.init(jax.random.PRNGKey(0))
    state, x_grad, metrics = head.train_step(
        state, x, targets, HeadHparams(lr=0.05, wd=1e-4, seed=step))
    values, ids = head.topk(state, x, k=5)

``ELMOHead`` auto-dispatches single-device vs label-sharded from the
ambient (or explicit) ``MeshContext`` and grid/fused/unfused from a
``HeadPlan`` resolved ONCE at construction — no ``_impl_split`` /
``_grid_ok`` / ``_want_cache_z`` re-resolution inside traced step
functions.  The legacy free functions (``head_train_step`` & friends)
survive as deprecated wrappers that resolve the same plan per call, and
``repro.core.elmo_head`` re-exports them, so the historical surface is
bit-identical to the facade by construction.

Layering (import order is strictly downward):

    config.py         ELMOHeadConfig, HeadHparams, head_config_for
    state.py          HeadState, init_head, init_xg_err
    plan.py           HeadPlan, resolve_plan, the CI plan-stability CLI
    train.py          single-device planned step (+ legacy wrapper)
    train_sharded.py  label-sharded planned step (+ legacy wrapper)
    serving.py        logits / top-k / P@k, local + sharded (+ wrappers)
    shortlist.py      2-stage shortlisted serving index (DESIGN.md §11)
    convert.py        checkpoint re-typing, post-hoc refinement,
                      offline shortlist build
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax

from repro.head import plan as plan_mod
from repro.head import serving as _serving
from repro.head import train as _train
from repro.head import train_sharded as _train_sharded
from repro.head.config import (ELMOHeadConfig, HeadHparams,
                               default_target_slots, head_config_for)
from repro.head.convert import convert_head, posthoc_refine
from repro.head.plan import HeadPlan, resolve_plan
from repro.head.serving import (head_logits, head_logits_sharded, head_topk,
                                head_topk_sharded, precision_at_k)
from repro.head.shortlist import (ShortlistError, ShortlistIndex,
                                  build_shortlist_index,
                                  load_shortlist_index,
                                  save_shortlist_index,
                                  shortlist_clusters,
                                  shortlist_recall_at_k)
from repro.head.state import (HeadState, init_head, init_xg_err,
                              state_bits_equal)
from repro.head.train import head_train_step
from repro.head.train_sharded import head_train_step_sharded

__all__ = [
    "ELMOHead", "ELMOHeadConfig", "HeadHparams", "HeadPlan", "HeadState",
    "ShortlistError", "ShortlistIndex", "build_shortlist_index",
    "convert_head", "default_target_slots", "get_head", "head_config_for",
    "head_logits",
    "head_logits_sharded", "head_topk", "head_topk_sharded",
    "head_train_step", "head_train_step_sharded", "init_head",
    "init_xg_err", "load_shortlist_index", "posthoc_refine",
    "precision_at_k", "resolve_plan", "save_shortlist_index",
    "shortlist_clusters", "shortlist_recall_at_k", "state_bits_equal",
]

_AMBIENT = object()   # sentinel: "capture the ambient mesh at construction"


class ELMOHead:
    """The mesh-aware facade over the ELMO head (DESIGN.md §8).

    Construction resolves the ``HeadPlan`` exactly once for the declared
    ``batch`` / ``target_slots`` / mesh; every method then executes the
    planned path with zero per-call resolution.  Calls at *other* shapes
    re-plan through the memoized resolver (still trace-time Python, never
    traced ops) — declare the shapes you train at for strict
    once-per-construction behavior.

    ``ctx`` defaults to the ambient ``dist.meshctx`` at construction time;
    pass an explicit ``MeshContext`` (or ``None`` for single-device
    semantics under an active mesh) to pin it.
    """

    def __init__(self, cfg: ELMOHeadConfig, *, batch: int,
                 target_slots: Optional[int] = None, ctx=_AMBIENT,
                 ce_comm: str = "gather", compress_xg: bool = False):
        if ctx is _AMBIENT:
            from repro.dist import meshctx
            ctx = meshctx.get()
        self.cfg = cfg
        self.ctx = ctx
        self.ce_comm = ce_comm
        self.compress_xg = compress_xg
        if target_slots is None:
            target_slots = 1
        self._model_size = 1 if ctx is None else ctx.model_size
        self._model_axis = None if ctx is None else ctx.model_axis
        self._plans: dict = {}
        self._shortlist: "ShortlistIndex | None" = None
        self.plan: HeadPlan = self._resolve(batch, target_slots)
        self._plans[self._plan_key(batch, target_slots)] = self.plan

    def _resolve(self, batch: int, target_slots: int) -> HeadPlan:
        return plan_mod.resolve_plan(
            self.cfg, batch=batch, target_slots=target_slots,
            model_size=self._model_size, model_axis=self._model_axis,
            ce_comm=self.ce_comm)

    def _plan_key(self, batch: int, target_slots: int):
        # the mutable budgets and the backend are part of the key so an
        # instance can never serve a stale plan after they move (the same
        # invariant resolve_plan/get_head keep via their memo keys)
        return (batch, target_slots, plan_mod._CACHE_Z_BYTES,
                plan_mod._TOPK_Z_BYTES, jax.default_backend())

    def _plan_for(self, batch: int, target_slots: int = 1) -> HeadPlan:
        key = self._plan_key(batch, target_slots)
        p = self._plans.get(key)
        if p is None:   # undeclared shape (or moved knobs): re-plan once
            p = self._plans[key] = self._resolve(batch, target_slots)
        return p

    # ---- state ----

    def init(self, key: jax.Array, scale: float | None = None):
        """Seeded head state: dense ``HeadState`` — or, when the config
        declares a ``fan_in``, the fixed-fan-in ``SparseHeadState``
        (DESIGN.md §13); every facade method auto-dispatches on the
        planned path, so call sites never branch."""
        if self.cfg.fan_in:
            from repro.head import sparse as _sparse
            return _sparse.init_sparse_head(key, self.cfg, scale)
        return init_head(key, self.cfg, scale)

    def init_xg_err(self, batch: int) -> jax.Array:
        return init_xg_err(self.cfg, batch, self.ctx)

    # ---- training ----

    def train_step(self, state: HeadState, x: jax.Array, targets: jax.Array,
                   hp: HeadHparams, *, xg_err=None):
        """One fused fwd/loss-skip-grad/update pass over all label chunks;
        label-sharded over the mesh's model axis when the plan says so.
        Returns (new_state, x_grad, metrics)[, xg_err']."""
        plan = self._plan_for(x.shape[0], plan_mod._target_slots(targets))
        if plan.path == "sparse":
            from repro.head import sparse as _sparse
            if plan.sharded:
                out = _sparse.train_step_sparse_sharded(
                    plan, self.cfg, self.ctx, state, x, targets, hp.lr,
                    hp.wd, hp.seed, ce_comm=self.ce_comm)
            else:
                out = _sparse.train_step_sparse(plan, self.cfg, state, x,
                                                targets, hp.lr, hp.wd,
                                                hp.seed)
            return out if xg_err is None else out + (xg_err,)
        if plan.sharded:
            return _train_sharded.train_step_sharded_planned(
                plan, self.cfg, self.ctx, state, x, targets, hp.lr, hp.wd,
                hp.seed, ce_comm=self.ce_comm, compress_xg=self.compress_xg,
                xg_err=xg_err)
        out = _train.train_step_planned(plan, self.cfg, state, x, targets,
                                        hp.lr, hp.wd, hp.seed)
        return out if xg_err is None else out + (xg_err,)

    def maybe_prune_regrow(self, state, x: jax.Array, targets: jax.Array,
                           step: jax.Array):
        """Periodic deterministic prune/regrow of the sparse topology
        (no-op for dense heads or ``prune_every == 0``): every
        ``cfg.prune_every`` steps the smallest-|value| slots are pruned
        and the largest-|dW| dense columns regrown (DESIGN.md §13).
        ``step`` may be traced — dispatch is a ``lax.cond``."""
        if not (self.cfg.fan_in and self.cfg.prune_every):
            return state
        from repro.head import sparse as _sparse
        return _sparse.maybe_prune_regrow(self.cfg, state, x, targets, step)

    # ---- serving ----

    def logits(self, state, x: jax.Array) -> jax.Array:
        plan = self._plan_for(x.shape[0])
        if plan.path == "sparse":
            from repro.head import sparse as _sparse
            if plan.sharded:
                return _sparse.logits_sparse_sharded_planned(
                    plan, self.cfg, self.ctx, state, x)
            return _sparse.logits_sparse_planned(plan, self.cfg, state, x)
        if plan.sharded:
            return _serving.logits_sharded_planned(plan, self.cfg, self.ctx,
                                                   state, x)
        return _serving.logits_planned(plan, self.cfg, state, x)

    def topk(self, state: HeadState, x: jax.Array, k: int, *,
             shortlist=_AMBIENT) -> Tuple[jax.Array, jax.Array]:
        """Top-k on the planned path.  ``shortlist`` overrides the
        attached index for THIS call: pass an index to serve through it
        (e.g. a narrowed-beam copy on the degradation ladder), or None
        to force the exact path — the default serves whatever
        ``attach_shortlist`` installed."""
        if shortlist is _AMBIENT:
            shortlist = self._shortlist
        plan = self._plan_for(x.shape[0])
        if plan.path == "sparse":
            from repro.head import sparse as _sparse
            if plan.sharded:
                return _sparse.topk_sparse_sharded_planned(
                    plan, self.cfg, self.ctx, state, x, k)
            return _sparse.topk_sparse_planned(plan, self.cfg, state, x, k)
        if plan.sharded:
            return _serving.topk_sharded_planned(plan, self.cfg, self.ctx,
                                                 state, x, k, shortlist)
        return _serving.topk_planned(plan, self.cfg, state, x, k,
                                     shortlist)

    # ---- 2-stage shortlisted serving (DESIGN.md §11) ----

    @property
    def shortlist(self) -> "ShortlistIndex | None":
        return self._shortlist

    def attach_shortlist(self, index: "ShortlistIndex | None", *,
                         rebuild_if_stale: bool = False,
                         state: "HeadState | None" = None,
                         iters: int = 8, seed: int = 0
                         ) -> "ShortlistIndex | None":
        """Attach (or, with None, detach) a shortlist index.  Serving uses
        it only when the plan resolved ``topk_path == "shortlist"``; with
        no index attached a shortlist plan serves exact (the downgrade is
        result-invisible — the exact top-k is a superset).

        ``rebuild_if_stale=True`` (requires ``state``) checks the index's
        W-bits checksum against ``state`` (``shortlist.is_stale``): a
        stale index is *correct* but its measured recall no longer
        applies, so it is rebuilt here — same geometry, offline host
        build — with a ``UserWarning`` naming the rebuild.  Returns the
        index actually attached."""
        if rebuild_if_stale and index is not None:
            import warnings

            from repro.head import shortlist as _sl
            if state is None:
                raise ValueError(
                    "attach_shortlist(rebuild_if_stale=True) needs the "
                    "state the index must match")
            if _sl.is_stale(index, state):
                warnings.warn(
                    "shortlist index is stale for this state "
                    "(weights moved since the build) — rebuilding with "
                    f"n_clusters={index.n_clusters} beam={index.beam}",
                    UserWarning, stacklevel=2)
                index = build_shortlist_index(
                    self.cfg, state, n_clusters=index.n_clusters,
                    beam=index.beam, iters=iters, seed=seed)
        self._shortlist = index
        return index

    def build_shortlist(self, state: HeadState, *, iters: int = 8,
                        seed: int = 0, n_clusters: int | None = None,
                        beam: int | None = None) -> "ShortlistIndex":
        """Build (offline, host numpy) AND attach a shortlist index for
        ``state``, defaulting to the geometry the plan resolved
        (``shortlist_c``/``shortlist_beam``); see
        ``convert.build_shortlist`` for the checkpoint-facing entry."""
        if n_clusters is None and self.plan.shortlist_c:
            n_clusters = self.plan.shortlist_c
        if beam is None and self.plan.shortlist_beam:
            beam = self.plan.shortlist_beam
        index = build_shortlist_index(self.cfg, state,
                                      n_clusters=n_clusters, beam=beam,
                                      iters=iters, seed=seed)
        self._shortlist = index
        return index

    def precision_at_k(self, state: HeadState, x: jax.Array,
                       label_ids: jax.Array, k: int,
                       denom: str = "positives") -> jax.Array:
        """P@k over the served top-k.  ``denom="positives"`` (default)
        divides each row by min(k, #positives); ``denom="k"`` is the
        strict XMC-leaderboard convention (see ``serving._p_at_k``)."""
        plan = self._plan_for(x.shape[0])
        if plan.path == "sparse":
            from repro.head import sparse as _sparse
            return _sparse.precision_at_k_sparse_planned(
                plan, self.cfg, self.ctx, state, x, label_ids, k, denom)
        return _serving.precision_at_k_planned(plan, self.cfg, self.ctx,
                                               state, x, label_ids, k,
                                               denom, self._shortlist)

    def psp_at_k(self, state: HeadState, x: jax.Array,
                 label_ids: jax.Array, propensity: jax.Array,
                 k: int) -> jax.Array:
        """Propensity-scored P@k (paper eq. 3) over the served top-k;
        ``propensity`` from ``losses.propensity_scores``."""
        plan = self._plan_for(x.shape[0])
        if plan.path == "sparse":
            from repro.core import losses as _L
            from repro.head import sparse as _sparse
            vals, pred = _sparse.topk_sparse_sharded_planned(
                plan, self.cfg, self.ctx, state, x, k)
            return _L.psp_at_k(_serving._real_preds(vals, pred), label_ids,
                               propensity, k)
        return _serving.psp_at_k_planned(plan, self.cfg, self.ctx, state,
                                         x, label_ids, propensity, k,
                                         self._shortlist)

    # ---- conversion ----

    def convert_from(self, state: HeadState,
                     from_cfg: ELMOHeadConfig) -> HeadState:
        """Re-type ``state`` (trained under ``from_cfg``) to this head's
        precision (e.g. FP8 checkpoint → BF16 for post-hoc refinement)."""
        return convert_head(state, from_cfg, self.cfg)

    def posthoc_refine(self, state: HeadState, batches, steps: int,
                       lr: float, seed: int = 0) -> HeadState:
        return posthoc_refine(self.cfg, state, batches, steps, lr, seed)

    def __repr__(self) -> str:
        return (f"ELMOHead({self.cfg.num_labels}×{self.cfg.d_model}, "
                f"{self.cfg.weight_dtype}, {self.cfg.loss}, "
                f"path={self.plan.path}, model_size={self.plan.model_size})")


@functools.lru_cache(maxsize=256)
def _cached_head(cfg, batch, target_slots, ctx, ce_comm, compress_xg,
                 _cache_budget, _topk_budget, _backend) -> ELMOHead:
    return ELMOHead(cfg, batch=batch, target_slots=target_slots, ctx=ctx,
                    ce_comm=ce_comm, compress_xg=compress_xg)


def get_head(cfg: ELMOHeadConfig, *, batch: int, target_slots: int = 1,
             ctx=_AMBIENT, ce_comm: str = "gather",
             compress_xg: bool = False) -> ELMOHead:
    """Memoized facade factory: one ``ELMOHead`` (and so one plan
    resolution) per distinct (config, shape, mesh, comm) — what hot call
    sites like ``launch.steps`` use so repeated traces never re-plan.
    The cache key includes the mutable byte budgets and the backend, so a
    cached head can never carry a stale plan."""
    if ctx is _AMBIENT:
        from repro.dist import meshctx
        ctx = meshctx.get()
    return _cached_head(cfg, batch, target_slots, ctx, ce_comm, compress_xg,
                        plan_mod._CACHE_Z_BYTES, plan_mod._TOPK_Z_BYTES,
                        jax.default_backend())
