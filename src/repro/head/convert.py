"""Checkpoint re-typing + post-hoc classifier refinement (paper App. D.1),
and the offline shortlist-index build (DESIGN.md §11)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import precision as P
from repro.head.config import ELMOHeadConfig
from repro.head.state import HeadState
from repro.head.train import head_train_step


def convert_head(state: HeadState, from_cfg: ELMOHeadConfig,
                 to_cfg: ELMOHeadConfig) -> HeadState:
    """Re-type the head weights (e.g. FP8 checkpoint → BF16 for refinement).

    Shapes must match (same labels/chunks); the Kahan buffer is created or
    dropped per the target config."""
    assert from_cfg.padded_labels == to_cfg.padded_labels
    assert from_cfg.num_chunks == to_cfg.num_chunks
    w = state.w.astype(jnp.float32).astype(to_cfg.wdtype)
    comp = (jnp.zeros((to_cfg.kahan_chunks, to_cfg.chunk, to_cfg.d_model),
                      P.BF16) if to_cfg.kahan_chunks else None)
    return HeadState(w, comp)


def posthoc_refine(to_cfg: ELMOHeadConfig, state: HeadState,
                   batches, steps: int, lr: float, seed: int = 0
                   ) -> HeadState:
    """App. D.1: fine-tune the head in higher precision on FROZEN encoder
    features.  ``batches`` yields (x, targets) with x already encoded —
    only head memory is resident, so this stays within the low-precision
    run's budget (label chunks stream exactly as in training)."""
    for i, (x, targets) in zip(range(steps), batches):
        state, _, _ = head_train_step(to_cfg, state, x, targets,
                                      jnp.float32(lr), jnp.float32(0.0),
                                      jnp.uint32(seed + i))
    return state


def build_shortlist(cfg: ELMOHeadConfig, state: HeadState, *,
                    out_dir: Optional[str] = None,
                    n_clusters: Optional[int] = None,
                    beam: Optional[int] = None,
                    iters: int = 8, seed: int = 0):
    """Offline 2-stage shortlist build from a (typically FP8) head
    checkpoint: balanced k-means over the W rows in BF16, optionally
    persisted beside the checkpoint with the same crc32-leaf integrity
    scheme (``shortlist.save_shortlist_index``).  Returns the
    ``ShortlistIndex``; attach it with ``ELMOHead.attach_shortlist`` (or
    rebuild via ``ELMOHead.build_shortlist``, which also attaches).

    Lives here with the other offline state transforms because the build
    reads checkpoint bits, not serving traffic — and MUST be re-run after
    further training moves W (``shortlist.is_stale``; DESIGN.md §11)."""
    from repro.head import shortlist as _sl
    index = _sl.build_shortlist_index(cfg, state, n_clusters=n_clusters,
                                      beam=beam, iters=iters, seed=seed)
    if out_dir is not None:
        _sl.save_shortlist_index(out_dir, index)
    return index
