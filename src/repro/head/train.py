"""Single-device ELMO head training step (paper §4.2–4.3), plan-driven.

One ``train_step_planned`` performs, for each label chunk:

    1. forward    z_c = q8(X) @ W_cᵀ            (FP8-storage matmul)
    2. loss-skip  ḡ_c = σ(z_c) − Y_c   |  softmax(z_c) − onehot      (App. B)
    3. input grad X̄  += ḡ_c @ W_c
    4. fused upd  W_c ← SR((1 − lr·wd) W_c − lr ḡ_cᵀ X)   (grad never in HBM)

so transient memory is 1/k of the full logits (paper §4.2, Table 10) and
the weight/optimizer memory is W itself — SGD without momentum (§4.2),
stochastic rounding instead of master weights (§4.1/4.3).

Which of the three algorithmically identical paths executes — the
whole-head grid megakernel (ONE Pallas launch, DESIGN.md §7), the PR-1
per-chunk ``lax.scan`` (its bit-parity oracle), or the legacy multi-kernel
composition — is decided by the ``HeadPlan`` passed in: this module
contains *no* dispatch logic (no ``_impl_split``/``_grid_ok`` calls inside
traced step functions — DESIGN.md §8).

The head never enters autodiff: the caller runs the backbone under
``jax.vjp`` and seeds it with the returned ``x_grad`` — which reproduces
the paper's reordered computation flow (encoder fwd → head fwd/bwd/update
→ encoder bwd) and its peak-memory profile by construction.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses as L
from repro.head import plan as _plan
from repro.head.config import ELMOHeadConfig
from repro.head.state import HeadState
from repro.kernels import ops
from repro.kernels import prng_utils as PR
from repro.kernels import tuning as _tuning
from repro.numerics import telemetry as NT


# ---------------------------------------------------------------------------
# chunk-level helpers shared by train / train_sharded / serving
# ---------------------------------------------------------------------------


def _valid_cols(cfg: ELMOHeadConfig, cidx: jax.Array) -> jax.Array:
    """(chunk,) bool — masks padded label columns in the final chunk."""
    c0 = cidx * cfg.chunk
    return (c0 + jnp.arange(cfg.chunk)) < cfg.num_labels


def _chunk_logits(cfg: ELMOHeadConfig, wc: jax.Array, x: jax.Array,
                  seed: jax.Array, impl: str) -> jax.Array:
    return ops.fp8_logits(x, wc, seed, drop_rate=cfg.drop_rate,
                          quantize_x=cfg.qx, impl=impl)


def _chunk_seed(seed: jax.Array, cidx: jax.Array, salt: int) -> jax.Array:
    return PR.mix32(seed.astype(jnp.uint32)
                    + cidx.astype(jnp.uint32) * np.uint32(2654435761)
                    + np.uint32(salt))


def _grid_seeds(cfg: ELMOHeadConfig, seed: jax.Array):
    """Per-chunk DropConnect/SR seed vectors — elementwise identical to the
    scalar ``_chunk_seed`` draws of the per-chunk scan."""
    cids = jnp.arange(cfg.num_chunks, dtype=jnp.int32)
    return _chunk_seed(seed, cids, 0), _chunk_seed(seed, cids, 1), cids


def _chunk_grad(cfg: ELMOHeadConfig, z: jax.Array, targets: jax.Array,
                cidx: jax.Array, lse: Optional[jax.Array],
                scale: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Loss-skip logit gradient + optional loss contribution for one chunk."""
    return L.chunk_loss_skip_grad(cfg.loss, z, targets, cidx * cfg.chunk,
                                  cfg.chunk, cfg.num_labels, lse, scale,
                                  cfg.compute_loss)


def _masked_z(cfg: ELMOHeadConfig, z: jax.Array, cidx: jax.Array) -> jax.Array:
    valid = _valid_cols(cfg, cidx)[None, :]
    return jnp.where(valid, z.astype(jnp.float32), L.NEG_INF)


def _scan_chunks(cfg: ELMOHeadConfig, w, comp, chunk_ids, zs, carry,
                 chunk_step):
    """The Kahan/SR chunk-scan split shared by every train-step path
    (fused, unfused, sharded).  ``chunk_step(*carry, wc, comp_c, cidx,
    z_c)`` is the per-chunk work — the carry is ``(xg, loss)`` or, when
    the numerics guard rides along, ``(xg, loss, tele)``; the documented
    fused-vs-unfused-vs-sharded parity depends on this scaffolding living
    in exactly one place.  Returns (carry, w_kahan, w_sr, comp_new)."""

    def kahan_body(carry, inp):
        wc, comp_c, cidx, z_c = (inp if zs is not None else inp + (None,))
        *carry, wc_new, comp_new = chunk_step(*carry, wc, comp_c, cidx,
                                              z_c)
        return tuple(carry), (wc_new, comp_new)

    def sr_body(carry, inp):
        wc, cidx, z_c = inp if zs is not None else inp + (None,)
        *carry, wc_new, _ = chunk_step(*carry, wc, None, cidx, z_c)
        return tuple(carry), wc_new

    ck = cfg.kahan_chunks
    if ck:
        xs = (w[:ck], comp, chunk_ids[:ck])
        if zs is not None:
            xs += (zs[:ck],)
        carry, (w_k, comp_new) = jax.lax.scan(kahan_body, carry, xs)
    else:
        w_k, comp_new = w[:0], comp

    if ck < cfg.num_chunks:
        xs = (w[ck:], chunk_ids[ck:])
        if zs is not None:
            xs += (zs[ck:],)
        carry, w_s = jax.lax.scan(sr_body, carry, xs)
    else:
        w_s = w[:0]
    return carry, w_k, w_s, comp_new


def _fold_loss(cfg: ELMOHeadConfig, loss_raw, targets, lse, scale,
               B: int) -> jax.Array:
    """Raw in-step loss accumulator → reported loss.  BCE: mean over the
    batch.  CE: Σ(lse − z_target) over valid tokens (loss_raw = Σ z_target).
    Shared by every dense path *and* the sparse subsystem — the loss-parity
    guarantees depend on this formula living in exactly one place."""
    if cfg.loss == "bce":
        return loss_raw / B
    tok_mask = (targets >= 0)
    return ((lse * tok_mask).sum() - loss_raw) * scale \
        if cfg.compute_loss else loss_raw


def _finalize_step(cfg: ELMOHeadConfig, carry, w_k, w_s, comp_new, targets,
                   lse, scale, B: int) -> Tuple[HeadState, jax.Array, dict]:
    """Shared epilogue of every train-step path: reassemble the chunk
    weights and fold the accumulated loss (the fused/unfused A/B guarantee
    depends on this formula living in exactly one place).  A 3-element
    carry additionally finalizes the numerics telemetry (DESIGN.md §14)
    into ``metrics["telemetry"]``."""
    xg, loss_raw = carry[0], carry[1]
    w_new = jnp.concatenate([w_k, w_s], axis=0) if cfg.kahan_chunks else w_s
    loss = _fold_loss(cfg, loss_raw, targets, lse, scale, B)
    metrics = {"loss": loss,
               "xgrad_norm": jnp.linalg.norm(xg.astype(jnp.float32))}
    if len(carry) > 2:
        metrics["telemetry"] = NT.finalize(
            carry[2], xg, None if lse is None else lse[:B])
    return HeadState(w_new, comp_new), xg, metrics


# ---------------------------------------------------------------------------
# planned training step
# ---------------------------------------------------------------------------


def train_step_planned(plan: "_plan.HeadPlan", cfg: ELMOHeadConfig,
                       state: HeadState, x: jax.Array, targets: jax.Array,
                       lr: jax.Array, wd: jax.Array, seed: jax.Array
                       ) -> Tuple[HeadState, jax.Array, dict]:
    """One fused forward/backward/update pass over all label chunks, on the
    path ``plan`` selected (grid / fused scan / unfused — all numerically
    identical by construction).

    x: (B, D) bf16 backbone outputs (tokens flattened).
    targets: (B, P) int32 multi-label ids (bce) or (B,) int32 ids (ce).
    Returns (new_state, x_grad (B, D) bf16, metrics).
    """
    if plan.path == "grid":
        return _train_step_grid(plan, cfg, state, x, targets, lr, wd, seed)
    if plan.path == "fused":
        return _train_step_fused(plan, cfg, state, x, targets, lr, wd, seed)
    return _train_step_unfused(plan, cfg, state, x, targets, lr, wd, seed)


def _train_step_grid(plan, cfg: ELMOHeadConfig, state: HeadState,
                     x: jax.Array, targets: jax.Array, lr: jax.Array,
                     wd: jax.Array, seed: jax.Array
                     ) -> Tuple[HeadState, jax.Array, dict]:
    """One whole-head grid-megakernel launch (DESIGN.md §7): the label loop
    runs inside the Pallas grid, so BCE is exactly one launch per step and
    softmax-CE one two-pass launch (the z-cache spills through a
    grid-mapped HBM buffer instead of a second launch)."""
    B = x.shape[0]
    impl = plan.train_inner
    x = x.astype(jnp.bfloat16)
    seed = seed.astype(jnp.uint32)
    seeds_d, seeds_u, cids = _grid_seeds(cfg, seed)
    base = cids * cfg.chunk
    kahan = cfg.kahan_chunks > 0
    comp = state.comp if kahan else None
    common = dict(num_labels=cfg.num_labels, use_sr=cfg.use_sr,
                  quantize_x=cfg.qx, drop_rate=cfg.drop_rate,
                  compute_loss=cfg.compute_loss, impl=impl,
                  guard=cfg.guard)

    if cfg.loss == "bce":
        scale, lse = jnp.float32(1.0 / B), None
        out = ops.fused_head_step(x, state.w, targets, lr, wd, scale,
                                  seeds_d, seeds_u, base, comp=comp,
                                  mode="bce", **common)
    else:
        n_tok = jnp.maximum((targets >= 0).sum(), 1).astype(jnp.float32)
        scale = 1.0 / n_tok
        out = ops.fused_head_step(x, state.w, targets, lr, wd, scale,
                                  seeds_d, seeds_u, base, comp=comp,
                                  mode="ce_full", cache_z=plan.cache_z,
                                  **common)
        lse = out.lse

    w_k = out.w if kahan else state.w[:0]
    w_s = state.w[:0] if kahan else out.w
    carry = (out.xg, out.loss) + ((out.tele,) if cfg.guard else ())
    return _finalize_step(cfg, carry, w_k, w_s, out.comp,
                          targets, lse, scale, B)


def _train_step_fused(plan, cfg: ELMOHeadConfig, state: HeadState,
                      x: jax.Array, targets: jax.Array, lr: jax.Array,
                      wd: jax.Array, seed: jax.Array
                      ) -> Tuple[HeadState, jax.Array, dict]:
    B = x.shape[0]
    impl = plan.train_inner
    x = x.astype(jnp.bfloat16)
    seed = seed.astype(jnp.uint32)
    chunk_ids = jnp.arange(cfg.num_chunks, dtype=jnp.int32)

    if cfg.loss == "bce":
        scale = jnp.float32(1.0 / B)
    else:
        n_tok = jnp.maximum((targets >= 0).sum(), 1).astype(jnp.float32)
        scale = 1.0 / n_tok

    # hoisted tile-alignment padding: the compiled-kernel path pads
    # x/x̄/targets ONCE per step here (the chunk kernel's own pad2 calls
    # become no-ops), instead of re-padding the loop-invariant operands at
    # every chunk of the scan.  ``n_b`` tells the kernel the logical batch
    # so its masking ignores the padded rows.  interpret/xla inners keep
    # exact shapes (their bitwise-parity contract forbids padding).
    n_b = None
    if plan.rimpl == "kernel":
        n_b = B
        Bp = _tuning._pad_up(B, 16)
        Dp = _tuning._pad_up(cfg.d_model, _tuning.LANE)
        x = _tuning.pad2(x, Bp, Dp)
        targets = _tuning.pad2(
            targets if targets.ndim == 2 else targets.reshape(B, 1),
            Bp, 1, value=-1)
        if cfg.loss == "softmax_ce":
            targets = targets.reshape(-1)

    if cfg.loss == "bce":
        lse, zs = None, None
    else:
        cache = plan.cache_z

        # ----- pass 1: streaming LSE (optionally caching each chunk's z
        # so pass 2 skips the forward matmul entirely)
        def lse_body(carry, inp):
            wc, cidx = inp
            m, s = carry
            z = _chunk_logits(cfg, wc, x, _chunk_seed(seed, cidx, 0), impl)
            carry = L.lse_update(m, s, _masked_z(cfg, z, cidx))
            return carry, (z if cache else None)

        (m, s), zs = jax.lax.scan(lse_body, L.lse_init(x.shape[0]),
                                  (state.w, chunk_ids))
        lse = L.lse_finalize(m, s)

    def chunk_step(xg, loss_acc, *rest):
        tele, (wc, comp_c, cidx, z_c) = (
            (rest[0], rest[1:]) if cfg.guard else (None, rest))
        out = ops.fused_chunk_step(
            x, wc, targets, xg, lr, wd, scale, cidx * cfg.chunk,
            _chunk_seed(seed, cidx, 0), _chunk_seed(seed, cidx, 1),
            lse=lse, z=z_c, comp=comp_c, loss=cfg.loss,
            num_labels=cfg.num_labels, use_sr=cfg.use_sr,
            quantize_x=cfg.qx, drop_rate=cfg.drop_rate,
            compute_loss=cfg.compute_loss, impl=impl, guard=cfg.guard,
            **({"n_b": n_b} if n_b is not None else {}))
        head = (out.xg, loss_acc + out.loss)
        if cfg.guard:
            head += (NT.combine(tele, out.tele),)
        return head + (out.w, out.comp)

    carry = (jnp.zeros(x.shape, jnp.bfloat16), jnp.float32(0.0))
    if cfg.guard:
        carry += (NT.zero(),)
    carry, w_k, w_s, comp_new = _scan_chunks(cfg, state.w, state.comp,
                                             chunk_ids, zs, carry,
                                             chunk_step)
    carry = (carry[0][:B, :cfg.d_model],) + tuple(carry[1:])
    return _finalize_step(cfg, carry, w_k, w_s, comp_new, targets, lse,
                          scale, B)


def _train_step_unfused(plan, cfg: ELMOHeadConfig, state: HeadState,
                        x: jax.Array, targets: jax.Array,
                        lr: jax.Array, wd: jax.Array, seed: jax.Array
                        ) -> Tuple[HeadState, jax.Array, dict]:
    """Legacy multi-kernel path (three launches + HBM logits/grad round
    trips per chunk) — kept selectable for fused-vs-unfused A/B."""
    assert not cfg.guard, \
        "numerics guard needs the grid or fused path (DESIGN.md §14)"
    B = x.shape[0]
    impl = plan.train_inner
    x = x.astype(jnp.bfloat16)
    seed = seed.astype(jnp.uint32)

    if cfg.loss == "bce":
        scale = jnp.float32(1.0 / B)
        lse = None
    else:
        n_tok = jnp.maximum((targets >= 0).sum(), 1).astype(jnp.float32)
        scale = 1.0 / n_tok

        # ----- pass 1: streaming LSE over chunks (paper §4.2 chunking + CE)
        def lse_body(carry, inp):
            wc, cidx = inp
            m, s = carry
            z = _masked_z(cfg, _chunk_logits(cfg, wc, x,
                                             _chunk_seed(seed, cidx, 0),
                                             impl), cidx)
            return L.lse_update(m, s, z), None

        (m, s), _ = jax.lax.scan(
            lse_body, L.lse_init(B),
            (state.w, jnp.arange(cfg.num_chunks, dtype=jnp.int32)))
        lse = L.lse_finalize(m, s)

    # ----- pass 2: per-chunk grad + fused update + x̄ accumulation
    def chunk_step(xg, loss_acc, wc, comp_c, cidx, _z):
        sd = _chunk_seed(seed, cidx, 0)
        z = _chunk_logits(cfg, wc, x, sd, impl)
        g, loss_c = _chunk_grad(cfg, z, targets, cidx, lse, scale)
        # x̄ accumulates in BF16 (paper §4.1: gradients stay BF16) — halves
        # the accumulator and its cross-model all-reduce
        xg = xg + ops.fp8_input_grad(g, wc, impl=impl)
        upd_seed = _chunk_seed(seed, cidx, 1)
        if comp_c is None:
            wc_new = ops.fused_head_update(g, x, wc, lr, wd, upd_seed,
                                           use_sr=cfg.use_sr, impl=impl)
            return xg, loss_acc + loss_c, wc_new, None
        wc_new, comp_new = ops.fused_head_update_kahan(
            g, x, wc, comp_c, lr, wd, upd_seed, impl=impl)
        return xg, loss_acc + loss_c, wc_new, comp_new

    carry = (jnp.zeros((B, cfg.d_model), jnp.bfloat16), jnp.float32(0.0))
    carry, w_k, w_s, comp_new = _scan_chunks(
        cfg, state.w, state.comp,
        jnp.arange(cfg.num_chunks, dtype=jnp.int32), None, carry,
        chunk_step)
    return _finalize_step(cfg, carry, w_k, w_s, comp_new, targets, lse,
                          scale, B)


# ---------------------------------------------------------------------------
# legacy free-function surface (deprecated; the facade pre-resolves)
# ---------------------------------------------------------------------------


def head_train_step(cfg: ELMOHeadConfig, state: HeadState, x: jax.Array,
                    targets: jax.Array, lr: jax.Array, wd: jax.Array,
                    seed: jax.Array
                    ) -> Tuple[HeadState, jax.Array, dict]:
    """Deprecated free-function form: resolves a ``HeadPlan`` per call
    (memoized) and runs the planned step.  Prefer ``repro.head.ELMOHead``,
    which resolves the plan once at construction."""
    plan = _plan.resolve_plan(cfg, batch=x.shape[0],
                              target_slots=_plan._target_slots(targets))
    return train_step_planned(plan, cfg, state, x, targets, lr, wd, seed)
