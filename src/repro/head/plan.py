"""``HeadPlan``: every residency/precision/dispatch decision, resolved ONCE.

ELMO's viability rests on a web of static decisions — which execution path
(grid megakernel / fused chunk scan / legacy unfused), which inner kernel
impl, whether the CE z-cache fits, which label tile the launch will use,
how the label axis shards over the mesh.  Historically each free function
in ``core/elmo_head.py`` re-derived them ad hoc at every call
(``_impl_split`` / ``_grid_ok`` / ``_want_cache_z`` per trace); this module
makes the decision a first-class, inspectable value:

    plan = resolve_plan(cfg, batch=128, target_slots=40, model_size=4)
    print(plan.explain())        # path, blocks, bytes, why any fallback

``ELMOHead`` (the facade) resolves its plan at construction and hands it
to the planned step functions in ``train`` / ``train_sharded`` /
``serving`` — which contain *no* resolution logic of their own.  The
legacy free functions resolve a plan per call through the same (memoized)
resolver, so facade and legacy paths are bit-identical by construction.

Resolution is memoized on every input that can change the answer —
including the mutable byte budgets below and the JAX backend — so a plan
can never go stale, and ``_RESOLVE_CALLS`` counts resolver entries so
tests can assert construction-time-only resolution (DESIGN.md §8).

CLI (the CI ``plan-stability`` gate)::

    PYTHONPATH=src python -m repro.head.plan --arch xmc-bert-3m --explain \
        --expect-path grid,fused
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from repro.core import memory_model as MM
from repro.head.config import ELMOHeadConfig
from repro.kernels import ops
from repro.kernels import tuning as _tuning

# z-cache budget for the CE cached-logits fast path (B·padded_labels bf16);
# past this, recomputing pass-2 logits beats holding them (paper §4.2: the
# whole point of chunking is not materializing (B, L))
_CACHE_Z_BYTES = 32 * 2 ** 20

# serving z-materialization budget for the single-launch top-k fast path —
# its own knob (initialized to the training z-cache default; retuning one
# at runtime deliberately does not move the other): past it, streaming wins
_TOPK_Z_BYTES = 32 * 2 ** 20

# "auto" shortlisting (DESIGN.md §11) only turns on at label counts where
# the 2-stage √L partition beats the exact scan by enough to matter; below
# this the exact streaming kernel is already cheap and the recall tax buys
# nothing.  cfg.shortlist == "on" bypasses the floor (tests, small heads).
_SHORTLIST_MIN_LABELS = 1 << 20

# entries into resolve_plan() — the facade contract is that this stops
# moving once an ELMOHead is constructed and used at its declared shapes
_RESOLVE_CALLS = 0


def _want_cache_z(cfg: ELMOHeadConfig, z_bytes: int,
                  budget: int | None = None) -> bool:
    """The ONE CE z-cache policy shared by the grid, fused-scan and
    sharded paths: explicit on/off wins; "auto" caches iff this path's
    z footprint (``z_bytes``, local to the device) fits the budget."""
    budget = _CACHE_Z_BYTES if budget is None else budget
    return cfg.cache_z == "on" or (cfg.cache_z == "auto"
                                   and z_bytes <= budget)


def _impl_split(impl: str) -> Tuple[str, str]:
    """cfg.impl → (path, inner kernel impl).

    path ∈ {"grid", "fused", "unfused"} (see ``ELMOHeadConfig.impl``).
    Bare inner names keep their historical meaning of "the default fast
    path with this inner impl" — which is now the grid path."""
    for path in ("grid", "fused", "unfused"):
        if impl == path or impl.startswith(path + "_") \
                or impl.startswith(path + ":"):
            rest = impl[len(path):].lstrip("_:")
            return path, (rest or "auto")
    return "grid", impl


def _grid_ok(cfg: ELMOHeadConfig, batch: int, rimpl: str,
             p_slots: int = 1) -> Tuple[bool, str]:
    """Whether the whole-head grid megakernel can run this step, and (for
    ``HeadPlan.fallback_reason``) why not.

    The grid kernel has no jnp oracle (inner "xla" routes to the fused
    scan, which *is* the oracle), the mixed Kahan hybrid keeps the
    per-chunk scan (a homogeneous update rule lets one grid cover every
    block), and the compiled path must fit the §7 VMEM residency model —
    gated with the same ``p_slots`` (resident target columns) the launch
    will size the kernel with, so gate and tile chooser agree."""
    if rimpl not in ("kernel", "interpret"):
        return False, (f"inner resolves to {rimpl!r} — the grid kernel has "
                       "no jnp oracle; the fused scan is the oracle")
    if cfg.kahan_chunks not in (0, cfg.num_chunks):
        return False, (f"mixed Kahan hybrid ({cfg.kahan_chunks}/"
                       f"{cfg.num_chunks} chunks) keeps the per-chunk scan")
    if rimpl == "kernel" and not _tuning.fused_head_viable(
            batch, cfg.d_model, jnp.dtype(cfg.wdtype).itemsize,
            kahan=cfg.kahan_chunks > 0, p_slots=p_slots):
        return False, ("grid residency model exceeds VMEM at "
                       f"B={batch} D={cfg.d_model}")
    return True, ""


def _target_slots(targets: jax.Array) -> int:
    return targets.shape[-1] if targets.ndim == 2 else 1


@dataclasses.dataclass(frozen=True)
class HeadPlan:
    """One resolved execution plan for the ELMO head (DESIGN.md §8).

    Immutable, hashable, safe to close over in jitted step functions: every
    field is a static Python value decided at resolution time."""
    # ---- resolution inputs (snapshot) ----
    batch: int                 # (global) token rows the step sees
    target_slots: int          # P of (B, P) multi-label targets, else 1
    model_size: int            # label shards (1 = single-device semantics)
    model_axis: Optional[str]  # mesh axis name when model_size > 1
    ce_comm: str               # sharded CE normalizer strategy
    backend: str               # jax.default_backend() at resolution
    # ---- train-step decision ----
    requested_path: str        # from cfg.impl ("grid" | "fused" | "unfused")
    inner: str                 # raw inner impl token from cfg.impl
    rimpl: str                 # resolved inner: kernel | interpret | xla
    path: str                  # EXECUTED path: grid | fused | unfused
    train_inner: str           # inner the step hands to kernels.ops (the
    #                            sharded scan may downgrade kernel → xla)
    cache_z: bool              # CE z-cache decision for the executed path
    fallback_reason: str       # "" when the requested path runs
    # ---- geometry ----
    lc: int                    # local label rows per chunk (chunk / n)
    block_l: int               # label tile of the executed path's launch
    # ---- shard layout (trivial specs when model_size == 1) ----
    w_spec: PS                 # (C, Lc, D) weights / Kahan comp
    xg_err_spec: PS            # (n, B, D) error-feedback carry
    # ---- byte estimates (tuning VMEM model + memory_model transients) ----
    vmem_bytes: int            # kernel working set at block_l (0 = n/a)
    temp_bytes: int            # predicted per-device logit/grad transients
    # ---- serving decision (same batch) ----
    serve_grid: bool           # single-launch logits kernel usable
    topk_path: str             # "kernel" (streaming top-k megakernel, 1
    #                            launch at O(B·k)) | "materialize" (logits
    #                            launch + one top_k, ≤ _TOPK_Z_BYTES) |
    #                            "stream" (per-chunk scan) | "shortlist"
    #                            (2-stage: centroid beam → restricted
    #                            kernel/scan, DESIGN.md §11)
    shortlist_c: int = 0       # shortlist cluster count (0 = exact serving)
    shortlist_beam: int = 0    # admitted clusters per query
    # ---- fixed-fan-in sparse head (DESIGN.md §13) ----
    fan_in: int = 0            # 0 = dense; > 0 ⇒ path == "sparse"

    @property
    def sharded(self) -> bool:
        return self.model_size > 1

    @property
    def topk_materialize(self) -> bool:
        """Back-compat view of the pre-ISSUE-5 two-way serving decision."""
        return self.topk_path == "materialize"

    def checkpoint_meta(self) -> dict:
        """What a checkpoint of this head's state must record (DESIGN.md
        §10): the shard layout W/comp were saved under (informative — leaves
        are stored full-logical and reshard on restore), the label geometry
        a restore's template must match bit-for-bit, and the backend the
        trajectory is deterministic on.  ``launch.train`` writes this into
        every manifest's ``extra``; restore cross-checks it before
        continuing a run."""
        return {"model_size": self.model_size, "model_axis": self.model_axis,
                "w_spec": str(self.w_spec), "lc": self.lc,
                "path": self.path, "backend": self.backend}

    def launches_per_step(self) -> str:
        if self.path == "sparse":
            return ("O(num_chunks) (sharded sparse ref scan)"
                    if self.sharded else "1")
        if self.path != "grid":
            return "O(num_chunks)"
        if self.sharded:
            return "1 (bce) / ≤2 (softmax-ce: collective between passes)"
        return "1"

    def explain(self) -> str:
        """Human-readable resolution trace for logs and benches."""
        mib = 2 ** 20
        lines = [
            f"HeadPlan: B={self.batch} P={self.target_slots} "
            f"backend={self.backend} model_size={self.model_size}"
            + (f" axis={self.model_axis!r} ce_comm={self.ce_comm}"
               if self.sharded else ""),
            f"  requested  path={self.requested_path} inner={self.inner!r} "
            f"(resolves to {self.rimpl!r})",
            f"  executed   path={self.path} inner={self.train_inner!r} "
            f"launches/step={self.launches_per_step()}",
        ]
        if self.fallback_reason:
            lines.append(f"  fallback   {self.fallback_reason}")
        if self.fan_in:
            lines.append(f"  sparse     fan_in={self.fan_in} "
                         f"(fixed-fan-in value/index head, DESIGN.md §13)")
        lines += [
            f"  geometry   lc={self.lc} block_l={self.block_l} "
            f"cache_z={'on' if self.cache_z else 'off'}",
            f"  estimates  vmem≈{self.vmem_bytes / mib:.2f} MiB "
            f"transients≈{self.temp_bytes / mib:.2f} MiB "
            f"(budgets: cache_z {_CACHE_Z_BYTES / mib:.0f} MiB, "
            f"topk_z {_TOPK_Z_BYTES / mib:.0f} MiB)",
            f"  serving    grid={self.serve_grid} topk={self.topk_path}"
            + (f" (C={self.shortlist_c} beam={self.shortlist_beam})"
               if self.topk_path == "shortlist" else ""),
            f"  sharding   w/comp={self.w_spec} xg_err={self.xg_err_spec}",
            f"  checkpoint full-logical leaves, reshard on restore; "
            f"manifest meta={self.checkpoint_meta()} (DESIGN.md §10)",
        ]
        return "\n".join(lines)


def resolve_plan(cfg: ELMOHeadConfig, *, batch: int, target_slots: int = 1,
                 model_size: int = 1, model_axis: Optional[str] = None,
                 ce_comm: str = "gather") -> HeadPlan:
    """Resolve every static head decision for one (config, shape, mesh).

    Memoized on all inputs *plus* the mutable byte budgets and the JAX
    backend; the un-memoized entry count is tracked in ``_RESOLVE_CALLS``
    so tests can pin construction-time-only resolution."""
    global _RESOLVE_CALLS
    _RESOLVE_CALLS += 1
    if model_size > 1 and cfg.chunk % model_size != 0:
        # indivisible chunk: sharded entry points fall back to the
        # single-device step — plan with single-device semantics
        model_size, model_axis = 1, None
    if model_size <= 1:
        model_axis = None
    return _resolve_cached(cfg, batch, target_slots, model_size, model_axis,
                           ce_comm, _CACHE_Z_BYTES, _TOPK_Z_BYTES,
                           jax.default_backend())


@functools.lru_cache(maxsize=4096)
def _resolve_cached(cfg, batch, target_slots, n, axis, ce_comm,
                    cache_budget, topk_budget, backend) -> HeadPlan:
    requested_path, inner = _impl_split(cfg.impl)
    rimpl = ops.resolve_impl(inner)
    wb = jnp.dtype(cfg.wdtype).itemsize
    kahan = cfg.kahan_chunks > 0
    lc = cfg.chunk // n
    local_padded = cfg.padded_labels // n

    if cfg.fan_in:
        # ---- fixed-fan-in sparse head (DESIGN.md §13): its own path ----
        # Single-device dispatches the sparse megakernel (ref scan on xla);
        # the sharded body runs the pure-JAX ref composition inside
        # shard_map.  Serving always scans chunks (densify-free top-k
        # merge) — no dense grid/materialize/shortlist machinery applies.
        reason = ""
        if rimpl == "kernel":
            if _tuning.sparse_head_viable(batch, cfg.d_model, cfg.fan_in,
                                          wb, kahan=kahan,
                                          p_slots=target_slots):
                train_inner = "kernel"
            else:
                train_inner = "xla"
                reason = ("sparse residency model exceeds VMEM at "
                          f"B={batch} D={cfg.d_model} F={cfg.fan_in} — "
                          "ref scan")
        elif rimpl == "interpret":
            train_inner = "interpret"
        else:
            train_inner = "xla"
        if train_inner == "kernel":
            block_l = _tuning.sparse_head_block_l(
                batch, lc, cfg.d_model, cfg.fan_in, wb, kahan=kahan,
                p_slots=target_slots, n_chunks=cfg.num_chunks)
        else:
            block_l = lc
        vmem = (0 if train_inner == "xla"
                else _tuning._sparse_head_vmem(batch, cfg.d_model,
                                               cfg.fan_in, block_l, wb,
                                               kahan, target_slots))
        s = MM.MemScenario(num_labels=cfg.num_labels, d_model=cfg.d_model,
                           batch=batch, num_chunks=cfg.num_chunks,
                           kahan_chunks=cfg.kahan_chunks)
        comp = MM.head_components(s, cfg.weight_dtype, n_label_shards=n,
                                  fan_in=cfg.fan_in)
        temp_bytes = int(comp["chunk_logits_bf16"]
                         + comp["chunk_logit_grad_bf16"])
        axis_spec = axis if n > 1 else None
        return HeadPlan(
            batch=batch, target_slots=target_slots, model_size=n,
            model_axis=axis, ce_comm=ce_comm, backend=backend,
            requested_path="sparse", inner=inner, rimpl=rimpl,
            path="sparse", train_inner=train_inner, cache_z=False,
            fallback_reason=reason, lc=lc, block_l=int(block_l),
            w_spec=PS(None, axis_spec, None),
            xg_err_spec=PS(axis_spec, None, None),
            vmem_bytes=int(vmem), temp_bytes=temp_bytes,
            serve_grid=False, topk_path="stream", fan_in=cfg.fan_in)

    grid, reason = False, ""
    if requested_path == "grid":
        grid, reason = _grid_ok(cfg, batch, rimpl, target_slots)

    train_inner = inner
    if n > 1:
        # ---- label-sharded decision block (was inline in
        # head_train_step_sharded) ----
        z_fits = batch * local_padded * 2 <= cache_budget
        if grid and ce_comm == "gather" and (cfg.loss == "softmax_ce"
                                             or cfg.compute_loss):
            if not z_fits:
                grid = False
                reason = ("gather-mode loss/LSE reads the local logits "
                          "back; their footprint exceeds the z budget")
        if not grid and rimpl == "kernel" and not _tuning.fused_chunk_viable(
                batch, cfg.d_model, wb, kahan=kahan):
            train_inner = "xla"   # sharded scan is megakernel-shaped;
            #                       oracle fallback
            reason = reason or ("fused chunk working set exceeds VMEM at "
                                f"B={batch}")
        if requested_path == "unfused":
            reason = reason or ("sharded step has no unfused branch; the "
                                "per-chunk fused scan runs instead")
        path = "grid" if grid else "fused"
    else:
        if grid:
            path = "grid"
        else:
            fused = requested_path != "unfused"
            if (fused and rimpl == "kernel"
                    and not _tuning.fused_chunk_viable(
                        batch, cfg.d_model, wb, kahan=kahan)):
                fused = False   # megakernel working set exceeds VMEM
                reason = ("fused chunk working set exceeds VMEM at "
                          f"B={batch} — unfused 3-kernel path")
            path = "fused" if fused else "unfused"

    # ---- CE z-cache decision for the executed path ----
    cache_z = False
    if cfg.loss == "softmax_ce" and path != "unfused":
        if n > 1:
            # grid/gather passes z between its two launches (no budget);
            # grid/stats and the scan branch cache against the LOCAL width
            if not (path == "grid" and ce_comm == "gather"):
                cache_z = _want_cache_z(cfg, batch * local_padded * 2,
                                        cache_budget)
        elif path == "grid":
            cache_z = _want_cache_z(cfg, batch * cfg.padded_labels * 2,
                                    cache_budget)
            if cache_z and rimpl == "kernel" and not _tuning.fused_head_viable(
                    batch, cfg.d_model, wb, kahan=kahan, cache_z=True,
                    lc=cfg.chunk, n_chunks=cfg.num_chunks):
                cache_z = False   # recompute pass-2 logits in-kernel
        else:
            cache_z = _want_cache_z(cfg, batch * cfg.padded_labels * 2,
                                    cache_budget)

    # ---- label tile + VMEM working set of the executed path ----
    if path == "grid":
        if rimpl == "kernel":
            block_l = _tuning.head_grid_block_l(
                batch, lc, cfg.d_model, wb, kahan=kahan,
                cache_z=cache_z and n == 1, p_slots=target_slots,
                n_chunks=cfg.num_chunks)
        else:
            block_l = lc       # interpret mode keeps exact shapes
        vmem = _tuning._head_grid_vmem(
            batch, cfg.d_model, block_l, wb, kahan,
            _tuning._grid_z_cols(lc, block_l, cfg.num_chunks,
                                 cache_z and n == 1), target_slots)
    elif path == "fused":
        if train_inner != "xla" and rimpl == "kernel":
            block_l = _tuning.chunk_block_l(batch, cfg.chunk, cfg.d_model,
                                            wb, kahan=kahan,
                                            cached_z=cache_z, n_shards=n)
        else:
            block_l = lc
        vmem = (0 if rimpl == "xla" or train_inner == "xla"   # no VMEM model
                else _tuning._chunk_vmem(batch, cfg.d_model, block_l, wb,
                                         kahan, cache_z))
    else:
        block_l, vmem = lc, 0

    # ---- memory_model transients (the paper-style per-device estimate) ----
    s = MM.MemScenario(num_labels=cfg.num_labels, d_model=cfg.d_model,
                       batch=batch, num_chunks=cfg.num_chunks,
                       kahan_chunks=cfg.kahan_chunks)
    comp = MM.head_components(s, cfg.weight_dtype, n_label_shards=n,
                              grid_block_l=block_l if path == "grid"
                              else None)
    temp_bytes = int(comp["chunk_logits_bf16"]
                     + comp["chunk_logit_grad_bf16"]
                     + comp.get("grid_resident_bf16", 0.0))

    # ---- serving decision (same batch) ----
    serve_grid = (requested_path == "grid"
                  and rimpl in ("kernel", "interpret")
                  and (rimpl != "kernel" or _tuning.head_logits_viable(
                      batch, cfg.d_model, wb)))
    # top-k path (DESIGN.md §9): the streaming megakernel needs no z
    # budget — 1 launch at O(B·k) for any label count — so it wins
    # whenever it can run; the materialized fast path (logits launch +
    # one top_k) stays as the fallback under _TOPK_Z_BYTES, and the
    # per-chunk scan serves everything else (incl. the xla oracle, which
    # streams through ops.fused_topk's ref path).
    if (requested_path == "grid" and rimpl in ("kernel", "interpret")
            and (rimpl != "kernel"
                 or _tuning.fused_topk_viable(batch, cfg.d_model, wb))):
        topk_path = "kernel"
    elif serve_grid and batch * local_padded * 2 <= topk_budget:
        topk_path = "materialize"
    else:
        topk_path = "stream"

    # ---- 2-stage shortlisted serving (DESIGN.md §11) ----
    # Replaces only the O(L) exec modes (kernel/stream): "materialize"
    # means the whole logits block fits the z budget, where a partition
    # buys nothing.  "auto" additionally requires the √L-scale label
    # count; geometry (C, beam) comes from the same residency/work model
    # as every other tile choice, and the restricted kernel re-checks
    # VMEM with the beam resident.  Serving still downgrades to the
    # exact path at call time when no index is attached
    # (``serving._topk_exec_path``).
    sl_c = sl_beam = 0
    if (cfg.shortlist != "off" and topk_path in ("kernel", "stream")
            and (cfg.shortlist == "on"
                 or cfg.num_labels >= _SHORTLIST_MIN_LABELS)):
        c, bm = _tuning.shortlist_params(cfg.num_labels, cfg.d_model)
        if c > 0 and (topk_path != "kernel" or rimpl != "kernel"
                      or _tuning.fused_topk_viable(batch, cfg.d_model, wb,
                                                   n_beam=bm)):
            sl_c, sl_beam = c, bm
            topk_path = "shortlist"

    axis_spec = axis if n > 1 else None
    return HeadPlan(
        batch=batch, target_slots=target_slots, model_size=n,
        model_axis=axis, ce_comm=ce_comm, backend=backend,
        requested_path=requested_path, inner=inner, rimpl=rimpl,
        path=path, train_inner=train_inner, cache_z=cache_z,
        fallback_reason=reason, lc=lc, block_l=int(block_l),
        w_spec=PS(None, axis_spec, None),
        xg_err_spec=PS(axis_spec, None, None),
        vmem_bytes=int(vmem), temp_bytes=temp_bytes,
        serve_grid=serve_grid, topk_path=topk_path,
        shortlist_c=sl_c, shortlist_beam=sl_beam)


def _grid_serving_ok(cfg: ELMOHeadConfig, batch: int) -> Tuple[bool, str]:
    """(use the single-launch logits grid kernel?, inner impl) for the
    serving paths — gated on the logits-only VMEM model (the serving grid
    allocates none of the train step's resident accumulators).  Kept as a
    thin wrapper over ``resolve_plan`` for the legacy free functions."""
    plan = resolve_plan(cfg, batch=batch)
    return plan.serve_grid, plan.inner


# ---------------------------------------------------------------------------
# CLI: the CI plan-stability gate
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    import argparse
    import dataclasses as _dc

    from repro.configs import get_config, get_smoke
    from repro.head.config import default_target_slots, head_config_for

    ap = argparse.ArgumentParser(
        description="Resolve and print the ELMO HeadPlan for an arch")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced (CPU-runnable) config")
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--impl", default=None,
                    help="override the head impl string (e.g. grid_interpret)")
    ap.add_argument("--model-size", type=int, default=1,
                    help="label shards (mesh model-axis size)")
    ap.add_argument("--ce-comm", default="gather",
                    choices=["gather", "stats"])
    ap.add_argument("--shortlist", default=None,
                    choices=["off", "on", "auto"],
                    help="override the head's 2-stage shortlisted-serving "
                         "mode (DESIGN.md §11)")
    ap.add_argument("--explain", action="store_true")
    ap.add_argument("--expect-path", default=None,
                    help="comma-separated allowed executed paths; exit 1 "
                         "on a silent fallback outside this set")
    ap.add_argument("--expect-topk", default=None,
                    help="comma-separated allowed serving top-k paths "
                         "(kernel|materialize|stream|shortlist); exit 1 "
                         "otherwise")
    args = ap.parse_args(argv)

    mcfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    hcfg = head_config_for(mcfg)
    if args.impl:
        hcfg = _dc.replace(hcfg, impl=args.impl)
    if args.shortlist:
        hcfg = _dc.replace(hcfg, shortlist=args.shortlist)
    plan = resolve_plan(hcfg, batch=args.batch,
                        target_slots=default_target_slots(mcfg),
                        model_size=args.model_size,
                        model_axis="model" if args.model_size > 1 else None,
                        ce_comm=args.ce_comm)
    print(f"# {mcfg.name}: {hcfg.num_labels} labels × {hcfg.d_model}, "
          f"{hcfg.num_chunks} chunks of {hcfg.chunk} "
          f"({hcfg.weight_dtype}, {hcfg.loss}, impl={hcfg.impl!r})")
    if args.explain:
        print(plan.explain())
    else:
        print(f"path={plan.path} inner={plan.train_inner} "
              f"block_l={plan.block_l} cache_z={plan.cache_z}")
    if args.expect_path:
        allowed = {p.strip() for p in args.expect_path.split(",")}
        if plan.path not in allowed:
            print(f"PLAN REGRESSION: executed path {plan.path!r} not in "
                  f"{sorted(allowed)} (fallback: "
                  f"{plan.fallback_reason or 'none'})")
            return 1
    if args.expect_topk:
        allowed = {p.strip() for p in args.expect_topk.split(",")}
        if plan.topk_path not in allowed:
            print(f"PLAN REGRESSION: serving top-k path "
                  f"{plan.topk_path!r} not in {sorted(allowed)}")
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
