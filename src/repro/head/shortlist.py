"""Two-stage shortlisted serving: the PLT-style label partition (DESIGN §11).

Exact serving walks all L label rows per query — O(B·L·D) forever, no
matter how lean the FP8 streaming kernel gets.  The classic XMC answer
(Parabel/PLT, X-Transformer's matcher, the meta-classifier of
"Memory-Efficient Training for Extremely Large Output Spaces") is a
2-level partition: cluster the label embeddings, score the B×C cluster
centroids first, and run the exact scorer only over the labels of the
top-``beam`` clusters — O(B·(C + beam·L/C)·D) per query, minimized near
C ≈ √(beam·L).

This module owns the index:

* ``build_shortlist_index`` — balanced k-means over the head's W rows in
  BF16 (the FP8 checkpoint is upcast first, so e4m3/e5m2 and bf16 heads
  share one geometry), built OFFLINE (numpy, deterministic seed) — see
  ``convert.build_shortlist`` for the checkpoint-facing entry point.
* ``shortlist_clusters`` — stage-1 scoring of the (C, D) BF16 centroids
  through ``ops.fused_topk`` itself: the centroids are one "chunk" of C
  pseudo-labels, so the streaming/merge contract (``ref.topk_merge``
  tie-breaks, sentinel slots) is reused verbatim, not re-implemented.
* ``save_shortlist_index`` / ``load_shortlist_index`` — persisted beside
  checkpoints with the SAME leaf integrity scheme as ``checkpoint.ckpt``
  (raw-bit .npy leaves + per-leaf crc32 in a manifest + a COMMITTED
  marker holding the manifest crc; atomic tmp-dir rename).
* staleness: the index records the crc32 of the exact W bits it was
  built from (``is_stale``) — training moves W, the partition does not
  follow, recall decays; rebuild policy in DESIGN.md §11.

Stage 2 (the restricted exact scorer) lives in ``kernels/fused_topk.py``
(admitted-cluster block-skip) with ``ref.fused_topk_ref`` as its
bit-exact oracle; ``head.serving`` wires both stages under
``HeadPlan.topk_path == "shortlist"``.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.losses import NEG_INF
from repro.head.config import ELMOHeadConfig
from repro.head.state import HeadState
from repro.kernels import tuning as _tuning

_FORMAT = "elmo-shortlist-v1"


class ShortlistError(RuntimeError):
    """Raised for torn/corrupt/incompatible persisted shortlist indices."""


class ShortlistIndex(NamedTuple):
    """The 2-level label partition stage-2 serving closes over.

    ``centroids``: (n_clusters, D) BF16 cluster means of the BF16-cast W
    rows.  ``assign``: (num_chunks, chunk) int32 cluster id per padded
    label row — real labels carry ids in [0, n_clusters); padded rows are
    -1, which can never match a beam entry.  ``beam`` is the default
    stage-1 width (admitted clusters per query).  ``w_checksum`` is the
    crc32 of the exact W bits the partition was built from — the
    staleness contract (``is_stale``)."""
    centroids: jax.Array
    assign: jax.Array
    n_clusters: int
    beam: int
    w_checksum: str


def _w_checksum(state: HeadState) -> str:
    from repro.checkpoint import ckpt as _ckpt
    return _ckpt._checksum(_ckpt._to_numpy(jnp.asarray(state.w)))


def is_stale(index: ShortlistIndex, state: HeadState) -> bool:
    """True when ``state.w`` no longer carries the bits the index was
    built from.  Serving a stale index is *correct* (stage 2 is exact on
    whatever it admits) but its recall is unquantified — rebuild after
    weight updates (DESIGN.md §11)."""
    return index.w_checksum != _w_checksum(state)


# ---------------------------------------------------------------------------
# offline build: balanced k-means over W rows (numpy, deterministic)
# ---------------------------------------------------------------------------


def _balanced_assign(rows: np.ndarray, cent: np.ndarray,
                     cap: int) -> np.ndarray:
    """Greedy capacity-constrained nearest-centroid assignment.

    Labels are visited in ascending best-distance order (confident labels
    claim their cluster first) and take the nearest centroid with free
    capacity — the standard balanced-k-means heuristic; with cap =
    ceil(L/C) every cluster ends within one label of balance."""
    d = ((rows * rows).sum(1, keepdims=True)
         - 2.0 * (rows @ cent.T)
         + (cent * cent).sum(1)[None, :])            # (L, C) squared dists
    order = np.argsort(d.min(axis=1), kind="stable")
    pref = np.argsort(d, axis=1, kind="stable")
    counts = np.zeros(cent.shape[0], np.int64)
    assign = np.empty(rows.shape[0], np.int64)
    for lab in order:
        for c in pref[lab]:
            if counts[c] < cap:
                assign[lab] = c
                counts[c] += 1
                break
    return assign


# above this many (L × C) distance-matrix entries the flat builder would
# not fit in host memory (2.8M labels × 8192 clusters is 172 GiB of f64);
# switch to the O(L·D)-memory hierarchical splitter
_FLAT_BUILD_MAX = 1 << 24


def _hierarchical_assign(rows: np.ndarray, n_clusters: int,
                         rng: np.random.Generator,
                         iters: int) -> np.ndarray:
    """Parabel-style recursive balanced 2-means for paper-scale L.

    Each node splits its labels into two halves sized proportionally to
    the leaf counts below (so every leaf ends within one label of L/C):
    Lloyd-iterate two centers, order labels by the margin d₀ − d₁
    (stable), send the first ``n_left`` to the left child.  Memory is
    O(L·D) — never an (L, C) matrix — and the recursion is sequential
    over a seeded generator, so the result is deterministic."""
    assign = np.zeros(rows.shape[0], np.int64)
    stack = [(np.arange(rows.shape[0]), 0, n_clusters)]
    while stack:
        idx, first_leaf, leaves = stack.pop()
        if leaves <= 1 or len(idx) <= 1:
            assign[idx] = first_leaf
            continue
        r = rows[idx].astype(np.float32)
        c = r[rng.choice(len(idx), size=2, replace=False)].copy()
        left_leaves = leaves // 2
        n_left = int(round(len(idx) * left_leaves / leaves))
        n_left = min(max(n_left, 1), len(idx) - 1)
        for _ in range(max(iters, 1)):
            d0 = ((r - c[0]) ** 2).sum(axis=1)
            d1 = ((r - c[1]) ** 2).sum(axis=1)
            order = np.argsort(d0 - d1, kind="stable")
            m0, m1 = order[:n_left], order[n_left:]
            c = np.stack([r[m0].mean(axis=0), r[m1].mean(axis=0)])
        stack.append((idx[m0], first_leaf, left_leaves))
        stack.append((idx[m1], first_leaf + left_leaves,
                      leaves - left_leaves))
    return assign


def build_shortlist_index(cfg: ELMOHeadConfig, state: HeadState, *,
                          n_clusters: Optional[int] = None,
                          beam: Optional[int] = None,
                          iters: int = 8, seed: int = 0) -> ShortlistIndex:
    """Balanced k-means over the head's W rows in BF16 — offline, host
    numpy (f64 accumulation so the result is stable across BLAS builds),
    seeded init, so one (cfg, state, seed) always yields one index.

    Geometry defaults come from ``tuning.shortlist_params`` (the serving
    residency/work model); pass ``n_clusters``/``beam`` to pin them (the
    golden fixture does).  Small problems run flat Lloyd + greedy
    capacity assignment; past ``_FLAT_BUILD_MAX`` distance entries the
    build switches to ``_hierarchical_assign`` (recursive balanced
    2-means, the Parabel/PLT construction) so multi-million-label heads
    cluster in O(L·D) host memory."""
    L, D = cfg.num_labels, cfg.d_model
    if n_clusters is None or beam is None:
        c_def, b_def = _tuning.shortlist_params(L, D)
        n_clusters = c_def if n_clusters is None else n_clusters
        beam = b_def if beam is None else beam
    n_clusters = int(min(max(n_clusters, 1), L))
    beam = int(min(max(beam, 1), n_clusters))
    rows = np.asarray(jnp.asarray(state.w).astype(jnp.bfloat16)
                      .astype(jnp.float32))
    rows = rows.reshape(cfg.padded_labels, D)[:L].astype(np.float64)
    rng = np.random.default_rng(seed)
    if L * n_clusters > _FLAT_BUILD_MAX:
        assign = _hierarchical_assign(rows, n_clusters, rng, iters)
        cent = np.zeros((n_clusters, D), np.float64)
        for c in range(n_clusters):
            m = assign == c
            if m.any():
                cent[c] = rows[m].mean(axis=0)
    else:
        cent = rows[rng.choice(L, size=n_clusters, replace=False)].copy()
        cap = -(-L // n_clusters)
        assign = _balanced_assign(rows, cent, cap)
        for _ in range(iters):
            for c in range(n_clusters):
                m = assign == c
                if m.any():
                    cent[c] = rows[m].mean(axis=0)
            assign = _balanced_assign(rows, cent, cap)
    asg = np.full((cfg.padded_labels,), -1, np.int32)
    asg[:L] = assign.astype(np.int32)
    centroids = jnp.asarray(cent.astype(np.float32)).astype(jnp.bfloat16)
    return ShortlistIndex(
        centroids=centroids,
        assign=jnp.asarray(asg.reshape(cfg.num_chunks, cfg.chunk)),
        n_clusters=n_clusters, beam=beam, w_checksum=_w_checksum(state))


def cluster_sizes(index: ShortlistIndex) -> np.ndarray:
    """(n_clusters,) member counts — the golden fixture pins these."""
    a = np.asarray(index.assign).reshape(-1)
    return np.bincount(a[a >= 0], minlength=index.n_clusters)


def synthetic_clustered_state(cfg: ELMOHeadConfig, *, groups: int = 128,
                              noise: float = 0.3, seed: int = 7
                              ) -> HeadState:
    """Deterministic structured head for recall fixtures and benches.

    An i.i.d.-Gaussian head has NO cluster structure — every label row is
    equidistant from every other in expectation — so a partition cannot
    route queries and shortlist recall is meaningless noise.  Trained XMC
    heads are the opposite (semantically related labels share direction;
    that structure is the entire PLT/X-Transformer premise), so the
    fixture draws rows around ``groups`` latent centers with ``noise``
    in-group spread, scaled 1/√D like ``init_head``, then quantized to
    the config's storage dtype.  Pure numpy from one seeded generator:
    the bits — and therefore the committed golden index built from them —
    are reproducible everywhere."""
    L, D = cfg.num_labels, cfg.d_model
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((groups, D))
    gid = rng.integers(0, groups, size=L)
    rows = centers[gid] + noise * rng.standard_normal((L, D))
    w = np.zeros((cfg.padded_labels, D), np.float32)
    w[:L] = rows / np.sqrt(D)
    w = jnp.asarray(w).reshape(cfg.num_chunks, cfg.chunk, D) \
        .astype(cfg.wdtype)
    comp = None
    if cfg.kahan_chunks:
        comp = jnp.zeros((cfg.kahan_chunks, cfg.chunk, D), jnp.bfloat16)
    return HeadState(w, comp)


# ---------------------------------------------------------------------------
# stage 1: centroid scoring through the fused_topk contract
# ---------------------------------------------------------------------------


def stage1_clusters(centroids: jax.Array, x: jax.Array, *,
                    n_clusters: int, beam: int,
                    impl: str = "auto") -> jax.Array:
    """(B, beam) admitted cluster ids per query, -1 in empty slots.

    The centroids are scored as ONE chunk of ``n_clusters`` pseudo-labels
    through ``ops.fused_topk`` — the same streaming/merge/tie-break
    contract (``ref.topk_merge``) as stage 2, so stage 1 needs no kernel
    of its own and inherits the sentinel semantics: overflow slots
    surface (NEG_INF, id 0) and are masked here to -1 so an unselected
    cluster 0 can never be admitted by accident.  Centroids are BF16 and
    score unquantized (``quantize_x=False``) regardless of the head's
    FP8 setting — stage 1 is a router, not the paper's scorer.

    Array-level so the sharded serving body (inside ``shard_map``) can
    call it on the replicated centroid leaf directly; use
    ``shortlist_clusters`` with a ``ShortlistIndex`` elsewhere."""
    from repro.kernels import ops as _ops
    vals, ids = _ops.fused_topk(
        x.astype(jnp.bfloat16), centroids[None],
        jnp.zeros((1,), jnp.uint32), jnp.zeros((1,), jnp.int32),
        k=beam, num_labels=n_clusters, quantize_x=False,
        drop_rate=0.0, impl=impl)
    return jnp.where(vals > NEG_INF / 2, ids, -1)


def shortlist_clusters(index: ShortlistIndex, x: jax.Array, *,
                       beam: Optional[int] = None,
                       impl: str = "auto") -> jax.Array:
    """``stage1_clusters`` over a built index (beam defaults to the
    index's)."""
    beam = index.beam if beam is None else int(beam)
    beam = min(max(beam, 1), index.n_clusters)
    return stage1_clusters(index.centroids, x, n_clusters=index.n_clusters,
                           beam=beam, impl=impl)


def full_beam(index: ShortlistIndex, batch: int) -> jax.Array:
    """(B, n_clusters) beam admitting every cluster — with it, the
    restricted top-k equals the exact top-k bit-for-bit (recall 1.0);
    the differential tests pin this."""
    return jnp.broadcast_to(
        jnp.arange(index.n_clusters, dtype=jnp.int32),
        (batch, index.n_clusters))


def shortlist_recall_at_k(cfg: ELMOHeadConfig, state: HeadState,
                          index: ShortlistIndex, x: jax.Array,
                          ks: Sequence[int] = (1, 5, 10), *,
                          impl: str = "xla") -> dict:
    """recall@k of shortlisted vs exact serving: mean over queries of
    |shortlisted top-k ∩ exact top-k| / k.  Quantifies what the beam
    excludes — the restricted result itself is exact on admitted labels,
    so recall is the ONLY quality axis the shortlist adds."""
    from repro.kernels import ops as _ops
    kmax = int(max(ks))
    xb = x.astype(jnp.bfloat16)
    seeds = jnp.zeros((cfg.num_chunks,), jnp.uint32)
    base = jnp.arange(cfg.num_chunks, dtype=jnp.int32) * cfg.chunk
    kw = dict(k=kmax, num_labels=cfg.num_labels, quantize_x=cfg.qx,
              drop_rate=0.0, impl=impl)
    ve, ie = _ops.fused_topk(xb, state.w, seeds, base, **kw)
    beam_ids = shortlist_clusters(index, xb, impl=impl)
    vs, is_ = _ops.fused_topk(xb, state.w, seeds, base,
                              assign=index.assign, beam=beam_ids, **kw)
    # sentinel slots must never count as hits: exact → -2, shortlist → -1
    ie = np.where(np.asarray(ve) > NEG_INF / 2, np.asarray(ie), -2)
    is_ = np.where(np.asarray(vs) > NEG_INF / 2, np.asarray(is_), -1)
    out = {}
    for k in ks:
        hit = (is_[:, :k, None] == ie[:, None, :k]).any(-1)
        out[int(k)] = float(hit.sum(-1).mean() / k)
    return out


# ---------------------------------------------------------------------------
# persistence: ckpt-style crc32 leaves, atomic commit
# ---------------------------------------------------------------------------


def save_shortlist_index(path: str, index: ShortlistIndex,
                         extra: Optional[dict] = None) -> str:
    """Persist the index as a committed directory beside checkpoints.

    Same integrity scheme as ``checkpoint.ckpt`` leaves: raw-bit .npy
    per array (BF16 stored as uint16 bits), per-leaf crc32 in
    ``manifest.json``, a ``COMMITTED`` marker holding the manifest crc,
    all staged in a tmp dir and atomically renamed."""
    from repro.checkpoint import ckpt as _ckpt
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"format": _FORMAT, "n_clusters": index.n_clusters,
                "beam": index.beam, "w_checksum": index.w_checksum,
                "extra": extra or {}, "leaves": []}
    for name in ("centroids", "assign"):
        arr = jnp.asarray(getattr(index, name))
        data = _ckpt._to_numpy(arr)
        fname = name + ".npy"
        np.save(os.path.join(tmp, fname), data)
        manifest["leaves"].append({
            "name": name, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "checksum": _ckpt._checksum(data)})
    mtext = json.dumps(manifest)
    _ckpt._fsync_write(os.path.join(tmp, "manifest.json"), mtext)
    _ckpt._fsync_write(os.path.join(tmp, "COMMITTED"), json.dumps(
        {"manifest_crc32": f"{zlib.crc32(mtext.encode()):08x}"}))
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


def load_shortlist_index(path: str, *, verify: bool = True
                         ) -> ShortlistIndex:
    """Load + integrity-check a persisted index.  Raises
    ``ShortlistError`` on a missing commit marker, torn manifest, crc
    mismatch, or unknown format — a corrupt index must never silently
    route serving."""
    from repro.checkpoint import ckpt as _ckpt
    if not os.path.exists(os.path.join(path, "COMMITTED")):
        raise ShortlistError(f"{path}: no COMMITTED marker")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            mtext = f.read()
        manifest = json.loads(mtext)
        with open(os.path.join(path, "COMMITTED")) as f:
            want = json.load(f).get("manifest_crc32")
    except (OSError, json.JSONDecodeError, ValueError) as e:
        raise ShortlistError(f"{path}: manifest unreadable ({e!r})")
    if want is not None and f"{zlib.crc32(mtext.encode()):08x}" != want:
        raise ShortlistError(f"{path}: manifest crc mismatch")
    if manifest.get("format") != _FORMAT:
        raise ShortlistError(
            f"{path}: unknown format {manifest.get('format')!r}")
    arrays = {}
    for entry in manifest["leaves"]:
        try:
            raw = np.load(os.path.join(path, entry["file"]))
        except (OSError, ValueError, EOFError) as e:
            raise ShortlistError(f"{entry['name']}: unreadable ({e!r})")
        if verify and _ckpt._checksum(raw) != entry["checksum"]:
            raise ShortlistError(f"{entry['name']}: checksum mismatch")
        arr = _ckpt._from_numpy(raw, entry["dtype"])
        arrays[entry["name"]] = jnp.asarray(arr).reshape(entry["shape"])
    for name in ("centroids", "assign"):
        if name not in arrays:
            raise ShortlistError(f"{path}: missing leaf {name!r}")
    return ShortlistIndex(
        centroids=arrays["centroids"].astype(jnp.bfloat16),
        assign=arrays["assign"].astype(jnp.int32),
        n_clusters=int(manifest["n_clusters"]),
        beam=int(manifest["beam"]),
        w_checksum=manifest.get("w_checksum", ""))
