"""Head state: weights + optional Kahan compensation, and their init.

The state is deliberately dumb — a NamedTuple of arrays — so it passes
through jit/shard_map/checkpointing untouched.  Everything clever lives in
``plan`` (decisions) and ``train``/``serving`` (execution).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import precision as P
from repro.head.config import ELMOHeadConfig


class HeadState(NamedTuple):
    """w: (C, Lc, D) in storage dtype; comp: (Ck, Lc, D) BF16 (App. D)."""
    w: jax.Array
    comp: Optional[jax.Array]


def init_head(key: jax.Array, cfg: ELMOHeadConfig, scale: float | None = None
              ) -> HeadState:
    scale = scale if scale is not None else 1.0 / np.sqrt(cfg.d_model)
    w = (jax.random.normal(key, (cfg.num_chunks, cfg.chunk, cfg.d_model),
                           jnp.float32) * scale).astype(cfg.wdtype)
    comp = (jnp.zeros((cfg.kahan_chunks, cfg.chunk, cfg.d_model), P.BF16)
            if cfg.kahan_chunks else None)
    return HeadState(w, comp)


def _resolve_ctx(ctx):
    """Active MeshContext (explicit arg wins) and its model-axis size."""
    from repro.dist import meshctx as _meshctx  # lazy: dist imports core
    ctx = _meshctx.get() if ctx is None else ctx
    return ctx, (1 if ctx is None else ctx.model_size)


def init_xg_err(cfg: ELMOHeadConfig, batch: int, ctx=None) -> jax.Array:
    """Per-shard E5M2 error-feedback carry for the compressed x̄ reduction:
    (model_size, B, D) BF16, row r owned by model rank r."""
    _, n = _resolve_ctx(ctx)
    return jnp.zeros((n, batch, cfg.d_model), P.BF16)


def state_bits_equal(a: HeadState, b: HeadState) -> bool:
    """Bitwise equality of two head states — the resume-determinism
    contract (DESIGN.md §10).  FP8 W and the BF16 Kahan compensation
    compare as raw bits: float comparison would call two states "equal"
    whose Kahan carries differ in the low bits that make pure-low-precision
    training stable."""
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    for x, y in zip(la, lb):
        xa, ya = np.asarray(x), np.asarray(y)
        if xa.shape != ya.shape or xa.dtype != ya.dtype:
            return False
        if xa.tobytes() != ya.tobytes():
            return False
    return True
