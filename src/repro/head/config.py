"""Head configuration types: ``ELMOHeadConfig`` and ``HeadHparams``.

``ELMOHeadConfig`` is the *statement of intent* — label geometry, storage
precision, loss, residency knobs.  How that intent executes on a given
(batch, mesh, backend) is decided exactly once by ``repro.head.plan``
(DESIGN.md §8); nothing in this module inspects the runtime.

``HeadHparams`` replaces the historical ``(lr, wd, seed)`` positional
threading through every step function with one typed, jit-transparent
pytree.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax

from repro.core import precision as P

_WEIGHT_DTYPES = {"bf16": P.BF16, "e4m3": P.E4M3, "e5m2": P.E5M2,
                  "f32": P.F32}


@dataclasses.dataclass(frozen=True)
class ELMOHeadConfig:
    num_labels: int
    d_model: int
    num_chunks: int = 8
    weight_dtype: str = "bf16"         # "bf16" | "e4m3" | "e5m2" | "f32"
    loss: str = "bce"                  # "bce" (XMC) | "softmax_ce" (LM)
    use_sr: bool = True                # stochastic rounding in the update
    kahan_chunks: int = 0              # leading chunks w/ Kahan comp (App. D)
    drop_rate: float = 0.0             # in-kernel DropConnect (App. H)
    quantize_x: Optional[bool] = None  # default: True iff weight is e4m3
    compute_loss: bool = True          # loss value is optional (loss-skip)
    # impl selects "<path>[_<inner>]" where path is one of
    #   grid    — whole-head grid megakernel, ONE launch per step
    #             (kernels/fused_head.py, DESIGN.md §7) — the default
    #   fused   — PR-1 per-chunk scan of the single-launch chunk kernel
    #             (kernels/fused_chunk.py) — the grid path's bit-parity
    #             oracle
    #   unfused — legacy 3-kernel composition, kept for A/B
    # and inner is auto|kernel|interpret|xla.  Bare inner names ("auto",
    # "xla", "interpret", …) select the grid path with that inner impl;
    # a grid path whose inner resolves to "xla" runs the fused scan (the
    # two are the same algorithm — the grid kernel has no jnp oracle of
    # its own).  ``repro.head.plan.resolve_plan`` turns this string into
    # an executed path exactly once per (config, batch, mesh, backend).
    impl: str = "auto"
    # softmax-CE only: reuse the LSE pre-pass logits in pass 2 ("on"/"off",
    # or "auto" = on when the z cache fits plan._CACHE_Z_BYTES)
    cache_z: str = "auto"
    # Serving historically applied DropConnect with a constant seed-0 mask
    # (``serving._eval_seeds``) — a head trained with drop_rate > 0 served
    # through one fixed random mask, which is neither train-time averaging
    # nor standard eval.  Serving now defaults to drop_rate = 0 (standard
    # "scale at train time, dense at eval" DropConnect); set True to
    # reproduce the historical seed-0-masked serving outputs bit-for-bit
    # (the pre-ISSUE-5 parity goldens).  Training is unaffected.
    compat_eval_drop: bool = False
    # 2-stage shortlisted serving (DESIGN.md §11): "off" serves exact,
    # "on" plans the shortlist path whenever the restricted kernel is
    # viable, "auto" enables it only at label counts where the √L
    # partition pays (``plan._SHORTLIST_MIN_LABELS``).  Serving-only:
    # training never shortlists, and serving falls back to the exact
    # path when no index is attached.
    shortlist: str = "off"
    # fixed-fan-in sparse head (DESIGN.md §13): 0 = dense; > 0 stores each
    # label row as ``fan_in`` FP8 value slots + i32 column indices and
    # plans the sparse streaming megakernel (kernels/sparse_head.py).
    # ``fan_in == d_model`` with identity indices is the dense-parity
    # anchor.  Sparse requires a *homogeneous* update rule —
    # ``kahan_chunks`` must be 0 or num_chunks — matching the grid path.
    fan_in: int = 0
    # prune/regrow cadence in steps (head.sparse.controller): every
    # ``prune_every`` steps the lowest-|value| ``round(fan_in ·
    # regrow_frac)`` slots per row are re-pointed at the highest-|grad|
    # dense columns.  0 = static sparsity.
    prune_every: int = 0
    regrow_frac: float = 0.1
    # numerics guard (DESIGN.md §14): when True, every train-step path
    # emits an 8-slot telemetry vector (saturation count of the W update,
    # non-finite z/LSE/x̄ counts, max |Kahan comp|) accumulated in VMEM
    # scratch alongside the step.  The counters are *bitwise invisible* to
    # W/comp/x̄/loss — guard-on ≡ guard-off on the 20-step goldens.
    guard: bool = False
    # saturation-fraction trip threshold consumed by numerics.NumericsMonitor
    # (fraction of W-update elements whose pre-cast f32 value lies at or
    # beyond the storage dtype's max finite — the e4m3 cliff is ±448).
    guard_sat_frac: float = 0.05

    @property
    def wdtype(self):
        return _WEIGHT_DTYPES[self.weight_dtype]

    @property
    def qx(self) -> bool:
        return self.weight_dtype == "e4m3" if self.quantize_x is None \
            else self.quantize_x

    # label rows per chunk are padded to a multiple of _CHUNK_ALIGN so the
    # chunk dimension stays divisible by the mesh's model axis (vocab-
    # parallel sharding) and by MXU tile sizes
    _CHUNK_ALIGN = 256

    @property
    def chunk(self) -> int:
        c = self.num_chunks
        per = (self.num_labels + c - 1) // c
        if self.num_labels >= self._CHUNK_ALIGN:
            per = ((per + self._CHUNK_ALIGN - 1) // self._CHUNK_ALIGN
                   ) * self._CHUNK_ALIGN
        return per

    @property
    def padded_labels(self) -> int:
        return self.chunk * self.num_chunks

    def __post_init__(self):
        assert 0 <= self.kahan_chunks <= self.num_chunks
        assert self.loss in ("bce", "softmax_ce")
        assert self.cache_z in ("auto", "on", "off")
        assert self.shortlist in ("off", "on", "auto")
        assert 0 <= self.fan_in <= self.d_model, \
            f"fan_in {self.fan_in} outside [0, d_model={self.d_model}]"
        if self.fan_in:
            assert self.kahan_chunks in (0, self.num_chunks), \
                "sparse head needs a homogeneous update rule " \
                "(kahan_chunks 0 or num_chunks)"
        assert 0.0 <= self.regrow_frac <= 1.0
        assert self.prune_every >= 0
        if self.prune_every:
            assert self.fan_in, "prune_every needs a sparse head (fan_in>0)"
        assert 0.0 < self.guard_sat_frac <= 1.0


class HeadHparams(NamedTuple):
    """Typed train-step hyperparameters (a jit-transparent pytree).

    ``seed`` is the *step* seed: per-chunk / per-microbatch streams are
    derived from it inside the step (``train._chunk_seed``,
    ``launch.steps._micro_seed``)."""
    lr: jax.Array | float
    wd: jax.Array | float = 0.0
    seed: jax.Array | int = 0


def default_target_slots(model_cfg) -> int:
    """The target-column count plan resolution should assume for a model:
    the sparse multi-label width P for BCE heads, 1 for LM (CE) heads.
    One derivation shared by the train/dryrun/bench/CLI call sites."""
    return (model_cfg.max_labels_per_example
            if model_cfg.head_loss == "bce" else 1)


def head_config_for(model_cfg, impl: str = "auto") -> ELMOHeadConfig:
    """The one ModelConfig → ELMOHeadConfig mapping (formerly re-derived at
    every call site as ``launch.steps.make_head_cfg``)."""
    return ELMOHeadConfig(
        num_labels=model_cfg.head_size,
        d_model=model_cfg.d_model,
        num_chunks=model_cfg.head_chunks,
        weight_dtype=model_cfg.head_weight_dtype,
        loss=model_cfg.head_loss,
        kahan_chunks=model_cfg.head_kahan_chunks,
        impl=impl,
        fan_in=getattr(model_cfg, "head_fan_in", 0),
        prune_every=getattr(model_cfg, "head_prune_every", 0),
        guard=getattr(model_cfg, "head_guard", False),
    )
