"""Label-sharded ELMO head training (DESIGN.md §6), plan-driven.

``train_step_sharded_planned`` runs the single-device step with the label
dimension sharded over the mesh's model axis (vocab parallelism, per
``HeadPlan.w_spec`` / ``dist.sharding.head_specs``): every model rank
holds ``chunk/n`` rows of each chunk (W and the Kahan buffer partitioned
identically) and runs the whole-head grid megakernel (one launch for BCE,
two for softmax-CE with the normalizer collective between them) or, off
the grid path, the per-chunk fused kernel scan on its local shard.  The
batch is gathered over the data axes so the in-kernel weight update sees
full-B gradients — W updates stay deterministic and need no cross-data
all-reduce.  Per-shard x̄ partials are ``psum``-reduced over the model
axis (optionally E5M2-compressed with error feedback).

Softmax-CE couples shards through the row normalizer; ``ce_comm`` picks
the cross-device LSE strategy (DESIGN.md §6):

* ``"gather"`` (default) — the pass-1 logits of each chunk are
  all-gathered (BF16, column-tiled) and the streaming LSE + the loss
  run on the full-width rows: weights, Kahan state and the loss are
  **bit-identical** to the single-device step for deterministic updates
  (BF16 Kahan / no-SR).  Comm: B·L·2 bytes/step.
* ``"stats"`` — each shard folds a local (max, Σexp) over its label
  windows, then one ``pmax`` + one rescaled ``psum`` form the global
  log-normalizer: comm is O(B) but sums reassociate (parity to ~1e-6).

Every static decision (grid vs scan, inner impl, z-cache, specs) comes
from the ``HeadPlan`` — this module performs no impl resolution.  SR and
DropConnect draws are hashed per *local* tile, so low-precision SR runs
match single-device only distributionally (the paper's own guarantee,
App. C).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as PS

from repro.core import losses as L
from repro.head import plan as _plan
from repro.head.config import ELMOHeadConfig
from repro.head.state import HeadState, _resolve_ctx
from repro.head.train import (_chunk_logits, _chunk_seed, _finalize_step,
                              _masked_z, _scan_chunks, _valid_cols,
                              train_step_planned)
from repro.kernels import ops
from repro.kernels import prng_utils as PR
from repro.numerics import telemetry as NT


def train_step_sharded_planned(plan: "_plan.HeadPlan", cfg: ELMOHeadConfig,
                               ctx, state: HeadState, x: jax.Array,
                               targets: jax.Array, lr: jax.Array,
                               wd: jax.Array, seed: jax.Array, *,
                               ce_comm: str = "gather",
                               compress_xg: bool = False,
                               xg_err: Optional[jax.Array] = None):
    """The sharded step on the path ``plan`` selected.  Falls back to the
    single-device step when the plan resolved to single-device semantics
    (no mesh, model axis of 1, or an indivisible chunk)."""
    from repro.dist.compat import shard_map as _shard_map

    assert ce_comm in ("gather", "stats"), ce_comm
    assert xg_err is None or compress_xg, "xg_err implies compress_xg"
    if not plan.sharded:
        out = train_step_planned(plan, cfg, state, x, targets, lr, wd, seed)
        return out if xg_err is None else out + (xg_err,)

    n = plan.model_size
    mesh, axis = ctx.mesh, ctx.model_axis
    batch_axes = tuple(a for a in ctx.batch_axes
                      if a in mesh.shape and mesh.shape[a] > 1)
    n_batch = 1
    for a in batch_axes:
        n_batch *= int(mesh.shape[a])
    if x.shape[0] % n_batch != 0:
        batch_axes, n_batch = (), 1      # ragged batch: replicate instead
    b0 = batch_axes if batch_axes else None

    lc = plan.lc
    # grid path: ONE whole-head launch per collective-free pass (BCE = 1
    # launch; CE = LSE launch + collective + update launch, ≤ 2) — decided
    # by the plan, which also downgraded the scan inner to "xla" when the
    # compiled megakernel would not fit VMEM at this global batch.
    grid = plan.path == "grid"
    impl = plan.train_inner
    cache_z = plan.cache_z

    kahan = cfg.kahan_chunks > 0
    chunk_ids = jnp.arange(cfg.num_chunks, dtype=jnp.int32)
    has_err = xg_err is not None

    def body(*args):
        it = iter(args)
        w = next(it)
        comp = next(it) if kahan else None
        xl, tgt = next(it), next(it)
        lr_, wd_, seed_ = next(it), next(it), next(it)
        err = next(it) if has_err else None          # (1, B, D) local slice

        Bl = xl.shape[0]
        for a in reversed(batch_axes):   # innermost batch axis first
            xl = jax.lax.all_gather(xl, a, axis=0, tiled=True)
            tgt = jax.lax.all_gather(tgt, a, axis=0, tiled=True)
        x16 = xl.astype(jnp.bfloat16)
        B = x16.shape[0]
        r = jax.lax.axis_index(axis)
        # independent SR/DropConnect stream per shard: kernel bits are
        # hashed by the *local* tile index, so shards must not share seeds
        seed_sh = PR.mix32(seed_.astype(jnp.uint32)
                           + (r.astype(jnp.uint32) + 1)
                           * np.uint32(0x85EBCA6B))

        def c0_of(cidx):
            return cidx * cfg.chunk + r.astype(jnp.int32) * lc

        kernel_loss = cfg.compute_loss and ce_comm == "stats"

        if grid:
            # ---- whole-head grid-megakernel branch (DESIGN.md §7) ----
            seeds_d = _chunk_seed(seed_sh, chunk_ids, 0)
            seeds_u = _chunk_seed(seed_sh, chunk_ids, 1)
            base = chunk_ids * cfg.chunk + r.astype(jnp.int32) * lc
            gkw = dict(num_labels=cfg.num_labels, use_sr=cfg.use_sr,
                       quantize_x=cfg.qx, drop_rate=cfg.drop_rate,
                       impl=impl, guard=cfg.guard)
            lse = None
            if cfg.loss == "bce":
                scale = jnp.float32(1.0 / B)
                # gather-mode loss needs the (pre-update) local logits:
                # the single launch emits them alongside the update
                want_z = cfg.compute_loss and ce_comm == "gather"
                out = ops.fused_head_step(
                    x16, w, tgt, lr_, wd_, scale, seeds_d, seeds_u, base,
                    comp=comp, mode="bce", cache_z=want_z,
                    compute_loss=kernel_loss, **gkw)
                loss_raw = out.loss
                if want_z:
                    z3 = jnp.moveaxis(
                        out.z.reshape(B, cfg.num_chunks, lc), 1, 0)

                    def loss_body(acc, inp):
                        zl, cidx = inp
                        zf = jax.lax.all_gather(zl, axis, axis=1,
                                                tiled=True)
                        y = L.chunk_multi_hot(tgt, cidx * cfg.chunk,
                                              cfg.chunk)
                        return acc + L.bce_chunk_loss(
                            zf, y, mask=_valid_cols(cfg, cidx)[None, :]), \
                            None

                    loss_raw, _ = jax.lax.scan(
                        loss_body, jnp.float32(0.0), (z3, chunk_ids))
            else:
                n_tok = jnp.maximum((tgt >= 0).sum(), 1
                                    ).astype(jnp.float32)
                scale = 1.0 / n_tok
                loss_pre = jnp.float32(0.0)
                if ce_comm == "gather":
                    # launch 1: all local logits; LSE + exact loss on the
                    # per-chunk gathered rows, op-for-op the single-device
                    # sequence (the bit-parity contract)
                    zflat = ops.fused_head_logits(
                        x16, w, seeds_d, quantize_x=cfg.qx,
                        drop_rate=cfg.drop_rate, impl=impl)
                    z3 = jnp.moveaxis(
                        zflat.reshape(B, cfg.num_chunks, lc), 1, 0)

                    def lse_body(carry, inp):
                        zl, cidx = inp
                        m, s, lraw = carry
                        zf = jax.lax.all_gather(zl, axis, axis=1,
                                                tiled=True)
                        m, s = L.lse_update(m, s, _masked_z(cfg, zf, cidx))
                        if cfg.compute_loss:
                            lraw = lraw + L.ce_target_logit_chunk(
                                zf, tgt, cidx * cfg.chunk, cfg.chunk).sum()
                        return (m, s, lraw), None

                    (m, s, loss_pre), _ = jax.lax.scan(
                        lse_body, L.lse_init(B) + (jnp.float32(0.0),),
                        (z3, chunk_ids))
                    lse = L.lse_finalize(m, s)
                else:
                    # launch 1: in-kernel local streaming (max, Σexp),
                    # then the O(B) pmax/psum normalizer collective
                    st = ops.fused_head_lse(
                        x16, w, seeds_d, base, num_labels=cfg.num_labels,
                        quantize_x=cfg.qx, drop_rate=cfg.drop_rate,
                        cache_z=cache_z, impl=impl)
                    m_g = jax.lax.pmax(st.m, axis)
                    s_g = jax.lax.psum(st.s * jnp.exp(st.m - m_g), axis)
                    lse = L.lse_finalize(m_g, s_g)
                    zflat = st.z
                # launch 2: the whole-head update against the global LSE
                out = ops.fused_head_step(
                    x16, w, tgt, lr_, wd_, scale, seeds_d, seeds_u, base,
                    lse=lse, z=zflat, comp=comp, mode="ce_update",
                    cache_z=zflat is not None, compute_loss=kernel_loss,
                    **gkw)
                loss_raw = loss_pre + out.loss
            xg_loc = out.xg
            w_k = out.w if kahan else w[:0]
            w_s = w[:0] if kahan else out.w
            comp_new = out.comp
            tele_loc = out.tele
        else:
            # ---- per-chunk scan branch (fused_chunk_step per chunk) ----
            loss_pre = jnp.float32(0.0)
            if cfg.loss == "bce":
                scale = jnp.float32(1.0 / B)
                lse, zs = None, None
            else:
                n_tok = jnp.maximum((tgt >= 0).sum(), 1).astype(jnp.float32)
                scale = 1.0 / n_tok
                cache = cache_z

                if ce_comm == "gather":
                    # pass 1: full-width streaming LSE on gathered chunk logits
                    # (identical op sequence to the single-device pass — the
                    # source of the bit-parity guarantee); the CE target-logit
                    # sum rides along so the loss is exact too
                    def lse_body(carry, inp):
                        wc, cidx = inp
                        m, s, lraw = carry
                        zl = _chunk_logits(cfg, wc, x16,
                                           _chunk_seed(seed_sh, cidx, 0), impl)
                        zf = jax.lax.all_gather(zl, axis, axis=1, tiled=True)
                        m, s = L.lse_update(m, s, _masked_z(cfg, zf, cidx))
                        if cfg.compute_loss:
                            lraw = lraw + L.ce_target_logit_chunk(
                                zf, tgt, cidx * cfg.chunk, cfg.chunk).sum()
                        return (m, s, lraw), (zl if cache else None)

                    (m, s, loss_pre), zs = jax.lax.scan(
                        lse_body, L.lse_init(B) + (jnp.float32(0.0),),
                        (w, chunk_ids))
                else:
                    # pass 1 (stats): local (max, Σexp) over this shard's label
                    # windows, then pmax + one rescaled psum — O(B) comm
                    def lse_body(carry, inp):
                        wc, cidx = inp
                        m, s = carry
                        zl = _chunk_logits(cfg, wc, x16,
                                           _chunk_seed(seed_sh, cidx, 0), impl)
                        validl = (c0_of(cidx) + jnp.arange(lc)) < cfg.num_labels
                        zm = jnp.where(validl[None, :], zl.astype(jnp.float32),
                                       L.NEG_INF)
                        return L.lse_update(m, s, zm), (zl if cache else None)

                    (m, s), zs = jax.lax.scan(lse_body, L.lse_init(B),
                                              (w, chunk_ids))
                    m_g = jax.lax.pmax(m, axis)
                    s_g = jax.lax.psum(s * jnp.exp(m - m_g), axis)
                    m, s = m_g, s_g
                lse = L.lse_finalize(m, s)

            def chunk_step(xg, loss_acc, *rest):
                tele, (wc, comp_c, cidx, z_c) = (
                    (rest[0], rest[1:]) if cfg.guard else (None, rest))
                if cfg.loss == "bce" and ce_comm == "gather":
                    z_c = _chunk_logits(cfg, wc, x16,
                                        _chunk_seed(seed_sh, cidx, 0), impl)
                    if cfg.compute_loss:
                        zf = jax.lax.all_gather(z_c, axis, axis=1, tiled=True)
                        y = L.chunk_multi_hot(tgt, cidx * cfg.chunk, cfg.chunk)
                        loss_acc = loss_acc + L.bce_chunk_loss(
                            zf, y, mask=_valid_cols(cfg, cidx)[None, :])
                out = ops.fused_chunk_step(
                    x16, wc, tgt, xg, lr_, wd_, scale, c0_of(cidx),
                    _chunk_seed(seed_sh, cidx, 0), _chunk_seed(seed_sh, cidx, 1),
                    lse=lse, z=z_c, comp=comp_c, loss=cfg.loss,
                    num_labels=cfg.num_labels, use_sr=cfg.use_sr,
                    quantize_x=cfg.qx, drop_rate=cfg.drop_rate,
                    compute_loss=kernel_loss, impl=impl, guard=cfg.guard)
                head = (out.xg, loss_acc + out.loss)
                if cfg.guard:
                    head += (NT.combine(tele, out.tele),)
                return head + (out.w, out.comp)

            carry = (jnp.zeros((B, cfg.d_model), jnp.bfloat16), loss_pre)
            if cfg.guard:
                carry += (NT.zero(),)
            carry, w_k, w_s, comp_new = _scan_chunks(cfg, w, comp, chunk_ids,
                                                     zs, carry, chunk_step)
            xg_loc, loss_raw = carry[0], carry[1]
            tele_loc = carry[2] if cfg.guard else None

        if ce_comm == "stats" and cfg.compute_loss:
            loss_raw = jax.lax.psum(loss_raw, axis)

        # ---- cross-shard x̄ reduction (optionally E5M2 on the wire) ----
        err_new = err
        if compress_xg:
            from repro.dist import compression as C
            if err is not None:
                cpr, e = C.compress_with_feedback(xg_loc, err[0])
                err_new = e[None]
            else:
                cpr = C.compress(xg_loc)
            payloads = jax.lax.all_gather(cpr.payload, axis)   # (n, B·D) e5m2
            scales = jax.lax.all_gather(cpr.scale, axis)       # (n,)
            xg32 = (payloads.astype(jnp.float32) * scales[:, None]).sum(0)
            xg_comb = xg32.reshape(B, cfg.d_model).astype(jnp.bfloat16)
        else:
            xg_comb = jax.lax.psum(xg_loc.astype(jnp.float32), axis
                                   ).astype(jnp.bfloat16)

        fin_carry = (xg_comb, loss_raw)
        if cfg.guard:
            # counts (slots 0–3) sum across label shards, the comp max
            # maxes; the LSE/x̄ slots come from the replicated final
            # outputs inside _finalize_step — identical on every shard
            slot = jnp.arange(tele_loc.shape[0])
            fin_carry += (jnp.where(slot == NT.SLOTS["comp_max"],
                                    jax.lax.pmax(tele_loc, axis),
                                    jax.lax.psum(tele_loc, axis)),)
        st_new, xg_full, metrics = _finalize_step(
            cfg, fin_carry, w_k, w_s, comp_new, tgt, lse, scale, B)

        if batch_axes:   # hand back only this rank's batch rows
            bidx = jnp.int32(0)
            for a in batch_axes:
                bidx = bidx * mesh.shape[a] + jax.lax.axis_index(a)
            xg_out = jax.lax.dynamic_slice_in_dim(xg_full, bidx * Bl, Bl, 0)
        else:
            xg_out = xg_full

        outs = [st_new.w]
        if kahan:
            outs.append(st_new.comp)
        outs += [xg_out, metrics["loss"], metrics["xgrad_norm"]]
        if cfg.guard:
            outs.append(metrics["telemetry"])
        if has_err:
            outs.append(err_new)
        return tuple(outs)

    wspec = plan.w_spec
    tgt_spec = PS(b0, None) if targets.ndim == 2 else PS(b0)
    operands = [state.w] + ([state.comp] if kahan else []) + [
        x, targets, jnp.asarray(lr, jnp.float32),
        jnp.asarray(wd, jnp.float32), jnp.asarray(seed).astype(jnp.uint32)]
    in_specs = [wspec] + ([wspec] if kahan else []) + [
        PS(b0, None), tgt_spec, PS(), PS(), PS()]
    out_specs = [wspec] + ([wspec] if kahan else []) + [
        PS(b0, None), PS(), PS()]
    if cfg.guard:
        out_specs.append(PS())
    if has_err:
        operands.append(xg_err)
        in_specs.append(plan.xg_err_spec)
        out_specs.append(plan.xg_err_spec)

    outs = _shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                      out_specs=tuple(out_specs), check_vma=False)(*operands)
    it = iter(outs)
    w_new = next(it)
    comp_new = next(it) if kahan else None
    xg, loss, xnorm = next(it), next(it), next(it)
    metrics = {"loss": loss, "xgrad_norm": xnorm}
    if cfg.guard:
        metrics["telemetry"] = next(it)
    ret = (HeadState(w_new, comp_new), xg, metrics)
    return ret + ((next(it),) if has_err else ())


# ---------------------------------------------------------------------------
# legacy free-function surface (deprecated; the facade pre-resolves)
# ---------------------------------------------------------------------------


def head_train_step_sharded(cfg: ELMOHeadConfig, state: HeadState,
                            x: jax.Array, targets: jax.Array, lr: jax.Array,
                            wd: jax.Array, seed: jax.Array, ctx=None, *,
                            ce_comm: str = "gather",
                            compress_xg: bool = False,
                            xg_err: Optional[jax.Array] = None):
    """Deprecated free-function form: resolves a ``HeadPlan`` per call
    (memoized) against the ambient/explicit ``MeshContext`` and runs the
    planned sharded step.  Prefer ``repro.head.ELMOHead``."""
    ctx, n = _resolve_ctx(ctx)
    plan = _plan.resolve_plan(
        cfg, batch=x.shape[0], target_slots=_plan._target_slots(targets),
        model_size=n, model_axis=None if ctx is None else ctx.model_axis,
        ce_comm=ce_comm)
    return train_step_sharded_planned(plan, cfg, ctx, state, x, targets,
                                      lr, wd, seed, ce_comm=ce_comm,
                                      compress_xg=compress_xg, xg_err=xg_err)
