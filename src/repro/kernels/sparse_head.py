"""Pallas TPU megakernel: fixed-fan-in sparse head train step in one launch.

The sparse head stores each label row as ``fan_in`` FP8 value slots plus
their i32 column indices (DESIGN.md §13) — a dense ``(L, fan_in)`` pair
that streams through the same grid machinery as ``fused_head``: the grid
iterates over all label blocks of all chunks, Pallas double-buffers the
value/index (and Kahan ``comp``) streams, and x, the running x̄, the
targets, the loss accumulator, and the CE streaming-LSE statistics stay
resident in VMEM scratch across every grid step.

Per label block the kernel *densifies in-register*: the ``(bl, F)`` value
slots are scattered into a ``(bl, Dp)`` BF16 tile via an unrolled
where-select chain (F static steps; indices are sorted-unique per row, a
``-1`` marks a padded slot and selects nothing), and the block then runs
the *identical* dense compute — q8(X) @ Wᵀ on the MXU, DropConnect drawn
from the dense ``(row, col)`` hash, the same loss-skip gradients, and the
dense ``ḡᵀX`` weight gradient — before gathering the ``fan_in`` columns
back out for the in-place SR/Kahan update (via input_output_aliases on
the value/comp streams; the index stream is read-only — prune/regrow
mutates it *outside* the step).

Two constructions make this bit-exact rather than merely close:

* densify uses iterated **select**, never add (``0.0 + (-0.0)`` would
  flip the sign of zero), and gather-back masks in the **i32 bit
  pattern** (a float masked-sum loses ``-0.0``) — see ``ref.sparse_densify``
  / ``ref.sparse_gather_cols``, which the kernel body calls directly;
* SR bits come from ``prng_utils.hash_bits_at(seed, off, idx)`` — the
  dense hash evaluated at the gathered (row, index) coordinates — and
  DropConnect from the dense ``hash_bits_2d`` on the densified tile, so
  every stochastic draw matches the dense kernel at the same coordinate.

Consequently at ``fan_in = D`` with identity indices every intermediate
— z, ḡ, x̄, dW, SR/Kahan bits — is bitwise the dense ``fused_head`` grid
path, which is the subsystem's parity anchor.  The win is memory and
weight-stream bandwidth (HBM traffic scales with F, weight+optimizer
state shrinks D/F-fold), not FLOPs: the MXU dots stay dense-shaped per
block.

``ref.sparse_head_step_ref`` is the pure-JAX oracle (and the production
``impl="xla"`` path): a scan of ``sparse_chunk_ref`` with the same
per-chunk seed addressing and accumulation order, bit-identical to this
kernel with one block per chunk.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.losses import NEG_INF
from repro.core import precision as P
from repro.kernels import prng_utils as PR
from repro.kernels import ref as REF
from repro.kernels import tuning
from repro.kernels.fused_head_update import _apply_sr

_UPDATE_MODES = ("bce", "ce_full", "ce_update")


class SparseStepOut(NamedTuple):
    """Results of one whole-head sparse grid step."""
    values: jax.Array                 # updated value slots (C, lc, F)
    xg: jax.Array                     # x̄ (B, D) bf16
    loss: jax.Array                   # f32 scalar raw loss accumulator
    comp: Optional[jax.Array] = None  # updated Kahan buffer (C, lc, F)
    lse: Optional[jax.Array] = None   # (B,) f32 (mode="ce_full" only)
    tele: Optional[jax.Array] = None  # (8,) f32 guard telemetry (guard=True)


def _sparse_kernel(*refs, mode: str, num_labels: int, lc: int, bpc: int,
                   n_b: int, fan_in: int, kahan: bool, use_sr: bool,
                   quantize_x: bool, drop_rate: float, compute_loss: bool,
                   guard: bool):
    # ---- unpack the mode-dependent ref list ----
    it = iter(refs)
    sd_ref, su_ref, hyper_ref = next(it), next(it), next(it)
    base_ref, tgt_ref = next(it), next(it)
    lse_in_ref = next(it) if mode == "ce_update" else None
    x_ref, v_ref, i_ref = next(it), next(it), next(it)
    comp_ref = next(it) if kahan else None
    v_out_ref = next(it)
    comp_out_ref = next(it) if kahan else None
    xg_out_ref, loss_ref = next(it), next(it)
    lse_out_ref = next(it) if mode == "ce_full" else None
    tele_ref = next(it) if guard else None
    xg_acc, xg_b16, loss_acc = next(it), next(it), next(it)
    if mode == "ce_full":
        m_acc, s_acc, lse_v = next(it), next(it), next(it)
    tele_acc = next(it) if guard else None

    if mode == "ce_full":
        pss, li = pl.program_id(0), pl.program_id(1)
        nb = pl.num_programs(1)
    else:
        pss, li = None, pl.program_id(0)
        nb = pl.num_programs(0)

    Bp, Dp = x_ref.shape
    bl = v_ref.shape[0]
    cidx = li // bpc                         # chunk of this label block
    off = (li % bpc) * bl                    # row offset inside the chunk
    # slice the streams to the logical fan-in: lane padding carries -1
    # indices / zero values, and keeping the loops at F avoids Fp − F
    # wasted (bl, Dp) selects per block
    v_blk = v_ref[...]
    idx = i_ref[...][:, :fan_in]
    vals = v_blk[:, :fan_in]
    w16 = REF.sparse_densify(vals, idx, Dp)  # (bl, Dp) bf16 densified tile
    x16 = x_ref[...].astype(jnp.bfloat16)

    col_local = jax.lax.broadcasted_iota(jnp.int32, (Bp, bl), 1) + off
    rowv = (jax.lax.broadcasted_iota(jnp.int32, (Bp, bl), 0)
            < n_b).astype(jnp.float32)
    col_global = col_local + base_ref[cidx]
    valid = ((col_global < num_labels)
             & (col_local < lc)).astype(jnp.float32)

    def compute_z16():
        """q8(X) @ densify(V, I)ᵀ — op-for-op the dense grid forward on
        the densified tile, DropConnect drawn at the dense coordinates."""
        xq = x_ref[...]
        if quantize_x:
            xq = xq.astype(jnp.float8_e4m3fn)
        xq = xq.astype(jnp.bfloat16)
        wmm = w16
        if drop_rate > 0.0:
            bits = PR.hash_bits_2d(sd_ref[cidx], off.astype(jnp.uint32),
                                   jnp.uint32(0), (bl, Dp))
            keep = PR.uniform_from_bits(bits) >= drop_rate
            wmm = jnp.where(keep, w16, jnp.bfloat16(0.0)) \
                / jnp.bfloat16(1.0 - drop_rate)
        z32mm = jax.lax.dot_general(xq, wmm, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        return z32mm.astype(jnp.bfloat16)

    def _write_stream(out_ref, new, blk):
        """Write the logical-F columns, preserving any lane padding."""
        if new.shape == out_ref.shape:
            out_ref[...] = new
        else:
            out_ref[...] = jnp.concatenate(
                [new, blk[:, new.shape[1]:]], axis=1)

    # ---- pass 0 work (CE): streaming (max, Σexp) in VMEM scratch ----
    def lse_work():
        z16 = compute_z16()
        zm = jnp.where(valid > 0, z16.astype(jnp.float32), NEG_INF)

        @pl.when(li == 0)
        def _init():
            m_acc[...] = jnp.full_like(m_acc, NEG_INF)
            s_acc[...] = jnp.zeros_like(s_acc)

        m = m_acc[...]
        m_new = jnp.maximum(m, zm.max(axis=-1, keepdims=True))
        s_acc[...] = (s_acc[...] * jnp.exp(m - m_new)
                      + jnp.exp(zm - m_new).sum(-1, keepdims=True))
        m_acc[...] = m_new

    # ---- update-pass work: grad, x̄, in-place value/comp update, loss ----
    def update_work():
        @pl.when(li == 0)
        def _init():
            xg_acc[...] = jnp.zeros_like(xg_acc)
            xg_b16[...] = jnp.zeros_like(xg_b16)
            loss_acc[...] = jnp.zeros_like(loss_acc)
            if guard:
                tele_acc[...] = jnp.zeros_like(tele_acc)

        z16 = compute_z16()
        z32 = z16.astype(jnp.float32)
        lr, wd, scale = hyper_ref[0], hyper_ref[1], hyper_ref[2]

        if mode == "bce":
            y = jnp.zeros((Bp, bl), jnp.float32)
            for slot in range(tgt_ref.shape[1]):
                y = jnp.maximum(
                    y, (col_global == tgt_ref[:, slot:slot + 1]
                        ).astype(jnp.float32))
            g32 = (jax.nn.sigmoid(z32) - y) * scale * valid * rowv
            if compute_loss:
                per = (jnp.maximum(z32, 0.0) - z32 * y
                       + jnp.log1p(jnp.exp(-jnp.abs(z32))))
                loss_acc[0, 0] += jnp.sum(per * valid * rowv)
        else:
            tid = tgt_ref[...]                              # (Bp, 1) int32
            onehot = (col_global == tid).astype(jnp.float32)
            tokm = (tid >= 0).astype(jnp.float32)           # (Bp, 1)
            lse_row = (lse_in_ref[...] if mode == "ce_update"
                       else lse_v[...])
            prob = jnp.exp(z32 - lse_row)
            g32 = (prob - onehot) * scale * valid * tokm * rowv
            if compute_loss:
                loss_acc[0, 0] += jnp.sum(z32 * onehot * rowv)

        g16 = g32.astype(jnp.bfloat16)
        xg_acc[...] += jnp.dot(g16, w16, preferred_element_type=jnp.float32)

        @pl.when((li + 1) % bpc == 0)
        def _chunk_flush():
            xg_b16[...] = (xg_b16[...]
                           + xg_acc[...].astype(jnp.bfloat16))
            xg_acc[...] = jnp.zeros_like(xg_acc)

        @pl.when(li == nb - 1)
        def _final_flush():
            xg_out_ref[...] = xg_b16[...]
            loss_ref[0, 0] = loss_acc[0, 0]

        # dense ḡᵀX then gather the fan-in columns back out (i32-bitcast
        # masked sum — sign-of-zero exact)
        dw = jax.lax.dot_general(g16, x16, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        dv = REF.sparse_gather_cols(dw, idx)                # (bl, F) f32
        v32 = vals.astype(jnp.float32)
        if kahan:
            comp_blk = comp_ref[...]
            upd = -lr * dv - (lr * wd) * v32
            yk = upd - comp_blk[:, :fan_in].astype(jnp.float32)
            t32 = v32 + yk
            v_new = t32.astype(v_out_ref.dtype)
            c_new = ((v_new.astype(jnp.float32) - v32) - yk
                     ).astype(comp_out_ref.dtype)
            _write_stream(v_out_ref, v_new, v_blk)
            _write_stream(comp_out_ref, c_new, comp_blk)
            pre_cast = t32
            cmax = jnp.max(jnp.abs(c_new.astype(jnp.float32)))
        else:
            v_new32 = v32 * (1.0 - lr * wd) - lr * dv
            bits = PR.hash_bits_at(su_ref[cidx], off.astype(jnp.uint32),
                                   idx)
            v_new = _apply_sr(v_new32, v_out_ref.dtype, bits, use_sr)
            _write_stream(v_out_ref, v_new, v_blk)
            pre_cast = v_new32
            cmax = jnp.float32(0.0)

        if guard:
            lim = jnp.float32(P.max_finite(v_out_ref.dtype))
            sat = jnp.sum((jnp.abs(pre_cast) >= lim).astype(jnp.float32))
            znf = jnp.sum((~jnp.isfinite(z32)).astype(jnp.float32)
                          * valid * rowv)
            slot = jax.lax.broadcasted_iota(jnp.int32, tele_acc.shape, 1)
            acc = tele_acc[...]
            acc = acc + jnp.where(slot == 0, sat, 0.0)
            acc = acc + jnp.where(slot == 1, znf, 0.0)
            acc = jnp.maximum(acc, jnp.where(slot == 4, cmax, 0.0))
            tele_acc[...] = acc

            @pl.when(li == nb - 1)
            def _tele_flush():
                tele_ref[...] = tele_acc[...]

    if mode == "ce_full":
        @pl.when(pss == 0)
        def _pass0():
            lse_work()
            # every mapped output block must be written each step it is
            # visited: write the aliased value/comp streams back unchanged
            v_out_ref[...] = v_ref[...]
            if kahan:
                comp_out_ref[...] = comp_ref[...]

            @pl.when(li == nb - 1)
            def _finalize_lse():
                lse_v[...] = m_acc[...] + jnp.log(s_acc[...])
                lse_out_ref[...] = lse_v[...]

        @pl.when(pss == 1)
        def _pass1():
            update_work()
    else:                                   # bce / ce_update
        update_work()


def _sparse_shapes(B, D, lc, F, block_l, interpret):
    """(Bp, Dp, Fp, lcp, bl): interpret mode keeps exact shapes (same
    bitwise-parity rule as ``fused_head._head_shapes``)."""
    if interpret:
        bl = lc if block_l is None else min(block_l, lc)
        if lc % bl != 0:
            bl = lc
        return B, D, F, lc, bl
    Bp = tuning._pad_up(B, 16)
    Dp = tuning._pad_up(D, tuning.LANE)
    Fp = tuning._pad_up(F, tuning.LANE)
    bl = min(block_l or lc, tuning._pad_up(lc, tuning.LANE))
    bl = tuning._pad_up(bl, tuning.SUBLANE)
    return Bp, Dp, Fp, tuning._pad_up(lc, bl), bl


def _pad_s3(a, lcp, Fp, value=0):
    """(C, lc, F) → (C·lcp, Fp) row-major stream; padded index slots get
    ``value=-1`` so they densify/gather/update as inert."""
    C, lc, F = a.shape
    if (lcp, Fp) != (lc, F):
        a = jnp.pad(a, ((0, 0), (0, lcp - lc), (0, Fp - F)),
                    constant_values=value)
    return a.reshape(C * lcp, Fp)


def _slice_s3(flat, C, lcp, lc, F):
    return flat.reshape(C, lcp, -1)[:, :lc, :F]


def _launch_sparse(mode, x, values, indices, targets, lr, wd, scale,
                   seeds_drop, seeds_upd, base, lse, comp, num_labels,
                   use_sr, quantize_x, drop_rate, compute_loss, block_l,
                   interpret, guard=False):
    """Spec/operand assembly — the sparse mirror of ``fused_head._launch``."""
    (B, D), (C, lc, F) = x.shape, values.shape
    kahan = comp is not None
    interpret = tuning.interpret_default(interpret)
    if block_l is None and not interpret:
        block_l = tuning.sparse_head_block_l(
            B, lc, D, F, jnp.dtype(values.dtype).itemsize, kahan=kahan,
            n_chunks=C,
            p_slots=targets.shape[-1] if targets.ndim == 2 else 1)
    Bp, Dp, Fp, lcp, bl = _sparse_shapes(B, D, lc, F, block_l, interpret)
    bpc = lcp // bl
    nb = C * bpc
    xp = tuning.pad2(x.astype(jnp.bfloat16), Bp, Dp)
    vflat = _pad_s3(values, lcp, Fp)
    iflat = _pad_s3(indices.astype(jnp.int32), lcp, Fp, value=-1)

    if mode == "ce_full":
        def full(p, l):
            return (0, 0)

        def wmap(p, l):
            return (l, 0)
        grid = (2, nb)
    else:
        def full(l):
            return (0, 0)

        def wmap(l):
            return (l, 0)
        grid = (nb,)

    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    hyper = jnp.stack([jnp.asarray(lr, jnp.float32),
                       jnp.asarray(wd, jnp.float32),
                       jnp.asarray(scale, jnp.float32)])
    tgt = targets if targets.ndim == 2 else targets.reshape(B, 1)
    tp = tuning.pad2(tgt, Bp, 1, value=-1)
    operands = [jnp.asarray(seeds_drop).astype(jnp.uint32),
                jnp.asarray(seeds_upd).astype(jnp.uint32), hyper,
                jnp.asarray(base).astype(jnp.int32), tp]
    in_specs = [smem, smem, smem, smem, pl.BlockSpec(tp.shape, full)]
    if mode == "ce_update":
        operands.append(
            tuning.pad2(lse.reshape(B, 1).astype(jnp.float32), Bp, 1))
        in_specs.append(pl.BlockSpec((Bp, 1), full))
    v_idx = len(operands) + 1
    operands += [xp, vflat, iflat]
    in_specs += [pl.BlockSpec((Bp, Dp), full),
                 pl.BlockSpec((bl, Fp), wmap),
                 pl.BlockSpec((bl, Fp), wmap)]
    if kahan:
        operands.append(_pad_s3(comp, lcp, Fp))
        in_specs.append(pl.BlockSpec((bl, Fp), wmap))

    out_shape = [jax.ShapeDtypeStruct((C * lcp, Fp), values.dtype)]
    out_specs = [pl.BlockSpec((bl, Fp), wmap)]
    if kahan:
        out_shape.append(jax.ShapeDtypeStruct((C * lcp, Fp), comp.dtype))
        out_specs.append(pl.BlockSpec((bl, Fp), wmap))
    out_shape += [jax.ShapeDtypeStruct((Bp, Dp), jnp.bfloat16),
                  jax.ShapeDtypeStruct((1, 1), jnp.float32)]
    out_specs += [pl.BlockSpec((Bp, Dp), full),
                  pl.BlockSpec((1, 1), full)]
    if mode == "ce_full":
        out_shape.append(jax.ShapeDtypeStruct((Bp, 1), jnp.float32))
        out_specs.append(pl.BlockSpec((Bp, 1), full))
    if guard:
        out_shape.append(jax.ShapeDtypeStruct((1, 8), jnp.float32))
        out_specs.append(pl.BlockSpec((1, 8), full))

    aliases = {v_idx: 0}                 # the index stream is read-only
    if kahan:
        aliases[v_idx + 2] = 1

    scratch = [pltpu.VMEM((Bp, Dp), jnp.float32),
               pltpu.VMEM((Bp, Dp), jnp.bfloat16),
               pltpu.VMEM((1, 1), jnp.float32)]
    if mode == "ce_full":
        scratch += [pltpu.VMEM((Bp, 1), jnp.float32),
                    pltpu.VMEM((Bp, 1), jnp.float32),
                    pltpu.VMEM((Bp, 1), jnp.float32)]
    if guard:
        scratch.append(pltpu.VMEM((1, 8), jnp.float32))

    outs = pl.pallas_call(
        functools.partial(
            _sparse_kernel, mode=mode, num_labels=num_labels, lc=lc,
            bpc=bpc, n_b=B, fan_in=F, kahan=kahan, use_sr=use_sr,
            quantize_x=quantize_x, drop_rate=drop_rate,
            compute_loss=compute_loss, guard=guard),
        grid=grid,
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shape),
        scratch_shapes=scratch,
        input_output_aliases=aliases,
        interpret=interpret,
    )(*operands)
    return outs, (B, D, C, lc, lcp, F, kahan)


@functools.partial(jax.jit, static_argnames=(
    "mode", "num_labels", "use_sr", "quantize_x", "drop_rate",
    "compute_loss", "block_l", "interpret", "guard"))
def sparse_head_step(x: jax.Array, values: jax.Array, indices: jax.Array,
                     targets: jax.Array, lr, wd, scale,
                     seeds_drop: jax.Array, seeds_upd: jax.Array,
                     base: jax.Array, lse: jax.Array | None = None,
                     comp: jax.Array | None = None, *,
                     mode: str, num_labels: int, use_sr: bool = True,
                     quantize_x: bool = True, drop_rate: float = 0.0,
                     compute_loss: bool = True, block_l: int | None = None,
                     interpret: bool | None = None,
                     guard: bool = False) -> SparseStepOut:
    """One whole sparse-head train step in a single launch.

    x (B, D) bf16 · values (C, lc, F) storage dtype · indices (C, lc, F)
    int32, sorted strictly increasing per row (−1 pads a dead slot) ·
    targets (B, P)/(B,) int32 · seeds_drop/seeds_upd (C,) uint32 ·
    base (C,) int32 · comp (C, lc, F) BF16 Kahan buffer (homogeneous:
    all chunks or none).  ``mode`` as in ``fused_head_step`` — "bce" /
    "ce_full" (2-pass in-launch grid, returns the LSE) / "ce_update"
    (sharded CE, LSE passed in).  No z cache: the sparse forward is
    cheap enough to recompute from the same per-chunk DropConnect seed.
    """
    assert mode in _UPDATE_MODES, mode
    if mode == "ce_update":
        assert lse is not None, "ce_update needs the finalized LSE"
    outs, (B, D, C, lc, lcp, F, kahan) = _launch_sparse(
        mode, x, values, indices, targets, lr, wd, scale, seeds_drop,
        seeds_upd, base, lse, comp, num_labels, use_sr, quantize_x,
        drop_rate, compute_loss, block_l, interpret, guard=guard)
    it = iter(outs)
    v_new = _slice_s3(next(it), C, lcp, lc, F)
    comp_new = _slice_s3(next(it), C, lcp, lc, F) if kahan else None
    xg = next(it)[:B, :D]
    loss = next(it)[0, 0]
    lse_out = next(it)[:B, 0] if mode == "ce_full" else None
    tele = next(it)[0] if guard else None
    return SparseStepOut(v_new, xg, loss, comp_new, lse_out, tele)
