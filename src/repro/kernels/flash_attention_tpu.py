"""Pallas TPU kernel: flash-attention forward (blockwise online softmax).

The XLA-level custom-VJP flash attention (models/flash_attention.py) is the
portable path used by training and the dry-run; this kernel is the TPU fast
path for the forward/serving side, with explicit VMEM tiling:

* grid (B·H, Sq/bq, Sk/bk), the KV loop innermost so the (bq, dh) output
  accumulator and the (bq,) online-softmax stats stay resident in VMEM;
* q tiles are (bq, dh) per (batch·head); k/v tiles (bk, dh) indexed through
  the GQA map h → h // group so grouped queries share KV traffic;
* causal/sliding-window masks are evaluated per tile from absolute block
  offsets, and fully-masked tiles are skipped with ``pl.when`` — on TPU the
  skipped MXU work is real saved time (the XLA path can only mask);
* fp32 accumulation, bf16 tile math on the MXU.

Backward uses the XLA custom-VJP path (kernel bwd: future work, noted in
EXPERIMENTS.md).  ``ref.py``'s oracle for this kernel is the dense softmax
attention; tests sweep shapes/dtypes/GQA groups in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               bq: int, bk: int, nk: int, causal: bool, window, scale):
    i = pl.program_id(1)          # q block
    j = pl.program_id(2)          # kv block

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level skip: causal ⇒ only j·bk ≤ (i+1)·bq − 1; window ⇒ lower cut
    q_end = (i + 1) * bq - 1
    live = jnp.bool_(True)
    if causal:
        live = live & (j * bk <= q_end)
    if window is not None:
        live = live & ((j + 1) * bk - 1 >= i * bq - (window - 1))

    @pl.when(live)
    def _tile():
        q = q_ref[...].astype(jnp.bfloat16)
        k = k_ref[...].astype(jnp.bfloat16)
        v = v_ref[...].astype(jnp.bfloat16)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale
        qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alive = m_new > NEG_INF / 2
        p = jnp.where(alive, jnp.exp(s - m_new), 0.0)
        corr = jnp.where(alive, jnp.exp(m_prev - m_new), 1.0)
        m_ref[...] = m_new
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(jnp.bfloat16), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nk - 1)
    def _flush():
        o_ref[...] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention_fwd_tpu(q: jax.Array, k: jax.Array, v: jax.Array, *,
                            causal: bool = True, window=None,
                            bq: int = 256, bk: int = 256,
                            interpret: bool | None = None) -> jax.Array:
    """q: (B, H, Sq, dh); k, v: (B, KH, Sk, dh), H % KH == 0 → (B, H, Sq, dh).

    Sq/Sk must be multiples of bq/bk (the caller pads — see
    models/attention.py for the padding contract)."""
    from repro.kernels import tuning
    interpret = tuning.interpret_default(interpret)
    B, H, Sq, dh = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    assert H % KH == 0 and Sq % bq == 0 and Sk % bk == 0
    G = H // KH
    nq, nk = Sq // bq, Sk // bk
    scale = np.float32(1.0 / np.sqrt(dh))

    q3 = q.reshape(B * H, Sq, dh)
    out = pl.pallas_call(
        functools.partial(_fa_kernel, bq=bq, bk=bk, nk=nk, causal=causal,
                          window=window, scale=scale),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((None, bq, dh), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((None, None, bk, dh),
                         lambda bh, i, j, G=G, H=H: (bh // H, (bh % H) // G,
                                                     j, 0)),
            pl.BlockSpec((None, None, bk, dh),
                         lambda bh, i, j, G=G, H=H: (bh // H, (bh % H) // G,
                                                     j, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, dh), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, dh), jnp.float32)],
        interpret=interpret,
    )(q3, k, v)
    return out.reshape(B, H, Sq, dh)
