"""Counter-based PRNG usable inside Pallas kernel bodies and jnp oracles.

``pltpu.prng_random_bits`` has no CPU/interpret lowering, so ELMO kernels
derive stochastic-rounding bits from a counter hash instead: uniform uint32
bits are a pure function of (seed, global element index).  This is

* portable   — identical bits in interpret mode, on TPU, and in the jnp oracle,
* stateless  — fits Pallas' functional model; no HBM random tensor is ever
               materialized (the paper's "no extra memory" property), and
* cheap      — a few VPU integer ops per element.

The mix is the murmur3/squirrel-style avalanche finalizer; SR only needs
uniformity of low bits, not cryptographic quality.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# numpy scalars embed as literals (jnp module-level arrays would be rejected
# as captured constants inside Pallas kernel bodies)
_PRIME1 = np.uint32(0x7FEB352D)
_PRIME2 = np.uint32(0x846CA68B)
_GOLDEN = np.uint32(0x9E3779B9)


def mix32(x: jax.Array) -> jax.Array:
    """Murmur3-style 32-bit avalanche. Input/output uint32."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * _PRIME1
    x = x ^ (x >> 15)
    x = x * _PRIME2
    x = x ^ (x >> 16)
    return x


def hash_bits_2d(seed: jax.Array, row0: jax.Array, col0: jax.Array,
                 shape: tuple[int, int]) -> jax.Array:
    """Uniform uint32 bits for a (rows, cols) tile at offset (row0, col0).

    Bits are a pure function of the *absolute* (row, col) coordinate and the
    seed — independent of tiling, block shape, or padding — so Pallas kernels
    and the jnp oracle produce identical draws for the same logical element.
    """
    rows, cols = shape
    ii = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 0)
    jj = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 1)
    r = row0.astype(jnp.uint32) + ii
    c = col0.astype(jnp.uint32) + jj
    h = mix32(r * _PRIME1 ^ mix32(seed.astype(jnp.uint32)))
    return mix32(h ^ (c * _GOLDEN))


def hash_bits_at(seed: jax.Array, row0: jax.Array, cols: jax.Array
                 ) -> jax.Array:
    """Uniform uint32 bits at explicit column coordinates: element (i, f) of
    the result draws the bits of absolute coordinate (row0 + i, cols[i, f]).

    This is ``hash_bits_2d`` restricted to a per-row column *gather* — the
    draw the fixed-fan-in sparse head needs for its (row, index[row, f])
    value slots.  Because the hash factors as mix32(h(row) ^ col·GOLDEN),
    the bits equal the dense draw at the same (row, col): with identity
    indices (cols[i, f] = f) this is bitwise ``hash_bits_2d(seed, row0, 0,
    cols.shape)``, which anchors the sparse kernel's fan_in = D parity.
    """
    rows, width = cols.shape
    ii = jax.lax.broadcasted_iota(jnp.uint32, (rows, width), 0)
    r = row0.astype(jnp.uint32) + ii
    h = mix32(r * _PRIME1 ^ mix32(seed.astype(jnp.uint32)))
    return mix32(h ^ (cols.astype(jnp.uint32) * _GOLDEN))


def hash_bits_nd(seed: jax.Array, shape: tuple[int, ...]) -> jax.Array:
    """Uniform uint32 bits for an arbitrary-rank array, built from per-axis
    iotas (elementwise → preserves any sharding; no reshape/flatten, so a
    sharded 4-D parameter never gets gathered just to draw SR bits)."""
    if not shape:
        return mix32(seed.astype(jnp.uint32))
    lin = jnp.zeros(shape, jnp.uint32)
    stride = np.uint32(1)
    for axis in range(len(shape) - 1, -1, -1):
        lin = lin + jax.lax.broadcasted_iota(jnp.uint32, shape, axis) * stride
        stride = np.uint32(stride * np.uint32(shape[axis]))
    return mix32(lin * _GOLDEN ^ mix32(seed.astype(jnp.uint32)))


def uniform_from_bits(bits: jax.Array) -> jax.Array:
    """uint32 → f32 uniform in [0, 1) using the top 24 bits."""
    return (bits >> np.uint32(8)).astype(jnp.float32) * np.float32(1.0 / float(1 << 24))
