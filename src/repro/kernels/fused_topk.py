"""Pallas TPU megakernel: streaming top-k serving in ONE launch (DESIGN §9).

Serving previously paid one of two prices for a top-k query batch: the
materialized fast path ran one logits launch but held the full (B, C·lc)
logits in HBM (gated at ``plan._TOPK_Z_BYTES``, so a 3M-label head could
only serve tiny batches that way), or the ``lax.scan`` streaming path kept
O(B·(k+chunk)) memory but launched one kernel per chunk and re-ranked a
``(k+chunk)``-wide candidate set each time.  ELMO's streaming argument for
the classifier gradient (§4.2–4.3: the big tensor is a *reduction
intermediate* — never materialize it) applies verbatim to inference: the
logits exist only to be reduced to (values, ids) top-k.

This kernel moves the label loop into the Pallas grid, exactly like the
train-step megakernel (``fused_head.py``): the grid walks every label
block of every chunk, Pallas double-buffers the W stream (1 byte/elem for
FP8 storage) so the DMA of block ``i+1`` overlaps the MXU dot of block
``i``, and the ONLY state that persists is a (B, K) value/id running
top-k in VMEM scratch:

    grid = (C · lcp/bl,)
    per label block (chunk c, rows [off, off+bl)):
      z     = q8(X) @ W_blᵀ                    (MXU, f32 acc → BF16)
      zm    = mask(z): padded / out-of-range columns → NEG_INF
      carry = merge_topk(carry, (zm, global col ids))   [VMEM scratch]
    last block: emit carry → (B, K) values f32, ids int32

so top-k serving is 1 launch at O(B·k) transient memory for ANY label
count — no z budget, no per-chunk launch tax.

Tie-break contract (bit-for-bit the streaming scan's, ``serving._topk_scan``):

* equal logits resolve to the EARLIEST candidate = lowest global label id
  (the scan's ``lax.top_k`` is stable and ids arrive in ascending order);
* the carry is initialized to k (NEG_INF, id 0) sentinels — the scan's
  initial carry — so overflow slots (k beyond the valid label count)
  surface exactly (NEG_INF, 0), never a padded label id.

The in-kernel merge is a selection sort over the (K + bl)-wide candidate
row: slot j takes the maximum value, ties broken by minimum id, then
retires that candidate.  Retiring by setting its value to NEG_INF (id
kept) is safe: a NEG_INF output slot can only happen while the carry
still holds a sentinel (id 0), which wins every NEG_INF tie — so retired
real ids can never resurface (see tests/test_fused_topk.py for the
adversarial sweeps).  Selection by (max value, min id) is exactly the
first-k prefix of a stable sort of ``[sentinels, cols...]`` by
(−value, id) — the scan's contract.

A per-block threshold check (``block max < carry min``, all rows) skips
the merge entirely once the carry saturates above the block: with no
sentinel in the carry row, nothing below the resident minimum can enter
or reorder the top-k, so the skip is bitwise-invisible.

Shortlisted mode (``assign``/``beam`` given — DESIGN §11) drives that
same skip machinery from the 2-level label partition instead: each label
block streams its (1, bl) int32 cluster ids alongside W, the per-query
admitted clusters sit VMEM-resident as a (Bp, n_beam) int32 beam, and a
column is *valid* only when its cluster appears in its query's beam.
When NO column of a block is admitted for ANY query the whole block —
the MXU dot included, not just the merge — is skipped under ``pl.when``,
so stage-2 work scales with beam·L/C rather than L.  The skip is
bitwise-invisible against the restricted oracle (``ref.fused_topk_ref``
with the same assign/beam): a fully-masked block contributes only
(NEG_INF, real id) candidates, and every carry slot holds either a
finite value (wins outright) or the (NEG_INF, id 0) sentinel (wins or
ties every NEG_INF tie, id 0 being minimal — masking label 0 itself
yields the identical (NEG_INF, 0) pair), so the merge could not have
changed the carry.  -1 entries are inert on both sides: real cluster
ids are ≥ 0, ``assign`` is -1 only on padded label rows (already masked
by the column-validity test) and ``beam`` is -1 only in sentinel/padded
slots.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.losses import NEG_INF
from repro.kernels import prng_utils as PR
from repro.kernels import tuning
from repro.kernels.fused_head import _head_shapes

_I32_MAX = 2 ** 31 - 1   # plain int: jnp scalars would be captured consts


def _topk_kernel(sd_ref, base_ref, x_ref, w_ref, *refs, k: int,
                 num_labels: int, lc: int, bpc: int, n_b: int,
                 quantize_x: bool, drop_rate: float, shortlisted: bool):
    if shortlisted:                         # + streamed cluster ids, beam
        asg_ref, beam_ref, vals_out, ids_out, vals_sc, ids_sc = refs
    else:
        vals_out, ids_out, vals_sc, ids_sc = refs
    li = pl.program_id(0)
    nb = pl.num_programs(0)
    Bp, Dp = x_ref.shape
    bl = w_ref.shape[1]                     # w block is (1, bl, Dp)
    K = vals_sc.shape[1]                    # carry width (k, lane-padded)
    cidx = li // bpc                        # chunk of this label block
    off = (li % bpc) * bl                   # row offset inside the chunk

    @pl.when(li == 0)
    def _init():                            # the scan's initial carry
        vals_sc[...] = jnp.full_like(vals_sc, NEG_INF)
        ids_sc[...] = jnp.zeros_like(ids_sc)

    # global label coordinate + validity (local-row × real-label × real
    # batch row), same construction as the train grid kernel.  Masking
    # the padded batch rows matters for PERF, not parity (their outputs
    # are sliced away): a padded row's z is exactly 0 on every column,
    # so an unmasked carry would saturate at 0 and `0 >= 0` would defeat
    # the threshold skip below for every remaining block.
    col_local = jax.lax.broadcasted_iota(jnp.int32, (Bp, bl), 1) + off
    col_global = col_local + base_ref[cidx]
    rowv = jax.lax.broadcasted_iota(jnp.int32, (Bp, bl), 0) < n_b
    valid = (col_global < num_labels) & (col_local < lc) & rowv

    if shortlisted:
        # a column is admitted iff its cluster id appears in its query's
        # beam.  -1 is inert by construction: beam −1 (sentinel/padded
        # slot) never equals a real assign ≥ 0, and assign −1 only sits
        # on padded label rows `valid` already excludes.
        asg = asg_ref[...]                  # (1, bl) streamed with W
        n_beam = beam_ref.shape[1]

        def _adm(e, adm):
            return adm | (beam_ref[:, pl.ds(e, 1)] == asg)

        admit = jax.lax.fori_loop(0, n_beam, _adm,
                                  jnp.zeros((Bp, bl), jnp.bool_))
        valid = valid & admit

    def _block():
        # ---- forward: op-for-op fused_head's serving matmul (parity) ----
        xq = x_ref[...]
        if quantize_x:
            xq = xq.astype(jnp.float8_e4m3fn)
        xq = xq.astype(jnp.bfloat16)
        w16 = w_ref[0].astype(jnp.bfloat16)
        if drop_rate > 0.0:
            bits = PR.hash_bits_2d(sd_ref[cidx], off.astype(jnp.uint32),
                                   jnp.uint32(0), (bl, Dp))
            keep = PR.uniform_from_bits(bits) >= drop_rate
            w16 = jnp.where(keep, w16, jnp.bfloat16(0.0)) \
                / jnp.bfloat16(1.0 - drop_rate)
        z16 = jax.lax.dot_general(xq, w16, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32
                                  ).astype(jnp.bfloat16)
        zm = jnp.where(valid, z16.astype(jnp.float32), NEG_INF)

        # ---- threshold skip: nothing in this block can displace the
        # carry.  Padded batch rows sit at (NEG_INF carry, NEG_INF block)
        # forever and would tie `>=` on every block — only REAL rows get
        # a vote.
        thresh = vals_sc[...][:, K - 1]     # per-row resident minimum
        need = jnp.any((zm.max(axis=1) >= thresh) & rowv[:, 0])

        @pl.when(need)
        def _merge():
            cv = jnp.concatenate([vals_sc[...], zm], axis=1)   # (Bp, K+bl)
            ci = jnp.concatenate([ids_sc[...], col_global], axis=1)
            iota = jax.lax.broadcasted_iota(jnp.int32, cv.shape, 1)

            def body(j, carry):
                cv, ci = carry
                m = cv.max(axis=1, keepdims=True)
                tie = cv == m
                sid = jnp.min(jnp.where(tie, ci, _I32_MAX), axis=1,
                              keepdims=True)
                hit = tie & (ci == sid)
                pos = jnp.min(jnp.where(hit, iota, _I32_MAX), axis=1,
                              keepdims=True)
                vals_sc[:, pl.ds(j, 1)] = m
                ids_sc[:, pl.ds(j, 1)] = sid
                return jnp.where(iota == pos, NEG_INF, cv), ci

            jax.lax.fori_loop(0, K, body, (cv, ci))

    if shortlisted:
        # the shortlist-driven block-skip: a block with no admitted
        # column for any query contributes only (NEG_INF, id) candidates
        # — which cannot change the carry (module docstring) — so the
        # MXU dot AND the merge are skipped wholesale.
        pl.when(jnp.any(valid))(_block)
    else:
        _block()

    @pl.when(li == nb - 1)
    def _emit():
        vals_out[...] = vals_sc[...]
        ids_out[...] = ids_sc[...]


@functools.partial(jax.jit, static_argnames=(
    "k", "num_labels", "quantize_x", "drop_rate", "block_l", "interpret"))
def fused_topk(x: jax.Array, w: jax.Array, seeds_drop: jax.Array,
               base: jax.Array, *, k: int, num_labels: int,
               quantize_x: bool = True, drop_rate: float = 0.0,
               block_l: int | None = None,
               interpret: bool | None = None,
               assign: jax.Array | None = None,
               beam: jax.Array | None = None
               ) -> Tuple[jax.Array, jax.Array]:
    """Top-k over every head logit in ONE launch, never materializing them.

    x (B, D) bf16 · w (C, lc, D) storage dtype · seeds_drop (C,) uint32
    per-chunk DropConnect seeds · base (C,) int32 global label id of each
    chunk's local row 0 (``cidx·chunk`` single-device, ``cidx·chunk +
    rank·lc`` label-sharded).  Returns ((B, k) f32 values descending,
    (B, k) int32 global ids) — bit-identical, values AND ids, to the
    chunk-scan streaming top-k and to ``ref.fused_topk_ref``.

    ``assign`` (C, lc) int32 + ``beam`` (B, n_beam) int32 (both or
    neither) switch on shortlisted mode: the top-k is restricted to the
    labels whose cluster appears in their query's beam, bit-identical to
    ``ref.fused_topk_ref`` with the same assign/beam, and label blocks
    with no admitted column are skipped wholesale (module docstring).
    """
    (B, D), (C, lc, _) = x.shape, w.shape
    assert k >= 1
    shortlisted = assign is not None
    if shortlisted:
        assert beam is not None, "assign without beam"
    interpret = tuning.interpret_default(interpret)
    n_beam = beam.shape[1] if shortlisted else 0
    if block_l is None:
        if interpret:
            # unlike the train grid, ANY label tile is bit-identical here
            # (columns are independent and the merge is prefix-associative),
            # so interpret mode — which has no DMA to amortize — takes a
            # lane-sized tile: the per-block merge carrier, not the W
            # stream, is the interpreter's live working set
            block_l = tuning.LANE
        else:
            block_l = tuning.topk_block_l(B, lc, D,
                                          jnp.dtype(w.dtype).itemsize, k,
                                          n_beam=n_beam)
    Bp, Dp, lcp, bl = _head_shapes(B, D, lc, block_l, interpret)
    # interpret mode keeps the exact carry width; compiled lanes pad it —
    # extra slots are sentinels past k and cannot change the first k
    K = k if interpret else tuning._pad_up(k, tuning.LANE)
    bpc = lcp // bl
    xp = tuning.pad2(x.astype(jnp.bfloat16), Bp, Dp)
    # W streams as a 3-D (1, bl, Dp) block — no flatten/copy: when the
    # shard geometry is already tile-aligned (the production case) the
    # operand is the checkpoint buffer itself, pad-free
    wp = w if (lcp, Dp) == (lc, D) else jnp.pad(
        w, ((0, 0), (0, lcp - lc), (0, Dp - D)))

    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    in_specs = [smem, smem,
                pl.BlockSpec((Bp, Dp), lambda l: (0, 0)),
                pl.BlockSpec((1, bl, Dp),
                             lambda l: (l // bpc, l % bpc, 0))]
    operands = [jnp.asarray(seeds_drop).astype(jnp.uint32),
                jnp.asarray(base).astype(jnp.int32), xp, wp]
    if shortlisted:
        # cluster ids stream (1, bl) blocks in lock-step with W; the beam
        # is VMEM-resident like the carry.  All padding is -1 (inert) —
        # pad2 would write 0s, which name a REAL cluster.
        Ep = n_beam if interpret else tuning._pad_up(n_beam, tuning.LANE)
        asgp = jnp.pad(jnp.asarray(assign).astype(jnp.int32),
                       ((0, 0), (0, lcp - lc)), constant_values=-1)
        beamp = jnp.pad(jnp.asarray(beam).astype(jnp.int32),
                        ((0, Bp - B), (0, Ep - n_beam)),
                        constant_values=-1)
        in_specs += [pl.BlockSpec((1, bl), lambda l: (l // bpc, l % bpc)),
                     pl.BlockSpec((Bp, Ep), lambda l: (0, 0))]
        operands += [asgp, beamp]
    vals, ids = pl.pallas_call(
        functools.partial(_topk_kernel, k=k, num_labels=num_labels, lc=lc,
                          bpc=bpc, n_b=B, quantize_x=quantize_x,
                          drop_rate=drop_rate, shortlisted=shortlisted),
        grid=(C * bpc,),
        in_specs=in_specs,
        out_specs=(pl.BlockSpec((Bp, K), lambda l: (0, 0)),
                   pl.BlockSpec((Bp, K), lambda l: (0, 0))),
        out_shape=(jax.ShapeDtypeStruct((Bp, K), jnp.float32),
                   jax.ShapeDtypeStruct((Bp, K), jnp.int32)),
        scratch_shapes=[pltpu.VMEM((Bp, K), jnp.float32),
                        pltpu.VMEM((Bp, K), jnp.int32)],
        interpret=interpret,
    )(*operands)
    return vals[:B, :k], ids[:B, :k]
