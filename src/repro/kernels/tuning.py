"""Roofline-guided block-size selection for the ELMO Pallas kernels.

Replaces the historical hardcoded ``(128, 256, 256)`` / ``(256, 256, 128)``
block tuples (DESIGN.md §5).  For a tiled GEMM with grid
``(M/bm, N/bn, K/bk)`` the HBM traffic is

    bytes(A)·(N/bn)  +  bytes(B)·(M/bm)  +  bytes(out)

so the chooser enumerates MXU-aligned candidate tiles, discards those whose
working set (with double buffering) exceeds the VMEM budget, and picks the
minimum-traffic tile, preferring an **unsplit K** (single-pass f32
accumulation: fewer partial-sum rounding steps, and the accumulator scratch
is written exactly once).  Compute time only floors the roofline — it is
identical across tilings — so traffic is the whole objective.

Everything is a pure function of static shapes; results are memoized.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

# TPU v5e (benchmarks/roofline.py): the numbers only steer *relative*
# choices, so v4/v5p drift is harmless.
PEAK_FLOPS = 197e12
HBM_BW = 819e9
VMEM_BYTES = 16 * 2 ** 20
VMEM_BUDGET = int(VMEM_BYTES * 0.9)
LANE = 128          # MXU systolic edge / lane count
SUBLANE = 8


def _pad_up(n: int, m: int) -> int:
    return -(-n // m) * m


def interpret_default(interpret: bool | None) -> bool:
    """Resolve a kernel wrapper's ``interpret=None`` from the backend.

    Pallas TPU kernels only compile on TPU; everywhere else interpret mode
    is the correct (and only) execution path.  Resolving here — at the
    launch-configuration layer, per call — replaces the old hardcoded
    ``interpret=True`` keyword defaults, which made a *real-TPU* run that
    called a kernel wrapper directly silently fall back to interpret mode.
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def pad2(x, b0: int, b1: int, value=0):
    """Pad a 2-D array up to multiples of (b0, b1) — the shared tile-
    alignment helper for every kernel wrapper in this package."""
    p0, p1 = (-x.shape[0]) % b0, (-x.shape[1]) % b1
    if p0 or p1:
        return jnp.pad(x, ((0, p0), (0, p1)), constant_values=value)
    return x


def _cands(dim: int, cap: int = 1024) -> list[int]:
    """MXU-aligned candidate tile sizes for one dimension."""
    padded = _pad_up(max(dim, 1), SUBLANE)
    if padded <= LANE:
        return [padded]
    out = {c for c in (128, 256, 512, 1024) if c <= min(cap, padded)}
    if padded <= cap:
        out.add(_pad_up(padded, LANE))   # the whole (padded) dimension
    return sorted(out)


@functools.lru_cache(maxsize=None)
def matmul_blocks(M: int, N: int, K: int, a_bytes: int, b_bytes: int,
                  o_bytes: int) -> tuple[int, int, int]:
    """(bm, bn, bk) for out[M,N] = A[M,K] · B[N,K]ᵀ with f32 accumulation."""
    Mp, Np, Kp = (_pad_up(d, SUBLANE) for d in (M, N, K))
    best, best_key = None, None
    for bk in _cands(K, cap=2048):
        k_tiles = -(-Kp // bk)
        for bm in _cands(M):
            m_tiles = -(-Mp // bm)
            for bn in _cands(N):
                n_tiles = -(-Np // bn)
                vmem = (2 * (bm * bk * a_bytes + bn * bk * b_bytes)
                        + bm * bn * 4            # f32 accumulator scratch
                        + 2 * bm * bn * o_bytes)
                if vmem > VMEM_BUDGET:
                    continue
                traffic = (Mp * Kp * a_bytes * n_tiles
                           + Np * Kp * b_bytes * m_tiles
                           + Mp * Np * o_bytes)
                # minimize traffic; prefer unsplit K, then fewer grid steps
                key = (traffic, k_tiles, m_tiles * n_tiles * k_tiles)
                if best_key is None or key < best_key:
                    best, best_key = (bm, bn, bk), key
    assert best is not None, (M, N, K)
    return best


def logits_blocks(B: int, L: int, D: int, w_bytes: int = 1
                  ) -> tuple[int, int, int]:
    """(bb, bl, bd) for Z[B, L] = q8(X)[B, D] · W[L, D]ᵀ."""
    bb, bl, bd = matmul_blocks(B, L, D, 2, w_bytes, 2)
    return bb, bl, bd


def input_grad_blocks(B: int, L: int, D: int, w_bytes: int = 1
                      ) -> tuple[int, int, int]:
    """(bb, bd, bl) for X̄[B, D] = G[B, L] · W[L, D]."""
    bb, bd, bl = matmul_blocks(B, D, L, 2, w_bytes, 2)
    return bb, bd, bl


def update_blocks(B: int, L: int, D: int, w_bytes: int = 1
                  ) -> tuple[int, int, int]:
    """(bl, bd, bb) for dW[L, D] = G[B, L]ᵀ · X[B, D] (+ aliased W in/out)."""
    bl, bd, bb = matmul_blocks(L, D, B, 2, 2, w_bytes + w_bytes)
    return bl, bd, bb


def _chunk_vmem(B: int, D: int, bl: int, w_bytes: int, kahan: bool,
                cached_z: bool) -> int:
    """Megakernel working-set model at label tile ``bl`` — the single
    source of truth for both the tile chooser and the viability gate."""
    Bp = _pad_up(max(B, 1), 16)          # bf16 sublane
    Dp = _pad_up(max(D, 1), LANE)
    resident = (Bp * Dp * 2              # X bf16
                + 2 * Bp * Dp * 2        # x̄ in + out (aliased) bf16
                + Bp * Dp * 4)           # x̄ accumulator f32
    per_tile = (2 * bl * Dp * w_bytes * 2          # W in+out, buffered
                + (2 * bl * Dp * 2 * 2 if kahan else 0)
                + Bp * bl * (2 if cached_z else 0)  # cached z stream
                + Bp * bl * 10                      # z32 + g + g16 regs
                + bl * Dp * 4)                      # dW f32 transient
    return resident + per_tile


def local_chunk(L: int, n_shards: int = 1) -> int:
    """Per-device label rows when the chunk dimension is sharded ``n_shards``
    ways over the mesh's model axis (``elmo_head.head_train_step_sharded``).
    The chunk alignment (256) guarantees exact divisibility for power-of-two
    meshes; ceil-divide so ragged hypothetical shardings stay conservative."""
    return -(-L // max(1, n_shards))


@functools.lru_cache(maxsize=None)
def chunk_block_l(B: int, L: int, D: int, w_bytes: int = 1,
                  kahan: bool = False, cached_z: bool = False,
                  n_shards: int = 1) -> int:
    """Label-row tile for the fused chunk megakernel (grid = (L/bl,)).

    X, the x̄ accumulator, and the aliased x̄ in/out stay fully resident;
    only the W row-block (and the per-tile logits/grad transients) stream.
    The largest fitting bl wins — bl == L (one tile) keeps every reduction
    unsplit and makes the kernel bit-identical to the jnp oracle.  When no
    tile fits the model, returns LANE — callers that compile for real TPU
    must gate on ``fused_chunk_viable`` first (interpret/xla paths have no
    VMEM and use the fallback freely).

    ``n_shards`` > 1 budgets against the *local* (label-sharded) chunk:
    each device of a vocab-parallel head only ever streams L/n rows, so
    tiles are chosen for that width, not the global label count."""
    L = local_chunk(L, n_shards)
    for bl in sorted(set(_cands(L, cap=4096)), reverse=True):
        if _chunk_vmem(B, D, bl, w_bytes, kahan, cached_z) <= VMEM_BUDGET:
            return bl
    return LANE


@functools.lru_cache(maxsize=None)
def fused_chunk_viable(B: int, D: int, w_bytes: int = 1,
                       kahan: bool = False, cached_z: bool = False) -> bool:
    """Whether the megakernel fits VMEM at even the smallest label tile —
    the same model ``chunk_block_l`` minimizes over, so the gate and the
    chooser cannot disagree.  When False (huge token counts — LM prefill
    at B·S ≫ 10⁴), ``elmo_head`` falls back to the unfused path on the
    compiled-kernel backend."""
    return _chunk_vmem(B, D, LANE, w_bytes, kahan, cached_z) <= VMEM_BUDGET


def _head_grid_vmem(B: int, D: int, bl: int, w_bytes: int, kahan: bool,
                    z_cols: int, p_slots: int) -> int:
    """Whole-head grid-megakernel working-set model at label tile ``bl`` —
    the single source of truth for the grid tile chooser and its viability
    gate (``kernels/fused_head.py``, DESIGN.md §7).

    Versus the per-chunk model (``_chunk_vmem``) the *persistent* set grows:
    the BF16 running x̄ and the streaming-LSE / loss statistics stay in VMEM
    scratch across every grid step (they used to be ``lax.scan`` carries in
    HBM), the targets block is resident for the whole launch, and — when
    the CE z-cache is on — so are all ``z_cols`` cached logit columns
    (Pallas defines no in-launch ordering for an HBM spill through an
    aliased operand, so the cache must live in VMEM)."""
    Bp = _pad_up(max(B, 1), 16)          # bf16 sublane
    Dp = _pad_up(max(D, 1), LANE)
    resident = (Bp * Dp * 2              # X bf16
                + Bp * Dp * 4            # per-chunk x̄ accumulator f32
                + Bp * Dp * 2            # running x̄ bf16 (was a scan carry)
                + 2 * Bp * Dp * 2        # x̄ out block, buffered
                + 3 * Bp * 4             # LSE (m, s) + finalized lse f32
                + Bp * max(1, p_slots) * 4    # resident targets block
                + Bp * z_cols * 2)       # grid-resident z cache bf16
    per_tile = (2 * bl * Dp * w_bytes * 2          # W in+out, buffered
                + (2 * bl * Dp * 2 * 2 if kahan else 0)
                + Bp * bl * 10                      # z32 + g + g16 regs
                + bl * Dp * 4)                      # dW f32 transient
    return resident + per_tile


def _grid_z_cols(lc: int, bl: int, n_chunks: int, cache_z: bool) -> int:
    return n_chunks * _pad_up(lc, bl) if cache_z else 0


@functools.lru_cache(maxsize=None)
def head_grid_block_l(B: int, lc: int, D: int, w_bytes: int = 1,
                      kahan: bool = False, cache_z: bool = False,
                      p_slots: int = 1, n_chunks: int = 1) -> int:
    """Label-row tile for the whole-head grid megakernel.

    ``lc`` is the (local) rows *per chunk*; the grid iterates
    ``num_chunks · lc/bl`` label blocks in one launch, so the tile must
    tile a chunk exactly — every candidate ``bl`` pads ``lc`` up to a
    multiple of itself, and the largest fitting candidate wins
    (``bl == lc``, one block per chunk, keeps the in-kernel LSE/x̄
    recurrences bit-identical to the per-chunk scan).  Returns LANE when
    nothing fits; compiled callers must gate on ``fused_head_viable``."""
    for bl in sorted(set(_cands(lc, cap=4096)), reverse=True):
        if _head_grid_vmem(B, D, bl, w_bytes, kahan,
                           _grid_z_cols(lc, bl, n_chunks, cache_z),
                           p_slots) <= VMEM_BUDGET:
            return bl
    return LANE


def _sparse_head_vmem(B: int, D: int, F: int, bl: int, w_bytes: int,
                      kahan: bool, p_slots: int) -> int:
    """Sparse-head grid-megakernel working-set model at label tile ``bl``
    (``kernels/sparse_head.py``, DESIGN.md §13) — single source of truth
    for the sparse tile chooser and its viability gate.

    The resident set matches the dense grid model minus the z cache (the
    sparse kernel never caches: the densify+forward is recomputed from
    the same seed).  Per tile the *streams* shrink to the fan-in width
    ``Fp`` — values in+out, the read-only index stream, and the optional
    Kahan pair — but the densified (bl, Dp) BF16 tile and the dense dW
    transient join the working set: the MXU compute stays dense-shaped,
    only the HBM traffic scales with F."""
    Bp = _pad_up(max(B, 1), 16)
    Dp = _pad_up(max(D, 1), LANE)
    Fp = _pad_up(max(F, 1), LANE)
    resident = (Bp * Dp * 2              # X bf16
                + Bp * Dp * 4            # per-chunk x̄ accumulator f32
                + Bp * Dp * 2            # running x̄ bf16
                + 2 * Bp * Dp * 2        # x̄ out block, buffered
                + 3 * Bp * 4             # LSE (m, s) + finalized lse f32
                + Bp * max(1, p_slots) * 4)   # resident targets block
    per_tile = (2 * bl * Fp * w_bytes * 2          # values in+out, buffered
                + 2 * bl * Fp * 4                  # index stream, buffered
                + (2 * bl * Fp * 2 * 2 if kahan else 0)
                + bl * Dp * 2                      # densified W tile bf16
                + Bp * bl * 10                     # z32 + g + g16 regs
                + bl * Dp * 4                      # dense dW f32 transient
                + bl * Fp * 4)                     # gathered dv f32
    return resident + per_tile


@functools.lru_cache(maxsize=None)
def sparse_head_block_l(B: int, lc: int, D: int, F: int, w_bytes: int = 1,
                        kahan: bool = False, p_slots: int = 1,
                        n_chunks: int = 1) -> int:
    """Label-row tile for the sparse-head grid megakernel.  Same selection
    rule as ``head_grid_block_l`` (largest fitting candidate; ``bl == lc``
    keeps the in-kernel recurrences bit-identical to the per-chunk ref
    scan); returns LANE when nothing fits — compiled callers gate on
    ``sparse_head_viable``."""
    del n_chunks     # the resident set is per-launch, not per-chunk
    for bl in sorted(set(_cands(lc, cap=4096)), reverse=True):
        if _sparse_head_vmem(B, D, F, bl, w_bytes, kahan,
                             p_slots) <= VMEM_BUDGET:
            return bl
    return LANE


@functools.lru_cache(maxsize=None)
def sparse_head_viable(B: int, D: int, F: int, w_bytes: int = 1,
                       kahan: bool = False, p_slots: int = 1) -> bool:
    """Whether the sparse megakernel fits VMEM at even the smallest label
    tile — same model ``sparse_head_block_l`` minimizes over.  When False
    the sparse head runs the pure-JAX ref scan instead (no per-chunk
    kernel fallback exists for the sparse layout)."""
    return _sparse_head_vmem(B, D, F, LANE, w_bytes, kahan,
                             p_slots) <= VMEM_BUDGET


def _topk_vmem(B: int, D: int, bl: int, w_bytes: int, k: int,
               n_beam: int = 0) -> int:
    """Streaming top-k serving megakernel working-set model at label tile
    ``bl`` (``kernels/fused_topk.py``, DESIGN.md §9) — the single source of
    truth for its tile chooser and viability gate.

    Resident across the whole launch: X and the (B, K) value/id running
    top-k (carry + double-buffered output blocks).  Per tile: the
    double-buffered W stream, the masked logits block, and the selection
    merge's (B, K+bl) candidate value/id pair.

    ``n_beam`` > 0 is shortlisted mode (DESIGN §11): the (B, n_beam) int32
    admitted-cluster beam joins the resident set, and each tile also
    streams its (1, bl) int32 cluster-id block and holds the (B, bl)
    admit-mask transient."""
    Bp = _pad_up(max(B, 1), 16)
    Dp = _pad_up(max(D, 1), LANE)
    Kp = _pad_up(max(k, 1), LANE)
    Ep = _pad_up(n_beam, LANE) if n_beam else 0
    resident = (Bp * Dp * 2              # X bf16
                + Bp * Kp * 8            # running (vals f32, ids i32)
                + 2 * Bp * Kp * 8        # out blocks, double-buffered
                + Bp * Ep * 4)           # resident beam i32 (shortlisted)
    per_tile = (2 * bl * Dp * w_bytes    # W stream, double-buffered
                + Bp * bl * 10           # z16 + masked f32 + col ids
                + Bp * (Kp + bl) * 8     # merge candidate (value, id) pair
                + (2 * bl * 4 + Bp * bl if n_beam else 0))  # asg + admit
    return resident + per_tile


@functools.lru_cache(maxsize=None)
def topk_block_l(B: int, lc: int, D: int, w_bytes: int = 1,
                 k: int = 128, n_beam: int = 0) -> int:
    """Label-row tile for the streaming top-k grid (one launch walks
    ``num_chunks · lc/bl`` blocks).  Largest fitting candidate wins —
    fewer merge steps and longer DMA/MXU overlap windows.  Returns LANE
    when nothing fits; compiled callers gate on ``fused_topk_viable``."""
    for bl in sorted(set(_cands(lc, cap=4096)), reverse=True):
        if _topk_vmem(B, D, bl, w_bytes, k, n_beam) <= VMEM_BUDGET:
            return bl
    return LANE


@functools.lru_cache(maxsize=None)
def fused_topk_viable(B: int, D: int, w_bytes: int = 1,
                      k: int = 128, n_beam: int = 0) -> bool:
    """Whether the streaming top-k megakernel fits VMEM at the smallest
    tile — same model ``topk_block_l`` minimizes over.  ``k`` defaults to
    one lane tile (the plan resolves the serving path before the query k
    is known; any k ≤ 128 shares the padded carry footprint).  When False,
    serving falls back to the materialized or chunk-scan path."""
    return _topk_vmem(B, D, LANE, w_bytes, k, n_beam) <= VMEM_BUDGET


@functools.lru_cache(maxsize=None)
def shortlist_params(L: int, D: int, k: int = 10) -> tuple[int, int]:
    """(n_clusters, beam) for 2-stage shortlisted serving (DESIGN §11).

    Per-query work is C·D (stage 1: score the centroids) plus
    beam·(L/C)·D (stage 2: exact scan over admitted clusters, balanced
    partition so every cluster holds ≈ L/C labels) — minimized at
    C = √(beam·L), the classic PLT/X-Transformer √L geometry.  The beam
    is fixed small (recall, not residency, sets it: the golden fixture
    pins recall@10 ≥ 0.95 at beam 16) and C snaps to a power of two ≥
    LANE/4 so the centroid block and the assign stream stay tile-friendly.
    Returns (0, 0) — shortlisting off — when L is too small for a
    partition to pay (below ~256 labels stage 1 costs as much as exact).
    """
    if L < 256:
        return (0, 0)
    beam = 16
    c = 2 ** max(round(math.log2(math.sqrt(beam * L))), 5)
    c = max(min(c, L // 4), 2)
    return c, min(beam, c)


@functools.lru_cache(maxsize=None)
def head_logits_viable(B: int, D: int, w_bytes: int = 1) -> bool:
    """Whether the logits-only grid kernel (serving: ``fused_head_logits``)
    fits VMEM at the smallest tile.  Much looser than ``fused_head_viable``
    — the logits grid allocates none of the update pass's resident set
    (x̄ accumulators, running x̄, loss/LSE scratch, targets): just X, one
    double-buffered W tile and one double-buffered z output tile."""
    Bp = _pad_up(max(B, 1), 16)
    Dp = _pad_up(max(D, 1), LANE)
    return (Bp * Dp * 2                    # X bf16, resident
            + 2 * LANE * Dp * w_bytes      # W tile, buffered
            + 2 * Bp * LANE * 2            # z out tile, buffered
            + Bp * LANE * 4) <= VMEM_BUDGET   # f32 matmul accumulator


@functools.lru_cache(maxsize=None)
def fused_head_viable(B: int, D: int, w_bytes: int = 1, kahan: bool = False,
                      cache_z: bool = False, p_slots: int = 1,
                      lc: int = 0, n_chunks: int = 1) -> bool:
    """Whether the whole-head grid megakernel fits VMEM at even the smallest
    label tile — same model ``head_grid_block_l`` minimizes over, so gate
    and chooser cannot disagree.  ``cache_z`` asks for the grid-resident
    CE z-cache too (pass ``lc``/``n_chunks`` so its footprint is real).
    When False the head falls back to the per-chunk fused scan (which has
    its own ``fused_chunk_viable`` gate)."""
    return _head_grid_vmem(B, D, LANE, w_bytes, kahan,
                           _grid_z_cols(lc, LANE, n_chunks, cache_z),
                           p_slots) <= VMEM_BUDGET


def tuning_table(shapes=((256, 512, 256), (256, 512, 768), (1024, 512, 256),
                         (8192, 512, 1024), (256, 4096, 256))
                 ) -> list[dict]:
    """Chosen tiles for representative (B, chunk, D) shapes (DESIGN.md §5)."""
    rows = []
    for B, L, D in shapes:
        rows.append({
            "B": B, "chunk": L, "D": D,
            "logits": logits_blocks(B, L, D),
            "input_grad": input_grad_blocks(B, L, D),
            "update": update_blocks(B, L, D),
            "fused_chunk_bl": chunk_block_l(B, L, D),
            "head_grid_bl": head_grid_block_l(B, L, D),
        })
    return rows
