"""Pallas TPU megakernel: the ENTIRE ELMO head train step in one launch.

PR 1 fused one label-chunk step into a single ``pallas_call`` but still
drove one launch per chunk from a ``lax.scan`` (``elmo_head._scan_chunks``
→ ``fused_chunk.fused_chunk_step``), paying per-chunk launch overhead,
per-chunk alignment copies of x/x̄/targets, redundant HBM round-trips of
the carried x̄, and — for softmax-CE — a second full sweep of W in a
separate LSE scan.  This kernel moves the label loop *into the Pallas
grid* (DESIGN.md §7): the grid iterates over all label blocks of all
chunks, Pallas double-buffers the W (and Kahan ``comp``) stream so DMA
overlaps the MXU dots, and everything the scan used to carry through HBM
— x, the running x̄, the streaming-LSE statistics, the loss accumulator —
stays resident in VMEM scratch across every grid step.

    BCE (and CE-with-LSE-operand):   grid = (C·lcp/bl,)
    softmax-CE ("ce_full"):          grid = (2, C·lcp/bl)   pass 0 = LSE
                                                            pass 1 = update

    per label block (chunk c, rows [off, off+bl) of that chunk):
      z    = q8(X) @ W_blᵀ                  (MXU, f32 acc, → BF16)
      pass 0 (CE):  (m, s) ← online-LSE(m, s, mask(z))   [VMEM scratch]
                    optionally spill z to the grid-mapped HBM cache
      update pass:  ḡ  = loss-skip grad(z)                (BCE scatter /
                                                           exp(z − LSE) − 1y)
                    x̄_f32 += ḡ @ W_bl          x̄_bf16 += x̄_f32 at chunk ends
                    dW = ḡᵀ X;  W_bl ← SR(…) or KahanAdd(…)  (in place via
                                                       input_output_aliases)

The CE z-cache stays *grid-resident*: pass 0 stores each logits block
into a VMEM scratch buffer that persists across every grid step, and
pass 1 reloads it instead of re-running the forward matmul — replacing
the PR-1 second launch.  (A single launch cannot spill the cache through
an aliased HBM operand: Pallas defines no write→read ordering between an
output block and its aliased input within one launch — the sharded path,
which must cross a collective anyway, passes the z buffer between its two
launches instead, where that ordering *is* defined.)  With the cache off
— the tuner's choice whenever B·L·2 exceeds the VMEM residency budget —
pass 1 recomputes z in-register from the same per-chunk DropConnect
seed: bit-identical either way.

Numerics mirror the per-chunk scan *operation for operation*: the same
per-chunk seed hash, the same SR-bit addressing by (row-in-chunk, col),
the same per-chunk BF16 rounding of the carried x̄, and the same per-chunk
LSE/loss accumulation order — so with one block per chunk (``bl == lc``,
the tuner's preference) the whole-head step is bit-identical to scanning
``fused_chunk_step`` over chunks, which is itself bit-identical to the
legacy unfused path.  ``fused_head_lse`` / ``fused_head_logits`` expose
the LSE-only and logits-only grids for the label-sharded CE path (whose
cross-device normalizer needs a collective between the passes) and for
serving.

Pipelining note: the two-pass grid revisits the aliased W/comp streams;
pass 0 writes them back *unchanged* (a mapped output block must be
written at every step that visits it), so whether pass 1's re-fetch
observes the flushed copy or a stale buffer is immaterial — the bytes
are identical.  Only the update pass mutates them, and each block exactly
once.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import precision as P
from repro.core.losses import NEG_INF
from repro.kernels import prng_utils as PR
from repro.kernels import tuning
from repro.kernels.fused_head_update import _apply_sr

_UPDATE_MODES = ("bce", "ce_full", "ce_update")


class HeadStepOut(NamedTuple):
    """Results of one whole-head grid step (None for absent outputs)."""
    w: jax.Array                      # updated weights (C, lc, D)
    xg: jax.Array                     # x̄ (B, D) bf16
    loss: jax.Array                   # f32 scalar raw loss accumulator
    comp: Optional[jax.Array] = None  # updated Kahan buffer (C, lc, D)
    lse: Optional[jax.Array] = None   # (B,) f32 (mode="ce_full" only)
    z: Optional[jax.Array] = None     # (B, C·lc) bf16 logits (cache_z, bce)
    tele: Optional[jax.Array] = None  # (8,) f32 numerics telemetry (guard)


class LseOut(NamedTuple):
    """Streaming-LSE statistics of one ``fused_head_lse`` launch."""
    m: jax.Array                      # (B,) f32 running max
    s: jax.Array                      # (B,) f32 running Σexp
    z: Optional[jax.Array] = None     # (B, C·lc) bf16 logits (cache_z)


def _head_kernel(*refs, mode: str, num_labels: int, lc: int, bpc: int,
                 n_b: int, kahan: bool, cache_z: bool, use_sr: bool,
                 quantize_x: bool, drop_rate: float, compute_loss: bool,
                 guard: bool):
    # ---- unpack the mode-dependent ref list ----
    update = mode in _UPDATE_MODES
    it = iter(refs)
    sd_ref = next(it)
    su_ref = next(it) if update else None
    hyper_ref = next(it) if update else None
    base_ref = next(it) if mode != "logits" else None
    tgt_ref = next(it) if update else None
    lse_in_ref = next(it) if mode == "ce_update" else None
    z_in_ref = next(it) if (cache_z and mode == "ce_update") else None
    x_ref, w_ref = next(it), next(it)
    comp_ref = next(it) if (kahan and update) else None
    if update:
        w_out_ref = next(it)
        comp_out_ref = next(it) if kahan else None
        z_out_ref = next(it) if (cache_z and mode == "bce") else None
        xg_out_ref, loss_ref = next(it), next(it)
        lse_out_ref = next(it) if mode == "ce_full" else None
        tele_ref = next(it) if guard else None
    elif mode == "ce_lse":
        z_out_ref = next(it) if cache_z else None
        m_out_ref, s_out_ref = next(it), next(it)
    else:                                   # logits
        z_out_ref = next(it)
    if update:
        xg_acc, xg_b16, loss_acc = next(it), next(it), next(it)
    if mode in ("ce_full", "ce_lse"):
        m_acc, s_acc = next(it), next(it)
    if mode == "ce_full":
        lse_v = next(it)
        z_sc = next(it) if cache_z else None    # grid-resident z cache
    tele_acc = next(it) if guard else None

    if mode == "ce_full":
        pss, li = pl.program_id(0), pl.program_id(1)
        nb = pl.num_programs(1)
    else:
        pss, li = None, pl.program_id(0)
        nb = pl.num_programs(0)

    Bp, Dp = x_ref.shape
    bl = w_ref.shape[0]
    cidx = li // bpc                         # chunk of this label block
    off = (li % bpc) * bl                    # row offset inside the chunk
    w16 = w_ref[...].astype(jnp.bfloat16)
    x16 = x_ref[...].astype(jnp.bfloat16)

    # masks in the *global* label coordinate (same construction as the
    # per-chunk kernel: local-row validity × real-label validity)
    col_local = jax.lax.broadcasted_iota(jnp.int32, (Bp, bl), 1) + off
    rowv = (jax.lax.broadcasted_iota(jnp.int32, (Bp, bl), 0)
            < n_b).astype(jnp.float32)
    if mode != "logits":
        col_global = col_local + base_ref[cidx]
        valid = ((col_global < num_labels)
                 & (col_local < lc)).astype(jnp.float32)

    def compute_z16():
        """q8(X) @ Wᵀ with in-kernel DropConnect — op-for-op the per-chunk
        kernel's forward, seeded per chunk and addressed per row-in-chunk,
        so cached and recomputed logits agree bit-for-bit."""
        xq = x_ref[...]
        if quantize_x:
            xq = xq.astype(jnp.float8_e4m3fn)
        xq = xq.astype(jnp.bfloat16)
        wmm = w16
        if drop_rate > 0.0:
            bits = PR.hash_bits_2d(sd_ref[cidx], off.astype(jnp.uint32),
                                   jnp.uint32(0), (bl, Dp))
            keep = PR.uniform_from_bits(bits) >= drop_rate
            wmm = jnp.where(keep, w16, jnp.bfloat16(0.0)) \
                / jnp.bfloat16(1.0 - drop_rate)
        z32mm = jax.lax.dot_general(xq, wmm, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        return z32mm.astype(jnp.bfloat16)

    if mode == "logits":
        z_out_ref[...] = compute_z16()
        return

    # ---- pass 0 / LSE-only work: streaming (max, Σexp) in VMEM scratch ----
    def lse_work():
        z16 = compute_z16()
        if cache_z:
            if mode == "ce_full":
                z_sc[:, pl.ds(li * bl, bl)] = z16
            else:
                z_out_ref[...] = z16
        zm = jnp.where(valid > 0, z16.astype(jnp.float32), NEG_INF)

        @pl.when(li == 0)
        def _init():
            m_acc[...] = jnp.full_like(m_acc, NEG_INF)
            s_acc[...] = jnp.zeros_like(s_acc)

        m = m_acc[...]
        m_new = jnp.maximum(m, zm.max(axis=-1, keepdims=True))
        s_acc[...] = (s_acc[...] * jnp.exp(m - m_new)
                      + jnp.exp(zm - m_new).sum(-1, keepdims=True))
        m_acc[...] = m_new

    # ---- update-pass work: grad, x̄, in-place W/comp update, loss ----
    def update_work():
        first = (li == 0)

        @pl.when(first)
        def _init():
            xg_acc[...] = jnp.zeros_like(xg_acc)
            xg_b16[...] = jnp.zeros_like(xg_b16)
            loss_acc[...] = jnp.zeros_like(loss_acc)
            if guard:
                tele_acc[...] = jnp.zeros_like(tele_acc)

        if cache_z and mode == "ce_full":
            z16 = z_sc[:, pl.ds(li * bl, bl)]
        elif cache_z and mode == "ce_update":
            z16 = z_in_ref[...]
        else:
            z16 = compute_z16()
            if cache_z and mode == "bce":
                z_out_ref[...] = z16
        z32 = z16.astype(jnp.float32)
        lr, wd, scale = hyper_ref[0], hyper_ref[1], hyper_ref[2]

        if mode == "bce":
            y = jnp.zeros((Bp, bl), jnp.float32)
            for slot in range(tgt_ref.shape[1]):
                y = jnp.maximum(
                    y, (col_global == tgt_ref[:, slot:slot + 1]
                        ).astype(jnp.float32))
            g32 = (jax.nn.sigmoid(z32) - y) * scale * valid * rowv
            if compute_loss:
                per = (jnp.maximum(z32, 0.0) - z32 * y
                       + jnp.log1p(jnp.exp(-jnp.abs(z32))))
                loss_acc[0, 0] += jnp.sum(per * valid * rowv)
        else:
            tid = tgt_ref[...]                              # (Bp, 1) int32
            onehot = (col_global == tid).astype(jnp.float32)
            tokm = (tid >= 0).astype(jnp.float32)           # (Bp, 1)
            lse_row = (lse_in_ref[...] if mode == "ce_update"
                       else lse_v[...])
            prob = jnp.exp(z32 - lse_row)
            g32 = (prob - onehot) * scale * valid * tokm * rowv
            if compute_loss:
                loss_acc[0, 0] += jnp.sum(z32 * onehot * rowv)

        g16 = g32.astype(jnp.bfloat16)
        xg_acc[...] += jnp.dot(g16, w16, preferred_element_type=jnp.float32)

        # the per-chunk scan rounded the carried x̄ to BF16 between chunks;
        # replay that rounding at every chunk's last block so the grid step
        # is bit-identical to the scan
        @pl.when((li + 1) % bpc == 0)
        def _chunk_flush():
            xg_b16[...] = (xg_b16[...]
                           + xg_acc[...].astype(jnp.bfloat16))
            xg_acc[...] = jnp.zeros_like(xg_acc)

        @pl.when(li == nb - 1)
        def _final_flush():
            xg_out_ref[...] = xg_b16[...]
            loss_ref[0, 0] = loss_acc[0, 0]

        dw = jax.lax.dot_general(g16, x16, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        w32 = w_ref[...].astype(jnp.float32)
        if kahan:
            upd = -lr * dw - (lr * wd) * w32
            yk = upd - comp_ref[...].astype(jnp.float32)
            t32 = w32 + yk
            w_new = t32.astype(w_out_ref.dtype)
            w_out_ref[...] = w_new
            c_new = ((w_new.astype(jnp.float32) - w32) - yk
                     ).astype(comp_out_ref.dtype)
            comp_out_ref[...] = c_new
            pre_cast = t32
            cmax = jnp.max(jnp.abs(c_new.astype(jnp.float32)))
        else:
            w_new = w32 * (1.0 - lr * wd) - lr * dw
            bits = PR.hash_bits_2d(su_ref[cidx], off.astype(jnp.uint32),
                                   jnp.uint32(0), (bl, Dp))
            w_out_ref[...] = _apply_sr(w_new, w_out_ref.dtype, bits, use_sr)
            pre_cast, cmax = w_new, jnp.float32(0.0)

        if guard:
            # numerics telemetry (DESIGN.md §14): pure reads of values the
            # update already computed, accumulated in a private scratch
            # row — bitwise invisible to W/comp/x̄/loss.  Counted only in
            # the update pass (ce_full pass 0 recomputes z but never
            # counts it).  Padding contributes exactly 0.
            lim = jnp.float32(P.max_finite(w_out_ref.dtype))
            sat = jnp.sum((jnp.abs(pre_cast) >= lim).astype(jnp.float32))
            znf = jnp.sum((~jnp.isfinite(z32)).astype(jnp.float32)
                          * valid * rowv)
            slot = jax.lax.broadcasted_iota(jnp.int32, tele_acc.shape, 1)
            acc = (tele_acc[...] + jnp.where(slot == 0, sat, 0.0)
                   + jnp.where(slot == 1, znf, 0.0))
            tele_acc[...] = jnp.maximum(acc,
                                        jnp.where(slot == 4, cmax, 0.0))

            @pl.when(li == nb - 1)
            def _tele_flush():
                tele_ref[...] = tele_acc[...]

    if mode == "ce_lse":
        lse_work()

        @pl.when(li == nb - 1)
        def _emit_stats():
            m_out_ref[...] = m_acc[...]
            s_out_ref[...] = s_acc[...]
    elif mode == "ce_full":
        @pl.when(pss == 0)
        def _pass0():
            lse_work()
            # every mapped output block must be written each step it is
            # visited: write the (aliased) W/comp streams back unchanged
            w_out_ref[...] = w_ref[...]
            if kahan:
                comp_out_ref[...] = comp_ref[...]

            @pl.when(li == nb - 1)
            def _finalize_lse():
                lse_v[...] = m_acc[...] + jnp.log(s_acc[...])
                lse_out_ref[...] = lse_v[...]

        @pl.when(pss == 1)
        def _pass1():
            update_work()
    else:                                   # bce / ce_update
        update_work()


def _head_shapes(B, D, lc, block_l, interpret):
    """(Bp, Dp, lcp, bl): interpret mode keeps exact shapes (alignment
    padding would change the K length of the f32 dots and break bitwise
    parity with the oracle scan — same rule as ``fused_chunk_step``)."""
    if interpret:
        bl = lc if block_l is None else min(block_l, lc)
        if lc % bl != 0:
            bl = lc
        return B, D, lc, bl
    Bp = tuning._pad_up(B, 16)
    Dp = tuning._pad_up(D, tuning.LANE)
    # sublane-align only (same rule as fused_chunk_step): the tuner's
    # candidates are already sublane-padded, so the compiled tile equals
    # the one the VMEM model validated — rounding further (e.g. to LANE)
    # would inflate the real footprint past the model
    bl = min(block_l or lc, tuning._pad_up(lc, tuning.LANE))
    bl = tuning._pad_up(bl, tuning.SUBLANE)
    return Bp, Dp, tuning._pad_up(lc, bl), bl


def _pad_w3(w, lcp, Dp):
    C, lc, D = w.shape
    if (lcp, Dp) != (lc, D):
        w = jnp.pad(w, ((0, 0), (0, lcp - lc), (0, Dp - D)))
    return w.reshape(C * lcp, Dp)


def _slice_w3(wflat, C, lcp, lc, D):
    return wflat.reshape(C, lcp, -1)[:, :lc, :D]


def _slice_z(zp, B, C, lcp, lc):
    return zp.reshape(-1, C, lcp)[:B, :, :lc].reshape(B, C * lc)


def _launch(mode, x, w, targets, lr, wd, scale, seeds_drop, seeds_upd, base,
            lse, z, comp, num_labels, use_sr, quantize_x, drop_rate,
            compute_loss, cache_z, block_l, interpret, guard=False):
    """Shared spec/operand assembly for every grid-kernel entry point."""
    (B, D), (C, lc, _) = x.shape, w.shape
    update = mode in _UPDATE_MODES
    kahan = comp is not None
    interpret = tuning.interpret_default(interpret)
    if block_l is None and not interpret:
        block_l = tuning.head_grid_block_l(
            B, lc, D, jnp.dtype(w.dtype).itemsize, kahan=kahan,
            cache_z=cache_z and mode == "ce_full", n_chunks=C,
            p_slots=targets.shape[-1] if (update and targets.ndim == 2)
            else 1)
    Bp, Dp, lcp, bl = _head_shapes(B, D, lc, block_l, interpret)
    bpc = lcp // bl
    nb = C * bpc
    xp = tuning.pad2(x.astype(jnp.bfloat16), Bp, Dp)
    wflat = _pad_w3(w, lcp, Dp)

    if mode == "ce_full":
        def full(p, l):
            return (0, 0)

        def wmap(p, l):
            return (l, 0)

        def zmap(p, l):
            return (0, l)
        grid = (2, nb)
    else:
        def full(l):
            return (0, 0)

        def wmap(l):
            return (l, 0)

        def zmap(l):
            return (0, l)
        grid = (nb,)

    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    operands = [jnp.asarray(seeds_drop).astype(jnp.uint32)]
    in_specs = [smem]
    if update:
        operands.append(jnp.asarray(seeds_upd).astype(jnp.uint32))
        in_specs.append(smem)
        hyper = jnp.stack([jnp.asarray(lr, jnp.float32),
                           jnp.asarray(wd, jnp.float32),
                           jnp.asarray(scale, jnp.float32)])
        operands.append(hyper)
        in_specs.append(smem)
    if mode != "logits":
        operands.append(jnp.asarray(base).astype(jnp.int32))
        in_specs.append(smem)
    if update:
        tgt = targets if targets.ndim == 2 else targets.reshape(B, 1)
        tp = tuning.pad2(tgt, Bp, 1, value=-1)
        operands.append(tp)
        in_specs.append(pl.BlockSpec(tp.shape, full))
    if mode == "ce_update":
        operands.append(
            tuning.pad2(lse.reshape(B, 1).astype(jnp.float32), Bp, 1))
        in_specs.append(pl.BlockSpec((Bp, 1), full))
    if cache_z and mode == "ce_update":
        zp = jnp.pad(z.astype(jnp.bfloat16).reshape(B, C, lc),
                     ((0, Bp - B), (0, 0), (0, lcp - lc))
                     ).reshape(Bp, C * lcp)
        operands.append(zp)
        in_specs.append(pl.BlockSpec((Bp, bl), zmap))
    w_idx = len(operands) + 1
    operands += [xp, wflat]
    in_specs += [pl.BlockSpec((Bp, Dp), full),
                 pl.BlockSpec((bl, Dp), wmap)]
    if kahan and update:
        operands.append(_pad_w3(comp, lcp, Dp))
        in_specs.append(pl.BlockSpec((bl, Dp), wmap))

    out_shape, out_specs = [], []
    if update:
        out_shape += [jax.ShapeDtypeStruct((C * lcp, Dp), w.dtype)]
        out_specs += [pl.BlockSpec((bl, Dp), wmap)]
        if kahan:
            out_shape.append(jax.ShapeDtypeStruct((C * lcp, Dp), comp.dtype))
            out_specs.append(pl.BlockSpec((bl, Dp), wmap))
        if cache_z and mode == "bce":
            out_shape.append(jax.ShapeDtypeStruct((Bp, C * lcp),
                                                  jnp.bfloat16))
            out_specs.append(pl.BlockSpec((Bp, bl), zmap))
        out_shape += [jax.ShapeDtypeStruct((Bp, Dp), jnp.bfloat16),
                      jax.ShapeDtypeStruct((1, 1), jnp.float32)]
        out_specs += [pl.BlockSpec((Bp, Dp), full),
                      pl.BlockSpec((1, 1), full)]
        if mode == "ce_full":
            out_shape.append(jax.ShapeDtypeStruct((Bp, 1), jnp.float32))
            out_specs.append(pl.BlockSpec((Bp, 1), full))
        if guard:
            out_shape.append(jax.ShapeDtypeStruct((1, 8), jnp.float32))
            out_specs.append(pl.BlockSpec((1, 8), full))
    elif mode == "ce_lse":
        if cache_z:
            out_shape.append(jax.ShapeDtypeStruct((Bp, C * lcp),
                                                  jnp.bfloat16))
            out_specs.append(pl.BlockSpec((Bp, bl), zmap))
        out_shape += [jax.ShapeDtypeStruct((Bp, 1), jnp.float32),
                      jax.ShapeDtypeStruct((Bp, 1), jnp.float32)]
        out_specs += [pl.BlockSpec((Bp, 1), full),
                      pl.BlockSpec((Bp, 1), full)]
    else:                                   # logits
        out_shape.append(jax.ShapeDtypeStruct((Bp, C * lcp), jnp.bfloat16))
        out_specs.append(pl.BlockSpec((Bp, bl), zmap))

    aliases = {}
    if update:
        aliases[w_idx] = 0
        if kahan:
            aliases[w_idx + 1] = 1

    scratch = []
    if update:
        scratch += [pltpu.VMEM((Bp, Dp), jnp.float32),
                    pltpu.VMEM((Bp, Dp), jnp.bfloat16),
                    pltpu.VMEM((1, 1), jnp.float32)]
    if mode in ("ce_full", "ce_lse"):
        scratch += [pltpu.VMEM((Bp, 1), jnp.float32),
                    pltpu.VMEM((Bp, 1), jnp.float32)]
    if mode == "ce_full":
        scratch.append(pltpu.VMEM((Bp, 1), jnp.float32))
        if cache_z:     # grid-resident z cache (persists across both passes)
            scratch.append(pltpu.VMEM((Bp, C * lcp), jnp.bfloat16))
    if guard:
        scratch.append(pltpu.VMEM((1, 8), jnp.float32))

    outs = pl.pallas_call(
        functools.partial(
            _head_kernel, mode=mode, num_labels=num_labels, lc=lc, bpc=bpc,
            n_b=B, kahan=kahan and update, cache_z=cache_z, use_sr=use_sr,
            quantize_x=quantize_x, drop_rate=drop_rate,
            compute_loss=compute_loss, guard=guard),
        grid=grid,
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shape),
        scratch_shapes=scratch,
        input_output_aliases=aliases,
        interpret=interpret,
    )(*operands)
    return outs, (B, D, C, lc, lcp, kahan)


@functools.partial(jax.jit, static_argnames=(
    "mode", "num_labels", "use_sr", "quantize_x", "drop_rate",
    "compute_loss", "cache_z", "block_l", "interpret", "guard"))
def fused_head_step(x: jax.Array, w: jax.Array, targets: jax.Array,
                    lr, wd, scale, seeds_drop: jax.Array,
                    seeds_upd: jax.Array, base: jax.Array,
                    lse: jax.Array | None = None,
                    z: jax.Array | None = None,
                    comp: jax.Array | None = None, *,
                    mode: str, num_labels: int, use_sr: bool = True,
                    quantize_x: bool = True, drop_rate: float = 0.0,
                    compute_loss: bool = True, cache_z: bool = False,
                    block_l: int | None = None,
                    interpret: bool | None = None,
                    guard: bool = False) -> HeadStepOut:
    """One whole-head train step in a single launch.

    x (B, D) bf16 · w (C, lc, D) storage dtype · targets (B, P)/(B,) int32 ·
    seeds_drop/seeds_upd (C,) uint32 per-chunk DropConnect/SR seeds ·
    base (C,) int32 global label id of each chunk's local row 0 · comp
    (C, lc, D) BF16 Kahan buffer (all-chunks Kahan; the mixed hybrid runs
    on the per-chunk scan).  ``mode``:

    * ``"bce"``       — 1 launch; ``cache_z`` additionally emits the (B,
      C·lc) logits (the sharded gather-loss path reads them back).
    * ``"ce_full"``   — 1 launch, 2-pass grid; returns the finalized LSE;
      ``cache_z`` keeps the pass-0 logits grid-resident in VMEM scratch
      so pass 1 skips the forward matmul (gate on
      ``tuning.fused_head_viable(..., cache_z=True)`` when compiling).
    * ``"ce_update"`` — 1 launch, LSE passed in (the sharded CE path, whose
      normalizer needs a collective between the passes); ``z`` optionally
      feeds pre-computed logits back in.
    """
    assert mode in _UPDATE_MODES, mode
    if mode == "ce_update":
        assert lse is not None, "ce_update needs the finalized LSE"
    outs, (B, D, C, lc, lcp, kahan) = _launch(
        mode, x, w, targets, lr, wd, scale, seeds_drop, seeds_upd, base,
        lse, z, comp, num_labels, use_sr, quantize_x, drop_rate,
        compute_loss, cache_z, block_l, interpret, guard=guard)
    it = iter(outs)
    w_new = _slice_w3(next(it), C, lcp, lc, D)
    comp_new = _slice_w3(next(it), C, lcp, lc, D) if kahan else None
    z_out = None
    if cache_z and mode == "bce":
        z_out = _slice_z(next(it), B, C, lcp, lc)
    xg = next(it)[:B, :D]
    loss = next(it)[0, 0]
    lse_out = next(it)[:B, 0] if mode == "ce_full" else None
    tele = next(it)[0] if guard else None
    return HeadStepOut(w_new, xg, loss, comp_new, lse_out, z_out, tele)


@functools.partial(jax.jit, static_argnames=(
    "num_labels", "quantize_x", "drop_rate", "cache_z", "block_l",
    "interpret"))
def fused_head_lse(x: jax.Array, w: jax.Array, seeds_drop: jax.Array,
                   base: jax.Array, *, num_labels: int,
                   quantize_x: bool = True, drop_rate: float = 0.0,
                   cache_z: bool = False, block_l: int | None = None,
                   interpret: bool | None = None) -> LseOut:
    """Streaming (max, Σexp) over every label block in one launch — the
    local half of the sharded CE normalizer (``ce_comm="stats"``); the
    caller folds the cross-device pmax/psum and finalizes."""
    outs, (B, D, C, lc, lcp, _) = _launch(
        "ce_lse", x, w, None, None, None, None, seeds_drop, None, base,
        None, None, None, num_labels, False, quantize_x, drop_rate, False,
        cache_z, block_l, interpret)
    it = iter(outs)
    z_out = _slice_z(next(it), B, C, lcp, lc) if cache_z else None
    return LseOut(next(it)[:B, 0], next(it)[:B, 0], z_out)


@functools.partial(jax.jit, static_argnames=(
    "quantize_x", "drop_rate", "block_l", "interpret"))
def fused_head_logits(x: jax.Array, w: jax.Array, seeds_drop: jax.Array, *,
                      quantize_x: bool = True, drop_rate: float = 0.0,
                      block_l: int | None = None,
                      interpret: bool | None = None) -> jax.Array:
    """All (B, C·lc) head logits in one launch (serving: ``head_logits``
    and the materialized-top-k fast path) — replaces one ``fp8_logits``
    launch per chunk."""
    outs, (B, D, C, lc, lcp, _) = _launch(
        "logits", x, w, None, None, None, None, seeds_drop, None, None,
        None, None, None, 0, False, quantize_x, drop_rate, False, False,
        block_l, interpret)
    return _slice_z(outs[0], B, C, lcp, lc)
