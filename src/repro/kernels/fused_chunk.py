"""Pallas TPU megakernel: one ELMO label-chunk step in a single launch.

The seed implementation ran each chunk as three kernel launches with HBM
round-trips between them (``fp8_logits`` → jnp loss-skip grad →
``fp8_input_grad`` + ``fused_head_update``), so the (B, chunk) logits and
the BF16 logit gradient each crossed HBM multiple times per chunk.  This
kernel collapses the whole step (DESIGN.md §3):

    grid = (chunk/bl,) over W row-blocks; X, x̄ stay fully resident

    per tile l:
      z_l  = q8(X) @ W_lᵀ                 (MXU, f32 accumulate, → BF16)
      ḡ_l  = loss-skip grad(z_l)           (BCE multi-hot scatter, or
                                            softmax-CE from the LSE operand)
      x̄   += ḡ_l @ W_l                    (f32 VMEM accumulator)
      dW_l = ḡ_lᵀ X                        (full-B, single pass)
      W_l ← SR((1 − lr·wd) W_l − lr dW_l)  (in place via input_output_aliases)
      or (W_l, C_l) ← KahanAdd(...)        (head-label hybrid, App. D)

Neither logits nor gradients ever materialize in HBM; the only HBM traffic
is X, W (1 byte/elem in + out), the aliased x̄, and a scalar loss.

Numerics mirror the unfused path *operation for operation* (z truncated to
BF16 before the gradient, ḡ cast to BF16 before both matmuls, counter-hash
SR bits addressed by global element index), so with an unsplit tile
(bl == chunk — the tuner's choice whenever VMEM allows) interpret-mode
outputs are bit-identical to ``ref.fused_chunk_ref`` and to the legacy
multi-kernel path.

The CE path takes the streaming LSE as an operand; ``z`` may be passed in
(cached from the LSE pre-pass) to skip the forward matmul entirely
(``elmo_head`` enables this for small chunk counts where the z cache fits).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import precision as P
from repro.kernels import prng_utils as PR
from repro.kernels import tuning
from repro.kernels.fused_head_update import _apply_sr


class ChunkOut(NamedTuple):
    """Results of one fused chunk step (None for absent optional outputs)."""
    w: jax.Array                     # updated chunk weights (L, D)
    xg: jax.Array                    # x̄ accumulator after this chunk (B, D)
    loss: jax.Array                  # f32 scalar chunk loss contribution
    comp: Optional[jax.Array] = None  # updated Kahan buffer (kahan chunks)
    z: Optional[jax.Array] = None    # chunk logits (only when return_z)
    tele: Optional[jax.Array] = None  # (8,) f32 numerics telemetry (guard)


def _chunk_kernel(seeds_ref, hyper_ref, c0_ref, tgt_ref, *refs,
                  loss: str, num_labels: int, n_b: int, n_l: int,
                  use_sr: bool, quantize_x: bool, drop_rate: float,
                  compute_loss: bool, cached_z: bool, kahan: bool,
                  return_z: bool, guard: bool):
    # ---- unpack the flag-dependent ref list ----
    it = iter(refs)
    lse_ref = next(it) if loss == "softmax_ce" else None
    z_ref = next(it) if cached_z else None
    x_ref, w_ref, xg_ref = next(it), next(it), next(it)
    comp_ref = next(it) if kahan else None
    w_out_ref, xg_out_ref, loss_ref = next(it), next(it), next(it)
    comp_out_ref = next(it) if kahan else None
    z_out_ref = next(it) if return_z else None
    tele_ref = next(it) if guard else None
    xg_acc, loss_acc = next(it), next(it)
    tele_acc = next(it) if guard else None

    li = pl.program_id(0)
    nl = pl.num_programs(0)
    Bp, Dp = x_ref.shape
    bl = w_ref.shape[0]

    @pl.when(li == 0)
    def _init():
        xg_acc[...] = jnp.zeros_like(xg_acc)
        loss_acc[...] = jnp.zeros_like(loss_acc)
        if guard:
            tele_acc[...] = jnp.zeros_like(tele_acc)

    lr, wd, scale = hyper_ref[0], hyper_ref[1], hyper_ref[2]
    row0 = (li * bl).astype(jnp.uint32)
    w16 = w_ref[...].astype(jnp.bfloat16)
    x16 = x_ref[...].astype(jnp.bfloat16)

    # ---- forward: logits tile (or the cached pass-1 logits) ----
    if cached_z:
        z16 = z_ref[...]
    else:
        xq = x_ref[...]
        if quantize_x:
            # paper §4.3: inputs cast to E4M3 for the logits product
            xq = xq.astype(jnp.float8_e4m3fn)
        xq = xq.astype(jnp.bfloat16)
        wmm = w16
        if drop_rate > 0.0:
            # in-kernel DropConnect (App. H) — same global-index hash as
            # fp8_logits, so cached and recomputed z agree bit-for-bit
            bits = PR.hash_bits_2d(seeds_ref[0], row0, jnp.uint32(0),
                                   (bl, Dp))
            keep = PR.uniform_from_bits(bits) >= drop_rate
            wmm = jnp.where(keep, w16, jnp.bfloat16(0.0)) \
                / jnp.bfloat16(1.0 - drop_rate)
        z32mm = jax.lax.dot_general(xq, wmm, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        z16 = z32mm.astype(jnp.bfloat16)
    if return_z:
        z_out_ref[...] = z16

    # gradient math reads the BF16-truncated logits (= what the unfused
    # path sees coming back from HBM)
    z32 = z16.astype(jnp.float32)

    # ---- loss-skip logit gradient, fully in-register ----
    col_local = jax.lax.broadcasted_iota(jnp.int32, (Bp, bl), 1) + li * bl
    col_global = col_local + c0_ref[0]
    valid = ((col_global < num_labels)
             & (col_local < n_l)).astype(jnp.float32)
    rowv = (jax.lax.broadcasted_iota(jnp.int32, (Bp, bl), 0)
            < n_b).astype(jnp.float32)

    if loss == "bce":
        # multi-hot scatter of the (B, P) padded label ids: one compare per
        # target slot; ids of −1 / other chunks never match a column
        y = jnp.zeros((Bp, bl), jnp.float32)
        for p in range(tgt_ref.shape[1]):
            y = jnp.maximum(
                y, (col_global == tgt_ref[:, p:p + 1]).astype(jnp.float32))
        g32 = (jax.nn.sigmoid(z32) - y) * scale * valid * rowv
        if compute_loss:
            per = (jnp.maximum(z32, 0.0) - z32 * y
                   + jnp.log1p(jnp.exp(-jnp.abs(z32))))
            loss_acc[0, 0] += jnp.sum(per * valid * rowv)
    else:
        tid = tgt_ref[...]                                  # (Bp, 1) int32
        onehot = (col_global == tid).astype(jnp.float32)
        tokm = (tid >= 0).astype(jnp.float32)               # (Bp, 1)
        prob = jnp.exp(z32 - lse_ref[...])
        g32 = (prob - onehot) * scale * valid * tokm * rowv
        if compute_loss:
            # Σ target logits; the caller folds Σ lse − this into CE loss
            loss_acc[0, 0] += jnp.sum(z32 * onehot * rowv)

    g16 = g32.astype(jnp.bfloat16)

    # ---- x̄ += ḡ @ W from the still-resident tiles ----
    xg_acc[...] += jnp.dot(g16, w16, preferred_element_type=jnp.float32)

    @pl.when(li == nl - 1)
    def _flush():
        xg_out_ref[...] = xg_ref[...] + xg_acc[...].astype(jnp.bfloat16)
        loss_ref[0, 0] = loss_acc[0, 0]

    # ---- fused weight update, in place (full B in one pass) ----
    dw = jax.lax.dot_general(g16, x16, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    w32 = w_ref[...].astype(jnp.float32)
    if kahan:
        upd = -lr * dw - (lr * wd) * w32
        yk = upd - comp_ref[...].astype(jnp.float32)
        t32 = w32 + yk
        w_new = t32.astype(w_out_ref.dtype)
        w_out_ref[...] = w_new
        c_new = ((w_new.astype(jnp.float32) - w32) - yk
                 ).astype(comp_out_ref.dtype)
        comp_out_ref[...] = c_new
        pre_cast, cmax = t32, jnp.max(jnp.abs(c_new.astype(jnp.float32)))
    else:
        w_new = w32 * (1.0 - lr * wd) - lr * dw
        bits = PR.hash_bits_2d(seeds_ref[1], row0, jnp.uint32(0), (bl, Dp))
        w_out_ref[...] = _apply_sr(w_new, w_out_ref.dtype, bits, use_sr)
        pre_cast, cmax = w_new, jnp.float32(0.0)

    if guard:
        # numerics telemetry (DESIGN.md §14) — pure reads of values the
        # update already computed, accumulated in a private scratch row:
        # bitwise invisible to W/comp/x̄/loss.  Padding contributes 0
        # (padded updates are 0; a poisoned-x NaN fails the >= compare).
        lim = jnp.float32(P.max_finite(w_out_ref.dtype))
        sat = jnp.sum((jnp.abs(pre_cast) >= lim).astype(jnp.float32))
        znf = jnp.sum((~jnp.isfinite(z32)).astype(jnp.float32)
                      * valid * rowv)
        slot = jax.lax.broadcasted_iota(jnp.int32, tele_acc.shape, 1)
        acc = (tele_acc[...] + jnp.where(slot == 0, sat, 0.0)
               + jnp.where(slot == 1, znf, 0.0))
        tele_acc[...] = jnp.maximum(acc, jnp.where(slot == 4, cmax, 0.0))

        @pl.when(li == nl - 1)
        def _tele_flush():
            tele_ref[...] = tele_acc[...]


@functools.partial(jax.jit, static_argnames=(
    "loss", "num_labels", "use_sr", "quantize_x", "drop_rate",
    "compute_loss", "block_l", "interpret", "return_z", "n_b", "n_l",
    "guard"))
def fused_chunk_step(x: jax.Array, w: jax.Array, targets: jax.Array,
                     xg: jax.Array, lr, wd, scale, c0: jax.Array,
                     seed_drop: jax.Array, seed_upd: jax.Array,
                     lse: jax.Array | None = None,
                     z: jax.Array | None = None,
                     comp: jax.Array | None = None, *,
                     loss: str, num_labels: int, use_sr: bool = True,
                     quantize_x: bool = True, drop_rate: float = 0.0,
                     compute_loss: bool = True, block_l: int | None = None,
                     interpret: bool | None = None,
                     return_z: bool = False, n_b: int | None = None,
                     n_l: int | None = None,
                     guard: bool = False) -> ChunkOut:
    """One fused chunk step.

    x (B, D) bf16 · w (L, D) e4m3/bf16/f32 · targets (B, P) int32 (bce) or
    (B,) int32 (softmax_ce) · xg (B, D) bf16 running x̄ · c0 int32 global
    label offset of this chunk · lse (B,) f32 (softmax_ce only) · z (B, L)
    bf16 cached chunk logits (optional) · comp (L, D) bf16 Kahan buffer
    (optional — selects the compensated update, no SR).

    ``interpret=None`` resolves from the backend (interpret everywhere but
    TPU) so a direct call on real hardware always compiles.  ``n_b``/``n_l``
    declare the *logical* batch / label-row counts when the caller hands in
    operands it already padded to tile alignment (the step level pads once
    per step instead of once per chunk); masking then targets the logical
    extent while outputs keep the padded operand shapes.
    """
    (B, D), L = x.shape, w.shape[0]
    n_b = B if n_b is None else n_b
    n_l = L if n_l is None else n_l
    interpret = tuning.interpret_default(interpret)
    kahan = comp is not None
    cached_z = z is not None
    assert not (cached_z and return_z), "z already in hand"
    if loss == "softmax_ce":
        assert lse is not None, "softmax_ce needs the streaming LSE"
        targets = targets.reshape(B, 1)

    wb = jnp.dtype(w.dtype).itemsize
    if block_l is None:
        block_l = tuning.chunk_block_l(B, L, D, wb, kahan=kahan,
                                       cached_z=cached_z)
    if interpret:
        # exact shapes: alignment padding changes the K length of the f32
        # dots, and the CPU backend's SIMD reduction reassociates under a
        # different K — which would break bitwise parity with the oracle
        Bp, Dp = B, D
        bl = min(block_l, L)
    else:
        Bp = tuning._pad_up(B, 16)
        Dp = tuning._pad_up(D, tuning.LANE)
        bl = min(block_l, tuning._pad_up(L, tuning.LANE))
    Lp = tuning._pad_up(L, bl)

    xp = tuning.pad2(x, Bp, Dp)
    wp = tuning.pad2(w, bl, Dp)
    xgp = tuning.pad2(xg, Bp, Dp)
    tp = tuning.pad2(targets, Bp, 1, value=-1)
    hyper = jnp.stack([jnp.asarray(lr, jnp.float32),
                       jnp.asarray(wd, jnp.float32),
                       jnp.asarray(scale, jnp.float32)])
    seeds = jnp.stack([seed_drop.reshape(()).astype(jnp.uint32),
                       seed_upd.reshape(()).astype(jnp.uint32)])
    c0a = c0.reshape(1).astype(jnp.int32)

    grid = (Lp // bl,)
    operands = [seeds, hyper, c0a, tp]
    in_specs = [pl.BlockSpec(memory_space=pltpu.SMEM),
                pl.BlockSpec(memory_space=pltpu.SMEM),
                pl.BlockSpec(memory_space=pltpu.SMEM),
                pl.BlockSpec(tp.shape, lambda l: (0, 0))]
    if loss == "softmax_ce":
        operands.append(tuning.pad2(lse.reshape(B, 1).astype(jnp.float32), Bp, 1))
        in_specs.append(pl.BlockSpec((Bp, 1), lambda l: (0, 0)))
    if cached_z:
        operands.append(tuning.pad2(z.astype(jnp.bfloat16), Bp, bl))
        in_specs.append(pl.BlockSpec((Bp, bl), lambda l: (0, l)))
    idx_x = len(operands)
    operands += [xp, wp, xgp]
    in_specs += [pl.BlockSpec((Bp, Dp), lambda l: (0, 0)),
                 pl.BlockSpec((bl, Dp), lambda l: (l, 0)),
                 pl.BlockSpec((Bp, Dp), lambda l: (0, 0))]
    if kahan:
        operands.append(tuning.pad2(comp, bl, Dp))
        in_specs.append(pl.BlockSpec((bl, Dp), lambda l: (l, 0)))

    out_shape = [jax.ShapeDtypeStruct((Lp, Dp), w.dtype),
                 jax.ShapeDtypeStruct((Bp, Dp), jnp.bfloat16),
                 jax.ShapeDtypeStruct((1, 1), jnp.float32)]
    out_specs = [pl.BlockSpec((bl, Dp), lambda l: (l, 0)),
                 pl.BlockSpec((Bp, Dp), lambda l: (0, 0)),
                 pl.BlockSpec((1, 1), lambda l: (0, 0))]
    if kahan:
        out_shape.append(jax.ShapeDtypeStruct((Lp, Dp), comp.dtype))
        out_specs.append(pl.BlockSpec((bl, Dp), lambda l: (l, 0)))
    if return_z:
        out_shape.append(jax.ShapeDtypeStruct((Bp, Lp), jnp.bfloat16))
        out_specs.append(pl.BlockSpec((Bp, bl), lambda l: (0, l)))
    if guard:
        out_shape.append(jax.ShapeDtypeStruct((1, 8), jnp.float32))
        out_specs.append(pl.BlockSpec((1, 8), lambda l: (0, 0)))

    aliases = {idx_x + 1: 0, idx_x + 2: 1}     # W → w_new, x̄ → x̄'
    if kahan:
        aliases[idx_x + 3] = 3                 # comp → comp'

    scratch = [pltpu.VMEM((Bp, Dp), jnp.float32),
               pltpu.VMEM((1, 1), jnp.float32)]
    if guard:
        scratch.append(pltpu.VMEM((1, 8), jnp.float32))

    outs = pl.pallas_call(
        functools.partial(
            _chunk_kernel, loss=loss, num_labels=num_labels, n_b=n_b,
            n_l=n_l,
            use_sr=use_sr, quantize_x=quantize_x, drop_rate=drop_rate,
            compute_loss=compute_loss, cached_z=cached_z, kahan=kahan,
            return_z=return_z, guard=guard),
        grid=grid,
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shape),
        scratch_shapes=scratch,
        input_output_aliases=aliases,
        interpret=interpret,
    )(*operands)

    w_new, xg_new, loss_c = outs[0], outs[1], outs[2]
    nxt = 3
    comp_new = None
    if kahan:
        comp_new = outs[nxt][:L, :D]
        nxt += 1
    z_out = None
    if return_z:
        z_out = outs[nxt][:B, :L]
        nxt += 1
    tele = outs[nxt][0] if guard else None
    return ChunkOut(w_new[:L, :D], xg_new[:B, :D], loss_c[0, 0],
                    comp_new, z_out, tele)
