"""Jaxpr inspection helpers: count kernel launches without running code.

The grid-resident head (DESIGN.md §7) exists to collapse the per-chunk
launch loop into one ``pallas_call``; this module makes that property
*testable* by statically counting the runtime Pallas launches a function
would perform, via recursive jaxpr traversal:

* a ``pallas_call`` equation counts once (its kernel-body jaxpr cannot
  launch again);
* a ``scan`` multiplies its body's count by the trip count — which is
  exactly how the legacy per-chunk path turns one lowered kernel into
  ``num_chunks`` runtime launches;
* ``while`` bodies have data-dependent trip counts and are counted once
  (a lower bound — none of the head paths loop kernels that way);
* every other sub-jaxpr (pjit, cond branches, custom_vjp calls, shard_map
  bodies, …) recurses with multiplicity 1; ``cond`` therefore counts the
  *sum* of its branches, an upper bound on any single execution.
"""
from __future__ import annotations

import jax


def _sub_jaxprs(params: dict):
    """Yield every (Closed)Jaxpr reachable from an equation's params."""
    for v in params.values():
        items = v if isinstance(v, (tuple, list)) else (v,)
        for u in items:
            if hasattr(u, "eqns"):                    # raw Jaxpr
                yield u
            elif hasattr(u, "jaxpr") and hasattr(u.jaxpr, "eqns"):
                yield u.jaxpr                          # ClosedJaxpr


def count_in_jaxpr(jaxpr) -> int:
    """Runtime Pallas launches performed by one (raw) jaxpr."""
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            total += 1
            continue
        inner = sum(count_in_jaxpr(j) for j in _sub_jaxprs(eqn.params))
        if not inner:
            continue
        mult = 1
        if eqn.primitive.name == "scan":
            mult = int(eqn.params["length"])
        total += mult * inner
    return total


def count_pallas_launches(fn, *args, **kwargs) -> int:
    """Number of Pallas launches one call of ``fn(*args, **kwargs)`` runs.

    Traces ``fn`` with ``jax.make_jaxpr`` (abstract — nothing executes)
    and counts as above.  This is what the launch-count acceptance tests
    assert: 1 launch/step for the grid BCE head, ≤ 2 for softmax-CE, vs
    O(num_chunks) on the legacy per-chunk scan.
    """
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return count_in_jaxpr(closed.jaxpr)
