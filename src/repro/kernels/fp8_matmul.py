"""Pallas TPU kernels: FP8-storage matmuls for the ELMO head (paper §4.3).

Two access patterns over the same FP8 E4M3 weight chunk W (L, D):

* ``fp8_logits``      Z = q8(X) @ Wᵀ   → BF16 logits      (head forward)
* ``fp8_input_grad``  X̄ = G @ W        → BF16 input grads (head backward)

TPU adaptation (DESIGN.md §2): the MXU has no FP8 mode, so FP8 is a *storage*
format — tiles are loaded from HBM at 1 byte/elem (halving weight traffic, the
paper's memory win) and upcast to BF16 in VREGs before hitting the MXU with
fp32 accumulation.  Inputs X are quantized to E4M3 (round-to-nearest, no
tensor scaling — paper Fig. 5b shows the native range suffices) before the
product so the forward numerics match the paper's FP8×FP8 GEMM.

``fp8_logits`` optionally applies DropConnect *inside* the kernel (paper
App. H): a hash-PRNG mask is applied to the W tile in VMEM, so no HBM-side
weight copy is ever made.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import prng_utils as PR
from repro.kernels import tuning


def _logits_kernel(seed_ref, x_ref, w_ref, o_ref, acc_ref, *,
                   drop_rate: float, quantize_x: bool):
    """Z[b, l] += q8(X)[b, k] · W[l, k] for one (b, l, k) grid step."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    if quantize_x:
        # paper §4.3: cast BF16 inputs to E4M3 when computing logits
        x = x.astype(jnp.float8_e4m3fn)
    x = x.astype(jnp.bfloat16)
    w = w_ref[...].astype(jnp.bfloat16)

    if drop_rate > 0.0:
        li, ki = pl.program_id(1), pl.program_id(2)
        rows, cols = w_ref.shape
        bits = PR.hash_bits_2d(seed_ref[0], (li * rows).astype(jnp.uint32),
                               (ki * cols).astype(jnp.uint32), (rows, cols))
        keep = PR.uniform_from_bits(bits) >= drop_rate
        w = jnp.where(keep, w, jnp.bfloat16(0.0)) / jnp.bfloat16(1.0 - drop_rate)

    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _input_grad_kernel(g_ref, w_ref, o_ref, acc_ref):
    """X̄[b, d] += G[b, l] · W[l, d] — BF16 × FP8-storage matmul."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[...].astype(jnp.bfloat16)
    w = w_ref[...].astype(jnp.bfloat16)
    acc_ref[...] += jnp.dot(g, w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("drop_rate", "quantize_x",
                                             "blocks", "interpret"))
def fp8_logits(x: jax.Array, w: jax.Array, seed: jax.Array | None = None, *,
               drop_rate: float = 0.0, quantize_x: bool = True,
               blocks: tuple[int, int, int] | None = None,
               interpret: bool | None = None) -> jax.Array:
    """Z = q8(X) @ Wᵀ.  x: (B, D) bf16, w: (L, D) e4m3/bf16 → (B, L) bf16.

    ``blocks=None`` → roofline-tuned tiles (kernels/tuning.py)."""
    interpret = tuning.interpret_default(interpret)
    (B, D), (L, _) = x.shape, w.shape
    if blocks is None:
        blocks = tuning.logits_blocks(B, L, D, jnp.dtype(w.dtype).itemsize)
    bb, bl, bd = blocks
    bb, bl, bd = min(bb, B) or 8, min(bl, L) or 8, min(bd, D) or 8
    xp, wp = tuning.pad2(x, bb, bd), tuning.pad2(w, bl, bd)
    Bp, Dp = xp.shape
    Lp = wp.shape[0]
    if seed is None:
        seed = jnp.zeros((), jnp.uint32)
    out = pl.pallas_call(
        functools.partial(_logits_kernel, drop_rate=drop_rate,
                          quantize_x=quantize_x),
        grid=(Bp // bb, Lp // bl, Dp // bd),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bb, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bl, bd), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bb, bl), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Lp), jnp.bfloat16),
        scratch_shapes=[pltpu.VMEM((bb, bl), jnp.float32)],
        interpret=interpret,
    )(seed.reshape(1).astype(jnp.uint32), xp, wp)
    return out[:B, :L]


@functools.partial(jax.jit, static_argnames=("blocks", "interpret"))
def fp8_input_grad(g: jax.Array, w: jax.Array, *,
                   blocks: tuple[int, int, int] | None = None,
                   interpret: bool | None = None) -> jax.Array:
    """X̄ = G @ W.  g: (B, L) bf16, w: (L, D) e4m3/bf16 → (B, D) bf16.

    ``blocks=None`` → roofline-tuned tiles (kernels/tuning.py)."""
    interpret = tuning.interpret_default(interpret)
    (B, L), (_, D) = g.shape, w.shape
    if blocks is None:
        blocks = tuning.input_grad_blocks(B, L, D,
                                          jnp.dtype(w.dtype).itemsize)
    bb, bd, bl = blocks
    bb, bd, bl = min(bb, B) or 8, min(bd, D) or 8, min(bl, L) or 8
    gp, wp = tuning.pad2(g, bb, bl), tuning.pad2(w, bl, bd)
    Bp, Lp = gp.shape
    Dp = wp.shape[1]
    out = pl.pallas_call(
        _input_grad_kernel,
        grid=(Bp // bb, Dp // bd, Lp // bl),
        in_specs=[
            pl.BlockSpec((bb, bl), lambda i, j, k: (i, k)),
            pl.BlockSpec((bl, bd), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bb, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Dp), jnp.bfloat16),
        scratch_shapes=[pltpu.VMEM((bb, bd), jnp.float32)],
        interpret=interpret,
    )(gp, wp)
    return out[:B, :D]
