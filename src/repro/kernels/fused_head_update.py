"""Pallas TPU kernel: fused classifier-gradient + SGD update (paper §4.3).

The flagship ELMO kernel.  For one label-chunk:

    dW = Gᵀ X                 (logit-grad × input, accumulated on the MXU)
    W ← SR( (1 − lr·wd)·W − lr·dW )        [stochastic-rounding variant]
    (W, C) ← KahanAdd(W, C, −lr·dW − lr·wd·W)  [head-label hybrid, App. D]

The gradient tile lives only in VMEM scratch — classifier gradients are never
materialized in HBM (the paper's "reducing its memory footprint to nearly
zero").  ``input_output_aliases`` makes the W (and C) update truly in-place.

Grid is (L/bl, D/bd, B/bk) with the batch reduction innermost so the dW
accumulator stays resident; the W tile is read and written exactly once per
(l, d) tile, at the final reduction step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import precision as P
from repro.kernels import prng_utils as PR
from repro.kernels import tuning


def _apply_sr(w_new32, out_dtype, bits, use_sr: bool):
    if not use_sr:
        return w_new32.astype(out_dtype)
    return P.sr_bits(w_new32, bits, out_dtype)


def _update_kernel_sr(seed_ref, hyper_ref, g_ref, x_ref, w_ref, w_out_ref,
                      acc_ref, *, use_sr: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # dW_tile += G_tileᵀ @ X_tile   (contract over the batch block)
    acc_ref[...] += jax.lax.dot_general(
        g_ref[...].astype(jnp.bfloat16), x_ref[...].astype(jnp.bfloat16),
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # program_id must be read at the top level (not inside pl.when bodies)
    li, di = pl.program_id(0), pl.program_id(1)
    rows, cols = w_ref.shape
    row0 = (li * rows).astype(jnp.uint32)
    col0 = (di * cols).astype(jnp.uint32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _update():
        lr, wd = hyper_ref[0], hyper_ref[1]
        w32 = w_ref[...].astype(jnp.float32)
        w_new = w32 * (1.0 - lr * wd) - lr * acc_ref[...]
        bits = PR.hash_bits_2d(seed_ref[0], row0, col0, (rows, cols))
        w_out_ref[...] = _apply_sr(w_new, w_out_ref.dtype, bits, use_sr)


def _update_kernel_kahan(seed_ref, hyper_ref, g_ref, x_ref, w_ref, c_ref,
                         w_out_ref, c_out_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        g_ref[...].astype(jnp.bfloat16), x_ref[...].astype(jnp.bfloat16),
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _update():
        lr, wd = hyper_ref[0], hyper_ref[1]
        w32 = w_ref[...].astype(jnp.float32)
        upd = -lr * acc_ref[...] - (lr * wd) * w32
        # Kahan compensated add (paper §3), all in VMEM
        y = upd - c_ref[...].astype(jnp.float32)
        t32 = w32 + y
        w_new = t32.astype(w_out_ref.dtype)
        c_new = (w_new.astype(jnp.float32) - w32) - y
        w_out_ref[...] = w_new
        c_out_ref[...] = c_new.astype(c_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("use_sr", "blocks", "interpret"))
def fused_head_update(g: jax.Array, x: jax.Array, w: jax.Array,
                      lr: jax.Array, wd: jax.Array, seed: jax.Array, *,
                      use_sr: bool = True,
                      blocks: tuple[int, int, int] | None = None,
                      interpret: bool | None = None) -> jax.Array:
    """W ← SR((1−lr·wd)·W − lr·GᵀX).  g:(B,L) x:(B,D) w:(L,D) → (L,D).

    ``blocks=None`` → roofline-tuned tiles (kernels/tuning.py)."""
    interpret = tuning.interpret_default(interpret)
    (B, L), (_, D) = g.shape, x.shape
    if blocks is None:
        blocks = tuning.update_blocks(B, L, D, jnp.dtype(w.dtype).itemsize)
    bl, bd, bb = blocks
    bl, bd, bb = min(bl, L) or 8, min(bd, D) or 8, min(bb, B) or 8
    gp, xp = tuning.pad2(g, bb, bl), tuning.pad2(x, bb, bd)
    wp = tuning.pad2(w, bl, bd)
    Bp, Lp = gp.shape
    Dp = xp.shape[1]
    hyper = jnp.stack([jnp.asarray(lr, jnp.float32),
                       jnp.asarray(wd, jnp.float32)])
    out = pl.pallas_call(
        functools.partial(_update_kernel_sr, use_sr=use_sr),
        grid=(Lp // bl, Dp // bd, Bp // bb),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),          # seed
            pl.BlockSpec(memory_space=pltpu.SMEM),          # (lr, wd)
            pl.BlockSpec((bb, bl), lambda i, j, k: (k, i)),  # G
            pl.BlockSpec((bb, bd), lambda i, j, k: (k, j)),  # X
            pl.BlockSpec((bl, bd), lambda i, j, k: (i, j)),  # W
        ],
        out_specs=pl.BlockSpec((bl, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Lp, Dp), w.dtype),
        scratch_shapes=[pltpu.VMEM((bl, bd), jnp.float32)],
        input_output_aliases={4: 0},
        interpret=interpret,
    )(seed.reshape(1).astype(jnp.uint32), hyper, gp, xp, wp)
    return out[:L, :D]


@functools.partial(jax.jit, static_argnames=("blocks", "interpret"))
def fused_head_update_kahan(g: jax.Array, x: jax.Array, w: jax.Array,
                            comp: jax.Array, lr: jax.Array, wd: jax.Array,
                            seed: jax.Array, *,
                            blocks: tuple[int, int, int] | None = None,
                            interpret: bool | None = None
                            ) -> tuple[jax.Array, jax.Array]:
    """Head-label hybrid (paper App. D): Kahan-compensated fused update."""
    (B, L), (_, D) = g.shape, x.shape
    if blocks is None:
        blocks = tuning.update_blocks(B, L, D, jnp.dtype(w.dtype).itemsize)
    bl, bd, bb = blocks
    bl, bd, bb = min(bl, L) or 8, min(bd, D) or 8, min(bb, B) or 8
    gp, xp = tuning.pad2(g, bb, bl), tuning.pad2(x, bb, bd)
    interpret = tuning.interpret_default(interpret)
    wp, cp = tuning.pad2(w, bl, bd), tuning.pad2(comp, bl, bd)
    Bp, Lp = gp.shape
    Dp = xp.shape[1]
    hyper = jnp.stack([jnp.asarray(lr, jnp.float32),
                       jnp.asarray(wd, jnp.float32)])
    w_new, c_new = pl.pallas_call(
        _update_kernel_kahan,
        grid=(Lp // bl, Dp // bd, Bp // bb),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bb, bl), lambda i, j, k: (k, i)),
            pl.BlockSpec((bb, bd), lambda i, j, k: (k, j)),
            pl.BlockSpec((bl, bd), lambda i, j, k: (i, j)),
            pl.BlockSpec((bl, bd), lambda i, j, k: (i, j)),
        ],
        out_specs=(pl.BlockSpec((bl, bd), lambda i, j, k: (i, j)),
                   pl.BlockSpec((bl, bd), lambda i, j, k: (i, j))),
        out_shape=(jax.ShapeDtypeStruct((Lp, Dp), w.dtype),
                   jax.ShapeDtypeStruct((Lp, Dp), comp.dtype)),
        scratch_shapes=[pltpu.VMEM((bl, bd), jnp.float32)],
        input_output_aliases={4: 0, 5: 1},
        interpret=interpret,
    )(seed.reshape(1).astype(jnp.uint32), hyper, gp, xp, wp, cp)
    return w_new[:L, :D], c_new[:L, :D]
