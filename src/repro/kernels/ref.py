"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` reproduces the kernel's exact semantics (including the counter
hash PRNG, so SR results match bit-for-bit in interpret mode).  These oracles
are also the production XLA fallback used by the distributed train step on
non-TPU backends and in the multi-pod dry-run (see DESIGN.md §4): they express
the same chunked algorithm, letting XLA fuse it, while the Pallas kernels are
the TPU fast path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import precision as P
from repro.kernels import prng_utils as PR


def _hash_full(seed: jax.Array, shape: tuple[int, int]) -> jax.Array:
    """Bits for the whole array — matches kernel tiling because the hash is a
    function of the *global* element index only."""
    zero = jnp.zeros((), jnp.uint32)
    return PR.hash_bits_2d(seed.reshape(()).astype(jnp.uint32), zero, zero,
                           shape)


def sr_cast_2d_ref(x: jax.Array, seed: jax.Array, *, out_dtype) -> jax.Array:
    bits = _hash_full(seed, x.shape)
    return P.sr_bits(x.astype(jnp.float32), bits, out_dtype)


def fp8_logits_ref(x: jax.Array, w: jax.Array, seed: jax.Array | None = None,
                   *, drop_rate: float = 0.0, quantize_x: bool = True
                   ) -> jax.Array:
    """Z = q8(X) @ Wᵀ with optional DropConnect on W (same hash mask)."""
    if quantize_x:
        x = x.astype(jnp.float8_e4m3fn)
    x = x.astype(jnp.bfloat16)
    w32 = w.astype(jnp.bfloat16)
    if drop_rate > 0.0:
        assert seed is not None
        bits = _hash_full(seed, w.shape)
        keep = PR.uniform_from_bits(bits) >= drop_rate
        w32 = jnp.where(keep, w32, 0).astype(jnp.bfloat16) / jnp.bfloat16(1.0 - drop_rate)
    z = jax.lax.dot_general(x, w32, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return z.astype(jnp.bfloat16)


def fp8_input_grad_ref(g: jax.Array, w: jax.Array) -> jax.Array:
    xg = jnp.dot(g.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                 preferred_element_type=jnp.float32)
    return xg.astype(jnp.bfloat16)


def fused_head_update_ref(g: jax.Array, x: jax.Array, w: jax.Array,
                          lr, wd, seed: jax.Array, *, use_sr: bool = True
                          ) -> jax.Array:
    dw = jax.lax.dot_general(g.astype(jnp.bfloat16), x.astype(jnp.bfloat16),
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    w32 = w.astype(jnp.float32)
    w_new = w32 * (1.0 - jnp.float32(lr) * jnp.float32(wd)) - jnp.float32(lr) * dw
    if not use_sr:
        return w_new.astype(w.dtype)
    bits = _hash_full(seed, w.shape)
    return P.sr_bits(w_new, bits, w.dtype)


def fused_head_update_kahan_ref(g: jax.Array, x: jax.Array, w: jax.Array,
                                comp: jax.Array, lr, wd, seed: jax.Array
                                ) -> tuple[jax.Array, jax.Array]:
    dw = jax.lax.dot_general(g.astype(jnp.bfloat16), x.astype(jnp.bfloat16),
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    w32 = w.astype(jnp.float32)
    upd = -jnp.float32(lr) * dw - (jnp.float32(lr) * jnp.float32(wd)) * w32
    return P.kahan_update(w, comp, upd)


def fused_chunk_ref(x: jax.Array, w: jax.Array, targets: jax.Array,
                    xg: jax.Array, lr, wd, scale, c0: jax.Array,
                    seed_drop: jax.Array, seed_upd: jax.Array,
                    lse: jax.Array | None = None,
                    z: jax.Array | None = None,
                    comp: jax.Array | None = None, *,
                    loss: str, num_labels: int, use_sr: bool = True,
                    quantize_x: bool = True, drop_rate: float = 0.0,
                    compute_loss: bool = True, return_z: bool = False):
    """Oracle for the fused chunk megakernel — the exact composition of the
    legacy multi-kernel chunk step (logits → loss-skip grad → input grad →
    fused update), so fused and unfused paths agree bit-for-bit."""
    from repro.core import losses as L  # local import: core imports kernels
    from repro.kernels.fused_chunk import ChunkOut

    Lc = w.shape[0]
    if z is None:
        z = fp8_logits_ref(x, w, seed_drop, drop_rate=drop_rate,
                           quantize_x=quantize_x)
    g, loss_c = L.chunk_loss_skip_grad(loss, z, targets, c0, Lc, num_labels,
                                       lse, scale, compute_loss)
    xg_new = xg + fp8_input_grad_ref(g, w)
    if comp is None:
        w_new = fused_head_update_ref(g, x, w, lr, wd, seed_upd,
                                      use_sr=use_sr)
        comp_new = None
    else:
        w_new, comp_new = fused_head_update_kahan_ref(g, x, w, comp, lr, wd,
                                                      seed_upd)
    return ChunkOut(w_new, xg_new, jnp.float32(loss_c), comp_new,
                    z if return_z else None)


def flash_attention_fwd_ref(q, k, v, causal: bool = True, window=None):
    """Dense softmax-attention oracle for the Pallas flash kernel.
    q: (B, H, Sq, dh); k, v: (B, KH, Sk, dh) — O(S²), tests/tiny only."""
    import numpy as _np
    B, H, Sq, dh = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    G = H // KH
    kk = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vv = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk)
    s = s / _np.sqrt(dh)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (kp <= qp)
    if window is not None:
        mask = mask & (qp - kp < window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv).astype(q.dtype)
