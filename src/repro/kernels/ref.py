"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` reproduces the kernel's exact semantics (including the counter
hash PRNG, so SR results match bit-for-bit in interpret mode).  These oracles
are also the production XLA fallback used by the distributed train step on
non-TPU backends and in the multi-pod dry-run (see DESIGN.md §4): they express
the same chunked algorithm, letting XLA fuse it, while the Pallas kernels are
the TPU fast path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import precision as P
from repro.kernels import prng_utils as PR
from repro.numerics import telemetry as NT


def _hash_full(seed: jax.Array, shape: tuple[int, int]) -> jax.Array:
    """Bits for the whole array — matches kernel tiling because the hash is a
    function of the *global* element index only."""
    zero = jnp.zeros((), jnp.uint32)
    return PR.hash_bits_2d(seed.reshape(()).astype(jnp.uint32), zero, zero,
                           shape)


def sr_cast_2d_ref(x: jax.Array, seed: jax.Array, *, out_dtype) -> jax.Array:
    bits = _hash_full(seed, x.shape)
    return P.sr_bits(x.astype(jnp.float32), bits, out_dtype)


def fp8_logits_ref(x: jax.Array, w: jax.Array, seed: jax.Array | None = None,
                   *, drop_rate: float = 0.0, quantize_x: bool = True
                   ) -> jax.Array:
    """Z = q8(X) @ Wᵀ with optional DropConnect on W (same hash mask)."""
    if quantize_x:
        x = x.astype(jnp.float8_e4m3fn)
    x = x.astype(jnp.bfloat16)
    w32 = w.astype(jnp.bfloat16)
    if drop_rate > 0.0:
        assert seed is not None
        bits = _hash_full(seed, w.shape)
        keep = PR.uniform_from_bits(bits) >= drop_rate
        w32 = jnp.where(keep, w32, 0).astype(jnp.bfloat16) / jnp.bfloat16(1.0 - drop_rate)
    z = jax.lax.dot_general(x, w32, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return z.astype(jnp.bfloat16)


def fp8_input_grad_ref(g: jax.Array, w: jax.Array) -> jax.Array:
    xg = jnp.dot(g.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                 preferred_element_type=jnp.float32)
    return xg.astype(jnp.bfloat16)


def fused_head_update_ref(g: jax.Array, x: jax.Array, w: jax.Array,
                          lr, wd, seed: jax.Array, *, use_sr: bool = True
                          ) -> jax.Array:
    dw = jax.lax.dot_general(g.astype(jnp.bfloat16), x.astype(jnp.bfloat16),
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    w32 = w.astype(jnp.float32)
    w_new = w32 * (1.0 - jnp.float32(lr) * jnp.float32(wd)) - jnp.float32(lr) * dw
    if not use_sr:
        return w_new.astype(w.dtype)
    bits = _hash_full(seed, w.shape)
    return P.sr_bits(w_new, bits, w.dtype)


def fused_head_update_kahan_ref(g: jax.Array, x: jax.Array, w: jax.Array,
                                comp: jax.Array, lr, wd, seed: jax.Array
                                ) -> tuple[jax.Array, jax.Array]:
    dw = jax.lax.dot_general(g.astype(jnp.bfloat16), x.astype(jnp.bfloat16),
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    w32 = w.astype(jnp.float32)
    upd = -jnp.float32(lr) * dw - (jnp.float32(lr) * jnp.float32(wd)) * w32
    return P.kahan_update(w, comp, upd)


def fused_chunk_ref(x: jax.Array, w: jax.Array, targets: jax.Array,
                    xg: jax.Array, lr, wd, scale, c0: jax.Array,
                    seed_drop: jax.Array, seed_upd: jax.Array,
                    lse: jax.Array | None = None,
                    z: jax.Array | None = None,
                    comp: jax.Array | None = None, *,
                    loss: str, num_labels: int, use_sr: bool = True,
                    quantize_x: bool = True, drop_rate: float = 0.0,
                    compute_loss: bool = True, return_z: bool = False,
                    guard: bool = False):
    """Oracle for the fused chunk megakernel — the exact composition of the
    legacy multi-kernel chunk step (logits → loss-skip grad → input grad →
    fused update), so fused and unfused paths agree bit-for-bit."""
    from repro.core import losses as L  # local import: core imports kernels
    from repro.kernels.fused_chunk import ChunkOut

    Lc = w.shape[0]
    if z is None:
        z = fp8_logits_ref(x, w, seed_drop, drop_rate=drop_rate,
                           quantize_x=quantize_x)
    g, loss_c = L.chunk_loss_skip_grad(loss, z, targets, c0, Lc, num_labels,
                                       lse, scale, compute_loss)
    xg_new = xg + fp8_input_grad_ref(g, w)
    tele = None
    if not guard:
        if comp is None:
            w_new = fused_head_update_ref(g, x, w, lr, wd, seed_upd,
                                          use_sr=use_sr)
            comp_new = None
        else:
            w_new, comp_new = fused_head_update_kahan_ref(g, x, w, comp,
                                                          lr, wd, seed_upd)
    else:
        # inline the update so the pre-cast f32 value feeds BOTH the
        # storage cast and the telemetry from ONE dot — replaying the dot
        # as a separate expression defeated XLA CSE (a 4th gemm, ~12%
        # step-time).  The arithmetic below is term-for-term identical to
        # fused_head_update_ref / _kahan_ref, so guard-on stays bitwise
        # invisible to W/comp.
        dw = jax.lax.dot_general(g.astype(jnp.bfloat16),
                                 x.astype(jnp.bfloat16),
                                 (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        w32 = w.astype(jnp.float32)
        if comp is None:
            pre = w32 * (1.0 - jnp.float32(lr) * jnp.float32(wd)) \
                - jnp.float32(lr) * dw
            if use_sr:
                w_new = P.sr_bits(pre, _hash_full(seed_upd, w.shape),
                                  w.dtype)
            else:
                w_new = pre.astype(w.dtype)
            comp_new = None
        else:
            upd = -jnp.float32(lr) * dw \
                - (jnp.float32(lr) * jnp.float32(wd)) * w32
            pre = w32 + (upd - comp.astype(jnp.float32))   # kahan's t32
            w_new, comp_new = P.kahan_update(w, comp, upd)
        mask = ((c0 + jnp.arange(Lc)) < num_labels)[None, :]
        tele = NT.chunk(pre, comp_new, z, mask, w.dtype)
    return ChunkOut(w_new, xg_new, jnp.float32(loss_c), comp_new,
                    z if return_z else None, tele)


# ---------------------------------------------------------------------------
# fixed-fan-in sparse head (DESIGN.md §13)
# ---------------------------------------------------------------------------
#
# The sparse layout stores, per label row, ``fan_in`` (value, column-index)
# pairs.  The kernel and this oracle share the two primitives below so their
# bit-parity is structural, not coincidental:
#
# * ``sparse_densify``     — (…, F) values+indices → (…, D) bf16 row blocks
#   via an iterated *select* (never an add: 0.0 + (-0.0) would flip the sign
#   of zero and break the fan_in = D anchor against ``w.astype(bf16)``).
# * ``sparse_gather_cols`` — picks dense[…, idx[…, f]] bit-exactly through
#   an integer-view masked sum (a float masked sum would likewise lose the
#   sign of zero).
#
# Both require the per-row indices to be unique (the sparse-state invariant:
# sorted strictly-increasing per row; -1 marks padded slots and selects
# nothing).


def sparse_densify(values: jax.Array, idx: jax.Array, d: int) -> jax.Array:
    """(…, F) sparse rows → (…, d) dense bf16 rows; unindexed columns are
    exactly +0.0.  Lowers inside Pallas kernel bodies (iota/where only)."""
    v16 = values.astype(jnp.bfloat16)
    out = jnp.zeros(values.shape[:-1] + (d,), jnp.bfloat16)
    iota = jax.lax.broadcasted_iota(jnp.int32, out.shape, out.ndim - 1)
    for f in range(values.shape[-1]):
        out = jnp.where(iota == idx[..., f:f + 1], v16[..., f:f + 1], out)
    return out


def sparse_gather_cols(dense: jax.Array, idx: jax.Array) -> jax.Array:
    """dense (…, d) f32 → (…, F) f32 with out[…, f] = dense[…, idx[…, f]],
    bit-exact (sign of zero included); idx -1 slots gather exactly +0.0."""
    bits = jax.lax.bitcast_convert_type(dense.astype(jnp.float32), jnp.int32)
    iota = jax.lax.broadcasted_iota(jnp.int32, dense.shape, dense.ndim - 1)
    cols = []
    for f in range(idx.shape[-1]):
        m = iota == idx[..., f:f + 1]
        cols.append(jnp.where(m, bits, 0).sum(-1, keepdims=True))
    out = jnp.concatenate(cols, axis=-1)
    return jax.lax.bitcast_convert_type(out, jnp.float32)


def sparse_chunk_ref(x: jax.Array, values: jax.Array, indices: jax.Array,
                     targets: jax.Array, xg: jax.Array, lr, wd, scale,
                     c0: jax.Array, seed_drop: jax.Array,
                     seed_upd: jax.Array, lse: jax.Array | None = None,
                     comp: jax.Array | None = None, *, loss: str,
                     num_labels: int, use_sr: bool = True,
                     quantize_x: bool = True, drop_rate: float = 0.0,
                     compute_loss: bool = True, guard: bool = False):
    """Oracle for one label chunk of the sparse fused train step
    (``kernels/sparse_head.py``): densify the chunk's value/index rows,
    run the *dense* chunk computation op-for-op (same DropConnect draw
    addressed on the densified block, same MXU dot shapes, same loss-skip
    grad), then gather the dense dW back onto the fan_in slots and apply
    the SR/Kahan update with bits drawn at the slots' absolute (row, col)
    coordinates (``PR.hash_bits_at``).  At fan_in = D with identity
    indices every intermediate equals the dense ``fused_chunk_ref``
    bitwise — the parity anchor.  Returns (values', xg', loss_c, comp')."""
    from repro.core import losses as L  # local import: core imports kernels

    Lc = values.shape[0]
    w16 = sparse_densify(values, indices, x.shape[1])
    z = fp8_logits_ref(x, w16, seed_drop, drop_rate=drop_rate,
                       quantize_x=quantize_x)
    g, loss_c = L.chunk_loss_skip_grad(loss, z, targets, c0, Lc, num_labels,
                                       lse, scale, compute_loss)
    xg_new = xg + fp8_input_grad_ref(g, w16)
    dw = jax.lax.dot_general(g.astype(jnp.bfloat16), x.astype(jnp.bfloat16),
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    dv = sparse_gather_cols(dw, indices)
    v32 = values.astype(jnp.float32)
    if comp is None:
        v_new32 = v32 * (1.0 - jnp.float32(lr) * jnp.float32(wd)) \
            - jnp.float32(lr) * dv
        if use_sr:
            bits = PR.hash_bits_at(seed_upd.reshape(()).astype(jnp.uint32),
                                   jnp.zeros((), jnp.uint32), indices)
            values_new = P.sr_bits(v_new32, bits, values.dtype)
        else:
            values_new = v_new32.astype(values.dtype)
        comp_new = None
        pre = v_new32
    else:
        upd = -jnp.float32(lr) * dv \
            - (jnp.float32(lr) * jnp.float32(wd)) * v32
        values_new, comp_new = P.kahan_update(values, comp, upd)
        pre = v32 + (upd - comp.astype(jnp.float32))
    tele = None
    if guard:
        mask = ((c0 + jnp.arange(Lc)) < num_labels)[None, :]
        tele = NT.chunk(pre, comp_new, z, mask, values.dtype)
    return values_new, xg_new, jnp.float32(loss_c), comp_new, tele


def sparse_lse_chunk_ref(x: jax.Array, values: jax.Array,
                         indices: jax.Array, m: jax.Array, s: jax.Array,
                         c0: jax.Array, seed_drop: jax.Array, *,
                         num_labels: int, quantize_x: bool = True,
                         drop_rate: float = 0.0
                         ) -> tuple[jax.Array, jax.Array]:
    """Fold one sparse chunk's logits into the streaming (max, Σexp) CE
    carry — same masking as the kernel's pass 0 (padded / out-of-range
    columns pinned to NEG_INF before the fold)."""
    from repro.core import losses as L  # local import: core imports kernels
    from repro.core.losses import NEG_INF

    Lc = values.shape[0]
    w16 = sparse_densify(values, indices, x.shape[1])
    z = fp8_logits_ref(x, w16, seed_drop, drop_rate=drop_rate,
                       quantize_x=quantize_x)
    valid = ((c0 + jnp.arange(Lc)) < num_labels)[None, :]
    zm = jnp.where(valid, z.astype(jnp.float32), NEG_INF)
    return L.lse_update(m, s, zm)


def sparse_head_step_ref(x: jax.Array, values: jax.Array,
                         indices: jax.Array, targets: jax.Array, lr, wd,
                         scale, seeds_drop: jax.Array, seeds_upd: jax.Array,
                         base: jax.Array, lse: jax.Array | None = None,
                         comp: jax.Array | None = None, *, mode: str,
                         num_labels: int, use_sr: bool = True,
                         quantize_x: bool = True, drop_rate: float = 0.0,
                         compute_loss: bool = True, guard: bool = False):
    """Whole-step oracle for the sparse megakernel: a ``lax.scan`` of
    ``sparse_chunk_ref`` over chunks (with a streaming-LSE pre-scan for
    ``mode="ce_full"``) — the same per-chunk seed addressing, per-chunk
    BF16 x̄ rounding, and loss accumulation order as the kernel with one
    block per chunk.  Also the production non-TPU path (``impl="xla"``)."""
    from repro.core import losses as L  # local import: core imports kernels
    from repro.kernels.sparse_head import SparseStepOut

    B, D = x.shape
    kahan = comp is not None
    loss_name = "bce" if mode == "bce" else "softmax_ce"
    seeds_drop = jnp.asarray(seeds_drop).astype(jnp.uint32)
    seeds_upd = jnp.asarray(seeds_upd).astype(jnp.uint32)
    base = jnp.asarray(base).astype(jnp.int32)

    if mode == "ce_full":
        def lse_body(carry, inp):
            vals_c, idx_c, sd, b0 = inp
            m, s = carry
            return sparse_lse_chunk_ref(
                x, vals_c, idx_c, m, s, b0, sd, num_labels=num_labels,
                quantize_x=quantize_x, drop_rate=drop_rate), None

        (m, s), _ = jax.lax.scan(lse_body, L.lse_init(B),
                                 (values, indices, seeds_drop, base))
        lse = L.lse_finalize(m, s)
    elif mode == "ce_update":
        assert lse is not None, "ce_update needs the finalized LSE"

    def body(carry, inp):
        xg, loss_acc = carry[0], carry[1]
        if kahan:
            vals_c, idx_c, comp_c, sd, su, b0 = inp
        else:
            vals_c, idx_c, sd, su, b0 = inp
            comp_c = None
        v_new, xg_new, loss_c, comp_new, tele_c = sparse_chunk_ref(
            x, vals_c, idx_c, targets, xg, lr, wd, scale, b0, sd, su,
            lse=None if mode == "bce" else lse, comp=comp_c,
            loss=loss_name, num_labels=num_labels, use_sr=use_sr,
            quantize_x=quantize_x, drop_rate=drop_rate,
            compute_loss=compute_loss, guard=guard)
        ys = (v_new, comp_new) if kahan else (v_new,)
        out_carry = (xg_new, loss_acc + loss_c)
        if guard:
            out_carry += (NT.combine(carry[2], tele_c),)
        return out_carry, ys

    xs = (values, indices) + ((comp,) if kahan else ()) \
        + (seeds_drop, seeds_upd, base)
    xg0 = jnp.zeros((B, D), jnp.bfloat16)
    carry0 = (xg0, jnp.float32(0.0)) + ((NT.zero(),) if guard else ())
    carry, ys = jax.lax.scan(body, carry0, xs)
    xg, loss = carry[0], carry[1]
    tele = carry[2] if guard else None
    v_new = ys[0]
    comp_new = ys[1] if kahan else None
    return SparseStepOut(v_new, xg, loss, comp_new,
                         lse if mode == "ce_full" else None, tele)


def topk_carry_init(B: int, k: int) -> tuple[jax.Array, jax.Array]:
    """The streaming top-k initial carry: k (NEG_INF, id 0) sentinels per
    row — what overflow slots surface when k exceeds the candidates."""
    from repro.core.losses import NEG_INF  # local import: core ↔ kernels
    return (jnp.full((B, k), NEG_INF, jnp.float32),
            jnp.zeros((B, k), jnp.int32))


def topk_merge(vals: jax.Array, idx: jax.Array, z: jax.Array,
               cols: jax.Array, k: int, num_labels: int
               ) -> tuple[jax.Array, jax.Array]:
    """Fold one block of logits into a (B, k) running top-k — THE
    streaming tie-break contract, in exactly one place (the serving scan,
    this module's oracle, and — op-for-op in its selection-sort form —
    the Pallas megakernel all reproduce it): columns with global id
    ``cols`` ≥ num_labels are masked to NEG_INF, candidates are
    ``[carry, block]`` with the block in ascending-id order, and
    ``lax.top_k`` is stable — so equal logits resolve to the lowest label
    id, and padded columns lose every NEG_INF tie to the earlier
    sentinels/carry."""
    from repro.core.losses import NEG_INF  # local import: core ↔ kernels

    B, width = z.shape
    zm = jnp.where((cols < num_labels)[None, :], z.astype(jnp.float32),
                   NEG_INF)
    cand = jnp.concatenate([vals, zm], axis=1)
    cand_i = jnp.concatenate(
        [idx, jnp.broadcast_to(cols, (B, width))], axis=1)
    v, sel = jax.lax.top_k(cand, k)
    return v, jnp.take_along_axis(cand_i, sel, axis=1)


def fused_topk_ref(x: jax.Array, w: jax.Array, seeds: jax.Array,
                   base: jax.Array, *, k: int, num_labels: int,
                   quantize_x: bool = True, drop_rate: float = 0.0,
                   assign: jax.Array | None = None,
                   beam: jax.Array | None = None
                   ) -> tuple[jax.Array, jax.Array]:
    """Oracle for the streaming top-k serving megakernel
    (``kernels/fused_topk.py``) — and the non-TPU production path: a
    ``lax.scan`` over chunks carrying a (B, k) running top-k, O(B·(k+lc))
    memory, never materializing the full logits.  The merge body is
    ``topk_merge`` above — shared with ``head.serving._topk_scan``.

    ``base`` (C,) int32 is each chunk's global label id of local row 0
    (``cidx·chunk`` single-device, ``cidx·chunk + rank·lc`` sharded).

    ``assign`` (C, lc) int32 per-row cluster ids + ``beam`` (B, n_beam)
    int32 admitted clusters per query (both or neither) make this the
    RESTRICTED oracle for shortlisted serving (DESIGN §11): non-admitted
    columns are masked to NEG_INF before the merge, so the result is the
    exact top-k over exactly the labels the shortlist admits — sentinel
    slots (NEG_INF, id 0) surface when a row admits fewer than k labels,
    never a non-admitted id.  ``beam`` slots of -1 are inert (real
    cluster ids are ≥ 0; ``assign`` is -1 only on padded label rows,
    which the ``cols < num_labels`` mask in ``topk_merge`` kills
    regardless)."""
    from repro.core.losses import NEG_INF  # local import: core ↔ kernels
    B = x.shape[0]
    lc = w.shape[1]
    shortlisted = assign is not None
    if shortlisted:
        assert beam is not None, "assign without beam"
        beam = jnp.asarray(beam).astype(jnp.int32)

    def body(carry, inp):
        wc, sd, b0 = inp[:3]
        z = fp8_logits_ref(x, wc, sd, drop_rate=drop_rate,
                           quantize_x=quantize_x)
        if shortlisted:
            asg = inp[3]                              # (lc,) cluster ids
            adm = jnp.any(asg[None, :, None] == beam[:, None, :], axis=-1)
            z = jnp.where(adm, z.astype(jnp.float32), NEG_INF)
        cols = b0 + jnp.arange(lc, dtype=jnp.int32)
        return topk_merge(*carry, z, cols, k, num_labels), None

    xs = (w, jnp.asarray(seeds).astype(jnp.uint32),
          jnp.asarray(base).astype(jnp.int32))
    if shortlisted:
        xs = xs + (jnp.asarray(assign).astype(jnp.int32),)
    (vals, idx), _ = jax.lax.scan(body, topk_carry_init(B, k), xs)
    return vals, idx


def flash_attention_fwd_ref(q, k, v, causal: bool = True, window=None):
    """Dense softmax-attention oracle for the Pallas flash kernel.
    q: (B, H, Sq, dh); k, v: (B, KH, Sk, dh) — O(S²), tests/tiny only."""
    import numpy as _np
    B, H, Sq, dh = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    G = H // KH
    kk = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vv = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk)
    s = s / _np.sqrt(dh)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (kp <= qp)
    if window is not None:
        mask = mask & (qp - kp < window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv).astype(q.dtype)
