"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` reproduces the kernel's exact semantics (including the counter
hash PRNG, so SR results match bit-for-bit in interpret mode).  These oracles
are also the production XLA fallback used by the distributed train step on
non-TPU backends and in the multi-pod dry-run (see DESIGN.md §4): they express
the same chunked algorithm, letting XLA fuse it, while the Pallas kernels are
the TPU fast path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import precision as P
from repro.kernels import prng_utils as PR


def _hash_full(seed: jax.Array, shape: tuple[int, int]) -> jax.Array:
    """Bits for the whole array — matches kernel tiling because the hash is a
    function of the *global* element index only."""
    zero = jnp.zeros((), jnp.uint32)
    return PR.hash_bits_2d(seed.reshape(()).astype(jnp.uint32), zero, zero,
                           shape)


def sr_cast_2d_ref(x: jax.Array, seed: jax.Array, *, out_dtype) -> jax.Array:
    bits = _hash_full(seed, x.shape)
    return P.sr_bits(x.astype(jnp.float32), bits, out_dtype)


def fp8_logits_ref(x: jax.Array, w: jax.Array, seed: jax.Array | None = None,
                   *, drop_rate: float = 0.0, quantize_x: bool = True
                   ) -> jax.Array:
    """Z = q8(X) @ Wᵀ with optional DropConnect on W (same hash mask)."""
    if quantize_x:
        x = x.astype(jnp.float8_e4m3fn)
    x = x.astype(jnp.bfloat16)
    w32 = w.astype(jnp.bfloat16)
    if drop_rate > 0.0:
        assert seed is not None
        bits = _hash_full(seed, w.shape)
        keep = PR.uniform_from_bits(bits) >= drop_rate
        w32 = jnp.where(keep, w32, 0).astype(jnp.bfloat16) / jnp.bfloat16(1.0 - drop_rate)
    z = jax.lax.dot_general(x, w32, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return z.astype(jnp.bfloat16)


def fp8_input_grad_ref(g: jax.Array, w: jax.Array) -> jax.Array:
    xg = jnp.dot(g.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                 preferred_element_type=jnp.float32)
    return xg.astype(jnp.bfloat16)


def fused_head_update_ref(g: jax.Array, x: jax.Array, w: jax.Array,
                          lr, wd, seed: jax.Array, *, use_sr: bool = True
                          ) -> jax.Array:
    dw = jax.lax.dot_general(g.astype(jnp.bfloat16), x.astype(jnp.bfloat16),
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    w32 = w.astype(jnp.float32)
    w_new = w32 * (1.0 - jnp.float32(lr) * jnp.float32(wd)) - jnp.float32(lr) * dw
    if not use_sr:
        return w_new.astype(w.dtype)
    bits = _hash_full(seed, w.shape)
    return P.sr_bits(w_new, bits, w.dtype)


def fused_head_update_kahan_ref(g: jax.Array, x: jax.Array, w: jax.Array,
                                comp: jax.Array, lr, wd, seed: jax.Array
                                ) -> tuple[jax.Array, jax.Array]:
    dw = jax.lax.dot_general(g.astype(jnp.bfloat16), x.astype(jnp.bfloat16),
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    w32 = w.astype(jnp.float32)
    upd = -jnp.float32(lr) * dw - (jnp.float32(lr) * jnp.float32(wd)) * w32
    return P.kahan_update(w, comp, upd)


def fused_chunk_ref(x: jax.Array, w: jax.Array, targets: jax.Array,
                    xg: jax.Array, lr, wd, scale, c0: jax.Array,
                    seed_drop: jax.Array, seed_upd: jax.Array,
                    lse: jax.Array | None = None,
                    z: jax.Array | None = None,
                    comp: jax.Array | None = None, *,
                    loss: str, num_labels: int, use_sr: bool = True,
                    quantize_x: bool = True, drop_rate: float = 0.0,
                    compute_loss: bool = True, return_z: bool = False):
    """Oracle for the fused chunk megakernel — the exact composition of the
    legacy multi-kernel chunk step (logits → loss-skip grad → input grad →
    fused update), so fused and unfused paths agree bit-for-bit."""
    from repro.core import losses as L  # local import: core imports kernels
    from repro.kernels.fused_chunk import ChunkOut

    Lc = w.shape[0]
    if z is None:
        z = fp8_logits_ref(x, w, seed_drop, drop_rate=drop_rate,
                           quantize_x=quantize_x)
    g, loss_c = L.chunk_loss_skip_grad(loss, z, targets, c0, Lc, num_labels,
                                       lse, scale, compute_loss)
    xg_new = xg + fp8_input_grad_ref(g, w)
    if comp is None:
        w_new = fused_head_update_ref(g, x, w, lr, wd, seed_upd,
                                      use_sr=use_sr)
        comp_new = None
    else:
        w_new, comp_new = fused_head_update_kahan_ref(g, x, w, comp, lr, wd,
                                                      seed_upd)
    return ChunkOut(w_new, xg_new, jnp.float32(loss_c), comp_new,
                    z if return_z else None)


def topk_carry_init(B: int, k: int) -> tuple[jax.Array, jax.Array]:
    """The streaming top-k initial carry: k (NEG_INF, id 0) sentinels per
    row — what overflow slots surface when k exceeds the candidates."""
    from repro.core.losses import NEG_INF  # local import: core ↔ kernels
    return (jnp.full((B, k), NEG_INF, jnp.float32),
            jnp.zeros((B, k), jnp.int32))


def topk_merge(vals: jax.Array, idx: jax.Array, z: jax.Array,
               cols: jax.Array, k: int, num_labels: int
               ) -> tuple[jax.Array, jax.Array]:
    """Fold one block of logits into a (B, k) running top-k — THE
    streaming tie-break contract, in exactly one place (the serving scan,
    this module's oracle, and — op-for-op in its selection-sort form —
    the Pallas megakernel all reproduce it): columns with global id
    ``cols`` ≥ num_labels are masked to NEG_INF, candidates are
    ``[carry, block]`` with the block in ascending-id order, and
    ``lax.top_k`` is stable — so equal logits resolve to the lowest label
    id, and padded columns lose every NEG_INF tie to the earlier
    sentinels/carry."""
    from repro.core.losses import NEG_INF  # local import: core ↔ kernels

    B, width = z.shape
    zm = jnp.where((cols < num_labels)[None, :], z.astype(jnp.float32),
                   NEG_INF)
    cand = jnp.concatenate([vals, zm], axis=1)
    cand_i = jnp.concatenate(
        [idx, jnp.broadcast_to(cols, (B, width))], axis=1)
    v, sel = jax.lax.top_k(cand, k)
    return v, jnp.take_along_axis(cand_i, sel, axis=1)


def fused_topk_ref(x: jax.Array, w: jax.Array, seeds: jax.Array,
                   base: jax.Array, *, k: int, num_labels: int,
                   quantize_x: bool = True, drop_rate: float = 0.0,
                   assign: jax.Array | None = None,
                   beam: jax.Array | None = None
                   ) -> tuple[jax.Array, jax.Array]:
    """Oracle for the streaming top-k serving megakernel
    (``kernels/fused_topk.py``) — and the non-TPU production path: a
    ``lax.scan`` over chunks carrying a (B, k) running top-k, O(B·(k+lc))
    memory, never materializing the full logits.  The merge body is
    ``topk_merge`` above — shared with ``head.serving._topk_scan``.

    ``base`` (C,) int32 is each chunk's global label id of local row 0
    (``cidx·chunk`` single-device, ``cidx·chunk + rank·lc`` sharded).

    ``assign`` (C, lc) int32 per-row cluster ids + ``beam`` (B, n_beam)
    int32 admitted clusters per query (both or neither) make this the
    RESTRICTED oracle for shortlisted serving (DESIGN §11): non-admitted
    columns are masked to NEG_INF before the merge, so the result is the
    exact top-k over exactly the labels the shortlist admits — sentinel
    slots (NEG_INF, id 0) surface when a row admits fewer than k labels,
    never a non-admitted id.  ``beam`` slots of -1 are inert (real
    cluster ids are ≥ 0; ``assign`` is -1 only on padded label rows,
    which the ``cols < num_labels`` mask in ``topk_merge`` kills
    regardless)."""
    from repro.core.losses import NEG_INF  # local import: core ↔ kernels
    B = x.shape[0]
    lc = w.shape[1]
    shortlisted = assign is not None
    if shortlisted:
        assert beam is not None, "assign without beam"
        beam = jnp.asarray(beam).astype(jnp.int32)

    def body(carry, inp):
        wc, sd, b0 = inp[:3]
        z = fp8_logits_ref(x, wc, sd, drop_rate=drop_rate,
                           quantize_x=quantize_x)
        if shortlisted:
            asg = inp[3]                              # (lc,) cluster ids
            adm = jnp.any(asg[None, :, None] == beam[:, None, :], axis=-1)
            z = jnp.where(adm, z.astype(jnp.float32), NEG_INF)
        cols = b0 + jnp.arange(lc, dtype=jnp.int32)
        return topk_merge(*carry, z, cols, k, num_labels), None

    xs = (w, jnp.asarray(seeds).astype(jnp.uint32),
          jnp.asarray(base).astype(jnp.int32))
    if shortlisted:
        xs = xs + (jnp.asarray(assign).astype(jnp.int32),)
    (vals, idx), _ = jax.lax.scan(body, topk_carry_init(B, k), xs)
    return vals, idx


def flash_attention_fwd_ref(q, k, v, causal: bool = True, window=None):
    """Dense softmax-attention oracle for the Pallas flash kernel.
    q: (B, H, Sq, dh); k, v: (B, KH, Sk, dh) — O(S²), tests/tiny only."""
    import numpy as _np
    B, H, Sq, dh = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    G = H // KH
    kk = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vv = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk)
    s = s / _np.sqrt(dh)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (kp <= qp)
    if window is not None:
        mask = mask & (qp - kp < window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv).astype(q.dtype)
