"""Pallas TPU kernel: stochastic-rounding cast f32/bf16 → {bf16, e4m3}.

Used by the SGD-SR optimizer path when the fused head-update kernel is not in
play (e.g. backbone tensors).  Tiles the (flattened-to-2D) array through VMEM;
SR bits come from the counter hash in ``prng_utils`` (no HBM random tensor).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import precision as P
from repro.kernels import prng_utils as PR


def _sr_cast_kernel(seed_ref, x_ref, o_ref, *, out_dtype):
    i = pl.program_id(0)
    j = pl.program_id(1)
    rows, cols = x_ref.shape
    row0 = (i * rows).astype(jnp.uint32)
    col0 = (j * cols).astype(jnp.uint32)
    bits = PR.hash_bits_2d(seed_ref[0], row0, col0, (rows, cols))
    x32 = x_ref[...].astype(jnp.float32)
    o_ref[...] = P.sr_bits(x32, bits, out_dtype)


@functools.partial(jax.jit,
                   static_argnames=("out_dtype", "block", "interpret"))
def sr_cast_2d(x: jax.Array, seed: jax.Array, *, out_dtype,
               block: tuple[int, int] = (256, 256),
               interpret: bool | None = None) -> jax.Array:
    """SR-cast a 2-D array. Pads to block multiples, slices back."""
    from repro.kernels import tuning
    interpret = tuning.interpret_default(interpret)
    assert x.ndim == 2, x.shape
    m, n = x.shape
    bm, bn = block
    pm, pn = (-m) % bm, (-n) % bn
    xp = jnp.pad(x, ((0, pm), (0, pn)))
    mp, np_ = m + pm, n + pn
    out = pl.pallas_call(
        functools.partial(_sr_cast_kernel, out_dtype=out_dtype),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # seed: whole (1,) array
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        interpret=interpret,
    )(seed.reshape(1).astype(jnp.uint32), xp)
    return out[:m, :n]
