"""Backend dispatch for ELMO kernels.

``impl`` selects the execution path:

* ``"kernel"``     — Pallas, compiled for TPU (interpret=False).
* ``"interpret"``  — Pallas interpret mode (CPU-correct, used by tests).
* ``"xla"``        — the pure-jnp oracle from ``ref.py``; the production
                     fallback for non-TPU backends, and what the multi-pod
                     dry-run lowers (same algorithm, honest HLO costs).
* ``"auto"``       — "kernel" on TPU, "xla" elsewhere.

All entry points are jit-compatible and shard_map-friendly (they only see the
local shard of any distributed operand).
"""
from __future__ import annotations

import jax

from repro.kernels import flash_attention_tpu as _fa
from repro.kernels import fp8_matmul as _fp8
from repro.kernels import fused_chunk as _fc
from repro.kernels import fused_head as _fh
from repro.kernels import fused_topk as _ft
from repro.kernels import fused_head_update as _fused
from repro.kernels import ref as _ref
from repro.kernels import sr_cast as _sr

ChunkOut = _fc.ChunkOut
HeadStepOut = _fh.HeadStepOut
LseOut = _fh.LseOut


def resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "xla"
    return impl


def _interpret_of(impl: str) -> bool:
    """Kernel-family impl → interpret flag, resolved at this dispatch layer
    (never a hardcoded keyword default): "kernel" compiles, "interpret"
    interprets, anything else defers to the single backend-resolution
    policy in ``tuning.interpret_default``."""
    from repro.kernels import tuning as _tuning
    if impl == "kernel":
        return False
    if impl == "interpret":
        return True
    return _tuning.interpret_default(None)


def sr_cast_2d(x, seed, *, out_dtype, impl: str = "auto", **kw):
    impl = resolve_impl(impl)
    if impl == "xla":
        return _ref.sr_cast_2d_ref(x, seed, out_dtype=out_dtype)
    return _sr.sr_cast_2d(x, seed, out_dtype=out_dtype,
                          interpret=(impl == "interpret"), **kw)


def fp8_logits(x, w, seed=None, *, drop_rate: float = 0.0,
               quantize_x: bool = True, impl: str = "auto", **kw):
    impl = resolve_impl(impl)
    if impl == "xla":
        return _ref.fp8_logits_ref(x, w, seed, drop_rate=drop_rate,
                                   quantize_x=quantize_x)
    return _fp8.fp8_logits(x, w, seed, drop_rate=drop_rate,
                           quantize_x=quantize_x,
                           interpret=(impl == "interpret"), **kw)


def fp8_input_grad(g, w, *, impl: str = "auto", **kw):
    impl = resolve_impl(impl)
    if impl == "xla":
        return _ref.fp8_input_grad_ref(g, w)
    return _fp8.fp8_input_grad(g, w, interpret=(impl == "interpret"), **kw)


def fused_head_update(g, x, w, lr, wd, seed, *, use_sr: bool = True,
                      impl: str = "auto", **kw):
    impl = resolve_impl(impl)
    if impl == "xla":
        return _ref.fused_head_update_ref(g, x, w, lr, wd, seed, use_sr=use_sr)
    return _fused.fused_head_update(g, x, w, lr, wd, seed, use_sr=use_sr,
                                    interpret=(impl == "interpret"), **kw)


def fused_head_update_kahan(g, x, w, comp, lr, wd, seed, *,
                            impl: str = "auto", **kw):
    impl = resolve_impl(impl)
    if impl == "xla":
        return _ref.fused_head_update_kahan_ref(g, x, w, comp, lr, wd, seed)
    return _fused.fused_head_update_kahan(g, x, w, comp, lr, wd, seed,
                                          interpret=(impl == "interpret"),
                                          **kw)


def fused_chunk_step(x, w, targets, xg, lr, wd, scale, c0, seed_drop,
                     seed_upd, lse=None, z=None, comp=None, *, loss: str,
                     num_labels: int, use_sr: bool = True,
                     quantize_x: bool = True, drop_rate: float = 0.0,
                     compute_loss: bool = True, impl: str = "auto",
                     **kw) -> "ChunkOut":
    """Single-launch fused chunk step (logits + loss-skip grad + x̄ + W
    update); see kernels/fused_chunk.py.  ``impl="xla"`` runs the oracle
    composition (identical algorithm, XLA-fused)."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return _ref.fused_chunk_ref(
            x, w, targets, xg, lr, wd, scale, c0, seed_drop, seed_upd,
            lse=lse, z=z, comp=comp, loss=loss, num_labels=num_labels,
            use_sr=use_sr, quantize_x=quantize_x, drop_rate=drop_rate,
            compute_loss=compute_loss,
            return_z=kw.get("return_z", False),
            guard=kw.get("guard", False))
    return _fc.fused_chunk_step(
        x, w, targets, xg, lr, wd, scale, c0, seed_drop, seed_upd,
        lse=lse, z=z, comp=comp, loss=loss, num_labels=num_labels,
        use_sr=use_sr, quantize_x=quantize_x, drop_rate=drop_rate,
        compute_loss=compute_loss, interpret=(impl == "interpret"), **kw)


def fused_head_step(x, w, targets, lr, wd, scale, seeds_drop, seeds_upd,
                    base, lse=None, z=None, comp=None, *, mode: str,
                    num_labels: int, impl: str = "auto",
                    **kw) -> "HeadStepOut":
    """Whole-head grid megakernel train step (kernels/fused_head.py): the
    entire label loop inside one Pallas grid.  There is no jnp oracle at
    this granularity — ``impl="xla"`` callers route to the per-chunk scan
    (``elmo_head``), which is the grid kernel's bit-parity reference."""
    impl = resolve_impl(impl)
    assert impl != "xla", "grid head has no XLA path; use the chunk scan"
    return _fh.fused_head_step(
        x, w, targets, lr, wd, scale, seeds_drop, seeds_upd, base,
        lse=lse, z=z, comp=comp, mode=mode, num_labels=num_labels,
        interpret=_interpret_of(impl), **kw)


def fused_head_lse(x, w, seeds_drop, base, *, num_labels: int,
                   impl: str = "auto", **kw) -> "LseOut":
    """Single-launch streaming-LSE statistics over every label block (the
    sharded CE pass 1 under ``ce_comm="stats"``)."""
    impl = resolve_impl(impl)
    assert impl != "xla", "grid head has no XLA path; use the chunk scan"
    return _fh.fused_head_lse(x, w, seeds_drop, base, num_labels=num_labels,
                              interpret=_interpret_of(impl), **kw)


def fused_head_logits(x, w, seeds_drop, *, impl: str = "auto", **kw):
    """All head logits in one launch (serving fast path)."""
    impl = resolve_impl(impl)
    assert impl != "xla", "grid head has no XLA path; use the chunk scan"
    return _fh.fused_head_logits(x, w, seeds_drop,
                                 interpret=_interpret_of(impl), **kw)


def sparse_head_step(x, values, indices, targets, lr, wd, scale,
                     seeds_drop, seeds_upd, base, lse=None, comp=None, *,
                     mode: str, num_labels: int, impl: str = "auto", **kw):
    """Whole sparse-head train step in one launch (kernels/sparse_head.py):
    fixed-fan-in value/index streams, densify-per-block, in-place SR/Kahan
    value updates.  Unlike the dense grid, ``impl="xla"`` IS supported —
    ``ref.sparse_head_step_ref`` scans the per-chunk sparse oracle with
    identical seed addressing and accumulation order (the bit-parity
    reference for the kernel, and the production non-TPU / sharded path)."""
    impl = resolve_impl(impl)
    if impl == "xla":
        kw.pop("block_l", None)      # the oracle scan has no label tile
        return _ref.sparse_head_step_ref(
            x, values, indices, targets, lr, wd, scale, seeds_drop,
            seeds_upd, base, lse=lse, comp=comp, mode=mode,
            num_labels=num_labels, **kw)
    from repro.kernels import sparse_head as _sh
    return _sh.sparse_head_step(
        x, values, indices, targets, lr, wd, scale, seeds_drop, seeds_upd,
        base, lse=lse, comp=comp, mode=mode, num_labels=num_labels,
        interpret=_interpret_of(impl), **kw)


def fused_topk(x, w, seeds_drop, base, *, k: int, num_labels: int,
               impl: str = "auto", assign=None, beam=None, **kw):
    """Streaming top-k serving in one launch (kernels/fused_topk.py):
    (B, k) values/ids over every label block, the logits never leave
    VMEM.  ``impl="xla"`` runs the chunk-scan oracle (same tie-break
    contract, bit-identical) — the non-TPU production path.

    ``assign``/``beam`` (both or neither) restrict the top-k to the
    shortlisted clusters (DESIGN §11) — identically on every impl, so
    the XLA oracle IS the restricted reference the kernel is tested
    against bit-for-bit."""
    impl = resolve_impl(impl)
    if impl == "xla":
        kw.pop("block_l", None)     # the oracle scan has no label tile
        return _ref.fused_topk_ref(x, w, seeds_drop, base, k=k,
                                   num_labels=num_labels, assign=assign,
                                   beam=beam, **kw)
    return _ft.fused_topk(x, w, seeds_drop, base, k=k,
                          num_labels=num_labels, assign=assign, beam=beam,
                          interpret=_interpret_of(impl), **kw)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window=None,
                        impl: str = "auto", **kw):
    """TPU flash-attention forward (serving fast path).  The training path
    keeps the XLA custom-VJP flash (models/flash_attention.py) everywhere."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return _ref.flash_attention_fwd_ref(q, k, v, causal=causal,
                                            window=window)
    return _fa.flash_attention_fwd_tpu(q, k, v, causal=causal, window=window,
                                       interpret=(impl == "interpret"), **kw)
