"""smollm-360m [dense] — llama-architecture small model
[hf:HuggingFaceTB/SmolLM-135M; hf].  32L d=960 15H (GQA kv=5) d_ff=2560
vocab=49152.  The head is 47M/360M params — the closest small-scale
analogue of the paper's XMC regime."""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560,
    vocab=49152, head_dim=64,
    pattern=(BlockSpec(kind="attn", ffn="swiglu"),),
    # §Perf-derived default (EXPERIMENTS.md): fsdp_pure makes this arch
    # compute-bound on v5e; tp_sp baseline numbers retained in §Perf
    sharding_strategy="fsdp_pure",
)
