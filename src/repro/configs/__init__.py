"""Architecture registry: the 10 assigned configs + the paper's own XMC
models.  ``get_config(name)`` returns the full published config;
``get_smoke(name)`` a reduced same-family config for CPU smoke tests."""
from repro.configs.registry import ARCHS, get_config, get_smoke

__all__ = ["ARCHS", "get_config", "get_smoke"]
