"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].
12L d=768 4 heads, no separate FFN (d_ff=0; xLSTM blocks carry their own
projections), vocab=50304.  Pattern: 5×mLSTM + 1×sLSTM per period (the
paper's ~7:1 placement rounded to the 12-layer budget).  Attention-free,
O(1) decode state → runs long_500k."""
from repro.models.config import BlockSpec, ModelConfig

_PERIOD = tuple([BlockSpec(kind="mlstm", ffn="none")] * 5
                + [BlockSpec(kind="slstm", ffn="none")])

CONFIG = ModelConfig(
    name="xlstm-125m",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, mlstm_heads=4,
    pattern=_PERIOD,
    subquadratic=True,
    # §Perf-derived default (EXPERIMENTS.md): fsdp_pure makes this arch
    # compute-bound on v5e; tp_sp baseline numbers retained in §Perf
    sharding_strategy="fsdp_pure",
)
