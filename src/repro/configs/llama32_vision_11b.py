"""llama-3.2-vision-11b [vlm] — gated cross-attention image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].  40L d=4096 32H (GQA kv=8)
d_ff=14336 vocab=128256.  Vision tower is a STUB: precomputed patch
embeddings (1600 × 1280) enter via gated cross-attn every 5th layer.
Full attention → long_500k skipped."""
from repro.models.config import BlockSpec, ModelConfig

_PERIOD = tuple([BlockSpec(kind="attn", ffn="swiglu")] * 4
                + [BlockSpec(kind="attn", cross_attn=True, ffn="swiglu")])

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=128_256,
    pattern=_PERIOD,
    frontend="vision", n_frontend_tokens=1600,
    grad_accum=2,
    # fsdp_pure REFUTED for this arch (EXPERIMENTS.md §Perf): the vision
    # cross-attn context replicates under batch-over-512 (41.6 GiB/dev vs
    # 12.2 under tp_sp) — stays on the tp_sp baseline
)
