"""xmc-distilbert-8.6m — the paper's LF-Paper2Keywords-8.6M setting
(Table 3): DistilBERT-like 6L encoder + 8,623,847-label BCE ELMO head."""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="xmc-distilbert-8.6m",
    n_layers=6, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=30522,
    pattern=(BlockSpec(kind="attn", ffn="gelu"),),
    causal=False, pool="first",
    head_labels=8_623_847, head_chunks=16, head_weight_dtype="e4m3",
    max_labels_per_example=16,
)
