"""xmc-bert-3m-sparse — the fixed-fan-in sparse variant of the paper's
Amazon-3M setting (DESIGN.md §13): every label row keeps 16 of 768 weight
slots (FP8 values + i32 column indices, ~14× less head memory than the
dense FP8+Kahan baseline), with a periodic magnitude-prune /
gradient-regrow topology update.  Kahan is homogeneous-off here — the
sparse single-kernel update cannot mix Kahan and SR chunks the way the
dense hybrid does (head/config.py asserts this)."""
import dataclasses

from repro.configs.xmc_bert_3m import CONFIG as _DENSE

CONFIG = dataclasses.replace(
    _DENSE,
    name="xmc-bert-3m-sparse",
    head_fan_in=16,
    head_prune_every=100,
    head_kahan_chunks=0,
)
