"""gemma-7b [dense] — GeGLU, head_dim=256, MHA (kv=16)
[arXiv:2403.08295; hf].  28L d=3072 16H d_ff=24576 vocab=256000.
The 256K-vocab head (786M params; 512GB of unchunked train_4k logits) is
the flagship ELMO cell (DESIGN.md §3).  Full attention → long_500k skipped.
Input/output embeddings untied (deviation: ELMO head is separately
optimized; noted in EXPERIMENTS.md)."""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, d_ff=24576,
    vocab=256_000, head_dim=256,
    pattern=(BlockSpec(kind="attn", ffn="geglu"),),
    head_chunks=16,
    # §Perf-derived default (EXPERIMENTS.md): fsdp_pure makes this arch
    # compute-bound on v5e; tp_sp baseline numbers retained in §Perf
    sharding_strategy="fsdp_pure",
)
