"""phi3-medium-14b [dense] — RoPE SwiGLU GQA [arXiv:2404.14219; unverified].
40L d=5120 40H (GQA kv=10) d_ff=17920 vocab=100352.  Pure full attention →
long_500k skipped (DESIGN.md §3)."""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, d_ff=17920,
    vocab=100352,
    pattern=(BlockSpec(kind="attn", ffn="swiglu"),),
    # §Perf-derived default (EXPERIMENTS.md): fsdp_pure makes this arch
    # compute-bound on v5e; tp_sp baseline numbers retained in §Perf
    sharding_strategy="fsdp_pure",
)
