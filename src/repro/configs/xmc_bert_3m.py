"""xmc-bert-3m — the paper's own Amazon-3M setting (Table 2): BERT-base-like
bidirectional encoder (12L d=768, seq 128) + 2,812,281-label BCE ELMO head,
FP8 E4M3 weights, 8 chunks, momentum-free SR-SGD."""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="xmc-bert-3m",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=30522,
    pattern=(BlockSpec(kind="attn", ffn="gelu"),),
    causal=False, pool="first",
    head_labels=2_812_281, head_chunks=8, head_weight_dtype="e4m3",
    head_kahan_chunks=2,   # App. D: Kahan for the top ~25% (head) labels
    max_labels_per_example=40,
)
