"""Registry + input-shape definitions for every (arch × shape) cell.

The four LM shape regimes (task spec):
    train_4k     seq 4,096   global_batch 256   → train_step
    prefill_32k  seq 32,768  global_batch 32    → prefill (serve)
    decode_32k   seq 32,768  global_batch 128   → serve_step (1 new token,
                                                  KV/SSM state at seq_len)
    long_500k    seq 524,288 global_batch 1     → serve_step; ONLY for
                 subquadratic archs (DESIGN.md §3 skip rule)

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for every model input of a cell.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import frontends as Fe
from repro.models.config import ModelConfig, reduced

ARCHS = {
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "arctic-480b": "repro.configs.arctic_480b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "smollm-360m": "repro.configs.smollm_360m",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "gemma-7b": "repro.configs.gemma_7b",
    "musicgen-large": "repro.configs.musicgen_large",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "llama-3.2-vision-11b": "repro.configs.llama32_vision_11b",
    # paper's own
    "xmc-bert-3m": "repro.configs.xmc_bert_3m",
    "xmc-distilbert-8.6m": "repro.configs.xmc_distilbert_8_6m",
    # fixed-fan-in sparse head variants (DESIGN.md §13)
    "xmc-bert-3m-sparse": "repro.configs.xmc_bert_3m_sparse",
    "xmc-distilbert-8.6m-sparse":
        "repro.configs.xmc_distilbert_8_6m_sparse",
}

ASSIGNED = [k for k in ARCHS if not k.startswith("xmc-")]


def get_config(name: str) -> ModelConfig:
    return importlib.import_module(ARCHS[name]).CONFIG


def get_smoke(name: str, **overrides) -> ModelConfig:
    return reduced(get_config(name), **overrides)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
    # the paper's own regime (BERT-base, seq 128, batch 128 — Table 9)
    "xmc_train": ShapeCell("xmc_train", "train", 128, 128),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeCell) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the documented skip reason."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("pure full-attention arch: long_500k needs sub-quadratic "
                "attention (DESIGN.md §3 skip rule)")
    if shape.name == "xmc_train" and cfg.head_labels is None:
        return "xmc_train shape only applies to the paper's own XMC archs"
    if shape.name != "xmc_train" and cfg.head_labels is not None:
        return "XMC encoders use the xmc_train shape (paper Table 9 regime)"
    if shape.kind in ("prefill", "decode") and not cfg.causal:
        return "encoder-only arch has no decode step (task spec)"
    return None


def input_specs(cfg: ModelConfig, shape: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for one cell's step function inputs."""
    B, S = shape.batch, shape.seq
    f = jnp.bfloat16
    specs: dict = {}
    if shape.kind == "train":
        if cfg.frontend == "audio_frames":
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, S, Fe.D_FRONTEND["audio_frames"]), f)
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.frontend == "vision":
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, Fe.D_FRONTEND["vision"]), f)
        if cfg.head_labels:
            specs["targets"] = jax.ShapeDtypeStruct(
                (B, cfg.max_labels_per_example), jnp.int32)
        else:
            specs["targets"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.frontend == "audio_frames":
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, S, Fe.D_FRONTEND["audio_frames"]), f)
        if cfg.frontend == "vision":
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, Fe.D_FRONTEND["vision"]), f)
    else:  # decode: one new token against a seq-length cache
        specs["token"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        if cfg.frontend == "audio_frames":
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, 1, Fe.D_FRONTEND["audio_frames"]), f)
        if cfg.frontend == "vision":
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, Fe.D_FRONTEND["vision"]), f)
    return specs
