"""xmc-distilbert-8.6m-sparse — the fixed-fan-in sparse variant of the
paper's LF-Paper2Keywords-8.6M setting (DESIGN.md §13): 12 of 768 weight
slots per label row (FP8 values + i32 column indices; the dense baseline
here carries no Kahan buffer, so the fan-in is tighter than the
Amazon-3M variant's to keep the ≥10× head-memory margin), periodic
magnitude-prune / gradient-regrow topology updates."""
import dataclasses

from repro.configs.xmc_distilbert_8_6m import CONFIG as _DENSE

CONFIG = dataclasses.replace(
    _DENSE,
    name="xmc-distilbert-8.6m-sparse",
    head_fan_in=12,
    head_prune_every=100,
)
