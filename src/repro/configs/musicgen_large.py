"""musicgen-large [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].  48L d=2048 32H (MHA kv=32) d_ff=8192 vocab=2048.
EnCodec frontend is a STUB: the model consumes precomputed frame embeddings
(input_specs supplies them).  Head is tiny (2048) → ELMO applicable but not
profitable; head_chunks=1 (DESIGN.md §3).  Full attention → long_500k
skipped."""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=2048, head_dim=64,
    pattern=(BlockSpec(kind="attn", ffn="gelu"),),
    frontend="audio_frames",
    head_chunks=1, head_weight_dtype="bf16",
    # §Perf-derived default (EXPERIMENTS.md): fsdp_pure makes this arch
    # compute-bound on v5e; tp_sp baseline numbers retained in §Perf
    sharding_strategy="fsdp_pure",
)
