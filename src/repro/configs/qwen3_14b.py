"""qwen3-14b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf].
40L d=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.  Pure full attention →
long_500k skipped."""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=17408,
    vocab=151936, head_dim=128, qk_norm=True,
    pattern=(BlockSpec(kind="attn", ffn="swiglu"),),
    # §Perf-derived default (EXPERIMENTS.md): fsdp_pure makes this arch
    # compute-bound on v5e; tp_sp baseline numbers retained in §Perf
    sharding_strategy="fsdp_pure",
)
