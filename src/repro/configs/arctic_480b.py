"""arctic-480b [moe] — 128 experts top-2 + dense residual branch
[hf:Snowflake/snowflake-arctic-base; hf].  35L d=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000.  ~477B params; the ELMO treatment is extended to the
expert weights at this scale (DESIGN.md §3, beyond-paper)."""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab=32000,
    pattern=(BlockSpec(kind="attn", moe=True, ffn="swiglu"),),
    n_experts=128, top_k=2, moe_dense_residual=True,
    grad_accum=8,   # 469B params: divide token-side transients 8×
)
