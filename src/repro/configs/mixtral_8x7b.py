"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention
[arXiv:2401.04088; hf].  32L d=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
SWA bounds the decode KV state → runs long_500k (DESIGN.md §3)."""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=32000,
    pattern=(BlockSpec(kind="attn", moe=True, ffn="swiglu"),),
    n_experts=8, top_k=2, sliding_window=4096,
    grad_accum=4,
    subquadratic=True,
)
