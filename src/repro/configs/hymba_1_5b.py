"""hymba-1.5b [hybrid] — parallel attention+mamba heads per layer
[arXiv:2411.13676; hf].  32L d=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16.  Sliding-window attention (global-attn layers simplified to
SWA; DESIGN.md §3) + O(1) SSM state → runs long_500k."""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab=32001, head_dim=64,
    pattern=(BlockSpec(kind="hymba", ffn="swiglu"),),
    sliding_window=2048, ssm_state=16, ssm_expand=2,
    subquadratic=True,
    # §Perf-derived default (EXPERIMENTS.md): fsdp_pure makes this arch
    # compute-bound on v5e; tp_sp baseline numbers retained in §Perf
    sharding_strategy="fsdp_pure",
)
