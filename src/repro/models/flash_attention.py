"""Blockwise attention with a flash-style custom VJP (pure jnp).

Plain autodiff through an online-softmax scan would save the per-block
probability tiles — i.e. the full O(S²) attention matrix in pieces — which
is exactly the activation blow-up the paper's chunking philosophy removes.
This module gives attention the same treatment the ELMO head gives logits:

* forward: outer scan over q-blocks, inner scan over kv-blocks, online
  (m, l) softmax — saves only (q, k, v, out, lse);
* backward: FA2-style — recomputes each probability tile from the saved lse,
  accumulates dq per q-block and scatter-adds dk/dv per kv-block.  Transient
  memory is O(bq·bk), total O(S).

Sliding windows visit only the ≤ ceil(window/bk)+2 kv-blocks that can
intersect each q-block, in both passes — SWA costs S·window FLOPs, not S².

GQA is native: q (B,Sq,KH,G,dh) against k/v (B,Sk,KH,dh).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _win_blocks(window: Optional[int], nk: int, bk: int) -> int:
    if window is None:
        return nk
    return min(nk, int(np.ceil(window / bk)) + 2)


def _kv_index(i, r, bq: int, bk: int, nk: int, window: Optional[int]):
    """kv-block index at relative step r for q-block i, + visit validity
    (clipped steps would revisit block 0 and double-count)."""
    if window is None:
        return r, jnp.bool_(True)
    j_of_i = jnp.clip(((i + 1) * bq - 1) // bk, 0, nk - 1)
    raw = j_of_i - r
    return jnp.clip(raw, 0, nk - 1), raw >= 0


def _tile_mask(qp, kp, kv_valid, visit, causal: bool,
               window: Optional[int]):
    mask = kv_valid[:, None, :] & visit
    if causal:
        mask = mask & (kp[:, None, :] <= qp[:, :, None])
    if window is not None:
        mask = mask & (qp[:, :, None] - kp[:, None, :] < window)
    return mask


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9))
def flash_attention(q, k, v, q_pos, k_pos, k_valid, causal: bool,
                    window: Optional[int], bq: int, bk: int):
    """q: (B,Sq,KH,G,dh); k,v: (B,Sk,KH,dh); positions: (B,S) int32;
    k_valid: (B,Sk) bool (False = padding). Returns (B,Sq,KH,G,dh)."""
    out, _ = _flash_fwd_impl(q, k, v, q_pos, k_pos, k_valid, causal, window,
                             bq, bk)
    return out


def _flash_fwd_impl(q, k, v, q_pos, k_pos, k_valid, causal, window, bq, bk):
    B, Sq, KH, G, dh = q.shape
    Sk = k.shape[1]
    scale = 1.0 / np.sqrt(dh)
    nq, nk = Sq // bq, Sk // bk
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)

    qb = q.reshape(B, nq, bq, KH, G, dh).swapaxes(0, 1)
    qpb = q_pos.reshape(B, nq, bq).swapaxes(0, 1)
    kb = k.reshape(B, nk, bk, KH, dh)
    vb = v.reshape(B, nk, bk, KH, dh)
    kpb = k_pos.reshape(B, nk, bk)
    kvb = k_valid.reshape(B, nk, bk)
    n_win = _win_blocks(window, nk, bk)

    def q_block(_, inp):
        qi, qpi, i = inp
        m0 = jnp.full((B, bq, KH, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, bq, KH, G), jnp.float32)
        a0 = jnp.zeros((B, bq, KH, G, dh), jnp.float32)

        def kv_step(acc, r):
            m, l, a = acc
            j, visit = _kv_index(i, r, bq, bk, nk, window)
            kj = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
            kpj = jax.lax.dynamic_index_in_dim(kpb, j, 1, keepdims=False)
            kvj = jax.lax.dynamic_index_in_dim(kvb, j, 1, keepdims=False)
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qi.astype(jnp.bfloat16),
                           kj.astype(jnp.bfloat16),
                           preferred_element_type=jnp.float32) * scale
            mask = _tile_mask(qpi, kpj, kvj, visit, causal, window)
            s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            alive = m_new > NEG_INF / 2
            p = jnp.where(alive[..., None], jnp.exp(s - m_new[..., None]), 0.)
            corr = jnp.where(alive, jnp.exp(m - m_new), 1.0)
            l = l * corr + p.sum(-1)
            a = a * corr[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p.astype(jnp.bfloat16),
                vj.astype(jnp.bfloat16), preferred_element_type=jnp.float32)
            return (m_new, l, a), None

        (m, l, a), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                    jnp.arange(n_win, dtype=jnp.int32))
        out = (a / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(
        q_block, None, (qb, qpb, jnp.arange(nq, dtype=jnp.int32)))
    out = outs.swapaxes(0, 1).reshape(B, Sq, KH, G, dh)
    lse = lses.swapaxes(0, 1).reshape(B, Sq, KH, G)
    return out, lse


def _flash_fwd(q, k, v, q_pos, k_pos, k_valid, causal, window, bq, bk):
    out, lse = _flash_fwd_impl(q, k, v, q_pos, k_pos, k_valid, causal,
                               window, bq, bk)
    return out, (q, k, v, q_pos, k_pos, k_valid, out, lse)


def _flash_bwd(causal, window, bq, bk, res, do):
    q, k, v, q_pos, k_pos, k_valid, out, lse = res
    B, Sq, KH, G, dh = q.shape
    Sk = k.shape[1]
    scale = 1.0 / np.sqrt(dh)
    nq, nk = Sq // bq, Sk // bk
    n_win = _win_blocks(window, nk, bk)

    # delta_i = Σ_d do·out  (FA2)
    delta = (do.astype(jnp.float32) * out.astype(jnp.float32)).sum(-1)

    qb = q.reshape(B, nq, bq, KH, G, dh).swapaxes(0, 1)
    qpb = q_pos.reshape(B, nq, bq).swapaxes(0, 1)
    dob = do.reshape(B, nq, bq, KH, G, dh).swapaxes(0, 1)
    lseb = lse.reshape(B, nq, bq, KH, G).swapaxes(0, 1)
    deltab = delta.reshape(B, nq, bq, KH, G).swapaxes(0, 1)
    kb = k.reshape(B, nk, bk, KH, dh)
    vb = v.reshape(B, nk, bk, KH, dh)
    kpb = k_pos.reshape(B, nk, bk)
    kvb = k_valid.reshape(B, nk, bk)

    dk0 = jnp.zeros((B, nk, bk, KH, dh), jnp.float32)
    dv0 = jnp.zeros((B, nk, bk, KH, dh), jnp.float32)

    def q_block(carry, inp):
        dk, dv = carry
        qi, qpi, doi, lsei, di, i = inp

        def kv_step(acc, r):
            dq_i, dk, dv = acc
            j, visit = _kv_index(i, r, bq, bk, nk, window)
            kj = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
            kpj = jax.lax.dynamic_index_in_dim(kpb, j, 1, keepdims=False)
            kvj = jax.lax.dynamic_index_in_dim(kvb, j, 1, keepdims=False)
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qi.astype(jnp.bfloat16),
                           kj.astype(jnp.bfloat16),
                           preferred_element_type=jnp.float32) * scale
            mask = _tile_mask(qpi, kpj, kvj, visit, causal, window)
            s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
            p = jnp.exp(s - lsei[..., None])                     # (B,q,h,g,k)
            p = jnp.where(mask[:, :, None, None, :], p, 0.0)
            dvj = jnp.einsum("bqhgk,bqhgd->bkhd", p.astype(jnp.bfloat16),
                             doi.astype(jnp.bfloat16),
                             preferred_element_type=jnp.float32)
            dp = jnp.einsum("bqhgd,bkhd->bqhgk", doi.astype(jnp.bfloat16),
                            vj.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32)
            ds = p * (dp - di[..., None]) * scale
            dq_i = dq_i + jnp.einsum("bqhgk,bkhd->bqhgd",
                                     ds.astype(jnp.bfloat16),
                                     kj.astype(jnp.bfloat16),
                                     preferred_element_type=jnp.float32)
            dkj = jnp.einsum("bqhgk,bqhgd->bkhd", ds.astype(jnp.bfloat16),
                             qi.astype(jnp.bfloat16),
                             preferred_element_type=jnp.float32)
            dk = jax.lax.dynamic_update_index_in_dim(
                dk, jax.lax.dynamic_index_in_dim(dk, j, 1, keepdims=False)
                + dkj, j, 1)
            dv = jax.lax.dynamic_update_index_in_dim(
                dv, jax.lax.dynamic_index_in_dim(dv, j, 1, keepdims=False)
                + dvj, j, 1)
            return (dq_i, dk, dv), None

        dq0 = jnp.zeros((B, bq, KH, G, dh), jnp.float32)
        (dq_i, dk, dv), _ = jax.lax.scan(
            kv_step, (dq0, dk, dv), jnp.arange(n_win, dtype=jnp.int32))
        return (dk, dv), dq_i.astype(q.dtype)

    (dk, dv), dqs = jax.lax.scan(
        q_block, (dk0, dv0),
        (qb, qpb, dob, lseb, deltab, jnp.arange(nq, dtype=jnp.int32)))
    dq = dqs.swapaxes(0, 1).reshape(B, Sq, KH, G, dh)
    dk = dk.reshape(B, Sk, KH, dh).astype(k.dtype)
    dv = dv.reshape(B, Sk, KH, dh).astype(v.dtype)
    return dq, dk, dv, None, None, None


flash_attention.defvjp(_flash_fwd, _flash_bwd)
