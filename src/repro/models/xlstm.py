"""xLSTM blocks: chunk-parallel mLSTM (matrix memory) + sequential sLSTM.

mLSTM (Beck et al. 2024) is fully parallelizable: within a chunk the output
is an attention-like einsum weighted by cumulative exponential gates, and
only the (dh × dh) matrix memory crosses chunk boundaries.  Gate pre-
activations are clamped so all exponentials stay in f32 range (in place of
the paper's m-stabilizer state — the clamp bounds every exponent by
construction).  sLSTM has genuine recurrence (gates see h_{t-1} through a
per-head recurrent matrix), so it scans sequentially over time — that is its
honest cost, noted in DESIGN.md.

Decode carries (C, n) / (c, n, h) — O(1) state per token, which is why
xlstm runs the ``long_500k`` cell.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as Ly
from repro.models.config import ModelConfig

GATE_CLAMP = 5.0


def _heads(cfg: ModelConfig) -> Tuple[int, int]:
    H = cfg.mlstm_heads
    assert cfg.d_model % H == 0
    return H, cfg.d_model // H


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    H, _ = _heads(cfg)
    ks = jax.random.split(key, 7)
    return {
        "w_q": Ly.dense_init(ks[0], D, D),
        "w_k": Ly.dense_init(ks[1], D, D),
        "w_v": Ly.dense_init(ks[2], D, D),
        "w_z": Ly.dense_init(ks[3], D, D),             # output gate branch
        "w_i": Ly.dense_init(ks[4], D, H, dtype=jnp.float32),
        "w_f": Ly.dense_init(ks[5], D, H, dtype=jnp.float32),
        "f_bias": jnp.full((H,), 3.0, jnp.float32),    # start remembering
        "w_o": Ly.dense_init(ks[6], D, D),
    }


def _mlstm_gates(p, x):
    logf = jax.nn.log_sigmoid(
        jnp.dot(x.astype(jnp.float32), p["w_f"]) + p["f_bias"])
    logi = jnp.clip(jnp.dot(x.astype(jnp.float32), p["w_i"]),
                    -GATE_CLAMP, GATE_CLAMP)
    return logf, logi                                   # (B, S, H)


def _mlstm_chunk(q, k, v, logf, logi, C0, n0, scale):
    """One chunk of the mLSTM recurrence, fully parallel.

    q,k,v: (B,W,H,dh); logf/logi: (B,W,H); C0: (B,H,dh,dh); n0: (B,H,dh).
    """
    F = jnp.cumsum(logf, axis=1)                        # (B,W,H) inclusive
    # intra-chunk decay weights M_ij = exp(F_i − F_j + logi_j), j ≤ i
    diff = F[:, :, None, :] - F[:, None, :, :] + logi[:, None, :, :]
    W = q.shape[1]
    tri = jnp.tril(jnp.ones((W, W), bool))
    M = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)  # (B,i,j,H)
    s = jnp.einsum("bihd,bjhd->bijh", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    sw = s * M
    num_intra = jnp.einsum("bijh,bjhd->bihd", sw, v.astype(jnp.float32))
    den_intra = sw.sum(2)                               # Σ_j weights·(q·k)
    eF = jnp.exp(F)[..., None]                          # (B,W,H,1)
    num_inter = jnp.einsum("bihd,bhde->bihe", q.astype(jnp.float32) * eF, C0)
    den_inter = jnp.einsum("bihd,bhd->bih", q.astype(jnp.float32) * eF, n0)
    num = num_intra + num_inter
    den = jnp.abs(den_intra + den_inter)
    h = num / jnp.maximum(den, 1.0)[..., None]          # (B,W,H,dh)

    # state update to chunk end
    last = F[:, -1:, :]                                  # (B,1,H)
    wgt = jnp.exp(last - F + logi)[..., None]            # (B,W,H,1)
    C1 = C0 * jnp.exp(last[..., None]).swapaxes(1, 2) \
        + jnp.einsum("bjhd,bjhe->bhde", k.astype(jnp.float32) * wgt,
                     v.astype(jnp.float32))
    n1 = n0 * jnp.exp(last).swapaxes(1, 2)[..., 0][..., None] \
        + (k.astype(jnp.float32) * wgt).sum(1)
    return h, C1, n1


def mlstm_apply(p, cfg: ModelConfig, x, chunk: int = 64) -> jax.Array:
    B, S, D = x.shape
    H, dh = _heads(cfg)
    q = Ly.dense(p["w_q"], x).reshape(B, S, H, dh)
    k = Ly.dense(p["w_k"], x).reshape(B, S, H, dh)
    v = Ly.dense(p["w_v"], x).reshape(B, S, H, dh)
    logf, logi = _mlstm_gates(p, x)
    scale = 1.0 / np.sqrt(dh)

    Wc = min(chunk, S)
    pad = (-S) % Wc
    if pad:
        q, k, v = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for a in (q, k, v))
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))
        logi = jnp.pad(logi, ((0, 0), (0, pad), (0, 0)),
                       constant_values=-GATE_CLAMP * 10)
    nc = (S + pad) // Wc

    def body(carry, inp):
        C0, n0 = carry
        qc, kc, vc, lfc, lic = inp
        h, C1, n1 = _mlstm_chunk(qc, kc, vc, lfc, lic, C0, n0, scale)
        return (C1, n1), h

    xs = (q.reshape(B, nc, Wc, H, dh).swapaxes(0, 1),
          k.reshape(B, nc, Wc, H, dh).swapaxes(0, 1),
          v.reshape(B, nc, Wc, H, dh).swapaxes(0, 1),
          logf.reshape(B, nc, Wc, H).swapaxes(0, 1),
          logi.reshape(B, nc, Wc, H).swapaxes(0, 1))
    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    # remat: keep only (C, n) boundary states, not per-chunk (W,W) weights
    _, hs = jax.lax.scan(jax.checkpoint(body), (C0, n0), xs)
    h = hs.swapaxes(0, 1).reshape(B, nc * Wc, H * dh)[:, :S]
    z = jax.nn.silu(Ly.dense(p["w_z"], x).astype(jnp.float32))
    return Ly.dense(p["w_o"], (h * z).astype(x.dtype))


class MLSTMCache(NamedTuple):
    C: jax.Array    # (B, H, dh, dh)
    n: jax.Array    # (B, H, dh)


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> MLSTMCache:
    H, dh = _heads(cfg)
    return MLSTMCache(jnp.zeros((batch, H, dh, dh), jnp.float32),
                      jnp.zeros((batch, H, dh), jnp.float32))


def mlstm_decode(p, cfg: ModelConfig, x, cache: MLSTMCache):
    B = x.shape[0]
    H, dh = _heads(cfg)
    q = Ly.dense(p["w_q"], x).reshape(B, 1, H, dh)
    k = Ly.dense(p["w_k"], x).reshape(B, 1, H, dh)
    v = Ly.dense(p["w_v"], x).reshape(B, 1, H, dh)
    logf, logi = _mlstm_gates(p, x)
    h, C1, n1 = _mlstm_chunk(q, k, v, logf, logi, cache.C, cache.n,
                             1.0 / np.sqrt(dh))
    z = jax.nn.silu(Ly.dense(p["w_z"], x).astype(jnp.float32))
    y = Ly.dense(p["w_o"], (h.reshape(B, 1, H * dh) * z).astype(x.dtype))
    return y, MLSTMCache(C1, n1)


# ---------------------------------------------------------------------------
# sLSTM — sequential (true recurrence through h_{t-1})
# ---------------------------------------------------------------------------


def slstm_init(key, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    H, dh = _heads(cfg)
    ks = jax.random.split(key, 3)
    return {
        "w_in": Ly.dense_init(ks[0], D, 4 * D),        # z, i, f, o branches
        "r": (jax.random.normal(ks[1], (4, H, dh, dh), jnp.float32)
              / np.sqrt(dh)).astype(jnp.float32),
        "bias": jnp.concatenate([jnp.zeros((2 * D,), jnp.float32),
                                 jnp.full((D,), 3.0, jnp.float32),
                                 jnp.zeros((D,), jnp.float32)]),
        "w_o": Ly.dense_init(ks[2], D, D),
    }


class SLSTMCache(NamedTuple):
    c: jax.Array    # (B, H, dh)
    n: jax.Array
    h: jax.Array
    m: jax.Array    # stabilizer (B, H, dh)


def init_slstm_cache(cfg: ModelConfig, batch: int) -> SLSTMCache:
    H, dh = _heads(cfg)
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return SLSTMCache(z, z + 1e-6, z, z - 10.0)


def _slstm_step(p, cfg: ModelConfig, xt, cache: SLSTMCache):
    """xt: (B, D) — one timestep."""
    B = xt.shape[0]
    H, dh = _heads(cfg)
    pre = (jnp.dot(xt.astype(jnp.float32),
                   p["w_in"].astype(jnp.float32)) + p["bias"])
    pre = pre.reshape(B, 4, H, dh)
    rec = jnp.einsum("bhd,ghde->bghe", cache.h, p["r"])
    z_t = jnp.tanh(pre[:, 0] + rec[:, 0])
    i_t = jnp.clip(pre[:, 1] + rec[:, 1], -GATE_CLAMP * 3, GATE_CLAMP * 3)
    f_t = pre[:, 2] + rec[:, 2]
    o_t = jax.nn.sigmoid(pre[:, 3] + rec[:, 3])
    logf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(logf + cache.m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(logf + cache.m - m_new)
    c_new = f_p * cache.c + i_p * z_t
    n_new = f_p * cache.n + i_p
    h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
    return SLSTMCache(c_new, n_new, h_new, m_new)


def slstm_apply(p, cfg: ModelConfig, x) -> jax.Array:
    B, S, D = x.shape
    H, dh = _heads(cfg)

    def body(cache, xt):
        cache = _slstm_step(p, cfg, xt, cache)
        return cache, cache.h

    cache0 = init_slstm_cache(cfg, B)
    _, hs = jax.lax.scan(body, cache0, x.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).reshape(B, S, D)
    return Ly.dense(p["w_o"], h.astype(x.dtype))


def slstm_decode(p, cfg: ModelConfig, x, cache: SLSTMCache):
    B = x.shape[0]
    cache = _slstm_step(p, cfg, x[:, 0], cache)
    y = Ly.dense(p["w_o"], cache.h.reshape(B, 1, -1).astype(x.dtype))
    return y, cache
