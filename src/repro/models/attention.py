"""Attention: GQA + RoPE + sliding-window + qk-norm + cross-attn + KV cache.

Training/prefill attention is *blockwise with online softmax* (Rabe & Staats
2021 — cited by the paper for its chunking strategy): an outer scan over query
blocks and an inner scan over KV blocks keep transient memory at
O(bq·bk) instead of O(S²), which is what makes the 32k-prefill dry-run
cells compile within HBM.  Sliding-window attention only visits the KV blocks
inside the window (true sub-quadratic FLOPs, not just masking).

Decode attends a single query against the cache in one einsum.  SWA decode
uses a ring-buffer cache of size ``window`` — the reason mixtral/hymba run
the 500k-context cell (DESIGN.md §3).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as Ly
from repro.models.config import ModelConfig

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig, cross: bool = False) -> dict:
    dh, H, KH, D = cfg.hdim, cfg.n_heads, cfg.n_kv_heads, cfg.d_model
    ks = jax.random.split(key, 7)
    p = {
        "wq": Ly.dense_init(ks[0], D, H * dh),
        "wk": Ly.dense_init(ks[1], D, KH * dh),
        "wv": Ly.dense_init(ks[2], D, KH * dh),
        "wo": Ly.dense_init(ks[3], H * dh, D, scale=1.0 / np.sqrt(H * dh)),
    }
    if cfg.qk_norm:
        p["q_norm"] = Ly.rmsnorm_init(dh)
        p["k_norm"] = Ly.rmsnorm_init(dh)
    if cross:
        p["gate"] = jnp.zeros((), jnp.float32)  # tanh-gated injection (VLM)
    return p


def _project_q(p, cfg: ModelConfig, x, positions, rope: bool):
    B, S, _ = x.shape
    q = Ly.dense(p["wq"], x).reshape(B, S, cfg.n_heads, cfg.hdim)
    if cfg.qk_norm:
        q = Ly.rmsnorm(p["q_norm"], q, cfg.norm_eps)
    if rope:
        q = Ly.apply_rope(q, positions, cfg.rope_theta)
    return q


def _project_kv(p, cfg: ModelConfig, x, positions, rope: bool):
    B, S, _ = x.shape
    k = Ly.dense(p["wk"], x).reshape(B, S, cfg.n_kv_heads, cfg.hdim)
    v = Ly.dense(p["wv"], x).reshape(B, S, cfg.n_kv_heads, cfg.hdim)
    if cfg.qk_norm:
        k = Ly.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if rope:
        k = Ly.apply_rope(k, positions, cfg.rope_theta)
    return k, v


# ---------------------------------------------------------------------------
# blockwise online-softmax attention (training / prefill)
# ---------------------------------------------------------------------------


def _pad_seq(x, block):
    s = x.shape[1]
    pad = (-s) % block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
    return x, s


def blockwise_attention(q, k, v, q_pos, k_pos, *, causal: bool,
                        window: Optional[int], bq: int = 512,
                        bk: int = 1024) -> jax.Array:
    """q:(B,Sq,H,dh) k,v:(B,Sk,KH,dh) → (B,Sq,H,dh).

    Thin padding/layout wrapper over ``flash_attention`` (custom VJP: online
    softmax forward, FA2 recompute backward — O(S) memory in both passes)."""
    from repro.models.flash_attention import flash_attention

    B, Sq, H, dh = q.shape
    KH = k.shape[2]
    G = H // KH

    q, Sq0 = _pad_seq(q, bq)
    k, Sk0 = _pad_seq(k, bk)
    v, _ = _pad_seq(v, bk)
    q_pos, _ = _pad_seq(q_pos[..., None], bq)
    k_pos, _ = _pad_seq(k_pos[..., None], bk)
    q_pos, k_pos = q_pos[..., 0], k_pos[..., 0]
    Sqp, Skp = q.shape[1], k.shape[1]
    k_valid = jnp.broadcast_to(jnp.arange(Skp) < Sk0, (B, Skp))

    q5 = q.reshape(B, Sqp, KH, G, dh)
    out5 = flash_attention(q5, k, v, q_pos, k_pos, k_valid, causal, window,
                           bq, bk)
    return out5.reshape(B, Sqp, H, dh)[:, :Sq0]


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def self_attention(p, cfg: ModelConfig, x, positions, *,
                   bq: int = 512, bk: int = 1024) -> jax.Array:
    """Causal (optionally sliding-window) self-attention for training.
    Non-causal (bidirectional) when cfg.causal=False (XMC encoders)."""
    B, S, _ = x.shape
    q = _project_q(p, cfg, x, positions, rope=True)
    k, v = _project_kv(p, cfg, x, positions, rope=True)
    out = blockwise_attention(q, k, v, positions, positions, causal=cfg.causal,
                              window=cfg.sliding_window, bq=min(bq, S),
                              bk=min(bk, S))
    return Ly.dense(p["wo"], out.reshape(B, S, -1))


def cross_attention(p, cfg: ModelConfig, x, ctx) -> jax.Array:
    """Gated cross-attention onto precomputed context embeddings (VLM)."""
    B, S, _ = x.shape
    N = ctx.shape[1]
    zero = jnp.zeros((B, S), jnp.int32)
    q = _project_q(p, cfg, x, zero, rope=False)
    k, v = _project_kv(p, cfg, ctx, jnp.zeros((B, N), jnp.int32), rope=False)
    out = blockwise_attention(q, k, v, zero, jnp.zeros((B, N), jnp.int32),
                              causal=False, window=None,
                              bq=min(512, S), bk=min(1024, N))
    y = Ly.dense(p["wo"], out.reshape(B, S, -1))
    return jnp.tanh(p["gate"]).astype(y.dtype) * y


class KVCache(NamedTuple):
    k: jax.Array      # (B, C, KH, dh) — C = window (SWA) or max_len
    v: jax.Array
    pos: jax.Array    # scalar int32: tokens seen so far


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> KVCache:
    C = min(cfg.sliding_window, max_len) if cfg.sliding_window else max_len
    shape = (batch, C, cfg.n_kv_heads, cfg.hdim)
    return KVCache(jnp.zeros(shape, jnp.bfloat16),
                   jnp.zeros(shape, jnp.bfloat16), jnp.int32(0))


def decode_self_attention(p, cfg: ModelConfig, x, cache: KVCache):
    """One-token decode step against the (ring) cache."""
    B = x.shape[0]
    C = cache.k.shape[1]
    pos = cache.pos
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = _project_q(p, cfg, x, positions, rope=True)           # (B,1,H,dh)
    k_new, v_new = _project_kv(p, cfg, x, positions, rope=True)
    slot = jnp.mod(pos, C)
    k_cache = jax.lax.dynamic_update_slice(cache.k, k_new, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache.v, v_new, (0, slot, 0, 0))

    # absolute position of each cache slot under ring addressing
    idx = jnp.arange(C)
    n_seen = pos + 1
    abs_pos = jnp.where(
        n_seen <= C, idx,
        jnp.where(idx <= slot, pos - slot + idx, pos - slot - C + idx))
    valid = abs_pos < n_seen
    if cfg.sliding_window:
        valid = valid & (pos - abs_pos < cfg.sliding_window)

    H, KH, dh = cfg.n_heads, cfg.n_kv_heads, cfg.hdim
    G = H // KH
    qh = q.reshape(B, KH, G, dh)
    s = jnp.einsum("bhgd,bchd->bhgc", qh.astype(jnp.bfloat16),
                   k_cache.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32) / np.sqrt(dh)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgc,bchd->bhgd", w.astype(jnp.bfloat16),
                   v_cache.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)
    y = Ly.dense(p["wo"], o.reshape(B, 1, H * dh).astype(x.dtype))
    return y, KVCache(k_cache, v_cache, pos + 1)


def prefill_self_attention(p, cfg: ModelConfig, x, cache: KVCache):
    """Prefill: run blockwise attention AND populate the cache."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    y = self_attention(p, cfg, x, positions)
    k, v = _project_kv(p, cfg, x, positions, rope=True)
    C = cache.k.shape[1]
    if S >= C:   # keep last C entries, ring-aligned so slot = pos % C works
        k_c = jnp.roll(k[:, S - C:], shift=(S - C) % C, axis=1)
        v_c = jnp.roll(v[:, S - C:], shift=(S - C) % C, axis=1)
        cache = KVCache(k_c.astype(jnp.bfloat16), v_c.astype(jnp.bfloat16),
                        jnp.int32(S))
    else:
        k_c = jax.lax.dynamic_update_slice(cache.k, k.astype(jnp.bfloat16),
                                           (0, 0, 0, 0))
        v_c = jax.lax.dynamic_update_slice(cache.v, v.astype(jnp.bfloat16),
                                           (0, 0, 0, 0))
        cache = KVCache(k_c, v_c, jnp.int32(S))
    return y, cache
