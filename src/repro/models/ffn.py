"""Feed-forward blocks: SwiGLU (llama/phi/qwen/mixtral), GeGLU (gemma),
plain GELU (musicgen/BERT-style encoders)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as Ly
from repro.models.config import ModelConfig


def ffn_init(key, cfg: ModelConfig, kind: str) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {"w_gate": Ly.dense_init(ks[0], D, F),
                "w_up": Ly.dense_init(ks[1], D, F),
                "w_down": Ly.dense_init(ks[2], F, D)}
    if kind == "gelu":
        return {"w_up": Ly.dense_init(ks[0], D, F),
                "w_down": Ly.dense_init(ks[1], F, D)}
    raise ValueError(kind)


def ffn_apply(p, x, kind: str) -> jax.Array:
    if kind == "swiglu":
        h = jax.nn.silu(Ly.dense(p["w_gate"], x).astype(jnp.float32)
                        ).astype(x.dtype) * Ly.dense(p["w_up"], x)
    elif kind == "geglu":
        h = jax.nn.gelu(Ly.dense(p["w_gate"], x).astype(jnp.float32),
                        approximate=True).astype(x.dtype) \
            * Ly.dense(p["w_up"], x)
    elif kind == "gelu":
        h = jax.nn.gelu(Ly.dense(p["w_up"], x).astype(jnp.float32),
                        approximate=True).astype(x.dtype)
        return Ly.dense(p["w_down"], h)
    else:
        raise ValueError(kind)
    return Ly.dense(p["w_down"], h)
