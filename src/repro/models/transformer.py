"""Decoder backbone: assembles blocks from a ModelConfig pattern.

Parameters for each pattern position are stacked over ``n_periods`` and the
forward pass is a ``lax.scan`` over periods — HLO size stays O(period)
regardless of depth (48-layer musicgen compiles as fast as 2 layers), and
each period body is rematerialized (``jax.checkpoint``) so training
activation memory is one period's boundary, not the full depth.

The LM head is NOT part of the backbone: training composes
``backbone_apply`` under ``jax.vjp`` with the ELMO head's chunked
fwd/bwd/update (launch/train.py), reproducing the paper's computation
ordering.  ``hidden_for_head`` below is that seam.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as Attn
from repro.models import ffn as Ffn
from repro.models import frontends as Fe
from repro.models import layers as Ly
from repro.models import moe as Moe
from repro.models import ssm as Ssm
from repro.models import xlstm as Xl
from repro.models.config import BlockSpec, ModelConfig


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------


def block_init(key, cfg: ModelConfig, bs: BlockSpec) -> Dict[str, Any]:
    ks = iter(jax.random.split(key, 12))
    p: Dict[str, Any] = {"norm1": Ly.rmsnorm_init(cfg.d_model)}
    if bs.kind == "attn":
        p["attn"] = Attn.attn_init(next(ks), cfg)
    elif bs.kind == "mamba":
        p["ssm"] = Ssm.ssm_init(next(ks), cfg)
    elif bs.kind == "hymba":
        p["attn"] = Attn.attn_init(next(ks), cfg)
        p["ssm"] = Ssm.ssm_init(next(ks), cfg)
        p["norm_attn_out"] = Ly.rmsnorm_init(cfg.d_model)
        p["norm_ssm_out"] = Ly.rmsnorm_init(cfg.d_model)
    elif bs.kind == "mlstm":
        p["mlstm"] = Xl.mlstm_init(next(ks), cfg)
    elif bs.kind == "slstm":
        p["slstm"] = Xl.slstm_init(next(ks), cfg)
    else:
        raise ValueError(bs.kind)
    if bs.cross_attn:
        p["norm_cross"] = Ly.rmsnorm_init(cfg.d_model)
        p["cross"] = Attn.attn_init(next(ks), cfg, cross=True)
    if bs.ffn != "none":
        p["norm2"] = Ly.rmsnorm_init(cfg.d_model)
        if bs.moe:
            p["moe"] = Moe.moe_init(next(ks), cfg)
            if cfg.moe_dense_residual:
                p["ffn"] = Ffn.ffn_init(next(ks), cfg, bs.ffn)
        else:
            p["ffn"] = Ffn.ffn_init(next(ks), cfg, bs.ffn)
    return p


def _mixer_train(p, cfg: ModelConfig, bs: BlockSpec, x, positions):
    if bs.kind == "attn":
        return Attn.self_attention(p["attn"], cfg, x, positions)
    if bs.kind == "mamba":
        return Ssm.ssm_apply(p["ssm"], cfg, x)
    if bs.kind == "hymba":
        a = Attn.self_attention(p["attn"], cfg, x, positions)
        m = Ssm.ssm_apply(p["ssm"], cfg, x)
        return 0.5 * (Ly.rmsnorm(p["norm_attn_out"], a, cfg.norm_eps)
                      + Ly.rmsnorm(p["norm_ssm_out"], m, cfg.norm_eps))
    if bs.kind == "mlstm":
        return Xl.mlstm_apply(p["mlstm"], cfg, x)
    if bs.kind == "slstm":
        return Xl.slstm_apply(p["slstm"], cfg, x)
    raise ValueError(bs.kind)


def _ffn_part(p, cfg: ModelConfig, bs: BlockSpec, x):
    if bs.ffn == "none":
        return jnp.zeros_like(x)
    h = Ly.rmsnorm(p["norm2"], x, cfg.norm_eps)
    if bs.moe:
        y = Moe.moe_apply(p["moe"], cfg, h)
        if cfg.moe_dense_residual:
            y = y + Ffn.ffn_apply(p["ffn"], h, bs.ffn)
        return y
    return Ffn.ffn_apply(p["ffn"], h, bs.ffn)


def block_apply(p, cfg: ModelConfig, bs: BlockSpec, x, positions,
                ctx: Optional[jax.Array]) -> jax.Array:
    h = Ly.rmsnorm(p["norm1"], x, cfg.norm_eps)
    x = x + _mixer_train(p, cfg, bs, h, positions)
    if bs.cross_attn:
        assert ctx is not None, f"{cfg.name}: cross-attn needs ctx embeddings"
        x = x + Attn.cross_attention(
            p["cross"], cfg, Ly.rmsnorm(p["norm_cross"], x, cfg.norm_eps), ctx)
    return x + _ffn_part(p, cfg, bs, x)


# ---------------------------------------------------------------------------
# decode-step block (one token, stateful)
# ---------------------------------------------------------------------------


def block_cache_init(cfg: ModelConfig, bs: BlockSpec, batch: int,
                     max_len: int):
    c: Dict[str, Any] = {}
    if bs.kind in ("attn", "hymba"):
        c["kv"] = Attn.init_cache(cfg, batch, max_len)
    if bs.kind in ("mamba", "hymba"):
        c["ssm"] = Ssm.init_ssm_cache(cfg, batch)
    if bs.kind == "mlstm":
        c["mlstm"] = Xl.init_mlstm_cache(cfg, batch)
    if bs.kind == "slstm":
        c["slstm"] = Xl.init_slstm_cache(cfg, batch)
    return c


def block_decode(p, cfg: ModelConfig, bs: BlockSpec, x, cache,
                 ctx: Optional[jax.Array]):
    h = Ly.rmsnorm(p["norm1"], x, cfg.norm_eps)
    new_cache = dict(cache)
    if bs.kind == "attn":
        y, new_cache["kv"] = Attn.decode_self_attention(p["attn"], cfg, h,
                                                        cache["kv"])
    elif bs.kind == "mamba":
        y, new_cache["ssm"] = Ssm.ssm_decode(p["ssm"], cfg, h, cache["ssm"])
    elif bs.kind == "hymba":
        a, new_cache["kv"] = Attn.decode_self_attention(p["attn"], cfg, h,
                                                        cache["kv"])
        m, new_cache["ssm"] = Ssm.ssm_decode(p["ssm"], cfg, h, cache["ssm"])
        y = 0.5 * (Ly.rmsnorm(p["norm_attn_out"], a, cfg.norm_eps)
                   + Ly.rmsnorm(p["norm_ssm_out"], m, cfg.norm_eps))
    elif bs.kind == "mlstm":
        y, new_cache["mlstm"] = Xl.mlstm_decode(p["mlstm"], cfg, h,
                                                cache["mlstm"])
    elif bs.kind == "slstm":
        y, new_cache["slstm"] = Xl.slstm_decode(p["slstm"], cfg, h,
                                                cache["slstm"])
    else:
        raise ValueError(bs.kind)
    x = x + y
    if bs.cross_attn:
        x = x + Attn.cross_attention(
            p["cross"], cfg, Ly.rmsnorm(p["norm_cross"], x, cfg.norm_eps), ctx)
    return x + _ffn_part(p, cfg, bs, x), new_cache


# ---------------------------------------------------------------------------
# backbone
# ---------------------------------------------------------------------------


class Backbone(NamedTuple):
    embed: jax.Array
    frontend: Dict[str, Any]
    periods: Tuple[Dict[str, Any], ...]   # one stacked tree per pattern slot
    final_norm: jax.Array


def backbone_init(key, cfg: ModelConfig) -> Backbone:
    cfg.validate()
    k_embed, k_front, k_layers = jax.random.split(key, 3)
    embed = Ly.embed_init(k_embed, cfg.vocab, cfg.d_model)
    frontend = Fe.frontend_init(k_front, cfg)

    def init_slot(bs: BlockSpec, slot_key):
        keys = jax.random.split(slot_key, cfg.n_periods)
        return jax.vmap(lambda k: block_init(k, cfg, bs))(keys)

    slot_keys = jax.random.split(k_layers, cfg.period)
    periods = tuple(init_slot(bs, sk)
                    for bs, sk in zip(cfg.pattern, slot_keys))
    return Backbone(embed, frontend, periods, Ly.rmsnorm_init(cfg.d_model))


def _embed_inputs(params: Backbone, cfg: ModelConfig, tokens,
                  frontend_embeds):
    if cfg.frontend == "audio_frames":
        return Fe.frontend_apply(params.frontend, cfg, frontend_embeds), None
    x = Ly.embed_lookup(params.embed, tokens)
    ctx = None
    if cfg.frontend == "vision":
        ctx = Fe.frontend_apply(params.frontend, cfg, frontend_embeds)
    return x, ctx


def _seq_shard(x: jax.Array) -> jax.Array:
    """Sequence parallelism (Megatron-SP style): period-boundary activations
    — the tensors remat SAVES for the backward pass — are sharded over the
    model axis along S, so saved-activation memory scales with the full
    chip count instead of only the data axis.  XLA inserts the all-gather /
    reduce-scatter pair around each block from this constraint alone."""
    from repro.dist import meshctx
    ctx = meshctx.get()
    if ctx is None or ctx.model_size <= 1 or x.ndim != 3:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    if ctx.model_axis in ctx.batch_axes:   # fsdp_pure: no SP, batch only
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(ctx.mesh, P(ctx.batch_axes, None, None)))
    if x.shape[1] % ctx.model_size != 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(ctx.batch_axes, ctx.model_axis, None)))


def backbone_apply(params: Backbone, cfg: ModelConfig, tokens: jax.Array,
                   frontend_embeds: Optional[jax.Array] = None,
                   remat: bool = True) -> jax.Array:
    """tokens: (B, S) int32 → hidden (B, S, D) bf16 (pre-head)."""
    x, ctx = _embed_inputs(params, cfg, tokens, frontend_embeds)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def period_body(x, period_slice):
        x = _seq_shard(x)
        for bs, p in zip(cfg.pattern, period_slice):
            x = block_apply(p, cfg, bs, x, positions, ctx)
        return _seq_shard(x), None

    body = jax.checkpoint(period_body) if remat else period_body
    x = _seq_shard(x)
    x, _ = jax.lax.scan(lambda c, xs: body(c, xs), x, params.periods)
    return Ly.rmsnorm(params.final_norm, x, cfg.norm_eps)


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    def stack(bs):
        one = block_cache_init(cfg, bs, batch, max_len)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_periods,) + a.shape).copy()
            if hasattr(a, "shape") else a, one)
    return tuple(stack(bs) for bs in cfg.pattern)


def backbone_decode_step(params: Backbone, cfg: ModelConfig,
                         token: jax.Array, caches,
                         frontend_embeds: Optional[jax.Array] = None):
    """token: (B, 1) int32 (or (B,1,D_frontend) embeds for audio) → hidden
    (B, 1, D) + updated caches.

    Caches ride in the scan CARRY and are updated slice-in-place
    (dynamic_update_index), so XLA aliases one cache buffer instead of
    double-buffering xs→ys — at 32k context this halves decode memory."""
    x, ctx = _embed_inputs(params, cfg, token, frontend_embeds)

    def period_body(carry, inp):
        x, caches = carry
        param_slice, j = inp
        cache_slice = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, j, 0, keepdims=False),
            caches)
        new_slices = []
        for bs, p, c in zip(cfg.pattern, param_slice, cache_slice):
            x, c_new = block_decode(p, cfg, bs, x, c, ctx)
            new_slices.append(c_new)
        caches = jax.tree.map(
            lambda a, s: jax.lax.dynamic_update_index_in_dim(
                a, s.astype(a.dtype), j, 0),
            caches, tuple(new_slices))
        return (x, caches), None

    (x, new_caches), _ = jax.lax.scan(
        period_body, (x, caches),
        (params.periods, jnp.arange(cfg.n_periods, dtype=jnp.int32)))
    return Ly.rmsnorm(params.final_norm, x, cfg.norm_eps), new_caches
